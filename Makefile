# Build entry points.  Tier-1 verify needs only `make build test`
# (native backend, zero artifacts).  The artifact targets require a
# python environment with jax (the AOT / PJRT path).

.PHONY: build test gen artifacts artifacts-efficiency artifacts-ablation artifacts-lra fmt

build:
	cargo build --release

test:
	cargo test -q

# Native-runnable artifact directories (manifest.json only).
gen: build
	./target/release/cast gen --out artifacts

artifacts:
	cd python && python -m compile.aot --suite default --out-root ../artifacts

artifacts-efficiency:
	cd python && python -m compile.aot --suite efficiency --out-root ../artifacts

artifacts-ablation:
	cd python && python -m compile.aot --suite ablation --out-root ../artifacts

artifacts-lra:
	cd python && python -m compile.aot --suite lra --out-root ../artifacts

fmt:
	cargo fmt --all --check
