# Build entry points.  Tier-1 verify needs only `make build test`
# (native backend, zero artifacts).  The artifact targets require a
# python environment with jax (the AOT / PJRT path).

.PHONY: build test test-simd test-serve test-chaos test-trace test-memstats gen artifacts artifacts-efficiency artifacts-ablation artifacts-lra fmt clippy bench-json bench-simd serve bench-serve bench-profile bench-decode bench-memory

build:
	cargo build --release

test:
	cargo test -q

# SIMD parity + determinism suite under both dispatch modes (the lane
# kernels and the CAST_NO_SIMD=1 scalar reference; see DESIGN.md §SIMD).
test-simd:
	cargo test -q --test integration_simd
	CAST_NO_SIMD=1 cargo test -q --test integration_simd

clippy:
	cargo clippy --all-targets -- -D warnings

# Native-runnable artifact directories (manifest.json only).
gen: build
	./target/release/cast gen --out artifacts

# Measured perf trajectory: N=2048 native configs through the threaded
# engine, emitting BENCH_native.json (CAST_NUM_THREADS=1 for the serial
# baseline; see DESIGN.md §Threading).
bench-json: build
	./target/release/cast gen --out bench_artifacts --seq 2048 --nc 16 --kappa 128
	CAST_NUM_THREADS=1 ./target/release/cast bench --table 5 --artifacts bench_artifacts --seq 2048 --steps 3 --json BENCH_native_t1.json
	./target/release/cast bench --table 5 --artifacts bench_artifacts --seq 2048 --steps 3 --json BENCH_native.json

# SIMD speedup measurement: the seq=1024 CAST config once with the lane
# kernels and once with the scalar reference, appended as a row pair to
# BENCH_native.json (acceptance: simd steps_per_sec >= 1.5x scalar).
bench-simd: build
	./target/release/cast gen --out bench_simd_artifacts --variant cast_topk --seq 1024 --nc 8 --kappa 128
	./target/release/cast bench --table 5 --artifacts bench_simd_artifacts --seq 1024 --steps 5 --append-json BENCH_native.json
	CAST_NO_SIMD=1 ./target/release/cast bench --table 5 --artifacts bench_simd_artifacts --seq 1024 --steps 5 --append-json BENCH_native.json

# Serve-stack integration suite (HTTP parser, TCP round trips, batching
# determinism, graceful drain).
test-serve:
	cargo test -q --test integration_serve

# Chaos suite: server + trainer under seeded CAST_FAULTS plans (worker
# panics, deadline shedding, breaker trips, NaN steps, torn checkpoint
# writes; see DESIGN.md §Robustness).
test-chaos:
	cargo test -q --test integration_chaos

# Tracing suite: disabled-path zero-cost + bit-identical outputs, span
# trees, serve stage histograms, Chrome export (DESIGN.md §Observability).
test-trace:
	cargo test -q --test integration_trace

# Memory-observability suite: tracking-allocator accounting, disabled-
# path no-heap-traffic guards, bit-identical instrumented outputs, and
# the measured O(αN)-vs-O(N²) curve property test (DESIGN.md
# §Observability — the suite installs its own #[global_allocator]).
test-memstats:
	cargo test -q --test integration_memstats

# Measured attention-memory curves: the tracking allocator's peak-bytes
# watermark over the materializing cast/vanilla reference kernels across
# the paper's sequence sweep, appended as mem_peak_bytes rows to
# BENCH_native.json and printed against the §3.4 analytic model.
bench-memory: build
	./target/release/cast bench --memory --seq 512,1024,2048,4096,8192 \
	  --append-json BENCH_native.json

# Per-op time-share profile of the seq-1024 CAST config, plus a Chrome
# trace for Perfetto (see DESIGN.md §Observability for reading it).
bench-profile: build
	./target/release/cast gen --out bench_profile_artifacts --variant cast_topk --seq 1024 --nc 8 --kappa 128
	./target/release/cast bench --table 5 --artifacts bench_profile_artifacts --seq 1024 --steps 5 --profile --trace-out trace.json

# Run the inference server on a zero-artifact seq-1024 CAST config
# (ctrl-c drains gracefully; see DESIGN.md §Serving for the endpoints).
serve: build
	./target/release/cast serve --variant cast_topk --seq 1024 --nc 8 --kappa 128 --max-batch 8

# Serve throughput measurement: the seq-1024 CAST config under 16
# concurrent loadgen connections, once with --max-batch 8 and once with
# --max-batch 1, appended as a serve_reqs_per_sec row pair to
# BENCH_native.json (acceptance: batched >= 2x unbatched req/s).
bench-serve: build
	for mb in 8 1; do \
	  ./target/release/cast serve --variant cast_topk --seq 1024 --nc 8 --kappa 128 \
	    --addr 127.0.0.1:8477 --max-batch $$mb & pid=$$!; \
	  sleep 2; \
	  ./target/release/cast loadgen --addr 127.0.0.1:8477 --conns 16 --requests 25 \
	    --bench-json BENCH_native.json || { kill $$pid 2>/dev/null; exit 1; }; \
	  kill $$pid 2>/dev/null; wait $$pid 2>/dev/null || true; \
	done

# Incremental-decode throughput: greedy generation through the causal
# cluster-state cache vs full-forward recompute at two sequence lengths,
# parity-checked, appended as decode_tokens_per_sec rows to
# BENCH_native.json (acceptance: late-third tok/s ~= early-third tok/s,
# i.e. per-token cost does not grow with generated length).
bench-decode: build
	./target/release/cast bench --decode --seq 256,512 --kappa 32 --max-new 96 \
	  --append-json BENCH_native.json

artifacts:
	cd python && python -m compile.aot --suite default --out-root ../artifacts

artifacts-efficiency:
	cd python && python -m compile.aot --suite efficiency --out-root ../artifacts

artifacts-ablation:
	cd python && python -m compile.aot --suite ablation --out-root ../artifacts

artifacts-lra:
	cd python && python -m compile.aot --suite lra --out-root ../artifacts

fmt:
	cargo fmt --all --check
