"""Extensions beyond the paper's evaluated scope:

* LSH attention baseline (Reformer-style) — the paper's main clustering
  comparator (§2, Appendix A.6.4).
* Causal CAST (decoder variant) — the paper's §5.5 future work: causal
  greedy clustering (position-order assignment) + causal intra-cluster
  attention, no summaries.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import attention_baselines, cast_layer, clustering, model, train
from compile.configs import tiny

jax.config.update("jax_platform_name", "cpu")


# ---------------------------------------------------------------------------
# LSH baseline
# ---------------------------------------------------------------------------


def test_lsh_forward_and_grad():
    cfg = tiny("lsh")
    p = model.init(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 64), 0, 256)
    logits = model.forward(p, tokens, cfg)
    assert logits.shape == (2, 2)
    assert bool(jnp.all(jnp.isfinite(logits)))
    g = jax.grad(lambda pp: model.forward(pp, tokens, cfg).sum())(p)
    assert all(bool(jnp.all(jnp.isfinite(l))) for l in jax.tree_util.tree_leaves(g))


def test_lsh_buckets_cluster_similar_directions():
    """Same-direction vectors hash to the same bucket; opposite vectors
    to a different one (the LSH property CAST replaces with learning)."""
    d = 8
    base = jax.random.normal(jax.random.PRNGKey(2), (1, 1, d))
    qk = jnp.concatenate([base, base * 2.0, -base], axis=1)  # (1, 3, d)
    b = attention_baselines.lsh_buckets(qk, n_buckets=8)
    b = np.asarray(b)[0]
    assert b[0] == b[1], "parallel vectors must share a bucket"
    assert b[0] != b[2], "antipodal vectors must differ"


def test_lsh_trains():
    cfg = tiny("lsh")
    p = model.init(jax.random.PRNGKey(3), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(4), (2, 64), 0, 256)
    labels = jnp.array([0, 1], dtype=jnp.int32)
    m = train.zeros_like_tree(p)
    v = train.zeros_like_tree(p)
    step = jnp.float32(0)
    losses = []
    jit_step = jax.jit(
        lambda p, m, v, s: train.train_step(p, m, v, s, jnp.float32(3e-3), tokens, labels, cfg)
    )
    for _ in range(10):
        p, m, v, step, loss, _ = jit_step(p, m, v, step)
        losses.append(float(loss))
    assert losses[-1] < losses[0]


# ---------------------------------------------------------------------------
# Causal CAST (decoder extension)
# ---------------------------------------------------------------------------


def causal_setup(seed=0):
    cfg = tiny("cast_sa", causal=True)
    p = cast_layer.init(jax.random.PRNGKey(seed), cfg)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (2, cfg.seq_len, cfg.d))
    return cfg, p, x


def test_causal_no_future_leakage():
    """THE decoder property: output at position t is invariant to any
    perturbation of tokens at positions > t — through clustering AND
    attention."""
    cfg, p, x = causal_setup()
    out0 = cast_layer.apply(p, x, cfg)
    for t in [20, 40, 63]:
        x2 = x.at[0, t].add(7.0)
        out1 = cast_layer.apply(p, x2, cfg)
        delta = np.abs(np.asarray(out1 - out0))[0].sum(-1)
        assert delta[:t].max() == 0.0, f"future leak at perturbation {t}"
        assert delta[t:].max() > 0.0, "perturbation must affect its own future"


def test_causal_clustering_is_prefix_deterministic():
    """Token n's assignment must not change when suffix tokens change."""
    a = jax.random.normal(jax.random.PRNGKey(5), (1, 32, 4))
    idx0, valid0, _ = clustering.cluster(a, 8, "causal")
    a2 = a.at[:, 20:].add(3.0)
    idx1, valid1, _ = clustering.cluster(a2, 8, "causal")

    def assignment_of(idx, valid, token):
        idx = np.asarray(idx)[0]
        valid = np.asarray(valid)[0]
        for c in range(idx.shape[0]):
            for k in range(idx.shape[1]):
                if valid[c, k] and idx[c, k] == token:
                    return c
        return -1

    for t in range(20):
        assert assignment_of(idx0, valid0, t) == assignment_of(idx1, valid1, t), t


def test_causal_clustering_partitions_all_tokens():
    a = jax.random.normal(jax.random.PRNGKey(6), (2, 32, 4))
    idx, valid, member = clustering.cluster(a, 8, "causal")
    assert bool(jnp.all(valid == 1.0))
    for b in range(2):
        flat = sorted(np.asarray(idx)[b].reshape(-1).tolist())
        assert flat == list(range(32))


def test_causal_kernel_matches_causal_ref():
    from compile.kernels import cast_kernel, ref

    key = jax.random.PRNGKey(7)
    ks = jax.random.split(key, 4)
    g, kappa, d_h = 4, 16, 8
    q = jax.random.normal(ks[0], (g, kappa, d_h))
    k = jax.random.normal(ks[1], (g, kappa, d_h))
    v = jax.random.normal(ks[2], (g, kappa, d_h))
    pos = jax.random.permutation(ks[3], jnp.arange(g * kappa, dtype=jnp.float32)).reshape(
        g, kappa
    )
    valid = jnp.ones((g, kappa)).at[0, -3:].set(0.0)
    rp = cast_kernel.cast_core_causal_pallas(q, k, v, pos, valid)
    rr = ref.cast_core_causal_ref(q, k, v, pos, valid)
    np.testing.assert_allclose(rp, rr, atol=1e-5, rtol=1e-5)


def test_causal_first_position_attends_only_itself():
    """The globally-first position's output equals its own value row."""
    from compile.kernels import ref

    g, kappa, d_h = 1, 8, 4
    key = jax.random.PRNGKey(8)
    q = jax.random.normal(key, (g, kappa, d_h))
    k = jax.random.normal(jax.random.PRNGKey(9), (g, kappa, d_h))
    v = jax.random.normal(jax.random.PRNGKey(10), (g, kappa, d_h))
    pos = jnp.arange(kappa, dtype=jnp.float32)[None, :]
    valid = jnp.ones((g, kappa))
    r = ref.cast_core_causal_ref(q, k, v, pos, valid)
    np.testing.assert_allclose(r[0, 0], v[0, 0], atol=1e-6)


def test_causal_model_trains():
    cfg = tiny("cast_sa", causal=True)
    p = model.init(jax.random.PRNGKey(11), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(12), (2, 64), 0, 256)
    labels = jnp.array([1, 0], dtype=jnp.int32)
    m = train.zeros_like_tree(p)
    v = train.zeros_like_tree(p)
    step = jnp.float32(0)
    losses = []
    jit_step = jax.jit(
        lambda p, m, v, s: train.train_step(p, m, v, s, jnp.float32(3e-3), tokens, labels, cfg)
    )
    for _ in range(10):
        p, m, v, step, loss, _ = jit_step(p, m, v, step)
        losses.append(float(loss))
    assert losses[-1] < losses[0]
    assert all(np.isfinite(losses))
