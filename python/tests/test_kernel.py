"""L1 correctness: the fused Pallas kernel against the pure-jnp oracle.

Hypothesis sweeps shapes, masks, and attention functions; every case
asserts allclose between `cast_core` (pallas, interpret=True) and
`cast_core_ref`.  Gradients through the custom_vjp wrapper are also pinned
to the oracle's VJP.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import cast_kernel, ref

jax.config.update("jax_platform_name", "cpu")


def make_inputs(key, g, kappa, d_h, pad_last=0):
    ks = jax.random.split(key, 4)
    q = jax.random.normal(ks[0], (g, kappa, d_h), jnp.float32)
    k = jax.random.normal(ks[1], (g, kappa, d_h), jnp.float32)
    v = jax.random.normal(ks[2], (g, kappa, d_h), jnp.float32)
    w = jax.random.normal(ks[3], (g, kappa), jnp.float32)
    valid = jnp.ones((g, kappa), jnp.float32)
    if pad_last:
        valid = valid.at[:, kappa - pad_last:].set(0.0)
    return q, k, v, w, valid


@settings(max_examples=20, deadline=None)
@given(
    g=st.integers(1, 6),
    kappa=st.sampled_from([4, 8, 16, 32]),
    d_h=st.sampled_from([4, 8, 16]),
    pad=st.integers(0, 3),
    seed=st.integers(0, 2**16),
)
def test_kernel_matches_ref_softmax(g, kappa, d_h, pad, seed):
    pad = min(pad, kappa - 1)
    inputs = make_inputs(jax.random.PRNGKey(seed), g, kappa, d_h, pad)
    ri_p, rs_p = cast_kernel.cast_core_pallas(*inputs, "softmax")
    ri_r, rs_r = ref.cast_core_ref(*inputs, "softmax")
    np.testing.assert_allclose(ri_p, ri_r, atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(rs_p, rs_r, atol=1e-5, rtol=1e-5)


@settings(max_examples=10, deadline=None)
@given(
    kappa=st.sampled_from([8, 16]),
    d_h=st.sampled_from([4, 8]),
    seed=st.integers(0, 2**16),
)
def test_kernel_matches_ref_laplace(kappa, d_h, seed):
    inputs = make_inputs(jax.random.PRNGKey(seed), 3, kappa, d_h, 2)
    ri_p, rs_p = cast_kernel.cast_core_pallas(*inputs, "laplace")
    ri_r, rs_r = ref.cast_core_ref(*inputs, "laplace")
    # Laplace rows whose every score sits in the erf tail normalize by a
    # sum near the 1e-6 clamp floor, where the kernel-vs-einsum 1e-6 score
    # drift is amplified ~1e4x.  The softmax test above pins the tight
    # tolerance on the production path; here we bound the degenerate-row
    # amplification instead.
    np.testing.assert_allclose(ri_p, ri_r, atol=2e-2, rtol=2e-2)
    np.testing.assert_allclose(rs_p, rs_r, atol=2e-2, rtol=2e-2)


def test_gradients_match_oracle_vjp():
    inputs = make_inputs(jax.random.PRNGKey(0), 4, 16, 8, pad_last=3)
    q, k, v, w, valid = inputs

    def loss_pallas(q, k, v, w):
        ri, rs = cast_kernel.cast_core(q, k, v, w, valid, "softmax")
        return jnp.sum(ri * ri) + jnp.sum(rs)

    def loss_ref(q, k, v, w):
        ri, rs = ref.cast_core_ref(q, k, v, w, valid, "softmax")
        return jnp.sum(ri * ri) + jnp.sum(rs)

    gp = jax.grad(loss_pallas, argnums=(0, 1, 2, 3))(q, k, v, w)
    gr = jax.grad(loss_ref, argnums=(0, 1, 2, 3))(q, k, v, w)
    for a, b in zip(gp, gr):
        np.testing.assert_allclose(a, b, atol=1e-5, rtol=1e-5)


def test_padding_rows_produce_zero_output():
    q, k, v, w, valid = make_inputs(jax.random.PRNGKey(1), 2, 8, 4, pad_last=3)
    ri, _ = cast_kernel.cast_core_pallas(q, k, v, w, valid, "softmax")
    np.testing.assert_allclose(ri[:, -3:, :], 0.0, atol=1e-7)


def test_attention_rows_are_convex_combinations():
    """Softmax attention output lies within [min(V), max(V)] per feature."""
    q, k, v, w, valid = make_inputs(jax.random.PRNGKey(2), 3, 16, 8)
    ri, rs = cast_kernel.cast_core_pallas(q, k, v, w, valid, "softmax")
    vmin = jnp.min(v, axis=1, keepdims=True)
    vmax = jnp.max(v, axis=1, keepdims=True)
    assert bool(jnp.all(ri >= vmin - 1e-5)) and bool(jnp.all(ri <= vmax + 1e-5))
    assert bool(jnp.all(rs >= vmin[:, 0] - 1e-5)) and bool(jnp.all(rs <= vmax[:, 0] + 1e-5))


def test_single_token_cluster_is_identity_on_values():
    """kappa=1: attention over one token returns exactly that value row."""
    q, k, v, w, valid = make_inputs(jax.random.PRNGKey(3), 2, 1, 8)
    ri, rs = cast_kernel.cast_core_pallas(q, k, v, w, valid, "softmax")
    np.testing.assert_allclose(ri[:, 0], v[:, 0], atol=1e-6)
    np.testing.assert_allclose(rs, v[:, 0], atol=1e-6)


def test_kernel_is_permutation_equivariant_in_keys():
    """Permuting (K,V) rows together leaves R_intra unchanged."""
    q, k, v, w, valid = make_inputs(jax.random.PRNGKey(4), 1, 8, 4)
    perm = jnp.array([3, 1, 0, 2, 7, 6, 5, 4])
    ri1, _ = cast_kernel.cast_core_pallas(q, k, v, w, valid, "softmax")
    ri2, _ = cast_kernel.cast_core_pallas(
        q, k[:, perm], v[:, perm], w[:, perm], valid, "softmax"
    )
    np.testing.assert_allclose(ri1, ri2, atol=1e-5, rtol=1e-5)
