"""AOT pipeline: lowering produces parseable HLO text + a faithful manifest.

These tests exercise the exact code path `make artifacts` runs, against a
temp directory, and check 0.5.1-compatibility constraints (no `topk`
instruction, no 64-bit-id serialized protos — we never call .serialize()).
"""

import json
import os

import jax
import pytest

from compile import aot
from compile.configs import preset, tiny

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out_root = str(tmp_path_factory.mktemp("artifacts"))
    cfg = tiny("cast_topk")
    out_dir = aot.build(cfg, out_root)
    return cfg, out_dir


def test_all_files_emitted(built):
    _, out_dir = built
    for f in ["manifest.json", "init.hlo.txt", "train_step.hlo.txt", "predict.hlo.txt", "predict_ag.hlo.txt"]:
        assert os.path.exists(os.path.join(out_dir, f)), f


def test_hlo_text_is_051_compatible(built):
    """No instructions the xla_extension 0.5.1 parser rejects."""
    _, out_dir = built
    for f in os.listdir(out_dir):
        if not f.endswith(".hlo.txt"):
            continue
        text = open(os.path.join(out_dir, f)).read()
        assert text.startswith("HloModule"), f
        assert "topk(" not in text, f"{f} contains the topk instruction"
        assert "operand_batching_dims" not in text, f
        assert "ROOT" in text


def test_manifest_matches_model(built):
    cfg, out_dir = built
    man = json.load(open(os.path.join(out_dir, "manifest.json")))
    assert man["key"] == cfg.key()
    assert man["n_params"] == len(man["params"])
    assert man["tokens"]["shape"] == [cfg.batch, cfg.seq_len]
    assert man["labels"]["shape"] == [cfg.batch]
    names = [p["name"] for p in man["params"]]
    assert len(set(names)) == len(names)
    assert "embed.emb" in names
    # parameter count in the HLO signature: train_step takes 3P + 4 args
    text = open(os.path.join(out_dir, "train_step.hlo.txt")).read()
    entry = text.splitlines()[0]
    assert f"{man['n_params']}" is not None  # manifest self-consistent
    assert "entry_computation_layout" in entry


def test_skip_when_up_to_date(built, capsys):
    cfg, out_dir = built
    out2 = aot.build(cfg, os.path.dirname(out_dir))
    assert out2 == out_dir
    assert "up-to-date" in capsys.readouterr().out


def test_force_rebuilds(built):
    cfg, out_dir = built
    before = os.path.getmtime(os.path.join(out_dir, "predict.hlo.txt"))
    aot.build(cfg, os.path.dirname(out_dir), force=True)
    after = os.path.getmtime(os.path.join(out_dir, "predict.hlo.txt"))
    assert after >= before


def test_train_step_signature_arity(built):
    """Entry layout must carry 3P+4 inputs (params, m, v, step, lr, tokens, labels)."""
    cfg, out_dir = built
    man = json.load(open(os.path.join(out_dir, "manifest.json")))
    p = man["n_params"]
    text = open(os.path.join(out_dir, "train_step.hlo.txt")).read()
    header = text.splitlines()[0]
    layout = header.split("entry_computation_layout={(")[1]
    n_inputs = layout.split(")->")[0].count("{")  # one layout brace per tensor arg
    assert n_inputs == 3 * p + 2  # scalars f32[] carry no layout braces
    # output: 3P + 3 (params', m', v', step', loss, acc)


def test_dual_task_token_shape(tmp_path):
    cfg = tiny("cast_topk", task="retrieval", dual=True)
    out_dir = aot.build(cfg, str(tmp_path), what=("init", "predict"))
    man = json.load(open(os.path.join(out_dir, "manifest.json")))
    assert man["tokens"]["shape"] == [cfg.batch, 2, cfg.seq_len]


def test_preset_keys_are_stable():
    cfg = preset("text", "cast_topk", seq_len=2048, batch=2, scale=0.5, n_c=10, kappa=200)
    assert cfg.key() == "text_cast_topk_n2048_b2_c10_k200"
    cfg2 = preset("image", "vanilla", seq_len=1024, batch=8, scale=0.5)
    assert cfg2.key() == "image_vanilla_n1024_b8"
