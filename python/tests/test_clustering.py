"""Clustering mechanism invariants (paper Algorithms 1 & 2)."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import clustering

jax.config.update("jax_platform_name", "cpu")


def random_ag(seed, b, n, n_c):
    return jax.random.normal(jax.random.PRNGKey(seed), (b, n, n_c), jnp.float32)


@settings(max_examples=15, deadline=None)
@given(
    b=st.integers(1, 3),
    n=st.sampled_from([16, 32, 64]),
    n_c=st.sampled_from([2, 4, 8]),
    seed=st.integers(0, 2**16),
)
def test_topk_picks_highest_affinity(b, n, n_c, seed):
    kappa = n // n_c
    ag = random_ag(seed, b, n, n_c)
    idx, valid, member = clustering.cluster(ag, kappa, "topk")
    assert idx.shape == (b, n_c, kappa)
    assert bool(jnp.all(valid == 1.0))
    ag_np = np.asarray(ag)
    idx_np = np.asarray(idx)
    for bi in range(b):
        for c in range(n_c):
            chosen = set(idx_np[bi, c].tolist())
            kth = np.sort(ag_np[bi, :, c])[-kappa]
            for t in chosen:
                assert ag_np[bi, t, c] >= kth - 1e-6


@settings(max_examples=10, deadline=None)
@given(
    b=st.integers(1, 2),
    n=st.sampled_from([16, 32]),
    n_c=st.sampled_from([2, 4]),
    seed=st.integers(0, 2**16),
)
def test_sa_topk_single_assignment_partition(b, n, n_c, seed):
    """SA Top-K with Nc*kappa == N must produce an exact partition."""
    kappa = n // n_c
    ag = random_ag(seed, b, n, n_c)
    idx, valid, member = clustering.cluster(ag, kappa, "sa")
    assert bool(jnp.all(valid == 1.0)), "all slots fill when Nc*kappa == N"
    idx_np = np.asarray(idx)
    for bi in range(b):
        flat = idx_np[bi].reshape(-1)
        assert sorted(flat.tolist()) == list(range(n)), "every token exactly once"
    # membership mask rows sum to exactly 1
    msum = np.asarray(member.sum(axis=2))
    np.testing.assert_allclose(msum, 1.0, atol=1e-6)


def test_sa_topk_greedy_priority():
    """The single highest-affinity token gets its preferred cluster."""
    ag = jnp.array([[[0.0, 5.0], [0.1, 0.2], [0.3, 0.1], [0.2, 0.0]]])  # (1,4,2)
    idx, valid, _ = clustering.cluster(ag, 2, "sa")
    # token 0 prefers cluster 1 with the globally highest score
    assert 0 in np.asarray(idx)[0, 1].tolist()


def test_sa_topk_capacity_respected():
    """When one cluster dominates, overflow tokens spill to the other."""
    n, n_c, kappa = 8, 2, 4
    ag = jnp.zeros((1, n, n_c)).at[:, :, 0].set(1.0)  # everyone prefers cluster 0
    idx, valid, member = clustering.cluster(ag, kappa, "sa")
    idx_np = np.asarray(idx)[0]
    assert len(set(idx_np[0].tolist())) == kappa
    assert sorted(np.concatenate([idx_np[0], idx_np[1]]).tolist()) == list(range(n))


def test_membership_matches_indices():
    ag = random_ag(3, 2, 32, 4)
    idx, valid, member = clustering.cluster(ag, 8, "topk")
    m = np.asarray(member)
    idx_np = np.asarray(idx)
    for bi in range(2):
        for c in range(4):
            for t in range(32):
                expected = 1.0 if t in idx_np[bi, c] else 0.0
                assert m[bi, t, c] == expected


def test_gather_scatter_roundtrip():
    """G^{-1}(G(x)) with a partition reproduces x (sum of single copy)."""
    b, n, n_c, kappa = 2, 16, 4, 4
    ag = random_ag(5, b, n, n_c)
    idx, valid, _ = clustering.cluster(ag, kappa, "sa")
    x = jax.random.normal(jax.random.PRNGKey(9), (b, n, 3))
    gathered = clustering.gather(idx, x)
    assert gathered.shape == (b, n_c, kappa, 3)
    back = clustering.scatter_add(idx, gathered, n)
    np.testing.assert_allclose(back, x, atol=1e-6)


def test_scatter_add_sums_duplicates():
    """Top-K can assign one token to several clusters; G^{-1} must sum."""
    idx = jnp.array([[[0, 1], [0, 2]]], dtype=jnp.int32)  # token 0 in both
    vals = jnp.ones((1, 2, 2, 1))
    out = clustering.scatter_add(idx, vals, 4)
    np.testing.assert_allclose(np.asarray(out)[0, :, 0], [2.0, 1.0, 1.0, 0.0])


def test_topk_padding_affinity_zero_excluded():
    """Paper §3.2: padding with affinity 0 is never clustered when real
    tokens have positive affinity."""
    n, n_c, kappa = 8, 2, 2
    ag = jnp.full((1, n, n_c), 0.0).at[:, :4, :].set(1.0)  # tokens 4..7 are "padding"
    idx, _, _ = clustering.cluster(ag, kappa, "topk")
    assert np.asarray(idx).max() < 4
