"""The composed CAST layer: shape/semantics invariants (paper §3.2–3.3)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import cast_layer, clustering, layers
from compile.configs import tiny

jax.config.update("jax_platform_name", "cpu")


def setup(variant="cast_topk", **kw):
    cfg = tiny(variant, **kw)
    key = jax.random.PRNGKey(0)
    p = cast_layer.init(key, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (cfg.batch, cfg.seq_len, cfg.d))
    return cfg, p, x


@settings(max_examples=8, deadline=None)
@given(
    variant=st.sampled_from(["cast_topk", "cast_sa"]),
    h=st.sampled_from([1, 2, 4]),
    seed=st.integers(0, 1000),
)
def test_output_shape_and_finiteness(variant, h, seed):
    cfg = tiny(variant, h=h, d=16)
    p = cast_layer.init(jax.random.PRNGKey(seed), cfg)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (cfg.batch, cfg.seq_len, cfg.d))
    out = cast_layer.apply(p, x, cfg)
    assert out.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(out)))


def test_pallas_and_reference_paths_agree():
    """use_pallas toggles L1 kernel vs oracle; outputs must be identical."""
    cfg_p, p, x = setup(use_pallas=True)
    cfg_r = tiny("cast_topk", use_pallas=False)
    out_p = cast_layer.apply(p, x, cfg_p)
    out_r = cast_layer.apply(p, x, cfg_r)
    np.testing.assert_allclose(out_p, out_r, atol=1e-5, rtol=1e-5)


def test_ag_rows_are_distributions():
    cfg, p, x = setup()
    _, a_g = cast_layer.apply(p, x, cfg, return_ag=True)
    assert a_g.shape == (cfg.batch, cfg.seq_len, cfg.n_c)
    sums = np.asarray(a_g.sum(axis=-1))
    np.testing.assert_allclose(sums, 1.0, atol=1e-5)
    assert np.all(np.asarray(a_g) >= 0.0)


def test_gradients_flow_to_all_parameters():
    cfg, p, x = setup()

    def loss(p):
        return jnp.sum(cast_layer.apply(p, x, cfg) ** 2)

    grads = jax.grad(loss)(p)
    flat, _ = jax.tree_util.tree_flatten(grads)
    for g in flat:
        assert bool(jnp.all(jnp.isfinite(g)))
    # surrogate tokens must receive gradient (the paper's central learnable)
    assert float(jnp.abs(grads["s"]).max()) > 0.0
    # phi gate gets gradient through both A_g mixing and A_sum weighting
    assert float(jnp.abs(grads["phi"]["w"]).max()) > 0.0


def test_gradients_flow_to_input_every_token():
    """Cluster summaries guarantee every token has a gradient path (the
    paper's stability argument for SA Top-K + summaries)."""
    cfg, p, x = setup("cast_sa")

    def loss(x):
        return jnp.sum(cast_layer.apply(p, x, cfg) ** 2)

    g = jax.grad(loss)(x)
    per_token = np.asarray(jnp.abs(g).sum(axis=-1))  # (B, N)
    assert (per_token > 0).all(), "some token received no gradient"


def test_information_flows_across_clusters():
    """Perturbing a token in one cluster must change outputs of tokens in
    OTHER clusters via R_inter — CAST's key property vs local attention."""
    cfg, p, x = setup("cast_sa")
    out0 = cast_layer.apply(p, x, cfg)
    _, a_g = cast_layer.apply(p, x, cfg, return_ag=True)
    idx, _, _ = clustering.cluster(a_g, cfg.kappa, "sa")
    idx = np.asarray(idx)  # (B, Nc, kappa)
    # perturb the first token of cluster 0 (batch 0)
    t0 = int(idx[0, 0, 0])
    x2 = x.at[0, t0].add(3.0)
    out1 = cast_layer.apply(p, x2, cfg)
    delta = np.asarray(jnp.abs(out1 - out0).sum(axis=-1))[0]  # (N,)
    other_cluster_tokens = [int(t) for t in idx[0, 1]]
    moved = sum(delta[t] for t in other_cluster_tokens)
    assert moved > 1e-6, "no information flow to other clusters"


def test_single_cluster_limit_is_dense_attention_mixture():
    """Nc=1, kappa=N: every token in one cluster; output finite & dense."""
    cfg = tiny("cast_topk", n_c=1, kappa=64)
    p = cast_layer.init(jax.random.PRNGKey(2), cfg)
    x = jax.random.normal(jax.random.PRNGKey(3), (cfg.batch, cfg.seq_len, cfg.d))
    out = cast_layer.apply(p, x, cfg)
    assert bool(jnp.all(jnp.isfinite(out)))


def test_laplace_attention_variant():
    cfg = tiny("cast_topk", attn_fn="laplace")
    p = cast_layer.init(jax.random.PRNGKey(4), cfg)
    x = jax.random.normal(jax.random.PRNGKey(5), (cfg.batch, cfg.seq_len, cfg.d))
    out = cast_layer.apply(p, x, cfg)
    assert bool(jnp.all(jnp.isfinite(out)))


def test_phi_gate_bounds():
    """softplus1(phi) >= 1 and sigmoid gate in (0,1) — eq. 2/4/5 sanity."""
    x = jnp.linspace(-10, 10, 101)
    sp1 = layers.softplus1(x)
    assert bool(jnp.all(sp1 >= 1.0))
    g = jax.nn.sigmoid(x)
    assert bool(jnp.all((g > 0) & (g < 1)))
