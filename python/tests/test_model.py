"""Full model (L2): shapes, training dynamics, variant parity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model, train
from compile.configs import tiny

jax.config.update("jax_platform_name", "cpu")


def setup(variant="cast_topk", **kw):
    cfg = tiny(variant, **kw)
    key = jax.random.PRNGKey(0)
    params = model.init(key, cfg)
    tokens = jax.random.randint(
        jax.random.PRNGKey(1), (cfg.batch, cfg.seq_len), 0, cfg.vocab
    )
    labels = jnp.arange(cfg.batch, dtype=jnp.int32) % cfg.n_classes
    return cfg, params, tokens, labels


@pytest.mark.parametrize("variant", ["cast_topk", "cast_sa", "vanilla", "local"])
def test_forward_shapes_all_variants(variant):
    cfg, params, tokens, _ = setup(variant)
    logits = model.forward(params, tokens, cfg)
    assert logits.shape == (cfg.batch, cfg.n_classes)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_dual_encoder_retrieval_shape():
    cfg, params, _, _ = setup(dual=True, task="retrieval")
    tokens = jax.random.randint(
        jax.random.PRNGKey(2), (cfg.batch, 2, cfg.seq_len), 0, cfg.vocab
    )
    logits = model.forward(params, tokens, cfg)
    assert logits.shape == (cfg.batch, cfg.n_classes)


def test_param_names_align_with_flatten_order():
    cfg, params, _, _ = setup()
    flat, _ = model.flatten(params)
    names = model.param_names(params)
    assert len(flat) == len(names)
    assert len(set(names)) == len(names), "names must be unique"
    # spot-check: the embedding leaf matches its name
    i = names.index("embed.emb")
    assert flat[i].shape == (cfg.vocab, cfg.d_emb)
    # blocks are enumerated
    assert any(n.startswith("blocks.0.attn.") for n in names)
    assert any(n.startswith("blocks.1.ffn.") for n in names)


@pytest.mark.parametrize("variant", ["cast_topk", "cast_sa", "vanilla"])
def test_train_step_decreases_loss(variant):
    cfg, params, tokens, labels = setup(variant)
    m = train.zeros_like_tree(params)
    v = train.zeros_like_tree(params)
    step = jnp.float32(0)
    losses = []
    jit_step = jax.jit(
        lambda p, m, v, s: train.train_step(
            p, m, v, s, jnp.float32(3e-3), tokens, labels, cfg
        )
    )
    for _ in range(15):
        params, m, v, step, loss, acc = jit_step(params, m, v, step)
        losses.append(float(loss))
    assert losses[-1] < losses[0], f"no learning: {losses[0]:.4} -> {losses[-1]:.4}"
    assert all(np.isfinite(losses))


def test_adam_bias_correction_first_step_magnitude():
    """After one step with fresh moments, update ≈ lr per coordinate."""
    cfg, params, tokens, labels = setup()
    m = train.zeros_like_tree(params)
    v = train.zeros_like_tree(params)
    lr = 1e-2
    p2, *_ = train.train_step(
        params, m, v, jnp.float32(0), jnp.float32(lr), tokens, labels, cfg
    )
    flat0, _ = model.flatten(params)
    flat1, _ = model.flatten(p2)
    deltas = [float(jnp.abs(a - b).max()) for a, b in zip(flat0, flat1)]
    # with bias correction, |Δ| <= lr * (1 + wd·|p|) approximately
    assert max(deltas) < 3 * lr, f"first-step update too large: {max(deltas)}"
    assert max(deltas) > 0.0


def test_gradient_clipping_bounds_update():
    cfg, params, tokens, labels = setup()
    cfg_clipped = tiny("cast_topk", clip=1e-6)  # aggressive clip
    m = train.zeros_like_tree(params)
    v = train.zeros_like_tree(params)
    _, _, _, _, loss_a, _ = train.train_step(
        params, m, v, jnp.float32(0), jnp.float32(1e-3), tokens, labels, cfg_clipped
    )
    assert bool(jnp.isfinite(loss_a))


def test_weight_decay_excludes_norms_and_biases():
    assert train._decayable("blocks.0.attn.wq.w")
    assert train._decayable("blocks.0.attn.s")
    assert not train._decayable("blocks.0.attn.wq.b")
    assert not train._decayable("blocks.0.norm1.g")
    assert not train._decayable("embed.emb")


def test_forward_ag_stacks_all_layers():
    cfg, params, tokens, _ = setup()
    ags = model.forward_ag(params, tokens, cfg)
    assert ags.shape == (cfg.depth, cfg.batch, cfg.seq_len, cfg.n_c)
    np.testing.assert_allclose(np.asarray(ags.sum(-1)), 1.0, atol=1e-4)


def test_init_is_seed_deterministic():
    cfg = tiny()
    a = model.init(jax.random.PRNGKey(3), cfg)
    b = model.init(jax.random.PRNGKey(3), cfg)
    c = model.init(jax.random.PRNGKey(4), cfg)
    fa, _ = model.flatten(a)
    fb, _ = model.flatten(b)
    fc, _ = model.flatten(c)
    assert all(np.array_equal(x, y) for x, y in zip(fa, fb))
    assert not all(np.array_equal(x, y) for x, y in zip(fa, fc))


def test_prenorm_variant_runs():
    cfg, params, tokens, _ = setup(prenorm=True, norm="batch")
    logits = model.forward(params, tokens, cfg)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_scale_norm_variant_runs():
    cfg, params, tokens, _ = setup(norm="scale")
    logits = model.forward(params, tokens, cfg)
    assert bool(jnp.all(jnp.isfinite(logits)))
