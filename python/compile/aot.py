"""AOT pipeline: lower every model variant to HLO text + manifest.json.

This is the ONLY place python executes in the system; everything it emits
is loaded by the rust coordinator via ``HloModuleProto::from_text_file``.
Per config key (``ModelConfig.key()``) the artifact directory contains:

  init.hlo.txt        (seed u32[])                          -> (param_0..P)
  train_step.hlo.txt  (P params, P m, P v, step, lr, tokens, labels)
                                                            -> (P params', P m', P v', step', loss, acc)
  predict.hlo.txt     (P params, tokens)                    -> (logits,)
  predict_ag.hlo.txt  (P params, tokens)                    -> (A_g[L,B,N,Nc],)   [cast only]
  manifest.json       flattened-IO description (names/shapes/dtypes) + config

Interchange is HLO *text*, not serialized protos: jax >= 0.5 emits 64-bit
instruction ids that xla_extension 0.5.1 rejects; the text parser reassigns
ids (see /opt/xla-example/README.md).

Usage (from python/):
  python -m compile.aot --task text --variant cast_topk --seq 1024 --batch 4
  python -m compile.aot --suite default          # everything the Makefile needs
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model, train
from .configs import ModelConfig, preset, tiny

DTYPE_NAMES = {jnp.float32.dtype: "f32", jnp.int32.dtype: "s32", jnp.uint32.dtype: "u32"}


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the 0.5.1-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _token_spec(cfg: ModelConfig):
    shape = (cfg.batch, 2, cfg.seq_len) if cfg.dual else (cfg.batch, cfg.seq_len)
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def build_fns(cfg: ModelConfig):
    """The flat-list-interface functions that get lowered."""
    key0 = jax.random.PRNGKey(0)
    shapes = jax.eval_shape(lambda k: model.init(k, cfg), key0)
    treedef = jax.tree_util.tree_structure(shapes)
    flat_shapes = jax.tree_util.tree_leaves(shapes)
    names = model.param_names(shapes)

    def unflatten(flat):
        return jax.tree_util.tree_unflatten(treedef, list(flat))

    n_p = len(flat_shapes)

    def init_fn(seed):
        params = model.init(jax.random.PRNGKey(seed), cfg)
        return tuple(jax.tree_util.tree_leaves(params))

    def train_fn(*args):
        p = unflatten(args[:n_p])
        m = unflatten(args[n_p : 2 * n_p])
        v = unflatten(args[2 * n_p : 3 * n_p])
        step, lr, tokens, labels = args[3 * n_p :]
        p2, m2, v2, step2, loss, acc = train.train_step(
            p, m, v, step, lr, tokens, labels, cfg, names=names
        )
        return (
            tuple(jax.tree_util.tree_leaves(p2))
            + tuple(jax.tree_util.tree_leaves(m2))
            + tuple(jax.tree_util.tree_leaves(v2))
            + (step2, loss, acc)
        )

    def predict_fn(*args):
        p = unflatten(args[:n_p])
        logits = model.forward(p, args[n_p], cfg)
        # Variants that do not touch every parameter at inference (e.g. the
        # LSH baseline ties Q/K and never reads W_k) would otherwise get
        # their unused args pruned by the MLIR->HLO conversion; tie all
        # params in so every artifact shares the flat input contract.
        tie = sum(jnp.sum(a) * 0.0 for a in args[:n_p])
        return (logits + tie,)

    def predict_ag_fn(*args):
        p = unflatten(args[:n_p])
        ags = model.forward_ag(p, args[n_p], cfg)
        # A_g does not depend on the classifier head; tie every parameter
        # into the output so the MLIR->HLO conversion keeps the full
        # argument list and rust can feed the same flat param vector to
        # every artifact.
        tie = sum(jnp.sum(a) * 0.0 for a in args[:n_p])
        return (ags + tie,)

    return init_fn, train_fn, predict_fn, predict_ag_fn, flat_shapes, names


def manifest(cfg: ModelConfig, flat_shapes, names, files) -> dict:
    tok = _token_spec(cfg)
    return {
        "config": dataclasses.asdict(cfg),
        "key": cfg.key(),
        "n_params": len(flat_shapes),
        "params": [
            {
                "name": n,
                "shape": list(s.shape),
                "dtype": DTYPE_NAMES.get(s.dtype, str(s.dtype)),
            }
            for n, s in zip(names, flat_shapes)
        ],
        "tokens": {"shape": list(tok.shape), "dtype": "s32"},
        "labels": {"shape": [cfg.batch], "dtype": "s32"},
        "n_classes": cfg.n_classes,
        "files": files,
    }


def build(cfg: ModelConfig, out_root: str, what=("init", "train_step", "predict"), force=False) -> str:
    """Lower the requested artifact set for ``cfg``.  Returns the out dir.

    Skips work when manifest.json already exists with the same config and
    all requested files are present (makes ``make artifacts`` a no-op).
    """
    out_dir = os.path.join(out_root, cfg.key())
    man_path = os.path.join(out_dir, "manifest.json")
    wanted = list(what)
    if cfg.is_cast and "predict_ag" not in wanted and "predict" in wanted and not cfg.dual:
        wanted.append("predict_ag")
    if not force and os.path.exists(man_path):
        try:
            old = json.load(open(man_path))
            have = all(
                os.path.exists(os.path.join(out_dir, f"{w}.hlo.txt")) for w in wanted
            )
            if old.get("config") == dataclasses.asdict(cfg) and have:
                print(f"[aot] up-to-date: {out_dir}")
                return out_dir
        except Exception:
            pass

    os.makedirs(out_dir, exist_ok=True)
    init_fn, train_fn, predict_fn, predict_ag_fn, flat_shapes, names = build_fns(cfg)
    n_p = len(flat_shapes)
    tok = _token_spec(cfg)
    lab = jax.ShapeDtypeStruct((cfg.batch,), jnp.int32)
    scalar = jax.ShapeDtypeStruct((), jnp.float32)

    files = {}

    def emit(name, fn, example_args):
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        print(f"[aot] lowering {cfg.key()}/{name} ...", flush=True)
        lowered = jax.jit(fn).lower(*example_args)
        text = to_hlo_text(lowered)
        with open(path, "w") as f:
            f.write(text)
        files[name] = f"{name}.hlo.txt"
        print(f"[aot]   wrote {len(text)} chars")

    if "init" in wanted:
        emit("init", init_fn, [jax.ShapeDtypeStruct((), jnp.uint32)])
    if "train_step" in wanted:
        emit(
            "train_step",
            train_fn,
            list(flat_shapes) * 3 + [scalar, scalar, tok, lab],
        )
    if "predict" in wanted:
        emit("predict", predict_fn, list(flat_shapes) + [tok])
    if "predict_ag" in wanted and cfg.is_cast and not cfg.dual:
        emit("predict_ag", predict_ag_fn, list(flat_shapes) + [tok])

    with open(man_path, "w") as f:
        json.dump(manifest(cfg, flat_shapes, names, files), f, indent=1)
    print(f"[aot] manifest -> {man_path}")
    return out_dir


# ---------------------------------------------------------------------------
# suites: the artifact sets the Makefile / benches expect
# ---------------------------------------------------------------------------


def suite_default(out_root: str, force=False):
    """Small, fast-to-build set: quickstart + end-to-end example configs."""
    cfgs = [
        # end-to-end training examples (scaled presets, CPU-sized)
        preset("listops", "cast_topk", seq_len=256, batch=8, scale=0.5, n_c=8),
        preset("image", "cast_topk", seq_len=1024, batch=8, scale=0.5, n_c=8),
        preset("image", "cast_sa", seq_len=1024, batch=8, scale=0.5, n_c=8),
        preset("image", "vanilla", seq_len=1024, batch=8, scale=0.5),
        # tiny smoke config used by rust integration tests
        tiny("cast_topk"),
        tiny("cast_sa"),
        tiny("vanilla"),
        tiny("local"),
        tiny("lsh"),
        tiny("cast_sa", causal=True),  # decoder extension (§5.5)
    ]
    for c in cfgs:
        build(c, out_root, force=force)


def suite_efficiency(out_root: str, force=False):
    """Table 1 / Table 5: Text task at 1K..4K, kappa=200, CAST vs vanilla."""
    for seq in (1024, 2048, 3072, 4096):
        for variant in ("cast_topk", "cast_sa", "vanilla"):
            kw = dict(n_c=max(2, seq // 200), kappa=200) if variant != "vanilla" else {}
            cfg = preset("text", variant, seq_len=seq, batch=2, scale=0.5, **kw)
            build(cfg, out_root, what=("init", "train_step", "predict"), force=force)


def suite_ablation(out_root: str, force=False):
    """Figure 3: cluster-size sweep on Text + Image, both mechanisms."""
    for task, seq in (("text", 2048), ("image", 1024)):
        for kappa in (32, 64, 128, 256, 512):
            n_c = max(2, seq // kappa)
            for variant in ("cast_topk", "cast_sa"):
                cfg = preset(
                    task, variant, seq_len=seq, batch=2, scale=0.5, n_c=n_c, kappa=kappa
                )
                build(cfg, out_root, what=("init", "train_step"), force=force)


def suite_lra(out_root: str, force=False):
    """Table 2: one CAST + one vanilla config per LRA task (scaled)."""
    seqs = {"listops": 512, "text": 1024, "retrieval": 512, "image": 1024, "pathfinder": 1024}
    for task, seq in seqs.items():
        for variant in ("cast_topk", "cast_sa", "vanilla"):
            cfg = preset(task, variant, seq_len=seq, batch=8, scale=0.5)
            build(cfg, out_root, what=("init", "train_step", "predict"), force=force)


SUITES = {
    "default": suite_default,
    "efficiency": suite_efficiency,
    "ablation": suite_ablation,
    "lra": suite_lra,
}


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-root", default="../artifacts")
    ap.add_argument("--suite", choices=sorted(SUITES), default=None)
    ap.add_argument("--task", default="text")
    ap.add_argument("--variant", default="cast_topk")
    ap.add_argument("--seq", type=int, default=None)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--scale", type=float, default=0.5)
    ap.add_argument("--nc", type=int, default=None)
    ap.add_argument("--kappa", type=int, default=None)
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--no-pallas", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args(argv)

    if args.suite:
        SUITES[args.suite](args.out_root, force=args.force)
        return

    if args.tiny:
        cfg = tiny(args.variant, use_pallas=not args.no_pallas)
    else:
        cfg = preset(
            args.task,
            args.variant,
            seq_len=args.seq,
            batch=args.batch,
            scale=args.scale,
            n_c=args.nc,
            kappa=args.kappa,
            use_pallas=not args.no_pallas,
        )
    build(cfg, args.out_root, force=args.force)


if __name__ == "__main__":
    main()
