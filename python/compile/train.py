"""L2: loss, metrics, and a hand-rolled AdamW train step.

optax is unavailable offline, so the optimizer is implemented directly —
Adam (Kingma & Ba) with decoupled weight decay and global-norm gradient
clipping, matching the paper's Appendix A.5 setup (wd = 1e-2, clip = 1).

Everything here is lowered into ONE ``train_step`` HLO: the rust trainer
owns only raw buffers (params, m, v, step) and the learning-rate *value*,
which is an input so L3 can run warmup/decay schedules without re-lowering.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import model
from .configs import ModelConfig

ADAM_B1 = 0.9
ADAM_B2 = 0.999
ADAM_EPS = 1e-8


def loss_fn(params, tokens, labels, cfg: ModelConfig):
    """Mean softmax cross-entropy + accuracy."""
    logits = model.forward(params, tokens, cfg)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=1)[:, 0]
    loss = jnp.mean(nll)
    acc = jnp.mean((jnp.argmax(logits, axis=-1) == labels).astype(jnp.float32))
    return loss, acc


def _global_norm(grads):
    leaves = jax.tree_util.tree_leaves(grads)
    return jnp.sqrt(sum(jnp.sum(g * g) for g in leaves))


def _decayable(name: str) -> bool:
    """AdamW convention: no decay on biases, norms, or embeddings."""
    leaf = name.rsplit(".", 1)[-1]
    return leaf not in ("b", "g", "emb")


def train_step(params, m, v, step, lr, tokens, labels, cfg: ModelConfig, names=None):
    """One AdamW update.  All pytrees share the structure of ``params``.

    step: f32 scalar (Adam bias-correction counter, incremented here).
    Returns (params', m', v', step', loss, acc).
    """
    (loss, acc), grads = jax.value_and_grad(loss_fn, has_aux=True)(
        params, tokens, labels, cfg
    )

    gnorm = _global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip / jnp.maximum(gnorm, 1e-6))
    grads = jax.tree_util.tree_map(lambda g: g * scale, grads)

    t = step + 1.0
    bc1 = 1.0 - ADAM_B1**t
    bc2 = 1.0 - ADAM_B2**t

    if names is None:
        names = model.param_names(params)
    names_tree = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(params), names
    )

    def upd(p, g, m_, v_, name):
        m2 = ADAM_B1 * m_ + (1.0 - ADAM_B1) * g
        v2 = ADAM_B2 * v_ + (1.0 - ADAM_B2) * g * g
        mhat = m2 / bc1
        vhat = v2 / bc2
        delta = mhat / (jnp.sqrt(vhat) + ADAM_EPS)
        if _decayable(name):
            delta = delta + cfg.wd * p
        return p - lr * delta, m2, v2

    out = jax.tree_util.tree_map(upd, params, grads, m, v, names_tree)
    p2 = jax.tree_util.tree_map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
    m2 = jax.tree_util.tree_map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
    v2 = jax.tree_util.tree_map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return p2, m2, v2, t, loss, acc


def zeros_like_tree(params):
    return jax.tree_util.tree_map(jnp.zeros_like, params)
