"""L2: the full multi-head CAST attention layer (paper §3.2–3.3).

Pipeline per layer (B = batch, N = tokens, h = heads, Nc = clusters,
kappa = cluster size, d_h = d/h):

  1. Q,K,V projections (eq. 1)                                (B,N,h,d_h)
  2. Surrogate similarities A_q, A_k (eq. 6)                  (B,N,h,Nc)
  3. Gate phi = X W_phi + b; affinity
       A_g = sigm(phi) * f2(sum_h A_q) + (1-sigm(phi)) * f2(sum_h A_k)
  4. Clustering G over A_g (Top-K / SA Top-K)  -> idx (B,Nc,kappa)
  5. Fused kernel (L1): R_intra (eq. 3) + R_inter (eq. 4) per cluster/head
  6. Combination (eq. 5):
       A_sum  = f3(A_q_raw ⊙ softplus1(phi) / tau_q)          (B,N,Nc)
       R      = G^{-1}(A_g, A_intra ⊙ R_intra) + (A_sum⊙(1-M)) R_inter
  7. Output projection W_o.

The clustering *indices* are shared across heads (eq. 6 sums similarities
over heads before f2), so one gather serves all h heads — this is what the
kernel's folded (B*Nc*h) grid exploits.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from . import clustering, layers
from .configs import ModelConfig
from .kernels import cast_kernel
from .kernels import ref as kernel_ref


def init(key, cfg: ModelConfig):
    """Parameters of one CAST layer."""
    ks = jax.random.split(key, 6)
    d, h, d_h, n_c = cfg.d, cfg.h, cfg.d_h, cfg.n_c
    return {
        "wq": layers.dense_init(ks[0], d, d),
        "wk": layers.dense_init(ks[1], d, d),
        "wv": layers.dense_init(ks[2], d, d),
        "wo": layers.dense_init(ks[3], d, d),
        # surrogate tokens S (Nc, h, d_h): the learnable cluster directions
        "s": jax.random.normal(ks[4], (n_c, h, d_h), jnp.float32) / math.sqrt(d_h),
        "phi": layers.dense_init(ks[5], d, 1),
    }


def affinities(p, x, cfg: ModelConfig):
    """Steps 1–3: projections, surrogate similarities, gate, affinity A_g.

    Returns (q, k, v, a_q, a_k, a_q_raw, phi, a_g).
    """
    b, n, _ = x.shape
    h, d_h = cfg.h, cfg.d_h
    q = layers.dense(p["wq"], x).reshape(b, n, h, d_h)
    k = layers.dense(p["wk"], x).reshape(b, n, h, d_h)
    v = layers.dense(p["wv"], x).reshape(b, n, h, d_h)

    a_q = jnp.einsum("bnhd,chd->bnhc", q, p["s"])  # (B,N,h,Nc)
    a_k = jnp.einsum("bnhd,chd->bnhc", k, p["s"])
    phi = layers.dense(p["phi"], x)  # (B,N,1)

    a_q_raw = a_q.sum(axis=2)  # (B,N,Nc) head-summed similarities
    a_k_raw = a_k.sum(axis=2)
    gate = jax.nn.sigmoid(phi)  # (B,N,1)
    f2 = lambda t: kernel_ref.attn_weights(t, cfg.attn_fn)
    a_g = gate * f2(a_q_raw) + (1.0 - gate) * f2(a_k_raw)  # (B,N,Nc)
    return q, k, v, a_q, a_k, a_q_raw, phi, a_g


def apply(p, x, cfg: ModelConfig, return_ag: bool = False):
    """Full CAST attention layer.  x: (B,N,d) -> (B,N,d)."""
    b, n, d = x.shape
    h, d_h, n_c, kappa = cfg.h, cfg.d_h, cfg.n_c, cfg.kappa
    tau_s = math.sqrt(d_h)  # surrogate-similarity temperature (tau_q = tau_k)

    q, k, v, a_q, a_k, a_q_raw, phi, a_g = affinities(p, x, cfg)

    # ---- step 4: clustering ------------------------------------------
    idx, valid, member = clustering.cluster(a_g, kappa, cfg.clustering)

    g_of = lambda t: clustering.gather(idx, t)  # (B,N,...) -> (B,Nc,kappa,...)
    q_g, k_g, v_g = g_of(q), g_of(k), g_of(v)  # (B,Nc,kappa,h,d_h)

    # ---- eq. 4 weights: A_inter = G(A_g, A_k ⊙ softplus1(-phi) / tau_k),
    # taking each cluster's own column.  §Perf L2-1: gather the own column
    # directly via take_along_axis on a (B,Nc,h,N) transpose instead of
    # materializing the full (B,Nc,kappa,h,Nc) cluster gather and slicing
    # its diagonal — an Nc-fold smaller intermediate.
    w_all = a_k * layers.softplus1(-phi)[..., None] / tau_s  # (B,N,h,Nc)
    w_t = jnp.transpose(w_all, (0, 3, 2, 1))  # (B,Nc,h,N)
    w_inter = jnp.moveaxis(
        jnp.take_along_axis(w_t, idx[:, :, None, :], axis=3), 2, 3
    )  # (B,Nc,kappa,h)

    # ---- step 5: fused kernel over folded grid ------------------------
    fold = lambda t: jnp.moveaxis(t, 3, 2).reshape(b * n_c * h, kappa, d_h)
    q_f, k_f, v_f = fold(q_g), fold(k_g), fold(v_g)
    w_f = jnp.moveaxis(w_inter, 3, 2).reshape(b * n_c * h, kappa)
    valid_f = jnp.broadcast_to(valid[:, :, None, :], (b, n_c, h, kappa)).reshape(
        b * n_c * h, kappa
    )
    if cfg.causal:
        # Decoder extension (paper §5.5): causal masking inside clusters by
        # original position; no summaries (they would leak future tokens).
        pos = clustering.gather(idx, jnp.broadcast_to(
            jnp.arange(n, dtype=jnp.float32)[None, :], (b, n)
        ))  # (B,Nc,kappa)
        pos_f = jnp.broadcast_to(pos[:, :, None, :], (b, n_c, h, kappa)).reshape(
            b * n_c * h, kappa
        )
        causal_core = (
            cast_kernel.cast_core_causal
            if cfg.use_pallas
            else (lambda *a: kernel_ref.cast_core_causal_ref(*a))
        )
        r_intra_f = causal_core(q_f, k_f, v_f, pos_f, valid_f, cfg.attn_fn)
        r_inter_f = jnp.zeros((b * n_c * h, d_h), q_f.dtype)
    else:
        core = cast_kernel.cast_core if cfg.use_pallas else cast_kernel.cast_core_reference
        r_intra_f, r_inter_f = core(q_f, k_f, v_f, w_f, valid_f, cfg.attn_fn)
    # unfold: (B,Nc,h,kappa,d_h) -> (B,Nc,kappa,h*d_h)
    r_intra = jnp.moveaxis(r_intra_f.reshape(b, n_c, h, kappa, d_h), 2, 3).reshape(
        b, n_c, kappa, d
    )
    r_inter = r_inter_f.reshape(b, n_c, h * d_h)  # (B,Nc,d)

    # ---- step 6: combination (eq. 5) ----------------------------------
    a_sum = kernel_ref.attn_weights(
        a_q_raw * layers.softplus1(phi) / tau_s, cfg.attn_fn
    )  # (B,N,Nc)

    # intra weights: each clustered occurrence weighted by its token's own
    # A_sum entry for that cluster (§Perf L2-1: own-column gather again).
    a_sum_t = jnp.swapaxes(a_sum, 1, 2)  # (B,Nc,N)
    w_intra = jnp.take_along_axis(a_sum_t, idx, axis=2) * valid  # (B,Nc,kappa)
    r_from_intra = clustering.scatter_add(idx, w_intra[..., None] * r_intra, n)

    # inter: summaries of *other* clusters, weighted by A_sum off-membership
    if cfg.causal:
        r = r_from_intra  # no summaries in the causal variant
    else:
        a_inter = a_sum * (1.0 - member)  # (B,N,Nc)
        r_from_inter = jnp.einsum("bnc,bcd->bnd", a_inter, r_inter)
        r = r_from_intra + r_from_inter
    out = layers.dense(p["wo"], r)
    if return_ag:
        return out, a_g
    return out
