"""L1: the CAST hot spot as a fused Pallas kernel.

One grid step owns one (batch, cluster, head) cell — folded into a single
leading grid axis ``G = B * Nc * h`` — and computes *both* paper equations
that touch the clustered values:

  R_intra[g] = f(Q_g K_g^T / tau) V_g      (eq. 3, attention inside the cluster)
  R_inter[g] = f2(A_inter[g])^T V_g        (eq. 4, the cluster summary)

Fusing the summary into the attention step reuses the V tile already
resident in VMEM; a CUDA port would have needed a second kernel or a
grid-wide reduction (see DESIGN.md §Hardware-Adaptation).

TPU mapping (estimated in DESIGN.md / EXPERIMENTS.md §Perf):
  * VMEM per step: 3*kappa*d_h*4B (Q,K,V tiles) + kappa^2*4B (score tile)
    + 2*kappa*4B (weights)  —  ~0.45 MB at kappa=256, d_h=64.
  * MXU work: two kappa x d_h x kappa matmuls; kappa and d_h are chosen as
    multiples of the 128-lane tiling in every preset.

CPU execution uses ``interpret=True``: real-TPU lowering emits a Mosaic
custom-call the CPU PJRT plugin cannot run.  The kernel is wrapped in a
``jax.custom_vjp`` whose backward pass is the VJP of the pure-jnp oracle
(`ref.cast_core_ref`), so the lowered *training* graph still contains the
Pallas forward while gradients match the oracle by construction.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

NEG_INF = ref.NEG_INF


def _kernel(q_ref, k_ref, v_ref, w_ref, valid_ref, ri_ref, rs_ref, *, attn_fn: str):
    """Pallas body for one (batch*cluster*head) grid cell.

    Refs carry a leading block axis of size 1:
      q/k/v: (1, kappa, d_h);  w/valid: (1, kappa);  outputs likewise.
    """
    q = q_ref[0]  # (kappa, d_h)
    k = k_ref[0]
    v = v_ref[0]
    w = w_ref[0]  # (kappa,)
    valid = valid_ref[0]

    d_h = q.shape[-1]
    inv_tau = 1.0 / math.sqrt(d_h)

    # --- eq. 3: intra-cluster attention -------------------------------
    scores = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * inv_tau
    scores = scores + (1.0 - valid)[None, :] * NEG_INF
    if attn_fn == "softmax":
        m = jnp.max(scores, axis=-1, keepdims=True)
        e = jnp.exp(scores - m)
        p = e / jnp.sum(e, axis=-1, keepdims=True)
    else:  # laplace (MEGA)
        mu = math.sqrt(0.5)
        sigma = math.sqrt(0.25 / math.pi)
        l = 0.5 * (1.0 + jax.lax.erf((scores - mu) / (sigma * math.sqrt(2.0))))
        p = l / jnp.maximum(jnp.sum(l, axis=-1, keepdims=True), 1e-6)
    p = p * valid[None, :]
    r_intra = jnp.dot(p, v, preferred_element_type=jnp.float32)
    ri_ref[0] = r_intra * valid[:, None]

    # --- eq. 4: cluster summary, reusing the resident V tile ----------
    wm = w + (1.0 - valid) * NEG_INF
    if attn_fn == "softmax":
        mw = jnp.max(wm)
        ew = jnp.exp(wm - mw)
        pk = ew / jnp.sum(ew)
    else:
        mu = math.sqrt(0.5)
        sigma = math.sqrt(0.25 / math.pi)
        lw = 0.5 * (1.0 + jax.lax.erf((wm - mu) / (sigma * math.sqrt(2.0))))
        pk = lw / jnp.maximum(jnp.sum(lw), 1e-6)
    pk = pk * valid
    rs_ref[0] = jnp.dot(pk[None, :], v, preferred_element_type=jnp.float32)[0]


def cast_core_pallas(q_g, k_g, v_g, w_inter, valid, attn_fn: str = "softmax"):
    """Run the fused kernel over the folded grid.  Shapes as in ref."""
    g, kappa, d_h = q_g.shape
    grid = (g,)
    blk_kd = pl.BlockSpec((1, kappa, d_h), lambda i: (i, 0, 0))
    blk_k = pl.BlockSpec((1, kappa), lambda i: (i, 0))
    blk_d = pl.BlockSpec((1, d_h), lambda i: (i, 0))
    return pl.pallas_call(
        functools.partial(_kernel, attn_fn=attn_fn),
        grid=grid,
        in_specs=[blk_kd, blk_kd, blk_kd, blk_k, blk_k],
        out_specs=[blk_kd, blk_d],
        out_shape=[
            jax.ShapeDtypeStruct((g, kappa, d_h), q_g.dtype),
            jax.ShapeDtypeStruct((g, d_h), q_g.dtype),
        ],
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(q_g, k_g, v_g, w_inter, valid)


# ---------------------------------------------------------------------------
# custom_vjp wrapper: pallas forward, oracle-VJP backward.
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(5,))
def cast_core(q_g, k_g, v_g, w_inter, valid, attn_fn: str = "softmax"):
    """Differentiable fused CAST core.  See module docstring."""
    return cast_core_pallas(q_g, k_g, v_g, w_inter, valid, attn_fn)


def _fwd(q_g, k_g, v_g, w_inter, valid, attn_fn):
    out = cast_core_pallas(q_g, k_g, v_g, w_inter, valid, attn_fn)
    return out, (q_g, k_g, v_g, w_inter, valid)


def _bwd(attn_fn, residuals, cotangents):
    q_g, k_g, v_g, w_inter, valid = residuals
    _, vjp_fn = jax.vjp(
        lambda a, b, c, w: ref.cast_core_ref(a, b, c, w, valid, attn_fn),
        q_g,
        k_g,
        v_g,
        w_inter,
    )
    dq, dk, dv, dw = vjp_fn(cotangents)
    return dq, dk, dv, dw, None  # no gradient for `valid`


cast_core.defvjp(_fwd, _bwd)


def cast_core_reference(q_g, k_g, v_g, w_inter, valid, attn_fn: str = "softmax"):
    """Alias so L2 can swap kernel<->oracle via config (use_pallas=False)."""
    return ref.cast_core_ref(q_g, k_g, v_g, w_inter, valid, attn_fn)


# ---------------------------------------------------------------------------
# Causal variant (decoder extension, paper §5.5 future work).
# ---------------------------------------------------------------------------


def _kernel_causal(q_ref, k_ref, v_ref, pos_ref, valid_ref, ri_ref, *, attn_fn: str):
    """Causal intra-cluster attention: slot i attends to slot j iff the
    original sequence position pos[j] <= pos[i].  Summaries are omitted —
    see ref.cast_core_causal_ref."""
    q = q_ref[0]
    k = k_ref[0]
    v = v_ref[0]
    pos = pos_ref[0]
    valid = valid_ref[0]
    d_h = q.shape[-1]
    inv_tau = 1.0 / math.sqrt(d_h)
    scores = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * inv_tau
    causal = (pos[None, :] <= pos[:, None]).astype(scores.dtype)
    mask = causal * valid[None, :]
    scores = scores + (1.0 - mask) * NEG_INF
    if attn_fn == "softmax":
        m = jnp.max(scores, axis=-1, keepdims=True)
        e = jnp.exp(scores - m)
        p = e / jnp.sum(e, axis=-1, keepdims=True)
    else:
        mu = math.sqrt(0.5)
        sigma = math.sqrt(0.25 / math.pi)
        l = 0.5 * (1.0 + jax.lax.erf((scores - mu) / (sigma * math.sqrt(2.0))))
        p = l / jnp.maximum(jnp.sum(l, axis=-1, keepdims=True), 1e-6)
    p = p * mask
    ri_ref[0] = jnp.dot(p, v, preferred_element_type=jnp.float32) * valid[:, None]


def cast_core_causal_pallas(q_g, k_g, v_g, pos, valid, attn_fn: str = "softmax"):
    g, kappa, d_h = q_g.shape
    blk_kd = pl.BlockSpec((1, kappa, d_h), lambda i: (i, 0, 0))
    blk_k = pl.BlockSpec((1, kappa), lambda i: (i, 0))
    return pl.pallas_call(
        functools.partial(_kernel_causal, attn_fn=attn_fn),
        grid=(g,),
        in_specs=[blk_kd, blk_kd, blk_kd, blk_k, blk_k],
        out_specs=blk_kd,
        out_shape=jax.ShapeDtypeStruct((g, kappa, d_h), q_g.dtype),
        interpret=True,
    )(q_g, k_g, v_g, pos, valid)


@functools.partial(jax.custom_vjp, nondiff_argnums=(5,))
def cast_core_causal(q_g, k_g, v_g, pos, valid, attn_fn: str = "softmax"):
    """Differentiable causal CAST core: pallas forward, oracle-VJP backward."""
    return cast_core_causal_pallas(q_g, k_g, v_g, pos, valid, attn_fn)


def _causal_fwd(q_g, k_g, v_g, pos, valid, attn_fn):
    out = cast_core_causal_pallas(q_g, k_g, v_g, pos, valid, attn_fn)
    return out, (q_g, k_g, v_g, pos, valid)


def _causal_bwd(attn_fn, residuals, ct):
    q_g, k_g, v_g, pos, valid = residuals
    _, vjp_fn = jax.vjp(
        lambda a, b, c: ref.cast_core_causal_ref(a, b, c, pos, valid, attn_fn),
        q_g,
        k_g,
        v_g,
    )
    dq, dk, dv = vjp_fn(ct)
    return dq, dk, dv, None, None


cast_core_causal.defvjp(_causal_fwd, _causal_bwd)
