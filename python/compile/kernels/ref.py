"""Pure-jnp correctness oracle for the CAST hot spot.

``cast_core_ref`` is the reference semantics of the fused L1 kernel
(``cast_kernel.cast_core``): given the *clustered* queries/keys/values plus
the pre-activation summary weights, compute

  R_intra[g] = f(Q_g K_g^T / tau) V_g          (paper eq. 3)
  R_inter[g] = f_2(A_inter[g])^T V_g           (paper eq. 4)

for every grid cell g = (batch, cluster, head) folded into one leading axis.
Invalid (padding) slots — SA Top-K clusters that did not fill — are masked
out of both softmaxes.

This file must stay dependency-light and obviously-correct: it is what the
hypothesis test-suite and the custom_vjp backward pass are built on.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e9


def attn_weights(scores: jax.Array, fn: str) -> jax.Array:
    """Row-normalized attention weights for `softmax` or MEGA's `laplace`."""
    if fn == "softmax":
        return jax.nn.softmax(scores, axis=-1)
    if fn == "laplace":
        # MEGA (Ma et al., 2023) appendix: phi_laplace(x) with mu = sqrt(1/2),
        # sigma = sqrt(1/(4*pi)), rescaled to a proper distribution row-wise.
        mu = math.sqrt(0.5)
        sigma = math.sqrt(0.25 / math.pi)
        l = 0.5 * (1.0 + jax.lax.erf((scores - mu) / (sigma * math.sqrt(2.0))))
        # rows whose every entry is masked produce 0/eps -> 0 weights
        return l / jnp.maximum(l.sum(axis=-1, keepdims=True), 1e-6)
    raise ValueError(f"unknown attention fn {fn!r}")


def cast_core_ref(
    q_g: jax.Array,  # (G, kappa, d_h)
    k_g: jax.Array,  # (G, kappa, d_h)
    v_g: jax.Array,  # (G, kappa, d_h)
    w_inter: jax.Array,  # (G, kappa) pre-activation summary weights
    valid: jax.Array,  # (G, kappa) 1.0 real slot / 0.0 padding
    attn_fn: str = "softmax",
):
    """Reference for the fused intra-cluster attention + summary kernel.

    Returns (r_intra (G, kappa, d_h), r_inter (G, d_h)).
    """
    d_h = q_g.shape[-1]
    tau = math.sqrt(d_h)
    scores = jnp.einsum("gkd,gld->gkl", q_g, k_g) / tau
    mask = valid[:, None, :]  # keys masked per row
    scores = scores + (1.0 - mask) * NEG_INF
    p = attn_weights(scores, attn_fn)
    p = p * mask  # laplace path: force masked keys to exactly 0
    r_intra = jnp.einsum("gkl,gld->gkd", p, v_g)
    # zero out rows that are themselves padding slots
    r_intra = r_intra * valid[:, :, None]

    w = w_inter + (1.0 - valid) * NEG_INF
    pk = attn_weights(w[:, None, :], attn_fn)[:, 0, :] * valid  # (G, kappa)
    r_inter = jnp.einsum("gk,gkd->gd", pk, v_g)
    return r_intra, r_inter


# ---------------------------------------------------------------------------
# Full-layer reference (used by python/tests/test_cast_layer.py to pin the
# composed semantics of cast_layer.py, and for vanilla-attention parity
# checks in the limit Nc=1, kappa=N).
# ---------------------------------------------------------------------------


def full_attention_ref(q, k, v):
    """Vanilla multi-head attention oracle.  q,k,v: (B, N, h, d_h)."""
    d_h = q.shape[-1]
    scores = jnp.einsum("bnhd,bmhd->bhnm", q, k) / math.sqrt(d_h)
    p = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhnm,bmhd->bnhd", p, v)


def local_attention_ref(q, k, v, window: int):
    """Chunked local attention oracle (LRA 'Local Attention' baseline).

    The sequence is split into non-overlapping windows; full attention runs
    within each window.  q,k,v: (B, N, h, d_h), N divisible by window.
    """
    b, n, h, d_h = q.shape
    w = window
    assert n % w == 0, "sequence length must be divisible by the window"

    def chunk(x):
        return x.reshape(b, n // w, w, h, d_h)

    qc, kc, vc = chunk(q), chunk(k), chunk(v)
    scores = jnp.einsum("bcnhd,bcmhd->bchnm", qc, kc) / math.sqrt(d_h)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bchnm,bcmhd->bcnhd", p, vc)
    return out.reshape(b, n, h, d_h)


def cast_core_causal_ref(q_g, k_g, v_g, pos, valid, attn_fn: str = "softmax"):
    """Causal intra-cluster attention oracle (decoder extension, §5.5).

    ``pos`` (G, kappa) carries each slot's original sequence position;
    slot i may attend to slot j iff pos[j] <= pos[i].  Cluster summaries
    are omitted in causal mode (they would leak future tokens); the layer
    relies on intra-cluster flow only — the conservative decoder variant
    sketched in the paper's §5.5.
    """
    d_h = q_g.shape[-1]
    tau = math.sqrt(d_h)
    scores = jnp.einsum("gkd,gld->gkl", q_g, k_g) / tau
    causal = (pos[:, None, :] <= pos[:, :, None]).astype(scores.dtype)
    mask = causal * valid[:, None, :]
    scores = scores + (1.0 - mask) * NEG_INF
    p = attn_weights(scores, attn_fn) * mask
    r_intra = jnp.einsum("gkl,gld->gkd", p, v_g)
    return r_intra * valid[:, :, None]
