"""Model / task configuration for the CAST reproduction.

Single source of truth for every hyperparameter that crosses the
python (build-time) <-> rust (run-time) boundary.  ``aot.py`` serializes a
``ModelConfig`` into ``manifest.json`` next to each HLO artifact; the rust
coordinator reads it back (``rust/src/runtime/artifacts.rs``).

Presets mirror Table 4 of the paper (final LRA hyperparameters), with a
``scale`` knob so the CPU testbed can run depth/width-reduced versions of
the same shapes without touching the task definitions.
"""

from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass
from typing import Optional

# Attention variants lowered by aot.py.  `cast_topk` / `cast_sa` share all
# weights and differ only in the clustering mechanism G.
VARIANTS = ("cast_topk", "cast_sa", "vanilla", "local", "lsh")

# Attention score functions supported by the intra-cluster kernel.
ATTN_FNS = ("softmax", "laplace")

NORMS = ("layer", "scale", "batch")


@dataclass
class ModelConfig:
    """Everything needed to build + lower one model variant.

    Field names follow the paper's nomenclature (Table 4): ``depth`` is the
    number of transformer blocks, ``h`` heads, ``d`` attention features,
    ``d_ff`` feedforward features, ``d_emb`` embedding features, ``n_c``
    the number of clusters (= surrogate tokens), ``kappa`` the cluster size.
    """

    task: str = "text"
    variant: str = "cast_topk"
    # -- shapes --------------------------------------------------------
    seq_len: int = 1024
    batch: int = 4
    vocab: int = 256
    n_classes: int = 2
    dual: bool = False  # Retrieval: two documents per example
    # -- architecture (Table 4) ----------------------------------------
    depth: int = 2
    h: int = 2
    d: int = 64
    d_ff: int = 128
    d_emb: int = 64
    n_c: int = 8
    kappa: int = 128  # cluster size; Top-K may oversample (n_c*kappa != N ok)
    norm: str = "layer"
    prenorm: bool = False
    attn_fn: str = "softmax"
    # local-attention baseline window (chunk) size
    window: int = 128
    # -- optimization ---------------------------------------------------
    wd: float = 1e-2
    clip: float = 1.0
    # -- decoder extension (paper §5.5 future work) -----------------------
    causal: bool = False
    # -- lowering options -------------------------------------------------
    use_pallas: bool = True

    def __post_init__(self) -> None:
        if self.variant not in VARIANTS:
            raise ValueError(f"unknown variant {self.variant!r}")
        if self.attn_fn not in ATTN_FNS:
            raise ValueError(f"unknown attn_fn {self.attn_fn!r}")
        if self.norm not in NORMS:
            raise ValueError(f"unknown norm {self.norm!r}")
        if self.d % self.h:
            raise ValueError(f"d={self.d} not divisible by h={self.h}")
        self.window = min(self.window, self.seq_len)
        if self.variant == "local" and self.seq_len % self.window:
            raise ValueError(
                f"local attention needs seq_len % window == 0 "
                f"(got {self.seq_len} % {self.window})"
            )
        if self.causal and self.is_cast and self.n_c * self.kappa < self.seq_len:
            raise ValueError(
                "causal CAST requires n_c*kappa >= seq_len (every token "
                "must be assigned for the causal mask to cover it)"
            )
        if self.variant == "cast_sa" and self.n_c * self.kappa < self.seq_len:
            raise ValueError(
                "SA Top-K requires n_c*kappa >= seq_len so every token can "
                f"be assigned (got {self.n_c}*{self.kappa} < {self.seq_len})"
            )

    @property
    def d_h(self) -> int:
        return self.d // self.h

    @property
    def is_cast(self) -> bool:
        return self.variant.startswith("cast")

    @property
    def clustering(self) -> str:
        if self.causal:
            return "causal"  # position-order greedy: assignment is causal
        return "sa" if self.variant == "cast_sa" else "topk"

    def key(self) -> str:
        """Stable artifact-directory name for this config."""
        parts = [self.task, self.variant, f"n{self.seq_len}", f"b{self.batch}"]
        if self.is_cast or self.variant == "lsh":
            parts += [f"c{self.n_c}", f"k{self.kappa}"]
        if self.variant == "local":
            parts.append(f"w{self.window}")
        if self.causal:
            parts.append("causal")
        return "_".join(parts)

    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self), indent=1, sort_keys=True)

    @staticmethod
    def from_json(text: str) -> "ModelConfig":
        return ModelConfig(**json.loads(text))


def _balanced_kappa(seq_len: int, n_c: int) -> int:
    """kappa = N / Nc (paper §3.4's balanced relation), rounded up."""
    return -(-seq_len // n_c)


# ---------------------------------------------------------------------------
# Task presets (Table 4), parameterizable by sequence length + scale.
# ---------------------------------------------------------------------------

_TABLE4 = {
    # task: (depth, h, d, d_ff, d_emb, n_c, norm, prenorm, n_classes, vocab, dual)
    "listops": (4, 8, 64, 128, 256, 10, "layer", False, 10, 24, False),
    "text": (4, 4, 64, 128, 256, 20, "scale", False, 2, 256, False),
    "retrieval": (2, 8, 256, 256, 256, 20, "layer", False, 2, 256, True),
    "image": (2, 2, 128, 128, 256, 16, "batch", True, 10, 256, False),
    "pathfinder": (2, 2, 32, 32, 64, 16, "batch", True, 2, 256, False),
    "pathx": (2, 2, 32, 32, 64, 16, "batch", True, 2, 256, False),
}

_DEFAULT_SEQ = {
    "listops": 2048,
    "text": 4096,
    "retrieval": 4096,
    "image": 1024,
    "pathfinder": 1024,
    "pathx": 16384,
}


def preset(
    task: str,
    variant: str = "cast_topk",
    seq_len: Optional[int] = None,
    batch: int = 4,
    scale: float = 1.0,
    n_c: Optional[int] = None,
    kappa: Optional[int] = None,
    use_pallas: bool = True,
) -> ModelConfig:
    """Build a Table-4 preset, optionally width/depth-scaled by ``scale``.

    ``scale`` < 1 shrinks depth/d/d_ff/d_emb proportionally (min 1 block,
    head count preserved when divisible) so the same task runs on the CPU
    testbed at a fraction of the FLOPs while keeping all shape *relations*
    (the quantities the efficiency experiments measure) intact.
    """
    if task not in _TABLE4:
        raise ValueError(f"unknown task {task!r}; know {sorted(_TABLE4)}")
    depth, h, d, d_ff, d_emb, nc0, norm, prenorm, n_classes, vocab, dual = _TABLE4[task]
    seq = seq_len or _DEFAULT_SEQ[task]
    if scale != 1.0:
        depth = max(1, int(round(depth * scale)))
        d = max(h, int(round(d * scale)) // h * h)
        d_ff = max(8, int(round(d_ff * scale)))
        d_emb = max(8, int(round(d_emb * scale)))
    nc = n_c or nc0
    k = kappa or _balanced_kappa(seq, nc)
    if variant == "cast_sa" and nc * k < seq:
        k = _balanced_kappa(seq, nc)
    return ModelConfig(
        task=task,
        variant=variant,
        seq_len=seq,
        batch=batch,
        vocab=vocab,
        n_classes=n_classes,
        dual=dual,
        depth=depth,
        h=h,
        d=d,
        d_ff=d_ff,
        d_emb=d_emb,
        n_c=nc,
        kappa=min(k, seq),
        norm=norm,
        prenorm=prenorm,
        use_pallas=use_pallas,
    )


def tiny(variant: str = "cast_topk", **kw) -> ModelConfig:
    """A deliberately small config for unit tests and smoke lowering."""
    base = dict(
        task="text",
        variant=variant,
        seq_len=64,
        batch=2,
        vocab=256,  # byte-level: must cover the text generator's range
        n_classes=2,
        depth=2,
        h=2,
        d=16,
        d_ff=32,
        d_emb=16,
        n_c=4,
        kappa=16,
        norm="layer",
        prenorm=False,
    )
    base.update(kw)
    return ModelConfig(**base)
