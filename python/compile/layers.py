"""L2 building blocks shared by all model variants.

Parameters are plain dicts of jnp arrays; parameter *creation* lives in
``init_*`` functions that consume a PRNG key and return the dict.  The
model keeps params as an ordered flat list at the AOT boundary (see
``model.flatten_params``) so the rust side never needs pytree logic.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------


def dense_init(key, d_in: int, d_out: int, scale: float | None = None):
    """Glorot-ish scaled normal dense layer."""
    s = scale if scale is not None else 1.0 / math.sqrt(d_in)
    wk, _ = jax.random.split(key)
    return {
        "w": jax.random.normal(wk, (d_in, d_out), jnp.float32) * s,
        "b": jnp.zeros((d_out,), jnp.float32),
    }


def dense(p, x):
    return x @ p["w"] + p["b"]


def embedding_init(key, vocab: int, d: int):
    return {"emb": jax.random.normal(key, (vocab, d), jnp.float32) / math.sqrt(d)}


def embedding(p, tokens):
    return p["emb"][tokens]


def sinusoidal_positions(n: int, d: int) -> jnp.ndarray:
    """Fixed sinusoidal positional embeddings (Vaswani et al., 2017)."""
    pos = jnp.arange(n, dtype=jnp.float32)[:, None]
    half = (d + 1) // 2
    freq = jnp.exp(-math.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = pos * freq[None, :]
    pe = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=1)
    return pe[:, :d]


# ---------------------------------------------------------------------------
# normalization (paper Table 4: Layer / Scale / Batch)
# ---------------------------------------------------------------------------


def norm_init(kind: str, d: int):
    if kind == "layer":
        return {"g": jnp.ones((d,), jnp.float32), "b": jnp.zeros((d,), jnp.float32)}
    if kind == "scale":
        return {"g": jnp.ones((), jnp.float32)}
    if kind == "batch":
        # Substitution (DESIGN.md): running-stats batchnorm would leak state
        # across the AOT boundary; we use a per-feature affine layernorm,
        # which at our scale behaves equivalently for the comparisons made.
        return {"g": jnp.ones((d,), jnp.float32), "b": jnp.zeros((d,), jnp.float32)}
    raise ValueError(f"unknown norm {kind!r}")


def norm_apply(kind: str, p, x, eps: float = 1e-5):
    if kind == "scale":
        # ScaleNorm (Nguyen & Salazar, 2019): g * x / ||x||
        rms = jnp.sqrt(jnp.sum(x * x, axis=-1, keepdims=True) + eps)
        return p["g"] * x * math.sqrt(x.shape[-1]) / rms
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return p["g"] * (x - mu) / jnp.sqrt(var + eps) + p["b"]


# ---------------------------------------------------------------------------
# feedforward
# ---------------------------------------------------------------------------


def ffn_init(key, d: int, d_ff: int):
    k1, k2 = jax.random.split(key)
    return {"in": dense_init(k1, d, d_ff), "out": dense_init(k2, d_ff, d)}


def ffn(p, x):
    return dense(p["out"], jax.nn.gelu(dense(p["in"], x)))


def softplus1(x):
    """phi(x) = Softplus(x) + 1 (Zheng et al., 2015), used in eq. 4/5."""
    return jax.nn.softplus(x) + 1.0
