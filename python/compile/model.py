"""L2: the encoder model every experiment runs.

A standard pre/post-norm transformer encoder where the attention block is
one of {CAST Top-K, CAST SA Top-K, vanilla, local} — CAST as a *drop-in
replacement* for self-attention, exactly the paper's framing.

Setup follows LRA / paper Appendix A.5: sinusoidal positional embeddings,
mean-pooling over the sequence for classification features, a dual-encoder
("two towers", shared weights) for the Retrieval task, and an extra output
normalization when pre-normalization is used.

Parameters cross the AOT boundary as a *flat ordered list* of arrays;
``param_names`` produces the matching name list recorded in manifest.json.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import attention_baselines, cast_layer, layers
from .configs import ModelConfig

# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def init(key, cfg: ModelConfig):
    """Initialize the full parameter tree."""
    n_keys = 3 + cfg.depth
    ks = jax.random.split(key, n_keys)
    attn_init = cast_layer.init if cfg.is_cast else attention_baselines.init

    blocks = []
    for i in range(cfg.depth):
        bk = jax.random.split(ks[3 + i], 4)
        blocks.append(
            {
                "attn": attn_init(bk[0], cfg),
                "ffn": layers.ffn_init(bk[1], cfg.d, cfg.d_ff),
                "norm1": layers.norm_init(cfg.norm, cfg.d),
                "norm2": layers.norm_init(cfg.norm, cfg.d),
            }
        )

    params = {
        "embed": layers.embedding_init(ks[0], cfg.vocab, cfg.d_emb),
        "proj": layers.dense_init(ks[1], cfg.d_emb, cfg.d),
        "blocks": blocks,
        "head": _head_init(ks[2], cfg),
    }
    if cfg.prenorm:
        params["out_norm"] = layers.norm_init(cfg.norm, cfg.d)
    return params


def _head_init(key, cfg: ModelConfig):
    k1, k2 = jax.random.split(key)
    d_in = 4 * cfg.d if cfg.dual else cfg.d
    return {
        "fc": layers.dense_init(k1, d_in, cfg.d),
        "out": layers.dense_init(k2, cfg.d, cfg.n_classes),
    }


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def _attn_apply(p, x, cfg: ModelConfig, return_ag: bool):
    if cfg.is_cast:
        return cast_layer.apply(p, x, cfg, return_ag=return_ag)
    if cfg.variant == "vanilla":
        out = attention_baselines.apply_vanilla(p, x, cfg)
    elif cfg.variant == "lsh":
        out = attention_baselines.apply_lsh(p, x, cfg)
    else:
        out = attention_baselines.apply_local(p, x, cfg)
    if return_ag:
        return out, jnp.zeros((x.shape[0], x.shape[1], cfg.n_c), x.dtype)
    return out


def encode(params, tokens, cfg: ModelConfig, collect_ag: bool = False):
    """tokens (B,N) int32 -> pooled features (B,d) [+ A_g (L,B,N,Nc)]."""
    x = layers.embedding(params["embed"], tokens)  # (B,N,d_emb)
    x = x + layers.sinusoidal_positions(cfg.seq_len, cfg.d_emb)[None]
    x = layers.dense(params["proj"], x)  # (B,N,d)

    ags = []
    for blk in params["blocks"]:
        if cfg.prenorm:
            a = _attn_apply(blk["attn"], layers.norm_apply(cfg.norm, blk["norm1"], x), cfg, collect_ag)
            if collect_ag:
                a, ag = a
                ags.append(ag)
            x = x + a
            x = x + layers.ffn(blk["ffn"], layers.norm_apply(cfg.norm, blk["norm2"], x))
        else:
            a = _attn_apply(blk["attn"], x, cfg, collect_ag)
            if collect_ag:
                a, ag = a
                ags.append(ag)
            x = layers.norm_apply(cfg.norm, blk["norm1"], x + a)
            x = layers.norm_apply(cfg.norm, blk["norm2"], x + layers.ffn(blk["ffn"], x))
    if cfg.prenorm:
        x = layers.norm_apply(cfg.norm, params["out_norm"], x)

    pooled = jnp.mean(x, axis=1)  # (B,d)
    if collect_ag:
        return pooled, jnp.stack(ags)  # (L,B,N,Nc)
    return pooled


def forward(params, tokens, cfg: ModelConfig):
    """tokens (B,N) or (B,2,N) for dual -> logits (B,n_classes)."""
    if cfg.dual:
        f1 = encode(params, tokens[:, 0], cfg)
        f2 = encode(params, tokens[:, 1], cfg)
        feats = jnp.concatenate([f1, f2, f1 * f2, f1 - f2], axis=-1)
    else:
        feats = encode(params, tokens, cfg)
    h = jax.nn.gelu(layers.dense(params["head"]["fc"], feats))
    return layers.dense(params["head"]["out"], h)


def forward_ag(params, tokens, cfg: ModelConfig):
    """Return per-layer cluster affinities A_g — Figure 4 / 7–9 pipeline."""
    assert cfg.is_cast and not cfg.dual
    _, ags = encode(params, tokens, cfg, collect_ag=True)
    return ags


# ---------------------------------------------------------------------------
# flat parameter interface (the AOT boundary)
# ---------------------------------------------------------------------------


def flatten(params):
    flat, treedef = jax.tree_util.tree_flatten(params)
    return flat, treedef


def param_names(params):
    """Names aligned with jax's tree_flatten order (sorted dict keys)."""
    named = _name_tree(params, "")
    flat, _ = jax.tree_util.tree_flatten(named)
    return flat


def _name_tree(tree, prefix):
    if isinstance(tree, dict):
        return {k: _name_tree(v, f"{prefix}{k}.") for k, v in tree.items()}
    if isinstance(tree, (list, tuple)):
        return type(tree)(_name_tree(v, f"{prefix}{i}.") for i, v in enumerate(tree))
    return prefix.rstrip(".")
