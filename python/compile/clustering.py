"""Clustering mechanisms G / G^{-1} for CAST (paper §3.2, Appendix A.3).

Both mechanisms consume the token->cluster affinity matrix ``A_g`` of shape
``(B, N, Nc)`` and produce:

* ``idx``   int32 ``(B, Nc, kappa)`` — for each cluster, the indices of the
            tokens assigned to it (the clustered sequence G(A_g, .)).
* ``valid`` float32 ``(B, Nc, kappa)`` — 1.0 where the slot holds a real
            assignment, 0.0 for padding slots (SA Top-K when Nc*kappa > N).
* ``member`` float32 ``(B, N, Nc)`` — the paper's mask M: ``member[b,n,c]=1``
            iff token n is assigned to cluster c.

Top-K (Algorithm 1) lets a token live in 0..Nc clusters; SA Top-K
(Algorithm 2) assigns each token to exactly one cluster, greedily in
descending order of its best affinity, subject to per-cluster capacity.

Gradients: indices are non-differentiable (as in the paper); gathers and
scatter-adds built from them are differentiable w.r.t. the gathered values.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def argsort_desc(x: jax.Array) -> jax.Array:
    """Descending argsort along the last axis via lax.sort_key_val.

    jnp.argsort in jax >= 0.6 lowers through gathers with
    `operand_batching_dims`, which the xla_extension 0.5.1 HLO converter
    rejects; sort_key_val lowers to a plain `sort` instruction that
    round-trips through HLO text cleanly (DESIGN.md §Substitutions).
    """
    iota = lax.broadcasted_iota(jnp.int32, x.shape, x.ndim - 1)
    _, idx = lax.sort_key_val(-x, iota, dimension=x.ndim - 1)
    return idx


def gather(idx: jax.Array, x: jax.Array) -> jax.Array:
    """G(A_g, X): cluster a per-token tensor.

    idx: (B, Nc, kappa) int32;  x: (B, N, ...) -> (B, Nc, kappa, ...)
    """
    return jax.vmap(lambda i, t: t[i])(idx, x)


def scatter_add(idx: jax.Array, values: jax.Array, n: int) -> jax.Array:
    """G^{-1}(A_g, V): un-cluster, summing duplicate assignments.

    idx: (B, Nc, kappa);  values: (B, Nc, kappa, ...) -> (B, N, ...)
    """

    def one(i, v):
        flat_i = i.reshape(-1)
        flat_v = v.reshape((flat_i.shape[0],) + v.shape[2:])
        out = jnp.zeros((n,) + flat_v.shape[1:], dtype=v.dtype)
        return out.at[flat_i].add(flat_v)

    return jax.vmap(one)(idx, values)


def membership(idx: jax.Array, valid: jax.Array, n: int) -> jax.Array:
    """The paper's mask M (B, N, Nc) from cluster slots."""
    b, n_c, kappa = idx.shape
    onehot = jax.nn.one_hot(idx, n, dtype=valid.dtype)  # (B, Nc, kappa, N)
    m = jnp.einsum("bckn,bck->bnc", onehot, valid)
    return jnp.clip(m, 0.0, 1.0)


def top_k_cluster(a_g: jax.Array, kappa: int):
    """Algorithm 1: per-cluster Top-K over affinity columns.

    Every cluster independently takes its ``kappa`` highest-affinity tokens;
    a token may appear in several clusters or in none.
    """
    scores = jnp.swapaxes(a_g, 1, 2)  # (B, Nc, N)
    # NOTE: sort-based top-k, not lax.top_k — the latter lowers to the
    # `topk(..., largest=true)` HLO instruction which xla_extension 0.5.1's
    # text parser rejects; `sort` round-trips fine (see DESIGN.md).
    idx = argsort_desc(scores)[..., :kappa].astype(jnp.int32)
    valid = jnp.ones(idx.shape, dtype=a_g.dtype)
    return idx, valid


def sa_top_k_cluster(a_g: jax.Array, kappa: int):
    """Algorithm 2: Single-Assignment Top-K.

    Tokens are visited in descending order of their best cluster affinity;
    each is placed into its most-preferred cluster that still has capacity.
    Faithfully sequential (a ``fori_loop`` over N tokens), which is exactly
    why the paper's Table 1 / Figure 3 show SA Top-K to be slower.
    """
    n = a_g.shape[1]
    n_c = a_g.shape[2]

    def one(ag):  # ag: (N, Nc)
        best = jnp.max(ag, axis=1)  # (N,)
        order = argsort_desc(best)  # token visit order
        pref = argsort_desc(ag)  # (N, Nc) cluster preference
        slots0 = jnp.zeros((n_c, kappa), dtype=jnp.int32)
        fill0 = jnp.zeros((n_c,), dtype=jnp.int32)

        def body(r, carry):
            slots, fill = carry
            t = order[r]
            avail = fill[pref[t]] < kappa  # (Nc,) in preference order
            p = jnp.argmax(avail)  # first cluster with room
            c = pref[t, p]
            has_room = jnp.any(avail)
            pos = fill[c]
            slots = lax.cond(
                has_room,
                lambda s: s.at[c, pos].set(t),
                lambda s: s,
                slots,
            )
            fill = lax.cond(
                has_room,
                lambda f: f.at[c].add(1),
                lambda f: f,
                fill,
            )
            return slots, fill

        slots, fill = lax.fori_loop(0, n, body, (slots0, fill0))
        valid = (jnp.arange(kappa)[None, :] < fill[:, None]).astype(ag.dtype)
        return slots, valid

    idx, valid = jax.vmap(one)(a_g)
    return idx, valid


def causal_greedy_cluster(a_g: jax.Array, kappa: int):
    """Causal clustering for the decoder extension (paper §5.5).

    Tokens are assigned in *position* order (not affinity order): token n's
    cluster depends only on tokens 0..n, so the assignment — not just the
    attention — is causal.  Each token goes to its highest-affinity cluster
    with remaining capacity; per-token affinity A_g[n] itself only reads
    token n's own q/k/phi, so no future information enters anywhere.
    """
    n = a_g.shape[1]
    n_c = a_g.shape[2]

    def one(ag):  # ag: (N, Nc)
        pref = argsort_desc(ag)  # (N, Nc) per-token cluster preference
        slots0 = jnp.zeros((n_c, kappa), dtype=jnp.int32)
        fill0 = jnp.zeros((n_c,), dtype=jnp.int32)

        def body(t, carry):
            slots, fill = carry
            avail = fill[pref[t]] < kappa
            p = jnp.argmax(avail)
            c = pref[t, p]
            has_room = jnp.any(avail)
            pos = fill[c]
            slots = lax.cond(has_room, lambda s: s.at[c, pos].set(t), lambda s: s, slots)
            fill = lax.cond(has_room, lambda f: f.at[c].add(1), lambda f: f, fill)
            return slots, fill

        slots, fill = lax.fori_loop(0, n, body, (slots0, fill0))
        valid = (jnp.arange(kappa)[None, :] < fill[:, None]).astype(ag.dtype)
        return slots, valid

    idx, valid = jax.vmap(one)(a_g)
    return idx, valid


def cluster(a_g: jax.Array, kappa: int, mechanism: str):
    """Dispatch to the configured clustering mechanism.

    Returns (idx, valid, member) — see module docstring.
    """
    # Indices are non-differentiable (paper §3.2); stop_gradient also keeps
    # jax from emitting a VJP through `sort`, whose take_along_axis-based
    # rule lowers to batched gathers the 0.5.1 HLO converter rejects.
    a_g_ng = lax.stop_gradient(a_g)
    if mechanism == "topk":
        idx, valid = top_k_cluster(a_g_ng, kappa)
    elif mechanism == "sa":
        idx, valid = sa_top_k_cluster(a_g_ng, kappa)
    elif mechanism == "causal":
        idx, valid = causal_greedy_cluster(a_g_ng, kappa)
    else:
        raise ValueError(f"unknown clustering mechanism {mechanism!r}")
    member = membership(idx, valid, a_g.shape[1])
    return idx, valid, member
