"""Baseline attention layers the paper compares against.

* ``vanilla``: the original O(N^2) multi-head self-attention
  (Vaswani et al., 2017) — the denominator of every relative number in
  Table 1 / Table 5.
* ``local``: LRA's Local Attention (Luong et al., 2015 windowing): the
  sequence is chunked into non-overlapping windows of ``cfg.window`` and
  full attention runs within each window.  No cross-window flow — the
  failure mode CAST's cluster summaries exist to fix.
* ``lsh``: Reformer-style LSH attention (Kitaev et al., 2020), the paper's
  main *clustering* comparator (§2, Appendix A.6.4): shared query/key
  representation, random-rotation hashing into Nc buckets, tokens sorted
  by bucket and chunked into fixed-size blocks, attention within blocks.
  Static random clustering directions — exactly the thing CAST's
  *learnable* surrogate tokens replace — and no cluster summaries, so no
  cross-bucket information flow.

All variants share the CAST layer's projection structure so parameter
counts are comparable.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import layers
from .configs import ModelConfig
from .kernels import ref as kernel_ref


def init(key, cfg: ModelConfig):
    ks = jax.random.split(key, 4)
    d = cfg.d
    return {
        "wq": layers.dense_init(ks[0], d, d),
        "wk": layers.dense_init(ks[1], d, d),
        "wv": layers.dense_init(ks[2], d, d),
        "wo": layers.dense_init(ks[3], d, d),
    }


def _qkv(p, x, cfg: ModelConfig):
    b, n, _ = x.shape
    h, d_h = cfg.h, cfg.d_h
    q = layers.dense(p["wq"], x).reshape(b, n, h, d_h)
    k = layers.dense(p["wk"], x).reshape(b, n, h, d_h)
    v = layers.dense(p["wv"], x).reshape(b, n, h, d_h)
    return q, k, v


def apply_vanilla(p, x, cfg: ModelConfig):
    b, n, d = x.shape
    q, k, v = _qkv(p, x, cfg)
    out = kernel_ref.full_attention_ref(q, k, v).reshape(b, n, d)
    return layers.dense(p["wo"], out)


def apply_local(p, x, cfg: ModelConfig):
    b, n, d = x.shape
    q, k, v = _qkv(p, x, cfg)
    out = kernel_ref.local_attention_ref(q, k, v, cfg.window).reshape(b, n, d)
    return layers.dense(p["wo"], out)


def lsh_buckets(qk: jax.Array, n_buckets: int, seed: int = 0) -> jax.Array:
    """Reformer hashing: argmax over [xR ; -xR] rotations.

    qk: (B, N, d) shared query-key representation -> (B, N) bucket ids in
    [0, n_buckets).  The rotation matrix is a fixed pseudorandom constant
    (Reformer re-draws per batch; a fixed draw keeps the artifact
    deterministic and changes nothing about the comparison).
    """
    d = qk.shape[-1]
    rot = jax.random.normal(jax.random.PRNGKey(seed), (d, max(1, n_buckets // 2)))
    h = qk @ rot  # (B, N, n_buckets//2)
    h = jnp.concatenate([h, -h], axis=-1)  # (B, N, n_buckets)
    return jnp.argmax(h, axis=-1).astype(jnp.int32)


def apply_lsh(p, x, cfg: ModelConfig):
    """LSH attention: hash, sort by bucket, chunk, attend within chunks.

    Shares W_q as the query-key projection (Reformer ties Q and K); V and
    the output projection are as in the other baselines.  Chunk size is
    ``cfg.kappa`` so efficiency is directly comparable to CAST at equal
    cluster size.
    """
    from . import clustering

    b, n, d = x.shape
    h, d_h = cfg.h, cfg.d_h
    qk = layers.dense(p["wq"], x)  # shared query-key representation
    v = layers.dense(p["wv"], x)
    buckets = lsh_buckets(jax.lax.stop_gradient(qk), cfg.n_c)  # (B, N)

    # sort tokens by bucket (stable), chunk into kappa-sized blocks
    order = clustering.argsort_desc(-buckets.astype(jnp.float32))  # ascending
    qk_s = jnp.take_along_axis(qk, order[..., None], axis=1)
    v_s = jnp.take_along_axis(v, order[..., None], axis=1)
    kappa = min(cfg.kappa, n)
    pad = (-n) % kappa
    if pad:
        qk_s = jnp.pad(qk_s, ((0, 0), (0, pad), (0, 0)))
        v_s = jnp.pad(v_s, ((0, 0), (0, pad), (0, 0)))
    m = qk_s.shape[1]
    qh = qk_s.reshape(b, m, h, d_h)
    vh = v_s.reshape(b, m, h, d_h)
    out = kernel_ref.local_attention_ref(qh, qh, vh, kappa).reshape(b, m, d)
    out = out[:, :n]
    # un-sort back to sequence order
    inv = clustering.argsort_desc(-order.astype(jnp.float32))
    out = jnp.take_along_axis(out, inv[..., None], axis=1)
    return layers.dense(p["wo"], out)
