//! Quickstart: load a prebuilt CAST artifact, run inference, run a few
//! training steps — the 60-second tour of the public API.
//!
//!     make artifacts && cargo run --release --example quickstart

use std::path::PathBuf;

use anyhow::{Context, Result};

use cast::data;
use cast::model::ModelState;
use cast::runtime::{Engine, HostTensor, Manifest};
use cast::train::{Schedule, TrainConfig, Trainer};
use cast::util::rng::Rng;

fn main() -> Result<()> {
    // 1. Artifacts are produced once by `make artifacts` (python AOT);
    //    at run time everything is rust + PJRT.
    let dir = PathBuf::from("artifacts/text_cast_topk_n64_b2_c4_k16");
    let manifest = Manifest::load(&dir)
        .context("tiny artifact missing — run `make artifacts` first")?;
    println!(
        "loaded {}: task={} variant={} seq={} Nc={} kappa={}",
        manifest.key,
        manifest.meta.task,
        manifest.meta.variant,
        manifest.meta.seq_len,
        manifest.meta.n_c,
        manifest.meta.kappa
    );

    // 2. Initialize parameters by executing the `init` artifact.
    let engine = Engine::cpu()?;
    let state = ModelState::init(&engine, &manifest, 42)?;
    println!("initialized {} tensors ({} parameters)", state.n_params(), state.total_elems());

    // 3. Inference: synthesize a batch and run `predict`.
    let gen = data::task(&manifest.meta.task)?;
    let mut rng = Rng::new(0);
    let batch = data::make_batch(gen.as_ref(), &mut rng, manifest.meta.batch, manifest.meta.seq_len);
    let predict = engine.load_hlo(&manifest.hlo_path("predict")?)?;
    let mut inputs: Vec<HostTensor> = state.params.clone();
    inputs.push(batch.tokens.clone());
    let logits = predict.run(&inputs)?;
    println!("logits: {:?} -> {:?}", logits[0].shape, logits[0].as_f32()?);

    // 4. Training: a handful of steps through the `train_step` artifact.
    let cfg = TrainConfig {
        steps: 10,
        schedule: Schedule::Warmup { lr: 1e-3, warmup: 3 },
        log_every: 2,
        eval_batches: 2,
        ..Default::default()
    };
    let mut trainer = Trainer::new(engine, manifest, cfg, 42)?;
    let report = trainer.run()?;
    println!(
        "10 steps done: loss {:.4} -> {:.4}, {:.2} steps/s",
        report.history.steps.first().map(|r| r.loss).unwrap_or(f32::NAN),
        report.final_train_loss,
        report.steps_per_sec
    );
    Ok(())
}
