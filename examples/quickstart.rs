//! Quickstart: build a model config, run inference, run a few training
//! steps — the 60-second tour of the public API.
//!
//!     cargo run --release --example quickstart
//!
//! No artifacts are required: when the tiny artifact directory is absent
//! the example synthesizes the same config in memory and the native
//! backend runs it.  With `make artifacts` + a `--features xla` build and
//! CAST_BACKEND=pjrt, the identical code drives the AOT HLO path.

use std::path::PathBuf;

use anyhow::Result;

use cast::data;
use cast::model::ModelState;
use cast::runtime::native::spec::tiny_meta;
use cast::runtime::{Engine, HostTensor, Manifest};
use cast::train::{Schedule, TrainConfig, Trainer};
use cast::util::rng::Rng;

fn main() -> Result<()> {
    // 1. A model config: from an artifact dir if one exists, otherwise
    //    synthesized in memory (zero files, zero Python).  A *present but
    //    unreadable* manifest is a real error and is reported as such.
    let dir = PathBuf::from("artifacts/text_cast_topk_n64_b2_c4_k16");
    let manifest = if dir.join("manifest.json").exists() {
        Manifest::load(&dir)?
    } else {
        println!("no artifact dir at {} — using an in-memory synthetic config", dir.display());
        Manifest::synthetic(tiny_meta("cast_topk"))
    };
    println!(
        "loaded {}: task={} variant={} seq={} Nc={} kappa={}",
        manifest.key,
        manifest.meta.task,
        manifest.meta.variant,
        manifest.meta.seq_len,
        manifest.meta.n_c,
        manifest.meta.kappa
    );

    // 2. Initialize parameters by executing the `init` program.
    let engine = Engine::auto()?;
    println!("backend: {}", engine.backend_name());
    let state = ModelState::init(&engine, &manifest, 42)?;
    println!("initialized {} tensors ({} parameters)", state.n_params(), state.total_elems());

    // 3. Inference: synthesize a batch and run `predict`.
    let gen = data::task(&manifest.meta.task)?;
    let mut rng = Rng::new(0);
    let batch = data::make_batch(gen.as_ref(), &mut rng, manifest.meta.batch, manifest.meta.seq_len);
    let predict = engine.load(&manifest, "predict")?;
    let mut inputs: Vec<HostTensor> = state.params.clone();
    inputs.push(batch.tokens.clone());
    let logits = predict.run(&inputs)?;
    println!("logits: {:?} -> {:?}", logits[0].shape, logits[0].as_f32()?);

    // 4. Training: a handful of steps through the `train_step` program.
    let cfg = TrainConfig {
        steps: 10,
        schedule: Schedule::Warmup { lr: 1e-3, warmup: 3 },
        log_every: 2,
        eval_batches: 2,
        ..Default::default()
    };
    let mut trainer = Trainer::new(engine, manifest, cfg, 42)?;
    let report = trainer.run()?;
    println!(
        "10 steps done: loss {:.4} -> {:.4}, {:.2} steps/s",
        report.history.steps.first().map(|r| r.loss).unwrap_or(f32::NAN),
        report.final_train_loss,
        report.steps_per_sec
    );
    Ok(())
}
