//! End-to-end driver: train CAST on real LRA workloads and log the loss
//! curve — the full-system validation run (see DESIGN.md §Layers).
//!
//! Trains the scaled ListOps and Image presets (built by `make artifacts`)
//! for a few hundred steps each, evaluating on a held-out stream, and
//! writes loss curves to `runs/<key>.json` + a markdown summary.
//!
//!     cargo run --release --example lra_train -- [--steps 300] [--tasks listops,image]

use std::path::PathBuf;

use anyhow::{Context, Result};

use cast::runtime::{Engine, Manifest};
use cast::train::{Schedule, TrainConfig, Trainer};
use cast::util::cli::Args;

const RUNS: &[(&str, &str)] = &[
    ("listops", "artifacts/listops_cast_topk_n256_b8_c8_k32"),
    ("image", "artifacts/image_cast_topk_n1024_b8_c8_k128"),
    ("image_vanilla", "artifacts/image_vanilla_n1024_b8"),
];

fn main() -> Result<()> {
    let args = Args::parse();
    let steps = args.usize("steps", 300);
    let want: Vec<String> = args
        .str("tasks", "listops,image,image_vanilla")
        .split(',')
        .map(|s| s.to_string())
        .collect();
    std::fs::create_dir_all("runs")?;
    let engine = Engine::cpu()?;

    let mut summary = String::from("| run | steps | first loss | final loss | train acc | eval acc | steps/s |\n|---|---|---|---|---|---|---|\n");
    for (name, dir) in RUNS {
        if !want.iter().any(|w| w == name) {
            continue;
        }
        let manifest = Manifest::load(&PathBuf::from(dir))
            .with_context(|| format!("{dir} missing — run `make artifacts`"))?;
        println!("=== training {name}: {} for {steps} steps ===", manifest.key);
        let cfg = TrainConfig {
            steps,
            schedule: Schedule::WarmupCosine {
                lr: args.f32("lr", 2e-3),
                warmup: steps / 10,
                total: steps,
                floor: 1e-4,
            },
            seed: args.u64("seed", 0),
            eval_every: (steps / 4).max(1),
            eval_batches: 8,
            data_workers: 3,
            queue_depth: 6,
            log_every: 20,
            checkpoint: Some(PathBuf::from(format!("runs/{name}.ckpt"))),
        };
        let key = manifest.key.clone();
        let mut trainer = Trainer::new(engine.clone(), manifest, cfg, 0)?;
        let report = trainer.run()?;
        report.history.save_json(&PathBuf::from(format!("runs/{name}.json")))?;
        report.history.save_csv(&PathBuf::from(format!("runs/{name}.csv")))?;
        let first = report.history.steps.first().map(|r| r.loss).unwrap_or(f32::NAN);
        summary.push_str(&format!(
            "| {key} | {steps} | {first:.4} | {:.4} | {:.3} | {} | {:.3} |\n",
            report.final_train_loss,
            report.final_train_acc,
            report
                .best_eval_acc
                .map(|a| format!("{a:.3}"))
                .unwrap_or_else(|| "-".into()),
            report.steps_per_sec,
        ));
    }
    std::fs::write("runs/summary.md", &summary)?;
    println!("\n{summary}\nwritten to runs/summary.md");
    Ok(())
}
