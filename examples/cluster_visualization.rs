//! Figure 4 / Appendix A.6.3 pipeline: train a small CAST model on the
//! Image task, then render which pixels each surrogate-token cluster
//! claims, per layer — the foreground/background separation analysis.
//!
//!     cargo run --release --example cluster_visualization -- [--steps 150]
//!
//! Outputs to viz_out/: input.pgm, layer{i}_clusters.ppm (one color per
//! cluster), layer{i}_cluster{c}_scores.pgm (A_g heatmaps).

use std::path::PathBuf;

use anyhow::{Context, Result};

use cast::analysis;
use cast::data;
use cast::runtime::{Engine, Manifest};
use cast::train::{Schedule, TrainConfig, Trainer};
use cast::util::cli::Args;
use cast::util::rng::Rng;

fn main() -> Result<()> {
    let args = Args::parse();
    // SA Top-K + 8 clusters, matching the paper's Figure-4 setup.
    let dir = PathBuf::from(args.str("dir", "artifacts/image_cast_sa_n1024_b8_c8_k128"));
    let manifest =
        Manifest::load(&dir).context("image artifact missing — run `make artifacts`")?;
    let engine = Engine::cpu()?;

    let steps = args.usize("steps", 150);
    println!("training {} for {steps} steps before visualizing ...", manifest.key);
    let cfg = TrainConfig {
        steps,
        schedule: Schedule::Warmup { lr: args.f32("lr", 2e-3), warmup: steps / 10 },
        eval_batches: 4,
        log_every: 25,
        ..Default::default()
    };
    let meta_batch = manifest.meta.batch;
    let meta_seq = manifest.meta.seq_len;
    let task = manifest.meta.task.clone();
    let mut trainer = Trainer::new(engine.clone(), manifest, cfg, 0)?;
    let report = trainer.run()?;
    println!("trained: final loss {:.4}", report.final_train_loss);

    let gen = data::task(&task)?;
    let mut rng = Rng::new(args.u64("seed", 1234));
    let batch = data::make_batch(gen.as_ref(), &mut rng, meta_batch, meta_seq);
    let out = PathBuf::from(args.str("out", "viz_out"));
    let files = analysis::visualize_image_clusters(
        &engine,
        &trainer.manifest,
        &trainer.state,
        &batch.tokens,
        args.usize("index", 0),
        &out,
    )?;
    println!("wrote {} images to {}/ :", files.len(), out.display());
    for f in files.iter().take(6) {
        println!("  {}", f.display());
    }
    println!("  ... (open .ppm/.pgm with any netpbm viewer)");
    Ok(())
}
