//! Reproduce the paper's efficiency story in one command: the measured
//! Table-1-style rows (steps/s + peak memory relative to the vanilla
//! Transformer, same hyperparameters) next to the analytic §3.4 model.
//!
//!     make artifacts-efficiency
//!     cargo run --release --example efficiency_report -- [--steps 5] [--isolate]

use std::path::PathBuf;

use anyhow::Result;

use cast::bench::{efficiency_table, memmodel};
use cast::coordinator::JobKind;
use cast::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::parse();
    let root = PathBuf::from(args.str("artifacts", "artifacts"));
    let steps = args.usize("steps", 5);
    let seq_lens = [1024usize, 2048, 3072, 4096];

    println!("# Analytic model (paper §3.4): predicted CAST/Transformer memory ratio\n");
    println!("| N | kappa=200 ratio | alpha |");
    println!("|---|---|---|");
    for &seq in &seq_lens {
        let n_c = seq.div_ceil(200);
        let s = memmodel::AttnShape { batch: 25, seq, heads: 4, d: 64, n_c, kappa: 200 };
        println!("| {seq} | {:.3} | {} |", s.memory_ratio(), s.alpha());
    }

    println!("\n# Measured (this CPU testbed, scaled models)\n");
    let table = efficiency_table(
        &root,
        &args.str("task", "text"),
        &seq_lens,
        JobKind::TrainEfficiency { steps },
        args.has("isolate"),
        "Table 1 (measured): training efficiency relative to Transformer",
    )?;
    println!("{}", table.render());
    println!(
        "paper reference @4K: CAST Top-K 6.18x speed, 0.10x memory; \
         shapes (who wins, direction of scaling) are the reproduction target."
    );
    Ok(())
}
