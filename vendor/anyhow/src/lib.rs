//! Offline substrate for the `anyhow` error-handling crate.
//!
//! The build environment has no network and no crates.io mirror (the same
//! constraint that produced `util/json`, `util/rng`, and `util/prop` in the
//! main crate), so this workspace vendors the small slice of anyhow's API
//! the codebase actually uses:
//!
//! * `Result<T>` / `Error` with a context *chain* rendered by `{:#}`
//! * the `Context` trait (`.context(..)` / `.with_context(|| ..)`) on both
//!   `Result` and `Option`
//! * the `anyhow!`, `bail!`, and `ensure!` macros
//! * blanket `From<E: std::error::Error>` so `?` converts std errors
//!
//! Like the real crate, `Error` deliberately does **not** implement
//! `std::error::Error` — that is what makes the blanket `From` impl
//! coherent.

use std::fmt;

/// `Result` specialized to [`Error`], matching `anyhow::Result`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A context-chained error. `chain[0]` is the outermost (most recent)
/// context message; the last entry is the root cause.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from a single message (the `anyhow!` macro's backend).
    pub fn msg(message: impl Into<String>) -> Error {
        Error { chain: vec![message.into()] }
    }

    /// Push an outer context message onto the chain.
    pub fn context(mut self, context: impl fmt::Display) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The root cause (innermost message).
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` renders the whole chain, outermost first — the format
            // every `eprintln!("error: {e:#}")` in the workspace relies on.
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

/// `Debug` matches anyhow's shape: message, then a `Caused by:` list.
impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// Attach context to a fallible value, exactly like `anyhow::Context`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f().to_string()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::Error::msg(format!($($arg)*)))
    };
}

/// Return early with an error when a condition does not hold.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(format!(
                "condition failed: {}",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::Error::msg(format!($($arg)*)));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<String> {
        let s = std::fs::read_to_string("/nonexistent/anyhow/shim/test")
            .context("reading test file")?;
        Ok(s)
    }

    #[test]
    fn context_chain_renders_outermost_first() {
        let e = io_fail().unwrap_err();
        let full = format!("{e:#}");
        assert!(full.starts_with("reading test file: "), "{full}");
        assert_eq!(format!("{e}"), "reading test file");
    }

    #[test]
    fn option_context_and_macros() {
        let none: Option<u32> = None;
        let e = none.with_context(|| format!("missing {}", "thing")).unwrap_err();
        assert_eq!(format!("{e}"), "missing thing");

        fn barf() -> Result<()> {
            bail!("bad {}", 7);
        }
        assert_eq!(format!("{}", barf().unwrap_err()), "bad 7");

        fn check(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            Ok(x)
        }
        assert!(check(3).is_ok());
        assert_eq!(format!("{}", check(30).unwrap_err()), "x too big: 30");

        let e = anyhow!("standalone {}", 1);
        assert_eq!(format!("{e}"), "standalone 1");
    }
}
