//! Shared bench-harness helpers (criterion is unavailable offline; every
//! bench is a `harness = false` main that prints its paper table).

use std::path::PathBuf;

pub fn artifacts_root() -> PathBuf {
    std::env::var("CAST_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"))
}

/// Steps per measured config; benches honour CAST_BENCH_STEPS.
pub fn bench_steps(default: usize) -> usize {
    std::env::var("CAST_BENCH_STEPS").ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}

/// Graceful skip: `cargo bench` runs every bench, but the heavier suites
/// need their artifact sets built first.
pub fn skip(msg: &str) -> ! {
    println!("SKIPPED: {msg}");
    std::process::exit(0)
}

pub fn has_artifacts_matching(prefix: &str) -> bool {
    cast::runtime::artifacts::discover(&artifacts_root())
        .iter()
        .any(|d| d.file_name().map(|n| n.to_string_lossy().starts_with(prefix)).unwrap_or(false))
}
