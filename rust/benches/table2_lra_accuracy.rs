//! Paper Table 2: LRA classification accuracy per task, CAST (Top-K and
//! SA Top-K) vs the vanilla Transformer — short-budget version.
//!
//! Full training runs take hours on CPU; this bench trains each artifact
//! for CAST_BENCH_STEPS (default 60) steps and reports held-out accuracy,
//! which is enough to reproduce the paper's *comparative* claim (CAST
//! learns the tasks about as well as the quadratic Transformer at equal
//! hyperparameters).  Build inputs: `make artifacts-lra`.

mod bench_common;

use bench_common::*;
use cast::bench::{parse_key, AccuracyTable};
use cast::coordinator::sweep::{jobs_matching, Sweep};
use cast::coordinator::JobKind;
use cast::runtime::Engine;

const TASKS: &[&str] = &["listops", "text", "retrieval", "image", "pathfinder"];

fn main() {
    if !has_artifacts_matching("listops_cast_topk_n512") {
        skip("Table-2 artifacts missing — run `make artifacts-lra`");
    }
    let steps = bench_steps(60);
    let sweep = Sweep::new();
    let engine = Engine::cpu().expect("engine");
    let mut table = AccuracyTable::new(
        &format!("Table 2: LRA accuracy after {steps} steps (scaled models, synthetic LRA)"),
        TASKS,
    );
    for task in TASKS {
        let t = task.to_string();
        let jobs = jobs_matching(
            &artifacts_root(),
            move |key| {
                key.starts_with(&format!("{t}_"))
                    && key.contains(&format!("n{}", lra_seq(&t)))
            },
            JobKind::Train { steps, lr: 2e-3, warmup: steps / 10 },
            0,
        );
        for (job, res) in sweep.run_all(&engine, &jobs, false) {
            let key = job.artifact_dir.file_name().unwrap().to_string_lossy().to_string();
            let variant = parse_key(&key).map(|(v, _)| v).unwrap_or_default();
            match res {
                Ok(r) => {
                    let acc = r.eval_acc.unwrap_or(r.final_acc) as f64 * 100.0;
                    table.insert(&variant, task, acc);
                }
                Err(e) => println!("skip {key}: {e:#}"),
            }
        }
    }
    println!("{}", table.render());
    println!(
        "paper (full budget): CAST Top-K avg 59.32, SA Top-K 57.57, Transformer 57.71 — \
         the reproduction claim is comparative (CAST ≈ Transformer quality)."
    );
}

fn lra_seq(task: &str) -> usize {
    match task {
        "listops" => 512,
        "text" => 1024,
        "retrieval" => 512,
        _ => 1024,
    }
}
