//! Paper Table 5 (Appendix A.6.1): inference steps/s and peak memory vs
//! the vanilla Transformer, Text task @ 1K..4K.
//!
//! Build inputs first: `make artifacts-efficiency`.

mod bench_common;

use bench_common::*;
use cast::bench::efficiency_table;
use cast::coordinator::JobKind;

fn main() {
    if !has_artifacts_matching("text_cast_topk_n1024") {
        skip("Table-5 artifacts missing — run `make artifacts-efficiency`");
    }
    let steps = bench_steps(8);
    let table = efficiency_table(
        &artifacts_root(),
        "text",
        &[1024, 2048, 3072, 4096],
        JobKind::InferEfficiency { steps },
        std::env::var("CAST_NO_ISOLATE").is_err(),
        "Table 5: inference efficiency relative to Transformer (Text task)",
    )
    .expect("table 5 run failed");
    println!("{}", table.render());
    println!("paper @4K: CAST(Top-K) 6.91x steps/s, 0.081x memory.");
}
