//! Paper Table 5 (Appendix A.6.1): inference steps/s and peak memory vs
//! the vanilla Transformer, Text task @ 1K..4K.
//!
//! Build inputs first: `make artifacts-efficiency`.

mod bench_common;

use bench_common::*;
use cast::bench::{efficiency_rows, table_from_rows, write_bench_json};
use cast::coordinator::JobKind;

fn main() {
    if !has_artifacts_matching("text_cast_topk_n1024") {
        skip("Table-5 artifacts missing — run `make artifacts-efficiency`");
    }
    let steps = bench_steps(8);
    let seq_lens = [1024, 2048, 3072, 4096];
    let rows = efficiency_rows(
        &artifacts_root(),
        "text",
        &seq_lens,
        JobKind::InferEfficiency { steps },
        std::env::var("CAST_NO_ISOLATE").is_err(),
    )
    .expect("table 5 run failed");
    let table = table_from_rows(
        "Table 5: inference efficiency relative to Transformer (Text task)",
        "vanilla",
        &seq_lens,
        &rows,
    );
    println!("{}", table.render());
    if let Ok(path) = std::env::var("CAST_BENCH_JSON") {
        write_bench_json(std::path::Path::new(&path), &rows).expect("writing bench json");
        println!("bench json -> {path}");
    }
    println!("paper @4K: CAST(Top-K) 6.91x steps/s, 0.081x memory.");
}
