//! Paper Figure 3 (a–f): cluster-size ablation — accuracy proxy, peak
//! memory, and training steps/s for kappa ∈ {32,64,128,256,512} with both
//! clustering mechanisms, on the Text and Image tasks.
//!
//! Build inputs first: `make artifacts-ablation`.

mod bench_common;

use bench_common::*;
use cast::bench::ablation_points;

fn main() {
    if !has_artifacts_matching("text_cast_topk_n2048") {
        skip("Figure-3 artifacts missing — run `make artifacts-ablation`");
    }
    let steps = bench_steps(4);
    let isolate = std::env::var("CAST_NO_ISOLATE").is_err();
    for task in ["text", "image"] {
        println!("## Figure 3 ({task}): kappa sweep\n");
        println!("| variant | kappa | Nc | steps/s | peak RSS (MB) | loss@{steps} |");
        println!("|---|---|---|---|---|---|");
        let points = ablation_points(&artifacts_root(), task, steps, isolate)
            .expect("ablation run failed");
        for p in &points {
            println!(
                "| {} | {} | {} | {:.3} | {:.1} | {:.4} |",
                p.variant,
                p.kappa,
                p.n_c,
                p.result.steps_per_sec,
                p.result.peak_rss_bytes as f64 / 1e6,
                p.result.final_loss
            );
        }
        println!();
    }
    println!(
        "paper shapes to check: (c,f) Top-K faster than SA Top-K everywhere, gap \
         largest at small kappa on long sequences; (b,e) memory minimal near \
         Nc^2 = kappa; (a,d) accuracy flat-ish in kappa for Text, dip at 64-128 \
         for Image."
    );
}
