//! Paper Figure 4 + Appendix Figures 7–9: cluster-assignment and A_g
//! score visualizations on the Image task (8 surrogate tokens, SA Top-K).
//!
//! Trains briefly, then writes netpbm images under bench_out/fig4/.
//! Build inputs first: `make artifacts` (default suite).

mod bench_common;

use std::path::PathBuf;

use bench_common::*;
use cast::analysis;
use cast::data;
use cast::runtime::{Engine, Manifest};
use cast::train::{Schedule, TrainConfig, Trainer};
use cast::util::rng::Rng;

fn main() {
    let dir = artifacts_root().join("image_cast_sa_n1024_b8_c8_k128");
    if !dir.join("manifest.json").exists() {
        skip("Figure-4 artifact missing — run `make artifacts`");
    }
    let steps = bench_steps(80);
    let manifest = Manifest::load(&dir).expect("manifest");
    let engine = Engine::cpu().expect("engine");
    let cfg = TrainConfig {
        steps,
        schedule: Schedule::Warmup { lr: 2e-3, warmup: steps / 10 },
        eval_batches: 0,
        log_every: 0,
        ..Default::default()
    };
    let b = manifest.meta.batch;
    let n = manifest.meta.seq_len;
    let mut trainer = Trainer::new(engine.clone(), manifest, cfg, 0).expect("trainer");
    let report = trainer.run().expect("train");
    println!("trained {steps} steps (loss {:.4}); rendering clusters ...", report.final_train_loss);

    let gen = data::task("image").expect("gen");
    // three sample images, as in Appendix A.6.3
    for (i, seed) in [11u64, 22, 33].iter().enumerate() {
        let mut rng = Rng::new(*seed);
        let batch = data::make_batch(gen.as_ref(), &mut rng, b, n);
        let out = PathBuf::from(format!("bench_out/fig4/sample{i}"));
        let files = analysis::visualize_image_clusters(
            &engine,
            &trainer.manifest,
            &trainer.state,
            &batch.tokens,
            0,
            &out,
        )
        .expect("viz");
        println!("sample {i}: {} images -> {}", files.len(), out.display());
    }
    println!("inspect layer0 vs layer1 cluster maps: early layers cluster by position (slices), later layers by content — the paper's §5.4 observation.");
}
