//! Paper §3.4 complexity claims, regenerated from the analytic model (no
//! artifacts needed): O(αN) scaling, the CAST/Transformer memory ratio
//! curve of Table 1, and the Nc²=κ memory minimum.  Also prints the
//! fused-kernel TPU estimate from DESIGN.md §Hardware-Adaptation.

mod bench_common;

use cast::bench::memmodel::{kappa_memory_curve, kernel_estimate, AttnShape, TPU_VMEM_BYTES};

fn main() {
    println!("## §3.4 check 1: memory ratio vs sequence length (kappa=200, Table-1 shape)\n");
    println!("| N | predicted CAST/Transformer memory | paper measured |");
    println!("|---|---|---|");
    let paper = [(1024, 0.33), (2048, 0.18), (3072, 0.13), (4096, 0.10)];
    for (seq, paper_ratio) in paper {
        let s = AttnShape { batch: 25, seq, heads: 4, d: 64, n_c: seq.div_ceil(200), kappa: 200 };
        println!("| {seq} | {:.3} | {paper_ratio} |", s.memory_ratio());
    }

    println!("\n## §3.4 check 2: memory minimum near Nc² = kappa (N = 4096)\n");
    println!("| kappa | Nc | Nc² | predicted attention bytes |");
    println!("|---|---|---|---|");
    let kappas = [32, 64, 128, 256, 512, 1024];
    let curve = kappa_memory_curve(1, 4096, 2, 64, &kappas);
    let best = curve.iter().min_by_key(|(_, b)| *b).unwrap().0;
    for (kappa, bytes) in &curve {
        let n_c = 4096usize.div_ceil(*kappa);
        let star = if kappa == &best { " <- min" } else { "" };
        println!("| {kappa} | {n_c} | {} | {bytes}{star} |", n_c * n_c);
    }
    println!("\npaper: theoretical minimum at Nc² = kappa -> kappa = N^(2/3) = 256 for N=4096.");
    assert!((128..=512).contains(&best), "model minimum drifted from paper prediction");

    println!("\n## FLOPs scaling: CAST is O(N), Transformer O(N²)\n");
    println!("| N | CAST flops | Transformer flops | ratio |");
    println!("|---|---|---|---|");
    for seq in [1024usize, 2048, 4096, 8192, 16384] {
        let s = AttnShape { batch: 1, seq, heads: 4, d: 64, n_c: seq.div_ceil(200), kappa: 200 };
        let (c, v) = (s.cast_attn_flops(), s.vanilla_attn_flops());
        println!("| {seq} | {c} | {v} | {:.3} |", c as f64 / v as f64);
    }

    println!("\n## Fused-kernel TPU estimate (DESIGN.md §Hardware-Adaptation)\n");
    println!("| kappa | VMEM/step | fits 16MB VMEM (2x buffered) | flops/step | intensity (f/B) |");
    println!("|---|---|---|---|---|");
    for kappa in [128usize, 256, 512] {
        let e = kernel_estimate(kappa, 64);
        println!(
            "| {kappa} | {:.1} KB | {} | {} | {:.1} |",
            e.vmem_bytes as f64 / 1024.0,
            if e.vmem_bytes < TPU_VMEM_BYTES / 2 { "yes" } else { "no" },
            e.mxu_flops,
            e.arithmetic_intensity
        );
    }
    println!("\nMXU ridge ~240 f/B (v4-like): kappa>=256 keeps the kernel compute-bound.");
}
