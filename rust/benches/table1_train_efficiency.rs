//! Paper Table 1: training steps/s and peak memory vs the vanilla
//! Transformer on the Text task at 1K/2K/3K/4K (CAST kappa=200).
//!
//! Build inputs first: `make artifacts-efficiency`.  Then:
//!     cargo bench --bench table1_train_efficiency
//! Peak memory uses child-process isolation (VmHWM per config).

mod bench_common;

use bench_common::*;
use cast::bench::{efficiency_rows, table_from_rows, write_bench_json};
use cast::coordinator::JobKind;

fn main() {
    if !has_artifacts_matching("text_cast_topk_n1024") {
        skip("Table-1 artifacts missing — run `make artifacts-efficiency`");
    }
    let steps = bench_steps(5);
    let seq_lens = [1024, 2048, 3072, 4096];
    let rows = efficiency_rows(
        &artifacts_root(),
        "text",
        &seq_lens,
        JobKind::TrainEfficiency { steps },
        std::env::var("CAST_NO_ISOLATE").is_err(),
    )
    .expect("table 1 run failed");
    let table = table_from_rows(
        "Table 1: training efficiency relative to Transformer (Text task)",
        "vanilla",
        &seq_lens,
        &rows,
    );
    println!("{}", table.render());
    if let Ok(path) = std::env::var("CAST_BENCH_JSON") {
        write_bench_json(std::path::Path::new(&path), &rows).expect("writing bench json");
        println!("bench json -> {path}");
    }
    println!("paper @4K: CAST(Top-K) 6.18x steps/s, 0.10x memory; CAST(SA) 2.62x, 0.10x.");
}
