//! Tracing/profiling integration suite: the disabled path records
//! nothing and tracing never perturbs results (the bit-identical
//! determinism contract), drained span trees are well-formed, the serve
//! stage histograms stay consistent with the request count, and the
//! Chrome trace export is valid JSON.
//!
//! Every test that flips the global tracer holds `trace::test_guard()`
//! so tests in this binary serialize around the shared state.

use std::net::{SocketAddr, TcpStream};
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

use cast::model::ModelState;
use cast::runtime::native::spec::tiny_meta;
use cast::runtime::{Engine, HostTensor, Manifest};
use cast::serve::http;
use cast::serve::{ModelSource, Registry, ServeConfig, Server};
use cast::util::json::Json;
use cast::util::trace;

// ---------------------------------------------------------------------------
// engine-side: zero-record disabled path, bit-identical traced outputs
// ---------------------------------------------------------------------------

/// One forward pass of the tiny cast_topk config, returning the logits.
fn predict_logits(seed: u32) -> Vec<f32> {
    let engine = Engine::cpu().unwrap();
    let manifest = Manifest::synthetic(tiny_meta("cast_topk"));
    let exe = engine.load(&manifest, "predict").unwrap();
    let state = ModelState::init(&engine, &manifest, seed).unwrap();
    let meta = &manifest.meta;
    let tokens: Vec<i32> =
        (0..meta.batch * meta.seq_len).map(|i| (i * 7 % 50) as i32).collect();
    let tensor = HostTensor::s32(vec![meta.batch, meta.seq_len], tokens);
    let mut inputs: Vec<&HostTensor> = state.params.iter().collect();
    inputs.push(&tensor);
    let out = exe.run_refs(&inputs).unwrap();
    out[0].as_f32().unwrap().to_vec()
}

#[test]
fn disabled_tracer_records_nothing_and_tracing_is_bit_identical() {
    let _g = trace::test_guard();

    trace::set_enabled(false);
    trace::clear();
    let baseline = predict_logits(3);
    let t = trace::drain();
    assert!(
        t.spans.is_empty() && t.events.is_empty(),
        "disabled tracer must record nothing ({} spans, {} events)",
        t.spans.len(),
        t.events.len()
    );

    trace::set_enabled(true);
    trace::clear();
    let traced = predict_logits(3);
    let spans = trace::drain().spans;
    trace::set_enabled(false);

    // exact f32 equality: tracing only reads the clock and pushes to
    // thread-local buffers, so every output bit must match
    assert_eq!(baseline.len(), traced.len());
    for (i, (b, t)) in baseline.iter().zip(&traced).enumerate() {
        assert_eq!(b.to_bits(), t.to_bits(), "logit {i} differs under tracing");
    }

    assert!(!spans.is_empty(), "traced forward pass must record spans");
    for want in ["embed", "attn", "attn.cast_topk", "attn.qkv_proj", "pool", "head"] {
        assert!(
            spans.iter().any(|s| s.name == want),
            "expected a {want:?} span in {:?}",
            spans.iter().map(|s| s.name).collect::<Vec<_>>()
        );
    }
    // per-layer attribution: the tiny config has 2 layers
    let layers: Vec<i32> =
        spans.iter().filter(|s| s.name == "attn").map(|s| s.layer).collect();
    assert!(layers.contains(&0) && layers.contains(&1), "attn layers seen: {layers:?}");
}

#[test]
fn drained_span_trees_are_well_formed() {
    let _g = trace::test_guard();
    trace::set_enabled(true);
    trace::clear();
    let _ = predict_logits(5);
    let spans = trace::drain().spans;
    trace::set_enabled(false);
    assert!(!spans.is_empty());

    for s in &spans {
        assert!(s.self_ns <= s.dur_ns, "{}: self {} > dur {}", s.name, s.self_ns, s.dur_ns);
    }
    // drain() sorts by (start_ns, tid)
    for w in spans.windows(2) {
        assert!((w[0].start_ns, w[0].tid) <= (w[1].start_ns, w[1].tid));
    }
    // depth consistency: every nested span lies inside an enclosing span
    // one level up on the same thread
    for s in spans.iter().filter(|s| s.depth > 0) {
        let end = s.start_ns + s.dur_ns;
        let parent = spans.iter().any(|p| {
            p.tid == s.tid
                && p.depth + 1 == s.depth
                && p.start_ns <= s.start_ns
                && p.start_ns + p.dur_ns >= end
        });
        assert!(parent, "span {:?} (depth {}) has no enclosing parent", s.name, s.depth);
    }
    // self-time partitions traced time: shares sum to 100%
    let stats = trace::summarize(&spans);
    let total: f64 = stats.iter().map(|s| s.share_pct).sum();
    assert!((total - 100.0).abs() < 1e-6, "shares sum to {total}");
}

#[test]
fn chrome_export_is_valid_json_with_complete_events() {
    let _g = trace::test_guard();
    trace::set_enabled(true);
    trace::clear();
    {
        let _outer = trace::span("outer_op");
        let _inner = trace::span_layer("inner_op", 3);
        trace::event("fault:engine.layer");
    }
    let t = trace::drain();
    trace::set_enabled(false);

    let parsed = Json::parse(&trace::chrome_json(&t)).expect("chrome export must parse");
    let evs = parsed.get("traceEvents").and_then(Json::as_arr).expect("traceEvents array");
    assert_eq!(evs.len(), 3, "2 spans + 1 instant event");
    let complete: Vec<&Json> =
        evs.iter().filter(|e| e.get("ph").and_then(Json::as_str) == Some("X")).collect();
    assert_eq!(complete.len(), 2);
    for e in &complete {
        assert!(e.get("name").and_then(Json::as_str).is_some());
        assert!(e.get("ts").and_then(Json::as_f64).is_some());
        assert!(e.get("dur").and_then(Json::as_f64).is_some());
    }
    let instants: Vec<&Json> =
        evs.iter().filter(|e| e.get("ph").and_then(Json::as_str) == Some("i")).collect();
    assert_eq!(instants.len(), 1);
    assert_eq!(instants[0].get("name").and_then(Json::as_str), Some("fault:engine.layer"));
}

// ---------------------------------------------------------------------------
// serve-side: stage histograms, /debug/trace, X-Stage-Timings
// ---------------------------------------------------------------------------

struct Harness {
    server: Arc<Server>,
    addr: SocketAddr,
    join: Option<std::thread::JoinHandle<anyhow::Result<()>>>,
}

impl Harness {
    fn start() -> Harness {
        let registry = Arc::new(Registry::new(Engine::cpu().unwrap()));
        registry
            .load(None, ModelSource::Synthetic { meta: tiny_meta("cast_topk"), seed: 5 })
            .unwrap();
        let cfg = ServeConfig { addr: "127.0.0.1:0".to_string(), ..ServeConfig::default() };
        let server = Arc::new(Server::bind(cfg, registry).unwrap());
        let addr = server.local_addr();
        let runner = server.clone();
        let join = std::thread::spawn(move || runner.run());
        Harness { server, addr, join: Some(join) }
    }

    fn stop(&mut self) {
        self.server.shutdown_flag().store(true, Ordering::SeqCst);
        if let Some(join) = self.join.take() {
            join.join().expect("server thread panicked").expect("server run failed");
        }
    }
}

impl Drop for Harness {
    fn drop(&mut self) {
        if self.join.is_some() {
            self.stop();
        }
    }
}

fn request(addr: SocketAddr, method: &str, target: &str, body: &[u8]) -> http::Response {
    let mut s = TcpStream::connect(addr).unwrap();
    http::write_request(&mut s, method, target, body).unwrap();
    http::read_response(&mut s, &mut Vec::new(), http::CLIENT_MAX_BODY).unwrap()
}

fn predict_body(fill: i32) -> String {
    let vals: Vec<usize> = (0..64).map(|i| ((fill + i) % 50) as usize).collect();
    Json::obj(vec![("tokens", Json::Arr(vec![Json::arr_usize(&vals)]))]).to_string()
}

#[test]
fn stage_histograms_count_every_request_and_debug_trace_replays_them() {
    let _g = trace::test_guard();
    trace::set_enabled(false);
    let mut h = Harness::start();
    let n_requests = 5usize;
    for i in 0..n_requests {
        let resp = request(h.addr, "POST", "/predict", predict_body(i as i32).as_bytes());
        assert_eq!(resp.status, 200, "{}", String::from_utf8_lossy(&resp.body));
        // stage timings flow to /metrics and /debug/trace even with the
        // tracer off; only the response header is gated on CAST_TRACE
        assert!(
            !resp.headers.contains_key("x-stage-timings"),
            "header must be absent with tracing disabled"
        );
    }

    let resp = request(h.addr, "GET", "/metrics", b"");
    assert_eq!(resp.status, 200);
    let page = String::from_utf8(resp.body).unwrap();
    for stage in cast::serve::metrics::STAGES {
        let needle = format!(
            "cast_serve_stage_seconds_count{{stage=\"{stage}\"}} {n_requests}"
        );
        assert!(page.contains(&needle), "missing {needle:?} in:\n{page}");
        // bucket series carry the stage label too
        let bucket = format!("cast_serve_stage_seconds_bucket{{stage=\"{stage}\",le=");
        assert!(page.contains(&bucket), "missing bucket series for {stage}");
    }

    let resp = request(h.addr, "GET", "/debug/trace?n=3", b"");
    assert_eq!(resp.status, 200);
    let parsed = Json::parse(std::str::from_utf8(&resp.body).unwrap()).unwrap();
    let rows = parsed.get("requests").and_then(Json::as_arr).expect("requests array");
    assert_eq!(rows.len(), 3, "?n=3 caps the replay");
    for row in rows {
        assert_eq!(row.get("status").and_then(Json::as_usize), Some(200));
        assert_eq!(row.get("rows").and_then(Json::as_usize), Some(1));
        let total = row.get("total_us").and_then(Json::as_f64).unwrap();
        let parts: f64 = ["parse_us", "queue_us", "batch_us", "compute_us", "reply_us"]
            .iter()
            .map(|k| row.get(k).and_then(Json::as_f64).unwrap())
            .sum();
        assert_eq!(total, parts, "total_us must equal the stage sum");
    }
    // ring is newest-last: the last row is the most recent request
    let seqs: Vec<f64> =
        rows.iter().map(|r| r.get("seq").and_then(Json::as_f64).unwrap()).collect();
    assert!(seqs.windows(2).all(|w| w[0] < w[1]), "seqs not ascending: {seqs:?}");

    h.stop();
}

#[test]
fn stage_timing_header_appears_when_tracing_is_on() {
    let _g = trace::test_guard();
    trace::set_enabled(true);
    trace::clear();
    let mut h = Harness::start();
    let resp = request(h.addr, "POST", "/predict", predict_body(9).as_bytes());
    assert_eq!(resp.status, 200, "{}", String::from_utf8_lossy(&resp.body));
    let header = resp
        .headers
        .get("x-stage-timings")
        .expect("X-Stage-Timings must be present under tracing")
        .clone();
    h.stop();
    trace::set_enabled(false);
    trace::clear();

    // parseable k=v;k=v with all five stages
    let mut stages = Vec::new();
    for field in header.split(';') {
        let (k, v) = field.split_once('=').expect("k=v fields");
        v.parse::<u64>().expect("integer microseconds");
        stages.push(k.to_string());
    }
    assert_eq!(stages, ["parse", "queue", "batch", "compute", "reply"]);
}
