//! Parallel-vs-serial parity for the native engine: with
//! `CAST_NUM_THREADS=1` (here: `parallel::set_threads(1)`) and with a
//! multi-worker pool, every layer and the full predict path must agree —
//! bit-for-bit for `dense`, ≤ 1e-5 elsewhere (the engine's helpers are
//! designed to be bit-identical for any worker count; the tolerance is
//! headroom, not an excuse) — and repeated threaded runs must be
//! bit-for-bit deterministic.
//!
//! The thread override is process-global, which is safe exactly because
//! the engine's results never depend on the worker count.

use cast::runtime::artifacts::Manifest;
use cast::runtime::native::grad;
use cast::runtime::native::layer::{
    cast_layer, local_layer, lsh_layer, vanilla_layer, BaselineParams, CastParams, CastScratch,
    Dims,
};
use cast::runtime::native::model::{run_init, run_predict};
use cast::runtime::native::ops::{self, AttnFn};
use cast::runtime::native::spec::tiny_meta;
use cast::runtime::tensor::HostTensor;
use cast::util::parallel;
use cast::util::rng::Rng;

const THREADED: usize = 4;

/// Serializes every test body that touches the process-global thread
/// override, so a concurrently-running test can never retarget the pool
/// mid-comparison (which would silently turn a serial-vs-threaded parity
/// check into threaded-vs-threaded).
static THREAD_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

fn with_threads<T>(n: usize, f: impl FnOnce() -> T) -> T {
    let _guard = THREAD_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    parallel::set_threads(n);
    let out = f();
    parallel::set_threads(0);
    out
}

fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}

#[test]
fn dense_is_bit_for_bit_across_thread_counts() {
    let mut rng = Rng::new(17);
    // deliberately awkward sizes to exercise remainder chunks
    let (rows, d_in, d_out) = (37usize, 19usize, 23usize);
    let x: Vec<f32> = (0..rows * d_in).map(|_| rng.gaussian() as f32).collect();
    let w: Vec<f32> = (0..d_in * d_out).map(|_| rng.gaussian() as f32).collect();
    let b: Vec<f32> = (0..d_out).map(|_| rng.gaussian() as f32).collect();
    let serial = with_threads(1, || ops::dense(&x, &w, &b, rows, d_in, d_out));
    let threaded = with_threads(THREADED, || ops::dense(&x, &w, &b, rows, d_in, d_out));
    assert_eq!(serial, threaded, "dense must be bit-for-bit identical");
}

fn layer_dims(clustering: &str, attn: AttnFn) -> Dims {
    Dims {
        b: 2,
        n: 24,
        heads: 2,
        d_h: 8,
        n_c: 4,
        kappa: 8,
        attn,
        clustering: clustering.to_string(),
        causal: clustering == "causal",
        window: 8,
    }
}

fn rand_vec(rng: &mut Rng, len: usize, scale: f32) -> Vec<f32> {
    (0..len).map(|_| rng.gaussian() as f32 * scale).collect()
}

fn cast_param_bufs(d: usize, h: usize, n_c: usize, seed: u64) -> Vec<Vec<f32>> {
    let d_h = d / h;
    let mut rng = Rng::new(seed);
    let s = 1.0 / (d as f32).sqrt();
    vec![
        rand_vec(&mut rng, d * d, s),
        vec![0.0; d],
        rand_vec(&mut rng, d * d, s),
        vec![0.0; d],
        rand_vec(&mut rng, d * d, s),
        vec![0.0; d],
        rand_vec(&mut rng, d * d, s),
        vec![0.0; d],
        rand_vec(&mut rng, n_c * h * d_h, 1.0 / (d_h as f32).sqrt()),
        rand_vec(&mut rng, d, s),
        vec![0.0; 1],
    ]
}

fn cast_params(buf: &[Vec<f32>]) -> CastParams<'_> {
    CastParams {
        wq_w: &buf[0],
        wq_b: &buf[1],
        wk_w: &buf[2],
        wk_b: &buf[3],
        wv_w: &buf[4],
        wv_b: &buf[5],
        wo_w: &buf[6],
        wo_b: &buf[7],
        s: &buf[8],
        phi_w: &buf[9],
        phi_b: &buf[10],
    }
}

fn baseline_param_bufs(d: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(seed);
    let s = 1.0 / (d as f32).sqrt();
    vec![
        rand_vec(&mut rng, d * d, s),
        vec![0.0; d],
        rand_vec(&mut rng, d * d, s),
        vec![0.0; d],
        rand_vec(&mut rng, d * d, s),
        vec![0.0; d],
        rand_vec(&mut rng, d * d, s),
        vec![0.0; d],
    ]
}

fn baseline_params(buf: &[Vec<f32>]) -> BaselineParams<'_> {
    BaselineParams {
        wq_w: &buf[0],
        wq_b: &buf[1],
        wk_w: &buf[2],
        wk_b: &buf[3],
        wv_w: &buf[4],
        wv_b: &buf[5],
        wo_w: &buf[6],
        wo_b: &buf[7],
    }
}

#[test]
fn cast_layer_parity_serial_vs_threaded() {
    for mech in ["topk", "sa", "causal"] {
        for attn in [AttnFn::Softmax, AttnFn::Laplace] {
            let dm = layer_dims(mech, attn);
            let d = dm.d();
            let buf = cast_param_bufs(d, dm.heads, dm.n_c, 31);
            let p = cast_params(&buf);
            let mut rng = Rng::new(5);
            let x: Vec<f32> = rand_vec(&mut rng, dm.b * dm.n * d, 1.0);
            let (out1, ag1) = with_threads(1, || {
                cast_layer(&p, &x, &dm, &mut CastScratch::new()).unwrap()
            });
            let (out4, ag4) = with_threads(THREADED, || {
                cast_layer(&p, &x, &dm, &mut CastScratch::new()).unwrap()
            });
            assert!(
                max_abs_diff(&out1, &out4) <= 1e-5,
                "{mech}/{attn:?}: out diverged by {}",
                max_abs_diff(&out1, &out4)
            );
            assert!(max_abs_diff(&ag1, &ag4) <= 1e-5, "{mech}/{attn:?}: a_g diverged");
        }
    }
}

#[test]
fn baselines_parity_serial_vs_threaded() {
    let dm = layer_dims("topk", AttnFn::Softmax);
    let d = dm.d();
    let buf = baseline_param_bufs(d, 77);
    let p = baseline_params(&buf);
    let mut rng = Rng::new(9);
    let x: Vec<f32> = rand_vec(&mut rng, dm.b * dm.n * d, 1.0);
    for name in ["vanilla", "local", "lsh"] {
        let run = |threads: usize| {
            with_threads(threads, || match name {
                "vanilla" => vanilla_layer(&p, &x, &dm).unwrap(),
                "local" => local_layer(&p, &x, &dm).unwrap(),
                _ => lsh_layer(&p, &x, &dm).unwrap(),
            })
        };
        let serial = run(1);
        let threaded = run(THREADED);
        assert!(
            max_abs_diff(&serial, &threaded) <= 1e-5,
            "{name}: diverged by {}",
            max_abs_diff(&serial, &threaded)
        );
    }
}

fn predict_logits(variant: &str, threads: usize) -> Vec<f32> {
    let man = Manifest::synthetic(tiny_meta(variant));
    with_threads(threads, || {
        let seed = HostTensor::u32(vec![], vec![11]);
        let params = run_init(&man, &[&seed]).unwrap();
        let n: usize = man.tokens_shape.iter().product();
        let tokens = HostTensor::s32(
            man.tokens_shape.clone(),
            (0..n).map(|i| ((i * 13 + 5) % 97) as i32).collect(),
        );
        let mut inputs: Vec<&HostTensor> = params.iter().collect();
        inputs.push(&tokens);
        let out = run_predict(&man, &inputs).unwrap();
        out[0].as_f32().unwrap().to_vec()
    })
}

#[test]
fn predict_parity_serial_vs_threaded() {
    for variant in cast::runtime::native::VARIANTS {
        let serial = predict_logits(variant, 1);
        let threaded = predict_logits(variant, THREADED);
        assert!(
            max_abs_diff(&serial, &threaded) <= 1e-5,
            "{variant}: logits diverged by {}",
            max_abs_diff(&serial, &threaded)
        );
    }
}

/// Full forward+backward gradients of the tiny config at a given worker
/// count (the autograd mirror of `predict_logits`).
fn full_grads(variant: &str, threads: usize) -> (f32, Vec<Vec<f32>>) {
    let man = Manifest::synthetic(tiny_meta(variant));
    with_threads(threads, || {
        let seed = HostTensor::u32(vec![], vec![7]);
        let params = run_init(&man, &[&seed]).unwrap();
        let refs: Vec<&HostTensor> = params.iter().collect();
        let n: usize = man.tokens_shape.iter().product();
        let tokens = HostTensor::s32(
            man.tokens_shape.clone(),
            (0..n).map(|i| ((i * 11 + 2) % 97) as i32).collect(),
        );
        let labels = [0i32, 1];
        let mut ws = grad::GradScratch::new();
        let out = grad::loss_and_grads(&man, &refs, &tokens, &labels, &mut ws).unwrap();
        (out.loss, out.grads)
    })
}

/// Backward mirror of the forward parity suite: serial (1 worker) vs
/// threaded (2 and 8 workers) gradients must agree for every variant —
/// the reverse passes keep every reduction in a fixed order, so the
/// tolerance is headroom, not an excuse (see DESIGN.md §Autograd).
#[test]
fn backward_parity_across_thread_counts() {
    for variant in cast::runtime::native::VARIANTS {
        let (loss1, g1) = full_grads(variant, 1);
        for threads in [2usize, 8] {
            let (loss_t, g_t) = full_grads(variant, threads);
            assert_eq!(loss1, loss_t, "{variant}@{threads}: loss must be bit-identical");
            assert_eq!(g1.len(), g_t.len(), "{variant}@{threads}");
            for (i, (a, b)) in g1.iter().zip(&g_t).enumerate() {
                let diff = max_abs_diff(a, b);
                assert!(
                    diff <= 1e-5,
                    "{variant}@{threads}: grad tensor {i} diverged by {diff}"
                );
            }
        }
    }
}

#[test]
fn threaded_backward_is_bit_for_bit_deterministic() {
    let (loss_a, ga) = full_grads("cast_topk", THREADED);
    let (loss_b, gb) = full_grads("cast_topk", THREADED);
    assert_eq!(loss_a, loss_b, "threaded backward loss must be deterministic");
    for (a, b) in ga.iter().zip(&gb) {
        assert_eq!(a, b, "threaded backward gradients must be deterministic");
    }
}

#[test]
fn threaded_runs_are_bit_for_bit_deterministic() {
    // repeated runs at the same worker count must agree exactly —
    // dynamic task scheduling must never change any reduction order
    let dm = layer_dims("topk", AttnFn::Softmax);
    let d = dm.d();
    let buf = cast_param_bufs(d, dm.heads, dm.n_c, 3);
    let p = cast_params(&buf);
    let x: Vec<f32> = (0..dm.b * dm.n * d).map(|i| (i as f32 * 0.11).sin()).collect();
    let (a, ag_a) = with_threads(THREADED, || {
        cast_layer(&p, &x, &dm, &mut CastScratch::new()).unwrap()
    });
    for _ in 0..3 {
        let (b, ag_b) = with_threads(THREADED, || {
            cast_layer(&p, &x, &dm, &mut CastScratch::new()).unwrap()
        });
        assert_eq!(a, b, "threaded cast_layer output must be deterministic");
        assert_eq!(ag_a, ag_b, "threaded A_g must be deterministic");
    }
    let l1 = predict_logits("cast_topk", THREADED);
    let l2 = predict_logits("cast_topk", THREADED);
    assert_eq!(l1, l2, "threaded predict must be deterministic");
}
