//! Shared helpers for integration tests: locate the artifacts root and the
//! tiny smoke-test artifact, skipping gracefully when neither
//! `make artifacts` (AOT HLO) nor `cast gen` (native manifests) has run.
//! The native-backend suite (`integration_native.rs`) needs no disk
//! artifacts at all — it synthesizes manifests in memory.

use std::path::PathBuf;

pub fn artifacts_root() -> PathBuf {
    let root = std::env::var("CAST_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"));
    root
}

/// The tiny config lowered by `make artifacts` (aot.py suite `default`).
pub fn tiny_dir(variant: &str) -> Option<PathBuf> {
    let key = match variant {
        "cast_topk" => "text_cast_topk_n64_b2_c4_k16",
        "cast_sa" => "text_cast_sa_n64_b2_c4_k16",
        "vanilla" => "text_vanilla_n64_b2",
        "local" => "text_local_n64_b2_w64",
        "lsh" => "text_lsh_n64_b2_c4_k16",
        "causal" => "text_cast_sa_n64_b2_c4_k16_causal",
        _ => return None,
    };
    let dir = artifacts_root().join(key);
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        None
    }
}

/// Skip (with a loud message) when artifacts are missing — integration
/// tests require `make artifacts` to have run.
#[macro_export]
macro_rules! require_artifact {
    ($variant:expr) => {
        match common::tiny_dir($variant) {
            Some(dir) => dir,
            None => {
                eprintln!(
                    "SKIP: tiny artifact for {:?} missing — run `make artifacts`",
                    $variant
                );
                return;
            }
        }
    };
}
