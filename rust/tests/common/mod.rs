//! Shared helpers for integration tests: locate the artifacts root and the
//! tiny smoke-test artifact, skipping gracefully when neither
//! `make artifacts` (AOT HLO) nor `cast gen` (native manifests) has run.
//! The native-backend suite (`integration_native.rs`) needs no disk
//! artifacts at all — it synthesizes manifests in memory.
//!
//! Also home to the golden-fingerprint helpers (`golden_*` /
//! [`Fingerprint`]): fixed-seed forward-logit and gradient-norm
//! fingerprints for one tiny config per attention variant, so kernel
//! rewrites diff against the committed baseline in
//! `tests/goldens/fingerprints.json` instead of only self-consistency
//! (used by `integration_simd.rs`).
//!
//! Every test binary that declares `mod common` compiles this whole
//! file, and each binary uses a different subset of the helpers — so
//! dead-code analysis is per-binary noise here, not signal.
#![allow(dead_code)]

use std::path::PathBuf;

pub fn artifacts_root() -> PathBuf {
    let root = std::env::var("CAST_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"));
    root
}

/// The tiny config lowered by `make artifacts` (aot.py suite `default`).
pub fn tiny_dir(variant: &str) -> Option<PathBuf> {
    let key = match variant {
        "cast_topk" => "text_cast_topk_n64_b2_c4_k16",
        "cast_sa" => "text_cast_sa_n64_b2_c4_k16",
        "vanilla" => "text_vanilla_n64_b2",
        "local" => "text_local_n64_b2_w64",
        "lsh" => "text_lsh_n64_b2_c4_k16",
        "clustered" => "text_clustered_n64_b2_c4_k16",
        "tost" => "text_tost_n64_b2",
        "causal" => "text_cast_sa_n64_b2_c4_k16_causal",
        _ => return None,
    };
    let dir = artifacts_root().join(key);
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        None
    }
}

// ---------------------------------------------------------------------------
// golden fingerprints
// ---------------------------------------------------------------------------

/// The attention variants the golden suite pins, in fingerprint order
/// ("causal" is the `cast_sa` mechanism with the causal flag; the rest
/// are registry variant names passed through by [`golden_meta`]).
pub const GOLDEN_VARIANTS: [&str; 8] =
    ["topk", "sa", "causal", "vanilla", "local", "lsh", "clustered", "tost"];

/// Fixed-seed forward + backward fingerprint of one tiny config.
pub struct Fingerprint {
    pub loss: f32,
    /// Global L2 norm over every parameter gradient, accumulated in f64.
    pub grad_norm: f64,
    /// The full logit block (B=2 × 2 classes).
    pub logits: Vec<f32>,
}

/// One tiny config per variant × attention fn: seq 16, batch 2, depth 1,
/// h 2, d 8, Nc 2, κ 4 — small enough that the whole 16-entry suite runs
/// in well under a second, big enough that every kernel participates.
pub fn golden_meta(variant: &str, attn_fn: &str) -> cast::runtime::ModelMeta {
    let (var, causal) = match variant {
        "topk" => ("cast_topk", false),
        "sa" => ("cast_sa", false),
        "causal" => ("cast_sa", true),
        other => (other, false), // vanilla | local | lsh
    };
    cast::runtime::ModelMeta {
        task: "text".to_string(),
        variant: var.to_string(),
        seq_len: 16,
        batch: 2,
        n_c: 2,
        kappa: 4,
        depth: 1,
        heads: 2,
        d: 8,
        d_ff: 16,
        d_emb: 8,
        vocab: 32,
        n_classes: 2,
        dual: false,
        norm: "layer".to_string(),
        prenorm: false,
        attn_fn: attn_fn.to_string(),
        window: 8,
        causal,
    }
}

/// Compute the fingerprint of one golden config under the *current*
/// SIMD/thread settings (the comparison tolerance absorbs the documented
/// reassociation drift between modes).
pub fn compute_fingerprint(variant: &str, attn_fn: &str) -> Fingerprint {
    use cast::runtime::native::grad;
    use cast::runtime::native::model::{run_init, run_predict};
    use cast::runtime::tensor::HostTensor;
    let man = cast::runtime::Manifest::synthetic(golden_meta(variant, attn_fn));
    let seed = HostTensor::u32(vec![], vec![1234]);
    let params = run_init(&man, &[&seed]).unwrap();
    let n: usize = man.tokens_shape.iter().product();
    let tokens = HostTensor::s32(
        man.tokens_shape.clone(),
        (0..n).map(|i| ((i * 7 + 3) % 32) as i32).collect(),
    );
    let mut inputs: Vec<&HostTensor> = params.iter().collect();
    inputs.push(&tokens);
    let logits = run_predict(&man, &inputs).unwrap()[0].as_f32().unwrap().to_vec();
    let refs: Vec<&HostTensor> = params.iter().collect();
    let labels = vec![0i32, 1];
    let mut ws = grad::GradScratch::new();
    let out = grad::loss_and_grads(&man, &refs, &tokens, &labels, &mut ws).unwrap();
    let mut sq = 0.0f64;
    for g in &out.grads {
        for &v in g {
            sq += (v as f64) * (v as f64);
        }
    }
    Fingerprint { loss: out.loss, grad_norm: sq.sqrt(), logits }
}

/// Committed baseline location (checked in once generated; the golden
/// test writes it with instructions when missing).
pub fn goldens_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests")
        .join("goldens")
        .join("fingerprints.json")
}

pub fn fingerprint_json(fp: &Fingerprint) -> cast::util::json::Json {
    use cast::util::json::Json;
    Json::obj(vec![
        ("loss", Json::num(fp.loss as f64)),
        ("grad_norm", Json::num(fp.grad_norm)),
        ("logits", Json::Arr(fp.logits.iter().map(|&v| Json::num(v as f64)).collect())),
    ])
}

/// Skip (with a loud message) when artifacts are missing — integration
/// tests require `make artifacts` to have run.
#[macro_export]
macro_rules! require_artifact {
    ($variant:expr) => {
        match common::tiny_dir($variant) {
            Some(dir) => dir,
            None => {
                eprintln!(
                    "SKIP: tiny artifact for {:?} missing — run `make artifacts`",
                    $variant
                );
                return;
            }
        }
    };
}
