//! Serve-stack integration suite: wire-level HTTP against a real
//! `TcpListener`-backed server, the batching-preserves-results
//! determinism contract, and the graceful-shutdown drain.
//!
//! (Pure parser unit cases live next to the code in `serve/http.rs`;
//! here every request crosses a real socket.)

use std::net::{SocketAddr, TcpStream};
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

use cast::runtime::native::spec::tiny_meta;
use cast::runtime::{Engine, HostTensor};
use cast::serve::http;
use cast::serve::{ModelSource, Registry, ServeConfig, Server};
use cast::util::json::Json;
use cast::util::rng::Rng;

const SEED: u32 = 5;

struct Harness {
    server: Arc<Server>,
    registry: Arc<Registry>,
    addr: SocketAddr,
    join: Option<std::thread::JoinHandle<anyhow::Result<()>>>,
}

impl Harness {
    fn start(cfg: ServeConfig, variants: &[&str]) -> Harness {
        let registry = Arc::new(Registry::new(Engine::cpu().unwrap()));
        for v in variants {
            registry
                .load(None, ModelSource::Synthetic { meta: tiny_meta(v), seed: SEED })
                .unwrap();
        }
        let server = Arc::new(Server::bind(cfg, registry.clone()).unwrap());
        let addr = server.local_addr();
        let runner = server.clone();
        let join = std::thread::spawn(move || runner.run());
        Harness { server, registry, addr, join: Some(join) }
    }

    fn tiny(max_batch: usize, max_wait: Duration) -> Harness {
        Harness::start(
            ServeConfig {
                addr: "127.0.0.1:0".to_string(),
                max_batch,
                max_wait,
                conn_workers: 16,
                ..ServeConfig::default()
            },
            &["cast_topk"],
        )
    }

    /// A server with a single causal CAST model (the /generate target).
    fn causal() -> Harness {
        let registry = Arc::new(Registry::new(Engine::cpu().unwrap()));
        let mut meta = tiny_meta("cast_sa");
        meta.causal = true;
        registry.load(None, ModelSource::Synthetic { meta, seed: SEED }).unwrap();
        let server = Arc::new(
            Server::bind(
                ServeConfig { addr: "127.0.0.1:0".to_string(), ..ServeConfig::default() },
                registry.clone(),
            )
            .unwrap(),
        );
        let addr = server.local_addr();
        let runner = server.clone();
        let join = std::thread::spawn(move || runner.run());
        Harness { server, registry, addr, join: Some(join) }
    }

    fn stop(&mut self) {
        self.server.shutdown_flag().store(true, Ordering::SeqCst);
        if let Some(join) = self.join.take() {
            join.join().expect("server thread panicked").expect("server run failed");
        }
    }
}

impl Drop for Harness {
    fn drop(&mut self) {
        if self.join.is_some() {
            self.stop();
        }
    }
}

/// One-shot request over a fresh connection.
fn request(addr: SocketAddr, method: &str, target: &str, body: &[u8]) -> (u16, Vec<u8>) {
    let mut s = TcpStream::connect(addr).unwrap();
    http::write_request(&mut s, method, target, body).unwrap();
    let resp = http::read_response(&mut s, &mut Vec::new(), http::CLIENT_MAX_BODY).unwrap();
    (resp.status, resp.body)
}

fn json_of(body: &[u8]) -> Json {
    Json::parse(std::str::from_utf8(body).unwrap()).unwrap()
}

/// Deterministic token row for one logical client request.
fn tokens_for(stream_id: u64, n: usize) -> Vec<i32> {
    let mut rng = Rng::new(0xC11E47).split(stream_id);
    (0..n).map(|_| rng.below(50) as i32).collect()
}

fn predict_body(tokens: &[i32]) -> String {
    let vals: Vec<usize> = tokens.iter().map(|&t| t as usize).collect();
    Json::obj(vec![("tokens", Json::Arr(vec![Json::arr_usize(&vals)]))]).to_string()
}

/// Reference logits: the same tokens through the engine directly, B=1.
fn reference_logits(harness: &Harness, tokens: &[i32]) -> Vec<f32> {
    let entry = harness.registry.resolve(None).unwrap();
    let n = entry.manifest.meta.seq_len;
    let tensor = HostTensor::s32(vec![1, n], tokens.to_vec());
    let inputs = entry.predict_inputs(&tensor);
    let out = entry.exe.run_refs(&inputs).unwrap();
    out[0].as_f32().unwrap().to_vec()
}

/// Parse the `logits` rows out of a /predict response body.
fn response_logits(body: &[u8]) -> Vec<Vec<f64>> {
    json_of(body)
        .get("logits")
        .and_then(Json::as_arr)
        .expect("logits array")
        .iter()
        .map(|row| row.as_arr().unwrap().iter().map(|v| v.as_f64().unwrap()).collect())
        .collect()
}

fn assert_exact(got: &[f64], want: &[f32]) {
    assert_eq!(got.len(), want.len());
    for (g, w) in got.iter().zip(want) {
        // f32 -> JSON -> f64 is exact both ways, so equality is exact
        assert_eq!(*g, *w as f64, "serve logits must be bit-identical to direct predict");
    }
}

// ---------------------------------------------------------------------------
// wire-level protocol behaviour
// ---------------------------------------------------------------------------

#[test]
fn tcp_roundtrip_health_models_metrics_and_predict() {
    let mut h = Harness::tiny(4, Duration::from_millis(2));

    let (status, body) = request(h.addr, "GET", "/healthz", b"");
    assert_eq!(status, 200);
    assert_eq!(json_of(&body).get("ok"), Some(&Json::Bool(true)));

    let (status, body) = request(h.addr, "GET", "/models", b"");
    assert_eq!(status, 200);
    let models = json_of(&body);
    let arr = models.get("models").and_then(Json::as_arr).unwrap();
    assert_eq!(arr.len(), 1);
    assert_eq!(arr[0].get("name").and_then(Json::as_str), Some("text_cast_topk_n64_b2_c4_k16"));
    assert_eq!(arr[0].get("seq_len").and_then(Json::as_usize), Some(64));

    // a padded (short) request and a full-length one, same connection
    let mut s = TcpStream::connect(h.addr).unwrap();
    for tokens in [tokens_for(1, 17), tokens_for(2, 64)] {
        http::write_request(&mut s, "POST", "/predict", predict_body(&tokens).as_bytes()).unwrap();
        let resp = http::read_response(&mut s, &mut Vec::new(), http::CLIENT_MAX_BODY).unwrap();
        assert_eq!(resp.status, 200, "{}", String::from_utf8_lossy(&resp.body));
        let parsed = json_of(&resp.body);
        assert_eq!(parsed.get("rows").and_then(Json::as_usize), Some(1));
        let rows = response_logits(&resp.body);
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].len(), 2, "tiny text config has 2 classes");
        // padding contract: short requests behave as zero-padded rows
        let mut padded = tokens.clone();
        padded.resize(64, 0);
        assert_exact(&rows[0], &reference_logits(&h, &padded));
    }
    drop(s);

    let (status, body) = request(h.addr, "GET", "/metrics", b"");
    assert_eq!(status, 200);
    let page = String::from_utf8(body).unwrap();
    for needle in [
        "cast_serve_requests_total{endpoint=\"predict\"} 2",
        "cast_serve_predict_rows_total 2",
        "cast_serve_request_latency_seconds_count 2",
        "cast_serve_models 1",
    ] {
        assert!(page.contains(needle), "missing {needle:?} in:\n{page}");
    }

    h.stop();
}

#[test]
fn malformed_requests_get_mapped_statuses() {
    let mut h = Harness::start(
        ServeConfig {
            addr: "127.0.0.1:0".to_string(),
            max_body: 1024,
            ..ServeConfig::default()
        },
        &["cast_topk"],
    );

    // bad JSON, missing tokens, bad token values, unknown model, 404 path
    for (body, want, hint) in [
        ("{not json", 400, "invalid JSON"),
        ("{}", 400, "tokens"),
        (r#"{"tokens":[[1.5]]}"#, 400, "not an i32"),
        (r#"{"tokens":[1,2],"model":"nope"}"#, 404, "unknown model"),
    ] {
        let (status, resp) = request(h.addr, "POST", "/predict", body.as_bytes());
        assert_eq!(status, want, "{hint}: {}", String::from_utf8_lossy(&resp));
        assert!(json_of(&resp).get("error").is_some());
    }
    let (status, _) = request(h.addr, "GET", "/nowhere", b"");
    assert_eq!(status, 404);

    // overlong row for the model's 64-token geometry
    let long = predict_body(&[1; 65]);
    let (status, resp) = request(h.addr, "POST", "/predict", long.as_bytes());
    assert_eq!(status, 400, "{}", String::from_utf8_lossy(&resp));

    // oversized declared body -> 413 before the server waits for it
    let mut s = TcpStream::connect(h.addr).unwrap();
    use std::io::Write;
    write!(s, "POST /predict HTTP/1.1\r\nContent-Length: 999999\r\n\r\n").unwrap();
    s.flush().unwrap();
    let resp = http::read_response(&mut s, &mut Vec::new(), http::CLIENT_MAX_BODY).unwrap();
    assert_eq!(resp.status, 413);

    // bad method over the raw socket -> 405
    let mut s = TcpStream::connect(h.addr).unwrap();
    write!(s, "DELETE /predict HTTP/1.1\r\n\r\n").unwrap();
    s.flush().unwrap();
    let resp = http::read_response(&mut s, &mut Vec::new(), http::CLIENT_MAX_BODY).unwrap();
    assert_eq!(resp.status, 405);

    h.stop();
}

#[test]
fn multi_model_routing_and_hot_reload() {
    let mut h = Harness::start(
        ServeConfig { addr: "127.0.0.1:0".to_string(), ..ServeConfig::default() },
        &["cast_topk", "vanilla"],
    );

    // ambiguous without a name
    let body = predict_body(&tokens_for(9, 64));
    let (status, _) = request(h.addr, "POST", "/predict", body.as_bytes());
    assert_eq!(status, 404, "two models need an explicit name");
    let (status, resp) =
        request(h.addr, "POST", "/predict?model=text_vanilla_n64_b2", body.as_bytes());
    assert_eq!(status, 200, "{}", String::from_utf8_lossy(&resp));
    assert_eq!(
        json_of(&resp).get("model").and_then(Json::as_str),
        Some("text_vanilla_n64_b2")
    );

    // hot reload bumps the served version; old in-flight snapshot is safe
    let (status, resp) =
        request(h.addr, "POST", "/models/reload?model=text_vanilla_n64_b2", b"");
    assert_eq!(status, 200, "{}", String::from_utf8_lossy(&resp));
    assert_eq!(json_of(&resp).get("version").and_then(Json::as_usize), Some(2));
    let (status, resp) =
        request(h.addr, "POST", "/predict?model=text_vanilla_n64_b2", body.as_bytes());
    assert_eq!(status, 200);
    assert_eq!(json_of(&resp).get("version").and_then(Json::as_usize), Some(2));
    let (status, _) = request(h.addr, "POST", "/models/reload?model=ghost", b"");
    assert_eq!(status, 404);

    h.stop();
}

// ---------------------------------------------------------------------------
// determinism: batching must not change results
// ---------------------------------------------------------------------------

#[test]
fn concurrent_clients_match_sequential_predicts_exactly() {
    // small max_batch + a generous fill window to force real coalescing
    let mut h = Harness::tiny(4, Duration::from_millis(30));
    let n_clients = 8usize;
    let reqs_per_client = 4usize;

    // reference logits for every (client, request), computed sequentially
    let mut want = Vec::new();
    for c in 0..n_clients {
        for r in 0..reqs_per_client {
            let tokens = tokens_for((c * 100 + r) as u64, 64);
            want.push(reference_logits(&h, &tokens));
        }
    }

    let addr = h.addr;
    let results: Vec<(usize, Vec<Vec<f64>>, usize)> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..n_clients)
            .map(|c| {
                s.spawn(move || {
                    let mut stream = TcpStream::connect(addr).unwrap();
                    let mut got = Vec::new();
                    let mut max_batch_rows = 0usize;
                    for r in 0..reqs_per_client {
                        let tokens = tokens_for((c * 100 + r) as u64, 64);
                        http::write_request(
                            &mut stream,
                            "POST",
                            "/predict",
                            predict_body(&tokens).as_bytes(),
                        )
                        .unwrap();
                        let resp = http::read_response(&mut stream, &mut Vec::new(), http::CLIENT_MAX_BODY).unwrap();
                        assert_eq!(resp.status, 200, "{}", String::from_utf8_lossy(&resp.body));
                        let parsed = json_of(&resp.body);
                        max_batch_rows = max_batch_rows
                            .max(parsed.get("batch_rows").and_then(Json::as_usize).unwrap_or(0));
                        got.push(response_logits(&resp.body).remove(0));
                    }
                    (c, got, max_batch_rows)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });

    let mut coalesced = 0usize;
    for (c, got, max_rows) in &results {
        for (r, row) in got.iter().enumerate() {
            assert_exact(row, &want[c * reqs_per_client + r]);
        }
        coalesced = coalesced.max(*max_rows);
    }
    assert!(
        coalesced >= 2,
        "8 concurrent closed-loop clients with a 30ms window should have formed \
         at least one multi-row batch (max observed {coalesced})"
    );
    h.stop();
}

#[test]
fn multi_row_request_matches_row_by_row_predicts() {
    let mut h = Harness::tiny(8, Duration::from_millis(2));
    let rows: Vec<Vec<i32>> = (0..3).map(|i| tokens_for(7000 + i, 64)).collect();
    let vals: Vec<Json> = rows
        .iter()
        .map(|r| Json::arr_usize(&r.iter().map(|&t| t as usize).collect::<Vec<_>>()))
        .collect();
    let body = Json::obj(vec![("tokens", Json::Arr(vals))]).to_string();
    let (status, resp) = request(h.addr, "POST", "/predict", body.as_bytes());
    assert_eq!(status, 200, "{}", String::from_utf8_lossy(&resp));
    let got = response_logits(&resp);
    assert_eq!(got.len(), 3);
    for (row, tokens) in got.iter().zip(&rows) {
        assert_exact(row, &reference_logits(&h, tokens));
    }
    h.stop();
}

// ---------------------------------------------------------------------------
// graceful shutdown
// ---------------------------------------------------------------------------

#[test]
fn graceful_shutdown_drains_in_flight_requests() {
    // a wide fill window keeps jobs sitting in the batch former when
    // shutdown lands — exactly the in-flight work a drain must finish
    let mut h = Harness::tiny(8, Duration::from_millis(150));
    let addr = h.addr;
    let flag = h.server.shutdown_flag();

    let outcomes: Vec<(u16, Vec<u8>, Vec<i32>)> = std::thread::scope(|s| {
        let clients: Vec<_> = (0..6)
            .map(|c| {
                s.spawn(move || {
                    let tokens = tokens_for(9000 + c as u64, 64);
                    let (status, body) =
                        request(addr, "POST", "/predict", predict_body(&tokens).as_bytes());
                    (status, body, tokens)
                })
            })
            .collect();
        // let the requests reach the queue, then pull the plug mid-window
        std::thread::sleep(Duration::from_millis(60));
        flag.store(true, Ordering::SeqCst);
        clients.into_iter().map(|c| c.join().unwrap()).collect()
    });

    let mut served = 0;
    for (status, body, tokens) in &outcomes {
        match status {
            200 => {
                // drained requests return *correct* results, not stubs
                assert_exact(&response_logits(body)[0], &reference_logits(&h, tokens));
                served += 1;
            }
            // a request that arrived after the flag flipped is refused
            // cleanly, never dropped
            503 => assert!(json_of(body).get("error").is_some()),
            other => panic!("unexpected status {other}"),
        }
    }
    assert!(served >= 1, "at least the in-flight requests must be served");

    // run() must return: drained and joined
    h.stop();
    // the drained server answered everything it accepted; new connects
    // may still enter the OS backlog but are never served — no assertion
    // on them (timing-dependent).
}

// ---------------------------------------------------------------------------
// wire-level parser behaviour (split reads over a real socket)
// ---------------------------------------------------------------------------

#[test]
fn split_writes_over_tcp_still_parse() {
    let mut h = Harness::tiny(2, Duration::from_millis(2));
    let tokens = tokens_for(31, 64);
    let body = predict_body(&tokens);
    let head = format!(
        "POST /predict HTTP/1.1\r\nContent-Type: application/json\r\nContent-Length: {}\r\n\r\n",
        body.len()
    );
    let mut s = TcpStream::connect(h.addr).unwrap();
    use std::io::Write;
    // dribble the request out in 4 chunks, with the first pause spanning
    // the server's 100ms read timeout — recv must resume (Idle), not
    // reset the partial parse
    let wire = format!("{head}{body}");
    let bytes = wire.as_bytes();
    for (i, chunk) in bytes.chunks(bytes.len() / 4 + 1).enumerate() {
        s.write_all(chunk).unwrap();
        s.flush().unwrap();
        std::thread::sleep(Duration::from_millis(if i == 0 { 130 } else { 15 }));
    }
    let resp = http::read_response(&mut s, &mut Vec::new(), http::CLIENT_MAX_BODY).unwrap();
    assert_eq!(resp.status, 200, "{}", String::from_utf8_lossy(&resp.body));
    assert_exact(&response_logits(&resp.body)[0], &reference_logits(&h, &tokens));
    h.stop();
}

// ---------------------------------------------------------------------------
// streaming /generate
// ---------------------------------------------------------------------------

fn generate_request(addr: SocketAddr, body: &str) -> http::Response {
    let mut s = TcpStream::connect(addr).unwrap();
    http::write_request(&mut s, "POST", "/generate", body.as_bytes()).unwrap();
    http::read_response_streaming(&mut s, &mut Vec::new(), http::CLIENT_MAX_BODY).unwrap()
}

fn ndjson_lines(body: &[u8]) -> Vec<Json> {
    std::str::from_utf8(body)
        .unwrap()
        .lines()
        .filter(|l| !l.trim().is_empty())
        .map(|l| Json::parse(l).unwrap())
        .collect()
}

#[test]
fn generate_streams_tokens_matching_the_full_causal_forward() {
    use cast::runtime::native::decode;
    let mut h = Harness::causal();
    let prompt: Vec<usize> = vec![7, 3, 250, 9];
    let body = Json::obj(vec![
        ("prompt", Json::arr_usize(&prompt)),
        ("max_new_tokens", Json::num(6.0)),
    ])
    .to_string();
    let resp = generate_request(h.addr, &body);
    assert_eq!(resp.status, 200, "{}", String::from_utf8_lossy(&resp.body));
    assert_eq!(
        resp.headers.get("content-type").map(|s| s.as_str()),
        Some("application/x-ndjson")
    );
    assert!(
        !resp.headers.contains_key("content-length"),
        "streamed response must be close-delimited"
    );
    let lines = ndjson_lines(&resp.body);
    assert_eq!(lines.len(), 7, "6 token lines + the done summary");
    let done = lines.last().unwrap();
    assert_eq!(done.get("done").and_then(Json::as_bool), Some(true));
    assert_eq!(done.get("tokens").and_then(Json::as_usize), Some(6));
    assert_eq!(done.get("stop").and_then(Json::as_str), Some("length"));
    // greedy stream == full causal forward recomputed at every step
    let entry = h.registry.resolve(None).unwrap();
    let refs: Vec<&HostTensor> = entry.params.iter().collect();
    let mut history: Vec<i32> = prompt.iter().map(|&t| t as i32).collect();
    for (i, line) in lines[..6].iter().enumerate() {
        let logits = decode::full_logits(&entry.manifest, &refs, &history).unwrap();
        let want = decode::argmax(&logits);
        assert_eq!(line.get("token").and_then(Json::as_usize), Some(want), "token {i}");
        assert_eq!(line.get("pos").and_then(Json::as_usize), Some(history.len()), "pos {i}");
        history.push(want as i32);
    }
    h.stop();
}

#[test]
fn generate_rejections_stay_buffered_json() {
    let mut h = Harness::causal();
    // malformed body: buffered 400, ordinary fixed-length response
    let (status, body) = request(h.addr, "POST", "/generate", b"{\"prompt\":[]}");
    assert_eq!(status, 400);
    assert!(json_of(&body).get("error").is_some());
    let (status, _) = request(h.addr, "POST", "/generate", b"not json");
    assert_eq!(status, 400);
    let (status, body) =
        request(h.addr, "POST", "/generate", b"{\"prompt\":[1],\"max_new_tokens\":0}");
    assert_eq!(status, 400, "{}", String::from_utf8_lossy(&body));
    // and the server still answers normal requests on fresh connections
    let (status, _) = request(h.addr, "GET", "/healthz", b"");
    assert_eq!(status, 200);
    h.stop();
}

#[test]
fn trace_ring_flag_caps_debug_trace_and_debug_clusters_reports_health() {
    use cast::runtime::native::cluster_stats;
    let _g = cluster_stats::test_guard();
    cluster_stats::set_enabled(true);
    cluster_stats::clear();
    let mut h = Harness::start(
        ServeConfig { addr: "127.0.0.1:0".to_string(), trace_ring: 3, ..ServeConfig::default() },
        &["cast_topk"],
    );
    let n = tiny_meta("cast_topk").seq_len;
    for i in 0..6u64 {
        let (status, body) =
            request(h.addr, "POST", "/predict", predict_body(&tokens_for(i, n)).as_bytes());
        assert_eq!(status, 200, "{}", String::from_utf8_lossy(&body));
    }

    // --trace-ring 3: the replay ring keeps only the newest 3 requests
    let (status, body) = request(h.addr, "GET", "/debug/trace?n=100", b"");
    assert_eq!(status, 200);
    let rows = json_of(&body).get("requests").and_then(Json::as_arr).unwrap().len();
    assert_eq!(rows, 3, "--trace-ring must cap the replay buffer");

    // /debug/clusters mirrors the health the batches harvested.  The
    // accumulator is process-global and other tests in this binary may
    // drain it concurrently, so drive more traffic until a harvest
    // lands on *this* server instead of asserting on the first try.
    let mut health = None;
    for round in 0..5u64 {
        let (status, body) = request(h.addr, "GET", "/debug/clusters", b"");
        assert_eq!(status, 200);
        let json = json_of(&body);
        assert_eq!(json.get("enabled"), Some(&Json::Bool(true)));
        assert!(json.get("decode_passthrough_tokens").is_some(), "{json:?}");
        if json.get("models").and_then(Json::as_arr).is_some_and(|m| !m.is_empty()) {
            health = Some(json);
            break;
        }
        for i in 0..3u64 {
            let tokens = tokens_for(100 + round * 10 + i, n);
            let (status, _) =
                request(h.addr, "POST", "/predict", predict_body(&tokens).as_bytes());
            assert_eq!(status, 200);
        }
    }
    let json = health.expect("cluster health must reach /debug/clusters");
    let models = json.get("models").and_then(Json::as_arr).unwrap();
    let m = &models[0];
    assert!(m.get("layers").and_then(Json::as_usize).unwrap() >= 1, "{json:?}");
    let entropy = m.get("entropy").and_then(Json::as_f64).unwrap();
    assert!((0.0..=1.0).contains(&entropy), "normalized entropy: {json:?}");
    assert!(m.get("collapsed_layers").and_then(Json::as_f64).is_some(), "{json:?}");

    // the same health rides /metrics as per-model gauges
    let (status, body) = request(h.addr, "GET", "/metrics", b"");
    assert_eq!(status, 200);
    let page = String::from_utf8(body).unwrap();
    assert!(page.contains("cast_cluster_affinity_entropy{model="), "{page}");
    assert!(page.contains("cast_decode_passthrough_tokens_total"), "{page}");

    cluster_stats::set_enabled(false);
    cluster_stats::clear();
    h.stop();
}

#[test]
fn generate_rejects_models_without_a_decode_entry() {
    // non-causal cast_topk: predict works, /generate must 400
    let mut h = Harness::tiny(2, Duration::from_millis(1));
    let (status, body) =
        request(h.addr, "POST", "/generate", b"{\"prompt\":[1,2,3],\"max_new_tokens\":2}");
    assert_eq!(status, 400, "{}", String::from_utf8_lossy(&body));
    let msg = json_of(&body).get("error").and_then(Json::as_str).unwrap().to_string();
    assert!(msg.contains("cannot decode"), "{msg}");
    h.stop();
}
