//! Integration: the runtime over on-disk artifact directories (tiny
//! config).  Requires `make artifacts` (aot.py default suite) or
//! `cast gen` (manifest-only, native backend).

mod common;

use cast::model::ModelState;
use cast::runtime::{Engine, HostTensor, Manifest};

#[test]
fn manifest_loads_and_describes_tiny_model() {
    let dir = require_artifact!("cast_topk");
    let m = Manifest::load(&dir).unwrap();
    assert_eq!(m.meta.task, "text");
    assert_eq!(m.meta.seq_len, 64);
    assert_eq!(m.meta.batch, 2);
    assert_eq!(m.meta.n_c, 4);
    assert!(m.n_params() > 10);
    let engine = Engine::cpu().unwrap();
    assert!(engine.has(&m, "init") && engine.has(&m, "train_step") && engine.has(&m, "predict"));
    assert!(engine.has(&m, "predict_ag"), "cast configs include predict_ag");
}

#[test]
fn init_produces_manifest_shaped_params_deterministically() {
    let dir = require_artifact!("cast_topk");
    let m = Manifest::load(&dir).unwrap();
    let engine = Engine::cpu().unwrap();
    let a = ModelState::init(&engine, &m, 7).unwrap();
    let b = ModelState::init(&engine, &m, 7).unwrap();
    let c = ModelState::init(&engine, &m, 8).unwrap();
    assert_eq!(a.n_params(), m.n_params());
    // same seed -> identical params; different seed -> different params
    assert_eq!(a.params[0].as_f32().unwrap(), b.params[0].as_f32().unwrap());
    let same = a
        .params
        .iter()
        .zip(&c.params)
        .all(|(x, y)| x.as_f32().ok() == y.as_f32().ok());
    assert!(!same, "different seeds must give different params");
    // finite values
    for p in &a.params {
        if let Ok(v) = p.as_f32() {
            assert!(v.iter().all(|x| x.is_finite()));
        }
    }
}

#[test]
fn predict_runs_and_emits_logits() {
    let dir = require_artifact!("cast_topk");
    let m = Manifest::load(&dir).unwrap();
    let engine = Engine::cpu().unwrap();
    let state = ModelState::init(&engine, &m, 0).unwrap();
    let exe = engine.load(&m, "predict").unwrap();
    let tokens = HostTensor::s32(m.tokens_shape.clone(), vec![1; 2 * 64]);
    let mut inputs: Vec<HostTensor> = state.params.clone();
    inputs.push(tokens);
    let out = exe.run(&inputs).unwrap();
    assert_eq!(out.len(), 1);
    assert_eq!(out[0].shape, vec![2, 2]); // (batch, classes)
    assert!(out[0].as_f32().unwrap().iter().all(|x| x.is_finite()));
}

#[test]
fn predict_is_deterministic_across_calls() {
    let dir = require_artifact!("cast_topk");
    let m = Manifest::load(&dir).unwrap();
    let engine = Engine::cpu().unwrap();
    let state = ModelState::init(&engine, &m, 3).unwrap();
    let exe = engine.load(&m, "predict").unwrap();
    let tokens = HostTensor::s32(m.tokens_shape.clone(), (0..128).map(|i| i % 30).collect());
    let mut inputs: Vec<HostTensor> = state.params.clone();
    inputs.push(tokens);
    let a = exe.run(&inputs).unwrap();
    let b = exe.run(&inputs).unwrap();
    assert_eq!(a[0].as_f32().unwrap(), b[0].as_f32().unwrap());
}

#[test]
fn executable_cache_deduplicates_compiles() {
    let dir = require_artifact!("cast_topk");
    let m = Manifest::load(&dir).unwrap();
    let engine = Engine::cpu().unwrap();
    let before = engine.compiled_count();
    let _a = engine.load(&m, "predict").unwrap();
    let _b = engine.load(&m, "predict").unwrap();
    assert_eq!(engine.compiled_count(), before + 1);
}

#[test]
fn predict_ag_shape_is_layers_batch_tokens_clusters() {
    let dir = require_artifact!("cast_topk");
    let m = Manifest::load(&dir).unwrap();
    let engine = Engine::cpu().unwrap();
    let state = ModelState::init(&engine, &m, 0).unwrap();
    let exe = engine.load(&m, "predict_ag").unwrap();
    let tokens = HostTensor::s32(m.tokens_shape.clone(), vec![2; 128]);
    let mut inputs: Vec<HostTensor> = state.params.clone();
    inputs.push(tokens);
    let out = exe.run(&inputs).unwrap();
    assert_eq!(out[0].shape, vec![m.meta.depth, 2, 64, 4]);
    // A_g is a convex-ish mixture of two softmaxes: rows sum to ~1
    let v = out[0].as_f32().unwrap();
    for row in v.chunks(4) {
        let s: f32 = row.iter().sum();
        assert!((s - 1.0).abs() < 1e-3, "A_g row sums to {s}");
    }
}

#[test]
fn all_four_variants_load_and_predict() {
    for variant in ["cast_topk", "cast_sa", "vanilla", "local"] {
        let dir = match common::tiny_dir(variant) {
            Some(d) => d,
            None => {
                eprintln!("SKIP {variant}: artifact missing");
                continue;
            }
        };
        let m = Manifest::load(&dir).unwrap();
        let engine = Engine::cpu().unwrap();
        let state = ModelState::init(&engine, &m, 1).unwrap();
        let exe = engine.load(&m, "predict").unwrap();
        let tokens = HostTensor::s32(m.tokens_shape.clone(), vec![5; 128]);
        let mut inputs: Vec<HostTensor> = state.params.clone();
        inputs.push(tokens);
        let out = exe.run(&inputs).unwrap();
        assert_eq!(out[0].shape, vec![2, 2], "{variant}");
    }
}
