//! Integration: full training loop over the tiny artifacts — the
//! end-to-end proof that L3 (rust trainer) → L2 (jax train_step) → L1
//! (pallas kernel) compose and actually learn.

mod common;

use cast::model::{checkpoint, ModelState};
use cast::runtime::{Engine, Manifest};
use cast::train::{Schedule, TrainConfig, Trainer};

fn quick_cfg(steps: usize) -> TrainConfig {
    TrainConfig {
        steps,
        schedule: Schedule::Warmup { lr: 2e-3, warmup: 5 },
        seed: 1,
        eval_every: 0,
        eval_batches: 4,
        data_workers: 2,
        queue_depth: 2,
        log_every: 0,
        checkpoint: None,
        ckpt_every: 0,
    }
}

#[test]
fn train_step_reduces_loss_on_tiny_cast() {
    let dir = require_artifact!("cast_topk");
    let manifest = Manifest::load(&dir).unwrap();
    let engine = Engine::cpu().unwrap();
    let mut trainer = Trainer::new(engine, manifest, quick_cfg(30), 1).unwrap();
    let report = trainer.run().unwrap();
    let first = report.history.steps[..5].iter().map(|r| r.loss).sum::<f32>() / 5.0;
    let last = report.final_train_loss;
    assert!(
        last < first,
        "loss should decrease: first5 {first:.4} -> last {last:.4}"
    );
    assert!(report.history.steps.iter().all(|r| r.loss.is_finite()));
    assert!(trainer.state.step >= 30.0);
}

#[test]
fn sa_topk_variant_trains_too() {
    let dir = require_artifact!("cast_sa");
    let manifest = Manifest::load(&dir).unwrap();
    let engine = Engine::cpu().unwrap();
    let mut trainer = Trainer::new(engine, manifest, quick_cfg(8), 2).unwrap();
    let report = trainer.run().unwrap();
    assert!(report.history.steps.iter().all(|r| r.loss.is_finite()));
}

#[test]
fn evaluation_runs_on_heldout_stream() {
    let dir = require_artifact!("cast_topk");
    let manifest = Manifest::load(&dir).unwrap();
    let engine = Engine::cpu().unwrap();
    let trainer = Trainer::new(engine, manifest, quick_cfg(1), 3).unwrap();
    let (acc, loss) = trainer.evaluate(3).unwrap();
    assert!((0.0..=1.0).contains(&acc));
    assert!(loss.is_finite() && loss > 0.0);
}

#[test]
fn checkpoint_roundtrip_preserves_training_state() {
    let dir = require_artifact!("cast_topk");
    let manifest = Manifest::load(&dir).unwrap();
    let engine = Engine::cpu().unwrap();
    let mut cfg = quick_cfg(5);
    let ckpt_path = std::env::temp_dir().join("cast_it_train.ckpt");
    cfg.checkpoint = Some(ckpt_path.clone());
    let mut trainer = Trainer::new(engine.clone(), manifest, cfg, 4).unwrap();
    let _ = trainer.run().unwrap();
    let expect = trainer.state.params[0].as_f32().unwrap().to_vec();

    let (loaded, names) = checkpoint::load(&ckpt_path).unwrap();
    assert_eq!(loaded.step, 5.0);
    assert_eq!(loaded.params[0].as_f32().unwrap(), &expect[..]);
    assert_eq!(names.len(), loaded.n_params());
    // moments survive the roundtrip (exact resume)
    assert_eq!(
        loaded.m[0].as_f32().unwrap(),
        trainer.state.m[0].as_f32().unwrap()
    );
}

#[test]
fn deterministic_training_same_seed_same_loss() {
    let dir = require_artifact!("cast_topk");
    let engine = Engine::cpu().unwrap();
    let run = |seed: u64| {
        let manifest = Manifest::load(&dir).unwrap();
        let mut cfg = quick_cfg(6);
        cfg.seed = seed;
        let mut t = Trainer::new(engine.clone(), manifest, cfg, seed as u32).unwrap();
        t.run().unwrap().history.steps.iter().map(|r| r.loss).collect::<Vec<_>>()
    };
    assert_eq!(run(9), run(9));
    assert_ne!(run(9), run(10));
}

#[test]
fn causal_decoder_extension_trains() {
    // §5.5 extension: the causal artifact flows through the same L3
    // trainer unchanged (variant-agnostic manifest contract).
    let dir = require_artifact!("causal");
    let manifest = Manifest::load(&dir).unwrap();
    let engine = Engine::cpu().unwrap();
    let mut trainer = Trainer::new(engine, manifest, quick_cfg(6), 21).unwrap();
    let report = trainer.run().unwrap();
    assert!(report.history.steps.iter().all(|r| r.loss.is_finite()));
}

#[test]
fn lsh_baseline_trains() {
    let dir = require_artifact!("lsh");
    let manifest = Manifest::load(&dir).unwrap();
    let engine = Engine::cpu().unwrap();
    let mut trainer = Trainer::new(engine, manifest, quick_cfg(6), 22).unwrap();
    let report = trainer.run().unwrap();
    assert!(report.history.steps.iter().all(|r| r.loss.is_finite()));
}

#[test]
fn vanilla_baseline_trains() {
    let dir = require_artifact!("vanilla");
    let manifest = Manifest::load(&dir).unwrap();
    let engine = Engine::cpu().unwrap();
    let mut trainer = Trainer::new(engine, manifest, quick_cfg(8), 5).unwrap();
    let report = trainer.run().unwrap();
    assert!(report.history.steps.iter().all(|r| r.loss.is_finite()));
}

#[test]
fn params_change_after_one_step() {
    let dir = require_artifact!("cast_topk");
    let manifest = Manifest::load(&dir).unwrap();
    let engine = Engine::cpu().unwrap();
    let mut trainer = Trainer::new(engine.clone(), manifest, quick_cfg(1), 6).unwrap();
    let before = trainer.state.params[0].as_f32().unwrap().to_vec();
    let _ = trainer.run().unwrap();
    let after = trainer.state.params[0].as_f32().unwrap();
    assert_ne!(&before[..], after, "one Adam step must move parameters");
}

#[test]
fn model_state_from_params_matches_init_shapes() {
    let dir = require_artifact!("cast_topk");
    let manifest = Manifest::load(&dir).unwrap();
    let engine = Engine::cpu().unwrap();
    let st = ModelState::init(&engine, &manifest, 0).unwrap();
    let st2 = ModelState::from_params(st.params.clone());
    assert_eq!(st2.n_params(), manifest.n_params());
    assert_eq!(st2.step, 0.0);
}
