//! Memory-observability integration suite: this binary installs the
//! tracking allocator itself (`#[global_allocator]` is per binary), so
//! it is where the byte-level assertions live — allocator accounting,
//! phase watermarks, the no-heap-traffic guarantee of gated-off
//! instrumentation, bit-identical outputs with every tap on, and the
//! measured O(αN)-vs-O(N²) memory curves cross-validated against the
//! §3.4 analytic model.
//!
//! Every test serializes on `memtrack::test_guard()` (and the
//! cluster-stats/trace guards where it flips those gates, always in
//! that order) because the counters and gates are process-global.

use cast::bench::memmodel::AttnShape;
use cast::bench::memory;
use cast::model::ModelState;
use cast::runtime::native::cluster_stats;
use cast::runtime::native::spec::tiny_meta;
use cast::runtime::{Engine, HostTensor, Manifest};
use cast::util::{memtrack, trace};

#[global_allocator]
static ALLOC: memtrack::TrackingAlloc = memtrack::TrackingAlloc;

/// One forward pass of the tiny cast_topk config, returning the logits
/// (same idiom as integration_trace.rs).
fn predict_logits(seed: u32) -> Vec<f32> {
    let engine = Engine::cpu().unwrap();
    let manifest = Manifest::synthetic(tiny_meta("cast_topk"));
    let exe = engine.load(&manifest, "predict").unwrap();
    let state = ModelState::init(&engine, &manifest, seed).unwrap();
    let meta = &manifest.meta;
    let tokens: Vec<i32> =
        (0..meta.batch * meta.seq_len).map(|i| (i * 7 % 50) as i32).collect();
    let tensor = HostTensor::s32(vec![meta.batch, meta.seq_len], tokens);
    let mut inputs: Vec<&HostTensor> = state.params.iter().collect();
    inputs.push(&tensor);
    let out = exe.run_refs(&inputs).unwrap();
    out[0].as_f32().unwrap().to_vec()
}

#[test]
fn tracking_allocator_is_installed_and_counts_bytes() {
    let _g = memtrack::test_guard();
    assert!(memtrack::installed(), "this binary declares #[global_allocator]");

    let a0 = memtrack::total_allocs();
    let c0 = memtrack::current_bytes();
    let v = std::hint::black_box(Vec::<u8>::with_capacity(1 << 20));
    assert!(
        memtrack::current_bytes() >= c0 + (1 << 20),
        "a 1 MiB allocation must move the live counter"
    );
    assert!(memtrack::total_allocs() > a0, "the allocation counter must tick");
    let with_v = memtrack::current_bytes();
    drop(std::hint::black_box(v));
    assert!(
        memtrack::current_bytes() < with_v,
        "freeing must bring the live counter back down"
    );
}

#[test]
fn watermarks_account_phase_peaks_and_the_gate_controls_recording() {
    let _g = memtrack::test_guard();

    // gate off: measurement still works, nothing is recorded
    memtrack::set_enabled(false);
    let _ = memtrack::drain_marks();
    {
        let wm = memtrack::Watermark::begin("itest.off");
        let buf = std::hint::black_box(vec![0u8; 1 << 20]);
        assert!(wm.peak_delta() >= 1 << 20, "peak_delta works without the gate");
        drop(std::hint::black_box(buf));
    }
    assert!(memtrack::drain_marks().is_empty(), "no marks while the gate is off");

    // gate on: the phase lands in the mark store with its peak
    memtrack::set_enabled(true);
    {
        let wm = memtrack::Watermark::begin("itest.phase");
        let buf = std::hint::black_box(vec![0u8; 3 << 20]);
        assert!(wm.peak_delta() >= 3 << 20);
        drop(std::hint::black_box(buf));
        drop(wm);
    }
    let marks = memtrack::drain_marks();
    memtrack::set_enabled(false);
    assert_eq!(marks.len(), 1, "exactly the one phase: {marks:?}");
    assert_eq!(marks[0].name, "itest.phase");
    assert!(marks[0].peak_delta_bytes >= 3 << 20, "{marks:?}");
    assert!(
        marks[0].end_bytes <= marks[0].base_bytes + (1 << 16),
        "the phase freed its buffer, so it must not read as a leak: {marks:?}"
    );
}

#[test]
fn gated_off_instrumentation_does_no_heap_traffic() {
    let _g = memtrack::test_guard();
    let _g2 = cluster_stats::test_guard();
    let _g3 = trace::test_guard();
    memtrack::set_enabled(false);
    cluster_stats::set_enabled(false);
    trace::set_enabled(false);
    cluster_stats::clear();

    let a_g = std::hint::black_box(vec![0.25f32; 4 * 4]);
    // idle pool threads from earlier tests can allocate concurrently,
    // so demand one clean pass out of several rather than exactly-zero
    // on the first try
    let mut clean = false;
    for _ in 0..5 {
        let a0 = memtrack::total_allocs();
        for _ in 0..1000 {
            std::hint::black_box(cluster_stats::active());
            std::hint::black_box(memtrack::active());
            cluster_stats::record(0, 1, 4, 4, &a_g);
            let wm = memtrack::Watermark::begin("itest.noalloc");
            std::hint::black_box(wm.peak_delta());
            drop(wm);
            let span = trace::span("itest.noalloc");
            drop(span);
        }
        if memtrack::total_allocs() == a0 {
            clean = true;
            break;
        }
    }
    assert!(clean, "gated-off taps/spans/watermarks must not touch the heap");
    assert!(
        cluster_stats::snapshot().is_empty(),
        "a gated-off record() must accumulate nothing"
    );
}

#[test]
fn instrumentation_is_bit_identical_and_the_cluster_tap_fires() {
    let _g = memtrack::test_guard();
    let _g2 = cluster_stats::test_guard();
    memtrack::set_enabled(false);
    cluster_stats::set_enabled(false);
    cluster_stats::clear();
    let baseline = predict_logits(3);
    assert!(cluster_stats::snapshot().is_empty(), "tap must stay silent while off");

    memtrack::set_enabled(true);
    cluster_stats::set_enabled(true);
    cluster_stats::clear();
    let _ = memtrack::drain_marks();
    let instrumented = predict_logits(3);
    let snaps = cluster_stats::snapshot();
    cluster_stats::clear();
    cluster_stats::set_enabled(false);
    memtrack::set_enabled(false);
    let _ = memtrack::drain_marks();

    // exact f32 equality: the taps only *read* A_g and the allocator
    // only counts, so every output bit must match
    assert_eq!(baseline.len(), instrumented.len());
    for (i, (b, t)) in baseline.iter().zip(&instrumented).enumerate() {
        assert_eq!(b.to_bits(), t.to_bits(), "logit {i} differs under instrumentation");
    }

    assert!(!snaps.is_empty(), "the cluster tap must fire for a cast variant");
    assert!(
        snaps.iter().any(|s| s.layer == 0),
        "layer attribution from the blocks.N.attn prefix: {snaps:?}"
    );
    for s in &snaps {
        assert!(s.n_c >= 1 && s.forwards >= 1, "{s:?}");
        assert!((0.0..=1.0).contains(&s.entropy), "entropy normalized: {s:?}");
        assert!((0.0..=1.0).contains(&s.max_fraction), "{s:?}");
        assert_eq!(s.occupancy.len(), s.n_c, "{s:?}");
        let occ: u64 = s.occupancy.iter().sum();
        assert_eq!(occ, s.tokens, "occupancy partitions the tokens: {s:?}");
    }
}

#[test]
fn measured_memory_curves_match_the_model() {
    let _g = memtrack::test_guard();
    memtrack::set_enabled(false);

    let (batch, heads, d) = (1usize, 2usize, 32usize);
    let seqs = [256usize, 512, 1024];
    let points = memory::memory_sweep(&seqs, batch, heads, d).unwrap();
    assert_eq!(points.len(), seqs.len() * 2, "a cast/vanilla pair per length");

    // measured peak lands within a constant factor of model + q/k/v/out
    // base — the §3.4 tensor accounting, cross-validated in bytes
    for p in &points {
        let shape = AttnShape { batch, seq: p.seq_len, heads, d, n_c: p.n_c, kappa: p.kappa };
        let predicted = p.model_bytes + memory::base_bytes(&shape);
        assert!(
            p.measured_peak_bytes >= p.model_bytes,
            "{}: measured {} under the model's own {}",
            p.config,
            p.measured_peak_bytes,
            p.model_bytes
        );
        let ratio = p.measured_peak_bytes as f64 / predicted as f64;
        assert!(
            (0.9..=1.5).contains(&ratio),
            "{}: measured {} vs predicted {predicted} (x{ratio:.3})",
            p.config,
            p.measured_peak_bytes
        );
    }

    let cast_pts: Vec<&memory::MemoryPoint> =
        points.iter().filter(|p| p.variant == "cast_topk").collect();
    let van_pts: Vec<&memory::MemoryPoint> =
        points.iter().filter(|p| p.variant == "vanilla").collect();

    // vanilla doubles quadratically: slab x4 plus a linear base
    for w in van_pts.windows(2) {
        let r = w[1].measured_peak_bytes as f64 / w[0].measured_peak_bytes as f64;
        assert!(
            (3.0..=5.0).contains(&r),
            "vanilla N {} -> {} grew x{r:.2}, expected ~4 (quadratic)",
            w[0].seq_len,
            w[1].seq_len
        );
    }
    // balanced CAST doubles sub-quadratically and strictly slower than
    // vanilla at every transition
    for (wc, wv) in cast_pts.windows(2).zip(van_pts.windows(2)) {
        let rc = wc[1].measured_peak_bytes as f64 / wc[0].measured_peak_bytes as f64;
        let rv = wv[1].measured_peak_bytes as f64 / wv[0].measured_peak_bytes as f64;
        assert!(
            rc <= 3.6,
            "cast N {} -> {} grew x{rc:.2}, expected sub-quadratic",
            wc[0].seq_len,
            wc[1].seq_len
        );
        assert!(rc < rv - 0.15, "cast x{rc:.2} must double slower than vanilla x{rv:.2}");
    }
    // and the curves have crossed by the largest length
    let (c_last, v_last) = (cast_pts.last().unwrap(), van_pts.last().unwrap());
    assert!(
        c_last.measured_peak_bytes < v_last.measured_peak_bytes,
        "at N={} cast ({}) must beat vanilla ({})",
        c_last.seq_len,
        c_last.measured_peak_bytes,
        v_last.measured_peak_bytes
    );
}
