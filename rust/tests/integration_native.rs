//! Integration: the native CPU backend end-to-end with ZERO artifacts on
//! disk — manifests are synthesized in memory, and init → predict →
//! cluster-assignment extraction, the trainer loop, and the Figure-4
//! visualization pipeline all run through the same backend-agnostic code
//! paths the PJRT backend uses.

use std::sync::Arc;

use cast::analysis;
use cast::data;
use cast::model::ModelState;
use cast::runtime::native::spec::tiny_meta;
use cast::runtime::{Engine, HostTensor, Manifest};
use cast::train::{Schedule, TrainConfig, Trainer};
use cast::util::rng::Rng;

fn tiny_manifest(variant: &str) -> Manifest {
    Manifest::synthetic(tiny_meta(variant))
}

fn quick_cfg(steps: usize) -> TrainConfig {
    TrainConfig {
        steps,
        schedule: Schedule::Warmup { lr: 2e-3, warmup: 2 },
        seed: 1,
        eval_every: 0,
        eval_batches: 2,
        data_workers: 2,
        queue_depth: 2,
        log_every: 0,
        checkpoint: None,
        ckpt_every: 0,
    }
}

/// The acceptance path: init → predict → cluster-assignment extraction,
/// all through `Engine::cpu()` with an in-memory manifest.
#[test]
fn native_init_predict_and_cluster_extraction_end_to_end() {
    let manifest = tiny_manifest("cast_topk");
    let engine = Engine::cpu().unwrap();
    assert_eq!(engine.backend_name(), "native");

    // init: manifest-shaped, deterministic parameters
    let state = ModelState::init(&engine, &manifest, 7).unwrap();
    assert_eq!(state.n_params(), manifest.n_params());
    let again = ModelState::init(&engine, &manifest, 7).unwrap();
    for (a, b) in state.params.iter().zip(&again.params) {
        assert_eq!(a.as_f32().unwrap(), b.as_f32().unwrap());
    }

    // predict: finite logits of the right shape
    let gen = data::task(&manifest.meta.task).unwrap();
    let mut rng = Rng::new(3);
    let batch =
        data::make_batch(gen.as_ref(), &mut rng, manifest.meta.batch, manifest.meta.seq_len);
    let exe = engine.load(&manifest, "predict").unwrap();
    let mut inputs: Vec<&HostTensor> = state.params.iter().collect();
    inputs.push(&batch.tokens);
    let out = exe.run_refs(&inputs).unwrap();
    assert_eq!(out[0].shape, vec![2, 2]);
    assert!(out[0].as_f32().unwrap().iter().all(|x| x.is_finite()));

    // cluster-assignment extraction (predict_ag → argmax assignments)
    let ag = analysis::cluster_assignments(&engine, &manifest, &state, &batch.tokens, 0).unwrap();
    assert_eq!(ag.layers, manifest.meta.depth);
    assert_eq!(ag.n, manifest.meta.seq_len);
    assert_eq!(ag.n_c, manifest.meta.n_c);
    for layer in 0..ag.layers {
        let assign = ag.assignments(layer);
        assert_eq!(assign.len(), 64);
        assert!(assign.iter().all(|&c| c < 4), "assignments must index clusters");
    }
    // scores are a convex softmax mix: rows sum to ~1
    for t in 0..ag.n {
        let s: f32 = (0..ag.n_c).map(|c| ag.at(0, t, c)).sum();
        assert!((s - 1.0).abs() < 1e-3, "A_g row sums to {s}");
    }
}

#[test]
fn native_predict_runs_for_every_variant() {
    for variant in ["cast_topk", "cast_sa", "vanilla", "local", "lsh"] {
        let manifest = tiny_manifest(variant);
        let engine = Engine::cpu().unwrap();
        let state = ModelState::init(&engine, &manifest, 1).unwrap();
        let exe = engine.load(&manifest, "predict").unwrap();
        let tokens = HostTensor::s32(manifest.tokens_shape.clone(), vec![5; 128]);
        let mut inputs: Vec<&HostTensor> = state.params.iter().collect();
        inputs.push(&tokens);
        let out = exe.run_refs(&inputs).unwrap();
        assert_eq!(out[0].shape, vec![2, 2], "{variant}");
        assert!(out[0].as_f32().unwrap().iter().all(|x| x.is_finite()), "{variant}");
    }
}

#[test]
fn native_trainer_runs_end_to_end_and_counts_steps() {
    let manifest = tiny_manifest("cast_topk");
    let engine = Engine::cpu().unwrap();
    let mut trainer = Trainer::new(engine, manifest, quick_cfg(5), 4).unwrap();
    let report = trainer.run().unwrap();
    assert_eq!(report.history.steps.len(), 5);
    assert!(report.history.steps.iter().all(|r| r.loss.is_finite() && r.loss < 20.0));
    assert_eq!(trainer.state.step, 5.0);
    // head parameters moved under the native train_step
    let head_idx = trainer
        .manifest
        .params
        .iter()
        .position(|p| p.name == "head.out.w")
        .unwrap();
    let fresh = ModelState::init(trainer.engine(), &trainer.manifest, 4).unwrap();
    assert_ne!(
        trainer.state.params[head_idx].as_f32().unwrap(),
        fresh.params[head_idx].as_f32().unwrap(),
        "training must move the classifier head"
    );
    // evaluation on the held-out stream works through the same backend
    let (acc, loss) = trainer.evaluate(2).unwrap();
    assert!((0.0..=1.0).contains(&acc));
    assert!(loss.is_finite() && loss > 0.0);
}

#[test]
fn native_training_is_deterministic_per_seed() {
    let engine = Engine::cpu().unwrap();
    let run = |seed: u64| {
        let manifest = tiny_manifest("cast_topk");
        let mut cfg = quick_cfg(4);
        cfg.seed = seed;
        let mut t = Trainer::new(engine.clone(), manifest, cfg, seed as u32).unwrap();
        t.run().unwrap().history.steps.iter().map(|r| r.loss).collect::<Vec<_>>()
    };
    assert_eq!(run(9), run(9));
    assert_ne!(run(9), run(10));
}

#[test]
fn native_viz_pipeline_writes_cluster_maps() {
    // seq_len 64 = 8x8 is square, so the Figure-4 image pipeline runs on
    // the tiny config directly.
    let manifest = tiny_manifest("cast_sa");
    let engine = Engine::cpu().unwrap();
    let state = ModelState::init(&engine, &manifest, 2).unwrap();
    let tokens = HostTensor::s32(vec![2, 64], (0..128).map(|i| i % 90).collect());
    let out_dir = std::env::temp_dir().join("cast_native_viz_test");
    let _ = std::fs::remove_dir_all(&out_dir);
    let files =
        analysis::visualize_image_clusters(&engine, &manifest, &state, &tokens, 0, &out_dir)
            .unwrap();
    // input.pgm + per layer: clusters.ppm + Nc score maps
    let expected = 1 + manifest.meta.depth * (1 + manifest.meta.n_c);
    assert_eq!(files.len(), expected);
    for f in &files {
        assert!(f.exists(), "{f:?} missing");
        assert!(std::fs::metadata(f).unwrap().len() > 0);
    }
}

#[test]
fn viz_rejects_out_of_range_batch_index() {
    let manifest = tiny_manifest("cast_topk");
    let engine = Engine::cpu().unwrap();
    let state = ModelState::init(&engine, &manifest, 0).unwrap();
    let tokens = HostTensor::s32(vec![2, 64], vec![1; 128]);
    let out_dir = std::env::temp_dir().join("cast_native_viz_oob");
    let err =
        analysis::visualize_image_clusters(&engine, &manifest, &state, &tokens, 5, &out_dir)
            .unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("out of range"), "want a bounds error, got: {msg}");
}

#[test]
fn native_infer_efficiency_job_runs_without_artifacts_on_disk() {
    // The sweep runner path (JobKind::InferEfficiency) over a saved
    // manifest-only artifact dir — what `cast gen` emits.
    use cast::coordinator::sweep::Sweep;
    use cast::coordinator::{Job, JobKind};
    let root = std::env::temp_dir().join("cast_native_job_test");
    let _ = std::fs::remove_dir_all(&root);
    let dir = tiny_manifest("cast_topk").save(&root).unwrap();
    let engine = Engine::cpu().unwrap();
    let sweep = Sweep::new();
    let job = Job { artifact_dir: dir, kind: JobKind::InferEfficiency { steps: 2 }, seed: 3 };
    let result = sweep.run_inprocess(&engine, &job).unwrap();
    assert_eq!(result.key, "text_cast_topk_n64_b2_c4_k16");
    assert!(result.steps_per_sec > 0.0);
    assert!((0.0..=1.0).contains(&result.final_acc));
}

#[test]
fn checkpoint_roundtrip_on_native_state() {
    let manifest = tiny_manifest("cast_topk");
    let engine = Engine::cpu().unwrap();
    let mut cfg = quick_cfg(3);
    let ckpt = std::env::temp_dir().join("cast_native_it.ckpt");
    cfg.checkpoint = Some(ckpt.clone());
    let mut trainer = Trainer::new(engine, manifest, cfg, 6).unwrap();
    let _ = trainer.run().unwrap();
    let (loaded, names) = cast::model::checkpoint::load(&ckpt).unwrap();
    assert_eq!(loaded.step, 3.0);
    assert_eq!(names.len(), loaded.n_params());
    assert_eq!(
        loaded.params[0].as_f32().unwrap(),
        trainer.state.params[0].as_f32().unwrap()
    );
}

/// One full-backprop train_step on a fixed batch, via the engine path.
fn fixed_batch_step(
    exe: &std::sync::Arc<dyn cast::runtime::Executable>,
    state: &mut ModelState,
    tokens: &HostTensor,
    labels: &HostTensor,
) {
    let scalars = (HostTensor::scalar_f32(state.step), HostTensor::scalar_f32(2e-3));
    let inputs = state.train_inputs_refs(&scalars, tokens, labels);
    let outputs = exe.run_refs(&inputs).unwrap();
    state.absorb(outputs).unwrap();
}

#[test]
fn checkpoint_resume_is_bit_identical_including_adam_moments() {
    // 3 steps -> checkpoint -> 2 more must equal 5 uninterrupted steps
    // exactly: the checkpoint carries params, m, v, AND the step counter,
    // so bias correction and momentum resume mid-flight.
    let manifest = tiny_manifest("cast_topk");
    let engine = Engine::cpu().unwrap();
    let exe = engine.load(&manifest, "train_step").unwrap();
    let tokens = HostTensor::s32(
        manifest.tokens_shape.clone(),
        (0..128).map(|i| ((i * 13 + 1) % 90) as i32).collect(),
    );
    let labels = HostTensor::s32(vec![2], vec![0, 1]);

    let mut state = ModelState::init(&engine, &manifest, 3).unwrap();
    for _ in 0..3 {
        fixed_batch_step(&exe, &mut state, &tokens, &labels);
    }
    let names: Vec<String> = manifest.params.iter().map(|p| p.name.clone()).collect();
    let dir = std::env::temp_dir().join("cast_native_resume_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("resume.ckpt");
    cast::model::checkpoint::save(&state, &names, &path).unwrap();

    // uninterrupted continuation
    for _ in 0..2 {
        fixed_batch_step(&exe, &mut state, &tokens, &labels);
    }
    // resumed continuation
    let (mut resumed, loaded_names) = cast::model::checkpoint::load(&path).unwrap();
    assert_eq!(loaded_names, names);
    assert_eq!(resumed.step, 3.0);
    for _ in 0..2 {
        fixed_batch_step(&exe, &mut resumed, &tokens, &labels);
    }

    assert_eq!(state.step, resumed.step);
    for i in 0..state.n_params() {
        assert_eq!(
            state.params[i].as_f32().unwrap(),
            resumed.params[i].as_f32().unwrap(),
            "param {} diverged after resume",
            names[i]
        );
        assert_eq!(
            state.m[i].as_f32().unwrap(),
            resumed.m[i].as_f32().unwrap(),
            "adam m {} diverged after resume",
            names[i]
        );
        assert_eq!(
            state.v[i].as_f32().unwrap(),
            resumed.v[i].as_f32().unwrap(),
            "adam v {} diverged after resume",
            names[i]
        );
    }
}

#[test]
fn full_backprop_beats_frozen_backbone_on_equal_budget() {
    // the acceptance bar for the autograd subsystem: 200 native steps of
    // full backprop reach strictly higher training accuracy than the
    // same budget with the PR-1 head-only (frozen backbone) path
    use cast::util::json::Json;
    let steps = 200;
    let run = |head_only: bool| -> (f32, f32) {
        // fresh engine per run: the executable cache keys on the model
        // config, and the two runs differ only in the train-scope flag
        let engine = Engine::cpu().unwrap();
        let mut man = tiny_manifest("cast_topk");
        if head_only {
            man.raw = Json::obj(vec![(
                "config",
                Json::obj(vec![("train_scope", Json::str("head"))]),
            )]);
        }
        let cfg = TrainConfig {
            steps,
            schedule: Schedule::Warmup { lr: 1e-3, warmup: 20 },
            seed: 5,
            eval_every: 0,
            eval_batches: 0,
            data_workers: 2,
            queue_depth: 2,
            log_every: 0,
            checkpoint: None,
            ckpt_every: 0,
        };
        let mut t = Trainer::new(engine, man, cfg, 5).unwrap();
        let report = t.run().unwrap();
        (report.history.recent_acc(100), report.history.recent_loss(100))
    };
    let (full_acc, full_loss) = run(false);
    let (head_acc, head_loss) = run(true);
    assert!(
        full_acc > head_acc,
        "full backprop must beat the frozen backbone: acc {full_acc:.3} vs {head_acc:.3} \
         (loss {full_loss:.4} vs {head_loss:.4})"
    );
    assert!(
        full_loss < head_loss,
        "full backprop must reach lower loss: {full_loss:.4} vs {head_loss:.4}"
    );
}

#[test]
fn dual_encoder_retrieval_config_predicts_natively() {
    // Retrieval-style dual tower: tokens (B,2,N), 4d head features.
    let mut meta = tiny_meta("cast_topk");
    meta.task = "retrieval".to_string();
    meta.dual = true;
    let manifest = Manifest::synthetic(meta);
    let engine = Engine::cpu().unwrap();
    let state = ModelState::init(&engine, &manifest, 1).unwrap();
    let exe = engine.load(&manifest, "predict").unwrap();
    let tokens = HostTensor::s32(vec![2, 2, 64], (0..256).map(|i| i % 60).collect());
    let mut inputs: Vec<&HostTensor> = state.params.iter().collect();
    inputs.push(&tokens);
    let out = exe.run_refs(&inputs).unwrap();
    assert_eq!(out[0].shape, vec![2, 2]);
    assert!(out[0].as_f32().unwrap().iter().all(|x| x.is_finite()));
    // dual configs have no predict_ag
    assert!(!Engine::cpu().unwrap().has(&manifest, "predict_ag"));
}

#[test]
fn fully_masked_attention_rows_are_zero_not_uniform() {
    // A row with zero valid slots (every score at NEG_INF — reachable at
    // decode step 0 with a fresh empty cluster) must weight nothing: all
    // zeros, never NaN and never a uniform distribution over masked slots.
    use cast::runtime::native::ops::{self, AttnFn};
    for f in [AttnFn::Softmax, AttnFn::Laplace] {
        let mut x = vec![ops::NEG_INF; 8];
        ops::attn_rows(&mut x, 4, f);
        assert!(x.iter().all(|v| *v == 0.0), "{f:?}: fully-masked row must be zeros, got {x:?}");

        // a partially-masked row still normalizes to 1 over survivors
        let mut y = vec![0.3, ops::NEG_INF, 1.1, ops::NEG_INF];
        ops::attn_rows(&mut y, 4, f);
        let s: f32 = y.iter().sum();
        assert!((s - 1.0).abs() < 1e-4, "{f:?}: partial mask row sums to {s}");
        assert_eq!(y[1], 0.0, "{f:?}: masked slot must carry zero weight");
        assert_eq!(y[3], 0.0, "{f:?}: masked slot must carry zero weight");
    }
}

#[test]
fn synthetic_and_saved_manifests_agree_with_batcher_contract() {
    // The trainer's data path: generated batches satisfy the manifest the
    // native engine validates against.
    let manifest = tiny_manifest("cast_sa");
    let gen: Arc<dyn data::TaskGen> = Arc::from(data::task("text").unwrap());
    let mut stream = data::batcher::SyncStream::new(gen, 11, manifest.meta.batch, 64);
    let batch = stream.next();
    assert_eq!(batch.tokens.shape, manifest.tokens_shape);
    assert_eq!(batch.labels.shape, vec![manifest.meta.batch]);
}
