//! Integration: data pipeline × coordinator invariants that span modules
//! (no artifacts required — pure L3).

mod common;

use std::sync::Arc;

use cast::data::batcher::{Batcher, SyncStream};
use cast::data::{self, TaskGen};
use cast::util::prop;
use cast::util::rng::Rng;

#[test]
fn prop_batches_respect_model_contract_all_tasks() {
    // Every generated batch must satisfy the manifest contract the models
    // are lowered against: token range < vocab, labels < n_classes.
    for name in ["listops", "text", "retrieval", "image", "pathfinder"] {
        let gen = data::task(name).unwrap();
        let seq = match name {
            "image" | "pathfinder" => 1024,
            _ => 128,
        };
        prop::check(
            "batch contract",
            prop::Config { cases: 10, ..Default::default() },
            |rng| data::make_batch(gen.as_ref(), rng, 3, seq),
            |batch| {
                let toks = batch.tokens.as_s32().map_err(|e| e.to_string())?;
                if !toks.iter().all(|&t| t >= 0 && (t as usize) < gen.vocab()) {
                    return Err(format!("{name}: token out of range"));
                }
                let labels = batch.labels.as_s32().map_err(|e| e.to_string())?;
                if !labels.iter().all(|&l| l >= 0 && (l as usize) < gen.n_classes()) {
                    return Err(format!("{name}: label out of range"));
                }
                Ok(())
            },
        );
    }
}

#[test]
fn train_and_eval_streams_are_disjoint() {
    // The trainer derives its eval stream by XORing the seed; the first
    // batches of both streams must differ (overlap would inflate eval).
    let gen: Arc<dyn TaskGen> = Arc::from(data::task("text").unwrap());
    let mut train = SyncStream::new(gen.clone(), 42, 2, 128);
    let mut eval = SyncStream::new(gen, 42 ^ 0xE7A1_0000_0000_0000, 2, 128);
    let a = train.next();
    let b = eval.next();
    assert_ne!(a.tokens.as_s32().unwrap(), b.tokens.as_s32().unwrap());
}

#[test]
fn batcher_survives_slow_consumer_and_stays_ordered() {
    let gen: Arc<dyn TaskGen> = Arc::from(data::task("listops").unwrap());
    let mut reference = SyncStream::new(gen.clone(), 5, 2, 64);
    let mut batcher = Batcher::spawn(gen, 5, 2, 64, 3, 2);
    for i in 0..8 {
        if i % 3 == 0 {
            std::thread::sleep(std::time::Duration::from_millis(20));
        }
        let got = batcher.next();
        let want = reference.next();
        assert_eq!(got.labels.as_s32().unwrap(), want.labels.as_s32().unwrap(), "batch {i}");
    }
}

#[test]
fn listops_stream_has_parseable_prefix_rate() {
    // Every listops example must be a valid expression (evaluator != None).
    let gen = data::task("listops").unwrap();
    let mut rng = Rng::new(77);
    for _ in 0..50 {
        let ex = gen.example(&mut rng, 128);
        let stripped: Vec<i32> =
            ex.tokens.iter().copied().take_while(|&t| t != 0).collect();
        let val = cast::data::listops::eval_tokens(&stripped);
        assert_eq!(val, Some(ex.label));
    }
}

#[test]
fn pathx_batches_are_generatable_at_16k() {
    // Path-X (16K tokens) — the paper reports × (not learnable) but the
    // substrate must still produce the workload.
    let gen = data::task("pathx").unwrap();
    let mut rng = Rng::new(3);
    let b = data::make_batch(gen.as_ref(), &mut rng, 1, 16384);
    assert_eq!(b.tokens.shape, vec![1, 16384]);
}
