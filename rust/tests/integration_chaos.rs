//! Chaos integration suite: a real server and a real training loop run
//! under seeded `util::fault` plans, and the resilience invariants from
//! DESIGN.md §Robustness are asserted end-to-end:
//!
//! * every request the server accepts gets an answer — panicking
//!   batches turn into 500s, never into hung or dropped connections;
//! * the process survives injected worker panics (infer and conn side);
//! * deadline-expired jobs are shed with 503 + `Retry-After` instead of
//!   computed, and the breaker sheds fast once a model keeps failing;
//! * training still descends despite injected non-finite steps and a
//!   torn checkpoint write, and auto-resume never loads a corrupt file.
//!
//! The fault plan store is process-global, so every test here holds
//! [`fault::test_guard`] and installs/clears its own plan.

use std::io::Write;
use std::net::{SocketAddr, TcpStream};
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

use cast::model::checkpoint;
use cast::runtime::native::spec::tiny_meta;
use cast::runtime::{Engine, Manifest};
use cast::serve::http;
use cast::serve::{ModelSource, Registry, ServeConfig, Server};
use cast::train::{Schedule, TrainConfig, Trainer};
use cast::util::fault;
use cast::util::json::Json;
use cast::util::rng::Rng;

const SEED: u32 = 5;

struct Harness {
    server: Arc<Server>,
    addr: SocketAddr,
    join: Option<std::thread::JoinHandle<anyhow::Result<()>>>,
}

impl Harness {
    fn start(cfg: ServeConfig) -> Harness {
        let registry = Arc::new(Registry::new(Engine::cpu().unwrap()));
        registry
            .load(None, ModelSource::Synthetic { meta: tiny_meta("cast_topk"), seed: SEED })
            .unwrap();
        let server = Arc::new(Server::bind(cfg, registry).unwrap());
        let addr = server.local_addr();
        let runner = server.clone();
        let join = std::thread::spawn(move || runner.run());
        Harness { server, addr, join: Some(join) }
    }

    fn tiny() -> Harness {
        Harness::start(ServeConfig {
            addr: "127.0.0.1:0".to_string(),
            max_batch: 4,
            max_wait: Duration::from_millis(1),
            conn_workers: 8,
            ..ServeConfig::default()
        })
    }

    fn stop(&mut self) {
        self.server.shutdown_flag().store(true, Ordering::SeqCst);
        if let Some(join) = self.join.take() {
            join.join().expect("server thread panicked").expect("server run failed");
        }
    }
}

impl Drop for Harness {
    fn drop(&mut self) {
        if self.join.is_some() {
            self.stop();
        }
    }
}

/// One-shot request over a fresh connection, with arbitrary extra
/// headers (the plain helper in `integration_serve.rs` can't carry
/// `X-Deadline-Ms`).
fn raw_request(
    addr: SocketAddr,
    method: &str,
    target: &str,
    extra: &[(&str, &str)],
    body: &[u8],
) -> std::io::Result<http::Response> {
    let mut s = TcpStream::connect(addr).unwrap();
    let mut head = format!(
        "{method} {target} HTTP/1.1\r\nHost: chaos\r\nConnection: close\r\nContent-Length: {}\r\n",
        body.len()
    );
    for (name, value) in extra {
        head.push_str(&format!("{name}: {value}\r\n"));
    }
    head.push_str("\r\n");
    s.write_all(head.as_bytes()).unwrap();
    s.write_all(body).unwrap();
    http::read_response(&mut s, &mut Vec::new(), http::CLIENT_MAX_BODY)
}

fn predict_body(stream_id: u64, n: usize) -> String {
    let mut rng = Rng::new(0xC11E47).split(stream_id);
    let vals: Vec<usize> = (0..n).map(|_| rng.below(50)).collect();
    Json::obj(vec![("tokens", Json::Arr(vec![Json::arr_usize(&vals)]))]).to_string()
}

fn body_text(resp: &http::Response) -> String {
    String::from_utf8(resp.body.clone()).unwrap()
}

/// Value of an unlabeled counter family on `/metrics`.
fn metric_value(addr: SocketAddr, name: &str) -> f64 {
    let resp = raw_request(addr, "GET", "/metrics", &[], b"").unwrap();
    assert_eq!(resp.status, 200);
    body_text(&resp)
        .lines()
        .find_map(|l| {
            let mut parts = l.split_whitespace();
            (parts.next() == Some(name)).then(|| parts.next().unwrap().parse().unwrap())
        })
        .unwrap_or_else(|| panic!("metric {name} missing from /metrics"))
}

// ---------------------------------------------------------------------------
// serve under injected panics
// ---------------------------------------------------------------------------

#[test]
fn injected_infer_panics_answer_every_request_and_server_survives() {
    let _g = fault::test_guard();
    fault::clear();
    let mut h = Harness::tiny();
    let n = tiny_meta("cast_topk").seq_len;

    // the first three batches panic deterministically (prob 1.0, x3 cap)
    fault::set_plan("serve.infer.batch=panic:x3@42");
    let (mut ok, mut failed) = (0u64, 0u64);
    for i in 0..40u64 {
        let body = predict_body(i, n);
        let resp = raw_request(h.addr, "POST", "/predict", &[], body.as_bytes()).unwrap();
        match resp.status {
            200 => ok += 1,
            500 => {
                assert!(body_text(&resp).contains("panicked"), "{}", body_text(&resp));
                failed += 1;
            }
            other => panic!("request {i}: unexpected status {other}"),
        }
    }
    // accepted-implies-answered: all 40 requests got a response above
    // (read_response would have errored otherwise), exactly the injected
    // three as 500s, and the worker kept serving afterwards
    assert_eq!(fault::fired("serve.infer.batch"), 3, "plan must not pass vacuously");
    assert_eq!(failed, 3);
    assert_eq!(ok, 37);
    assert_eq!(metric_value(h.addr, "cast_serve_worker_panics_total"), 3.0);

    // liveness and readiness survive: three consecutive failures stay
    // under the breaker threshold, so the model is still routable
    let resp = raw_request(h.addr, "GET", "/healthz", &[], b"").unwrap();
    assert_eq!(resp.status, 200);
    let ready = raw_request(h.addr, "GET", "/readyz", &[], b"").unwrap();
    assert_eq!(ready.status, 200);
    assert_eq!(Json::parse(&body_text(&ready)).unwrap().get("status"), Some(&Json::str("ok")));

    fault::clear();
    h.stop();
}

#[test]
fn injected_conn_worker_panics_drop_only_their_connection() {
    let _g = fault::test_guard();
    fault::clear();
    let mut h = Harness::tiny();
    let n = tiny_meta("cast_topk").seq_len;

    fault::set_plan("serve.conn.handle=panic:x2@3");
    // the first two connections die before a response is written — the
    // client observes a clean EOF (the stale-connection kind loadgen
    // retries on), never a hang
    for i in 0..2u64 {
        let body = predict_body(i, n);
        let err = raw_request(h.addr, "POST", "/predict", &[], body.as_bytes()).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::UnexpectedEof, "{err}");
    }
    assert_eq!(fault::fired("serve.conn.handle"), 2);
    // the pool survives: fresh connections are served normally
    let resp = raw_request(h.addr, "POST", "/predict", &[], predict_body(9, n).as_bytes()).unwrap();
    assert_eq!(resp.status, 200);
    assert_eq!(metric_value(h.addr, "cast_serve_worker_panics_total"), 2.0);

    fault::clear();
    h.stop();
}

#[test]
fn client_faults_are_shed_cleanly_while_honest_requests_succeed() {
    let _g = fault::test_guard();
    fault::clear();
    let mut h = Harness::tiny();

    // `--client-faults`: residues 1 and 3 (mod 5) of each worker's 10
    // requests turn hostile — 2 slow-loris + 2 mid-body disconnects per
    // connection
    let cfg = cast::serve::LoadgenConfig {
        addr: h.addr.to_string(),
        conns: 2,
        requests: 10,
        client_faults: true,
        ..Default::default()
    };
    let report = cast::serve::loadgen::run(&cfg).unwrap();

    assert_eq!(report.faults_slowloris, 4, "{report:?}");
    assert_eq!(report.faults_disconnect, 4, "{report:?}");
    assert_eq!(
        report.faults_shed,
        report.faults_slowloris + report.faults_disconnect,
        "every fault must be shed cleanly: {report:?}"
    );
    // honest requests ride through untouched by their hostile neighbors
    assert_eq!(report.ok, 2 * 10 - 8, "{report:?}");
    assert_eq!(report.errors, 0, "{report:?}");

    // and the server is still fully healthy afterwards
    let resp = raw_request(h.addr, "GET", "/healthz", &[], b"").unwrap();
    assert_eq!(resp.status, 200);
    h.stop();
}

// ---------------------------------------------------------------------------
// deadline budgets and the circuit breaker
// ---------------------------------------------------------------------------

#[test]
fn queue_expired_deadline_is_shed_with_503_and_retry_after() {
    let _g = fault::test_guard();
    fault::clear();
    // a long batching window guarantees the tiny budget expires while
    // the job waits for its batch to fill
    let mut h = Harness::start(ServeConfig {
        addr: "127.0.0.1:0".to_string(),
        max_batch: 8,
        max_wait: Duration::from_millis(150),
        conn_workers: 4,
        ..ServeConfig::default()
    });
    let n = tiny_meta("cast_topk").seq_len;

    let body = predict_body(1, n);
    let resp =
        raw_request(h.addr, "POST", "/predict", &[("X-Deadline-Ms", "10")], body.as_bytes())
            .unwrap();
    assert_eq!(resp.status, 503);
    assert_eq!(resp.headers.get("retry-after").map(String::as_str), Some("1"));
    assert!(body_text(&resp).contains("deadline exceeded"), "{}", body_text(&resp));
    assert_eq!(metric_value(h.addr, "cast_serve_shed_total"), 1.0);
    assert_eq!(metric_value(h.addr, "cast_serve_deadline_exceeded_total"), 1.0);

    // a generous budget survives the batching window
    let resp =
        raw_request(h.addr, "POST", "/predict", &[("X-Deadline-Ms", "5000")], body.as_bytes())
            .unwrap();
    assert_eq!(resp.status, 200);
    // malformed budgets are a client error, not a shed
    let resp =
        raw_request(h.addr, "POST", "/predict", &[("X-Deadline-Ms", "soon")], body.as_bytes())
            .unwrap();
    assert_eq!(resp.status, 400);

    h.stop();
}

#[test]
fn breaker_opens_after_consecutive_panics_and_readyz_degrades() {
    let _g = fault::test_guard();
    fault::clear();
    let mut h = Harness::tiny();
    let n = tiny_meta("cast_topk").seq_len;

    // five failures = the serve breaker threshold; each panic records one
    fault::set_plan("serve.infer.batch=panic:x5@1");
    for i in 0..5u64 {
        let resp =
            raw_request(h.addr, "POST", "/predict", &[], predict_body(i, n).as_bytes()).unwrap();
        assert_eq!(resp.status, 500, "failure {i} reaches the engine and panics");
    }
    // open breaker: shed before enqueue, retryable, and visible on both
    // /readyz (degraded, still 200) and /metrics (state gauge = 2)
    let resp =
        raw_request(h.addr, "POST", "/predict", &[], predict_body(9, n).as_bytes()).unwrap();
    assert_eq!(resp.status, 503);
    assert_eq!(resp.headers.get("retry-after").map(String::as_str), Some("1"));
    assert!(body_text(&resp).contains("circuit breaker"), "{}", body_text(&resp));

    let ready = raw_request(h.addr, "GET", "/readyz", &[], b"").unwrap();
    assert_eq!(ready.status, 200, "degraded must not cut in-flight traffic");
    let json = Json::parse(&body_text(&ready)).unwrap();
    assert_eq!(json.get("status"), Some(&Json::str("degraded")));
    assert_eq!(json.get("breakers_open"), Some(&Json::num(1.0)));

    let metrics = raw_request(h.addr, "GET", "/metrics", &[], b"").unwrap();
    let text = body_text(&metrics);
    let line = text
        .lines()
        .find(|l| l.starts_with("cast_serve_breaker_state{model="))
        .expect("breaker gauge exported");
    assert!(line.ends_with(" 2"), "{line}");

    fault::clear();
    h.stop();
}

// ---------------------------------------------------------------------------
// training under injected NaNs and torn checkpoint writes
// ---------------------------------------------------------------------------

#[test]
fn training_descends_despite_nan_steps_and_torn_saves_and_resumes_cleanly() {
    let _g = fault::test_guard();
    fault::clear();
    let dir = std::env::temp_dir().join("cast_chaos_train");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let ckpt = dir.join("model.ckpt");

    // ~1/4 of steps report a non-finite loss; the first checkpoint write
    // attempt is torn mid-file (the retry path must recover it)
    fault::set_plan("train.step.nan=flag:0.25;ckpt.save.torn=torn(60):x1@11");
    let cfg = TrainConfig {
        steps: 40,
        schedule: Schedule::Warmup { lr: 2e-3, warmup: 5 },
        seed: 1,
        eval_every: 0,
        eval_batches: 0,
        data_workers: 2,
        queue_depth: 2,
        log_every: 0,
        checkpoint: Some(ckpt.clone()),
        ckpt_every: 8,
    };
    let manifest = Manifest::synthetic(tiny_meta("cast_topk"));
    let engine = Engine::cpu().unwrap();
    let mut trainer = Trainer::new(engine.clone(), manifest, cfg, 1).unwrap();
    let report = trainer.run().unwrap();

    assert!(fault::fired("train.step.nan") > 0, "NaN plan must not pass vacuously");
    assert_eq!(trainer.nan_skips as u64, fault::fired("train.step.nan"));
    assert_eq!(fault::fired("ckpt.save.torn"), 1, "one save attempt was torn");
    fault::clear();

    // skipped steps stay out of history, applied steps still descend
    let steps = &report.history.steps;
    assert!(steps.len() >= 20, "most steps still apply ({} did)", steps.len());
    assert!(steps.iter().all(|r| r.loss.is_finite()));
    let first5 = steps[..5].iter().map(|r| r.loss).sum::<f32>() / 5.0;
    let last5 = steps[steps.len() - 5..].iter().map(|r| r.loss).sum::<f32>() / 5.0;
    assert!(last5 < first5, "loss should decrease: first5 {first5:.4} -> last5 {last5:.4}");
    // never write NaN into params or moments
    for group in [&trainer.state.params, &trainer.state.m, &trainer.state.v] {
        for t in group.iter() {
            if let Ok(v) = t.as_f32() {
                assert!(v.iter().all(|x| x.is_finite()), "non-finite value in trainer state");
            }
        }
    }

    // the torn first attempt never reached <ckpt>: both rotation slots
    // on disk are digest-valid and no tmp file is left behind
    let (primary, names) = checkpoint::load(&ckpt).unwrap();
    let (prev, _) = checkpoint::load(&checkpoint::prev_path(&ckpt)).unwrap();
    assert!(!dir.join("model.ckpt.tmp").exists(), "tmp file must be renamed away");
    assert!(primary.step > prev.step, "rotation keeps an older generation in .prev");

    // corrupt the primary: auto-resume must fall back to .prev
    // bit-identically instead of loading a corrupt file
    let mut bytes = std::fs::read(&ckpt).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0x10;
    std::fs::write(&ckpt, &bytes).unwrap();

    let (resumed, rnames, from) = checkpoint::load_auto(&ckpt).unwrap();
    assert_eq!(from, checkpoint::prev_path(&ckpt));
    assert_eq!(rnames, names);
    assert_eq!(resumed.step, prev.step);
    for (a, b) in resumed.params.iter().zip(&prev.params) {
        assert_eq!(a.as_f32().unwrap(), b.as_f32().unwrap());
    }
    for (a, b) in resumed.m.iter().zip(&prev.m) {
        assert_eq!(a.as_f32().unwrap(), b.as_f32().unwrap());
    }

    // and the trainer-level entry point takes the same fallback
    let manifest = Manifest::synthetic(tiny_meta("cast_topk"));
    let cfg = TrainConfig {
        steps: 1,
        schedule: Schedule::Warmup { lr: 2e-3, warmup: 5 },
        seed: 1,
        eval_every: 0,
        eval_batches: 0,
        data_workers: 2,
        queue_depth: 2,
        log_every: 0,
        checkpoint: None,
        ckpt_every: 0,
    };
    let mut trainer2 = Trainer::new(engine, manifest, cfg, 1).unwrap();
    trainer2.load_checkpoint(&ckpt).unwrap();
    assert_eq!(trainer2.state.step, prev.step);
}
