//! Incremental-decode subsystem proof: greedy generation through the
//! `decode` entry's cluster-state cache is **bit-identical** to
//! re-running the full causal forward over the whole history at every
//! step, across the `CAST_NUM_THREADS ∈ {1,4}` × SIMD {forced-on,
//! forced-off} matrix; chunked prefill reaches exactly the same cache
//! (and the same continuation) as monolithic prefill; and the entry's
//! support gating + session sanity checks hold.
//!
//! The SIMD mode and thread count are process-global, so the matrix test
//! serializes on one lock — this binary owns its process (each
//! integration test file is a separate binary), so no other suite can
//! observe the flips.

use std::sync::Arc;

use cast::model::ModelState;
use cast::runtime::native::decode::{self, DecodeState};
use cast::runtime::native::spec::tiny_meta;
use cast::runtime::{DecodeSession, Engine, Executable, HostTensor, Manifest, ModelMeta};
use cast::util::parallel;
use cast::util::simd;

static GLOBAL_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

fn with_settings<T>(lanes: Option<bool>, threads: usize, f: impl FnOnce() -> T) -> T {
    struct Restore;
    impl Drop for Restore {
        fn drop(&mut self) {
            simd::set_forced(None);
            parallel::set_threads(0);
        }
    }
    let _guard = GLOBAL_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let _restore = Restore;
    simd::set_forced(lanes);
    parallel::set_threads(threads);
    f()
}

/// The decode model under test: tiny causal CAST (κ=16, Nc=4 — total
/// cluster capacity 64, small enough that long generations overflow it
/// and exercise the unplaced-token path).
fn causal_meta(variant: &str) -> ModelMeta {
    let mut meta = tiny_meta(variant);
    meta.causal = true;
    meta
}

fn setup(variant: &str) -> (Manifest, Vec<HostTensor>, Arc<dyn Executable>) {
    let manifest = Manifest::synthetic(causal_meta(variant));
    let engine = Engine::cpu().unwrap();
    let state = ModelState::init(&engine, &manifest, 11).unwrap();
    let exe = engine.load(&manifest, "decode").unwrap();
    (manifest, state.params, exe)
}

/// Greedy generation through the decode seam, checking every step's
/// logits bitwise against the full-forward recompute reference.  Returns
/// the generated token ids.
fn generate_checked(
    manifest: &Manifest,
    params: &[&HostTensor],
    exe: &Arc<dyn Executable>,
    prompt: &[i32],
    steps: usize,
) -> Vec<i32> {
    let mut session = exe.decode_begin().unwrap();
    exe.decode_prefill(params, session.as_mut(), &prompt[..prompt.len() - 1]).unwrap();
    let mut history: Vec<i32> = prompt.to_vec();
    let mut next = *prompt.last().unwrap();
    let mut out = Vec::new();
    for step in 0..steps {
        let logits = exe.decode_step(params, session.as_mut(), next).unwrap();
        assert_eq!(logits.len(), manifest.meta.vocab);
        let reference = decode::full_logits(manifest, params, &history).unwrap();
        assert_eq!(
            logits, reference,
            "step {step} (history {}): incremental logits diverge from full forward",
            history.len()
        );
        assert_eq!(session.len(), history.len());
        next = decode::argmax(&logits) as i32;
        history.push(next);
        out.push(next);
    }
    out
}

#[test]
fn incremental_decode_matches_full_forward_bitwise_across_modes() {
    let (manifest, params, exe) = setup("cast_sa");
    let refs: Vec<&HostTensor> = params.iter().collect();
    // prompt of 3 < κ=16: generation crosses fallback → cache-build →
    // incremental; 67 steps push the history past the 64-slot cluster
    // capacity into the unplaced-token regime
    let prompt = [7i32, 3, 250];
    let mut sequences = Vec::new();
    for (lanes, threads) in [(Some(false), 1), (Some(false), 4), (Some(true), 1), (Some(true), 4)] {
        let toks = with_settings(lanes, threads, || {
            generate_checked(&manifest, &refs, &exe, &prompt, 67)
        });
        sequences.push((lanes, threads, toks));
    }
    let (_, _, first) = &sequences[0];
    for (lanes, threads, toks) in &sequences {
        assert_eq!(
            toks, first,
            "greedy sequence differs under simd={lanes:?} threads={threads}"
        );
    }
}

#[test]
fn chunked_prefill_matches_monolithic_cache_and_continuation() {
    let (manifest, params, exe) = setup("cast_sa");
    let refs: Vec<&HostTensor> = params.iter().collect();
    let prompt: Vec<i32> = (0..33).map(|i| (i * 37 + 5) % 256).collect();

    // chunked, through the backend seam (uneven chunks straddling κ=16)
    let mut chunked = exe.decode_begin().unwrap();
    for chunk in [&prompt[..7], &prompt[7..20], &prompt[20..]] {
        exe.decode_prefill(&refs, chunked.as_mut(), chunk).unwrap();
    }
    let chunked_st =
        chunked.as_any().downcast_mut::<DecodeState>().expect("native decode session");

    // monolithic reference: one full forward over the whole prompt
    let mut mono_st = DecodeState::new(&manifest);
    decode::prefill(&manifest, &refs, &mut mono_st, &prompt, true).unwrap();

    assert!(chunked_st.incremental() && mono_st.incremental());
    assert_eq!(chunked_st.history(), mono_st.history());
    assert_eq!(
        chunked_st.cache_digest(),
        mono_st.cache_digest(),
        "chunked prefill must rebuild the exact monolithic cluster state"
    );

    // and the continuations agree bitwise, step by step
    let mut next = 42i32;
    for step in 0..8 {
        let a = decode::step(&manifest, &refs, chunked_st, next).unwrap();
        let b = decode::step(&manifest, &refs, &mut mono_st, next).unwrap();
        assert_eq!(a, b, "continuation step {step} diverges after chunked prefill");
        next = decode::argmax(&a) as i32;
    }
}

#[test]
fn decode_entry_support_gating() {
    let engine = Engine::cpu().unwrap();
    // causal CAST (either clustering flavor): supported
    for variant in ["cast_sa", "cast_topk"] {
        let man = Manifest::synthetic(causal_meta(variant));
        assert!(engine.load(&man, "decode").is_ok(), "{variant} causal should decode");
    }
    // non-causal CAST: no frozen assignment to cache
    let man = Manifest::synthetic(tiny_meta("cast_sa"));
    assert!(engine.load(&man, "decode").is_err(), "non-causal must not decode");
    // non-CAST: no cluster state at all
    let man = Manifest::synthetic(causal_meta("vanilla"));
    assert!(engine.load(&man, "decode").is_err(), "vanilla must not decode");
    // dual towers pool per tower — no single causal stream
    let mut meta = causal_meta("cast_sa");
    meta.dual = true;
    assert!(engine.load(&Manifest::synthetic(meta), "decode").is_err(), "dual must not decode");
}

#[test]
fn decode_seam_rejects_misuse() {
    let (manifest, params, exe) = setup("cast_sa");
    let refs: Vec<&HostTensor> = params.iter().collect();

    // the stateful entry cannot be driven through run_refs
    assert!(exe.run_refs(&refs).is_err());

    // a non-decode executable has no sessions
    let engine = Engine::cpu().unwrap();
    let predict = engine.load(&manifest, "predict").unwrap();
    assert!(predict.decode_begin().is_err());

    // a session opened for one model is rejected by another
    let other = Manifest::synthetic(causal_meta("cast_topk"));
    let other_state = ModelState::init(&engine, &other, 11).unwrap();
    let other_exe = engine.load(&other, "decode").unwrap();
    let other_refs: Vec<&HostTensor> = other_state.params.iter().collect();
    let mut session = exe.decode_begin().unwrap();
    assert!(session.is_empty());
    assert!(other_exe.decode_step(&other_refs, session.as_mut(), 1).is_err());
}
