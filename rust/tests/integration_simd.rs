//! SIMD kernel subsystem proof: every `util::simd` lane kernel against
//! its sequential scalar reference (randomized shapes, ragged < 8
//! remainders), the forced-dispatch escape hatch, a full-model
//! forward+backward determinism cross-matrix over
//! `SIMD × CAST_NUM_THREADS ∈ {1,4}`, the SIMD-vs-scalar grad-check
//! divergence report, and the golden-fingerprint regression gate.
//!
//! Exactness contract under test (see `util::simd` module docs):
//! elementwise kernels, `max8`, and the matmul microkernel are
//! bit-identical across modes; the reductions (`dot8`/`sum8`/
//! `sumsq_diff8`) may differ only by reassociation, bounded here by
//! 1e-5 relative to the condition scale `Σ|terms|`.
//!
//! The SIMD mode and thread count are process-global, so every test that
//! touches either serializes on one lock — this binary owns its process
//! (each integration test file is a separate binary), so no other suite
//! can observe the flips.

mod common;

use cast::runtime::native::grad;
use cast::runtime::native::model::{run_init, run_predict};
use cast::runtime::native::spec::tiny_meta;
use cast::runtime::tensor::HostTensor;
use cast::runtime::Manifest;
use cast::util::json::Json;
use cast::util::parallel;
use cast::util::prop::{grad_check_modes, GradCheckCfg};
use cast::util::rng::Rng;
use cast::util::simd;

/// Serializes every test that flips the process-global SIMD mode or
/// thread count (results *do* depend on the SIMD mode, within tolerance,
/// so unsynchronized flips could turn a determinism check flaky).
static GLOBAL_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

fn with_settings<T>(lanes: Option<bool>, threads: usize, f: impl FnOnce() -> T) -> T {
    /// Clears both overrides even when `f` panics (an assertion failure
    /// must not leak a forced mode into the tests that run afterwards).
    struct Restore;
    impl Drop for Restore {
        fn drop(&mut self) {
            simd::set_forced(None);
            parallel::set_threads(0);
        }
    }
    let _guard = GLOBAL_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let _restore = Restore;
    simd::set_forced(lanes);
    parallel::set_threads(threads);
    f()
}

fn randn(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.gaussian() as f32).collect()
}

/// Ragged lengths straddling the 8-lane width, plus layer-sized rows.
const LENS: [usize; 12] = [0, 1, 2, 3, 5, 7, 8, 9, 15, 16, 17, 129];

// ---------------------------------------------------------------------------
// kernel-level parity: lanes vs scalar reference
// ---------------------------------------------------------------------------

#[test]
fn reduction_kernels_match_scalar_reference_within_tolerance() {
    let mut rng = Rng::new(101);
    for trial in 0..20 {
        for &n in &LENS {
            let a = randn(&mut rng, n);
            let b = randn(&mut rng, n);
            // condition scale: reassociation error is relative to the sum
            // of |terms|, not to the (possibly cancelled) result
            let dot_scale: f32 = a.iter().zip(&b).map(|(x, y)| (x * y).abs()).sum::<f32>() + 1.0;
            let sum_scale: f32 = a.iter().map(|x| x.abs()).sum::<f32>() + 1.0;
            let d = (simd::dot8_lanes(&a, &b) - simd::dot8_scalar(&a, &b)).abs();
            assert!(d <= 1e-5 * dot_scale, "dot8 n={n} trial={trial}: {d} vs scale {dot_scale}");
            let s = (simd::sum8_lanes(&a) - simd::sum8_scalar(&a)).abs();
            assert!(s <= 1e-5 * sum_scale, "sum8 n={n} trial={trial}: {s}");
            let mu = 0.3f32;
            let q = (simd::sumsq_diff8_lanes(&a, mu) - simd::sumsq_diff8_scalar(&a, mu)).abs();
            let q_scale: f32 = a.iter().map(|x| (x - mu) * (x - mu)).sum::<f32>() + 1.0;
            assert!(q <= 1e-5 * q_scale, "sumsq_diff8 n={n} trial={trial}: {q}");
        }
    }
}

#[test]
fn order_preserving_kernels_are_bit_exact_across_modes() {
    let mut rng = Rng::new(202);
    for &n in &LENS {
        let x = randn(&mut rng, n);
        let base = randn(&mut rng, n);
        let g = randn(&mut rng, n);
        let bv = randn(&mut rng, n);
        let a = -1.37f32;

        assert_eq!(simd::max8_lanes(&x), simd::max8_scalar(&x), "max8 n={n}");

        let mut y1 = base.clone();
        let mut y2 = base.clone();
        simd::axpy8_lanes(&mut y1, a, &x);
        simd::axpy8_scalar(&mut y2, a, &x);
        assert_eq!(y1, y2, "axpy8 n={n}");

        let mut y1 = base.clone();
        let mut y2 = base.clone();
        simd::add8_lanes(&mut y1, &x);
        simd::add8_scalar(&mut y2, &x);
        assert_eq!(y1, y2, "add8 n={n}");

        let mut y1 = base.clone();
        let mut y2 = base.clone();
        simd::scale8_lanes(&mut y1, a);
        simd::scale8_scalar(&mut y2, a);
        assert_eq!(y1, y2, "scale8 n={n}");

        let mut y1 = base.clone();
        let mut y2 = base.clone();
        simd::scale_add8_lanes(&mut y1, a, 0.21);
        simd::scale_add8_scalar(&mut y2, a, 0.21);
        assert_eq!(y1, y2, "scale_add8 n={n}");

        let mut y1 = base.clone();
        let mut y2 = base;
        simd::norm_affine8_lanes(&mut y1, &g, &bv, 0.4, 2.3);
        simd::norm_affine8_scalar(&mut y2, &g, &bv, 0.4, 2.3);
        assert_eq!(y1, y2, "norm_affine8 n={n}");
    }
}

#[test]
fn matmul_microkernel_is_bit_exact_across_modes() {
    // the per-element accumulation order (ascending input dim) is the
    // same in both dispatch modes, so the full matmul must agree exactly
    let mut rng = Rng::new(303);
    for &(rows, d_in, d_out) in &[
        (1usize, 1usize, 1usize),
        (2, 3, 1),
        (7, 5, 3),
        (8, 8, 8),
        (9, 16, 7),
        (23, 13, 17),
        (64, 16, 32),
    ] {
        let x = randn(&mut rng, rows * d_in);
        let w = randn(&mut rng, d_in * d_out);
        let b = randn(&mut rng, d_out);
        let lanes = with_settings(Some(true), 1, || {
            let mut y = vec![0.0f32; rows * d_out];
            simd::matmul_rows8(&x, &w, &b, rows, d_in, d_out, &mut y);
            y
        });
        let scalar = with_settings(Some(false), 1, || {
            let mut y = vec![0.0f32; rows * d_out];
            simd::matmul_rows8(&x, &w, &b, rows, d_in, d_out, &mut y);
            y
        });
        assert_eq!(lanes, scalar, "matmul ({rows},{d_in},{d_out})");
    }
}

#[test]
fn forced_dispatch_routes_to_the_requested_variant() {
    let mut rng = Rng::new(404);
    let a = randn(&mut rng, 100);
    let b = randn(&mut rng, 100);
    let via_scalar = with_settings(Some(false), 1, || simd::dot8(&a, &b));
    let via_lanes = with_settings(Some(true), 1, || simd::dot8(&a, &b));
    assert_eq!(via_scalar, simd::dot8_scalar(&a, &b), "forced scalar must hit the reference");
    assert_eq!(via_lanes, simd::dot8_lanes(&a, &b), "forced lanes must hit the lane kernel");
}

// ---------------------------------------------------------------------------
// full-model determinism cross-matrix: SIMD × CAST_NUM_THREADS
// ---------------------------------------------------------------------------

/// Forward logits + loss + full-parameter gradients of the tiny config
/// under explicit SIMD/thread settings.
fn model_pass(variant: &str, lanes: bool, threads: usize) -> (Vec<f32>, f32, Vec<Vec<f32>>) {
    let man = Manifest::synthetic(tiny_meta(variant));
    with_settings(Some(lanes), threads, || {
        let seed = HostTensor::u32(vec![], vec![11]);
        let params = run_init(&man, &[&seed]).unwrap();
        let n: usize = man.tokens_shape.iter().product();
        let tokens = HostTensor::s32(
            man.tokens_shape.clone(),
            (0..n).map(|i| ((i * 13 + 5) % 97) as i32).collect(),
        );
        let mut inputs: Vec<&HostTensor> = params.iter().collect();
        inputs.push(&tokens);
        let logits = run_predict(&man, &inputs).unwrap()[0].as_f32().unwrap().to_vec();
        let refs: Vec<&HostTensor> = params.iter().collect();
        let mut ws = grad::GradScratch::new();
        let out = grad::loss_and_grads(&man, &refs, &tokens, &[0, 1], &mut ws).unwrap();
        (logits, out.loss, out.grads)
    })
}

fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}

#[test]
fn model_determinism_cross_matrix_simd_by_threads() {
    for variant in cast::runtime::native::VARIANTS {
        let mut per_mode = Vec::new();
        for lanes in [true, false] {
            // within one SIMD mode, the thread count must not move a bit
            let (lg1, loss1, g1) = model_pass(variant, lanes, 1);
            let (lg4, loss4, g4) = model_pass(variant, lanes, 4);
            assert_eq!(lg1, lg4, "{variant} lanes={lanes}: logits vary with threads");
            assert_eq!(loss1, loss4, "{variant} lanes={lanes}: loss varies with threads");
            for (i, (a, b)) in g1.iter().zip(&g4).enumerate() {
                assert_eq!(a, b, "{variant} lanes={lanes}: grad tensor {i} varies with threads");
            }
            per_mode.push((lg1, loss1, g1));
        }
        // across SIMD modes, only the documented reassociation drift
        let (lg_s, loss_s, g_s) = &per_mode[0];
        let (lg_n, loss_n, g_n) = &per_mode[1];
        assert!(
            max_abs_diff(lg_s, lg_n) <= 1e-4,
            "{variant}: SIMD-vs-scalar logits diverged by {}",
            max_abs_diff(lg_s, lg_n)
        );
        assert!(
            (loss_s - loss_n).abs() <= 1e-4,
            "{variant}: SIMD-vs-scalar loss diverged: {loss_s} vs {loss_n}"
        );
        for (i, (a, b)) in g_s.iter().zip(g_n).enumerate() {
            let scale = a.iter().fold(0.0f32, |m, v| m.max(v.abs())).max(1.0);
            let diff = max_abs_diff(a, b);
            assert!(
                diff <= 1e-4 * scale,
                "{variant}: grad tensor {i} SIMD-vs-scalar diverged by {diff} (scale {scale})"
            );
        }
    }
}

#[test]
fn repeated_simd_runs_are_bit_for_bit_deterministic() {
    let (lg_a, loss_a, g_a) = model_pass("cast_topk", true, 4);
    let (lg_b, loss_b, g_b) = model_pass("cast_topk", true, 4);
    assert_eq!(lg_a, lg_b);
    assert_eq!(loss_a, loss_b);
    for (a, b) in g_a.iter().zip(&g_b) {
        assert_eq!(a, b);
    }
}

// ---------------------------------------------------------------------------
// grad-check under both modes + per-block backward divergence report
// ---------------------------------------------------------------------------

#[test]
fn central_difference_passes_in_both_modes_with_bounded_divergence() {
    let man = Manifest::synthetic(common::golden_meta("topk", "softmax"));
    let params = {
        let _guard = GLOBAL_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        run_init(&man, &[&HostTensor::u32(vec![], vec![5])]).unwrap()
    };
    let mut theta = Vec::new();
    for t in &params {
        theta.extend_from_slice(t.as_f32().unwrap());
    }
    let blocks: Vec<(String, usize)> = man
        .params
        .iter()
        .map(|s| (s.name.clone(), s.shape.iter().product()))
        .collect();
    let n: usize = man.tokens_shape.iter().product();
    let tokens = HostTensor::s32(
        man.tokens_shape.clone(),
        (0..n).map(|i| ((i * 7 + 3) % 32) as i32).collect(),
    );
    let labels = vec![0i32, 1];

    let rebuild = |t: &[f32]| -> Vec<HostTensor> {
        let mut out = Vec::with_capacity(man.params.len());
        let mut off = 0usize;
        for spec in &man.params {
            let l: usize = spec.shape.iter().product();
            out.push(HostTensor::f32(spec.shape.clone(), t[off..off + l].to_vec()));
            off += l;
        }
        out
    };
    let run = |t: &[f32]| -> grad::LossAndGrads {
        let tensors = rebuild(t);
        let refs: Vec<&HostTensor> = tensors.iter().collect();
        let mut ws = grad::GradScratch::new();
        grad::loss_and_grads(&man, &refs, &tokens, &labels, &mut ws).unwrap()
    };

    // grad_check_modes flips the global SIMD mode — hold the lock
    let _guard = GLOBAL_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let cfg = GradCheckCfg { eps: 5e-3, rel_tol: 1e-2, abs_tol: 1e-4, max_per_block: 2 };
    let report = grad_check_modes(
        &cfg,
        &theta,
        &blocks,
        || run(&theta).grads.concat(),
        |t| {
            let o = run(t);
            (o.loss, o.fingerprint)
        },
    );
    for d in &report {
        eprintln!(
            "simd-vs-scalar backward divergence {:<24} max_abs {:.3e} max_rel {:.3e}",
            d.name, d.max_abs, d.max_rel
        );
        assert!(
            d.max_abs <= 1e-4,
            "block {:?}: backward passes diverged across SIMD modes by {}",
            d.name,
            d.max_abs
        );
    }
    assert_eq!(report.len(), blocks.len());
}

// ---------------------------------------------------------------------------
// golden fingerprints
// ---------------------------------------------------------------------------

#[test]
fn golden_fingerprints_match_committed_baseline() {
    // ambient mode, default threads: the tolerance absorbs the
    // documented SIMD-vs-scalar drift, so one baseline serves both CI
    // legs; the lock keeps concurrent mode flips out of the computation
    let _guard = GLOBAL_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let mut computed: Vec<(String, common::Fingerprint)> = Vec::new();
    for variant in common::GOLDEN_VARIANTS {
        for attn in ["softmax", "laplace"] {
            let fp = common::compute_fingerprint(variant, attn);
            computed.push((format!("{variant}_{attn}"), fp));
        }
    }
    let path = common::goldens_path();
    if !path.exists() {
        let pairs: Vec<(&str, Json)> = computed
            .iter()
            .map(|(k, fp)| (k.as_str(), common::fingerprint_json(fp)))
            .collect();
        std::fs::create_dir_all(path.parent().unwrap()).unwrap();
        std::fs::write(&path, Json::obj(pairs).to_string() + "\n").unwrap();
        eprintln!(
            "golden baseline was missing — wrote {} entries to {} (commit this file so \
             future kernel rewrites diff against it)",
            computed.len(),
            path.display()
        );
        return;
    }
    let base = Json::parse(&std::fs::read_to_string(&path).unwrap())
        .unwrap_or_else(|e| panic!("unparseable golden baseline {}: {e}", path.display()));
    let close = |a: f64, b: f64| (a - b).abs() <= 1e-4 + 1e-3 * a.abs().max(b.abs());
    for (key, fp) in &computed {
        let entry = base.get(key).unwrap_or_else(|| {
            panic!(
                "golden baseline has no entry {key:?} — delete {} to regenerate",
                path.display()
            )
        });
        let loss = entry.get("loss").and_then(Json::as_f64).unwrap();
        let gnorm = entry.get("grad_norm").and_then(Json::as_f64).unwrap();
        assert!(
            close(loss, fp.loss as f64),
            "{key}: loss drifted from baseline: {loss} -> {}",
            fp.loss
        );
        assert!(
            close(gnorm, fp.grad_norm),
            "{key}: gradient norm drifted from baseline: {gnorm} -> {}",
            fp.grad_norm
        );
        let logits = entry.get("logits").and_then(Json::as_arr).unwrap();
        assert_eq!(logits.len(), fp.logits.len(), "{key}: logit arity changed");
        for (i, (lv, &cv)) in logits.iter().zip(&fp.logits).enumerate() {
            let lv = lv.as_f64().unwrap();
            assert!(
                close(lv, cv as f64),
                "{key}: logit {i} drifted from baseline: {lv} -> {cv}"
            );
        }
    }
}
