//! # CAST — Clustering self-Attention using Surrogate Tokens
//!
//! A three-layer reproduction of *CAST: Clustering self-Attention using
//! Surrogate Tokens for efficient transformers* (van Engelenhoven,
//! Strisciuglio & Talavera, 2024):
//!
//! * **L1** — the intra-cluster attention + cluster-summary hot spot as a
//!   Pallas kernel (`python/compile/kernels/`), AOT-lowered.
//! * **L2** — the full CAST encoder + baselines in JAX
//!   (`python/compile/`), lowered once to HLO-text artifacts.
//! * **L3** — this crate: the coordinator that generates LRA workloads,
//!   drives training/inference through PJRT, runs every efficiency
//!   benchmark in the paper, and renders the cluster visualizations.
//!
//! Python never runs at run time; artifacts are produced by
//! `make artifacts` and the `cast` binary is self-contained after that.
//!
//! See DESIGN.md for the system inventory and EXPERIMENTS.md for the
//! paper-vs-measured results.

pub mod analysis;
pub mod bench;
pub mod coordinator;
pub mod data;
pub mod model;
pub mod runtime;
pub mod train;
pub mod util;
