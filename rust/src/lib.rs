//! # CAST — Clustering self-Attention using Surrogate Tokens
//!
//! A three-layer reproduction of *CAST: Clustering self-Attention using
//! Surrogate Tokens for efficient transformers* (van Engelenhoven,
//! Strisciuglio & Talavera, 2024):
//!
//! * **L1** — the intra-cluster attention + cluster-summary hot spot as a
//!   Pallas kernel (`python/compile/kernels/`), AOT-lowered.
//! * **L2** — the full CAST encoder + baselines in JAX
//!   (`python/compile/`), lowered once to HLO-text artifacts.
//! * **L3** — this crate: the coordinator that generates LRA workloads,
//!   drives training/inference through a pluggable [`runtime::Backend`],
//!   runs every efficiency benchmark in the paper, and renders the
//!   cluster visualizations.
//!
//! Two backends sit behind [`runtime::Engine`]:
//!
//! * **native** (default) — a pure-Rust f32 engine implementing the CAST
//!   forward pass and the `init`/`predict`/`predict_ag`/`train_step`
//!   program contracts (`runtime::native`).  Needs no artifacts, no
//!   Python, and no external crates: `cargo build && cargo test` work on
//!   a fresh checkout.
//! * **pjrt** (`xla` cargo feature) — executes the AOT HLO artifacts
//!   produced by `make artifacts` (python/compile/aot.py) through PJRT.
//!
//! See DESIGN.md (repo root) for the layer inventory, the backend seam,
//! and the offline-substitution rationale.

pub mod analysis;
pub mod bench;
pub mod coordinator;
pub mod data;
pub mod model;
pub mod runtime;
pub mod serve;
pub mod train;
pub mod util;
