//! Artifact manifests: the contract between model *programs* and the rust
//! runtime.  One directory per model config, containing `manifest.json`
//! describing the flattened parameter list and batch shapes, plus — for
//! the PJRT backend — HLO text files produced by `python/compile/aot.py`
//! (`make artifacts`).  The native backend needs only the manifest (and
//! can synthesize one in memory with [`Manifest::synthetic`], so it runs
//! with zero files on disk).

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

use super::native::variants::AttnVariant;
use super::tensor::DType;

#[derive(Clone, Debug)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

/// The subset of ModelConfig the runtime needs (full config kept as Json
/// for reporting).  The architecture fields beyond the original set
/// (`norm`, `prenorm`, `attn_fn`, `window`, `causal`) default to the
/// Table-4 text-task values when a manifest predates them.
#[derive(Clone, Debug)]
pub struct ModelMeta {
    pub task: String,
    pub variant: String,
    pub seq_len: usize,
    pub batch: usize,
    pub n_c: usize,
    pub kappa: usize,
    pub depth: usize,
    pub heads: usize,
    pub d: usize,
    pub d_ff: usize,
    pub d_emb: usize,
    pub vocab: usize,
    pub n_classes: usize,
    pub dual: bool,
    pub norm: String,
    pub prenorm: bool,
    pub attn_fn: String,
    pub window: usize,
    pub causal: bool,
}

impl ModelMeta {
    pub fn d_h(&self) -> usize {
        self.d / self.heads
    }

    /// Registry entry for this config's variant, when the name is known.
    /// The predicates below fall back to conservative defaults for
    /// unknown names so metadata parsing stays infallible — the engine's
    /// `load` is where unknown variants are rejected with a full list.
    fn attn_variant(&self) -> Option<AttnVariant> {
        AttnVariant::parse(&self.variant).ok()
    }

    pub fn is_cast(&self) -> bool {
        self.attn_variant().is_some_and(|v| v.is_cast())
    }

    /// The clustering mechanism G (paper §3.2 / §5.5).
    pub fn clustering(&self) -> &'static str {
        self.attn_variant().map_or("topk", |v| v.clustering(self.causal))
    }

    /// Whether the `predict_ag` entry point exists for this config
    /// (cluster affinities need a `supports_ag` variant and a non-dual
    /// model).
    pub fn has_ag(&self) -> bool {
        self.attn_variant().is_some_and(|v| v.supports_ag(self.dual))
    }

    /// Token batch shape: `(B, N)`, or `(B, 2, N)` for dual-encoder tasks.
    pub fn tokens_shape(&self) -> Vec<usize> {
        if self.dual {
            vec![self.batch, 2, self.seq_len]
        } else {
            vec![self.batch, self.seq_len]
        }
    }

    /// Stable artifact key, mirroring python `ModelConfig.key()`.
    pub fn key(&self) -> String {
        let mut parts = vec![
            self.task.clone(),
            self.variant.clone(),
            format!("n{}", self.seq_len),
            format!("b{}", self.batch),
        ];
        let v = self.attn_variant();
        if v.is_some_and(|v| v.key_has_clusters()) {
            parts.push(format!("c{}", self.n_c));
            parts.push(format!("k{}", self.kappa));
        }
        if v.is_some_and(|v| v.key_has_window()) {
            parts.push(format!("w{}", self.window));
        }
        if self.causal {
            parts.push("causal".to_string());
        }
        parts.join("_")
    }

    fn to_config_json(&self) -> Json {
        Json::obj(vec![
            ("task", Json::str(&self.task)),
            ("variant", Json::str(&self.variant)),
            ("seq_len", Json::num(self.seq_len as f64)),
            ("batch", Json::num(self.batch as f64)),
            ("n_c", Json::num(self.n_c as f64)),
            ("kappa", Json::num(self.kappa as f64)),
            ("depth", Json::num(self.depth as f64)),
            ("h", Json::num(self.heads as f64)),
            ("d", Json::num(self.d as f64)),
            ("d_ff", Json::num(self.d_ff as f64)),
            ("d_emb", Json::num(self.d_emb as f64)),
            ("vocab", Json::num(self.vocab as f64)),
            ("n_classes", Json::num(self.n_classes as f64)),
            ("dual", Json::Bool(self.dual)),
            ("norm", Json::str(&self.norm)),
            ("prenorm", Json::Bool(self.prenorm)),
            ("attn_fn", Json::str(&self.attn_fn)),
            ("window", Json::num(self.window as f64)),
            ("causal", Json::Bool(self.causal)),
        ])
    }
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub key: String,
    pub params: Vec<ParamSpec>,
    pub tokens_shape: Vec<usize>,
    pub labels_shape: Vec<usize>,
    pub meta: ModelMeta,
    pub files: Vec<(String, String)>,
    pub raw: Json,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let man_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&man_path)
            .with_context(|| format!("reading {man_path:?} (run `make artifacts` or `cast gen`?)"))?;
        let raw = Json::parse(&text).with_context(|| format!("parsing {man_path:?}"))?;

        let key = raw
            .get("key")
            .and_then(Json::as_str)
            .context("manifest missing 'key'")?
            .to_string();

        let mut params = Vec::new();
        for p in raw.get("params").and_then(Json::as_arr).context("manifest missing 'params'")? {
            params.push(ParamSpec {
                name: p.get("name").and_then(Json::as_str).context("param name")?.to_string(),
                shape: shape_of(p.get("shape").context("param shape")?)?,
                dtype: DType::parse(p.get("dtype").and_then(Json::as_str).context("param dtype")?)?,
            });
        }
        if params.is_empty() {
            bail!("manifest has no parameters");
        }

        let cfg = raw.get("config").context("manifest missing 'config'")?;
        let get_usize = |k: &str| -> Result<usize> {
            cfg.get(k).and_then(Json::as_usize).with_context(|| format!("config.{k}"))
        };
        let meta = ModelMeta {
            task: cfg.get("task").and_then(Json::as_str).context("config.task")?.to_string(),
            variant: cfg.get("variant").and_then(Json::as_str).context("config.variant")?.to_string(),
            seq_len: get_usize("seq_len")?,
            batch: get_usize("batch")?,
            n_c: get_usize("n_c")?,
            kappa: get_usize("kappa")?,
            depth: get_usize("depth")?,
            heads: get_usize("h")?,
            d: get_usize("d")?,
            d_ff: get_usize("d_ff")?,
            d_emb: get_usize("d_emb")?,
            vocab: get_usize("vocab")?,
            n_classes: get_usize("n_classes")?,
            dual: cfg.get("dual").and_then(Json::as_bool).unwrap_or(false),
            norm: cfg
                .get("norm")
                .and_then(Json::as_str)
                .unwrap_or("layer")
                .to_string(),
            prenorm: cfg.get("prenorm").and_then(Json::as_bool).unwrap_or(false),
            attn_fn: cfg
                .get("attn_fn")
                .and_then(Json::as_str)
                .unwrap_or("softmax")
                .to_string(),
            window: cfg.get("window").and_then(Json::as_usize).unwrap_or(128),
            causal: cfg.get("causal").and_then(Json::as_bool).unwrap_or(false),
        };

        let tokens_shape = shape_of(raw.path("tokens.shape").context("tokens.shape")?)?;
        let labels_shape = shape_of(raw.path("labels.shape").context("labels.shape")?)?;

        let mut files = Vec::new();
        if let Some(obj) = raw.get("files").and_then(Json::as_obj) {
            for (k, v) in obj {
                if let Some(f) = v.as_str() {
                    files.push((k.clone(), f.to_string()));
                }
            }
        }

        Ok(Manifest {
            dir: dir.to_path_buf(),
            key,
            params,
            tokens_shape,
            labels_shape,
            meta,
            files,
            raw,
        })
    }

    /// Build a manifest in memory from a model config alone — the native
    /// backend's zero-artifact entry point.  The parameter list replicates
    /// the flat ordering the AOT pipeline records (jax tree_flatten over
    /// sorted dict keys; see `runtime::native::spec`).
    pub fn synthetic(meta: ModelMeta) -> Manifest {
        let params = super::native::spec::param_specs(&meta);
        Manifest {
            dir: PathBuf::new(),
            key: meta.key(),
            params,
            tokens_shape: meta.tokens_shape(),
            labels_shape: vec![meta.batch],
            meta,
            files: Vec::new(),
            raw: Json::Null,
        }
    }

    /// Write `manifest.json` into `root/<key>/` so the standard discovery
    /// path (`Manifest::load`, `artifacts::discover`, the bench harness)
    /// picks this config up — no HLO files required for the native
    /// backend.  Returns the artifact directory.
    pub fn save(&self, root: &Path) -> Result<PathBuf> {
        let dir = root.join(&self.key);
        std::fs::create_dir_all(&dir).with_context(|| format!("creating {dir:?}"))?;
        let params: Vec<Json> = self
            .params
            .iter()
            .map(|p| {
                Json::obj(vec![
                    ("name", Json::str(&p.name)),
                    ("shape", Json::arr_usize(&p.shape)),
                    ("dtype", Json::str(p.dtype.name())),
                ])
            })
            .collect();
        let man = Json::obj(vec![
            ("key", Json::str(&self.key)),
            ("n_params", Json::num(self.params.len() as f64)),
            ("params", Json::Arr(params)),
            ("config", self.meta.to_config_json()),
            (
                "tokens",
                Json::obj(vec![
                    ("shape", Json::arr_usize(&self.tokens_shape)),
                    ("dtype", Json::str("s32")),
                ]),
            ),
            (
                "labels",
                Json::obj(vec![
                    ("shape", Json::arr_usize(&self.labels_shape)),
                    ("dtype", Json::str("s32")),
                ]),
            ),
            ("n_classes", Json::num(self.meta.n_classes as f64)),
        ]);
        let path = dir.join("manifest.json");
        std::fs::write(&path, man.to_string_pretty())
            .with_context(|| format!("writing {path:?}"))?;
        Ok(dir)
    }

    pub fn n_params(&self) -> usize {
        self.params.len()
    }

    pub fn total_param_elems(&self) -> usize {
        self.params.iter().map(|p| p.shape.iter().product::<usize>()).sum()
    }

    pub fn hlo_path(&self, name: &str) -> Result<PathBuf> {
        let file = self
            .files
            .iter()
            .find(|(k, _)| k == name)
            .map(|(_, f)| f.clone())
            .unwrap_or_else(|| format!("{name}.hlo.txt"));
        let p = self.dir.join(file);
        if !p.exists() {
            bail!("artifact {:?} not found in {:?} (run `make artifacts`)", name, self.dir);
        }
        Ok(p)
    }

    /// Whether an HLO file for `name` is on disk (PJRT backend contract;
    /// the native backend answers through `Engine::has` instead).
    pub fn has(&self, name: &str) -> bool {
        !self.dir.as_os_str().is_empty() && self.dir.join(format!("{name}.hlo.txt")).exists()
    }
}

fn shape_of(v: &Json) -> Result<Vec<usize>> {
    v.as_arr()
        .context("shape is not an array")?
        .iter()
        .map(|d| d.as_usize().context("shape entry not a number"))
        .collect()
}

/// Find every artifact directory under the root (directories containing a
/// manifest.json).
pub fn discover(root: &Path) -> Vec<PathBuf> {
    let mut out = Vec::new();
    if let Ok(rd) = std::fs::read_dir(root) {
        for entry in rd.flatten() {
            let p = entry.path();
            if p.is_dir() && p.join("manifest.json").exists() {
                out.push(p);
            }
        }
    }
    out.sort();
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_manifest() -> String {
        r#"{
            "key": "tiny_test",
            "n_params": 2,
            "params": [
                {"name": "a.w", "shape": [2, 3], "dtype": "f32"},
                {"name": "a.b", "shape": [3], "dtype": "f32"}
            ],
            "config": {"task": "text", "variant": "cast_topk", "seq_len": 64,
                       "batch": 2, "n_c": 4, "kappa": 16, "depth": 2, "h": 2,
                       "d": 16, "d_ff": 32, "d_emb": 16, "vocab": 32,
                       "n_classes": 2, "dual": false},
            "tokens": {"shape": [2, 64], "dtype": "s32"},
            "labels": {"shape": [2], "dtype": "s32"},
            "n_classes": 2,
            "files": {"init": "init.hlo.txt"}
        }"#
        .to_string()
    }

    #[test]
    fn parses_manifest() {
        let dir = std::env::temp_dir().join("cast_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), fake_manifest()).unwrap();
        std::fs::write(dir.join("init.hlo.txt"), "HloModule fake").unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.key, "tiny_test");
        assert_eq!(m.n_params(), 2);
        assert_eq!(m.total_param_elems(), 9);
        assert_eq!(m.meta.kappa, 16);
        assert_eq!(m.tokens_shape, vec![2, 64]);
        assert!(m.hlo_path("init").is_ok());
        assert!(m.hlo_path("train_step").is_err());
        // architecture fields absent from older manifests take defaults
        assert_eq!(m.meta.norm, "layer");
        assert_eq!(m.meta.attn_fn, "softmax");
        assert!(!m.meta.prenorm && !m.meta.causal);
    }

    #[test]
    fn missing_dir_is_error() {
        assert!(Manifest::load(Path::new("/nonexistent/xyz")).is_err());
    }

    #[test]
    fn synthetic_manifest_roundtrips_through_save_and_load() {
        let meta = crate::runtime::native::spec::tiny_meta("cast_topk");
        let m = Manifest::synthetic(meta);
        assert_eq!(m.key, "text_cast_topk_n64_b2_c4_k16");
        assert!(m.n_params() > 10);
        let root = std::env::temp_dir().join("cast_manifest_synth_test");
        let _ = std::fs::remove_dir_all(&root);
        let dir = m.save(&root).unwrap();
        let back = Manifest::load(&dir).unwrap();
        assert_eq!(back.key, m.key);
        assert_eq!(back.n_params(), m.n_params());
        assert_eq!(back.meta.norm, m.meta.norm);
        assert_eq!(back.meta.kappa, m.meta.kappa);
        assert_eq!(back.tokens_shape, m.tokens_shape);
        for (a, b) in back.params.iter().zip(&m.params) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.shape, b.shape);
        }
        // no HLO files exist — disk `has` is false, hlo_path errors
        assert!(!back.has("predict"));
        assert!(back.hlo_path("predict").is_err());
    }

    #[test]
    fn meta_key_matches_python_key_scheme() {
        let mut meta = crate::runtime::native::spec::tiny_meta("vanilla");
        assert_eq!(meta.key(), "text_vanilla_n64_b2");
        meta.variant = "local".into();
        meta.window = 64;
        assert_eq!(meta.key(), "text_local_n64_b2_w64");
        meta.variant = "cast_sa".into();
        meta.causal = true;
        assert_eq!(meta.key(), "text_cast_sa_n64_b2_c4_k16_causal");
    }
}
