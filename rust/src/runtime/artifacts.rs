//! Artifact manifests: the contract between `python/compile/aot.py` and the
//! rust runtime.  One directory per model config, containing HLO text files
//! plus `manifest.json` describing the flattened parameter list and batch
//! shapes (see aot.py's `manifest()` for the writer side).

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

use super::tensor::DType;

#[derive(Clone, Debug)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

/// The subset of ModelConfig the runtime needs (full config kept as Json
/// for reporting).
#[derive(Clone, Debug)]
pub struct ModelMeta {
    pub task: String,
    pub variant: String,
    pub seq_len: usize,
    pub batch: usize,
    pub n_c: usize,
    pub kappa: usize,
    pub depth: usize,
    pub heads: usize,
    pub d: usize,
    pub d_ff: usize,
    pub d_emb: usize,
    pub vocab: usize,
    pub n_classes: usize,
    pub dual: bool,
}

#[derive(Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub key: String,
    pub params: Vec<ParamSpec>,
    pub tokens_shape: Vec<usize>,
    pub labels_shape: Vec<usize>,
    pub meta: ModelMeta,
    pub files: Vec<(String, String)>,
    pub raw: Json,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let man_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&man_path)
            .with_context(|| format!("reading {man_path:?} (run `make artifacts`?)"))?;
        let raw = Json::parse(&text).with_context(|| format!("parsing {man_path:?}"))?;

        let key = raw
            .get("key")
            .and_then(Json::as_str)
            .context("manifest missing 'key'")?
            .to_string();

        let mut params = Vec::new();
        for p in raw.get("params").and_then(Json::as_arr).context("manifest missing 'params'")? {
            params.push(ParamSpec {
                name: p.get("name").and_then(Json::as_str).context("param name")?.to_string(),
                shape: shape_of(p.get("shape").context("param shape")?)?,
                dtype: DType::parse(p.get("dtype").and_then(Json::as_str).context("param dtype")?)?,
            });
        }
        if params.is_empty() {
            bail!("manifest has no parameters");
        }

        let cfg = raw.get("config").context("manifest missing 'config'")?;
        let get_usize = |k: &str| -> Result<usize> {
            cfg.get(k).and_then(Json::as_usize).with_context(|| format!("config.{k}"))
        };
        let meta = ModelMeta {
            task: cfg.get("task").and_then(Json::as_str).context("config.task")?.to_string(),
            variant: cfg.get("variant").and_then(Json::as_str).context("config.variant")?.to_string(),
            seq_len: get_usize("seq_len")?,
            batch: get_usize("batch")?,
            n_c: get_usize("n_c")?,
            kappa: get_usize("kappa")?,
            depth: get_usize("depth")?,
            heads: get_usize("h")?,
            d: get_usize("d")?,
            d_ff: get_usize("d_ff")?,
            d_emb: get_usize("d_emb")?,
            vocab: get_usize("vocab")?,
            n_classes: get_usize("n_classes")?,
            dual: cfg.get("dual").and_then(Json::as_bool).unwrap_or(false),
        };

        let tokens_shape = shape_of(raw.path("tokens.shape").context("tokens.shape")?)?;
        let labels_shape = shape_of(raw.path("labels.shape").context("labels.shape")?)?;

        let mut files = Vec::new();
        if let Some(obj) = raw.get("files").and_then(Json::as_obj) {
            for (k, v) in obj {
                if let Some(f) = v.as_str() {
                    files.push((k.clone(), f.to_string()));
                }
            }
        }

        Ok(Manifest {
            dir: dir.to_path_buf(),
            key,
            params,
            tokens_shape,
            labels_shape,
            meta,
            files,
            raw,
        })
    }

    pub fn n_params(&self) -> usize {
        self.params.len()
    }

    pub fn total_param_elems(&self) -> usize {
        self.params.iter().map(|p| p.shape.iter().product::<usize>()).sum()
    }

    pub fn hlo_path(&self, name: &str) -> Result<PathBuf> {
        let file = self
            .files
            .iter()
            .find(|(k, _)| k == name)
            .map(|(_, f)| f.clone())
            .unwrap_or_else(|| format!("{name}.hlo.txt"));
        let p = self.dir.join(file);
        if !p.exists() {
            bail!("artifact {:?} not found in {:?} (run `make artifacts`)", name, self.dir);
        }
        Ok(p)
    }

    pub fn has(&self, name: &str) -> bool {
        self.dir.join(format!("{name}.hlo.txt")).exists()
    }
}

fn shape_of(v: &Json) -> Result<Vec<usize>> {
    v.as_arr()
        .context("shape is not an array")?
        .iter()
        .map(|d| d.as_usize().context("shape entry not a number"))
        .collect()
}

/// Find every artifact directory under the root (directories containing a
/// manifest.json).
pub fn discover(root: &Path) -> Vec<PathBuf> {
    let mut out = Vec::new();
    if let Ok(rd) = std::fs::read_dir(root) {
        for entry in rd.flatten() {
            let p = entry.path();
            if p.is_dir() && p.join("manifest.json").exists() {
                out.push(p);
            }
        }
    }
    out.sort();
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_manifest() -> String {
        r#"{
            "key": "tiny_test",
            "n_params": 2,
            "params": [
                {"name": "a.w", "shape": [2, 3], "dtype": "f32"},
                {"name": "a.b", "shape": [3], "dtype": "f32"}
            ],
            "config": {"task": "text", "variant": "cast_topk", "seq_len": 64,
                       "batch": 2, "n_c": 4, "kappa": 16, "depth": 2, "h": 2,
                       "d": 16, "d_ff": 32, "d_emb": 16, "vocab": 32,
                       "n_classes": 2, "dual": false},
            "tokens": {"shape": [2, 64], "dtype": "s32"},
            "labels": {"shape": [2], "dtype": "s32"},
            "n_classes": 2,
            "files": {"init": "init.hlo.txt"}
        }"#
        .to_string()
    }

    #[test]
    fn parses_manifest() {
        let dir = std::env::temp_dir().join("cast_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), fake_manifest()).unwrap();
        std::fs::write(dir.join("init.hlo.txt"), "HloModule fake").unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.key, "tiny_test");
        assert_eq!(m.n_params(), 2);
        assert_eq!(m.total_param_elems(), 9);
        assert_eq!(m.meta.kappa, 16);
        assert_eq!(m.tokens_shape, vec![2, 64]);
        assert!(m.hlo_path("init").is_ok());
        assert!(m.hlo_path("train_step").is_err());
    }

    #[test]
    fn missing_dir_is_error() {
        assert!(Manifest::load(Path::new("/nonexistent/xyz")).is_err());
    }
}
