//! Host-side tensors: the typed buffers the coordinator owns between
//! backend calls (parameters, optimizer state, batches, metrics).
//!
//! Deliberately minimal — three dtypes (f32/s32/u32 are all the program
//! contracts use).  Conversion to/from `xla::Literal` is only compiled
//! with the optional `xla` feature (the PJRT backend); the native backend
//! consumes `HostTensor`s directly.

use anyhow::{bail, Result};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    S32,
    U32,
}

impl DType {
    pub fn parse(s: &str) -> Result<DType> {
        Ok(match s {
            "f32" | "float32" => DType::F32,
            "s32" | "int32" | "i32" => DType::S32,
            "u32" | "uint32" => DType::U32,
            other => bail!("unsupported dtype {other:?}"),
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            DType::F32 => "f32",
            DType::S32 => "s32",
            DType::U32 => "u32",
        }
    }

    #[cfg(feature = "xla")]
    fn element_type(self) -> xla::ElementType {
        match self {
            DType::F32 => xla::ElementType::F32,
            DType::S32 => xla::ElementType::S32,
            DType::U32 => xla::ElementType::U32,
        }
    }
}

#[derive(Clone, Debug)]
pub enum Data {
    F32(Vec<f32>),
    S32(Vec<i32>),
    U32(Vec<u32>),
}

/// A dense row-major host tensor.
#[derive(Clone, Debug)]
pub struct HostTensor {
    pub shape: Vec<usize>,
    pub data: Data,
}

impl HostTensor {
    pub fn f32(shape: Vec<usize>, data: Vec<f32>) -> HostTensor {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        HostTensor { shape, data: Data::F32(data) }
    }

    pub fn s32(shape: Vec<usize>, data: Vec<i32>) -> HostTensor {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        HostTensor { shape, data: Data::S32(data) }
    }

    pub fn u32(shape: Vec<usize>, data: Vec<u32>) -> HostTensor {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        HostTensor { shape, data: Data::U32(data) }
    }

    pub fn zeros(dtype: DType, shape: Vec<usize>) -> HostTensor {
        let n: usize = shape.iter().product();
        match dtype {
            DType::F32 => HostTensor::f32(shape, vec![0.0; n]),
            DType::S32 => HostTensor::s32(shape, vec![0; n]),
            DType::U32 => HostTensor::u32(shape, vec![0; n]),
        }
    }

    pub fn scalar_f32(x: f32) -> HostTensor {
        HostTensor::f32(vec![], vec![x])
    }

    pub fn dtype(&self) -> DType {
        match self.data {
            Data::F32(_) => DType::F32,
            Data::S32(_) => DType::S32,
            Data::U32(_) => DType::U32,
        }
    }

    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn size_bytes(&self) -> usize {
        self.len() * 4
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match &self.data {
            Data::F32(v) => Ok(v),
            _ => bail!("tensor is {}, expected f32", self.dtype().name()),
        }
    }

    pub fn as_s32(&self) -> Result<&[i32]> {
        match &self.data {
            Data::S32(v) => Ok(v),
            _ => bail!("tensor is {}, expected s32", self.dtype().name()),
        }
    }

    pub fn as_u32(&self) -> Result<&[u32]> {
        match &self.data {
            Data::U32(v) => Ok(v),
            _ => bail!("tensor is {}, expected u32", self.dtype().name()),
        }
    }

    /// The single f32 value of a scalar tensor.  The error names the
    /// actual dtype/shape so arity bugs in program outputs are diagnosable.
    pub fn scalar(&self) -> Result<f32> {
        let v = match &self.data {
            Data::F32(v) => v,
            _ => bail!(
                "expected an f32 scalar, tensor is {} with shape {:?}",
                self.dtype().name(),
                self.shape
            ),
        };
        if v.len() != 1 {
            bail!("expected a scalar, shape is {:?}", self.shape);
        }
        Ok(v[0])
    }
}

// -- literal conversion (PJRT backend only) --------------------------------

#[cfg(feature = "xla")]
impl HostTensor {
    pub fn to_literal(&self) -> Result<xla::Literal> {
        use anyhow::Context;
        let bytes: &[u8] = match &self.data {
            Data::F32(v) => bytemuck_cast(v),
            Data::S32(v) => bytemuck_cast(v),
            Data::U32(v) => bytemuck_cast(v),
        };
        xla::Literal::create_from_shape_and_untyped_data(
            self.dtype().element_type(),
            &self.shape,
            bytes,
        )
        .context("creating literal from host tensor")
    }

    pub fn from_literal(lit: &xla::Literal) -> Result<HostTensor> {
        use anyhow::Context;
        let shape = lit.array_shape().context("literal has no array shape")?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        let ty = lit.ty().context("literal element type")?;
        let t = match ty {
            xla::ElementType::F32 => HostTensor::f32(dims, lit.to_vec::<f32>()?),
            xla::ElementType::S32 => HostTensor::s32(dims, lit.to_vec::<i32>()?),
            xla::ElementType::U32 => HostTensor::u32(dims, lit.to_vec::<u32>()?),
            other => bail!("unsupported literal element type {other:?}"),
        };
        Ok(t)
    }
}

/// Reinterpret a &[T] of 4-byte scalars as bytes (little-endian host).
#[cfg(feature = "xla")]
fn bytemuck_cast<T>(v: &[T]) -> &[u8] {
    unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, std::mem::size_of_val(v)) }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_product_checked() {
        let t = HostTensor::f32(vec![2, 3], vec![0.0; 6]);
        assert_eq!(t.len(), 6);
        assert_eq!(t.size_bytes(), 24);
    }

    #[test]
    #[should_panic(expected = "shape/data mismatch")]
    fn bad_shape_panics() {
        HostTensor::f32(vec![2, 3], vec![0.0; 5]);
    }

    #[test]
    fn dtype_parse() {
        assert_eq!(DType::parse("f32").unwrap(), DType::F32);
        assert_eq!(DType::parse("s32").unwrap(), DType::S32);
        assert!(DType::parse("f64").is_err());
    }

    #[test]
    fn u32_accessor_roundtrip() {
        let t = HostTensor::u32(vec![3], vec![7, 0, u32::MAX]);
        assert_eq!(t.as_u32().unwrap(), &[7, 0, u32::MAX]);
        assert_eq!(t.dtype(), DType::U32);
        // the other typed accessors must refuse a u32 tensor
        assert!(t.as_f32().is_err());
        assert!(t.as_s32().is_err());
    }

    #[test]
    fn u32_accessor_rejects_other_dtypes() {
        let f = HostTensor::f32(vec![1], vec![1.5]);
        let err = format!("{:#}", f.as_u32().unwrap_err());
        assert!(err.contains("f32"), "error should name actual dtype: {err}");
        let s = HostTensor::s32(vec![1], vec![-3]);
        assert!(s.as_u32().is_err());
    }

    #[test]
    fn scalar_reports_actual_dtype_on_mismatch() {
        let t = HostTensor::s32(vec![], vec![5]);
        let err = format!("{:#}", t.scalar().unwrap_err());
        assert!(err.contains("s32"), "error should name the actual dtype: {err}");

        let u = HostTensor::u32(vec![2], vec![1, 2]);
        let err = format!("{:#}", u.scalar().unwrap_err());
        assert!(err.contains("u32"), "error should name the actual dtype: {err}");

        // non-scalar f32 still errors on shape
        let f = HostTensor::f32(vec![2], vec![1.0, 2.0]);
        let err = format!("{:#}", f.scalar().unwrap_err());
        assert!(err.contains("shape"), "{err}");

        assert_eq!(HostTensor::scalar_f32(2.5).scalar().unwrap(), 2.5);
    }

    #[cfg(feature = "xla")]
    #[test]
    fn literal_roundtrip_f32() {
        let t = HostTensor::f32(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let lit = t.to_literal().unwrap();
        let back = HostTensor::from_literal(&lit).unwrap();
        assert_eq!(back.shape, vec![2, 2]);
        assert_eq!(back.as_f32().unwrap(), &[1.0, 2.0, 3.0, 4.0]);
    }

    #[cfg(feature = "xla")]
    #[test]
    fn literal_roundtrip_s32_scalar_shapes() {
        let t = HostTensor::s32(vec![3], vec![7, -1, 0]);
        let back = HostTensor::from_literal(&t.to_literal().unwrap()).unwrap();
        assert_eq!(back.as_s32().unwrap(), &[7, -1, 0]);

        let s = HostTensor::scalar_f32(2.5);
        let back = HostTensor::from_literal(&s.to_literal().unwrap()).unwrap();
        assert_eq!(back.scalar().unwrap(), 2.5);
    }
}
