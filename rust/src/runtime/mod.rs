//! L3 ↔ compute boundary: the pluggable execution backends, artifact
//! manifests, and host tensors.
//!
//! Loading path (the only way compute enters the system at run time):
//!   `Manifest` (loaded from disk, or `Manifest::synthetic(meta)`) →
//!   `Engine::load(&manifest, entry)` → `Executable::run(&[HostTensor])`.
//!
//! Two [`Backend`] implementations sit behind the `Engine` facade:
//! * `native` — a pure-Rust f32 CAST engine (`runtime::native`), the
//!   default; zero artifacts, zero Python, zero external crates.
//! * `pjrt` — AOT HLO artifacts produced by `python/compile/aot.py`
//!   (`make artifacts`) executed through PJRT; `xla` cargo feature.

pub mod artifacts;
pub mod backend;
pub mod engine;
pub mod native;
#[cfg(feature = "xla")]
pub mod pjrt;
pub mod tensor;

pub use artifacts::{Manifest, ModelMeta, ParamSpec};
pub use backend::{Backend, DecodeSession, Executable, Scratch};
pub use engine::Engine;
pub use tensor::{DType, Data, HostTensor};
