//! L3 ↔ XLA boundary: PJRT client, AOT artifact manifests, host tensors.
//!
//! Loading path (the only way compute enters the system at run time):
//!   `artifacts::Manifest::load(dir)` → `engine::Engine::load_hlo(path)`
//!   → `Executable::run(&[HostTensor])`.
//! Python never executes here; `artifacts/` is produced once by
//! `make artifacts` (python/compile/aot.py).

pub mod artifacts;
pub mod engine;
pub mod tensor;

pub use artifacts::{Manifest, ModelMeta, ParamSpec};
pub use engine::{Engine, Executable};
pub use tensor::{DType, Data, HostTensor};
