//! PJRT engine: load AOT HLO-text artifacts and execute them.
//!
//! Wraps the `xla` crate exactly the way /opt/xla-example/load_hlo does:
//! `PjRtClient::cpu()` → `HloModuleProto::from_text_file` →
//! `client.compile` → `execute`.  All artifacts are lowered with
//! `return_tuple=True`, so every execution returns ONE tuple literal that
//! we decompose into per-output `HostTensor`s.
//!
//! The engine is shared (`Arc`) across trainer / bench / analysis code;
//! compiled executables are cached by path so sweeps that revisit a config
//! don't recompile.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use anyhow::{bail, Context, Result};
use xla::{HloModuleProto, Literal, PjRtClient, PjRtLoadedExecutable, XlaComputation};

use super::tensor::HostTensor;

pub struct Engine {
    client: PjRtClient,
    cache: Mutex<HashMap<PathBuf, Arc<Executable>>>,
}

pub struct Executable {
    exe: PjRtLoadedExecutable,
    pub path: PathBuf,
}

// The PJRT CPU client is thread-safe at the C++ level; executions are
// serialized per-executable by XLA itself.
unsafe impl Send for Engine {}
unsafe impl Sync for Engine {}
unsafe impl Send for Executable {}
unsafe impl Sync for Executable {}

impl Engine {
    pub fn cpu() -> Result<Arc<Engine>> {
        let client = PjRtClient::cpu().context("creating PJRT CPU client")?;
        crate::debug!(
            "engine: platform={} devices={}",
            client.platform_name(),
            client.device_count()
        );
        Ok(Arc::new(Engine { client, cache: Mutex::new(HashMap::new()) }))
    }

    /// Load + compile an HLO text file (cached by canonical path).
    pub fn load_hlo(&self, path: &Path) -> Result<Arc<Executable>> {
        let key = path.canonicalize().unwrap_or_else(|_| path.to_path_buf());
        if let Some(exe) = self.cache.lock().unwrap().get(&key) {
            return Ok(exe.clone());
        }
        let t = crate::util::Timer::start();
        let proto = HloModuleProto::from_text_file(path)
            .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("XLA compile of {path:?}"))?;
        crate::debug!("engine: compiled {:?} in {:.2}s", path.file_name().unwrap(), t.seconds());
        let exe = Arc::new(Executable { exe, path: key.clone() });
        self.cache.lock().unwrap().insert(key, exe.clone());
        Ok(exe)
    }

    /// Number of executables compiled so far (for tests/metrics).
    pub fn compiled_count(&self) -> usize {
        self.cache.lock().unwrap().len()
    }
}

impl Executable {
    /// Execute with host tensors; returns the decomposed output tuple.
    pub fn run(&self, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let literals: Vec<Literal> =
            inputs.iter().map(|t| t.to_literal()).collect::<Result<_>>()?;
        self.run_literals(&literals)
    }

    /// Execute with borrowed host tensors — the trainer's hot path.  Lets
    /// the caller assemble the (3P+4)-argument train_step input list
    /// without cloning the full parameter/optimizer state every step
    /// (§Perf L3 item 1 in EXPERIMENTS.md).
    pub fn run_refs(&self, inputs: &[&HostTensor]) -> Result<Vec<HostTensor>> {
        let literals: Vec<Literal> =
            inputs.iter().map(|t| t.to_literal()).collect::<Result<_>>()?;
        self.run_literals(&literals)
    }

    /// Execute with pre-built literals (hot path: lets the caller reuse
    /// param literals across steps instead of re-encoding them).
    pub fn run_literals(&self, literals: &[Literal]) -> Result<Vec<HostTensor>> {
        let out = self.run_literals_raw(literals)?;
        out.iter().map(HostTensor::from_literal).collect()
    }

    /// Execute returning raw literals (no host-tensor conversion) — the
    /// trainer feeds these straight back into the next step.
    pub fn run_literals_raw(&self, literals: &[Literal]) -> Result<Vec<Literal>> {
        let result = self
            .exe
            .execute::<Literal>(literals)
            .with_context(|| format!("executing {:?}", self.path.file_name().unwrap()))?;
        if result.is_empty() || result[0].is_empty() {
            bail!("execution produced no outputs");
        }
        let root = result[0][0].to_literal_sync().context("fetching result literal")?;
        let mut root = root;
        let parts = root.decompose_tuple().context("decomposing result tuple")?;
        Ok(parts)
    }
}
