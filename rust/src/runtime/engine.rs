//! `Engine`: the backend-owning facade the rest of the system talks to.
//!
//! Holds one [`Backend`] implementation plus a load cache keyed by
//! `(artifact key, entry)` so sweeps that revisit a config don't recompile
//! (PJRT) or revalidate (native).  The engine is shared (`Arc`) across
//! trainer / bench / analysis code.
//!
//! Backend selection:
//! * [`Engine::cpu`] — the native pure-Rust engine (always available,
//!   zero artifacts required).
//! * [`Engine::pjrt`] — PJRT over AOT HLO artifacts (`xla` feature).
//! * [`Engine::auto`] — `CAST_BACKEND=native|pjrt` env override, default
//!   native.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use anyhow::Result;

use super::artifacts::Manifest;
use super::backend::{Backend, Executable};
use super::native::NativeBackend;

pub struct Engine {
    backend: Box<dyn Backend>,
    cache: Mutex<HashMap<(String, String), Arc<dyn Executable>>>,
}

impl Engine {
    /// The native CPU engine — the default backend.
    pub fn cpu() -> Result<Arc<Engine>> {
        Ok(Engine::with_backend(Box::new(NativeBackend)))
    }

    /// The PJRT backend executing AOT HLO-text artifacts.
    #[cfg(feature = "xla")]
    pub fn pjrt() -> Result<Arc<Engine>> {
        Ok(Engine::with_backend(Box::new(super::pjrt::PjrtBackend::new()?)))
    }

    /// Backend selected by the `CAST_BACKEND` environment variable
    /// (`native` default; `pjrt` requires the `xla` feature).
    pub fn auto() -> Result<Arc<Engine>> {
        match std::env::var("CAST_BACKEND").as_deref() {
            Ok("pjrt") => pjrt_or_err(),
            Ok("native") | Err(_) => Engine::cpu(),
            Ok(other) => anyhow::bail!("unknown CAST_BACKEND {other:?} (know native, pjrt)"),
        }
    }

    pub fn with_backend(backend: Box<dyn Backend>) -> Arc<Engine> {
        crate::debug!("engine: backend={} threads={}", backend.name(), Engine::threads());
        Arc::new(Engine { backend, cache: Mutex::new(HashMap::new()) })
    }

    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// Worker count the native engine dispatches on (`CAST_NUM_THREADS`
    /// override, else hardware parallelism) — reported by the bench JSON.
    pub fn threads() -> usize {
        crate::util::parallel::max_threads()
    }

    /// Whether `entry` is available for this config on this backend.
    pub fn has(&self, manifest: &Manifest, entry: &str) -> bool {
        self.backend.supports(manifest, entry)
    }

    /// Load (compile) a program, cached per `(artifact, entry)`.
    pub fn load(&self, manifest: &Manifest, entry: &str) -> Result<Arc<dyn Executable>> {
        let key = (cache_scope(manifest), entry.to_string());
        if let Some(exe) = self.cache.lock().unwrap().get(&key) {
            return Ok(exe.clone());
        }
        let t = crate::util::Timer::start();
        let exe = self.backend.load(manifest, entry)?;
        crate::debug!(
            "engine: loaded {}/{} on {} in {:.2}s",
            manifest.key,
            entry,
            self.backend.name(),
            t.seconds()
        );
        self.cache.lock().unwrap().insert(key, exe.clone());
        Ok(exe)
    }

    /// Number of distinct programs loaded so far (for tests/metrics).
    pub fn compiled_count(&self) -> usize {
        self.cache.lock().unwrap().len()
    }
}

#[cfg(feature = "xla")]
fn pjrt_or_err() -> Result<Arc<Engine>> {
    Engine::pjrt()
}

#[cfg(not(feature = "xla"))]
fn pjrt_or_err() -> Result<Arc<Engine>> {
    anyhow::bail!(
        "CAST_BACKEND=pjrt but this build has no `xla` feature; \
         rebuild with `--features xla` (requires the xla crate, see Cargo.toml)"
    )
}

/// Cache scope for a manifest: the canonical artifact directory when it
/// lives on disk (so relative and absolute spellings of the same dir hit
/// one cache entry), the full config when synthetic — the key alone
/// omits fields like depth/attn_fn/prenorm, and two synthetic configs
/// differing only there must not share an executable.
fn cache_scope(manifest: &Manifest) -> String {
    if manifest.dir.as_os_str().is_empty() {
        format!("synthetic:{:?}", manifest.meta)
    } else {
        manifest
            .dir
            .canonicalize()
            .unwrap_or_else(|_| manifest.dir.clone())
            .to_string_lossy()
            .into_owned()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::native::spec::tiny_meta;
    use crate::runtime::HostTensor;

    #[test]
    fn load_caches_by_artifact_and_entry() {
        let engine = Engine::cpu().unwrap();
        let man = Manifest::synthetic(tiny_meta("cast_topk"));
        assert_eq!(engine.compiled_count(), 0);
        let a = engine.load(&man, "predict").unwrap();
        let b = engine.load(&man, "predict").unwrap();
        assert_eq!(engine.compiled_count(), 1);
        assert!(Arc::ptr_eq(&a, &b));
        let _ = engine.load(&man, "init").unwrap();
        assert_eq!(engine.compiled_count(), 2);
    }

    #[test]
    fn native_engine_runs_init_through_trait_object() {
        let engine = Engine::cpu().unwrap();
        assert_eq!(engine.backend_name(), "native");
        let man = Manifest::synthetic(tiny_meta("cast_topk"));
        assert!(engine.has(&man, "predict_ag"));
        let exe = engine.load(&man, "init").unwrap();
        assert_eq!(exe.entry(), "init");
        let out = exe.run(&[HostTensor::u32(vec![], vec![42])]).unwrap();
        assert_eq!(out.len(), man.n_params());
    }

    #[test]
    fn auto_defaults_to_native() {
        // NB: relies on CAST_BACKEND being unset in the test environment
        if std::env::var("CAST_BACKEND").is_err() {
            let engine = Engine::auto().unwrap();
            assert_eq!(engine.backend_name(), "native");
        }
    }
}
