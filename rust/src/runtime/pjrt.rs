//! PJRT backend (`xla` feature): load AOT HLO-text artifacts and execute
//! them, wrapping the `xla` crate the way /opt/xla-example/load_hlo does:
//! `PjRtClient::cpu()` → `HloModuleProto::from_text_file` →
//! `client.compile` → `execute`.
//!
//! Most artifacts are lowered with `return_tuple=True`, so execution
//! returns ONE tuple literal decomposed into per-output `HostTensor`s;
//! single-output programs whose root is *not* a tuple yield a 1-element
//! vector instead of failing (see `run_literals_raw`).

use std::sync::Arc;

use anyhow::{bail, Context, Result};
use xla::{HloModuleProto, Literal, PjRtClient, PjRtLoadedExecutable, XlaComputation};

use super::artifacts::Manifest;
use super::backend::{Backend, Executable};
use super::tensor::HostTensor;

pub struct PjrtBackend {
    client: PjRtClient,
}

// The PJRT CPU client is thread-safe at the C++ level; executions are
// serialized per-executable by XLA itself.
unsafe impl Send for PjrtBackend {}
unsafe impl Sync for PjrtBackend {}
unsafe impl Send for PjrtExecutable {}
unsafe impl Sync for PjrtExecutable {}

impl PjrtBackend {
    pub fn new() -> Result<PjrtBackend> {
        let client = PjRtClient::cpu().context("creating PJRT CPU client")?;
        crate::debug!(
            "pjrt: platform={} devices={}",
            client.platform_name(),
            client.device_count()
        );
        Ok(PjrtBackend { client })
    }
}

impl Backend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn supports(&self, manifest: &Manifest, entry: &str) -> bool {
        manifest.has(entry)
    }

    fn load(&self, manifest: &Manifest, entry: &str) -> Result<Arc<dyn Executable>> {
        let path = manifest.hlo_path(entry)?;
        let proto = HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("XLA compile of {path:?}"))?;
        Ok(Arc::new(PjrtExecutable { exe, entry: entry.to_string() }))
    }
}

pub struct PjrtExecutable {
    exe: PjRtLoadedExecutable,
    entry: String,
}

impl Executable for PjrtExecutable {
    fn entry(&self) -> &str {
        &self.entry
    }

    fn run_refs(&self, inputs: &[&HostTensor]) -> Result<Vec<HostTensor>> {
        let literals: Vec<Literal> =
            inputs.iter().map(|t| t.to_literal()).collect::<Result<_>>()?;
        self.run_literals(&literals)
    }
}

impl PjrtExecutable {
    /// Execute with pre-built literals (hot path: lets the caller reuse
    /// param literals across steps instead of re-encoding them).
    pub fn run_literals(&self, literals: &[Literal]) -> Result<Vec<HostTensor>> {
        let out = self.run_literals_raw(literals)?;
        out.iter().map(HostTensor::from_literal).collect()
    }

    /// Execute returning raw literals (no host-tensor conversion).
    ///
    /// Handles both root shapes the AOT pipeline can produce: a tuple
    /// (decomposed into its elements) and a plain array (returned as a
    /// 1-element vec) — `decompose_tuple` hard-failing on single-output
    /// programs was a long-standing bug.
    pub fn run_literals_raw(&self, literals: &[Literal]) -> Result<Vec<Literal>> {
        let result = self
            .exe
            .execute::<Literal>(literals)
            .with_context(|| format!("executing {:?}", self.entry))?;
        if result.is_empty() || result[0].is_empty() {
            bail!("execution produced no outputs");
        }
        let mut root = result[0][0].to_literal_sync().context("fetching result literal")?;
        match root.decompose_tuple() {
            Ok(parts) if !parts.is_empty() => Ok(parts),
            // non-tuple root (single-output program): the literal itself
            // is the one output
            _ => Ok(vec![root]),
        }
    }
}
