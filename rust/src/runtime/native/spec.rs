//! Flat parameter layout of the encoder model — the native mirror of the
//! ordering `python/compile/model.py` records in `manifest.json`
//! (jax `tree_flatten`, which walks dict keys in sorted order).
//!
//! Top-level order: `blocks` < `embed` < `head` < `out_norm` < `proj`.
//! Per block: `attn` < `ffn` < `norm1` < `norm2`; per dense layer `b` < `w`.
//! Keeping this order bit-identical to the AOT pipeline means one
//! `ModelState` / checkpoint layout serves both backends.

use anyhow::Result;

use crate::runtime::artifacts::{ModelMeta, ParamSpec};
use crate::runtime::tensor::DType;

fn f32_spec(name: String, shape: Vec<usize>) -> ParamSpec {
    ParamSpec { name, shape, dtype: DType::F32 }
}

fn dense_specs(out: &mut Vec<ParamSpec>, prefix: &str, d_in: usize, d_out: usize) {
    out.push(f32_spec(format!("{prefix}.b"), vec![d_out]));
    out.push(f32_spec(format!("{prefix}.w"), vec![d_in, d_out]));
}

fn norm_specs(out: &mut Vec<ParamSpec>, prefix: &str, kind: &str, d: usize) {
    if kind == "scale" {
        out.push(f32_spec(format!("{prefix}.g"), vec![]));
    } else {
        // "layer" and "batch" (substituted by an affine layernorm, see
        // DESIGN.md §Substitutions) share the same parameter shape
        out.push(f32_spec(format!("{prefix}.b"), vec![d]));
        out.push(f32_spec(format!("{prefix}.g"), vec![d]));
    }
}

/// The full flat parameter list for a model config, in manifest order.
pub fn param_specs(meta: &ModelMeta) -> Vec<ParamSpec> {
    let (d, d_ff, d_emb) = (meta.d, meta.d_ff, meta.d_emb);
    let mut out = Vec::new();
    for i in 0..meta.depth {
        let blk = format!("blocks.{i}");
        // attn (sorted keys: phi < s < wk < wo < wq < wv; baselines have
        // only the four projections)
        if meta.is_cast() {
            dense_specs(&mut out, &format!("{blk}.attn.phi"), d, 1);
            out.push(f32_spec(
                format!("{blk}.attn.s"),
                vec![meta.n_c, meta.heads, meta.d_h()],
            ));
        }
        for proj in ["wk", "wo", "wq", "wv"] {
            dense_specs(&mut out, &format!("{blk}.attn.{proj}"), d, d);
        }
        // ffn ("in" < "out")
        dense_specs(&mut out, &format!("{blk}.ffn.in"), d, d_ff);
        dense_specs(&mut out, &format!("{blk}.ffn.out"), d_ff, d);
        norm_specs(&mut out, &format!("{blk}.norm1"), &meta.norm, d);
        norm_specs(&mut out, &format!("{blk}.norm2"), &meta.norm, d);
    }
    out.push(f32_spec("embed.emb".to_string(), vec![meta.vocab, d_emb]));
    let d_head_in = if meta.dual { 4 * d } else { d };
    dense_specs(&mut out, "head.fc", d_head_in, d);
    dense_specs(&mut out, "head.out", d, meta.n_classes);
    if meta.prenorm {
        norm_specs(&mut out, "out_norm", &meta.norm, d);
    }
    dense_specs(&mut out, "proj", d_emb, d);
    out
}

/// The tiny smoke config (`python/compile/configs.py::tiny`): text task,
/// seq 64, batch 2, depth 2, h 2, d 16, Nc 4, kappa 16.
pub fn tiny_meta(variant: &str) -> ModelMeta {
    ModelMeta {
        task: "text".to_string(),
        variant: variant.to_string(),
        seq_len: 64,
        batch: 2,
        n_c: 4,
        kappa: 16,
        depth: 2,
        heads: 2,
        d: 16,
        d_ff: 32,
        d_emb: 16,
        vocab: 256,
        n_classes: 2,
        dual: false,
        norm: "layer".to_string(),
        prenorm: false,
        attn_fn: "softmax".to_string(),
        window: 64,
        causal: false,
    }
}

/// [`tiny_meta`] adapted to another LRA task's token space: the vocab,
/// class count, and dual-encoder shape come from the task generator, so
/// `cast train --task <t>` can synthesize a runnable config for any
/// task with zero artifacts on disk.
pub fn tiny_meta_for_task(task: &str, variant: &str) -> Result<ModelMeta> {
    let gen = crate::data::task(task)?;
    let mut meta = tiny_meta(variant);
    meta.task = task.to_string();
    meta.vocab = gen.vocab().max(1);
    meta.n_classes = gen.n_classes().max(2);
    meta.dual = gen.dual();
    Ok(meta)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_cast_layout_matches_aot_count_and_order() {
        let specs = param_specs(&tiny_meta("cast_topk"));
        // per block: 11 attn + 4 ffn + 2 + 2 norms = 19; x2 blocks = 38;
        // + embed + 4 head + 2 proj = 45
        assert_eq!(specs.len(), 45);
        assert_eq!(specs[0].name, "blocks.0.attn.phi.b");
        assert_eq!(specs[1].name, "blocks.0.attn.phi.w");
        assert_eq!(specs[1].shape, vec![16, 1]);
        assert_eq!(specs[2].name, "blocks.0.attn.s");
        assert_eq!(specs[2].shape, vec![4, 2, 8]);
        assert_eq!(specs[19].name, "blocks.1.attn.phi.b");
        assert_eq!(specs[38].name, "embed.emb");
        assert_eq!(specs[38].shape, vec![256, 16]);
        assert_eq!(specs[39].name, "head.fc.b");
        assert_eq!(specs[43].name, "proj.b");
        assert_eq!(specs[44].name, "proj.w");
        assert_eq!(specs[44].shape, vec![16, 16]);
        // names are strictly ordered the way sorted-dict flattening yields
        for pair in specs.windows(2) {
            assert_ne!(pair[0].name, pair[1].name);
        }
    }

    #[test]
    fn baseline_layout_drops_cast_params() {
        let cast = param_specs(&tiny_meta("cast_topk"));
        let vanilla = param_specs(&tiny_meta("vanilla"));
        // vanilla loses phi.b, phi.w and s per block
        assert_eq!(cast.len() - vanilla.len(), 2 * 3);
        assert_eq!(vanilla[0].name, "blocks.0.attn.wk.b");
        assert!(vanilla.iter().all(|p| !p.name.contains(".phi.") && !p.name.ends_with(".s")));
    }

    #[test]
    fn tiny_meta_for_task_inherits_task_token_space() {
        let m = tiny_meta_for_task("listops", "cast_topk").unwrap();
        assert_eq!(m.task, "listops");
        assert_eq!(m.n_classes, 10);
        assert!(!m.dual);
        let r = tiny_meta_for_task("retrieval", "vanilla").unwrap();
        assert!(r.dual);
        assert_eq!(r.tokens_shape()[1], 2);
        assert!(tiny_meta_for_task("nope", "vanilla").is_err());
    }

    #[test]
    fn prenorm_and_scale_and_dual_variants() {
        let mut meta = tiny_meta("cast_topk");
        meta.prenorm = true;
        meta.norm = "scale".to_string();
        meta.dual = true;
        let specs = param_specs(&meta);
        // scale norm: one scalar g per norm site
        let norm1: Vec<_> = specs.iter().filter(|p| p.name.contains("norm1")).collect();
        assert_eq!(norm1.len(), 2); // one per block
        assert!(norm1.iter().all(|p| p.shape.is_empty()));
        // out_norm present between head.* and proj.*
        let names: Vec<&str> = specs.iter().map(|p| p.name.as_str()).collect();
        let i_out = names.iter().position(|n| *n == "out_norm.g").unwrap();
        let i_head = names.iter().position(|n| *n == "head.out.w").unwrap();
        let i_proj = names.iter().position(|n| *n == "proj.b").unwrap();
        assert!(i_head < i_out && i_out < i_proj);
        // dual head consumes 4d features
        let fc_w = specs.iter().find(|p| p.name == "head.fc.w").unwrap();
        assert_eq!(fc_w.shape, vec![64, 16]);
    }
}
