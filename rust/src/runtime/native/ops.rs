//! f32 building blocks for the native CPU engine.
//!
//! Semantics mirror `python/compile/layers.py` and
//! `python/compile/kernels/ref.py` (the correctness oracles of the AOT
//! path): same activation definitions, same normalizations, same masking
//! conventions.  Everything is dense row-major `Vec<f32>`; shapes are
//! carried by the callers.
//!
//! Inner loops run on the `util::simd` 8-lane kernel subsystem
//! (DESIGN.md §SIMD): reductions (dot / row sums / row max / squared
//! norms) and the dense matmul microkernel dispatch to explicit lane
//! kernels, with `CAST_NO_SIMD=1` routing every call to the sequential
//! scalar reference.  Transcendentals (`exp`, `erf`, `tanh`) stay
//! scalar-libm on both paths, so lanes-vs-scalar differences come only
//! from the documented reduction reassociation.

use anyhow::{bail, Result};

use crate::util::simd;

/// Additive mask value (matches `kernel_ref.NEG_INF`).
pub const NEG_INF: f32 = -1e9;

/// Row-normalized attention weight function (softmax or MEGA's laplace).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AttnFn {
    Softmax,
    Laplace,
}

impl AttnFn {
    pub fn parse(s: &str) -> Result<AttnFn> {
        Ok(match s {
            "softmax" => AttnFn::Softmax,
            "laplace" => AttnFn::Laplace,
            other => bail!("unknown attention fn {other:?}"),
        })
    }
}

/// `y = x @ w + b` where `x` is (rows, d_in), `w` is (d_in, d_out),
/// `b` is (d_out).
pub fn dense(x: &[f32], w: &[f32], b: &[f32], rows: usize, d_in: usize, d_out: usize) -> Vec<f32> {
    let mut y = Vec::new();
    dense_into(x, w, b, rows, d_in, d_out, &mut y);
    y
}

/// [`dense`] writing into a reusable output buffer (cleared + resized) so
/// callers with a `Workspace` avoid a fresh allocation per layer per call.
///
/// The row range is dispatched across the worker pool in cache-sized row
/// blocks; each block runs the `simd::matmul_rows8` rank-1-update
/// microkernel (weight rows streamed once per 8 output rows, no
/// transpose scratch).  The per-element accumulation order — ascending
/// input dimension — is independent of both the row blocking and the
/// lane/scalar dispatch, so results are bit-for-bit equal for any
/// thread count *and* for `CAST_NO_SIMD` on or off.
pub fn dense_into(
    x: &[f32],
    w: &[f32],
    b: &[f32],
    rows: usize,
    d_in: usize,
    d_out: usize,
    y: &mut Vec<f32>,
) {
    debug_assert_eq!(x.len(), rows * d_in);
    debug_assert_eq!(w.len(), d_in * d_out);
    debug_assert_eq!(b.len(), d_out);
    y.clear();
    y.resize(rows * d_out, 0.0);
    if rows == 0 || d_out == 0 {
        return;
    }
    if rows < 16 {
        // tiny row counts (e.g. the per-batch classifier head): skip the
        // thread-pool dispatch entirely
        simd::matmul_rows8(x, w, b, rows, d_in, d_out, y);
        return;
    }
    let block = crate::util::parallel::row_block(rows);
    crate::util::parallel::par_chunks_mut(y.as_mut_slice(), block * d_out, |ci, out| {
        let r0 = ci * block;
        let nr = out.len() / d_out;
        simd::matmul_rows8(&x[r0 * d_in..(r0 + nr) * d_in], w, b, nr, d_in, d_out, out);
    });
}

/// Unit-stride dot product — the single chunked-reduction implementation
/// every call site shares (8-lane accumulators, or the sequential scalar
/// reference under `CAST_NO_SIMD=1`; see `util::simd`).
pub use crate::util::simd::dot8 as dot;

/// Normalize every `cols`-wide row of `x` in place with the given weight
/// function.  A row that is entirely masked to `NEG_INF` has zero valid
/// slots — there is nothing to attend to, so it becomes all zeros rather
/// than an arbitrary uniform distribution over masked columns.  (Reachable
/// at decode step 0 when a fresh cluster has no members, and via all-masked
/// rows in the fused kernels.)  Partially-masked rows still normalize to 1
/// over the surviving columns; callers multiply by the mask afterwards,
/// exactly like the reference kernel.
pub fn attn_rows(x: &mut [f32], cols: usize, f: AttnFn) {
    debug_assert!(cols > 0 && x.len() % cols == 0);
    match f {
        AttnFn::Softmax => {
            for row in x.chunks_mut(cols) {
                // row max and normalizer are lane reductions; the subtract
                // rides the (scalar-libm) exp pass — elementwise, so still
                // bit-identical across SIMD modes
                let m = simd::max8(row);
                if m <= NEG_INF * 0.5 {
                    // every column masked: no valid slot, weight nothing
                    row.fill(0.0);
                    continue;
                }
                for v in row.iter_mut() {
                    *v = (*v - m).exp();
                }
                let z = simd::sum8(row);
                simd::scale8(row, 1.0 / z.max(1e-30));
            }
        }
        AttnFn::Laplace => {
            // MEGA (Ma et al., 2023): phi_laplace with mu = sqrt(1/2),
            // sigma = sqrt(1/(4*pi)), rescaled row-wise to a distribution.
            let mu = 0.5f32.sqrt();
            let sigma = (0.25 / std::f32::consts::PI).sqrt();
            let denom = sigma * 2.0f32.sqrt();
            for row in x.chunks_mut(cols) {
                let m = simd::max8(row);
                if m <= NEG_INF * 0.5 {
                    row.fill(0.0);
                    continue;
                }
                for v in row.iter_mut() {
                    *v = 0.5 * (1.0 + erf((*v - mu) / denom));
                }
                let z = simd::sum8(row);
                simd::scale8(row, 1.0 / z.max(1e-6));
            }
        }
    }
}

/// Error function (Abramowitz & Stegun 7.1.26, |err| < 1.5e-7).
pub fn erf(x: f32) -> f32 {
    let sign: f64 = if x < 0.0 { -1.0 } else { 1.0 };
    let x = x.abs() as f64;
    let t = 1.0 / (1.0 + 0.327_591_1 * x);
    let poly = t
        * (0.254_829_592
            + t * (-0.284_496_736 + t * (1.421_413_741 + t * (-1.453_152_027 + t * 1.061_405_429))));
    (sign * (1.0 - poly * (-x * x).exp())) as f32
}

/// d erf / dx = 2/sqrt(pi) * exp(-x^2) (the laplace attention backward).
pub fn erf_prime(x: f32) -> f32 {
    std::f32::consts::FRAC_2_SQRT_PI * (-x * x).exp()
}

pub fn sigmoid(x: f32) -> f32 {
    if x >= 0.0 {
        1.0 / (1.0 + (-x).exp())
    } else {
        let e = x.exp();
        e / (1.0 + e)
    }
}

/// Numerically stable softplus.
pub fn softplus(x: f32) -> f32 {
    if x > 20.0 {
        x
    } else if x < -20.0 {
        x.exp()
    } else {
        x.exp().ln_1p()
    }
}

/// `softplus(x) + 1` (Zheng et al., 2015), used in paper eq. 4/5.
pub fn softplus1(x: f32) -> f32 {
    softplus(x) + 1.0
}

/// Gelu with the tanh approximation (jax.nn.gelu's default).
pub fn gelu(x: f32) -> f32 {
    const C: f32 = 0.797_884_6; // sqrt(2/pi)
    0.5 * x * (1.0 + (C * (x + 0.044_715 * x * x * x)).tanh())
}

/// Apply [`gelu`] to every element in place — the one FFN-activation
/// loop the forward, the taped forward, and the backward recompute all
/// share.  Elementwise with a scalar-libm `tanh`, so it is bit-identical
/// across SIMD modes and thread counts by construction.
pub fn gelu_rows(x: &mut [f32]) {
    for v in x.iter_mut() {
        *v = gelu(*v);
    }
}

/// d gelu / dx for the tanh approximation (the head-gradient path).
pub fn gelu_prime(x: f32) -> f32 {
    const C: f32 = 0.797_884_6;
    let u = C * (x + 0.044_715 * x * x * x);
    let t = u.tanh();
    let du = C * (1.0 + 3.0 * 0.044_715 * x * x);
    0.5 * (1.0 + t) + 0.5 * x * (1.0 - t * t) * du
}

/// LayerNorm over the last dimension: `g * (x - mu) / sqrt(var + eps) + b`.
pub fn layernorm_rows(x: &mut [f32], g: &[f32], b: &[f32], d: usize, eps: f32) {
    debug_assert!(x.len() % d == 0);
    for row in x.chunks_mut(d) {
        let mu = simd::sum8(row) / d as f32;
        let var = simd::sumsq_diff8(row, mu) / d as f32;
        let inv = 1.0 / (var + eps).sqrt();
        simd::norm_affine8(row, g, b, mu, inv);
    }
}

/// ScaleNorm (Nguyen & Salazar, 2019): `g * x * sqrt(d) / ||x||`.
pub fn scalenorm_rows(x: &mut [f32], g: f32, d: usize, eps: f32) {
    debug_assert!(x.len() % d == 0);
    let sqrt_d = (d as f32).sqrt();
    for row in x.chunks_mut(d) {
        let rms = (simd::sumsq_diff8(row, 0.0) + eps).sqrt();
        simd::scale8(row, g * sqrt_d / rms);
    }
}

/// Fixed sinusoidal positional embeddings (Vaswani et al., 2017), matching
/// `layers.sinusoidal_positions`: `(n, d)` with sin block then cos block.
pub fn sinusoidal_positions(n: usize, d: usize) -> Vec<f32> {
    let half = d.div_ceil(2);
    let mut pe = vec![0.0f32; n * d];
    for pos in 0..n {
        for j in 0..half {
            let freq = (-(10000.0f64.ln()) * j as f64 / half as f64).exp();
            let ang = pos as f64 * freq;
            pe[pos * d + j] = ang.sin() as f32;
            let cj = half + j;
            if cj < d {
                pe[pos * d + cj] = ang.cos() as f32;
            }
        }
    }
    pe
}

/// One row of [`sinusoidal_positions`] — bit-identical to row `pos` of
/// the full table for any table length (a row depends only on `pos` and
/// `d`), so the decode path embeds one appended token without building an
/// O(n·d) table.
pub fn sinusoidal_position_row(pos: usize, d: usize) -> Vec<f32> {
    let half = d.div_ceil(2);
    let mut pe = vec![0.0f32; d];
    for j in 0..half {
        let freq = (-(10000.0f64.ln()) * j as f64 / half as f64).exp();
        let ang = pos as f64 * freq;
        pe[j] = ang.sin() as f32;
        let cj = half + j;
        if cj < d {
            pe[cj] = ang.cos() as f32;
        }
    }
    pe
}

/// Stable descending argsort (ties keep the lower index first — the same
/// order `lax.sort_key_val` over `(-x, iota)` produces).
pub fn argsort_desc(scores: &[f32]) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..scores.len()).collect();
    idx.sort_by(|&a, &b| scores[b].partial_cmp(&scores[a]).unwrap_or(std::cmp::Ordering::Equal));
    idx
}

/// The `(score desc, index asc)` total order underlying [`argsort_desc`]
/// — index tiebreak makes it equivalent to the stable sort without the
/// stability (and allocation) cost.
#[inline]
fn desc_by(scores: &[f32]) -> impl Fn(&usize, &usize) -> std::cmp::Ordering + Copy + '_ {
    move |&a, &b| {
        scores[b]
            .partial_cmp(&scores[a])
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(&b))
    }
}

/// Fill `idx` so that `idx[..k]` holds the indices of the `k` largest
/// entries of `scores` in stable descending order — identical to
/// `argsort_desc(scores)[..k]` but O(N + k log k) via quickselect instead
/// of a full O(N log N) sort, and allocation-free when `idx` is reused.
pub fn top_k_desc(scores: &[f32], k: usize, idx: &mut Vec<usize>) {
    let n = scores.len();
    let k = k.min(n);
    idx.clear();
    idx.extend(0..n);
    if k == 0 {
        return;
    }
    let cmp = desc_by(scores);
    if k < n {
        idx.select_nth_unstable_by(k - 1, cmp);
    }
    idx[..k].sort_unstable_by(cmp);
}

/// Fill `idx` with the full descending argsort of `scores`, reusing the
/// buffer (same order as [`argsort_desc`]).
pub fn argsort_desc_into(scores: &[f32], idx: &mut Vec<usize>) {
    idx.clear();
    idx.extend(0..scores.len());
    idx.sort_unstable_by(desc_by(scores));
}

/// Elementwise `x += y`, dispatched across the worker pool.
pub fn add_assign(x: &mut [f32], y: &[f32]) {
    debug_assert_eq!(x.len(), y.len());
    let block = crate::util::parallel::elem_block(x.len());
    crate::util::parallel::par_chunks_mut(x, block, |ci, chunk| {
        let off = ci * block;
        simd::add8(chunk, &y[off..off + chunk.len()]);
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dense_matches_manual() {
        // x (2,3) @ w (3,2) + b
        let x = [1.0, 2.0, 3.0, 0.5, -1.0, 0.0];
        let w = [1.0, 0.0, 0.0, 1.0, 1.0, 1.0];
        let b = [10.0, 20.0];
        let y = dense(&x, &w, &b, 2, 3, 2);
        assert_eq!(y, vec![14.0, 25.0, 10.5, 19.0]);
    }

    #[test]
    fn softmax_rows_normalize() {
        let mut x = vec![1.0, 2.0, 3.0, -1.0, NEG_INF, -1.0];
        attn_rows(&mut x, 3, AttnFn::Softmax);
        for row in x.chunks(3) {
            let s: f32 = row.iter().sum();
            assert!((s - 1.0).abs() < 1e-5, "row sums to {s}");
        }
        assert!(x[2] > x[1] && x[1] > x[0]);
        assert!(x[4] < 1e-6, "masked entry must vanish: {}", x[4]);
    }

    #[test]
    fn laplace_rows_normalize_and_mask() {
        let mut x = vec![0.5, 1.5, NEG_INF];
        attn_rows(&mut x, 3, AttnFn::Laplace);
        let s: f32 = x.iter().sum();
        assert!((s - 1.0).abs() < 1e-4, "sums to {s}");
        assert!(x[2] < 1e-6);
        assert!(x[1] > x[0]);
    }

    #[test]
    fn erf_reference_points() {
        assert!(erf(0.0).abs() < 1e-6);
        assert!((erf(1.0) - 0.8427).abs() < 1e-3);
        assert!((erf(-1.0) + 0.8427).abs() < 1e-3);
        assert!((erf(3.0) - 1.0).abs() < 1e-4);
    }

    #[test]
    fn erf_prime_matches_numeric_derivative() {
        for &x in &[-2.0f32, -0.5, 0.0, 0.3, 1.7] {
            let h = 1e-3;
            let num = (erf(x + h) - erf(x - h)) / (2.0 * h);
            assert!(
                (num - erf_prime(x)).abs() < 1e-2,
                "x={x}: {num} vs {}",
                erf_prime(x)
            );
        }
        // vanishes fast in the tails (masked scores must not explode)
        assert_eq!(erf_prime(-1e6), 0.0);
    }

    #[test]
    fn gelu_and_derivative() {
        assert!(gelu(0.0).abs() < 1e-6);
        assert!((gelu(10.0) - 10.0).abs() < 1e-3);
        assert!(gelu(-10.0).abs() < 1e-3);
        // numeric derivative check
        for &x in &[-2.0f32, -0.5, 0.0, 0.7, 2.5] {
            let h = 1e-3;
            let num = (gelu(x + h) - gelu(x - h)) / (2.0 * h);
            assert!((num - gelu_prime(x)).abs() < 1e-2, "x={x}: {num} vs {}", gelu_prime(x));
        }
    }

    #[test]
    fn softplus_stable() {
        assert!((softplus(0.0) - 0.6931).abs() < 1e-3);
        assert!((softplus(30.0) - 30.0).abs() < 1e-3);
        assert!(softplus(-30.0) >= 0.0 && softplus(-30.0) < 1e-6);
        assert!((softplus1(0.0) - 1.6931).abs() < 1e-3);
    }

    #[test]
    fn layernorm_zero_mean_unit_var() {
        let d = 4;
        let mut x = vec![1.0, 2.0, 3.0, 4.0];
        let g = vec![1.0; d];
        let b = vec![0.0; d];
        layernorm_rows(&mut x, &g, &b, d, 1e-5);
        let mu: f32 = x.iter().sum::<f32>() / d as f32;
        let var: f32 = x.iter().map(|v| (v - mu) * (v - mu)).sum::<f32>() / d as f32;
        assert!(mu.abs() < 1e-5);
        assert!((var - 1.0).abs() < 1e-3);
    }

    #[test]
    fn scalenorm_sets_norm() {
        let d = 4;
        let mut x = vec![3.0, 0.0, 4.0, 0.0]; // ||x|| = 5
        scalenorm_rows(&mut x, 1.0, d, 1e-5);
        let norm = x.iter().map(|v| v * v).sum::<f32>().sqrt();
        assert!((norm - (d as f32).sqrt()).abs() < 1e-3, "norm {norm}");
    }

    #[test]
    fn sinusoidal_shape_and_range() {
        let pe = sinusoidal_positions(8, 6);
        assert_eq!(pe.len(), 48);
        assert!(pe.iter().all(|v| v.abs() <= 1.0 + 1e-6));
        // position 0: sin block is 0, cos block is 1
        assert!(pe[0].abs() < 1e-6);
        assert!((pe[3] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn argsort_desc_stable_ties() {
        assert_eq!(argsort_desc(&[0.5, 0.9, 0.5, 0.1]), vec![1, 0, 2, 3]);
    }

    #[test]
    fn top_k_matches_full_argsort_prefix() {
        let mut rng = crate::util::rng::Rng::new(42);
        let mut idx = Vec::new();
        for n in [1usize, 2, 7, 33, 100] {
            // include duplicates to exercise the index tiebreak
            let scores: Vec<f32> = (0..n).map(|_| (rng.f32() * 8.0).floor() / 8.0).collect();
            let full = argsort_desc(&scores);
            for k in [0usize, 1, n / 2, n.saturating_sub(1), n] {
                top_k_desc(&scores, k, &mut idx);
                assert_eq!(&idx[..k], &full[..k], "n={n} k={k}");
            }
            argsort_desc_into(&scores, &mut idx);
            assert_eq!(idx, full, "n={n} full argsort");
        }
    }

    #[test]
    fn dot_handles_remainders() {
        let a: Vec<f32> = (0..11).map(|i| i as f32).collect();
        let b: Vec<f32> = (0..11).map(|i| (i as f32) * 0.5).collect();
        let expect: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert!((dot(&a, &b) - expect).abs() < 1e-4);
        assert_eq!(dot(&[], &[]), 0.0);
    }

    #[test]
    fn dense_into_reuses_buffer() {
        let x = [1.0, 2.0, 3.0, 0.5, -1.0, 0.0];
        let w = [1.0, 0.0, 0.0, 1.0, 1.0, 1.0];
        let b = [10.0, 20.0];
        let mut y = vec![99.0f32; 64];
        dense_into(&x, &w, &b, 2, 3, 2, &mut y);
        assert_eq!(y, vec![14.0, 25.0, 10.5, 19.0]);
    }

    #[test]
    fn add_assign_elementwise() {
        let mut x: Vec<f32> = (0..300).map(|i| i as f32).collect();
        let y: Vec<f32> = (0..300).map(|i| 2.0 * i as f32).collect();
        add_assign(&mut x, &y);
        for (i, v) in x.iter().enumerate() {
            assert_eq!(*v, 3.0 * i as f32);
        }
    }
}
