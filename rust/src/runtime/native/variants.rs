//! The attention-variant registry: the ONE place that maps a variant
//! name to its behavior.
//!
//! Every other layer resolves variants through this table — `model.rs`
//! (forward), `grad/model.rs` (taped forward + backward), `spec.rs`
//! (parameter schema), `artifacts.rs` (`ModelMeta` capability queries +
//! artifact keys), the serve registry and the CLI (name validation, HELP
//! text).  Adding a variant means writing its module (forward + tape +
//! backward) and extending the `AttnVariant` enum + the `match` arms in
//! this file; nothing else in the codebase enumerates variants by hand
//! (tests iterate [`ALL`]).
//!
//! The seam's contract, per variant:
//! * **params** — either the CAST schema (baseline 8 + `phi` + `s`) or
//!   the baseline 8-tensor schema (`wq/wk/wv/wo` × `w/b`), selected by
//!   [`AttnVariant::is_cast`]; `spec.rs` lays tensors out from it.
//! * **forward** — `(out, a_g)` where `a_g` is the (B·N, Nc) cluster
//!   affinity block (zeros unless [`AttnVariant::supports_ag`]).
//! * **tape** — an [`AttnTape`] arm: whatever the backward needs beyond
//!   recomputation, plus a fingerprint of every *discrete* choice
//!   (cluster assignments, top-k selections, bucket orders) so gradient
//!   checks can skip perturbations that cross a decision boundary.
//! * **backward** — exact reverse-mode gradients with the discrete
//!   choices held fixed (straight-through), accumulating into the
//!   manifest-ordered gradient run returned by [`grad_param_names`].
//! * **determinism** — results must be bit-identical across
//!   `CAST_NUM_THREADS`: parallel tasks own disjoint output chunks and
//!   every reduction runs in a fixed (ascending-index) order.

use anyhow::{bail, Result};

use super::clustered::{self, ClusteredTape};
use super::grad::layer as glayer;
use super::layer::{self as flayer, BaselineParams, CastParams, CastScratch, Dims};
use super::model::Params;
use super::tost;

/// One attention mechanism behind the layer seam.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AttnVariant {
    /// CAST with Top-K clustering (paper Algorithm 1).
    CastTopk,
    /// CAST with single-assignment clustering (paper §3.2; the causal
    /// decoder extension rides on this mechanism).
    CastSa,
    /// Full softmax attention (the Transformer baseline).
    Vanilla,
    /// Non-overlapping local window attention.
    Local,
    /// LSH-bucketed chunked attention (Reformer-style baseline).
    Lsh,
    /// K-means clustered attention with exact top-κ correction
    /// (Vyas et al., arXiv 2007.04825).
    Clustered,
    /// Token-Statistics-style linear attention (arXiv 2412.17810).
    Tost,
}

/// Every registered variant, in canonical order (tests and `cast gen`
/// enumerate this instead of hand-written lists).
pub const ALL: [AttnVariant; 7] = [
    AttnVariant::CastTopk,
    AttnVariant::CastSa,
    AttnVariant::Vanilla,
    AttnVariant::Local,
    AttnVariant::Lsh,
    AttnVariant::Clustered,
    AttnVariant::Tost,
];

/// The registered variant names, aligned with [`ALL`].
pub const NAMES: [&str; 7] =
    ["cast_topk", "cast_sa", "vanilla", "local", "lsh", "clustered", "tost"];

/// The default variant for synthesized configs.
pub const DEFAULT: AttnVariant = AttnVariant::CastTopk;

impl AttnVariant {
    pub const fn name(self) -> &'static str {
        match self {
            AttnVariant::CastTopk => "cast_topk",
            AttnVariant::CastSa => "cast_sa",
            AttnVariant::Vanilla => "vanilla",
            AttnVariant::Local => "local",
            AttnVariant::Lsh => "lsh",
            AttnVariant::Clustered => "clustered",
            AttnVariant::Tost => "tost",
        }
    }

    /// Resolve a variant name; the error lists every registered name.
    pub fn parse(name: &str) -> Result<AttnVariant> {
        for v in ALL {
            if v.name() == name {
                return Ok(v);
            }
        }
        bail!("unknown attention variant {name:?} (know {NAMES:?})")
    }

    /// Uses the CAST parameter schema (surrogate tokens `s` + the φ
    /// scorer) instead of the baseline 8-tensor schema.
    pub const fn is_cast(self) -> bool {
        matches!(self, AttnVariant::CastTopk | AttnVariant::CastSa)
    }

    /// Emits real cluster-affinity matrices A_g, so `predict_ag` (and
    /// the fig-4 cluster viz in `analysis/clusters.rs`) works.  Dual
    /// (two-tower) models pool per tower and expose no single A_g.
    pub const fn supports_ag(self, dual: bool) -> bool {
        matches!(self, AttnVariant::CastTopk | AttnVariant::CastSa | AttnVariant::Clustered)
            && !dual
    }

    /// The CAST clustering mechanism G this variant runs ("topk" | "sa"
    /// | "causal"); non-CAST variants keep the "topk" default (unused).
    pub const fn clustering(self, causal: bool) -> &'static str {
        if causal {
            "causal"
        } else if matches!(self, AttnVariant::CastSa) {
            "sa"
        } else {
            "topk"
        }
    }

    /// Artifact keys carry the `c{n_c}_k{kappa}` suffix (cluster-shaped
    /// geometry matters to this variant).
    pub const fn key_has_clusters(self) -> bool {
        matches!(
            self,
            AttnVariant::CastTopk
                | AttnVariant::CastSa
                | AttnVariant::Lsh
                | AttnVariant::Clustered
        )
    }

    /// Artifact keys carry the `w{window}` suffix.
    pub const fn key_has_window(self) -> bool {
        matches!(self, AttnVariant::Local)
    }
}

/// True when `name` resolves in the registry.
pub fn is_valid(name: &str) -> bool {
    AttnVariant::parse(name).is_ok()
}

// ---------------------------------------------------------------------------
// parameter binding
// ---------------------------------------------------------------------------

fn cast_params<'a>(p: &Params<'a>, prefix: &str) -> Result<CastParams<'a>> {
    Ok(CastParams {
        wq_w: p.f(&format!("{prefix}.wq.w"))?,
        wq_b: p.f(&format!("{prefix}.wq.b"))?,
        wk_w: p.f(&format!("{prefix}.wk.w"))?,
        wk_b: p.f(&format!("{prefix}.wk.b"))?,
        wv_w: p.f(&format!("{prefix}.wv.w"))?,
        wv_b: p.f(&format!("{prefix}.wv.b"))?,
        wo_w: p.f(&format!("{prefix}.wo.w"))?,
        wo_b: p.f(&format!("{prefix}.wo.b"))?,
        s: p.f(&format!("{prefix}.s"))?,
        phi_w: p.f(&format!("{prefix}.phi.w"))?,
        phi_b: p.f(&format!("{prefix}.phi.b"))?,
    })
}

fn baseline_params<'a>(p: &Params<'a>, prefix: &str) -> Result<BaselineParams<'a>> {
    Ok(BaselineParams {
        wq_w: p.f(&format!("{prefix}.wq.w"))?,
        wq_b: p.f(&format!("{prefix}.wq.b"))?,
        wk_w: p.f(&format!("{prefix}.wk.w"))?,
        wk_b: p.f(&format!("{prefix}.wk.b"))?,
        wv_w: p.f(&format!("{prefix}.wv.w"))?,
        wv_b: p.f(&format!("{prefix}.wv.b"))?,
        wo_w: p.f(&format!("{prefix}.wo.w"))?,
        wo_b: p.f(&format!("{prefix}.wo.b"))?,
    })
}

fn zero_ag(dims: &Dims) -> Vec<f32> {
    vec![0.0f32; dims.b * dims.n * dims.n_c]
}

/// Trace span name of one forward dispatch (static, per variant).
const fn attn_span_name(v: AttnVariant) -> &'static str {
    match v {
        AttnVariant::CastTopk => "attn.cast_topk",
        AttnVariant::CastSa => "attn.cast_sa",
        AttnVariant::Vanilla => "attn.vanilla",
        AttnVariant::Local => "attn.local",
        AttnVariant::Lsh => "attn.lsh",
        AttnVariant::Clustered => "attn.clustered",
        AttnVariant::Tost => "attn.tost",
    }
}

// ---------------------------------------------------------------------------
// forward dispatch
// ---------------------------------------------------------------------------

/// One attention layer forward: `(out, a_g)`.  `a_g` is all-zero for
/// variants without [`AttnVariant::supports_ag`] (model.py returns zeros
/// for baselines too).
pub fn attn_forward(
    v: AttnVariant,
    p: &Params,
    prefix: &str,
    x: &[f32],
    dims: &Dims,
    ws: &mut CastScratch,
) -> Result<(Vec<f32>, Vec<f32>)> {
    // per-layer compute fault point (chaos testing: `err` bubbles up as
    // an engine failure, `panic` exercises the serve worker isolation,
    // `delay` models a slow layer); `prefix` names the firing layer
    if crate::util::fault::active() {
        crate::util::fault::check("engine.layer")
            .map_err(|e| anyhow::anyhow!("{e} (layer {prefix})"))?;
    }
    let _t = crate::util::trace::span(attn_span_name(v));
    let (out, a_g) = match v {
        AttnVariant::CastTopk | AttnVariant::CastSa => {
            flayer::cast_layer(&cast_params(p, prefix)?, x, dims, ws)?
        }
        AttnVariant::Vanilla => {
            (flayer::vanilla_layer(&baseline_params(p, prefix)?, x, dims)?, zero_ag(dims))
        }
        AttnVariant::Local => {
            (flayer::local_layer(&baseline_params(p, prefix)?, x, dims)?, zero_ag(dims))
        }
        AttnVariant::Lsh => {
            (flayer::lsh_layer(&baseline_params(p, prefix)?, x, dims)?, zero_ag(dims))
        }
        AttnVariant::Clustered => {
            clustered::clustered_layer(&baseline_params(p, prefix)?, x, dims)?
        }
        AttnVariant::Tost => {
            (tost::tost_layer(&baseline_params(p, prefix)?, x, dims)?, zero_ag(dims))
        }
    };
    // cluster-health tap (one relaxed load when off): reads the affinity
    // block only *after* the layer computed it, so logits are bit-identical
    // with stats on or off; only variants with a real A_g are recorded
    if super::cluster_stats::active() && v.supports_ag(false) {
        super::cluster_stats::record(
            super::cluster_stats::layer_of_prefix(prefix),
            dims.b,
            dims.n,
            dims.n_c,
            &a_g,
        );
    }
    Ok((out, a_g))
}

// ---------------------------------------------------------------------------
// taped forward + backward dispatch
// ---------------------------------------------------------------------------

/// Forward intermediates of one attention layer, for the reverse pass.
pub enum AttnTape {
    Cast(glayer::CastTape),
    /// Only the layer input is stored; everything is recomputed
    /// (vanilla / local / tost — fully smooth layers).
    Input(Vec<f32>),
    Lsh(glayer::LshTape),
    Clustered(ClusteredTape),
}

/// Fingerprint of every discrete (non-differentiable) choice the layer
/// made; gradient checks skip perturbations that change it.
pub fn attn_fingerprint(tape: &AttnTape) -> u64 {
    match tape {
        AttnTape::Cast(t) => t.fingerprint(),
        AttnTape::Input(_) => 0,
        AttnTape::Lsh(t) => t.fingerprint(),
        AttnTape::Clustered(t) => t.fingerprint(),
    }
}

/// One attention layer forward with tape capture.  Arithmetic matches
/// [`attn_forward`] bit-for-bit (the parity test in `grad/model.rs`
/// enumerates the registry).
pub fn attn_forward_tape(
    v: AttnVariant,
    p: &Params,
    prefix: &str,
    x: &[f32],
    dims: &Dims,
    cast_fwd: &mut CastScratch,
) -> Result<(Vec<f32>, AttnTape)> {
    match v {
        AttnVariant::CastTopk | AttnVariant::CastSa => {
            let cp = cast_params(p, prefix)?;
            let (out, ag) = flayer::cast_layer(&cp, x, dims, cast_fwd)?;
            // same cluster-health tap as attn_forward, so training steps
            // feed the per-layer churn/collapse telemetry too
            if super::cluster_stats::active() {
                super::cluster_stats::record(
                    super::cluster_stats::layer_of_prefix(prefix),
                    dims.b,
                    dims.n,
                    dims.n_c,
                    &ag,
                );
            }
            Ok((out, AttnTape::Cast(glayer::CastTape::capture(x, cast_fwd))))
        }
        AttnVariant::Vanilla => {
            let bp = baseline_params(p, prefix)?;
            Ok((flayer::vanilla_layer(&bp, x, dims)?, AttnTape::Input(x.to_vec())))
        }
        AttnVariant::Local => {
            let bp = baseline_params(p, prefix)?;
            Ok((flayer::local_layer(&bp, x, dims)?, AttnTape::Input(x.to_vec())))
        }
        AttnVariant::Lsh => {
            let bp = baseline_params(p, prefix)?;
            let (out, tape) = glayer::lsh_forward_tape(&bp, x, dims)?;
            Ok((out, AttnTape::Lsh(tape)))
        }
        AttnVariant::Clustered => {
            let bp = baseline_params(p, prefix)?;
            let (out, tape) = clustered::clustered_forward_tape(&bp, x, dims)?;
            Ok((out, AttnTape::Clustered(tape)))
        }
        AttnVariant::Tost => {
            let bp = baseline_params(p, prefix)?;
            Ok((tost::tost_layer(&bp, x, dims)?, AttnTape::Input(x.to_vec())))
        }
    }
}

/// The variant's gradient-buffer run: its attention parameter names in
/// manifest (lexicographic) order, as consumed by `GradStore::consecutive`
/// and destructured by [`attn_backward`].
pub fn grad_param_names(v: AttnVariant, prefix: &str) -> Vec<String> {
    if v.is_cast() {
        vec![
            format!("{prefix}.phi.b"),
            format!("{prefix}.phi.w"),
            format!("{prefix}.s"),
            format!("{prefix}.wk.b"),
            format!("{prefix}.wk.w"),
            format!("{prefix}.wo.b"),
            format!("{prefix}.wo.w"),
            format!("{prefix}.wq.b"),
            format!("{prefix}.wq.w"),
            format!("{prefix}.wv.b"),
            format!("{prefix}.wv.w"),
        ]
    } else {
        vec![
            format!("{prefix}.wk.b"),
            format!("{prefix}.wk.w"),
            format!("{prefix}.wo.b"),
            format!("{prefix}.wo.w"),
            format!("{prefix}.wq.b"),
            format!("{prefix}.wq.w"),
            format!("{prefix}.wv.b"),
            format!("{prefix}.wv.w"),
        ]
    }
}

/// One attention layer backward.  `grad_bufs` is the consecutive
/// gradient run for [`grad_param_names`]`(v, prefix)`, in that order;
/// `dx_acc` accumulates the input gradient.
#[allow(clippy::too_many_arguments)]
pub fn attn_backward(
    v: AttnVariant,
    p: &Params,
    prefix: &str,
    tape: &AttnTape,
    dims: &Dims,
    d_out: &[f32],
    dx_acc: &mut [f32],
    grad_bufs: &mut [Vec<f32>],
    cast_bwd: &mut glayer::CastBwdScratch,
    base_bwd: &mut glayer::BaselineBwdScratch,
) -> Result<()> {
    if v.is_cast() {
        let AttnTape::Cast(t) = tape else {
            bail!("attention tape does not match variant {:?}", v.name())
        };
        let cp = cast_params(p, prefix)?;
        let [phi_b, phi_w, s, wk_b, wk_w, wo_b, wo_w, wq_b, wq_w, wv_b, wv_w] = grad_bufs
        else {
            bail!("gradient run for {:?} must have 11 buffers", v.name())
        };
        let mut g = glayer::CastGradRefs {
            wq_w: wq_w.as_mut_slice(),
            wq_b: wq_b.as_mut_slice(),
            wk_w: wk_w.as_mut_slice(),
            wk_b: wk_b.as_mut_slice(),
            wv_w: wv_w.as_mut_slice(),
            wv_b: wv_b.as_mut_slice(),
            wo_w: wo_w.as_mut_slice(),
            wo_b: wo_b.as_mut_slice(),
            s: s.as_mut_slice(),
            phi_w: phi_w.as_mut_slice(),
            phi_b: phi_b.as_mut_slice(),
        };
        return glayer::cast_layer_backward(&cp, t, dims, d_out, dx_acc, &mut g, cast_bwd);
    }
    let bp = baseline_params(p, prefix)?;
    let [wk_b, wk_w, wo_b, wo_w, wq_b, wq_w, wv_b, wv_w] = grad_bufs else {
        bail!("gradient run for {:?} must have 8 buffers", v.name())
    };
    let mut g = glayer::BaselineGradRefs {
        wq_w: wq_w.as_mut_slice(),
        wq_b: wq_b.as_mut_slice(),
        wk_w: wk_w.as_mut_slice(),
        wk_b: wk_b.as_mut_slice(),
        wv_w: wv_w.as_mut_slice(),
        wv_b: wv_b.as_mut_slice(),
        wo_w: wo_w.as_mut_slice(),
        wo_b: wo_b.as_mut_slice(),
    };
    match (v, tape) {
        (AttnVariant::Vanilla, AttnTape::Input(x)) => {
            glayer::window_backward(&bp, x, dims, None, d_out, dx_acc, &mut g, base_bwd)
        }
        (AttnVariant::Local, AttnTape::Input(x)) => {
            let w = dims.window.min(dims.n).max(1);
            glayer::window_backward(&bp, x, dims, Some(w), d_out, dx_acc, &mut g, base_bwd)
        }
        (AttnVariant::Lsh, AttnTape::Lsh(t)) => {
            glayer::lsh_backward(&bp, t, dims, d_out, dx_acc, &mut g, base_bwd)
        }
        (AttnVariant::Clustered, AttnTape::Clustered(t)) => {
            clustered::clustered_backward(&bp, t, dims, d_out, dx_acc, &mut g)
        }
        (AttnVariant::Tost, AttnTape::Input(x)) => {
            tost::tost_backward(&bp, x, dims, d_out, dx_acc, &mut g)
        }
        _ => bail!("attention tape does not match variant {:?}", v.name()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_align_with_all_and_roundtrip() {
        assert_eq!(ALL.len(), NAMES.len());
        for (v, name) in ALL.iter().zip(NAMES.iter()) {
            assert_eq!(v.name(), *name);
            assert_eq!(AttnVariant::parse(name).unwrap(), *v);
            assert!(is_valid(name));
        }
        // names are unique
        let mut sorted: Vec<&str> = NAMES.to_vec();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), NAMES.len());
        assert!(ALL.contains(&DEFAULT));
    }

    #[test]
    fn unknown_variant_error_lists_registry() {
        let err = AttnVariant::parse("performer").unwrap_err().to_string();
        for name in NAMES {
            assert!(err.contains(name), "{err:?} missing {name}");
        }
        assert!(!is_valid("performer"));
    }

    #[test]
    fn capability_table() {
        use AttnVariant::*;
        for v in ALL {
            assert_eq!(v.is_cast(), matches!(v, CastTopk | CastSa));
            // ag needs a non-dual model and a clustering mechanism
            assert_eq!(v.supports_ag(false), matches!(v, CastTopk | CastSa | Clustered));
            assert!(!v.supports_ag(true));
        }
        assert_eq!(CastSa.clustering(false), "sa");
        assert_eq!(CastSa.clustering(true), "causal");
        assert_eq!(CastTopk.clustering(false), "topk");
        assert_eq!(Clustered.clustering(false), "topk");
        assert!(Clustered.key_has_clusters() && !Clustered.key_has_window());
        assert!(Local.key_has_window() && !Local.key_has_clusters());
        assert!(!Tost.key_has_clusters() && !Tost.key_has_window());
    }

    #[test]
    fn grad_param_name_counts_match_schema() {
        for v in ALL {
            let names = grad_param_names(v, "blocks.0.attn");
            assert_eq!(names.len(), if v.is_cast() { 11 } else { 8 });
            // manifest order is lexicographic within the run
            let mut sorted = names.clone();
            sorted.sort();
            assert_eq!(names, sorted);
        }
    }
}
