//! Native encoder model: embedding → blocks (attention + FFN + norms) →
//! mean-pool → classifier head, mirroring `python/compile/model.py`.
//!
//! Entry points match the AOT program contracts exactly (same flat
//! parameter order, same input/output arity), so `ModelState`, the
//! trainer, and the analysis code are backend-agnostic:
//!
//!   init       (seed u32)                          → P param tensors
//!   predict    (P params, tokens)                  → logits (B, classes)
//!   predict_ag (P params, tokens)                  → A_g (L, B, N, Nc)
//!   train_step (P params, P m, P v, step, lr, tokens, labels)
//!                                                  → (P, P, P, step', loss, acc)
//!
//! Training scope: by default `train_step` backpropagates through the
//! **whole model** (`runtime::native::grad` — every CAST layer, norms,
//! FFNs, embedding, pooling, head) and applies a full-parameter AdamW
//! update with the same global-norm clipping as
//! `python/compile/train.py`.  The PR-1 head-only path (exact classifier
//! gradients, frozen backbone) is kept for regression comparison behind
//! `CAST_TRAIN_SCOPE=head` or a `train_scope: "head"` entry in the
//! manifest's `config` object.

use std::collections::HashMap;

use anyhow::{bail, ensure, Context, Result};

use crate::runtime::artifacts::{Manifest, ModelMeta, ParamSpec};
use crate::runtime::tensor::HostTensor;
use crate::util::json::Json;
use crate::util::parallel;
use crate::util::rng::Rng;
use crate::util::simd;
use crate::util::trace;

use super::grad;
use super::layer::{CastScratch, Dims};
use super::ops::{self, AttnFn};
use super::variants;

const ADAM_B1: f32 = 0.9;
const ADAM_B2: f32 = 0.999;
const ADAM_EPS: f32 = 1e-8;
const WEIGHT_DECAY: f32 = 1e-2;
const GRAD_CLIP: f32 = 1.0;
pub(crate) const NORM_EPS: f32 = 1e-5;

/// Pre-clip global gradient norm of the most recent `train_step` on any
/// thread (f32 bits in an atomic).  The program contract fixes the
/// output arity of `train_step`, so the trainer's metrics stream reads
/// this side-channel instead of a new output tensor.  Purely
/// observational: never read back into the math.
static LAST_GRAD_NORM: std::sync::atomic::AtomicU32 = std::sync::atomic::AtomicU32::new(0);

/// The global gradient norm recorded by the last [`run_train_step`]
/// (0.0 before any step has run).
pub fn last_grad_norm() -> f32 {
    f32::from_bits(LAST_GRAD_NORM.load(std::sync::atomic::Ordering::Relaxed))
}

/// Borrowed flat parameter list, addressable by manifest name.
pub struct Params<'a> {
    by_name: HashMap<&'a str, &'a HostTensor>,
}

impl<'a> Params<'a> {
    pub fn bind(specs: &'a [ParamSpec], bufs: &[&'a HostTensor]) -> Result<Params<'a>> {
        ensure!(
            specs.len() == bufs.len(),
            "expected {} parameter tensors, got {}",
            specs.len(),
            bufs.len()
        );
        let mut by_name = HashMap::with_capacity(specs.len());
        for (spec, &buf) in specs.iter().zip(bufs.iter()) {
            ensure!(
                buf.shape == spec.shape,
                "param {:?}: tensor shape {:?} does not match manifest {:?}",
                spec.name,
                buf.shape,
                spec.shape
            );
            by_name.insert(spec.name.as_str(), buf);
        }
        Ok(Params { by_name })
    }

    pub(crate) fn f(&self, name: &str) -> Result<&'a [f32]> {
        self.by_name
            .get(name)
            .with_context(|| format!("model parameter {name:?} missing from manifest"))?
            .as_f32()
            .with_context(|| format!("parameter {name:?}"))
    }
}

pub(crate) fn dims_for(meta: &ModelMeta, b: usize) -> Result<Dims> {
    dims_for_n(meta, b, meta.seq_len)
}

/// [`dims_for`] with an explicit sequence length — the decode paths run
/// the same layers over growing prefixes instead of `meta.seq_len`.
pub(crate) fn dims_for_n(meta: &ModelMeta, b: usize, n: usize) -> Result<Dims> {
    ensure!(meta.heads > 0 && meta.d % meta.heads == 0, "d={} not divisible by h={}", meta.d, meta.heads);
    Ok(Dims {
        b,
        n,
        heads: meta.heads,
        d_h: meta.d_h(),
        n_c: meta.n_c.max(1),
        kappa: meta.kappa.max(1),
        attn: AttnFn::parse(&meta.attn_fn)?,
        clustering: meta.clustering().to_string(),
        causal: meta.causal,
        window: meta.window.max(1),
    })
}

/// Reusable forward scratch: one instance serves every layer of an
/// `encode` call, so the per-layer `Vec` allocations on the hot path
/// collapse to one set per forward.  Entry points are stateless by the
/// program contract, so `run_predict` builds one per call — but callers
/// that run the same program repeatedly (the serve inference workers)
/// own one per worker and thread it back in through the
/// `Executable::run_refs_scratch` seam (`run_predict_ws`), dropping the
/// per-batch allocations too.  Buffers resize lazily, so one workspace
/// serves any batch size or model geometry.
#[derive(Default)]
pub struct Workspace {
    /// CAST attention intermediates (q/k/v/affinities/R-slabs).
    cast: CastScratch,
    /// Pre-norm input copy (prenorm blocks norm a copy, not the residual).
    xn: Vec<f32>,
    /// FFN hidden activations (rows, d_ff).
    hid: Vec<f32>,
    /// FFN output (rows, d).
    ffn_out: Vec<f32>,
}

impl crate::runtime::backend::Scratch for Workspace {
    fn as_any(&mut self) -> &mut dyn std::any::Any {
        self
    }
}

pub(crate) fn apply_norm(p: &Params, meta: &ModelMeta, prefix: &str, x: &mut [f32]) -> Result<()> {
    let d = meta.d;
    let blk = parallel::row_block(x.len() / d.max(1)) * d;
    if meta.norm == "scale" {
        let g = p.f(&format!("{prefix}.g"))?;
        parallel::par_chunks_mut(x, blk, |_, chunk| {
            ops::scalenorm_rows(chunk, g[0], d, NORM_EPS);
        });
    } else {
        // "layer", and "batch" substituted by affine layernorm (DESIGN.md)
        let g = p.f(&format!("{prefix}.g"))?;
        let b = p.f(&format!("{prefix}.b"))?;
        parallel::par_chunks_mut(x, blk, |_, chunk| {
            ops::layernorm_rows(chunk, g, b, d, NORM_EPS);
        });
    }
    Ok(())
}

/// FFN into `out`, with hidden activations in the reusable `hid` buffer
/// (both owned by the caller's [`Workspace`]).
#[allow(clippy::too_many_arguments)]
pub(crate) fn ffn(
    p: &Params,
    prefix: &str,
    x: &[f32],
    rows: usize,
    d: usize,
    d_ff: usize,
    hid: &mut Vec<f32>,
    out: &mut Vec<f32>,
) -> Result<()> {
    ops::dense_into(
        x,
        p.f(&format!("{prefix}.in.w"))?,
        p.f(&format!("{prefix}.in.b"))?,
        rows,
        d,
        d_ff,
        hid,
    );
    let blk = parallel::elem_block(hid.len());
    parallel::par_chunks_mut(hid.as_mut_slice(), blk, |_, chunk| {
        ops::gelu_rows(chunk);
    });
    ops::dense_into(
        hid,
        p.f(&format!("{prefix}.out.w"))?,
        p.f(&format!("{prefix}.out.b"))?,
        rows,
        d_ff,
        d,
        out,
    );
    Ok(())
}

fn attn_apply(
    p: &Params,
    meta: &ModelMeta,
    prefix: &str,
    x: &[f32],
    dims: &Dims,
    ws: &mut CastScratch,
) -> Result<(Vec<f32>, Vec<f32>)> {
    let v = variants::AttnVariant::parse(&meta.variant)?;
    variants::attn_forward(v, p, prefix, x, dims, ws)
}

/// tokens (b·n,) int32 → final pre-pool activations x (b·n, d) [+ per-layer
/// A_g].  `n` is explicit (the decode paths run growing prefixes, not
/// `meta.seq_len`); `after_attn` fires right after each block's attention
/// with the layer index and the attention scratch — the decode cache
/// rebuild reads the per-layer K/V rows and cluster assignments out of it,
/// everyone else passes a no-op.
pub(crate) fn encode_x(
    p: &Params,
    meta: &ModelMeta,
    tokens: &[i32],
    b: usize,
    n: usize,
    collect_ag: bool,
    ws: &mut Workspace,
    after_attn: &mut dyn FnMut(usize, &CastScratch),
) -> Result<(Vec<f32>, Vec<Vec<f32>>)> {
    ensure!(tokens.len() == b * n, "tokens length {} != {}x{}", tokens.len(), b, n);
    let (d, d_emb) = (meta.d, meta.d_emb);
    let rows = b * n;

    // embedding + fixed sinusoidal positions + input projection, sharded
    // over row blocks (the batch×sequence grid)
    let t = trace::span("embed");
    let emb = p.f("embed.emb")?;
    let pe = ops::sinusoidal_positions(n, d_emb);
    let mut x = vec![0.0f32; rows * d_emb];
    let vocab_max = meta.vocab.saturating_sub(1);
    let rblk = parallel::row_block(rows);
    parallel::par_chunks_mut(x.as_mut_slice(), rblk * d_emb, |ci, chunk| {
        let r0 = ci * rblk;
        for (rr, dst) in chunk.chunks_mut(d_emb).enumerate() {
            let gr = r0 + rr;
            let nn = gr % n;
            let tok = (tokens[gr].max(0) as usize).min(vocab_max);
            let erow = &emb[tok * d_emb..(tok + 1) * d_emb];
            let prow = &pe[nn * d_emb..(nn + 1) * d_emb];
            dst.copy_from_slice(erow);
            simd::add8(dst, prow);
        }
    });
    let mut x = ops::dense(&x, p.f("proj.w")?, p.f("proj.b")?, rows, d_emb, d);
    drop(t);

    let dims = dims_for_n(meta, b, n)?;
    let mut ags = Vec::new();
    for i in 0..meta.depth {
        let li = i as i32;
        let blk = format!("blocks.{i}");
        if meta.prenorm {
            let t = trace::span_layer("norm", li);
            ws.xn.clear();
            ws.xn.extend_from_slice(&x);
            apply_norm(p, meta, &format!("{blk}.norm1"), &mut ws.xn)?;
            drop(t);
            let t = trace::span_layer("attn", li);
            let (a, ag) = attn_apply(p, meta, &format!("{blk}.attn"), &ws.xn, &dims, &mut ws.cast)?;
            drop(t);
            after_attn(i, &ws.cast);
            if collect_ag {
                ags.push(ag);
            }
            ops::add_assign(&mut x, &a);
            let t = trace::span_layer("norm", li);
            ws.xn.clear();
            ws.xn.extend_from_slice(&x);
            apply_norm(p, meta, &format!("{blk}.norm2"), &mut ws.xn)?;
            drop(t);
            let t = trace::span_layer("ffn", li);
            let name = format!("{blk}.ffn");
            ffn(p, &name, &ws.xn, rows, d, meta.d_ff, &mut ws.hid, &mut ws.ffn_out)?;
            ops::add_assign(&mut x, &ws.ffn_out);
            drop(t);
        } else {
            let t = trace::span_layer("attn", li);
            let (a, ag) = attn_apply(p, meta, &format!("{blk}.attn"), &x, &dims, &mut ws.cast)?;
            drop(t);
            after_attn(i, &ws.cast);
            if collect_ag {
                ags.push(ag);
            }
            ops::add_assign(&mut x, &a);
            let t = trace::span_layer("norm", li);
            apply_norm(p, meta, &format!("{blk}.norm1"), &mut x)?;
            drop(t);
            let t = trace::span_layer("ffn", li);
            ffn(p, &format!("{blk}.ffn"), &x, rows, d, meta.d_ff, &mut ws.hid, &mut ws.ffn_out)?;
            ops::add_assign(&mut x, &ws.ffn_out);
            drop(t);
            let t = trace::span_layer("norm", li);
            apply_norm(p, meta, &format!("{blk}.norm2"), &mut x)?;
            drop(t);
        }
    }
    if meta.prenorm {
        apply_norm(p, meta, "out_norm", &mut x)?;
    }
    Ok((x, ags))
}

/// tokens (b·N,) int32 → pooled features (b, d) [+ per-layer A_g].
fn encode(
    p: &Params,
    meta: &ModelMeta,
    tokens: &[i32],
    b: usize,
    collect_ag: bool,
    ws: &mut Workspace,
) -> Result<(Vec<f32>, Vec<Vec<f32>>)> {
    let n = meta.seq_len;
    let d = meta.d;
    let (x, ags) = encode_x(p, meta, tokens, b, n, collect_ag, ws, &mut |_, _| {})?;

    // mean-pool over the sequence, one task per batch element
    let t = trace::span("pool");
    let mut pooled = vec![0.0f32; b * d];
    let inv = 1.0 / n as f32;
    let xs: &[f32] = &x;
    parallel::par_chunks_mut(pooled.as_mut_slice(), d, |bb, prow| {
        for nn in 0..n {
            let src = (bb * n + nn) * d;
            simd::axpy8(prow, inv, &xs[src..src + d]);
        }
    });
    drop(t);
    Ok((pooled, ags))
}

/// Pooled classifier features (B, d or 4d for dual), from a token tensor.
fn pooled_features(
    p: &Params,
    meta: &ModelMeta,
    tokens: &HostTensor,
    ws: &mut Workspace,
) -> Result<(Vec<f32>, usize)> {
    let toks = tokens.as_s32().context("tokens tensor")?;
    let n = meta.seq_len;
    if meta.dual {
        ensure!(
            tokens.shape.len() == 3 && tokens.shape[1] == 2 && tokens.shape[2] == n,
            "dual tokens must be (B,2,{}), got {:?}",
            n,
            tokens.shape
        );
        let b = tokens.shape[0];
        let mut t1 = vec![0i32; b * n];
        let mut t2 = vec![0i32; b * n];
        for bb in 0..b {
            t1[bb * n..(bb + 1) * n].copy_from_slice(&toks[(bb * 2) * n..(bb * 2 + 1) * n]);
            t2[bb * n..(bb + 1) * n].copy_from_slice(&toks[(bb * 2 + 1) * n..(bb * 2 + 2) * n]);
        }
        let (f1, _) = encode(p, meta, &t1, b, false, ws)?;
        let (f2, _) = encode(p, meta, &t2, b, false, ws)?;
        let d = meta.d;
        let mut feats = vec![0.0f32; b * 4 * d];
        for bb in 0..b {
            for j in 0..d {
                let (a, c) = (f1[bb * d + j], f2[bb * d + j]);
                feats[bb * 4 * d + j] = a;
                feats[bb * 4 * d + d + j] = c;
                feats[bb * 4 * d + 2 * d + j] = a * c;
                feats[bb * 4 * d + 3 * d + j] = a - c;
            }
        }
        Ok((feats, 4 * d))
    } else {
        ensure!(
            tokens.shape.len() == 2 && tokens.shape[1] == n,
            "tokens must be (B,{}), got {:?}",
            n,
            tokens.shape
        );
        let b = tokens.shape[0];
        let (feats, _) = encode(p, meta, toks, b, false, ws)?;
        Ok((feats, meta.d))
    }
}

pub(crate) struct HeadForward {
    pub(crate) h_pre: Vec<f32>,
    pub(crate) h: Vec<f32>,
    pub(crate) logits: Vec<f32>,
}

pub(crate) fn head_forward(
    p: &Params,
    meta: &ModelMeta,
    feats: &[f32],
    b: usize,
    d_in: usize,
) -> Result<HeadForward> {
    let _t = trace::span("head");
    let d = meta.d;
    let h_pre = ops::dense(feats, p.f("head.fc.w")?, p.f("head.fc.b")?, b, d_in, d);
    let mut h = h_pre.clone();
    ops::gelu_rows(&mut h);
    let logits = ops::dense(&h, p.f("head.out.w")?, p.f("head.out.b")?, b, d, meta.n_classes);
    Ok(HeadForward { h_pre, h, logits })
}

// ---------------------------------------------------------------------------
// program entry points
// ---------------------------------------------------------------------------

/// `init`: deterministic parameter synthesis from a u32 seed, following
/// the same initializer families as `python/compile/layers.py` (scaled
/// normal weights, zero biases, unit norm gains).
pub fn run_init(manifest: &Manifest, inputs: &[&HostTensor]) -> Result<Vec<HostTensor>> {
    ensure!(inputs.len() == 1, "init takes one seed input, got {}", inputs.len());
    let seed_buf = inputs[0].as_u32().context("init seed")?;
    ensure!(seed_buf.len() == 1, "init seed must be a scalar");
    let seed = seed_buf[0];
    let mut rng = Rng::new(seed as u64 ^ 0x5EED_CA57_0000);
    let mut out = Vec::with_capacity(manifest.n_params());
    for spec in &manifest.params {
        let n: usize = spec.shape.iter().product();
        let data: Vec<f32> = if spec.name.ends_with(".g") {
            vec![1.0; n]
        } else if spec.name.ends_with(".b") {
            vec![0.0; n]
        } else if spec.name == "embed.emb" {
            let scale = 1.0 / (spec.shape[1] as f32).sqrt();
            (0..n).map(|_| rng.gaussian() as f32 * scale).collect()
        } else if spec.name.ends_with(".s") {
            // surrogate tokens: normal / sqrt(d_h)
            let d_h = *spec.shape.last().unwrap_or(&1);
            let scale = 1.0 / (d_h as f32).sqrt();
            (0..n).map(|_| rng.gaussian() as f32 * scale).collect()
        } else if spec.name.ends_with(".w") {
            let d_in = spec.shape.first().copied().unwrap_or(1);
            let scale = 1.0 / (d_in as f32).sqrt();
            (0..n).map(|_| rng.gaussian() as f32 * scale).collect()
        } else {
            bail!("init: unrecognized parameter role for {:?}", spec.name);
        };
        out.push(HostTensor::f32(spec.shape.clone(), data));
    }
    Ok(out)
}

/// `predict`: (P params, tokens) → logits (B, n_classes).
pub fn run_predict(manifest: &Manifest, inputs: &[&HostTensor]) -> Result<Vec<HostTensor>> {
    let mut ws = Workspace::default();
    run_predict_ws(manifest, inputs, &mut ws)
}

/// [`run_predict`] with a caller-owned reusable [`Workspace`] — the
/// serve inference workers' hot path (no per-batch scratch allocation).
pub fn run_predict_ws(
    manifest: &Manifest,
    inputs: &[&HostTensor],
    ws: &mut Workspace,
) -> Result<Vec<HostTensor>> {
    let p_count = manifest.n_params();
    ensure!(
        inputs.len() == p_count + 1,
        "predict takes {} params + tokens, got {} inputs",
        p_count,
        inputs.len()
    );
    let p = Params::bind(&manifest.params, &inputs[..p_count])?;
    let meta = &manifest.meta;
    let (feats, d_in) = pooled_features(&p, meta, inputs[p_count], ws)?;
    let b = feats.len() / d_in;
    let head = head_forward(&p, meta, &feats, b, d_in)?;
    Ok(vec![HostTensor::f32(vec![b, meta.n_classes], head.logits)])
}

/// `predict_ag`: (P params, tokens) → A_g (L, B, N, Nc).
pub fn run_predict_ag(manifest: &Manifest, inputs: &[&HostTensor]) -> Result<Vec<HostTensor>> {
    let p_count = manifest.n_params();
    ensure!(
        inputs.len() == p_count + 1,
        "predict_ag takes {} params + tokens, got {} inputs",
        p_count,
        inputs.len()
    );
    let meta = &manifest.meta;
    ensure!(
        meta.has_ag(),
        "predict_ag requires a variant with cluster affinities (supports_ag) and a non-dual model"
    );
    let p = Params::bind(&manifest.params, &inputs[..p_count])?;
    let tokens = inputs[p_count];
    let toks = tokens.as_s32().context("tokens tensor")?;
    ensure!(
        tokens.shape.len() == 2 && tokens.shape[1] == meta.seq_len,
        "tokens must be (B,{}), got {:?}",
        meta.seq_len,
        tokens.shape
    );
    let b = tokens.shape[0];
    let (_, ags) = encode(&p, meta, toks, b, true, &mut Workspace::default())?;
    ensure!(ags.len() == meta.depth, "collected {} A_g layers, expected {}", ags.len(), meta.depth);
    let mut stacked = Vec::with_capacity(meta.depth * b * meta.seq_len * meta.n_c);
    for ag in &ags {
        stacked.extend_from_slice(ag);
    }
    Ok(vec![HostTensor::f32(
        vec![meta.depth, b, meta.seq_len, meta.n_c],
        stacked,
    )])
}

/// Softmax cross-entropy over a (B, nc) logit block: returns the mean
/// loss, the argmax accuracy, and `dL/dlogits` (already scaled by 1/B).
/// Shared by the full-backprop and head-only training paths.
pub(crate) fn softmax_xent(
    logits: &[f32],
    labels: &[i32],
    nc: usize,
) -> Result<(f32, f32, Vec<f32>)> {
    let b = labels.len();
    ensure!(logits.len() == b * nc, "logits length {} != {}x{}", logits.len(), b, nc);
    let mut loss = 0.0f64;
    let mut correct = 0usize;
    let mut dlogits = vec![0.0f32; b * nc];
    for i in 0..b {
        let row = &logits[i * nc..(i + 1) * nc];
        let label = labels[i];
        ensure!(
            label >= 0 && (label as usize) < nc,
            "label {label} out of range for {nc} classes"
        );
        let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let z: f32 = row.iter().map(|&x| (x - mx).exp()).sum();
        loss += -((row[label as usize] - mx) - z.ln()) as f64;
        let mut arg = 0usize;
        for (j, &x) in row.iter().enumerate() {
            if x > row[arg] {
                arg = j;
            }
            dlogits[i * nc + j] = (x - mx).exp() / z;
        }
        dlogits[i * nc + label as usize] -= 1.0;
        if arg as i32 == label {
            correct += 1;
        }
    }
    let inv_b = 1.0 / b as f32;
    for g in dlogits.iter_mut() {
        *g *= inv_b;
    }
    Ok(((loss / b as f64) as f32, correct as f32 / b as f32, dlogits))
}

/// What `train_step` differentiates (see module docs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum TrainScope {
    /// Exact gradients for every parameter (default).
    Full,
    /// PR-1 regression path: classifier head only, backbone frozen.
    Head,
}

/// Scope resolution: `CAST_TRAIN_SCOPE` env var, else a `train_scope`
/// key in the manifest's `config` object, else full backprop.
fn train_scope(manifest: &Manifest) -> Result<TrainScope> {
    let choice = std::env::var("CAST_TRAIN_SCOPE").ok().or_else(|| {
        manifest
            .raw
            .path("config.train_scope")
            .and_then(Json::as_str)
            .map(str::to_string)
    });
    match choice.as_deref() {
        None | Some("full") => Ok(TrainScope::Full),
        Some("head") => Ok(TrainScope::Head),
        Some(other) => bail!("unknown train scope {other:?} (know \"full\", \"head\")"),
    }
}

/// Head-only gradients (the frozen-backbone regression path): exact for
/// `head.fc` / `head.out`, `None` for everything else.
fn head_only_grads(
    manifest: &Manifest,
    p: &Params,
    tokens: &HostTensor,
    labels: &[i32],
) -> Result<(f32, f32, Vec<Option<Vec<f32>>>)> {
    let meta = &manifest.meta;
    let (feats, d_in) = pooled_features(p, meta, tokens, &mut Workspace::default())?;
    let b = labels.len();
    ensure!(feats.len() == b * d_in, "feature/label batch mismatch");
    let head = head_forward(p, meta, &feats, b, d_in)?;
    let (d, nc) = (meta.d, meta.n_classes);
    let (loss, acc, dlogits) = softmax_xent(&head.logits, labels, nc)?;

    let mut g_out_w = vec![0.0f32; d * nc];
    let mut g_out_b = vec![0.0f32; nc];
    grad::ops::dense_grad_params(&head.h, &dlogits, b, d, nc, &mut g_out_w, &mut g_out_b);
    let mut dh = vec![0.0f32; b * d];
    grad::ops::dense_grad_input_acc(&dlogits, p.f("head.out.w")?, b, d, nc, &mut dh);
    for (v, &pre) in dh.iter_mut().zip(&head.h_pre) {
        *v *= ops::gelu_prime(pre);
    }
    let mut g_fc_w = vec![0.0f32; d_in * d];
    let mut g_fc_b = vec![0.0f32; d];
    grad::ops::dense_grad_params(&feats, &dh, b, d_in, d, &mut g_fc_w, &mut g_fc_b);

    let mut by_name: HashMap<&str, Vec<f32>> = HashMap::new();
    by_name.insert("head.fc.b", g_fc_b);
    by_name.insert("head.fc.w", g_fc_w);
    by_name.insert("head.out.b", g_out_b);
    by_name.insert("head.out.w", g_out_w);
    let grads = manifest
        .params
        .iter()
        .map(|spec| by_name.remove(spec.name.as_str()))
        .collect();
    Ok((loss, acc, grads))
}

/// `train_step`: one AdamW update (global-norm clip 1.0, decay on `.w`
/// weights only, as in `python/compile/train.py`).  The gradient scope
/// is full-model backprop by default, head-only behind the regression
/// flag — see module docs.  Input/output arity matches the AOT program.
pub fn run_train_step(manifest: &Manifest, inputs: &[&HostTensor]) -> Result<Vec<HostTensor>> {
    let p_count = manifest.n_params();
    ensure!(
        inputs.len() == 3 * p_count + 4,
        "train_step takes 3x{} params + (step, lr, tokens, labels), got {} inputs",
        p_count,
        inputs.len()
    );
    let params = &inputs[..p_count];
    let m_in = &inputs[p_count..2 * p_count];
    let v_in = &inputs[2 * p_count..3 * p_count];
    let step = inputs[3 * p_count].scalar().context("step")?;
    let lr = inputs[3 * p_count + 1].scalar().context("lr")?;
    let tokens = inputs[3 * p_count + 2];
    let labels = inputs[3 * p_count + 3].as_s32().context("labels")?;

    let tg = trace::span("train.backprop");
    let (loss, acc, grads) = match train_scope(manifest)? {
        TrainScope::Full => {
            let mut ws = grad::GradScratch::new();
            let out = grad::loss_and_grads(manifest, params, tokens, labels, &mut ws)?;
            (out.loss, out.acc, out.grads.into_iter().map(Some).collect::<Vec<_>>())
        }
        TrainScope::Head => {
            let p = Params::bind(&manifest.params, params)?;
            head_only_grads(manifest, &p, tokens, labels)?
        }
    };
    drop(tg);

    // global-norm clip over the trained subset (train.py: clip = 1.0)
    let tc = trace::span("train.grad_clip");
    let mut sq = 0.0f64;
    for g in grads.iter().flatten() {
        sq += g.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>();
    }
    let gnorm = sq.sqrt() as f32;
    let clip_scale = (GRAD_CLIP / gnorm.max(1e-6)).min(1.0);
    LAST_GRAD_NORM.store(gnorm.to_bits(), std::sync::atomic::Ordering::Relaxed);
    drop(tc);

    let ta = trace::span("train.adamw");
    let t = step + 1.0;
    let bc1 = 1.0 - ADAM_B1.powf(t);
    let bc2 = 1.0 - ADAM_B2.powf(t);

    let mut p_out = Vec::with_capacity(p_count);
    let mut m_out = Vec::with_capacity(p_count);
    let mut v_out = Vec::with_capacity(p_count);
    for (i, spec) in manifest.params.iter().enumerate() {
        match &grads[i] {
            Some(grad) => {
                let pv = params[i].as_f32()?;
                let mv = m_in[i].as_f32()?;
                let vv = v_in[i].as_f32()?;
                ensure!(pv.len() == grad.len(), "grad size mismatch for {:?}", spec.name);
                let decay = spec.name.ends_with(".w"); // AdamW: no decay on biases
                let mut p2 = Vec::with_capacity(pv.len());
                let mut m2 = Vec::with_capacity(pv.len());
                let mut v2 = Vec::with_capacity(pv.len());
                for j in 0..pv.len() {
                    let g = grad[j] * clip_scale;
                    let mj = ADAM_B1 * mv[j] + (1.0 - ADAM_B1) * g;
                    let vj = ADAM_B2 * vv[j] + (1.0 - ADAM_B2) * g * g;
                    let mhat = mj / bc1;
                    let vhat = vj / bc2;
                    let mut delta = mhat / (vhat.sqrt() + ADAM_EPS);
                    if decay {
                        delta += WEIGHT_DECAY * pv[j];
                    }
                    p2.push(pv[j] - lr * delta);
                    m2.push(mj);
                    v2.push(vj);
                }
                p_out.push(HostTensor::f32(spec.shape.clone(), p2));
                m_out.push(HostTensor::f32(spec.shape.clone(), m2));
                v_out.push(HostTensor::f32(spec.shape.clone(), v2));
            }
            None => {
                p_out.push(params[i].clone());
                m_out.push(m_in[i].clone());
                v_out.push(v_in[i].clone());
            }
        }
    }
    drop(ta);

    let mut outputs = p_out;
    outputs.extend(m_out);
    outputs.extend(v_out);
    outputs.push(HostTensor::scalar_f32(t));
    outputs.push(HostTensor::scalar_f32(loss));
    outputs.push(HostTensor::scalar_f32(acc));
    Ok(outputs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::native::spec::tiny_meta;

    fn tiny_manifest(variant: &str) -> Manifest {
        Manifest::synthetic(tiny_meta(variant))
    }

    fn init_params(man: &Manifest, seed: u32) -> Vec<HostTensor> {
        let seed_t = HostTensor::u32(vec![], vec![seed]);
        run_init(man, &[&seed_t]).unwrap()
    }

    fn tokens_for(man: &Manifest, fill: impl Fn(usize) -> i32) -> HostTensor {
        let n: usize = man.tokens_shape.iter().product();
        HostTensor::s32(man.tokens_shape.clone(), (0..n).map(fill).collect())
    }

    #[test]
    fn init_is_deterministic_and_shaped() {
        let man = tiny_manifest("cast_topk");
        let a = init_params(&man, 7);
        let b = init_params(&man, 7);
        let c = init_params(&man, 8);
        assert_eq!(a.len(), man.n_params());
        for ((x, y), spec) in a.iter().zip(&b).zip(&man.params) {
            assert_eq!(x.shape, spec.shape, "{}", spec.name);
            assert_eq!(x.as_f32().unwrap(), y.as_f32().unwrap(), "{}", spec.name);
            assert!(x.as_f32().unwrap().iter().all(|v| v.is_finite()));
        }
        let same = a
            .iter()
            .zip(&c)
            .all(|(x, y)| x.as_f32().unwrap() == y.as_f32().unwrap());
        assert!(!same, "different seeds must give different params");
    }

    #[test]
    fn predict_emits_finite_logits_for_every_variant() {
        for variant in variants::NAMES {
            let man = tiny_manifest(variant);
            let params = init_params(&man, 1);
            let tokens = tokens_for(&man, |i| (i % 30) as i32);
            let mut inputs: Vec<&HostTensor> = params.iter().collect();
            inputs.push(&tokens);
            let out = run_predict(&man, &inputs).unwrap();
            assert_eq!(out.len(), 1, "{variant}");
            assert_eq!(out[0].shape, vec![2, 2], "{variant}");
            assert!(
                out[0].as_f32().unwrap().iter().all(|v| v.is_finite()),
                "{variant}"
            );
        }
    }

    #[test]
    fn predict_is_deterministic() {
        let man = tiny_manifest("cast_topk");
        let params = init_params(&man, 3);
        let tokens = tokens_for(&man, |i| (i % 17) as i32);
        let mut inputs: Vec<&HostTensor> = params.iter().collect();
        inputs.push(&tokens);
        let a = run_predict(&man, &inputs).unwrap();
        let b = run_predict(&man, &inputs).unwrap();
        assert_eq!(a[0].as_f32().unwrap(), b[0].as_f32().unwrap());
    }

    #[test]
    fn predict_ag_shape_and_row_sums() {
        // every supports_ag variant — CAST's surrogate affinities and
        // clustered's k-means affinities — emits normalized A_g rows
        for variant in ["cast_topk", "clustered"] {
            let man = tiny_manifest(variant);
            let params = init_params(&man, 0);
            let tokens = tokens_for(&man, |_| 2);
            let mut inputs: Vec<&HostTensor> = params.iter().collect();
            inputs.push(&tokens);
            let out = run_predict_ag(&man, &inputs).unwrap();
            assert_eq!(out[0].shape, vec![2, 2, 64, 4], "{variant}");
            for row in out[0].as_f32().unwrap().chunks(4) {
                let s: f32 = row.iter().sum();
                assert!((s - 1.0).abs() < 1e-3, "{variant} A_g row sums to {s}");
            }
        }
    }

    #[test]
    fn predict_ag_refused_for_baselines() {
        let man = tiny_manifest("vanilla");
        let params = init_params(&man, 0);
        let tokens = tokens_for(&man, |_| 1);
        let mut inputs: Vec<&HostTensor> = params.iter().collect();
        inputs.push(&tokens);
        assert!(run_predict_ag(&man, &inputs).is_err());
    }

    /// A manifest whose config pins the PR-1 head-only regression scope
    /// (the raw-JSON route — no process-global env mutation in tests).
    fn head_scope_manifest(variant: &str) -> Manifest {
        let mut man = tiny_manifest(variant);
        man.raw = Json::obj(vec![(
            "config",
            Json::obj(vec![("train_scope", Json::str("head"))]),
        )]);
        man
    }

    fn train_step_once(man: &Manifest, seed: u32) -> (Vec<HostTensor>, Vec<HostTensor>) {
        let params = init_params(man, seed);
        let zeros: Vec<HostTensor> = params
            .iter()
            .map(|t| HostTensor::zeros(t.dtype(), t.shape.clone()))
            .collect();
        let step = HostTensor::scalar_f32(0.0);
        let lr = HostTensor::scalar_f32(1e-2);
        let tokens = tokens_for(man, |i| (i % 29) as i32);
        let labels = HostTensor::s32(vec![2], vec![0, 1]);
        let mut inputs: Vec<&HostTensor> = params.iter().collect();
        inputs.extend(zeros.iter());
        inputs.extend(zeros.iter());
        inputs.push(&step);
        inputs.push(&lr);
        inputs.push(&tokens);
        inputs.push(&labels);
        let out = run_train_step(man, &inputs).unwrap();
        (params, out)
    }

    #[test]
    fn train_step_full_scope_updates_the_whole_model() {
        let man = tiny_manifest("cast_topk");
        let (params, out) = train_step_once(&man, 5);
        let p = man.n_params();
        assert_eq!(out.len(), 3 * p + 3);
        assert_eq!(out[3 * p].scalar().unwrap(), 1.0); // step'
        let loss = out[3 * p + 1].scalar().unwrap();
        let acc = out[3 * p + 2].scalar().unwrap();
        assert!(loss.is_finite() && loss > 0.0);
        assert!((0.0..=1.0).contains(&acc));
        // full backprop: backbone weights move too (embedding, attention
        // projections, surrogate tokens, norms, FFN, head)
        for probe in [
            "embed.emb",
            "proj.w",
            "blocks.0.attn.wq.w",
            "blocks.0.attn.s",
            "blocks.0.attn.phi.w",
            "blocks.1.ffn.in.w",
            "blocks.1.norm2.g",
            "head.out.w",
        ] {
            let i = man.params.iter().position(|s| s.name == probe).unwrap();
            assert_ne!(
                params[i].as_f32().unwrap(),
                out[i].as_f32().unwrap(),
                "{probe} should update under full backprop"
            );
        }
        for t in out.iter().take(p) {
            assert!(t.as_f32().unwrap().iter().all(|v| v.is_finite()));
        }
    }

    #[test]
    fn train_step_head_scope_keeps_backbone_frozen() {
        let man = head_scope_manifest("cast_topk");
        let (params, out) = train_step_once(&man, 5);
        for (i, spec) in man.params.iter().enumerate() {
            let before = params[i].as_f32().unwrap();
            let after = out[i].as_f32().unwrap();
            if spec.name.starts_with("head.") {
                assert_ne!(before, after, "{} should update", spec.name);
            } else {
                assert_eq!(before, after, "{} is frozen", spec.name);
            }
        }
    }

    #[test]
    fn repeated_train_steps_on_one_batch_reduce_loss() {
        let man = tiny_manifest("cast_topk");
        let mut params = init_params(&man, 9);
        let mut m: Vec<HostTensor> = params
            .iter()
            .map(|t| HostTensor::zeros(t.dtype(), t.shape.clone()))
            .collect();
        let mut v = m.clone();
        let tokens = tokens_for(&man, |i| ((i * 7 + 3) % 90) as i32);
        let labels = HostTensor::s32(vec![2], vec![0, 1]);
        let lr = HostTensor::scalar_f32(3e-3);
        let mut step = HostTensor::scalar_f32(0.0);
        let mut first = f32::NAN;
        let mut last = f32::NAN;
        for it in 0..60 {
            let mut inputs: Vec<&HostTensor> = params.iter().collect();
            inputs.extend(m.iter());
            inputs.extend(v.iter());
            inputs.push(&step);
            inputs.push(&lr);
            inputs.push(&tokens);
            inputs.push(&labels);
            let mut out = run_train_step(&man, &inputs).unwrap();
            let p = man.n_params();
            last = out[3 * p + 1].scalar().unwrap();
            if it == 0 {
                first = last;
            }
            step = HostTensor::scalar_f32(out[3 * p].scalar().unwrap());
            let v_new = out.split_off(2 * p);
            // out now holds params' ++ m'; v_new holds v' ++ scalars
            let m_new = out.split_off(p);
            params = out;
            m = m_new;
            v = v_new.into_iter().take(p).collect();
        }
        assert!(last.is_finite() && first.is_finite());
        assert!(
            last < first * 0.9,
            "overfitting one batch must cut loss: {first:.4} -> {last:.4}"
        );
    }
}
