//! Backward counterparts of the forward building blocks in
//! `runtime::native::ops` — dense matmul input/parameter gradients, the
//! attention-row normalizations (softmax and MEGA's laplace), and the two
//! norms.  Every function follows one convention: **gradients accumulate**
//! (`+=`) into the caller's buffers, so a parameter touched from several
//! places (residual branches, dual encoders, shared projections) sums its
//! contributions naturally; callers zero buffers at the start of a
//! backward pass.
//!
//! Threading mirrors the forward (DESIGN.md §Threading): input gradients
//! shard over row blocks with disjoint `&mut` chunks, weight gradients
//! shard over input-dimension blocks with a fixed row-accumulation order
//! inside each task — bit-identical for any `CAST_NUM_THREADS`.  The
//! cheap cross-row reductions (biases, norm gains) stay serial.
//!
//! Vectorization mirrors the forward too (DESIGN.md §SIMD): the same
//! `util::simd` 8-lane kernels drive the dot/axpy/row-reduction inner
//! loops here, and `CAST_NO_SIMD=1` routes backward and forward to the
//! scalar reference together — the two passes never run in mixed modes.

use crate::util::parallel;
use crate::util::simd;

use super::super::ops::{self, AttnFn};

/// `dx += dy @ w^T` where `dy` is (rows, d_out) and `w` is (d_in, d_out):
/// the input gradient of `y = x @ w + b`.  Each `dx` element is a
/// unit-stride dot against a row of `w`, dispatched over row blocks.
pub fn dense_grad_input_acc(
    dy: &[f32],
    w: &[f32],
    rows: usize,
    d_in: usize,
    d_out: usize,
    dx: &mut [f32],
) {
    debug_assert_eq!(dy.len(), rows * d_out);
    debug_assert_eq!(w.len(), d_in * d_out);
    debug_assert_eq!(dx.len(), rows * d_in);
    let blk = parallel::row_block(rows);
    parallel::par_chunks_mut(dx, blk * d_in, |ci, chunk| {
        let r0 = ci * blk;
        for (rr, dxrow) in chunk.chunks_mut(d_in).enumerate() {
            let dyrow = &dy[(r0 + rr) * d_out..(r0 + rr + 1) * d_out];
            for (i, dv) in dxrow.iter_mut().enumerate() {
                *dv += ops::dot(dyrow, &w[i * d_out..(i + 1) * d_out]);
            }
        }
    });
}

/// Parameter gradients of `y = x @ w + b`:
/// `dw[i,o] += sum_r x[r,i] * dy[r,o]`, `db[o] += sum_r dy[r,o]`.
/// `dw` is sharded over input-dimension blocks; each task walks the rows
/// in ascending order, so the accumulation order is fixed for any worker
/// count.  The (cheap) bias reduction is serial.
pub fn dense_grad_params(
    x: &[f32],
    dy: &[f32],
    rows: usize,
    d_in: usize,
    d_out: usize,
    dw: &mut [f32],
    db: &mut [f32],
) {
    debug_assert_eq!(x.len(), rows * d_in);
    debug_assert_eq!(dy.len(), rows * d_out);
    debug_assert_eq!(dw.len(), d_in * d_out);
    debug_assert_eq!(db.len(), d_out);
    let iblk = parallel::row_block(d_in);
    parallel::par_chunks_mut(dw, iblk * d_out, |ci, chunk| {
        let i0 = ci * iblk;
        let ni = chunk.len() / d_out;
        for r in 0..rows {
            let dyrow = &dy[r * d_out..(r + 1) * d_out];
            for ii in 0..ni {
                let xv = x[r * d_in + i0 + ii];
                if xv != 0.0 {
                    simd::axpy8(&mut chunk[ii * d_out..(ii + 1) * d_out], xv, dyrow);
                }
            }
        }
    });
    for r in 0..rows {
        simd::add8(db, &dy[r * d_out..(r + 1) * d_out]);
    }
}

/// Backward of `ops::attn_rows` over every `cols`-wide row: given the
/// raw scores `pre`, the normalized output `post`, and the upstream
/// gradient `dy`, **accumulates** `d pre` into `dpre`.
///
/// Softmax rows use only `post`; laplace rows recompute the
/// unnormalized CDF values from `pre` (the same recompute-over-store
/// choice the layer backward makes for the score matrices).  Rows whose
/// normalizer hit the forward clamp are degenerate (fully masked) and
/// receive ~zero gradient either way.
pub fn attn_rows_backward(
    pre: &[f32],
    post: &[f32],
    dy: &[f32],
    cols: usize,
    f: AttnFn,
    dpre: &mut [f32],
) {
    debug_assert!(cols > 0 && pre.len() % cols == 0);
    debug_assert_eq!(pre.len(), post.len());
    debug_assert_eq!(pre.len(), dy.len());
    debug_assert_eq!(pre.len(), dpre.len());
    match f {
        AttnFn::Softmax => {
            for ((yrow, gyrow), drow) in
                post.chunks(cols).zip(dy.chunks(cols)).zip(dpre.chunks_mut(cols))
            {
                let s = simd::dot8(yrow, gyrow);
                for ((d, y), gy) in drow.iter_mut().zip(yrow).zip(gyrow) {
                    *d += y * (gy - s);
                }
            }
        }
        AttnFn::Laplace => {
            let mu = 0.5f32.sqrt();
            let sigma = (0.25 / std::f32::consts::PI).sqrt();
            let denom = sigma * 2.0f32.sqrt();
            for (((xrow, yrow), gyrow), drow) in pre
                .chunks(cols)
                .zip(post.chunks(cols))
                .zip(dy.chunks(cols))
                .zip(dpre.chunks_mut(cols))
            {
                // recompute the normalizer in *the same summation order*
                // as the forward's `simd::sum8`, so forward and backward
                // agree on z bit-for-bit in either SIMD mode (sum8_map
                // computes the CDF terms on the fly — no scratch row)
                let z_raw = simd::sum8_map(cols, |i| {
                    0.5 * (1.0 + ops::erf((xrow[i] - mu) / denom))
                });
                let z = z_raw.max(1e-6);
                // when the forward clamp engaged, the normalizer is a
                // *constant* — the quotient-rule coupling term vanishes
                let s = if z_raw < 1e-6 { 0.0 } else { simd::dot8(yrow, gyrow) };
                for ((d, &x), gy) in drow.iter_mut().zip(xrow).zip(gyrow) {
                    let uprime = 0.5 * ops::erf_prime((x - mu) / denom) / denom;
                    *d += (gy - s) / z * uprime;
                }
            }
        }
    }
}

/// Backward of `ops::layernorm_rows`: `x` is the **pre-norm** input (the
/// per-row mean/variance are recomputed rather than stored), `g` the
/// gain.  Accumulates `dx` (row-parallel), `dg`, and `db` (serial
/// cross-row reduction).
pub fn layernorm_backward(
    x: &[f32],
    g: &[f32],
    dy: &[f32],
    d: usize,
    eps: f32,
    dx: &mut [f32],
    dg: &mut [f32],
    db: &mut [f32],
) {
    debug_assert!(d > 0 && x.len() % d == 0);
    debug_assert_eq!(x.len(), dy.len());
    debug_assert_eq!(x.len(), dx.len());
    debug_assert_eq!(dg.len(), d);
    debug_assert_eq!(db.len(), d);
    let rows = x.len() / d;
    let blk = parallel::row_block(rows);
    parallel::par_chunks_mut(dx, blk * d, |ci, chunk| {
        let r0 = ci * blk;
        for (rr, dxrow) in chunk.chunks_mut(d).enumerate() {
            let xrow = &x[(r0 + rr) * d..(r0 + rr + 1) * d];
            let dyrow = &dy[(r0 + rr) * d..(r0 + rr + 1) * d];
            // same lane reductions as the forward norm, so the recomputed
            // statistics match it bit-for-bit in either SIMD mode
            let mu = simd::sum8(xrow) / d as f32;
            let var = simd::sumsq_diff8(xrow, mu) / d as f32;
            let inv = 1.0 / (var + eps).sqrt();
            let mut mean_dyh = 0.0f32;
            let mut mean_dyh_xhat = 0.0f32;
            for i in 0..d {
                let xhat = (xrow[i] - mu) * inv;
                let dyh = dyrow[i] * g[i];
                mean_dyh += dyh;
                mean_dyh_xhat += dyh * xhat;
            }
            mean_dyh /= d as f32;
            mean_dyh_xhat /= d as f32;
            for (i, dv) in dxrow.iter_mut().enumerate() {
                let xhat = (xrow[i] - mu) * inv;
                let dyh = dyrow[i] * g[i];
                *dv += inv * (dyh - mean_dyh - xhat * mean_dyh_xhat);
            }
        }
    });
    for r in 0..rows {
        let xrow = &x[r * d..(r + 1) * d];
        let dyrow = &dy[r * d..(r + 1) * d];
        let mu = simd::sum8(xrow) / d as f32;
        let var = simd::sumsq_diff8(xrow, mu) / d as f32;
        let inv = 1.0 / (var + eps).sqrt();
        for i in 0..d {
            dg[i] += dyrow[i] * (xrow[i] - mu) * inv;
            db[i] += dyrow[i];
        }
    }
}

/// Backward of `ops::scalenorm_rows` (`y = g * sqrt(d) * x / ||x||`):
/// accumulates `dx` row-parallel and the scalar `dg` serially.
pub fn scalenorm_backward(
    x: &[f32],
    g: f32,
    dy: &[f32],
    d: usize,
    eps: f32,
    dx: &mut [f32],
    dg: &mut f32,
) {
    debug_assert!(d > 0 && x.len() % d == 0);
    debug_assert_eq!(x.len(), dy.len());
    debug_assert_eq!(x.len(), dx.len());
    let rows = x.len() / d;
    let sqrt_d = (d as f32).sqrt();
    let blk = parallel::row_block(rows);
    parallel::par_chunks_mut(dx, blk * d, |ci, chunk| {
        let r0 = ci * blk;
        for (rr, dxrow) in chunk.chunks_mut(d).enumerate() {
            let xrow = &x[(r0 + rr) * d..(r0 + rr + 1) * d];
            let dyrow = &dy[(r0 + rr) * d..(r0 + rr + 1) * d];
            let rms = (simd::sumsq_diff8(xrow, 0.0) + eps).sqrt();
            let xdy = ops::dot(xrow, dyrow);
            let inv = 1.0 / rms;
            let inv3 = inv * inv * inv;
            for (i, dv) in dxrow.iter_mut().enumerate() {
                *dv += g * sqrt_d * (dyrow[i] * inv - xrow[i] * xdy * inv3);
            }
        }
    });
    for r in 0..rows {
        let xrow = &x[r * d..(r + 1) * d];
        let dyrow = &dy[r * d..(r + 1) * d];
        let rms = (simd::sumsq_diff8(xrow, 0.0) + eps).sqrt();
        *dg += sqrt_d * ops::dot(xrow, dyrow) / rms;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{assert_grads_close, GradCheckCfg};
    use crate::util::rng::Rng;

    fn randn(rng: &mut Rng, n: usize, scale: f32) -> Vec<f32> {
        (0..n).map(|_| rng.gaussian() as f32 * scale).collect()
    }

    /// `L(theta) = <c, f(theta)>` — a fixed random cotangent turns any
    /// forward op into a scalar loss whose exact gradient the backward
    /// op must reproduce.
    fn inner(c: &[f32], y: &[f32]) -> f32 {
        ops::dot(c, y)
    }

    #[test]
    fn dense_param_gradients_match_central_difference() {
        let (rows, d_in, d_out) = (3usize, 4usize, 5usize);
        let mut rng = Rng::new(11);
        let x = randn(&mut rng, rows * d_in, 1.0);
        let c = randn(&mut rng, rows * d_out, 1.0);
        let w = randn(&mut rng, d_in * d_out, 0.5);
        let b = randn(&mut rng, d_out, 0.5);

        let mut dw = vec![0.0f32; d_in * d_out];
        let mut db = vec![0.0f32; d_out];
        dense_grad_params(&x, &c, rows, d_in, d_out, &mut dw, &mut db);
        let mut analytic = dw.clone();
        analytic.extend_from_slice(&db);
        let mut theta = w.clone();
        theta.extend_from_slice(&b);
        let blocks = vec![("w".to_string(), d_in * d_out), ("b".to_string(), d_out)];
        assert_grads_close(&GradCheckCfg::default(), &theta, &blocks, &analytic, |t| {
            let y = ops::dense(&x, &t[..d_in * d_out], &t[d_in * d_out..], rows, d_in, d_out);
            (inner(&c, &y), 0)
        });
    }

    #[test]
    fn dense_input_gradient_matches_central_difference() {
        let (rows, d_in, d_out) = (2usize, 5usize, 3usize);
        let mut rng = Rng::new(7);
        let x = randn(&mut rng, rows * d_in, 1.0);
        let w = randn(&mut rng, d_in * d_out, 0.7);
        let b = randn(&mut rng, d_out, 0.3);
        let c = randn(&mut rng, rows * d_out, 1.0);

        let mut dx = vec![0.0f32; rows * d_in];
        dense_grad_input_acc(&c, &w, rows, d_in, d_out, &mut dx);
        let blocks = vec![("x".to_string(), rows * d_in)];
        assert_grads_close(&GradCheckCfg::default(), &x, &blocks, &dx, |t| {
            let y = ops::dense(t, &w, &b, rows, d_in, d_out);
            (inner(&c, &y), 0)
        });
    }

    #[test]
    fn attn_rows_backward_matches_central_difference_both_fns() {
        let (rows, cols) = (3usize, 5usize);
        let mut rng = Rng::new(23);
        for f in [AttnFn::Softmax, AttnFn::Laplace] {
            let mut pre = randn(&mut rng, rows * cols, 1.0);
            pre[cols - 1] = ops::NEG_INF; // one masked entry in row 0
            let c = randn(&mut rng, rows * cols, 1.0);
            let mut post = pre.clone();
            ops::attn_rows(&mut post, cols, f);
            let mut dpre = vec![0.0f32; rows * cols];
            attn_rows_backward(&pre, &post, &c, cols, f, &mut dpre);
            let blocks = vec![(format!("{f:?}-scores"), rows * cols)];
            assert_grads_close(&GradCheckCfg::default(), &pre, &blocks, &dpre, |t| {
                let mut y = t.to_vec();
                ops::attn_rows(&mut y, cols, f);
                (inner(&c, &y), 0)
            });
        }
    }

    #[test]
    fn layernorm_backward_matches_central_difference() {
        let (rows, d) = (3usize, 6usize);
        let mut rng = Rng::new(41);
        let x = randn(&mut rng, rows * d, 1.0);
        let g = randn(&mut rng, d, 0.8);
        let b = randn(&mut rng, d, 0.2);
        let c = randn(&mut rng, rows * d, 1.0);
        let eps = 1e-5;

        let mut dx = vec![0.0f32; rows * d];
        let mut dg = vec![0.0f32; d];
        let mut db = vec![0.0f32; d];
        layernorm_backward(&x, &g, &c, d, eps, &mut dx, &mut dg, &mut db);

        // input gradient
        let blocks = vec![("x".to_string(), rows * d)];
        assert_grads_close(&GradCheckCfg::default(), &x, &blocks, &dx, |t| {
            let mut y = t.to_vec();
            ops::layernorm_rows(&mut y, &g, &b, d, eps);
            (inner(&c, &y), 0)
        });

        // gain/bias gradients
        let mut theta = g.clone();
        theta.extend_from_slice(&b);
        let mut analytic = dg.clone();
        analytic.extend_from_slice(&db);
        let blocks = vec![("g".to_string(), d), ("b".to_string(), d)];
        assert_grads_close(&GradCheckCfg::default(), &theta, &blocks, &analytic, |t| {
            let mut y = x.clone();
            ops::layernorm_rows(&mut y, &t[..d], &t[d..], d, eps);
            (inner(&c, &y), 0)
        });
    }

    #[test]
    fn scalenorm_backward_matches_central_difference() {
        let (rows, d) = (2usize, 5usize);
        let mut rng = Rng::new(55);
        let x = randn(&mut rng, rows * d, 1.0);
        let c = randn(&mut rng, rows * d, 1.0);
        let g = 1.3f32;
        let eps = 1e-5;

        let mut dx = vec![0.0f32; rows * d];
        let mut dg = 0.0f32;
        scalenorm_backward(&x, g, &c, d, eps, &mut dx, &mut dg);

        let blocks = vec![("x".to_string(), rows * d)];
        assert_grads_close(&GradCheckCfg::default(), &x, &blocks, &dx, |t| {
            let mut y = t.to_vec();
            ops::scalenorm_rows(&mut y, g, d, eps);
            (inner(&c, &y), 0)
        });

        let blocks = vec![("g".to_string(), 1)];
        assert_grads_close(&GradCheckCfg::default(), &[g], &blocks, &[dg], |t| {
            let mut y = x.clone();
            ops::scalenorm_rows(&mut y, t[0], d, eps);
            (inner(&c, &y), 0)
        });
    }

    #[test]
    fn gradients_accumulate_rather_than_overwrite() {
        // the += convention: running a backward twice doubles the result
        let (rows, d_in, d_out) = (2usize, 3usize, 2usize);
        let mut rng = Rng::new(3);
        let dy = randn(&mut rng, rows * d_out, 1.0);
        let w = randn(&mut rng, d_in * d_out, 1.0);
        let mut once = vec![0.0f32; rows * d_in];
        dense_grad_input_acc(&dy, &w, rows, d_in, d_out, &mut once);
        let mut twice = vec![0.0f32; rows * d_in];
        dense_grad_input_acc(&dy, &w, rows, d_in, d_out, &mut twice);
        dense_grad_input_acc(&dy, &w, rows, d_in, d_out, &mut twice);
        for (a, b) in once.iter().zip(&twice) {
            assert!((2.0 * a - b).abs() < 1e-6);
        }
    }
}
