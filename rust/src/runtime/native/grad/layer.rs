//! Layer-level reverse passes: the CAST attention layer (paper §3.1–3.3)
//! and the three baselines.
//!
//! **Tape policy** (DESIGN.md §Autograd): after a forward, the
//! [`CastScratch`] *is* the tape — [`CastTape::capture`] snapshots the
//! projections (q/k/v/φ), the surrogate affinities, the hard cluster
//! assignment, the combination weights, and the R-slabs.  The κ×κ
//! intra-cluster probability matrices and the summary weight rows are
//! **recomputed** in the backward (they are cheap relative to storing
//! B·Nc·h of them per layer).  Baselines store only the layer input and
//! recompute projections + probabilities.
//!
//! **Straight-through clustering**: the assignment `(idx, valid)` and the
//! LSH bucket sort are hard, non-differentiable selections and are treated
//! as constants.  Gradients still flow through every *soft* use of the
//! affinities — `A_q`-raw via the combination weights (eq. 5), `A_k` via
//! the summary weight rows (eq. 4), and φ via both softplus gates — so
//! the surrogate tokens S and the gate projection φ train.
//!
//! **Threading** mirrors the forward: dense backward ops shard over row /
//! input-dim blocks, the attention backward shards over the B×Nc cluster
//! grid into disjoint per-cell gradient slabs which a token-parallel
//! gather (via the `slot_of` reverse map) folds back into per-token
//! buffers.  Every reduction keeps a fixed order — backward results are
//! bit-identical for any `CAST_NUM_THREADS`.  The inner d_h/d-length
//! accumulations run on the same `util::simd` kernels as the forward
//! (DESIGN.md §SIMD), so `CAST_NO_SIMD=1` flips both passes together.

use anyhow::{ensure, Result};

use crate::util::parallel;
use crate::util::simd;

use super::super::layer::{
    attend_windows, lsh_attend, lsh_sort_order, BaselineParams, CastParams, CastScratch, Dims,
};
use super::super::ops::{self, NEG_INF};
use super::ops as gops;

/// Clear + zero-fill a reusable buffer (keeps its allocation).
fn zeroed(buf: &mut Vec<f32>, len: usize) {
    buf.clear();
    buf.resize(len, 0.0);
}

/// Fold a discrete assignment into a running FNV-1a fingerprint.
pub(crate) fn fnv_fold(h: u64, v: u64) -> u64 {
    (h ^ v).wrapping_mul(0x0000_0100_0000_01b3)
}

// ---------------------------------------------------------------------------
// CAST layer
// ---------------------------------------------------------------------------

/// Snapshot of one CAST layer's forward intermediates (see module docs
/// for what is stored vs recomputed).
pub struct CastTape {
    /// Layer input (B·N, d).
    pub x: Vec<f32>,
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    phi: Vec<f32>,
    a_k: Vec<f32>,
    a_q_raw: Vec<f32>,
    a_sum: Vec<f32>,
    r_intra: Vec<f32>,
    r_inter: Vec<f32>,
    r: Vec<f32>,
    slot_of: Vec<usize>,
    idx: Vec<usize>,
    valid: Vec<f32>,
}

impl CastTape {
    /// Capture the tape right after `cast_layer(p, x, dims, ws)` ran.
    pub fn capture(x: &[f32], ws: &CastScratch) -> CastTape {
        CastTape {
            x: x.to_vec(),
            q: ws.q.clone(),
            k: ws.k.clone(),
            v: ws.v.clone(),
            phi: ws.phi.clone(),
            a_k: ws.a_k.clone(),
            a_q_raw: ws.a_q_raw.clone(),
            a_sum: ws.a_sum.clone(),
            r_intra: ws.r_intra.clone(),
            r_inter: ws.r_inter.clone(),
            r: ws.r.clone(),
            slot_of: ws.slot_of.clone(),
            idx: ws.idx.clone(),
            valid: ws.valid.clone(),
        }
    }

    /// FNV fingerprint of the discrete cluster assignment — gradient
    /// checks skip coordinates whose perturbation flips it (the
    /// derivative does not exist across that boundary).
    pub fn fingerprint(&self) -> u64 {
        let mut h = 0xcbf2_9ce4_8422_2325u64;
        for &i in &self.idx {
            h = fnv_fold(h, i as u64);
        }
        for &v in &self.valid {
            h = fnv_fold(h, (v > 0.0) as u64);
        }
        h
    }
}

/// Mutable views of one CAST layer's parameter-gradient buffers
/// (accumulated into — the `+=` convention of `grad::ops`).
pub struct CastGradRefs<'a> {
    pub wq_w: &'a mut [f32],
    pub wq_b: &'a mut [f32],
    pub wk_w: &'a mut [f32],
    pub wk_b: &'a mut [f32],
    pub wv_w: &'a mut [f32],
    pub wv_b: &'a mut [f32],
    pub wo_w: &'a mut [f32],
    pub wo_b: &'a mut [f32],
    pub s: &'a mut [f32],
    pub phi_w: &'a mut [f32],
    pub phi_b: &'a mut [f32],
}

/// Reusable backward buffers for [`cast_layer_backward`] — the reverse
/// analogue of [`CastScratch`], owned by the model-level `GradScratch`.
#[derive(Default)]
pub struct CastBwdScratch {
    dr: Vec<f32>,
    d_asum: Vec<f32>,
    d_aq_raw: Vec<f32>,
    d_phi: Vec<f32>,
    d_r_intra: Vec<f32>,
    d_r_inter: Vec<f32>,
    /// Fused per-cell gradient slabs: `dq | dk | dv` (κ·d each) then
    /// `d a_k` (h·κ) then `d φ` (κ), per (batch, cluster) cell.
    cell: Vec<f32>,
    d_ak: Vec<f32>,
    dq: Vec<f32>,
    dk: Vec<f32>,
    dv: Vec<f32>,
}

/// Per-worker recompute scratch for the B×Nc cell backward.
struct CellScratch {
    pre: Vec<f32>,
    p: Vec<f32>,
    dp: Vec<f32>,
    ds: Vec<f32>,
    wpre: Vec<f32>,
    wpost: Vec<f32>,
    dw: Vec<f32>,
    dwpre: Vec<f32>,
}

/// Reverse pass of `layer::cast_layer`.  `d_out` is the gradient of the
/// layer output (B·N, d); the input gradient is **accumulated** into
/// `dx`, parameter gradients into `g`.
pub fn cast_layer_backward(
    p: &CastParams,
    tape: &CastTape,
    dims: &Dims,
    d_out: &[f32],
    dx: &mut [f32],
    g: &mut CastGradRefs,
    ws: &mut CastBwdScratch,
) -> Result<()> {
    let (b, n, h, d_h, n_c) = (dims.b, dims.n, dims.heads, dims.d_h, dims.n_c);
    let d = dims.d();
    let kappa = dims.kappa.min(n);
    ensure!(kappa > 0 && n_c > 0, "CAST needs n_c>0 and kappa>0");
    let rows = b * n;
    ensure!(d_out.len() == rows * d && dx.len() == rows * d, "cast backward shape");
    let tau = (d_h as f32).sqrt();
    let attn = dims.attn;
    let causal = dims.causal;
    let blk = parallel::row_block(rows);

    let CastBwdScratch {
        dr,
        d_asum,
        d_aq_raw,
        d_phi,
        d_r_intra,
        d_r_inter,
        cell,
        d_ak,
        dq,
        dk,
        dv,
    } = ws;

    // output projection: r -> out
    zeroed(dr, rows * d);
    gops::dense_grad_input_acc(d_out, p.wo_w, rows, d, d, dr);
    gops::dense_grad_params(&tape.r, d_out, rows, d, d, g.wo_w, g.wo_b);
    let dr_s: &[f32] = dr.as_slice();

    // step 6b backward, token side: d A_sum (every (token, cluster) pair
    // is written by exactly one task)
    zeroed(d_asum, rows * n_c);
    parallel::par_chunks_mut(d_asum.as_mut_slice(), blk * n_c, |ci, chunk| {
        let r0 = ci * blk;
        for rr in 0..chunk.len() / n_c {
            let gr = r0 + rr;
            let bb = gr / n;
            let drrow = &dr_s[gr * d..(gr + 1) * d];
            for c in 0..n_c {
                let slot = tape.slot_of[gr * n_c + c];
                chunk[rr * n_c + c] = if slot > 0 {
                    let src = ((bb * n_c + c) * kappa + (slot - 1)) * d;
                    ops::dot(drrow, &tape.r_intra[src..src + d])
                } else if !causal {
                    let src = (bb * n_c + c) * d;
                    ops::dot(drrow, &tape.r_inter[src..src + d])
                } else {
                    0.0
                };
            }
        }
    });
    let d_asum_s: &[f32] = d_asum.as_slice();

    // step 6b backward, cluster side: d R_intra / d R_inter over the
    // B×Nc grid (each slot receives from exactly one member token; the
    // summary gradient reduces over non-member tokens in a fixed order)
    zeroed(d_r_intra, b * n_c * kappa * d);
    zeroed(d_r_inter, b * n_c * d);
    parallel::par_zip2_mut(
        d_r_intra.as_mut_slice(),
        kappa * d,
        d_r_inter.as_mut_slice(),
        d,
        |cell_i, dri, drc| {
            let bb = cell_i / n_c;
            let c = cell_i % n_c;
            let base = (bb * n_c + c) * kappa;
            for slot in 0..kappa {
                if tape.valid[base + slot] > 0.0 {
                    let gr = bb * n + tape.idx[base + slot];
                    let w = tape.a_sum[gr * n_c + c];
                    if w != 0.0 {
                        simd::axpy8(
                            &mut dri[slot * d..(slot + 1) * d],
                            w,
                            &dr_s[gr * d..(gr + 1) * d],
                        );
                    }
                }
            }
            if !causal {
                for t in 0..n {
                    let gr = bb * n + t;
                    if tape.slot_of[gr * n_c + c] == 0 {
                        let a = tape.a_sum[gr * n_c + c];
                        if a != 0.0 {
                            simd::axpy8(drc, a, &dr_s[gr * d..(gr + 1) * d]);
                        }
                    }
                }
            }
        },
    );

    // step 6a backward: combination weights A_sum -> (A_q-raw, φ),
    // token-parallel with a per-worker (pre, dpre) row pair
    zeroed(d_aq_raw, rows * n_c);
    zeroed(d_phi, rows);
    parallel::par_zip2_mut_with(
        d_aq_raw.as_mut_slice(),
        blk * n_c,
        d_phi.as_mut_slice(),
        blk,
        || vec![0.0f32; 2 * n_c],
        |scr, ci, daqr, dphi_c| {
            let (pre, dpre) = scr.split_at_mut(n_c);
            let r0 = ci * blk;
            for rr in 0..dphi_c.len() {
                let gr = r0 + rr;
                let phi_v = tape.phi[gr];
                let sp = ops::softplus1(phi_v) / tau;
                for c in 0..n_c {
                    pre[c] = tape.a_q_raw[gr * n_c + c] * sp;
                    dpre[c] = 0.0;
                }
                gops::attn_rows_backward(
                    pre,
                    &tape.a_sum[gr * n_c..(gr + 1) * n_c],
                    &d_asum_s[gr * n_c..(gr + 1) * n_c],
                    n_c,
                    attn,
                    dpre,
                );
                let sig = ops::sigmoid(phi_v) / tau;
                let mut dphi_acc = 0.0f32;
                for c in 0..n_c {
                    daqr[rr * n_c + c] = dpre[c] * sp;
                    dphi_acc += dpre[c] * tape.a_q_raw[gr * n_c + c] * sig;
                }
                dphi_c[rr] = dphi_acc;
            }
        },
    );

    // step 5 backward over the B×Nc grid: recompute the κ×κ probability
    // matrix and the summary weight row per (cell, head), writing this
    // cell's disjoint gradient slabs
    let cell_stride = 3 * kappa * d + h * kappa + kappa;
    zeroed(cell, b * n_c * cell_stride);
    let d_r_intra_s: &[f32] = d_r_intra.as_slice();
    let d_r_inter_s: &[f32] = d_r_inter.as_slice();
    parallel::par_chunks_mut_with(
        cell.as_mut_slice(),
        cell_stride,
        || CellScratch {
            pre: vec![0.0f32; kappa * kappa],
            p: vec![0.0f32; kappa * kappa],
            dp: vec![0.0f32; kappa * kappa],
            ds: vec![0.0f32; kappa * kappa],
            wpre: vec![0.0f32; kappa],
            wpost: vec![0.0f32; kappa],
            dw: vec![0.0f32; kappa],
            dwpre: vec![0.0f32; kappa],
        },
        |scr, cell_i, slab| {
            let bb = cell_i / n_c;
            let c = cell_i % n_c;
            let (dq_c, rest) = slab.split_at_mut(kappa * d);
            let (dk_c, rest) = rest.split_at_mut(kappa * d);
            let (dv_c, rest) = rest.split_at_mut(kappa * d);
            let (dak_c, dphi_c) = rest.split_at_mut(h * kappa);
            let base = (bb * n_c + c) * kappa;
            let slots = &tape.idx[base..base + kappa];
            let val = &tape.valid[base..base + kappa];
            let mask_ij = |i: usize, j: usize| -> f32 {
                if causal && slots[j] > slots[i] {
                    0.0
                } else {
                    val[j]
                }
            };
            for hh in 0..h {
                // recompute masked scores and their normalization
                for i in 0..kappa {
                    let qrow = &tape.q[(bb * n + slots[i]) * d + hh * d_h..][..d_h];
                    for j in 0..kappa {
                        let krow = &tape.k[(bb * n + slots[j]) * d + hh * d_h..][..d_h];
                        scr.pre[i * kappa + j] =
                            ops::dot(qrow, krow) / tau + (1.0 - mask_ij(i, j)) * NEG_INF;
                    }
                }
                scr.p.copy_from_slice(&scr.pre);
                ops::attn_rows(&mut scr.p, kappa, attn);

                // intra-cluster attention backward
                for v_ in scr.dp.iter_mut() {
                    *v_ = 0.0;
                }
                for i in 0..kappa {
                    if val[i] == 0.0 {
                        continue;
                    }
                    let dri = &d_r_intra_s[(base + i) * d + hh * d_h..][..d_h];
                    for j in 0..kappa {
                        let m = mask_ij(i, j);
                        if m == 0.0 {
                            continue;
                        }
                        let vrow = &tape.v[(bb * n + slots[j]) * d + hh * d_h..][..d_h];
                        scr.dp[i * kappa + j] = m * ops::dot(dri, vrow);
                        let pij = scr.p[i * kappa + j] * m;
                        if pij != 0.0 {
                            simd::axpy8(&mut dv_c[j * d + hh * d_h..][..d_h], pij, dri);
                        }
                    }
                }
                for v_ in scr.ds.iter_mut() {
                    *v_ = 0.0;
                }
                gops::attn_rows_backward(&scr.pre, &scr.p, &scr.dp, kappa, attn, &mut scr.ds);
                for i in 0..kappa {
                    for j in 0..kappa {
                        let dsv = scr.ds[i * kappa + j];
                        if dsv == 0.0 {
                            continue;
                        }
                        let qrow = &tape.q[(bb * n + slots[i]) * d + hh * d_h..][..d_h];
                        let krow = &tape.k[(bb * n + slots[j]) * d + hh * d_h..][..d_h];
                        let coef = dsv / tau;
                        simd::axpy8(&mut dq_c[i * d + hh * d_h..][..d_h], coef, krow);
                        simd::axpy8(&mut dk_c[j * d + hh * d_h..][..d_h], coef, qrow);
                    }
                }

                // cluster-summary backward (eq. 4; absent in causal mode)
                if !causal {
                    let drc = &d_r_inter_s[(bb * n_c + c) * d + hh * d_h..][..d_h];
                    for j in 0..kappa {
                        let t = slots[j];
                        scr.wpre[j] = tape.a_k[((bb * n + t) * h + hh) * n_c + c]
                            * ops::softplus1(-tape.phi[bb * n + t])
                            / tau
                            + (1.0 - val[j]) * NEG_INF;
                    }
                    scr.wpost.copy_from_slice(&scr.wpre);
                    ops::attn_rows(&mut scr.wpost, kappa, attn);
                    for j in 0..kappa {
                        if val[j] == 0.0 {
                            scr.dw[j] = 0.0;
                            continue;
                        }
                        let vrow = &tape.v[(bb * n + slots[j]) * d + hh * d_h..][..d_h];
                        scr.dw[j] = val[j] * ops::dot(drc, vrow);
                        let pk = scr.wpost[j] * val[j];
                        if pk != 0.0 {
                            simd::axpy8(&mut dv_c[j * d + hh * d_h..][..d_h], pk, drc);
                        }
                    }
                    for v_ in scr.dwpre.iter_mut() {
                        *v_ = 0.0;
                    }
                    gops::attn_rows_backward(
                        &scr.wpre,
                        &scr.wpost,
                        &scr.dw,
                        kappa,
                        attn,
                        &mut scr.dwpre,
                    );
                    for j in 0..kappa {
                        let dwp = scr.dwpre[j];
                        if dwp == 0.0 {
                            continue;
                        }
                        let t = slots[j];
                        let phi_t = tape.phi[bb * n + t];
                        let ak = tape.a_k[((bb * n + t) * h + hh) * n_c + c];
                        dak_c[hh * kappa + j] += dwp * ops::softplus1(-phi_t) / tau;
                        dphi_c[j] -= dwp * ak * ops::sigmoid(-phi_t) / tau;
                    }
                }
            }
        },
    );
    let cell_s: &[f32] = cell.as_slice();

    // token-parallel gathers via the slot_of reverse map: each token owns
    // at most one slot per cluster, so every read is unique
    let d_aq_raw_s: &[f32] = d_aq_raw.as_slice();
    zeroed(d_ak, rows * h * n_c);
    parallel::par_zip2_mut(
        d_ak.as_mut_slice(),
        blk * h * n_c,
        d_phi.as_mut_slice(),
        blk,
        |ci, dak_chunk, dphi_chunk| {
            let r0 = ci * blk;
            for rr in 0..dphi_chunk.len() {
                let gr = r0 + rr;
                let bb = gr / n;
                for c in 0..n_c {
                    let slot = tape.slot_of[gr * n_c + c];
                    if slot == 0 {
                        continue;
                    }
                    let off = (bb * n_c + c) * cell_stride;
                    dphi_chunk[rr] += cell_s[off + 3 * kappa * d + h * kappa + (slot - 1)];
                    for hh in 0..h {
                        dak_chunk[(rr * h + hh) * n_c + c] =
                            cell_s[off + 3 * kappa * d + hh * kappa + (slot - 1)];
                    }
                }
            }
        },
    );
    let d_ak_s: &[f32] = d_ak.as_slice();

    // per-token q/k/v gradients: cell-slab gather + the affinity terms
    // (d A_q-raw broadcasts over heads; d A_k came from the gather above)
    zeroed(dq, rows * d);
    zeroed(dk, rows * d);
    zeroed(dv, rows * d);
    let s_w = p.s;
    parallel::par_chunks_mut(dq.as_mut_slice(), blk * d, |ci, chunk| {
        let r0 = ci * blk;
        for (rr, dst) in chunk.chunks_mut(d).enumerate() {
            let gr = r0 + rr;
            let bb = gr / n;
            for c in 0..n_c {
                let slot = tape.slot_of[gr * n_c + c];
                if slot > 0 {
                    let src = (bb * n_c + c) * cell_stride + (slot - 1) * d;
                    simd::add8(dst, &cell_s[src..src + d]);
                }
                let daq = d_aq_raw_s[gr * n_c + c];
                if daq != 0.0 {
                    for hh in 0..h {
                        let srow = &s_w[(c * h + hh) * d_h..][..d_h];
                        simd::axpy8(&mut dst[hh * d_h..(hh + 1) * d_h], daq, srow);
                    }
                }
            }
        }
    });
    parallel::par_chunks_mut(dk.as_mut_slice(), blk * d, |ci, chunk| {
        let r0 = ci * blk;
        for (rr, dst) in chunk.chunks_mut(d).enumerate() {
            let gr = r0 + rr;
            let bb = gr / n;
            for c in 0..n_c {
                let slot = tape.slot_of[gr * n_c + c];
                if slot > 0 {
                    let src = (bb * n_c + c) * cell_stride + kappa * d + (slot - 1) * d;
                    simd::add8(dst, &cell_s[src..src + d]);
                }
                for hh in 0..h {
                    let dak = d_ak_s[(gr * h + hh) * n_c + c];
                    if dak != 0.0 {
                        let srow = &s_w[(c * h + hh) * d_h..][..d_h];
                        simd::axpy8(&mut dst[hh * d_h..(hh + 1) * d_h], dak, srow);
                    }
                }
            }
        }
    });
    parallel::par_chunks_mut(dv.as_mut_slice(), blk * d, |ci, chunk| {
        let r0 = ci * blk;
        for (rr, dst) in chunk.chunks_mut(d).enumerate() {
            let gr = r0 + rr;
            let bb = gr / n;
            for c in 0..n_c {
                let slot = tape.slot_of[gr * n_c + c];
                if slot > 0 {
                    let src = (bb * n_c + c) * cell_stride + 2 * kappa * d + (slot - 1) * d;
                    simd::add8(dst, &cell_s[src..src + d]);
                }
            }
        }
    });

    // surrogate-token gradients: one task per cluster, fixed token order
    parallel::par_chunks_mut(g.s, h * d_h, |c, schunk| {
        for gr in 0..rows {
            let daq = d_aq_raw_s[gr * n_c + c];
            for hh in 0..h {
                let dak = d_ak_s[(gr * h + hh) * n_c + c];
                if daq == 0.0 && dak == 0.0 {
                    continue;
                }
                let qrow = &tape.q[gr * d + hh * d_h..][..d_h];
                let krow = &tape.k[gr * d + hh * d_h..][..d_h];
                let dst = &mut schunk[hh * d_h..(hh + 1) * d_h];
                simd::axpy8(dst, daq, qrow);
                simd::axpy8(dst, dak, krow);
            }
        }
    });

    // projection backward (eq. 1)
    gops::dense_grad_params(&tape.x, dq, rows, d, d, g.wq_w, g.wq_b);
    gops::dense_grad_input_acc(dq, p.wq_w, rows, d, d, dx);
    gops::dense_grad_params(&tape.x, dk, rows, d, d, g.wk_w, g.wk_b);
    gops::dense_grad_input_acc(dk, p.wk_w, rows, d, d, dx);
    gops::dense_grad_params(&tape.x, dv, rows, d, d, g.wv_w, g.wv_b);
    gops::dense_grad_input_acc(dv, p.wv_w, rows, d, d, dx);
    gops::dense_grad_params(&tape.x, d_phi, rows, d, 1, g.phi_w, g.phi_b);
    gops::dense_grad_input_acc(d_phi, p.phi_w, rows, d, 1, dx);
    Ok(())
}

// ---------------------------------------------------------------------------
// baselines
// ---------------------------------------------------------------------------

/// Mutable views of a baseline layer's parameter-gradient buffers.
pub struct BaselineGradRefs<'a> {
    pub wq_w: &'a mut [f32],
    pub wq_b: &'a mut [f32],
    pub wk_w: &'a mut [f32],
    pub wk_b: &'a mut [f32],
    pub wv_w: &'a mut [f32],
    pub wv_b: &'a mut [f32],
    pub wo_w: &'a mut [f32],
    pub wo_b: &'a mut [f32],
}

/// Reusable backward buffers for the baseline layers.
#[derive(Default)]
pub struct BaselineBwdScratch {
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    attn_out: Vec<f32>,
    dr: Vec<f32>,
    /// Per-row fused `dq | dk | dv` slab (rows, 3d) — one window region
    /// per task owns a disjoint row range of all three.
    dqkv: Vec<f32>,
    dq: Vec<f32>,
    dk: Vec<f32>,
    dv: Vec<f32>,
}

/// Per-worker scratch for one window-attention backward task.
struct WindowScratch {
    pre: Vec<f32>,
    p: Vec<f32>,
    dp: Vec<f32>,
    ds: Vec<f32>,
}

/// Reverse pass of the vanilla (`window = None`) and local (`Some(w)`)
/// baselines.  Projections and attention probabilities are recomputed
/// from the stored layer input `x`; the parallel grain is one attention
/// window (the whole sequence for vanilla), whose q/k/v rows are
/// touched by no other window.
pub fn window_backward(
    p: &BaselineParams,
    x: &[f32],
    dims: &Dims,
    window: Option<usize>,
    d_out: &[f32],
    dx: &mut [f32],
    g: &mut BaselineGradRefs,
    ws: &mut BaselineBwdScratch,
) -> Result<()> {
    let (b, n, h, d_h) = (dims.b, dims.n, dims.heads, dims.d_h);
    let d = dims.d();
    let rows = b * n;
    let w = window.unwrap_or(n);
    ensure!(w > 0 && n % w == 0, "window {w} must divide seq_len {n}");
    ensure!(d_out.len() == rows * d && dx.len() == rows * d, "window backward shape");
    let tau = (d_h as f32).sqrt();
    let attn = dims.attn;

    let BaselineBwdScratch { q, k, v, attn_out, dr, dqkv, dq, dk, dv } = ws;

    // recompute projections + the pre-projection attention output
    ops::dense_into(x, p.wq_w, p.wq_b, rows, d, d, q);
    ops::dense_into(x, p.wk_w, p.wk_b, rows, d, d, k);
    ops::dense_into(x, p.wv_w, p.wv_b, rows, d, d, v);
    zeroed(attn_out, rows * d);
    attend_windows(attn_out.as_mut_slice(), q, k, v, b, n, h, d_h, window, attn);

    zeroed(dr, rows * d);
    gops::dense_grad_input_acc(d_out, p.wo_w, rows, d, d, dr);
    gops::dense_grad_params(attn_out, d_out, rows, d, d, g.wo_w, g.wo_b);
    let dr_s: &[f32] = dr.as_slice();
    let q_s: &[f32] = q.as_slice();
    let k_s: &[f32] = k.as_slice();
    let v_s: &[f32] = v.as_slice();

    // per-window backward into the fused dq|dk|dv row slab
    zeroed(dqkv, rows * 3 * d);
    parallel::par_chunks_mut_with(
        dqkv.as_mut_slice(),
        w * 3 * d,
        || WindowScratch {
            pre: vec![0.0f32; w],
            p: vec![0.0f32; w],
            dp: vec![0.0f32; w],
            ds: vec![0.0f32; w],
        },
        |scr, wi, slab| {
            let r0 = wi * w; // global first row of this window
            for i in 0..w {
                let gi = r0 + i;
                for hh in 0..h {
                    let qrow = &q_s[gi * d + hh * d_h..][..d_h];
                    for j in 0..w {
                        let krow = &k_s[(r0 + j) * d + hh * d_h..][..d_h];
                        scr.pre[j] = ops::dot(qrow, krow) / tau;
                    }
                    scr.p.copy_from_slice(&scr.pre);
                    ops::attn_rows(&mut scr.p, w, attn);
                    let dro = &dr_s[gi * d + hh * d_h..][..d_h];
                    for j in 0..w {
                        let vrow = &v_s[(r0 + j) * d + hh * d_h..][..d_h];
                        scr.dp[j] = ops::dot(dro, vrow);
                        let pj = scr.p[j];
                        if pj != 0.0 {
                            simd::axpy8(&mut slab[j * 3 * d + 2 * d + hh * d_h..][..d_h], pj, dro);
                        }
                    }
                    for v_ in scr.ds.iter_mut() {
                        *v_ = 0.0;
                    }
                    gops::attn_rows_backward(&scr.pre, &scr.p, &scr.dp, w, attn, &mut scr.ds);
                    for j in 0..w {
                        let dsv = scr.ds[j];
                        if dsv == 0.0 {
                            continue;
                        }
                        let krow = &k_s[(r0 + j) * d + hh * d_h..][..d_h];
                        let coef = dsv / tau;
                        simd::axpy8(&mut slab[i * 3 * d + hh * d_h..][..d_h], coef, krow);
                        simd::axpy8(&mut slab[j * 3 * d + d + hh * d_h..][..d_h], coef, qrow);
                    }
                }
            }
        },
    );

    // unpack the slab and run the projection backward
    let dqkv_s: &[f32] = dqkv.as_slice();
    let blk = parallel::row_block(rows);
    zeroed(dq, rows * d);
    zeroed(dk, rows * d);
    zeroed(dv, rows * d);
    parallel::par_chunks_mut(dq.as_mut_slice(), blk * d, |ci, chunk| {
        let r0 = ci * blk;
        for (rr, dst) in chunk.chunks_mut(d).enumerate() {
            dst.copy_from_slice(&dqkv_s[(r0 + rr) * 3 * d..][..d]);
        }
    });
    parallel::par_chunks_mut(dk.as_mut_slice(), blk * d, |ci, chunk| {
        let r0 = ci * blk;
        for (rr, dst) in chunk.chunks_mut(d).enumerate() {
            dst.copy_from_slice(&dqkv_s[(r0 + rr) * 3 * d + d..][..d]);
        }
    });
    parallel::par_chunks_mut(dv.as_mut_slice(), blk * d, |ci, chunk| {
        let r0 = ci * blk;
        for (rr, dst) in chunk.chunks_mut(d).enumerate() {
            dst.copy_from_slice(&dqkv_s[(r0 + rr) * 3 * d + 2 * d..][..d]);
        }
    });
    gops::dense_grad_params(x, dq, rows, d, d, g.wq_w, g.wq_b);
    gops::dense_grad_input_acc(dq, p.wq_w, rows, d, d, dx);
    gops::dense_grad_params(x, dk, rows, d, d, g.wk_w, g.wk_b);
    gops::dense_grad_input_acc(dk, p.wk_w, rows, d, d, dx);
    gops::dense_grad_params(x, dv, rows, d, d, g.wv_w, g.wv_b);
    gops::dense_grad_input_acc(dv, p.wv_w, rows, d, d, dx);
    Ok(())
}

/// Forward intermediates of one LSH baseline layer: the tied Q/K and V
/// projections plus the (non-differentiable, straight-through) bucket
/// sort order.  The chunked attention probabilities are recomputed.
pub struct LshTape {
    pub x: Vec<f32>,
    qk: Vec<f32>,
    v: Vec<f32>,
    order: Vec<usize>,
    attn_out: Vec<f32>,
}

impl LshTape {
    /// Fingerprint of the bucket-sort order (for gradient checks).
    pub fn fingerprint(&self) -> u64 {
        let mut h = 0xcbf2_9ce4_8422_2325u64;
        for &i in &self.order {
            h = fnv_fold(h, i as u64);
        }
        h
    }
}

/// Forward of the LSH baseline with tape capture — same pieces as
/// `layer::lsh_layer`, so outputs match it exactly.
pub fn lsh_forward_tape(
    p: &BaselineParams,
    x: &[f32],
    dims: &Dims,
) -> Result<(Vec<f32>, LshTape)> {
    let (b, n, h, d_h, n_c) = (dims.b, dims.n, dims.heads, dims.d_h, dims.n_c);
    let d = dims.d();
    let rows = b * n;
    let kappa = dims.kappa.min(n).max(1);
    let qk = ops::dense(x, p.wq_w, p.wq_b, rows, d, d);
    let v = ops::dense(x, p.wv_w, p.wv_b, rows, d, d);
    let order = lsh_sort_order(&qk, b, n, d, n_c);
    let attn_out = lsh_attend(&qk, &v, &order, b, n, h, d_h, kappa, dims.attn);
    let out = ops::dense(&attn_out, p.wo_w, p.wo_b, rows, d, d);
    Ok((out, LshTape { x: x.to_vec(), qk, v, order, attn_out }))
}

/// Per-worker scratch for one batch of the LSH backward.
struct LshBwdWorker {
    qk_s: Vec<f32>,
    v_s: Vec<f32>,
    dro_s: Vec<f32>,
    dqk_s: Vec<f32>,
    dv_s: Vec<f32>,
    pre: Vec<f32>,
    p: Vec<f32>,
    dp: Vec<f32>,
    ds: Vec<f32>,
}

/// Reverse pass of the LSH baseline with the bucket assignment held
/// constant.  The tied Q/K projection accumulates both roles' gradients
/// into `wq`; `wk` is unused by this layer and receives none.
pub fn lsh_backward(
    p: &BaselineParams,
    tape: &LshTape,
    dims: &Dims,
    d_out: &[f32],
    dx: &mut [f32],
    g: &mut BaselineGradRefs,
    ws: &mut BaselineBwdScratch,
) -> Result<()> {
    let (b, n, h, d_h) = (dims.b, dims.n, dims.heads, dims.d_h);
    let d = dims.d();
    let rows = b * n;
    let kappa = dims.kappa.min(n).max(1);
    ensure!(d_out.len() == rows * d && dx.len() == rows * d, "lsh backward shape");
    let m = n.div_ceil(kappa) * kappa;
    let tau = (d_h as f32).sqrt();
    let attn = dims.attn;

    let BaselineBwdScratch { dr, dq, dv, .. } = ws;

    zeroed(dr, rows * d);
    gops::dense_grad_input_acc(d_out, p.wo_w, rows, d, d, dr);
    gops::dense_grad_params(&tape.attn_out, d_out, rows, d, d, g.wo_w, g.wo_b);
    let dr_s: &[f32] = dr.as_slice();

    // per-batch chunked-attention backward into sorted copies, then
    // un-sorted into the per-token dqk (reusing the dq buffer) and dv
    zeroed(dq, rows * d);
    zeroed(dv, rows * d);
    parallel::par_zip2_mut_with(
        dq.as_mut_slice(),
        n * d,
        dv.as_mut_slice(),
        n * d,
        || LshBwdWorker {
            qk_s: vec![0.0f32; m * d],
            v_s: vec![0.0f32; m * d],
            dro_s: vec![0.0f32; m * d],
            dqk_s: vec![0.0f32; m * d],
            dv_s: vec![0.0f32; m * d],
            pre: vec![0.0f32; kappa],
            p: vec![0.0f32; kappa],
            dp: vec![0.0f32; kappa],
            ds: vec![0.0f32; kappa],
        },
        |scr, bb, dqk_b, dv_b| {
            let ord = &tape.order[bb * n..(bb + 1) * n];
            scr.qk_s.iter_mut().for_each(|z| *z = 0.0);
            scr.v_s.iter_mut().for_each(|z| *z = 0.0);
            scr.dro_s.iter_mut().for_each(|z| *z = 0.0);
            scr.dqk_s.iter_mut().for_each(|z| *z = 0.0);
            scr.dv_s.iter_mut().for_each(|z| *z = 0.0);
            for (pos, &t) in ord.iter().enumerate() {
                scr.qk_s[pos * d..(pos + 1) * d]
                    .copy_from_slice(&tape.qk[(bb * n + t) * d..][..d]);
                scr.v_s[pos * d..(pos + 1) * d]
                    .copy_from_slice(&tape.v[(bb * n + t) * d..][..d]);
                scr.dro_s[pos * d..(pos + 1) * d]
                    .copy_from_slice(&dr_s[(bb * n + t) * d..][..d]);
            }
            for chunk in 0..m / kappa {
                let lo = chunk * kappa;
                for i in lo..(lo + kappa).min(n) {
                    for hh in 0..h {
                        let qrow = &scr.qk_s[i * d + hh * d_h..][..d_h];
                        for jj in 0..kappa {
                            scr.pre[jj] = if lo + jj >= n {
                                NEG_INF
                            } else {
                                let krow = &scr.qk_s[(lo + jj) * d + hh * d_h..][..d_h];
                                ops::dot(qrow, krow) / tau
                            };
                        }
                        scr.p.copy_from_slice(&scr.pre);
                        ops::attn_rows(&mut scr.p, kappa, attn);
                        let dro0 = i * d + hh * d_h;
                        for jj in 0..kappa {
                            let vrow = &scr.v_s[(lo + jj) * d + hh * d_h..][..d_h];
                            scr.dp[jj] =
                                ops::dot(&scr.dro_s[dro0..dro0 + d_h], vrow);
                            let pj = scr.p[jj];
                            if pj != 0.0 {
                                simd::axpy8(
                                    &mut scr.dv_s[(lo + jj) * d + hh * d_h..][..d_h],
                                    pj,
                                    &scr.dro_s[dro0..dro0 + d_h],
                                );
                            }
                        }
                        for v_ in scr.ds.iter_mut() {
                            *v_ = 0.0;
                        }
                        gops::attn_rows_backward(
                            &scr.pre,
                            &scr.p,
                            &scr.dp,
                            kappa,
                            attn,
                            &mut scr.ds,
                        );
                        for jj in 0..kappa {
                            let dsv = scr.ds[jj];
                            if dsv == 0.0 {
                                continue;
                            }
                            // tied Q/K: both roles' gradients land in qk
                            let coef = dsv / tau;
                            simd::axpy8(
                                &mut scr.dqk_s[i * d + hh * d_h..][..d_h],
                                coef,
                                &scr.qk_s[(lo + jj) * d + hh * d_h..][..d_h],
                            );
                            simd::axpy8(
                                &mut scr.dqk_s[(lo + jj) * d + hh * d_h..][..d_h],
                                coef,
                                &scr.qk_s[i * d + hh * d_h..][..d_h],
                            );
                        }
                    }
                }
            }
            for (pos, &t) in ord.iter().enumerate() {
                dqk_b[t * d..][..d].copy_from_slice(&scr.dqk_s[pos * d..][..d]);
                dv_b[t * d..][..d].copy_from_slice(&scr.dv_s[pos * d..][..d]);
            }
        },
    );

    gops::dense_grad_params(&tape.x, dq, rows, d, d, g.wq_w, g.wq_b);
    gops::dense_grad_input_acc(dq, p.wq_w, rows, d, d, dx);
    gops::dense_grad_params(&tape.x, dv, rows, d, d, g.wv_w, g.wv_b);
    gops::dense_grad_input_acc(dv, p.wv_w, rows, d, d, dx);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::super::super::layer::{cast_layer, local_layer, vanilla_layer};
    use super::super::super::ops::AttnFn;
    use super::*;
    use crate::util::prop::{assert_grads_close, GradCheckCfg};
    use crate::util::rng::Rng;

    /// Layer-level checks use a larger step than the primitive ops: the
    /// loss sums ~64 outputs, so the f32 evaluation noise divided by 2ε
    /// needs ε ≈ 1e-2 to stay under the absolute tolerance.  Cluster
    /// flips induced by the larger step are caught by the fingerprint.
    fn layer_cfg() -> GradCheckCfg {
        GradCheckCfg { eps: 1e-2, rel_tol: 1e-2, abs_tol: 1e-3, max_per_block: 8 }
    }

    fn dims(clustering: &str, attn: AttnFn) -> Dims {
        Dims {
            b: 1,
            n: 8,
            heads: 2,
            d_h: 4,
            n_c: 2,
            kappa: 4,
            attn,
            clustering: clustering.to_string(),
            causal: clustering == "causal",
            window: 4,
        }
    }

    fn randn(rng: &mut Rng, n: usize, scale: f32) -> Vec<f32> {
        (0..n).map(|_| rng.gaussian() as f32 * scale).collect()
    }

    fn split<'a>(t: &'a [f32], lens: &[usize]) -> Vec<&'a [f32]> {
        let mut out = Vec::with_capacity(lens.len());
        let mut off = 0usize;
        for &l in lens {
            out.push(&t[off..off + l]);
            off += l;
        }
        out
    }

    fn cast_lens(dm: &Dims) -> Vec<(String, usize)> {
        let d = dm.d();
        vec![
            ("wq.w".into(), d * d),
            ("wq.b".into(), d),
            ("wk.w".into(), d * d),
            ("wk.b".into(), d),
            ("wv.w".into(), d * d),
            ("wv.b".into(), d),
            ("wo.w".into(), d * d),
            ("wo.b".into(), d),
            ("s".into(), dm.n_c * dm.heads * dm.d_h),
            ("phi.w".into(), d),
            ("phi.b".into(), 1),
        ]
    }

    fn cast_params_of<'a>(parts: &[&'a [f32]]) -> CastParams<'a> {
        CastParams {
            wq_w: parts[0],
            wq_b: parts[1],
            wk_w: parts[2],
            wk_b: parts[3],
            wv_w: parts[4],
            wv_b: parts[5],
            wo_w: parts[6],
            wo_b: parts[7],
            s: parts[8],
            phi_w: parts[9],
            phi_b: parts[10],
        }
    }

    fn random_theta(rng: &mut Rng, lens: &[(String, usize)], d: usize) -> Vec<f32> {
        let scale = 1.0 / (d as f32).sqrt();
        let mut theta = Vec::new();
        for (name, len) in lens {
            let s = if name.ends_with(".b") { 0.1 } else { scale };
            theta.extend(randn(rng, *len, s));
        }
        theta
    }

    fn scratch_fingerprint(ws: &CastScratch) -> u64 {
        let mut h = 0xcbf2_9ce4_8422_2325u64;
        for &i in &ws.idx {
            h = fnv_fold(h, i as u64);
        }
        for &v in &ws.valid {
            h = fnv_fold(h, (v > 0.0) as u64);
        }
        h
    }

    /// Analytic parameter gradients + input gradient of one cast layer
    /// under the linear loss `<c, out>`.
    fn cast_analytic(
        theta: &[f32],
        lens: &[(String, usize)],
        x: &[f32],
        c: &[f32],
        dm: &Dims,
    ) -> (Vec<f32>, Vec<f32>) {
        let lens_only: Vec<usize> = lens.iter().map(|(_, l)| *l).collect();
        let parts = split(theta, &lens_only);
        let p = cast_params_of(&parts);
        let mut ws = CastScratch::new();
        cast_layer(&p, x, dm, &mut ws).unwrap();
        let tape = CastTape::capture(x, &ws);
        let mut gbufs: Vec<Vec<f32>> = lens_only.iter().map(|&l| vec![0.0; l]).collect();
        let mut dx = vec![0.0f32; x.len()];
        let [wq_w, wq_b, wk_w, wk_b, wv_w, wv_b, wo_w, wo_b, s, phi_w, phi_b] =
            &mut gbufs[..]
        else {
            unreachable!()
        };
        let mut g = CastGradRefs {
            wq_w: wq_w.as_mut_slice(),
            wq_b: wq_b.as_mut_slice(),
            wk_w: wk_w.as_mut_slice(),
            wk_b: wk_b.as_mut_slice(),
            wv_w: wv_w.as_mut_slice(),
            wv_b: wv_b.as_mut_slice(),
            wo_w: wo_w.as_mut_slice(),
            wo_b: wo_b.as_mut_slice(),
            s: s.as_mut_slice(),
            phi_w: phi_w.as_mut_slice(),
            phi_b: phi_b.as_mut_slice(),
        };
        cast_layer_backward(&p, &tape, dm, c, &mut dx, &mut g, &mut CastBwdScratch::default())
            .unwrap();
        (gbufs.concat(), dx)
    }

    fn check_cast_layer(clustering: &str, attn: AttnFn, seed: u64) {
        let dm = dims(clustering, attn);
        let d = dm.d();
        let rows = dm.b * dm.n;
        let mut rng = Rng::new(seed);
        let lens = cast_lens(&dm);
        let theta = random_theta(&mut rng, &lens, d);
        let x = randn(&mut rng, rows * d, 1.0);
        let c = randn(&mut rng, rows * d, 0.5);
        let (analytic, _) = cast_analytic(&theta, &lens, &x, &c, &dm);
        let lens_only: Vec<usize> = lens.iter().map(|(_, l)| *l).collect();
        assert_grads_close(&layer_cfg(), &theta, &lens, &analytic, |t| {
            let parts = split(t, &lens_only);
            let p = cast_params_of(&parts);
            let mut ws = CastScratch::new();
            let (out, _) = cast_layer(&p, &x, &dm, &mut ws).unwrap();
            (ops::dot(&c, &out), scratch_fingerprint(&ws))
        });
    }

    #[test]
    fn cast_topk_softmax_parameter_gradients() {
        check_cast_layer("topk", AttnFn::Softmax, 101);
    }

    #[test]
    fn cast_topk_laplace_parameter_gradients() {
        check_cast_layer("topk", AttnFn::Laplace, 102);
    }

    #[test]
    fn cast_sa_softmax_parameter_gradients() {
        check_cast_layer("sa", AttnFn::Softmax, 103);
    }

    #[test]
    fn cast_causal_softmax_parameter_gradients() {
        check_cast_layer("causal", AttnFn::Softmax, 104);
    }

    #[test]
    fn cast_input_gradient_through_combination_scatter() {
        // perturbing x moves every path at once — the combination
        // scatter (member R_intra rows + non-member R_inter summaries)
        // must agree with the numeric derivative
        let dm = dims("topk", AttnFn::Softmax);
        let d = dm.d();
        let rows = dm.b * dm.n;
        let mut rng = Rng::new(77);
        let lens = cast_lens(&dm);
        let theta = random_theta(&mut rng, &lens, d);
        let x = randn(&mut rng, rows * d, 1.0);
        let c = randn(&mut rng, rows * d, 0.5);
        let (_, dx) = cast_analytic(&theta, &lens, &x, &c, &dm);
        let lens_only: Vec<usize> = lens.iter().map(|(_, l)| *l).collect();
        let blocks = vec![("x".to_string(), rows * d)];
        assert_grads_close(&layer_cfg(), &x, &blocks, &dx, |xt| {
            let parts = split(&theta, &lens_only);
            let p = cast_params_of(&parts);
            let mut ws = CastScratch::new();
            let (out, _) = cast_layer(&p, xt, &dm, &mut ws).unwrap();
            (ops::dot(&c, &out), scratch_fingerprint(&ws))
        });
    }

    fn baseline_lens(d: usize) -> Vec<(String, usize)> {
        vec![
            ("wq.w".into(), d * d),
            ("wq.b".into(), d),
            ("wk.w".into(), d * d),
            ("wk.b".into(), d),
            ("wv.w".into(), d * d),
            ("wv.b".into(), d),
            ("wo.w".into(), d * d),
            ("wo.b".into(), d),
        ]
    }

    fn baseline_params_of<'a>(parts: &[&'a [f32]]) -> BaselineParams<'a> {
        BaselineParams {
            wq_w: parts[0],
            wq_b: parts[1],
            wk_w: parts[2],
            wk_b: parts[3],
            wv_w: parts[4],
            wv_b: parts[5],
            wo_w: parts[6],
            wo_b: parts[7],
        }
    }

    fn baseline_analytic(
        theta: &[f32],
        lens_only: &[usize],
        x: &[f32],
        c: &[f32],
        dm: &Dims,
        which: &str,
    ) -> Vec<f32> {
        let parts = split(theta, lens_only);
        let p = baseline_params_of(&parts);
        let mut gbufs: Vec<Vec<f32>> = lens_only.iter().map(|&l| vec![0.0; l]).collect();
        let mut dx = vec![0.0f32; x.len()];
        let [wq_w, wq_b, wk_w, wk_b, wv_w, wv_b, wo_w, wo_b] = &mut gbufs[..] else {
            unreachable!()
        };
        let mut g = BaselineGradRefs {
            wq_w: wq_w.as_mut_slice(),
            wq_b: wq_b.as_mut_slice(),
            wk_w: wk_w.as_mut_slice(),
            wk_b: wk_b.as_mut_slice(),
            wv_w: wv_w.as_mut_slice(),
            wv_b: wv_b.as_mut_slice(),
            wo_w: wo_w.as_mut_slice(),
            wo_b: wo_b.as_mut_slice(),
        };
        let mut ws = BaselineBwdScratch::default();
        match which {
            "vanilla" => window_backward(&p, x, dm, None, c, &mut dx, &mut g, &mut ws).unwrap(),
            "local" => {
                window_backward(&p, x, dm, Some(dm.window), c, &mut dx, &mut g, &mut ws).unwrap()
            }
            _ => {
                let (_, tape) = lsh_forward_tape(&p, x, dm).unwrap();
                lsh_backward(&p, &tape, dm, c, &mut dx, &mut g, &mut ws).unwrap()
            }
        }
        gbufs.concat()
    }

    #[test]
    fn baseline_parameter_gradients_match_central_difference() {
        for (which, attn) in
            [("vanilla", AttnFn::Softmax), ("local", AttnFn::Laplace), ("lsh", AttnFn::Softmax)]
        {
            let dm = dims("topk", attn);
            let d = dm.d();
            let rows = dm.b * dm.n;
            let mut rng = Rng::new(301);
            let lens = baseline_lens(d);
            let lens_only: Vec<usize> = lens.iter().map(|(_, l)| *l).collect();
            let theta = random_theta(&mut rng, &lens, d);
            let x = randn(&mut rng, rows * d, 1.0);
            let c = randn(&mut rng, rows * d, 0.5);
            let analytic = baseline_analytic(&theta, &lens_only, &x, &c, &dm, which);
            assert_grads_close(&layer_cfg(), &theta, &lens, &analytic, |t| {
                let parts = split(t, &lens_only);
                let p = baseline_params_of(&parts);
                match which {
                    "vanilla" => (ops::dot(&c, &vanilla_layer(&p, &x, &dm).unwrap()), 0),
                    "local" => (ops::dot(&c, &local_layer(&p, &x, &dm).unwrap()), 0),
                    _ => {
                        let (out, tape) = lsh_forward_tape(&p, &x, &dm).unwrap();
                        (ops::dot(&c, &out), tape.fingerprint())
                    }
                }
            });
        }
    }

    #[test]
    fn lsh_tape_forward_matches_layer_forward() {
        let dm = dims("topk", AttnFn::Softmax);
        let d = dm.d();
        let mut rng = Rng::new(9);
        let lens = baseline_lens(d);
        let lens_only: Vec<usize> = lens.iter().map(|(_, l)| *l).collect();
        let theta = random_theta(&mut rng, &lens, d);
        let x = randn(&mut rng, dm.b * dm.n * d, 1.0);
        let parts = split(&theta, &lens_only);
        let p = baseline_params_of(&parts);
        let direct = super::super::super::layer::lsh_layer(&p, &x, &dm).unwrap();
        let (taped, _) = lsh_forward_tape(&p, &x, &dm).unwrap();
        assert_eq!(direct, taped, "tape forward must be bit-identical to the layer");
    }
}
