//! Native autograd: exact reverse-mode differentiation through the whole
//! CAST stack — embedding + positional lookup, every attention variant
//! (CAST Top-K / SA / causal, vanilla, local, LSH), both attention
//! weight functions (softmax, laplace), layer/scale norms, GELU FFNs,
//! residuals, mean-pooling, and the classifier head (single and dual).
//!
//! Three layers (DESIGN.md §Autograd):
//!
//! * [`ops`] — backward primitives (dense input/parameter grads, the
//!   attention-row normalizations, the norms), all accumulate-convention
//!   and threaded like their forwards.
//! * [`layer`] — per-layer tapes and reverse passes.  The forward
//!   scratch ([`super::layer::CastScratch`]) doubles as the tape source;
//!   probability matrices are recomputed, hard cluster assignments are
//!   straight-through constants.
//! * [`model`] — the whole-model taped forward + backward behind
//!   [`loss_and_grads`], which `run_train_step` drives for the default
//!   full-parameter training scope.
//!
//! Determinism: every backward pass shards over disjoint output chunks
//! (row blocks, the B×Nc cluster grid, per-window / per-batch regions)
//! with fixed reduction orders, so gradients are bit-identical for any
//! `CAST_NUM_THREADS` — asserted by `tests/integration_parallel.rs`.
//! Gradients are validated against central differences via
//! `util::prop::grad_check` (tolerance-aware, per-parameter-block,
//! fingerprint-guarded against cluster-assignment flips).

pub mod layer;
pub mod model;
pub mod ops;

pub use model::{loss_and_grads, GradScratch, LossAndGrads};
