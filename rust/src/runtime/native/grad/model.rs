//! Whole-model reverse pass: a taped forward through the native encoder
//! (embedding + positions → proj → blocks → pool → head, including the
//! dual-tower retrieval head) followed by exact backpropagation into
//! every parameter, in manifest order.
//!
//! The taped forward calls the *same* layer code the `predict` path
//! uses (`layer::cast_layer`, the baselines, `model::apply_norm`), so
//! training and inference can never drift; the tape captures layer
//! inputs, norm inputs, FFN pre-activations, and the attention
//! intermediates described in `grad::layer`.  [`loss_and_grads`] is the
//! single entry `run_train_step` (and the tests) drive.

use std::collections::HashMap;

use anyhow::{ensure, Context, Result};

use crate::runtime::artifacts::{Manifest, ModelMeta};
use crate::runtime::tensor::HostTensor;
use crate::util::parallel;
use crate::util::simd;
use crate::util::trace;

use super::super::layer::{CastScratch, Dims};
use super::super::model::{apply_norm, dims_for, head_forward, softmax_xent, Params, NORM_EPS};
use super::super::ops;
use super::super::variants::{self, AttnTape, AttnVariant};
use super::layer as glayer;
use super::layer::fnv_fold;
use super::ops as gops;

/// Clear + zero-fill a reusable buffer (keeps its allocation).
fn zeroed(buf: &mut Vec<f32>, len: usize) {
    buf.clear();
    buf.resize(len, 0.0);
}

/// Reusable backward buffers — the reverse analogue of the forward
/// `Workspace`: one instance serves every layer of every backward call.
#[derive(Default)]
pub struct GradScratch {
    cast_fwd: CastScratch,
    cast_bwd: glayer::CastBwdScratch,
    base_bwd: glayer::BaselineBwdScratch,
    /// Running activation gradient (B·N, d).
    dx: Vec<f32>,
    /// Norm-input gradient staging buffer (swapped with `dx`).
    dnorm: Vec<f32>,
    /// Copy of `dx` handed to a residual branch as its output gradient.
    dbranch: Vec<f32>,
    /// FFN input gradient (B·N, d).
    dffn_in: Vec<f32>,
    /// FFN hidden gradient (B·N, d_ff).
    dhid: Vec<f32>,
    /// Recomputed FFN activations gelu(hid_pre) (B·N, d_ff).
    act: Vec<f32>,
    /// Embedding-space gradient (B·N, d_emb).
    dx0: Vec<f32>,
}

impl GradScratch {
    pub fn new() -> GradScratch {
        GradScratch::default()
    }
}

/// The result of one forward+backward pass.
pub struct LossAndGrads {
    pub loss: f32,
    pub acc: f32,
    /// Per-parameter gradients, aligned with `manifest.params`.
    pub grads: Vec<Vec<f32>>,
    /// FNV fingerprint of every discrete forward choice (cluster
    /// assignments, LSH sort orders).  Gradient checks skip coordinates
    /// whose perturbation flips it — the loss is not differentiable
    /// across those boundaries (straight-through estimator).
    pub fingerprint: u64,
}

// ---------------------------------------------------------------------------
// gradient store
// ---------------------------------------------------------------------------

/// Zeroed gradient buffers in manifest order, addressable by name.
struct GradStore {
    bufs: Vec<Vec<f32>>,
    names: Vec<String>,
    index: HashMap<String, usize>,
}

impl GradStore {
    fn new(manifest: &Manifest) -> GradStore {
        let mut bufs = Vec::with_capacity(manifest.params.len());
        let mut names = Vec::with_capacity(manifest.params.len());
        let mut index = HashMap::with_capacity(manifest.params.len());
        for (i, spec) in manifest.params.iter().enumerate() {
            bufs.push(vec![0.0f32; spec.shape.iter().product()]);
            names.push(spec.name.clone());
            index.insert(spec.name.clone(), i);
        }
        GradStore { bufs, names, index }
    }

    fn idx(&self, name: &str) -> Result<usize> {
        self.index
            .get(name)
            .copied()
            .with_context(|| format!("gradient buffer {name:?} missing from manifest"))
    }

    fn one(&mut self, name: &str) -> Result<&mut Vec<f32>> {
        let i = self.idx(name)?;
        Ok(&mut self.bufs[i])
    }

    /// Mutable views of a run of manifest-consecutive parameters —
    /// verifies each requested name actually sits at `base + k` so the
    /// layout assumption can never silently drift from `spec.rs`.
    fn consecutive(&mut self, names: &[String]) -> Result<&mut [Vec<f32>]> {
        let base = self.idx(&names[0])?;
        for (k, name) in names.iter().enumerate() {
            ensure!(
                base + k < self.names.len() && self.names[base + k] == *name,
                "parameter {name:?} is not at manifest position {} (layout drift?)",
                base + k
            );
        }
        Ok(&mut self.bufs[base..base + names.len()])
    }
}

// ---------------------------------------------------------------------------
// taped forward
// ---------------------------------------------------------------------------

struct BlockTape {
    attn: AttnTape,
    /// Input of norm1 (postnorm: x + a; prenorm: the block input).
    norm1_in: Vec<f32>,
    /// Input of the FFN (postnorm: norm1 output; prenorm: norm2 output).
    ffn_in: Vec<f32>,
    /// FFN hidden pre-activations (B·N, d_ff).
    hid_pre: Vec<f32>,
    /// Input of norm2 (postnorm: y1 + f; prenorm: x after attn residual).
    norm2_in: Vec<f32>,
}

struct EncodeTape {
    /// Embedding + positional sum (B·N, d_emb) — the proj input.
    x0: Vec<f32>,
    blocks: Vec<BlockTape>,
    out_norm_in: Option<Vec<f32>>,
    /// Mean-pooled features (B, d).
    pooled: Vec<f32>,
    fingerprint: u64,
}

fn embed_tokens(p: &Params, meta: &ModelMeta, tokens: &[i32], b: usize) -> Result<Vec<f32>> {
    let n = meta.seq_len;
    ensure!(tokens.len() == b * n, "tokens length {} != {}x{}", tokens.len(), b, n);
    let d_emb = meta.d_emb;
    let rows = b * n;
    let emb = p.f("embed.emb")?;
    let pe = ops::sinusoidal_positions(n, d_emb);
    let mut x = vec![0.0f32; rows * d_emb];
    let vocab_max = meta.vocab.saturating_sub(1);
    let rblk = parallel::row_block(rows);
    parallel::par_chunks_mut(x.as_mut_slice(), rblk * d_emb, |ci, chunk| {
        let r0 = ci * rblk;
        for (rr, dst) in chunk.chunks_mut(d_emb).enumerate() {
            let gr = r0 + rr;
            let nn = gr % n;
            let tok = (tokens[gr].max(0) as usize).min(vocab_max);
            let erow = &emb[tok * d_emb..(tok + 1) * d_emb];
            let prow = &pe[nn * d_emb..(nn + 1) * d_emb];
            dst.copy_from_slice(erow);
            simd::add8(dst, prow);
        }
    });
    Ok(x)
}

fn attn_forward_tape(
    p: &Params,
    meta: &ModelMeta,
    prefix: &str,
    x: &[f32],
    dims: &Dims,
    cast_fwd: &mut CastScratch,
) -> Result<(Vec<f32>, AttnTape)> {
    let v = AttnVariant::parse(&meta.variant)?;
    variants::attn_forward_tape(v, p, prefix, x, dims, cast_fwd)
}

/// FFN with pre-activation capture: identical arithmetic to the forward
/// `model::ffn` (dense → gelu → dense), but the hidden pre-activations
/// survive for the backward.
fn ffn_forward_tape(
    p: &Params,
    prefix: &str,
    x: &[f32],
    rows: usize,
    d: usize,
    d_ff: usize,
) -> Result<(Vec<f32>, Vec<f32>)> {
    let mut hid_pre = Vec::new();
    ops::dense_into(
        x,
        p.f(&format!("{prefix}.in.w"))?,
        p.f(&format!("{prefix}.in.b"))?,
        rows,
        d,
        d_ff,
        &mut hid_pre,
    );
    let mut act = hid_pre.clone();
    let blk = parallel::elem_block(act.len());
    parallel::par_chunks_mut(act.as_mut_slice(), blk, |_, chunk| {
        ops::gelu_rows(chunk);
    });
    let mut out = Vec::new();
    ops::dense_into(
        &act,
        p.f(&format!("{prefix}.out.w"))?,
        p.f(&format!("{prefix}.out.b"))?,
        rows,
        d_ff,
        d,
        &mut out,
    );
    Ok((out, hid_pre))
}

/// Taped encoder forward: tokens (b·N) → pooled features (b, d).
fn encode_tape(
    p: &Params,
    meta: &ModelMeta,
    tokens: &[i32],
    b: usize,
    ws: &mut GradScratch,
) -> Result<EncodeTape> {
    let n = meta.seq_len;
    let (d, d_ff) = (meta.d, meta.d_ff);
    let rows = b * n;
    let x0 = embed_tokens(p, meta, tokens, b)?;
    let mut x = ops::dense(&x0, p.f("proj.w")?, p.f("proj.b")?, rows, meta.d_emb, d);

    let dims = dims_for(meta, b)?;
    let mut blocks = Vec::with_capacity(meta.depth);
    let mut fingerprint = 0xcbf2_9ce4_8422_2325u64;
    for i in 0..meta.depth {
        let blk = format!("blocks.{i}");
        let tape = if meta.prenorm {
            let norm1_in = x.clone();
            let mut xn = x.clone();
            apply_norm(p, meta, &format!("{blk}.norm1"), &mut xn)?;
            let (a, attn) =
                attn_forward_tape(p, meta, &format!("{blk}.attn"), &xn, &dims, &mut ws.cast_fwd)?;
            ops::add_assign(&mut x, &a);
            let norm2_in = x.clone();
            let mut xn2 = x.clone();
            apply_norm(p, meta, &format!("{blk}.norm2"), &mut xn2)?;
            let (f, hid_pre) = ffn_forward_tape(p, &format!("{blk}.ffn"), &xn2, rows, d, d_ff)?;
            ops::add_assign(&mut x, &f);
            BlockTape { attn, norm1_in, ffn_in: xn2, hid_pre, norm2_in }
        } else {
            let (a, attn) =
                attn_forward_tape(p, meta, &format!("{blk}.attn"), &x, &dims, &mut ws.cast_fwd)?;
            ops::add_assign(&mut x, &a);
            let norm1_in = x.clone();
            apply_norm(p, meta, &format!("{blk}.norm1"), &mut x)?;
            let ffn_in = x.clone();
            let (f, hid_pre) = ffn_forward_tape(p, &format!("{blk}.ffn"), &ffn_in, rows, d, d_ff)?;
            ops::add_assign(&mut x, &f);
            let norm2_in = x.clone();
            apply_norm(p, meta, &format!("{blk}.norm2"), &mut x)?;
            BlockTape { attn, norm1_in, ffn_in, hid_pre, norm2_in }
        };
        fingerprint = fnv_fold(fingerprint, variants::attn_fingerprint(&tape.attn));
        blocks.push(tape);
    }
    let out_norm_in = if meta.prenorm {
        let keep = x.clone();
        apply_norm(p, meta, "out_norm", &mut x)?;
        Some(keep)
    } else {
        None
    };

    // mean-pool over the sequence, one task per batch element
    let mut pooled = vec![0.0f32; b * d];
    let inv = 1.0 / n as f32;
    let xs: &[f32] = &x;
    parallel::par_chunks_mut(pooled.as_mut_slice(), d, |bb, prow| {
        for nn in 0..n {
            let src = (bb * n + nn) * d;
            simd::axpy8(prow, inv, &xs[src..src + d]);
        }
    });
    Ok(EncodeTape { x0, blocks, out_norm_in, pooled, fingerprint })
}

// ---------------------------------------------------------------------------
// backward
// ---------------------------------------------------------------------------

fn norm_backward(
    p: &Params,
    meta: &ModelMeta,
    store: &mut GradStore,
    prefix: &str,
    x_in: &[f32],
    dy: &[f32],
    dx_acc: &mut [f32],
) -> Result<()> {
    let d = meta.d;
    if meta.norm == "scale" {
        let gval = p.f(&format!("{prefix}.g"))?[0];
        let mut dg = 0.0f32;
        gops::scalenorm_backward(x_in, gval, dy, d, NORM_EPS, dx_acc, &mut dg);
        store.one(&format!("{prefix}.g"))?[0] += dg;
    } else {
        let g = p.f(&format!("{prefix}.g"))?;
        let pair = store.consecutive(&[format!("{prefix}.b"), format!("{prefix}.g")])?;
        let [b_buf, g_buf] = pair else { unreachable!() };
        gops::layernorm_backward(
            x_in,
            g,
            dy,
            d,
            NORM_EPS,
            dx_acc,
            g_buf.as_mut_slice(),
            b_buf.as_mut_slice(),
        );
    }
    Ok(())
}

/// FFN backward: `dy` is the gradient of the FFN output; the input
/// gradient lands in `ws_dffn_in` (zeroed here).
fn ffn_backward(
    p: &Params,
    store: &mut GradStore,
    prefix: &str,
    block: &BlockTape,
    rows: usize,
    d: usize,
    d_ff: usize,
    dy: &[f32],
    dhid: &mut Vec<f32>,
    act: &mut Vec<f32>,
    dffn_in: &mut Vec<f32>,
) -> Result<()> {
    // recompute the hidden activations from the taped pre-activations
    act.clear();
    act.extend_from_slice(&block.hid_pre);
    let eblk = parallel::elem_block(act.len());
    parallel::par_chunks_mut(act.as_mut_slice(), eblk, |_, chunk| {
        ops::gelu_rows(chunk);
    });
    let out_w = p.f(&format!("{prefix}.out.w"))?;
    let in_w = p.f(&format!("{prefix}.in.w"))?;
    {
        let quad = store.consecutive(&[
            format!("{prefix}.in.b"),
            format!("{prefix}.in.w"),
            format!("{prefix}.out.b"),
            format!("{prefix}.out.w"),
        ])?;
        let [in_b_g, in_w_g, out_b_g, out_w_g] = quad else { unreachable!() };
        gops::dense_grad_params(
            act,
            dy,
            rows,
            d_ff,
            d,
            out_w_g.as_mut_slice(),
            out_b_g.as_mut_slice(),
        );
        zeroed(dhid, rows * d_ff);
        gops::dense_grad_input_acc(dy, out_w, rows, d_ff, d, dhid);
        let hid_pre: &[f32] = &block.hid_pre;
        let hblk = parallel::elem_block(dhid.len());
        parallel::par_chunks_mut(dhid.as_mut_slice(), hblk, |ci, chunk| {
            let off = ci * hblk;
            for (j, v) in chunk.iter_mut().enumerate() {
                *v *= ops::gelu_prime(hid_pre[off + j]);
            }
        });
        gops::dense_grad_params(
            &block.ffn_in,
            dhid,
            rows,
            d,
            d_ff,
            in_w_g.as_mut_slice(),
            in_b_g.as_mut_slice(),
        );
    }
    zeroed(dffn_in, rows * d);
    gops::dense_grad_input_acc(dhid, in_w, rows, d, d_ff, dffn_in);
    Ok(())
}

#[allow(clippy::too_many_arguments)]
fn attn_backward(
    p: &Params,
    meta: &ModelMeta,
    store: &mut GradStore,
    prefix: &str,
    tape: &AttnTape,
    dims: &Dims,
    d_out: &[f32],
    dx_acc: &mut [f32],
    cast_bwd: &mut glayer::CastBwdScratch,
    base_bwd: &mut glayer::BaselineBwdScratch,
) -> Result<()> {
    let v = AttnVariant::parse(&meta.variant)?;
    let names = variants::grad_param_names(v, prefix);
    let run = store.consecutive(&names)?;
    variants::attn_backward(v, p, prefix, tape, dims, d_out, dx_acc, run, cast_bwd, base_bwd)
}

/// Backward through one taped encoder: `d_pooled` (b, d) → parameter
/// gradients (into `store`) and the embedding-table gradient.
fn encode_backward(
    p: &Params,
    meta: &ModelMeta,
    store: &mut GradStore,
    tape: &EncodeTape,
    tokens: &[i32],
    b: usize,
    d_pooled: &[f32],
    ws: &mut GradScratch,
) -> Result<()> {
    let n = meta.seq_len;
    let (d, d_ff, d_emb) = (meta.d, meta.d_ff, meta.d_emb);
    let rows = b * n;
    let dims = dims_for(meta, b)?;
    ensure!(d_pooled.len() == b * d, "pooled gradient shape");

    let GradScratch { cast_bwd, base_bwd, dx, dnorm, dbranch, dffn_in, dhid, act, dx0, .. } = ws;

    // mean-pool backward: every token row gets its batch row / n
    let t = trace::span("bwd.pool");
    zeroed(dx, rows * d);
    let inv = 1.0 / n as f32;
    let blk = parallel::row_block(rows);
    parallel::par_chunks_mut(dx.as_mut_slice(), blk * d, |ci, chunk| {
        let r0 = ci * blk;
        for (rr, dst) in chunk.chunks_mut(d).enumerate() {
            let bb = (r0 + rr) / n;
            dst.copy_from_slice(&d_pooled[bb * d..(bb + 1) * d]);
            simd::scale8(dst, inv);
        }
    });
    drop(t);

    if let Some(x_in) = &tape.out_norm_in {
        let t = trace::span("bwd.norm");
        zeroed(dnorm, rows * d);
        norm_backward(p, meta, store, "out_norm", x_in, dx, dnorm)?;
        std::mem::swap(dx, dnorm);
        drop(t);
    }

    for (i, block) in tape.blocks.iter().enumerate().rev() {
        let blk_name = format!("blocks.{i}");
        let li = i as i32;
        if meta.prenorm {
            // out = x_mid + ffn(norm2(x_mid)); x_mid = x_in + attn(norm1(x_in))
            let t = trace::span_layer("bwd.ffn", li);
            ffn_backward(
                p,
                store,
                &format!("{blk_name}.ffn"),
                block,
                rows,
                d,
                d_ff,
                dx,
                dhid,
                act,
                dffn_in,
            )?;
            drop(t);
            let t = trace::span_layer("bwd.norm", li);
            norm_backward(
                p,
                meta,
                store,
                &format!("{blk_name}.norm2"),
                &block.norm2_in,
                dffn_in,
                dx,
            )?;
            drop(t);
            dbranch.clear();
            dbranch.extend_from_slice(dx);
            zeroed(dnorm, rows * d);
            let t = trace::span_layer("bwd.attn", li);
            attn_backward(
                p,
                meta,
                store,
                &format!("{blk_name}.attn"),
                &block.attn,
                &dims,
                dbranch,
                dnorm,
                cast_bwd,
                base_bwd,
            )?;
            drop(t);
            let t = trace::span_layer("bwd.norm", li);
            norm_backward(
                p,
                meta,
                store,
                &format!("{blk_name}.norm1"),
                &block.norm1_in,
                dnorm,
                dx,
            )?;
            drop(t);
        } else {
            // out = norm2(y1 + ffn(y1)); y1 = norm1(x + attn(x))
            zeroed(dnorm, rows * d);
            let t = trace::span_layer("bwd.norm", li);
            norm_backward(
                p,
                meta,
                store,
                &format!("{blk_name}.norm2"),
                &block.norm2_in,
                dx,
                dnorm,
            )?;
            drop(t);
            std::mem::swap(dx, dnorm);
            let t = trace::span_layer("bwd.ffn", li);
            ffn_backward(
                p,
                store,
                &format!("{blk_name}.ffn"),
                block,
                rows,
                d,
                d_ff,
                dx,
                dhid,
                act,
                dffn_in,
            )?;
            drop(t);
            ops::add_assign(dx, dffn_in);
            zeroed(dnorm, rows * d);
            let t = trace::span_layer("bwd.norm", li);
            norm_backward(
                p,
                meta,
                store,
                &format!("{blk_name}.norm1"),
                &block.norm1_in,
                dx,
                dnorm,
            )?;
            drop(t);
            std::mem::swap(dx, dnorm);
            dbranch.clear();
            dbranch.extend_from_slice(dx);
            let t = trace::span_layer("bwd.attn", li);
            attn_backward(
                p,
                meta,
                store,
                &format!("{blk_name}.attn"),
                &block.attn,
                &dims,
                dbranch,
                dx,
                cast_bwd,
                base_bwd,
            )?;
            drop(t);
        }
    }

    // input projection backward
    let t = trace::span("bwd.embed");
    {
        let pair = store.consecutive(&["proj.b".to_string(), "proj.w".to_string()])?;
        let [proj_b, proj_w] = pair else { unreachable!() };
        gops::dense_grad_params(
            &tape.x0,
            dx,
            rows,
            d_emb,
            d,
            proj_w.as_mut_slice(),
            proj_b.as_mut_slice(),
        );
    }
    zeroed(dx0, rows * d_emb);
    gops::dense_grad_input_acc(dx, p.f("proj.w")?, rows, d_emb, d, dx0);

    // embedding backward: serial scatter-add in fixed row order (several
    // rows share a token id, so this reduction cannot shard by row)
    let g_emb = store.one("embed.emb")?;
    let vocab_max = meta.vocab.saturating_sub(1);
    for r in 0..rows {
        let tok = (tokens[r].max(0) as usize).min(vocab_max);
        let dst = &mut g_emb[tok * d_emb..(tok + 1) * d_emb];
        simd::add8(dst, &dx0[r * d_emb..(r + 1) * d_emb]);
    }
    drop(t);
    Ok(())
}

// ---------------------------------------------------------------------------
// the public entry point
// ---------------------------------------------------------------------------

/// Full forward + exact backward through the native model for one batch:
/// returns the mean cross-entropy loss, the batch accuracy, and the
/// gradient of every parameter in manifest order.
pub fn loss_and_grads(
    manifest: &Manifest,
    params: &[&HostTensor],
    tokens: &HostTensor,
    labels: &[i32],
    ws: &mut GradScratch,
) -> Result<LossAndGrads> {
    let meta = &manifest.meta;
    let p = Params::bind(&manifest.params, params)?;
    let mut store = GradStore::new(manifest);
    let b = labels.len();
    let n = meta.seq_len;
    let d = meta.d;
    let toks = tokens.as_s32().context("tokens tensor")?;

    let (feats, d_in, tapes, t1, t2) = if meta.dual {
        ensure!(
            tokens.shape.len() == 3
                && tokens.shape[0] == b
                && tokens.shape[1] == 2
                && tokens.shape[2] == n,
            "dual tokens must be ({b},2,{n}), got {:?}",
            tokens.shape
        );
        let mut a = vec![0i32; b * n];
        let mut c2 = vec![0i32; b * n];
        for bb in 0..b {
            a[bb * n..(bb + 1) * n].copy_from_slice(&toks[(bb * 2) * n..(bb * 2 + 1) * n]);
            c2[bb * n..(bb + 1) * n].copy_from_slice(&toks[(bb * 2 + 1) * n..(bb * 2 + 2) * n]);
        }
        let tape1 = encode_tape(&p, meta, &a, b, ws)?;
        let tape2 = encode_tape(&p, meta, &c2, b, ws)?;
        let mut f = vec![0.0f32; b * 4 * d];
        for bb in 0..b {
            for j in 0..d {
                let (u, v) = (tape1.pooled[bb * d + j], tape2.pooled[bb * d + j]);
                f[bb * 4 * d + j] = u;
                f[bb * 4 * d + d + j] = v;
                f[bb * 4 * d + 2 * d + j] = u * v;
                f[bb * 4 * d + 3 * d + j] = u - v;
            }
        }
        (f, 4 * d, vec![tape1, tape2], a, c2)
    } else {
        ensure!(
            tokens.shape.len() == 2 && tokens.shape[0] == b && tokens.shape[1] == n,
            "tokens must be ({b},{n}), got {:?}",
            tokens.shape
        );
        let tape = encode_tape(&p, meta, toks, b, ws)?;
        let feats = tape.pooled.clone();
        (feats, d, vec![tape], toks.to_vec(), Vec::new())
    };

    let head = head_forward(&p, meta, &feats, b, d_in)?;
    let nc = meta.n_classes;
    let (loss, acc, dlogits) = softmax_xent(&head.logits, labels, nc)?;

    // head backward
    let th = trace::span("bwd.head");
    let mut dh = vec![0.0f32; b * d];
    {
        let pair = store.consecutive(&["head.out.b".to_string(), "head.out.w".to_string()])?;
        let [out_b, out_w] = pair else { unreachable!() };
        gops::dense_grad_params(
            &head.h,
            &dlogits,
            b,
            d,
            nc,
            out_w.as_mut_slice(),
            out_b.as_mut_slice(),
        );
    }
    gops::dense_grad_input_acc(&dlogits, p.f("head.out.w")?, b, d, nc, &mut dh);
    for (v, &pre) in dh.iter_mut().zip(&head.h_pre) {
        *v *= ops::gelu_prime(pre);
    }
    let mut dfeats = vec![0.0f32; b * d_in];
    {
        let pair = store.consecutive(&["head.fc.b".to_string(), "head.fc.w".to_string()])?;
        let [fc_b, fc_w] = pair else { unreachable!() };
        gops::dense_grad_params(&feats, &dh, b, d_in, d, fc_w.as_mut_slice(), fc_b.as_mut_slice());
    }
    gops::dense_grad_input_acc(&dh, p.f("head.fc.w")?, b, d_in, d, &mut dfeats);
    drop(th);

    let mut fingerprint = 0xcbf2_9ce4_8422_2325u64;
    for t in &tapes {
        fingerprint = fnv_fold(fingerprint, t.fingerprint);
    }

    if meta.dual {
        // feats = [u, v, u*v, u-v] per batch row
        let mut df1 = vec![0.0f32; b * d];
        let mut df2 = vec![0.0f32; b * d];
        for bb in 0..b {
            for j in 0..d {
                let u = tapes[0].pooled[bb * d + j];
                let v = tapes[1].pooled[bb * d + j];
                let g0 = dfeats[bb * 4 * d + j];
                let g1 = dfeats[bb * 4 * d + d + j];
                let g2 = dfeats[bb * 4 * d + 2 * d + j];
                let g3 = dfeats[bb * 4 * d + 3 * d + j];
                df1[bb * d + j] = g0 + g2 * v + g3;
                df2[bb * d + j] = g1 + g2 * u - g3;
            }
        }
        encode_backward(&p, meta, &mut store, &tapes[0], &t1, b, &df1, ws)?;
        encode_backward(&p, meta, &mut store, &tapes[1], &t2, b, &df2, ws)?;
    } else {
        encode_backward(&p, meta, &mut store, &tapes[0], &t1, b, &dfeats, ws)?;
    }

    Ok(LossAndGrads { loss, acc, grads: store.bufs, fingerprint })
}

#[cfg(test)]
mod tests {
    use super::super::super::model::run_init;
    use super::*;
    use crate::util::prop::{assert_grads_close, GradCheckCfg};

    fn small_meta(variant: &str) -> ModelMeta {
        ModelMeta {
            task: "text".to_string(),
            variant: variant.to_string(),
            seq_len: 8,
            batch: 2,
            n_c: 2,
            kappa: 4,
            depth: 2,
            heads: 2,
            d: 8,
            d_ff: 16,
            d_emb: 8,
            vocab: 16,
            n_classes: 2,
            dual: false,
            norm: "layer".to_string(),
            prenorm: false,
            attn_fn: "softmax".to_string(),
            window: 4,
            causal: false,
        }
    }

    fn flat_theta(params: &[HostTensor]) -> Vec<f32> {
        let mut out = Vec::new();
        for t in params {
            out.extend_from_slice(t.as_f32().unwrap());
        }
        out
    }

    fn tensors_from_flat(man: &Manifest, theta: &[f32]) -> Vec<HostTensor> {
        let mut out = Vec::with_capacity(man.params.len());
        let mut off = 0usize;
        for spec in &man.params {
            let l: usize = spec.shape.iter().product();
            out.push(HostTensor::f32(spec.shape.clone(), theta[off..off + l].to_vec()));
            off += l;
        }
        out
    }

    fn name_blocks(man: &Manifest) -> Vec<(String, usize)> {
        man.params
            .iter()
            .map(|s| (s.name.clone(), s.shape.iter().product()))
            .collect()
    }

    fn tokens_for(man: &Manifest, stride: usize) -> HostTensor {
        let n: usize = man.tokens_shape.iter().product();
        let vocab = man.meta.vocab as i32;
        HostTensor::s32(
            man.tokens_shape.clone(),
            (0..n).map(|i| ((i * stride + 3) % vocab as usize) as i32).collect(),
        )
    }

    /// Model-level checks: ε balances f32 loss-evaluation noise against
    /// truncation error at loss magnitudes ~ln(2); the fingerprint skips
    /// coordinates that flip a cluster assignment.
    fn model_cfg() -> GradCheckCfg {
        GradCheckCfg { eps: 5e-3, rel_tol: 1e-2, abs_tol: 1e-4, max_per_block: 4 }
    }

    fn check_model(meta: ModelMeta, seed: u32) {
        let man = Manifest::synthetic(meta);
        let params = run_init(&man, &[&HostTensor::u32(vec![], vec![seed])]).unwrap();
        let theta = flat_theta(&params);
        let tokens = tokens_for(&man, 7);
        let labels: Vec<i32> = (0..man.meta.batch).map(|i| (i % 2) as i32).collect();
        let refs: Vec<&HostTensor> = params.iter().collect();
        let mut ws = GradScratch::new();
        let out = loss_and_grads(&man, &refs, &tokens, &labels, &mut ws).unwrap();
        assert!(out.loss.is_finite() && out.loss > 0.0);
        let analytic: Vec<f32> = out.grads.concat();
        let blocks = name_blocks(&man);
        let reports =
            assert_grads_close(&model_cfg(), &theta, &blocks, &analytic, |t| {
                let tensors = tensors_from_flat(&man, t);
                let r: Vec<&HostTensor> = tensors.iter().collect();
                let mut ws = GradScratch::new();
                let o = loss_and_grads(&man, &r, &tokens, &labels, &mut ws).unwrap();
                (o.loss, o.fingerprint)
            });
        // every block must have had at least one comparable coordinate
        let unchecked: Vec<&str> = reports
            .iter()
            .filter(|r| r.checked == 0)
            .map(|r| r.name.as_str())
            .collect();
        assert!(
            unchecked.is_empty(),
            "blocks with no comparable coordinate (all flipped clusters?): {unchecked:?}"
        );
    }

    #[test]
    fn full_model_gradients_cast_topk_postnorm_softmax() {
        check_model(small_meta("cast_topk"), 11);
    }

    #[test]
    fn full_model_gradients_cast_sa_prenorm_scale_laplace() {
        let mut meta = small_meta("cast_sa");
        meta.prenorm = true;
        meta.norm = "scale".to_string();
        meta.attn_fn = "laplace".to_string();
        meta.depth = 1;
        check_model(meta, 12);
    }

    #[test]
    fn full_model_gradients_causal_cast() {
        let mut meta = small_meta("cast_sa");
        meta.causal = true;
        meta.depth = 1;
        check_model(meta, 15);
    }

    #[test]
    fn full_model_gradients_dual_vanilla() {
        let mut meta = small_meta("vanilla");
        meta.task = "retrieval".to_string();
        meta.dual = true;
        meta.depth = 1;
        check_model(meta, 13);
    }

    #[test]
    fn full_model_gradients_lsh() {
        let mut meta = small_meta("lsh");
        meta.depth = 1;
        check_model(meta, 14);
    }

    #[test]
    fn full_model_gradients_clustered() {
        let mut meta = small_meta("clustered");
        meta.depth = 1;
        check_model(meta, 16);
    }

    #[test]
    fn full_model_gradients_tost() {
        let mut meta = small_meta("tost");
        meta.depth = 1;
        check_model(meta, 17);
    }

    #[test]
    fn taped_forward_is_bit_identical_to_predict_forward() {
        // the taped forward must never drift from the forward that
        // `predict`/eval run: same loss (and accuracy) bit-for-bit,
        // for every variant, prenorm/scale, and the dual head
        use super::super::super::model::run_predict;
        let mut metas: Vec<ModelMeta> = variants::NAMES.iter().map(|v| small_meta(v)).collect();
        let mut prenorm = small_meta("cast_topk");
        prenorm.prenorm = true;
        prenorm.norm = "scale".to_string();
        metas.push(prenorm);
        let mut dual = small_meta("vanilla");
        dual.task = "retrieval".to_string();
        dual.dual = true;
        metas.push(dual);
        for meta in metas {
            let tag = format!("{} prenorm={} dual={}", meta.variant, meta.prenorm, meta.dual);
            let man = Manifest::synthetic(meta);
            let params = run_init(&man, &[&HostTensor::u32(vec![], vec![7])]).unwrap();
            let tokens = tokens_for(&man, 11);
            let labels = vec![0, 1];
            let mut inputs: Vec<&HostTensor> = params.iter().collect();
            inputs.push(&tokens);
            let logits = run_predict(&man, &inputs).unwrap();
            let (ploss, pacc, _) =
                softmax_xent(logits[0].as_f32().unwrap(), &labels, man.meta.n_classes)
                    .unwrap();
            let refs: Vec<&HostTensor> = params.iter().collect();
            let mut ws = GradScratch::new();
            let out = loss_and_grads(&man, &refs, &tokens, &labels, &mut ws).unwrap();
            assert_eq!(out.loss, ploss, "{tag}: taped forward drifted from predict");
            assert_eq!(out.acc, pacc, "{tag}: accuracy drifted from predict");
        }
    }

    #[test]
    fn gradient_descent_on_one_batch_reduces_loss() {
        // plain SGD along the returned gradients must overfit one batch —
        // the whole-pipeline sanity the pointwise checks cannot give
        let man = Manifest::synthetic(small_meta("cast_topk"));
        let params = run_init(&man, &[&HostTensor::u32(vec![], vec![21])]).unwrap();
        let mut theta = flat_theta(&params);
        let tokens = tokens_for(&man, 5);
        let labels = vec![0, 1];
        let mut ws = GradScratch::new();
        let mut first = f32::NAN;
        let mut last = f32::NAN;
        for it in 0..60 {
            let tensors = tensors_from_flat(&man, &theta);
            let refs: Vec<&HostTensor> = tensors.iter().collect();
            let out = loss_and_grads(&man, &refs, &tokens, &labels, &mut ws).unwrap();
            if it == 0 {
                first = out.loss;
            }
            last = out.loss;
            let flat_grad: Vec<f32> = out.grads.concat();
            for (p, g) in theta.iter_mut().zip(&flat_grad) {
                *p -= 0.2 * g;
            }
        }
        assert!(first.is_finite() && last.is_finite());
        assert!(
            last < first * 0.8,
            "SGD on one batch must cut the loss: {first:.4} -> {last:.4}"
        );
    }

    #[test]
    fn grads_align_with_manifest_and_are_finite_for_every_variant() {
        for variant in variants::NAMES {
            let man = Manifest::synthetic(small_meta(variant));
            let params = run_init(&man, &[&HostTensor::u32(vec![], vec![3])]).unwrap();
            let refs: Vec<&HostTensor> = params.iter().collect();
            let tokens = tokens_for(&man, 3);
            let mut ws = GradScratch::new();
            let out = loss_and_grads(&man, &refs, &tokens, &[1, 0], &mut ws).unwrap();
            assert_eq!(out.grads.len(), man.n_params(), "{variant}");
            for (g, spec) in out.grads.iter().zip(&man.params) {
                assert_eq!(
                    g.len(),
                    spec.shape.iter().product::<usize>(),
                    "{variant}:{}",
                    spec.name
                );
                assert!(
                    g.iter().all(|v| v.is_finite()),
                    "{variant}:{} has non-finite gradients",
                    spec.name
                );
            }
            // the backbone actually receives gradient signal
            let idx = man.params.iter().position(|p| p.name == "embed.emb").unwrap();
            assert!(
                out.grads[idx].iter().any(|&v| v != 0.0),
                "{variant}: embedding gradient is all-zero"
            );
        }
    }
}
