//! `tost` — Token-Statistics-style linear attention (arXiv 2412.17810).
//!
//! Instead of the N×N score matrix, each (batch, head) folds its keys
//! and values into second-moment statistics once:
//!
//!     S = Σ_j k'_j v_jᵀ   (d_h × d_h)      z = Σ_j k'_j   (d_h)
//!     o_i = Sᵀ q'_i / (q'_i · z + ε)
//!
//! with the positive feature map `q' = softplus(q) + 1` (and likewise
//! `k'`), so every denominator is ≥ N·d_h and the whole layer is smooth
//! — no discrete choices, hence an [`super::variants::AttnTape::Input`]
//! tape (fingerprint 0) and recompute-everything backward.  Cost is
//! O(N·d_h²) per head: the linear-attention end of the bake-off frontier.
//!
//! Determinism: the parallel grain is one batch element (disjoint output
//! rows); heads, tokens and statistics accumulate sequentially in
//! ascending index order, so results are bit-identical across thread
//! counts.

use anyhow::{ensure, Result};

use super::grad::layer::BaselineGradRefs;
use super::grad::ops as gops;
use super::layer::{BaselineParams, Dims};
use super::ops;
use crate::util::{parallel, simd};

/// Denominator guard; dominated by the ≥ N·d_h mass of the positive
/// feature map, it only matters for degenerate zero-length inputs.
const EPS: f32 = 1e-6;

/// Per-worker buffers for one (batch, head) pass.
struct FwdScratch {
    qp: Vec<f32>,
    kp: Vec<f32>,
    s: Vec<f32>,
    z: Vec<f32>,
    num: Vec<f32>,
}

fn fwd_scratch(d_h: usize) -> FwdScratch {
    FwdScratch {
        qp: vec![0.0; d_h],
        kp: vec![0.0; d_h],
        s: vec![0.0; d_h * d_h],
        z: vec![0.0; d_h],
        num: vec![0.0; d_h],
    }
}

fn softplus1_into(dst: &mut [f32], src: &[f32]) {
    for (d, &s) in dst.iter_mut().zip(src) {
        *d = ops::softplus1(s);
    }
}

/// The attention core: projected `q`/`k`/`v` (rows, d) → `r` (rows, d).
/// Shared by the forward layer and the backward's recomputation so the
/// two are bit-identical.
fn attend_tost(r: &mut [f32], q: &[f32], k: &[f32], v: &[f32], dims: &Dims) {
    let (n, h, d_h) = (dims.n, dims.heads, dims.d_h);
    let d = dims.d();
    parallel::par_chunks_mut_with(
        r,
        n * d,
        || fwd_scratch(d_h),
        |scr, bb, chunk| {
            for hh in 0..h {
                // key/value statistics, ascending j
                scr.s.iter_mut().for_each(|x| *x = 0.0);
                scr.z.iter_mut().for_each(|x| *x = 0.0);
                for j in 0..n {
                    let row = (bb * n + j) * d + hh * d_h;
                    softplus1_into(&mut scr.kp, &k[row..row + d_h]);
                    let vrow = &v[row..row + d_h];
                    simd::add8(&mut scr.z, &scr.kp);
                    for (l, srow) in scr.s.chunks_mut(d_h).enumerate() {
                        simd::axpy8(srow, scr.kp[l], vrow);
                    }
                }
                for i in 0..n {
                    let row = (bb * n + i) * d + hh * d_h;
                    softplus1_into(&mut scr.qp, &q[row..row + d_h]);
                    scr.num.iter_mut().for_each(|x| *x = 0.0);
                    for (l, srow) in scr.s.chunks(d_h).enumerate() {
                        simd::axpy8(&mut scr.num, scr.qp[l], srow);
                    }
                    let den = ops::dot(&scr.qp, &scr.z) + EPS;
                    let out = &mut chunk[i * d + hh * d_h..][..d_h];
                    out.copy_from_slice(&scr.num);
                    simd::scale8(out, 1.0 / den);
                }
            }
        },
    );
}

/// Forward of the `tost` layer: project, fold token statistics, attend,
/// output-project.
pub fn tost_layer(p: &BaselineParams, x: &[f32], dims: &Dims) -> Result<Vec<f32>> {
    let rows = dims.b * dims.n;
    let d = dims.d();
    ensure!(x.len() == rows * d, "tost layer input shape");
    let q = ops::dense(x, p.wq_w, p.wq_b, rows, d, d);
    let k = ops::dense(x, p.wk_w, p.wk_b, rows, d, d);
    let v = ops::dense(x, p.wv_w, p.wv_b, rows, d, d);
    let mut r = vec![0.0f32; rows * d];
    attend_tost(&mut r, &q, &k, &v, dims);
    Ok(ops::dense(&r, p.wo_w, p.wo_b, rows, d, d))
}

/// Per-worker buffers for one (batch, head) backward pass.
struct BwdScratch {
    fwd: FwdScratch,
    dnum: Vec<f32>,
    dqp: Vec<f32>,
    dkp: Vec<f32>,
    ds: Vec<f32>,
    dz: Vec<f32>,
}

fn bwd_scratch(d_h: usize) -> BwdScratch {
    BwdScratch {
        fwd: fwd_scratch(d_h),
        dnum: vec![0.0; d_h],
        dqp: vec![0.0; d_h],
        dkp: vec![0.0; d_h],
        ds: vec![0.0; d_h * d_h],
        dz: vec![0.0; d_h],
    }
}

/// Exact reverse pass; the layer is smooth, so everything is recomputed
/// from the stored input `x`.  The parallel grain is one batch element's
/// fused `dq|dk|dv` row slab — all of a batch element's token indices
/// stay inside it, so chunks are disjoint and the accumulation order is
/// fixed regardless of thread count.
pub fn tost_backward(
    p: &BaselineParams,
    x: &[f32],
    dims: &Dims,
    d_out: &[f32],
    dx: &mut [f32],
    g: &mut BaselineGradRefs,
) -> Result<()> {
    let (b, n, h, d_h) = (dims.b, dims.n, dims.heads, dims.d_h);
    let d = dims.d();
    let rows = b * n;
    ensure!(d_out.len() == rows * d && dx.len() == rows * d, "tost backward shape");

    let q = ops::dense(x, p.wq_w, p.wq_b, rows, d, d);
    let k = ops::dense(x, p.wk_w, p.wk_b, rows, d, d);
    let v = ops::dense(x, p.wv_w, p.wv_b, rows, d, d);
    let mut r = vec![0.0f32; rows * d];
    attend_tost(&mut r, &q, &k, &v, dims);

    let mut dr = vec![0.0f32; rows * d];
    gops::dense_grad_input_acc(d_out, p.wo_w, rows, d, d, &mut dr);
    gops::dense_grad_params(&r, d_out, rows, d, d, g.wo_w, g.wo_b);
    let dr_s: &[f32] = &dr;
    let (q_s, k_s, v_s): (&[f32], &[f32], &[f32]) = (&q, &k, &v);

    let mut dqkv = vec![0.0f32; rows * 3 * d];
    parallel::par_chunks_mut_with(
        dqkv.as_mut_slice(),
        n * 3 * d,
        || bwd_scratch(d_h),
        |scr, bb, slab| {
            for hh in 0..h {
                // recompute the statistics of this (batch, head)
                scr.fwd.s.iter_mut().for_each(|x| *x = 0.0);
                scr.fwd.z.iter_mut().for_each(|x| *x = 0.0);
                for j in 0..n {
                    let row = (bb * n + j) * d + hh * d_h;
                    softplus1_into(&mut scr.fwd.kp, &k_s[row..row + d_h]);
                    let vrow = &v_s[row..row + d_h];
                    simd::add8(&mut scr.fwd.z, &scr.fwd.kp);
                    for (l, srow) in scr.fwd.s.chunks_mut(d_h).enumerate() {
                        simd::axpy8(srow, scr.fwd.kp[l], vrow);
                    }
                }
                scr.ds.iter_mut().for_each(|x| *x = 0.0);
                scr.dz.iter_mut().for_each(|x| *x = 0.0);
                // token loop: o_i = Sᵀq'_i / (q'_i·z + ε)
                for i in 0..n {
                    let row = (bb * n + i) * d + hh * d_h;
                    let qrow = &q_s[row..row + d_h];
                    softplus1_into(&mut scr.fwd.qp, qrow);
                    scr.fwd.num.iter_mut().for_each(|x| *x = 0.0);
                    for (l, srow) in scr.fwd.s.chunks(d_h).enumerate() {
                        simd::axpy8(&mut scr.fwd.num, scr.fwd.qp[l], srow);
                    }
                    let den = ops::dot(&scr.fwd.qp, &scr.fwd.z) + EPS;
                    let dro = &dr_s[row..row + d_h];
                    for (dn, &go) in scr.dnum.iter_mut().zip(dro) {
                        *dn = go / den;
                    }
                    let dden = -ops::dot(dro, &scr.fwd.num) / (den * den);
                    for (l, srow) in scr.fwd.s.chunks(d_h).enumerate() {
                        scr.dqp[l] = ops::dot(srow, &scr.dnum) + dden * scr.fwd.z[l];
                    }
                    for (l, dsrow) in scr.ds.chunks_mut(d_h).enumerate() {
                        simd::axpy8(dsrow, scr.fwd.qp[l], &scr.dnum);
                    }
                    simd::axpy8(&mut scr.dz, dden, &scr.fwd.qp);
                    // chain through q' = softplus1(q): dq = dq' ⊙ σ(q)
                    let dq_row = &mut slab[i * 3 * d + hh * d_h..][..d_h];
                    for ((dst, &dqp), &qv) in dq_row.iter_mut().zip(&scr.dqp).zip(qrow) {
                        *dst += dqp * ops::sigmoid(qv);
                    }
                }
                // key/value loop: scatter dS and dz back
                for j in 0..n {
                    let row = (bb * n + j) * d + hh * d_h;
                    let krow = &k_s[row..row + d_h];
                    softplus1_into(&mut scr.fwd.kp, krow);
                    let vrow = &v_s[row..row + d_h];
                    for (l, dsrow) in scr.ds.chunks(d_h).enumerate() {
                        scr.dkp[l] = ops::dot(dsrow, vrow) + scr.dz[l];
                    }
                    let dv_row = &mut slab[j * 3 * d + 2 * d + hh * d_h..][..d_h];
                    for (l, dsrow) in scr.ds.chunks(d_h).enumerate() {
                        simd::axpy8(dv_row, scr.fwd.kp[l], dsrow);
                    }
                    let dk_row = &mut slab[j * 3 * d + d + hh * d_h..][..d_h];
                    for ((dst, &dkp), &kv) in dk_row.iter_mut().zip(&scr.dkp).zip(krow) {
                        *dst += dkp * ops::sigmoid(kv);
                    }
                }
            }
        },
    );

    super::clustered::qkv_slab_project_backward(p, x, &dqkv, rows, d, g, dx);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::native::ops::AttnFn;
    use crate::util::prop::{assert_grads_close, GradCheckCfg};
    use crate::util::rng::Rng;

    fn dims(attn: AttnFn) -> Dims {
        Dims {
            b: 2,
            n: 8,
            heads: 2,
            d_h: 4,
            n_c: 2,
            kappa: 4,
            attn,
            clustering: "topk".to_string(),
            causal: false,
            window: 4,
        }
    }

    fn layer_cfg() -> GradCheckCfg {
        GradCheckCfg { eps: 1e-2, rel_tol: 1e-2, abs_tol: 1e-3, max_per_block: 8 }
    }

    fn randn(rng: &mut Rng, n: usize, scale: f32) -> Vec<f32> {
        (0..n).map(|_| rng.gaussian() as f32 * scale).collect()
    }

    fn lens(d: usize) -> Vec<(String, usize)> {
        vec![
            ("wq.w".into(), d * d),
            ("wq.b".into(), d),
            ("wk.w".into(), d * d),
            ("wk.b".into(), d),
            ("wv.w".into(), d * d),
            ("wv.b".into(), d),
            ("wo.w".into(), d * d),
            ("wo.b".into(), d),
        ]
    }

    fn random_theta(rng: &mut Rng, lens: &[(String, usize)], d: usize) -> Vec<f32> {
        let scale = 1.0 / (d as f32).sqrt();
        let mut theta = Vec::new();
        for (name, len) in lens {
            let s = if name.ends_with(".b") { 0.1 } else { scale };
            theta.extend(randn(rng, *len, s));
        }
        theta
    }

    fn split<'a>(t: &'a [f32], lens: &[usize]) -> Vec<&'a [f32]> {
        let mut out = Vec::with_capacity(lens.len());
        let mut off = 0usize;
        for &l in lens {
            out.push(&t[off..off + l]);
            off += l;
        }
        out
    }

    fn params_of<'a>(parts: &[&'a [f32]]) -> BaselineParams<'a> {
        BaselineParams {
            wq_w: parts[0],
            wq_b: parts[1],
            wk_w: parts[2],
            wk_b: parts[3],
            wv_w: parts[4],
            wv_b: parts[5],
            wo_w: parts[6],
            wo_b: parts[7],
        }
    }

    #[test]
    fn forward_is_finite_and_shaped() {
        let dm = dims(AttnFn::Softmax);
        let d = dm.d();
        let mut rng = Rng::new(41);
        let ls = lens(d);
        let lens_only: Vec<usize> = ls.iter().map(|(_, l)| *l).collect();
        let theta = random_theta(&mut rng, &ls, d);
        let x = randn(&mut rng, dm.b * dm.n * d, 1.0);
        let parts = split(&theta, &lens_only);
        let out = tost_layer(&params_of(&parts), &x, &dm).unwrap();
        assert_eq!(out.len(), dm.b * dm.n * d);
        assert!(out.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn constant_values_pass_through() {
        // with every v_j equal to a constant row c, the statistics
        // collapse: Sᵀq' = (q'·z)·c, so o_i ≈ c for every token — the
        // linear-attention identity that pins the normalization.
        let dm = dims(AttnFn::Softmax);
        let d = dm.d();
        let mut rng = Rng::new(43);
        let zeros = vec![0.0f32; d * d];
        let mut eye = vec![0.0f32; d * d];
        for i in 0..d {
            eye[i * d + i] = 1.0;
        }
        let zb = vec![0.0f32; d];
        let cbias = randn(&mut rng, d, 1.0);
        let wq = randn(&mut rng, d * d, 0.5);
        let wk = randn(&mut rng, d * d, 0.5);
        let p = BaselineParams {
            wq_w: &wq,
            wq_b: &zb,
            wk_w: &wk,
            wk_b: &zb,
            wv_w: &zeros,
            wv_b: &cbias, // every value row is exactly `cbias`
            wo_w: &eye,
            wo_b: &zb,
        };
        let x = randn(&mut rng, dm.b * dm.n * d, 1.0);
        let out = tost_layer(&p, &x, &dm).unwrap();
        for row in out.chunks(d) {
            for (o, c) in row.iter().zip(&cbias) {
                assert!((o - c).abs() < 1e-4, "expected {c}, got {o}");
            }
        }
    }

    #[test]
    fn parameter_gradients_match_central_difference() {
        let dm = dims(AttnFn::Softmax);
        let d = dm.d();
        let rows = dm.b * dm.n;
        let mut rng = Rng::new(311);
        let ls = lens(d);
        let lens_only: Vec<usize> = ls.iter().map(|(_, l)| *l).collect();
        let theta = random_theta(&mut rng, &ls, d);
        let x = randn(&mut rng, rows * d, 1.0);
        let c = randn(&mut rng, rows * d, 0.5);
        let analytic = {
            let parts = split(&theta, &lens_only);
            let p = params_of(&parts);
            let mut gbufs: Vec<Vec<f32>> = lens_only.iter().map(|&l| vec![0.0; l]).collect();
            let mut dx = vec![0.0f32; x.len()];
            let [wq_w, wq_b, wk_w, wk_b, wv_w, wv_b, wo_w, wo_b] = &mut gbufs[..] else {
                unreachable!()
            };
            let mut g = BaselineGradRefs {
                wq_w: wq_w.as_mut_slice(),
                wq_b: wq_b.as_mut_slice(),
                wk_w: wk_w.as_mut_slice(),
                wk_b: wk_b.as_mut_slice(),
                wv_w: wv_w.as_mut_slice(),
                wv_b: wv_b.as_mut_slice(),
                wo_w: wo_w.as_mut_slice(),
                wo_b: wo_b.as_mut_slice(),
            };
            tost_backward(&p, &x, &dm, &c, &mut dx, &mut g).unwrap();
            gbufs.concat()
        };
        assert_grads_close(&layer_cfg(), &theta, &ls, &analytic, |t| {
            let parts = split(t, &lens_only);
            (ops::dot(&c, &tost_layer(&params_of(&parts), &x, &dm).unwrap()), 0)
        });
    }

    #[test]
    fn input_gradient_matches_central_difference() {
        let dm = dims(AttnFn::Softmax);
        let d = dm.d();
        let rows = dm.b * dm.n;
        let mut rng = Rng::new(313);
        let ls = lens(d);
        let lens_only: Vec<usize> = ls.iter().map(|(_, l)| *l).collect();
        let theta = random_theta(&mut rng, &ls, d);
        let x = randn(&mut rng, rows * d, 1.0);
        let c = randn(&mut rng, rows * d, 0.5);
        let dx = {
            let parts = split(&theta, &lens_only);
            let p = params_of(&parts);
            let mut gbufs: Vec<Vec<f32>> = lens_only.iter().map(|&l| vec![0.0; l]).collect();
            let mut dx = vec![0.0f32; x.len()];
            let [wq_w, wq_b, wk_w, wk_b, wv_w, wv_b, wo_w, wo_b] = &mut gbufs[..] else {
                unreachable!()
            };
            let mut g = BaselineGradRefs {
                wq_w: wq_w.as_mut_slice(),
                wq_b: wq_b.as_mut_slice(),
                wk_w: wk_w.as_mut_slice(),
                wk_b: wk_b.as_mut_slice(),
                wv_w: wv_w.as_mut_slice(),
                wv_b: wv_b.as_mut_slice(),
                wo_w: wo_w.as_mut_slice(),
                wo_b: wo_b.as_mut_slice(),
            };
            tost_backward(&p, &x, &dm, &c, &mut dx, &mut g).unwrap();
            dx
        };
        let blocks = vec![("x".to_string(), rows * d)];
        assert_grads_close(&layer_cfg(), &x, &blocks, &dx, |xt| {
            let parts = split(&theta, &lens_only);
            (ops::dot(&c, &tost_layer(&params_of(&parts), xt, &dm).unwrap()), 0)
        });
    }
}
