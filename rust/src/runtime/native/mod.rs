//! The native CPU backend: a pure-Rust f32 implementation of the CAST
//! forward pass (surrogate-token affinities, Top-κ clustering,
//! intra-cluster attention, cluster summaries, inter-cluster mixing —
//! paper §3.1–3.3) plus the `init`/`predict`/`predict_ag`/`train_step`
//! program entry points, shaped exactly like the AOT artifact manifests,
//! and the stateful `decode` entry (incremental generation through the
//! [`decode`] cluster-state cache).
//!
//! This is the default [`Backend`](super::Backend): it needs no artifacts
//! on disk, no Python, and no external crates — `Manifest::synthetic`
//! plus this module is a complete zero-dependency runtime.  The PJRT
//! backend (`runtime::pjrt`, `xla` feature) plugs into the same trait.
//!
//! Every hot path runs on the `util::parallel` worker pool (sized by
//! `CAST_NUM_THREADS` / `available_parallelism`); outputs are
//! bit-identical for any thread count — see DESIGN.md §Threading.
//!
//! `train_step` backpropagates through the full model by default via the
//! [`grad`] autograd subsystem (tape capture + threaded reverse passes,
//! DESIGN.md §Autograd); `CAST_TRAIN_SCOPE=head` selects the PR-1
//! head-only regression path.

pub mod cluster_stats;
pub mod clustered;
pub mod decode;
pub mod grad;
pub mod layer;
pub mod model;
pub mod ops;
pub mod spec;
pub mod tost;
pub mod variants;

use std::sync::Arc;

use anyhow::{bail, ensure, Result};

use super::artifacts::Manifest;
use super::backend::{Backend, DecodeSession, Executable, Scratch};
use super::tensor::HostTensor;

/// The model variants the engine implements — re-exported from the
/// [`variants`] registry, the single source of truth for variant names.
pub use variants::NAMES as VARIANTS;
const ENTRIES: [&str; 5] = ["init", "predict", "predict_ag", "train_step", "decode"];

/// The pure-Rust CPU engine.
pub struct NativeBackend;

impl Backend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn supports(&self, manifest: &Manifest, entry: &str) -> bool {
        match entry {
            "init" | "predict" | "train_step" => true,
            "predict_ag" => manifest.meta.has_ag(),
            "decode" => decode::supported(&manifest.meta),
            _ => false,
        }
    }

    fn load(&self, manifest: &Manifest, entry: &str) -> Result<Arc<dyn Executable>> {
        ensure!(
            ENTRIES.contains(&entry),
            "unknown program entry {entry:?} (know {ENTRIES:?})"
        );
        ensure!(
            self.supports(manifest, entry),
            "native backend has no {entry:?} for {} (variant {})",
            manifest.key,
            manifest.meta.variant
        );
        let meta = &manifest.meta;
        variants::AttnVariant::parse(&meta.variant)?;
        ensure!(
            meta.heads > 0 && meta.d % meta.heads == 0,
            "d={} not divisible by h={}",
            meta.d,
            meta.heads
        );
        ops::AttnFn::parse(&meta.attn_fn)?;
        ensure!(
            matches!(meta.norm.as_str(), "layer" | "scale" | "batch"),
            "unknown norm {:?}",
            meta.norm
        );
        Ok(Arc::new(NativeExecutable {
            manifest: manifest.clone(),
            entry: entry.to_string(),
        }))
    }
}

/// One loaded native program (manifest snapshot + entry point).
pub struct NativeExecutable {
    manifest: Manifest,
    entry: String,
}

impl Executable for NativeExecutable {
    fn entry(&self) -> &str {
        &self.entry
    }

    fn run_refs(&self, inputs: &[&HostTensor]) -> Result<Vec<HostTensor>> {
        match self.entry.as_str() {
            "init" => model::run_init(&self.manifest, inputs),
            "predict" => model::run_predict(&self.manifest, inputs),
            "predict_ag" => model::run_predict_ag(&self.manifest, inputs),
            "train_step" => model::run_train_step(&self.manifest, inputs),
            "decode" => bail!(
                "the \"decode\" entry is stateful — drive it through \
                 decode_begin/decode_prefill/decode_step, not run_refs"
            ),
            other => bail!("unknown entry {other:?}"),
        }
    }

    /// `predict` hands out a reusable forward [`model::Workspace`]; the
    /// other entry points have no cross-call state worth keeping.
    fn make_scratch(&self) -> Box<dyn Scratch> {
        Box::new(model::Workspace::default())
    }

    fn run_refs_scratch(
        &self,
        inputs: &[&HostTensor],
        scratch: &mut dyn Scratch,
    ) -> Result<Vec<HostTensor>> {
        if self.entry == "predict" {
            if let Some(ws) = scratch.as_any().downcast_mut::<model::Workspace>() {
                return model::run_predict_ws(&self.manifest, inputs, ws);
            }
        }
        self.run_refs(inputs)
    }

    fn decode_begin(&self) -> Result<Box<dyn DecodeSession>> {
        decode::ensure_entry(&self.entry)?;
        Ok(Box::new(decode::DecodeState::new(&self.manifest)))
    }

    fn decode_prefill(
        &self,
        params: &[&HostTensor],
        session: &mut dyn DecodeSession,
        tokens: &[i32],
    ) -> Result<()> {
        decode::ensure_entry(&self.entry)?;
        let st = session
            .as_any()
            .downcast_mut::<decode::DecodeState>()
            .ok_or_else(|| anyhow::anyhow!("decode session is not a native DecodeState"))?;
        decode::prefill(&self.manifest, params, st, tokens, false)
    }

    fn decode_step(
        &self,
        params: &[&HostTensor],
        session: &mut dyn DecodeSession,
        token: i32,
    ) -> Result<Vec<f32>> {
        decode::ensure_entry(&self.entry)?;
        let st = session
            .as_any()
            .downcast_mut::<decode::DecodeState>()
            .ok_or_else(|| anyhow::anyhow!("decode session is not a native DecodeState"))?;
        decode::step(&self.manifest, params, st, token)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_supports_the_manifest_contract() {
        let b = NativeBackend;
        let cast = Manifest::synthetic(spec::tiny_meta("cast_topk"));
        let vanilla = Manifest::synthetic(spec::tiny_meta("vanilla"));
        for entry in ["init", "predict", "train_step"] {
            assert!(b.supports(&cast, entry), "{entry}");
            assert!(b.supports(&vanilla, entry), "{entry}");
        }
        assert!(b.supports(&cast, "predict_ag"));
        assert!(!b.supports(&vanilla, "predict_ag"));
        assert!(!b.supports(&cast, "nonsense"));
        assert!(b.load(&vanilla, "predict_ag").is_err());
        assert!(b.load(&cast, "predict_ag").is_ok());
    }

    #[test]
    fn scratch_reuse_matches_stateless_predict() {
        let b = NativeBackend;
        let man = Manifest::synthetic(spec::tiny_meta("cast_topk"));
        let init = b.load(&man, "init").unwrap();
        let params = init.run(&[HostTensor::u32(vec![], vec![7])]).unwrap();
        let exe = b.load(&man, "predict").unwrap();
        let tokens = HostTensor::s32(vec![2, 64], (0..128).map(|i| i % 50).collect());
        let mut inputs: Vec<&HostTensor> = params.iter().collect();
        inputs.push(&tokens);
        let plain = exe.run_refs(&inputs).unwrap();
        let mut scratch = exe.make_scratch();
        // same workspace across repeated calls: bit-identical logits
        for _ in 0..2 {
            let reused = exe.run_refs_scratch(&inputs, scratch.as_mut()).unwrap();
            assert_eq!(reused[0].as_f32().unwrap(), plain[0].as_f32().unwrap());
        }
    }

    #[test]
    fn load_rejects_bad_geometry() {
        let b = NativeBackend;
        let mut meta = spec::tiny_meta("cast_topk");
        meta.heads = 3; // 16 % 3 != 0
        let man = Manifest::synthetic(meta);
        assert!(b.load(&man, "predict").is_err());
    }
}
