//! Cluster-health telemetry for the clustering attention variants:
//! per-layer occupancy, affinity entropy, balance, and step-over-step
//! assignment churn — the collapse signals clustered-attention work
//! (arXiv 2007.04825) guards against and VCC (arXiv 2305.04241) watches
//! when scaling context length.
//!
//! Gated exactly like `util::trace`: `CAST_CLUSTER_STATS` (any
//! non-empty value other than `0`) or [`set_enabled`] turns recording
//! on; when off, the tap in `variants::attn_forward` is a single
//! relaxed atomic load — no locks, no allocation, no arithmetic.
//!
//! Assignments are derived from the returned A_g affinity block with
//! the same argmax-first-max-wins rule as `analysis/clusters.rs`, so
//! the telemetry agrees with the offline cluster visualization.
//! Recording only *reads* `a_g` after the layer has computed it, so
//! model outputs are bit-identical with stats on or off (pinned by
//! `tests/integration_memstats.rs`).
//!
//! Metric definitions (DESIGN.md §Observability):
//! * **occupancy** — tokens argmax-assigned per cluster, summed over
//!   recorded forwards (the histogram behind `/debug/clusters`).
//! * **entropy** — mean per-token affinity entropy, normalized by
//!   `ln(n_c)` to `[0, 1]`: 1 = affinities spread evenly, 0 = all mass
//!   on one cluster.
//! * **balance_cv** — coefficient of variation (std/mean) of per-batch
//!   cluster sizes: 0 = perfectly balanced, `sqrt(n_c - 1)` = collapsed.
//! * **churn** — fraction of tokens whose assignment differs from the
//!   previous recorded forward of the same layer and geometry (train
//!   steps: how fast the clustering is still moving).
//! * **collapsed** — early warning, latched per layer: the top cluster
//!   held ≥ [`COLLAPSE_MAX_FRACTION`] of tokens (with `n_c ≥ 2`) or
//!   mean entropy fell below [`COLLAPSE_MIN_ENTROPY`] on any forward.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::Mutex;

/// Top-cluster token share that flags collapse (half the batch in one
/// of ≥ 2 clusters means the others are starving).
pub const COLLAPSE_MAX_FRACTION: f64 = 0.5;

/// Normalized affinity entropy below which assignments are effectively
/// deterministic into a single cluster.
pub const COLLAPSE_MIN_ENTROPY: f64 = 0.05;

const UNINIT: u8 = 0;
const INACTIVE: u8 = 1;
const ENABLED: u8 = 2;

static STATE: AtomicU8 = AtomicU8::new(UNINIT);

/// True when cluster-stats recording is on.  One relaxed load when not.
#[inline]
pub fn active() -> bool {
    state() == ENABLED
}

/// Programmatically enable/disable recording (overrides
/// `CAST_CLUSTER_STATS`).
pub fn set_enabled(on: bool) {
    STATE.store(if on { ENABLED } else { INACTIVE }, Ordering::SeqCst);
}

#[inline]
fn state() -> u8 {
    let s = STATE.load(Ordering::Relaxed);
    if s == UNINIT {
        init_from_env()
    } else {
        s
    }
}

#[cold]
fn init_from_env() -> u8 {
    static INIT: std::sync::Once = std::sync::Once::new();
    INIT.call_once(|| {
        let on = match std::env::var("CAST_CLUSTER_STATS") {
            Ok(v) => !v.trim().is_empty() && v.trim() != "0",
            Err(_) => false,
        };
        if on {
            crate::info!("cluster_stats: enabled via CAST_CLUSTER_STATS");
        }
        let _ = STATE.compare_exchange(
            UNINIT,
            if on { ENABLED } else { INACTIVE },
            Ordering::SeqCst,
            Ordering::SeqCst,
        );
    });
    STATE.load(Ordering::Relaxed)
}

// ---------------------------------------------------------------------------
// per-layer accumulators
// ---------------------------------------------------------------------------

struct LayerAcc {
    layer: i32,
    n_c: usize,
    forwards: u64,
    tokens: u64,
    /// Tokens argmax-assigned per cluster, summed over forwards.
    occupancy: Vec<u64>,
    sum_entropy: f64,
    sum_balance_cv: f64,
    sum_max_fraction: f64,
    sum_churn: f64,
    /// Forwards that had a comparable predecessor to churn against.
    churn_samples: u64,
    collapsed: bool,
    /// Last forward's argmax assignments, for churn (compared only when
    /// the geometry matches).
    prev_assign: Vec<u32>,
}

static LAYERS: Mutex<Vec<LayerAcc>> = Mutex::new(Vec::new());

/// One layer's aggregated health, as exported by [`snapshot`].
#[derive(Clone, Debug)]
pub struct LayerSnapshot {
    pub layer: i32,
    pub n_c: usize,
    pub forwards: u64,
    pub tokens: u64,
    pub occupancy: Vec<u64>,
    pub entropy: f64,
    pub balance_cv: f64,
    pub max_fraction: f64,
    pub churn: f64,
    pub collapsed: bool,
}

/// Cross-layer roll-up for gauges (`/metrics`) and train JSONL.
#[derive(Clone, Debug)]
pub struct Summary {
    pub layers: usize,
    /// Mean over layers of mean normalized affinity entropy.
    pub entropy: f64,
    /// Mean over layers of the cluster-size CV.
    pub balance_cv: f64,
    /// Mean over layers of assignment churn.
    pub churn: f64,
    /// Worst (largest) per-layer top-cluster share.
    pub max_fraction: f64,
    /// Layers whose collapse warning has latched.
    pub collapsed_layers: usize,
}

/// Parse the layer index out of an attention parameter prefix
/// (`"blocks.3.attn"` → 3); -1 when the prefix has another shape.
pub fn layer_of_prefix(prefix: &str) -> i32 {
    let rest = match prefix.strip_prefix("blocks.") {
        Some(r) => r,
        None => return -1,
    };
    match rest.split('.').next().and_then(|s| s.parse::<i32>().ok()) {
        Some(i) => i,
        None => -1,
    }
}

/// Record one attention forward's affinity block.  `a_g` is row-major
/// `(b·n, n_c)` — exactly what `cast_layer`/`clustered_layer` return.
/// No-op (after the gate load in the caller) unless [`active`].
pub fn record(layer: i32, b: usize, n: usize, n_c: usize, a_g: &[f32]) {
    if !active() || n_c == 0 || b * n == 0 || a_g.len() < b * n * n_c {
        return;
    }
    let rows = b * n;
    // per-token argmax (first max wins — analysis/clusters.rs rule) and
    // per-row normalized entropy, computed outside the lock
    let mut assign = vec![0u32; rows];
    let mut sizes = vec![0u64; n_c];
    let mut entropy_sum = 0.0f64;
    let ln_nc = (n_c as f64).ln();
    for r in 0..rows {
        let row = &a_g[r * n_c..(r + 1) * n_c];
        let mut arg = 0usize;
        let mut total = 0.0f64;
        for (c, &v) in row.iter().enumerate() {
            if v > row[arg] {
                arg = c;
            }
            total += v.max(0.0) as f64;
        }
        assign[r] = arg as u32;
        sizes[arg] += 1;
        if n_c > 1 && total > 0.0 {
            let mut h = 0.0f64;
            for &v in row {
                let p = v.max(0.0) as f64 / total;
                if p > 0.0 {
                    h -= p * p.ln();
                }
            }
            entropy_sum += h / ln_nc;
        }
    }
    let entropy = if n_c > 1 { entropy_sum / rows as f64 } else { 1.0 };
    let mean = rows as f64 / n_c as f64;
    let var = sizes
        .iter()
        .map(|&s| {
            let d = s as f64 - mean;
            d * d
        })
        .sum::<f64>()
        / n_c as f64;
    let balance_cv = var.sqrt() / mean;
    let max_fraction = sizes.iter().copied().max().unwrap_or(0) as f64 / rows as f64;
    let collapsed_now = (n_c >= 2 && max_fraction >= COLLAPSE_MAX_FRACTION)
        || (n_c >= 2 && entropy <= COLLAPSE_MIN_ENTROPY);

    let mut layers = LAYERS.lock().unwrap_or_else(|p| p.into_inner());
    let acc = match layers.iter_mut().find(|a| a.layer == layer && a.n_c == n_c) {
        Some(a) => a,
        None => {
            layers.push(LayerAcc {
                layer,
                n_c,
                forwards: 0,
                tokens: 0,
                occupancy: vec![0; n_c],
                sum_entropy: 0.0,
                sum_balance_cv: 0.0,
                sum_max_fraction: 0.0,
                sum_churn: 0.0,
                churn_samples: 0,
                collapsed: false,
                prev_assign: Vec::new(),
            });
            layers.last_mut().unwrap()
        }
    };
    acc.forwards += 1;
    acc.tokens += rows as u64;
    for (o, &s) in acc.occupancy.iter_mut().zip(&sizes) {
        *o += s;
    }
    acc.sum_entropy += entropy;
    acc.sum_balance_cv += balance_cv;
    acc.sum_max_fraction += max_fraction;
    acc.collapsed |= collapsed_now;
    if acc.prev_assign.len() == rows {
        let moved = assign.iter().zip(&acc.prev_assign).filter(|(a, b)| a != b).count();
        acc.sum_churn += moved as f64 / rows as f64;
        acc.churn_samples += 1;
    }
    acc.prev_assign = assign;
}

/// Aggregated per-layer health, sorted by layer index.
pub fn snapshot() -> Vec<LayerSnapshot> {
    let layers = LAYERS.lock().unwrap_or_else(|p| p.into_inner());
    let mut out: Vec<LayerSnapshot> = layers
        .iter()
        .filter(|a| a.forwards > 0)
        .map(|a| LayerSnapshot {
            layer: a.layer,
            n_c: a.n_c,
            forwards: a.forwards,
            tokens: a.tokens,
            occupancy: a.occupancy.clone(),
            entropy: a.sum_entropy / a.forwards as f64,
            balance_cv: a.sum_balance_cv / a.forwards as f64,
            max_fraction: a.sum_max_fraction / a.forwards as f64,
            churn: if a.churn_samples > 0 {
                a.sum_churn / a.churn_samples as f64
            } else {
                0.0
            },
            collapsed: a.collapsed,
        })
        .collect();
    out.sort_by_key(|s| s.layer);
    out
}

/// Roll a snapshot up into the cross-layer gauges.
pub fn summarize(layers: &[LayerSnapshot]) -> Option<Summary> {
    if layers.is_empty() {
        return None;
    }
    let n = layers.len() as f64;
    Some(Summary {
        layers: layers.len(),
        entropy: layers.iter().map(|l| l.entropy).sum::<f64>() / n,
        balance_cv: layers.iter().map(|l| l.balance_cv).sum::<f64>() / n,
        churn: layers.iter().map(|l| l.churn).sum::<f64>() / n,
        max_fraction: layers.iter().map(|l| l.max_fraction).fold(0.0, f64::max),
        collapsed_layers: layers.iter().filter(|l| l.collapsed).count(),
    })
}

/// Snapshot, summarize, and clear in one step — the per-harvest shape
/// the serve batcher and the train metrics sink use so each harvest
/// covers only the forwards since the previous one.
pub fn take_summary() -> Option<Summary> {
    let snap = snapshot();
    clear();
    summarize(&snap)
}

/// Drop all accumulated state (assignments included, so the next churn
/// sample starts fresh).
pub fn clear() {
    LAYERS.lock().unwrap_or_else(|p| p.into_inner()).clear();
}

/// Serialize in-process tests that toggle the gate: the accumulator
/// store is process-global.  Not API.
#[doc(hidden)]
pub fn test_guard() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    LOCK.lock().unwrap_or_else(|p| p.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// a_g with every row's mass on `hot`, for (rows, n_c).
    fn one_hot_ag(rows: usize, n_c: usize, hot: usize) -> Vec<f32> {
        let mut a = vec![0.0f32; rows * n_c];
        for r in 0..rows {
            a[r * n_c + hot] = 1.0;
        }
        a
    }

    /// a_g that spreads rows round-robin with uniform affinities.
    fn uniform_ag(rows: usize, n_c: usize) -> Vec<f32> {
        let mut a = vec![1.0f32 / n_c as f32; rows * n_c];
        for r in 0..rows {
            // tiny tilt so argmax round-robins instead of always-0
            a[r * n_c + (r % n_c)] += 1e-3;
        }
        a
    }

    #[test]
    fn disabled_record_is_a_no_op() {
        let _g = test_guard();
        set_enabled(false);
        clear();
        record(0, 1, 8, 4, &one_hot_ag(8, 4, 0));
        assert!(snapshot().is_empty());
        assert!(take_summary().is_none());
    }

    #[test]
    fn uniform_affinities_are_healthy() {
        let _g = test_guard();
        set_enabled(true);
        clear();
        record(0, 2, 8, 4, &uniform_ag(16, 4));
        let snap = snapshot();
        set_enabled(false);
        assert_eq!(snap.len(), 1);
        let l = &snap[0];
        assert_eq!((l.layer, l.n_c, l.forwards, l.tokens), (0, 4, 1, 16));
        assert_eq!(l.occupancy, vec![4, 4, 4, 4], "round-robin argmax");
        assert!(l.entropy > 0.95, "near-uniform rows ⇒ entropy ≈ 1, got {}", l.entropy);
        assert!(l.balance_cv < 1e-9, "perfectly balanced, got {}", l.balance_cv);
        assert!(!l.collapsed);
        clear();
    }

    #[test]
    fn one_hot_affinities_latch_collapse() {
        let _g = test_guard();
        set_enabled(true);
        clear();
        record(1, 1, 16, 4, &one_hot_ag(16, 4, 2));
        let snap = snapshot();
        let l = &snap[0];
        assert_eq!(l.occupancy, vec![0, 0, 16, 0]);
        assert!(l.entropy < COLLAPSE_MIN_ENTROPY);
        assert!((l.max_fraction - 1.0).abs() < 1e-12);
        assert!(l.collapsed, "all mass on one cluster must warn");
        let sum = summarize(&snap).unwrap();
        assert_eq!(sum.collapsed_layers, 1);
        assert!((sum.max_fraction - 1.0).abs() < 1e-12);
        set_enabled(false);
        clear();
    }

    #[test]
    fn churn_counts_reassigned_tokens_between_forwards() {
        let _g = test_guard();
        set_enabled(true);
        clear();
        record(0, 1, 8, 2, &one_hot_ag(8, 2, 0));
        // second forward: every token flips cluster ⇒ churn 1.0
        record(0, 1, 8, 2, &one_hot_ag(8, 2, 1));
        // third forward: no movement ⇒ churn 0.0; mean is 0.5
        record(0, 1, 8, 2, &one_hot_ag(8, 2, 1));
        let snap = snapshot();
        set_enabled(false);
        assert!((snap[0].churn - 0.5).abs() < 1e-12, "got {}", snap[0].churn);
        clear();
    }

    #[test]
    fn take_summary_clears_and_prefix_parses() {
        let _g = test_guard();
        set_enabled(true);
        clear();
        record(0, 1, 4, 2, &uniform_ag(4, 2));
        record(3, 1, 4, 2, &uniform_ag(4, 2));
        let sum = take_summary().unwrap();
        set_enabled(false);
        assert_eq!(sum.layers, 2);
        assert!(snapshot().is_empty(), "take_summary clears");
        assert_eq!(layer_of_prefix("blocks.3.attn"), 3);
        assert_eq!(layer_of_prefix("blocks.12.attn"), 12);
        assert_eq!(layer_of_prefix("head.out"), -1);
        assert_eq!(layer_of_prefix("blocks.x.attn"), -1);
    }
}
