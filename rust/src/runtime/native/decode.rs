//! Incremental decode for the causal CAST variant: the cluster-state
//! cache behind the `Executable::decode_*` seam.
//!
//! CAST's analog of a KV cache is the per-layer cluster state — which
//! tokens sit in which cluster slot, plus their K/V projections.  Causal
//! clustering assigns tokens in *position* order (first non-full cluster
//! in descending-affinity preference order), so a token's assignment is
//! frozen the moment it is made: appending token `n` touches exactly one
//! cluster per layer, and the per-token cost is O(α) — four 1-row
//! projections, an Nc-wide gate, one κ-wide attention row, and an FFN —
//! independent of the sequence length.
//!
//! **Bit-parity contract** (asserted by `tests/integration_decode.rs`):
//! greedy generation through [`step`] is bit-identical to re-running the
//! full causal forward over the whole history each step, for any
//! `CAST_NUM_THREADS` and either SIMD mode.  Two properties make this
//! hold:
//! * every reduction in the engine is fixed-order and independent of row
//!   blocking (`matmul_rows8`, `dot8`, `sum8` at fixed row width), so a
//!   1-row dense equals the same row of an n-row dense bitwise;
//! * masked attention-score slots underflow to exactly +0.0 under
//!   `exp(score - max)`, so the *values* behind the mask never reach the
//!   output — the incremental path can score empty slots as `NEG_INF`
//!   without the (garbage) K rows the full kernel reads there.
//!
//! The one regime where widths differ is `n < κ`: `cast_layer` clamps
//! `kappa = κ.min(n)`, so attention-row widths grow with the prefix and
//! no fixed-width cache can be bit-stable.  Below κ the session therefore
//! falls back to a full forward over the (short) prefix each step; the
//! cache is built once `n ≥ κ` and every later token is O(α) incremental.
//! Chunked prefill exploits the same split: one full forward over the
//! first κ prompt tokens builds the cache, then each remaining prompt
//! token is absorbed incrementally — peak scratch is O(κ²) per layer, no
//! B×N slab is ever materialized for a long prompt.

use anyhow::{anyhow, bail, ensure, Result};

use crate::runtime::artifacts::{Manifest, ModelMeta};
use crate::runtime::backend::DecodeSession;
use crate::runtime::tensor::HostTensor;
use crate::util::fault;
use crate::util::rng::Rng;
use crate::util::simd;
use crate::util::trace;

use super::layer::{CastParams, CastScratch};
use super::model::{self, Params, Workspace};
use super::ops::{self, AttnFn, NEG_INF};

/// Per-layer cluster-state cache (B = 1): the frozen assignment plus the
/// K/V rows of every placed token, laid out by `(cluster, slot)`.
struct LayerCache {
    /// Occupied slots per cluster (greedy fills them contiguously).
    fill: Vec<usize>,
    /// Sequence position held by each `(cluster, slot)` cell.
    pos: Vec<usize>,
    /// 1.0 where the slot holds a real token (mirrors `CastScratch::valid`).
    valid: Vec<f32>,
    /// Cached K rows, (Nc·κ, d).
    k: Vec<f32>,
    /// Cached V rows, (Nc·κ, d).
    v: Vec<f32>,
}

impl LayerCache {
    fn new(n_c: usize, kappa: usize, d: usize) -> LayerCache {
        LayerCache {
            fill: vec![0; n_c],
            pos: vec![0; n_c * kappa],
            valid: vec![0.0; n_c * kappa],
            k: vec![0.0; n_c * kappa * d],
            v: vec![0.0; n_c * kappa * d],
        }
    }
}

/// One decode session: the token history plus the per-layer cluster
/// caches.  Owned by the caller (serve holds one per in-flight `/generate`
/// request and drops it on completion, deadline, or disconnect — that IS
/// the eviction policy) and threaded back through the
/// `Executable::decode_step` seam.
pub struct DecodeState {
    meta: ModelMeta,
    key: String,
    /// Full token history (prompt + generated) — the below-κ fallback
    /// recomputes from it, and the cache rebuild reads its prefix.
    tokens: Vec<i32>,
    /// `None` until the prefix reaches κ; `Some` = incremental regime.
    layers: Option<Vec<LayerCache>>,
    /// How many of `tokens` the cache has absorbed.
    absorbed: usize,
    /// Tokens absorbed incrementally after every cluster filled — the
    /// zero-attention passthrough dead-end (ROADMAP long-context item),
    /// exported as `cast_decode_passthrough_tokens_total`.
    passthrough: u64,
    /// Reusable forward workspace for the fallback / rebuild passes.
    ws: Workspace,
}

impl DecodeState {
    pub fn new(manifest: &Manifest) -> DecodeState {
        DecodeState {
            meta: manifest.meta.clone(),
            key: manifest.key.clone(),
            tokens: Vec::new(),
            layers: None,
            absorbed: 0,
            passthrough: 0,
            ws: Workspace::default(),
        }
    }

    /// Whether the session is past the κ threshold and running O(α)
    /// incremental steps (vs. the below-κ full-forward fallback).
    pub fn incremental(&self) -> bool {
        self.layers.is_some()
    }

    /// The token history absorbed so far.
    pub fn history(&self) -> &[i32] {
        &self.tokens
    }

    /// Tokens absorbed incrementally after every cluster slot filled
    /// (zero-attention passthroughs — the Nc·κ capacity dead-end).
    pub fn passthrough_tokens(&self) -> u64 {
        self.passthrough
    }

    /// Cluster-cache fill: `(occupied_slots, capacity_slots)` summed
    /// over layers.  Capacity is `depth · Nc · κ` whether or not the
    /// cache has been built yet; occupancy is 0 in the below-κ regime.
    pub fn cache_fill(&self) -> (usize, usize) {
        let capacity = self.meta.depth * self.meta.n_c.max(1) * self.meta.kappa.max(1);
        let filled = self
            .layers
            .as_ref()
            .map(|ls| ls.iter().map(|lc| lc.fill.iter().sum::<usize>()).sum())
            .unwrap_or(0);
        (filled, capacity)
    }

    /// FNV-1a fingerprint of the entire cluster-state cache (fills, slot
    /// positions, K/V bits).  Chunked and monolithic prefill must agree
    /// on it exactly — the parity suite's cheap equality witness.
    pub fn cache_digest(&self) -> u64 {
        fn eat(h: &mut u64, byte: u8) {
            *h ^= byte as u64;
            *h = h.wrapping_mul(0x100_0000_01b3);
        }
        fn eat_u64(h: &mut u64, x: u64) {
            for b in x.to_le_bytes() {
                eat(h, b);
            }
        }
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        eat_u64(&mut h, self.absorbed as u64);
        if let Some(layers) = &self.layers {
            for lc in layers {
                for &f in &lc.fill {
                    eat_u64(&mut h, f as u64);
                }
                for &p in &lc.pos {
                    eat_u64(&mut h, p as u64);
                }
                for &x in lc.valid.iter().chain(&lc.k).chain(&lc.v) {
                    for b in x.to_bits().to_le_bytes() {
                        eat(&mut h, b);
                    }
                }
            }
        }
        h
    }
}

impl DecodeSession for DecodeState {
    fn as_any(&mut self) -> &mut dyn std::any::Any {
        self
    }

    fn len(&self) -> usize {
        self.tokens.len()
    }
}

fn check_manifest(manifest: &Manifest, st: &DecodeState) -> Result<()> {
    ensure!(
        manifest.key == st.key,
        "decode session belongs to model {:?}, not {:?}",
        st.key,
        manifest.key
    );
    ensure!(
        manifest.meta.causal && manifest.meta.is_cast() && !manifest.meta.dual,
        "incremental decode needs a causal, non-dual CAST variant (got {:?})",
        manifest.meta.variant
    );
    Ok(())
}

fn cast_params<'a>(p: &Params<'a>, prefix: &str) -> Result<CastParams<'a>> {
    Ok(CastParams {
        wq_w: p.f(&format!("{prefix}.wq.w"))?,
        wq_b: p.f(&format!("{prefix}.wq.b"))?,
        wk_w: p.f(&format!("{prefix}.wk.w"))?,
        wk_b: p.f(&format!("{prefix}.wk.b"))?,
        wv_w: p.f(&format!("{prefix}.wv.w"))?,
        wv_b: p.f(&format!("{prefix}.wv.b"))?,
        wo_w: p.f(&format!("{prefix}.wo.w"))?,
        wo_b: p.f(&format!("{prefix}.wo.b"))?,
        s: p.f(&format!("{prefix}.s"))?,
        phi_w: p.f(&format!("{prefix}.phi.w"))?,
        phi_b: p.f(&format!("{prefix}.phi.b"))?,
    })
}

/// Tied-embedding next-token readout: the classifier head has no LM
/// head, so logits come from the transposed input path — final-layer
/// activations x (d) → `projᵀ` → (d_emb) → `embᵀ` → (vocab).  Shared by
/// the incremental step, the below-κ fallback, and the parity reference,
/// so parity tests exercise the transformer stack, not the readout.
pub fn readout(p: &Params, meta: &ModelMeta, xrow: &[f32]) -> Result<Vec<f32>> {
    let (d, d_emb) = (meta.d, meta.d_emb);
    ensure!(xrow.len() == d, "readout row has {} dims, want {}", xrow.len(), d);
    let proj = p.f("proj.w")?; // (d_emb, d) row-major
    let emb = p.f("embed.emb")?; // (vocab, d_emb)
    let e: Vec<f32> = (0..d_emb).map(|i| ops::dot(xrow, &proj[i * d..(i + 1) * d])).collect();
    Ok((0..meta.vocab).map(|v| ops::dot(&e, &emb[v * d_emb..(v + 1) * d_emb])).collect())
}

/// Reference next-token logits: a full causal forward over the entire
/// `tokens` prefix (B = 1, fresh workspace) followed by the same
/// tied-embedding [`readout`] the incremental path uses.  O(αN) per call —
/// this is the recompute baseline the parity suite and `bench --decode`
/// hold [`step`] against.
pub fn full_logits(manifest: &Manifest, params: &[&HostTensor], tokens: &[i32]) -> Result<Vec<f32>> {
    ensure!(!tokens.is_empty(), "full_logits needs at least one token");
    let p = Params::bind(&manifest.params, params)?;
    let meta = &manifest.meta;
    let n = tokens.len();
    let d = meta.d;
    let mut ws = Workspace::default();
    let (x, _) = model::encode_x(&p, meta, tokens, 1, n, false, &mut ws, &mut |_, _| {})?;
    readout(&p, meta, &x[(n - 1) * d..n * d])
}

/// Full causal forward over `st.tokens[..upto]` that (a) returns the
/// final pre-pool activations and (b) rebuilds the per-layer cluster
/// caches from the forward's own scratch.  Only called with `upto ≥ κ`,
/// so the κ clamp is the identity and the cache widths are steady-state.
fn rebuild(manifest: &Manifest, p: &Params, st: &mut DecodeState, upto: usize) -> Result<Vec<f32>> {
    let meta = &manifest.meta;
    let (d, n_c) = (meta.d, meta.n_c.max(1));
    let kappa = meta.kappa.max(1);
    ensure!(upto >= kappa, "cache rebuild needs a prefix of at least κ={kappa} tokens");
    let mut layers: Vec<LayerCache> =
        (0..meta.depth).map(|_| LayerCache::new(n_c, kappa, d)).collect();
    let toks = &st.tokens[..upto];
    let (x, _) = model::encode_x(
        p,
        meta,
        toks,
        1,
        upto,
        false,
        &mut st.ws,
        &mut |li: usize, cs: &CastScratch| {
            let lc = &mut layers[li];
            for c in 0..n_c {
                let mut fill = 0usize;
                for slot in 0..kappa {
                    let base = c * kappa + slot;
                    if cs.valid[base] > 0.0 {
                        let t = cs.idx[base];
                        lc.pos[base] = t;
                        lc.valid[base] = 1.0;
                        lc.k[base * d..(base + 1) * d].copy_from_slice(&cs.k[t * d..(t + 1) * d]);
                        lc.v[base * d..(base + 1) * d].copy_from_slice(&cs.v[t * d..(t + 1) * d]);
                        fill += 1;
                    }
                }
                lc.fill[c] = fill;
            }
        },
    )?;
    st.layers = Some(layers);
    st.absorbed = upto;
    Ok(x)
}

/// One O(α) incremental attention row for the token at `pos`: assign it
/// to a cluster (decode.assign), append its K/V to that cluster's cache
/// (decode.summary), attend over the cluster's κ slots and apply the
/// A_sum combination (decode.attn).  Mirrors `cast_layer` steps 1–6 for a
/// single appended row, bit-for-bit.  The second return is `true` when
/// the token could not be placed (every cluster full) and rode through
/// as a zero-attention passthrough.
#[allow(clippy::too_many_arguments)]
fn attn_row(
    cp: &CastParams,
    x: &[f32],
    lc: &mut LayerCache,
    pos: usize,
    meta: &ModelMeta,
    attn: AttnFn,
) -> Result<(Vec<f32>, bool)> {
    let (h, d_h) = (meta.heads, meta.d_h());
    let d = meta.d;
    let n_c = meta.n_c.max(1);
    let kappa = meta.kappa.max(1);
    let tau = (d_h as f32).sqrt();

    // step 1: 1-row projections
    let q = ops::dense(x, cp.wq_w, cp.wq_b, 1, d, d);
    let k = ops::dense(x, cp.wk_w, cp.wk_b, 1, d, d);
    let v = ops::dense(x, cp.wv_w, cp.wv_b, 1, d, d);
    let phi = ops::dense(x, cp.phi_w, cp.phi_b, 1, d, 1)[0];

    // step 2/3: surrogate affinities + head-summed gate (Nc-wide rows)
    let mut a_q = vec![0.0f32; h * n_c];
    let mut a_k = vec![0.0f32; h * n_c];
    for hh in 0..h {
        let qrow = &q[hh * d_h..][..d_h];
        let krow = &k[hh * d_h..][..d_h];
        for c in 0..n_c {
            let srow = &cp.s[(c * h + hh) * d_h..][..d_h];
            a_q[hh * n_c + c] = ops::dot(qrow, srow);
            a_k[hh * n_c + c] = ops::dot(krow, srow);
        }
    }
    let mut rq = vec![0.0f32; n_c];
    let mut f2k = vec![0.0f32; n_c];
    for hh in 0..h {
        for c in 0..n_c {
            rq[c] += a_q[hh * n_c + c];
            f2k[c] += a_k[hh * n_c + c];
        }
    }
    let mut f2q = rq.clone();
    ops::attn_rows(&mut f2q, n_c, attn);
    ops::attn_rows(&mut f2k, n_c, attn);
    let g = ops::sigmoid(phi);
    let mut agrow = vec![0.0f32; n_c];
    for c in 0..n_c {
        agrow[c] = g * f2q[c] + (1.0 - g) * f2k[c];
    }

    // step 4: causal greedy assignment — clusters in descending-affinity
    // order (index tiebreak), first non-full wins; same comparator as
    // `greedy_assign`, so the choice matches the full forward exactly
    let t = trace::span("decode.assign");
    let mut pref: Vec<usize> = (0..n_c).collect();
    pref.sort_unstable_by(|&a, &b| {
        agrow[b]
            .partial_cmp(&agrow[a])
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(&b))
    });
    let assigned = pref.iter().copied().find(|&c| lc.fill[c] < kappa);
    drop(t);

    let mut r = vec![0.0f32; d];
    if let Some(c) = assigned {
        // update only this cluster's cached state: append the token's
        // K/V into the next free slot
        let t = trace::span("decode.summary");
        let slot = lc.fill[c];
        let base = c * kappa + slot;
        lc.k[base * d..(base + 1) * d].copy_from_slice(&k);
        lc.v[base * d..(base + 1) * d].copy_from_slice(&v);
        lc.valid[base] = 1.0;
        lc.pos[base] = pos;
        lc.fill[c] += 1;
        drop(t);

        // step 5/6: one κ-wide masked attention row per head over the
        // cluster's slots, then the A_sum combination (eq. 5).  Empty
        // slots score NEG_INF — the full kernel reads garbage K rows
        // there, but exp() underflows both to exactly +0.0, so the
        // outputs agree bitwise.  Every cached member has position < pos
        // (and the token itself ==), so the causal mask never fires.
        let t = trace::span("decode.attn");
        let mut scores = vec![0.0f32; kappa];
        let mut intra = vec![0.0f32; d];
        for hh in 0..h {
            let qrow = &q[hh * d_h..][..d_h];
            for (j, sv) in scores.iter_mut().enumerate() {
                *sv = if lc.valid[c * kappa + j] != 0.0 {
                    let krow = &lc.k[(c * kappa + j) * d + hh * d_h..][..d_h];
                    ops::dot(qrow, krow) / tau
                } else {
                    NEG_INF
                };
            }
            ops::attn_rows(&mut scores, kappa, attn);
            for j in 0..kappa {
                let pij = scores[j] * lc.valid[c * kappa + j];
                if pij != 0.0 {
                    let vrow = &lc.v[(c * kappa + j) * d + hh * d_h..][..d_h];
                    simd::axpy8(&mut intra[hh * d_h..(hh + 1) * d_h], pij, vrow);
                }
            }
        }
        let sp = ops::softplus1(phi) / tau;
        let mut a_sum: Vec<f32> = (0..n_c).map(|cc| rq[cc] * sp).collect();
        ops::attn_rows(&mut a_sum, n_c, attn);
        let wi = a_sum[c];
        if wi != 0.0 {
            simd::axpy8(&mut r, wi, &intra);
        }
        drop(t);
    }
    // unplaced token (every cluster full): r stays zero and the output is
    // the wo bias row — exactly what the full forward produces
    Ok((ops::dense(&r, cp.wo_w, cp.wo_b, 1, d, d), assigned.is_none()))
}

/// Append one token at `pos` through every layer incrementally; returns
/// the final pre-readout activation row (d) and whether any layer had to
/// pass the token through unplaced (all caches fill in lockstep, so
/// "any" and "every" coincide — one flag per token).
fn append_incremental(
    p: &Params,
    meta: &ModelMeta,
    layers: &mut [LayerCache],
    pos: usize,
    token: i32,
) -> Result<(Vec<f32>, bool)> {
    let (d, d_emb) = (meta.d, meta.d_emb);
    let attn = AttnFn::parse(&meta.attn_fn)?;

    // embed: token row + its sinusoidal position row, then the input proj
    let emb = p.f("embed.emb")?;
    let vocab_max = meta.vocab.saturating_sub(1);
    let tok = (token.max(0) as usize).min(vocab_max);
    let mut e = emb[tok * d_emb..(tok + 1) * d_emb].to_vec();
    let pe = ops::sinusoidal_position_row(pos, d_emb);
    simd::add8(&mut e, &pe);
    let mut x = ops::dense(&e, p.f("proj.w")?, p.f("proj.b")?, 1, d_emb, d);

    let mut hid: Vec<f32> = Vec::new();
    let mut ffn_out: Vec<f32> = Vec::new();
    let mut passthrough = false;
    for (i, lc) in layers.iter_mut().enumerate() {
        let blk = format!("blocks.{i}");
        let cp = cast_params(p, &format!("{blk}.attn"))?;
        if meta.prenorm {
            let mut xn = x.clone();
            model::apply_norm(p, meta, &format!("{blk}.norm1"), &mut xn)?;
            let (a, unplaced) = attn_row(&cp, &xn, lc, pos, meta, attn)?;
            passthrough |= unplaced;
            simd::add8(&mut x, &a);
            let mut xn2 = x.clone();
            model::apply_norm(p, meta, &format!("{blk}.norm2"), &mut xn2)?;
            model::ffn(p, &format!("{blk}.ffn"), &xn2, 1, d, meta.d_ff, &mut hid, &mut ffn_out)?;
            simd::add8(&mut x, &ffn_out);
        } else {
            let (a, unplaced) = attn_row(&cp, &x, lc, pos, meta, attn)?;
            passthrough |= unplaced;
            simd::add8(&mut x, &a);
            model::apply_norm(p, meta, &format!("{blk}.norm1"), &mut x)?;
            model::ffn(p, &format!("{blk}.ffn"), &x, 1, d, meta.d_ff, &mut hid, &mut ffn_out)?;
            simd::add8(&mut x, &ffn_out);
            model::apply_norm(p, meta, &format!("{blk}.norm2"), &mut x)?;
        }
    }
    if meta.prenorm {
        model::apply_norm(p, meta, "out_norm", &mut x)?;
    }
    Ok((x, passthrough))
}

/// Absorb `tokens` (the prompt, or one chunk of it) into the session
/// without sampling.  `monolithic = false` (the production path) builds
/// the cache from a full forward over only the first κ tokens and absorbs
/// the rest one-by-one — O(κ²) peak scratch for any prompt length.
/// `monolithic = true` rebuilds from one full forward over the entire
/// history — the reference the parity suite checks chunking against.
pub fn prefill(
    manifest: &Manifest,
    params: &[&HostTensor],
    st: &mut DecodeState,
    tokens: &[i32],
    monolithic: bool,
) -> Result<()> {
    check_manifest(manifest, st)?;
    let p = Params::bind(&manifest.params, params)?;
    st.tokens.extend_from_slice(tokens);
    let meta = &manifest.meta;
    let kappa = meta.kappa.max(1);
    let n = st.tokens.len();
    if st.layers.is_none() {
        if n < kappa {
            return Ok(()); // below κ: nothing to cache yet (fallback regime)
        }
        let upto = if monolithic { n } else { kappa };
        rebuild(manifest, &p, st, upto)?;
    }
    while st.absorbed < st.tokens.len() {
        let i = st.absorbed;
        let tok = st.tokens[i];
        let layers = st.layers.as_mut().expect("cache exists past κ");
        let (_, passthrough) = append_incremental(&p, meta, layers, i, tok)?;
        if passthrough {
            st.passthrough += 1;
        }
        st.absorbed = i + 1;
    }
    Ok(())
}

/// Absorb one token and return the next-token logits (vocab).
/// Bit-identical to a full causal forward over the whole history — the
/// parity suite asserts it across the threads × SIMD matrix.
pub fn step(
    manifest: &Manifest,
    params: &[&HostTensor],
    st: &mut DecodeState,
    token: i32,
) -> Result<Vec<f32>> {
    // decode-path fault point (chaos testing: a mid-stream `panic` plan
    // must still answer the /generate request cleanly)
    if fault::active() {
        fault::check("engine.decode").map_err(|e| anyhow!("{e} (decode step)"))?;
    }
    check_manifest(manifest, st)?;
    let p = Params::bind(&manifest.params, params)?;
    let meta = &manifest.meta;
    let d = meta.d;
    let kappa = meta.kappa.max(1);
    st.tokens.push(token);
    let n = st.tokens.len();

    if st.layers.is_none() {
        if n < kappa {
            // below the κ clamp no fixed-width cache is bit-stable (row
            // widths still grow with the prefix): recompute the short
            // forward outright
            let toks = &st.tokens[..n];
            let (x, _) =
                model::encode_x(&p, meta, toks, 1, n, false, &mut st.ws, &mut |_, _| {})?;
            return readout(&p, meta, &x[(n - 1) * d..n * d]);
        }
        // crossing κ: one full forward over the first κ tokens builds the
        // cache; any backlog past it is absorbed incrementally below
        let x = rebuild(manifest, &p, st, kappa)?;
        if st.absorbed == n {
            return readout(&p, meta, &x[(n - 1) * d..n * d]);
        }
    }
    let mut last = Vec::new();
    while st.absorbed < n {
        let i = st.absorbed;
        let tok = st.tokens[i];
        let layers = st.layers.as_mut().expect("cache exists past κ");
        let (x, passthrough) = append_incremental(&p, meta, layers, i, tok)?;
        if passthrough {
            st.passthrough += 1;
        }
        last = x;
        st.absorbed = i + 1;
    }
    ensure!(!last.is_empty(), "decode step absorbed nothing");
    readout(&p, meta, &last)
}

/// Greedy next token: argmax with lowest-index tiebreak (matches the
/// parity reference exactly).
pub fn argmax(logits: &[f32]) -> usize {
    let mut arg = 0usize;
    for (i, &v) in logits.iter().enumerate() {
        if v > logits[arg] {
            arg = i;
        }
    }
    arg
}

/// Temperature sampling over softmax(logits / temp); `temp <= 0` falls
/// back to greedy.  Deterministic given the caller's `Rng`.
pub fn sample(logits: &[f32], temp: f32, rng: &mut Rng) -> usize {
    if temp <= 0.0 || !temp.is_finite() || logits.is_empty() {
        return argmax(logits);
    }
    let mx = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let weights: Vec<f64> = logits.iter().map(|&v| (((v - mx) / temp) as f64).exp()).collect();
    let z: f64 = weights.iter().sum();
    if !(z > 0.0) || !z.is_finite() {
        return argmax(logits);
    }
    let mut u = rng.f32() as f64 * z;
    for (i, w) in weights.iter().enumerate() {
        u -= w;
        if u <= 0.0 {
            return i;
        }
    }
    logits.len() - 1
}

/// The support predicate for the `"decode"` entry: causal CAST, non-dual.
pub fn supported(meta: &ModelMeta) -> bool {
    meta.causal && meta.is_cast() && !meta.dual
}

/// Guard against misuse of the seam from a non-decode executable.
pub fn ensure_entry(entry: &str) -> Result<()> {
    if entry != "decode" {
        bail!("decode sessions need a \"decode\" executable (this one is {entry:?})");
    }
    Ok(())
}
