//! `clustered` — k-means centroid attention with exact top-k correction
//! (Vyas et al., arXiv 2007.04825): the recipe CAST explicitly improves
//! on, implemented here as its strongest in-repo rival.
//!
//! Per (batch, head), queries are grouped by a short k-means (Lloyd)
//! run; each cluster attends once through its centroid μ_c over all N
//! keys, and every member token refines the κ keys the centroid rated
//! highest with its *own* exact attention:
//!
//!     p_c  = attn(μ_c · Kᵀ / τ)                    (centroid row, N wide)
//!     T_c  = top-κ indices of p_c                  (exact-correction set)
//!     o_i  = m_c · attn(q_i · K[T_c]ᵀ / τ) V[T_c]  (member's exact part)
//!            + p_c V − Σ_{t∈T_c} p_c[t] v_t        (centroid tail)
//!
//! with m_c = Σ_{t∈T_c} p_c[t], so the exact part replaces precisely
//! the probability mass the centroid assigned to T_c.  With κ ≥ N the
//! tail cancels and the layer degrades to vanilla attention.
//!
//! The discrete choices (cluster assignment, top-k sets) are captured
//! in a fused u32 *plan* and treated straight-through by the backward —
//! everything differentiable (centroid means, both attention rows, the
//! value mixes) gets an exact gradient.  Empty clusters have no member
//! tokens, contribute nothing to the output, and therefore need no
//! centroid gradient.
//!
//! Determinism: k-means ties break to the lowest cluster index, top-k
//! uses [`ops::top_k_desc`]'s (score desc, index asc) order, all member
//! and key reductions run in ascending index order, and the parallel
//! grain is one batch element — results are bit-identical across
//! thread counts.  The cluster affinity matrix `A_g` (softmax over
//! −‖q_i − μ_c‖²/τ, head-averaged) is exposed for `predict_ag`, the
//! clusters analysis, and the fig4 viz.

use anyhow::{ensure, Result};

use super::grad::layer::{fnv_fold, BaselineGradRefs};
use super::grad::ops as gops;
use super::layer::{BaselineParams, Dims};
use super::ops::{self, AttnFn};
use crate::util::{parallel, simd};

const FNV_SEED: u64 = 0xcbf2_9ce4_8422_2325;

/// Effective correction width: κ clamped to the sequence length.
fn top_width(dims: &Dims) -> usize {
    dims.kappa.min(dims.n).max(1)
}

/// Plan u32s per batch element: per head, one assignment per token plus
/// one top-k set per cluster.
fn plan_stride(dims: &Dims, kp: usize) -> usize {
    dims.heads * (dims.n + dims.n_c * kp)
}

/// Offset of head `hh`'s cluster-`c` top-k set inside a batch element's
/// plan chunk (assignments for all heads come first).
fn topk_off(dims: &Dims, kp: usize, hh: usize, c: usize) -> usize {
    dims.heads * dims.n + (hh * dims.n_c + c) * kp
}

/// Mean of each cluster's member q-rows, accumulated in ascending token
/// order.  Clusters with no members are left untouched (k-means "keep
/// previous centroid" semantics); callers must not read their μ unless
/// they own a previous value.  Shared by the Lloyd update and the
/// attend/backward recomputation so the two are bit-identical.
#[allow(clippy::too_many_arguments)]
fn means_from_assign(
    q: &[f32],
    bb: usize,
    hh: usize,
    dims: &Dims,
    assign: &[u32],
    sum: &mut [f32],
    cnt: &mut [usize],
    mu: &mut [f32],
) {
    let (n, d_h, cc) = (dims.n, dims.d_h, dims.n_c);
    let d = dims.d();
    sum.iter_mut().for_each(|x| *x = 0.0);
    cnt.iter_mut().for_each(|x| *x = 0);
    for (i, &a) in assign.iter().enumerate() {
        let c = a as usize;
        let qrow = &q[(bb * n + i) * d + hh * d_h..][..d_h];
        simd::add8(&mut sum[c * d_h..][..d_h], qrow);
        cnt[c] += 1;
    }
    for c in 0..cc {
        if cnt[c] > 0 {
            let inv = 1.0 / cnt[c] as f32;
            let dst = &mut mu[c * d_h..][..d_h];
            dst.copy_from_slice(&sum[c * d_h..][..d_h]);
            simd::scale8(dst, inv);
        }
    }
}

fn dist2(a: &[f32], b: &[f32]) -> f32 {
    let mut acc = 0.0f32;
    for (x, y) in a.iter().zip(b) {
        let diff = x - y;
        acc += diff * diff;
    }
    acc
}

/// Two Lloyd iterations over one (batch, head)'s query rows.  Centroids
/// start on evenly spaced tokens; assignment ties break to the lowest
/// cluster index.  Writes the final assignment and leaves `mu` holding
/// the matching final centroids (kept-previous for empty clusters).
#[allow(clippy::too_many_arguments)]
fn kmeans(
    q: &[f32],
    bb: usize,
    hh: usize,
    dims: &Dims,
    assign: &mut [u32],
    sum: &mut [f32],
    cnt: &mut [usize],
    mu: &mut [f32],
) {
    let (n, d_h, cc) = (dims.n, dims.d_h, dims.n_c);
    let d = dims.d();
    for c in 0..cc {
        let i = c * n / cc;
        let qrow = &q[(bb * n + i) * d + hh * d_h..][..d_h];
        mu[c * d_h..][..d_h].copy_from_slice(qrow);
    }
    for _ in 0..2 {
        for (i, a) in assign.iter_mut().enumerate() {
            let qrow = &q[(bb * n + i) * d + hh * d_h..][..d_h];
            let mut best = 0usize;
            let mut best_d = f32::INFINITY;
            for c in 0..cc {
                let dd = dist2(qrow, &mu[c * d_h..][..d_h]);
                if dd < best_d {
                    best_d = dd;
                    best = c;
                }
            }
            *a = best as u32;
        }
        means_from_assign(q, bb, hh, dims, assign, sum, cnt, mu);
    }
}

struct PlanScratch {
    mu: Vec<f32>,
    sum: Vec<f32>,
    cnt: Vec<usize>,
    pre: Vec<f32>,
    post: Vec<f32>,
    idx: Vec<usize>,
    arow: Vec<f32>,
}

fn plan_scratch(dims: &Dims) -> PlanScratch {
    let (n, d_h, cc) = (dims.n, dims.d_h, dims.n_c);
    PlanScratch {
        mu: vec![0.0; cc * d_h],
        sum: vec![0.0; cc * d_h],
        cnt: vec![0; cc],
        pre: vec![0.0; n],
        post: vec![0.0; n],
        idx: Vec::with_capacity(n),
        arow: vec![0.0; cc],
    }
}

/// Pass 1: per batch element, run k-means per head, record the plan
/// (assignments + per-cluster top-k sets) and accumulate the
/// head-averaged cluster affinity matrix `A_g`.
fn compute_plan_and_ag(
    q: &[f32],
    k: &[f32],
    dims: &Dims,
    kp: usize,
    plan: &mut [u32],
    ag: &mut [f32],
) {
    let (n, h, d_h, cc) = (dims.n, dims.heads, dims.d_h, dims.n_c);
    let d = dims.d();
    let tau = (d_h as f32).sqrt();
    let inv_h = 1.0 / h as f32;
    let attn = dims.attn;
    parallel::par_zip2_mut_with(
        plan,
        plan_stride(dims, kp),
        ag,
        n * cc,
        || plan_scratch(dims),
        |scr, bb, pchunk, agchunk| {
            for hh in 0..h {
                {
                    let head_assign = &mut pchunk[hh * n..][..n];
                    kmeans(q, bb, hh, dims, head_assign, &mut scr.sum, &mut scr.cnt, &mut scr.mu);
                }
                // affinity rows: softmax over −‖q_i − μ_c‖²/τ, averaged
                // over heads (empty clusters use their kept-previous μ)
                for i in 0..n {
                    let qrow = &q[(bb * n + i) * d + hh * d_h..][..d_h];
                    for c in 0..cc {
                        scr.arow[c] = -dist2(qrow, &scr.mu[c * d_h..][..d_h]) / tau;
                    }
                    ops::attn_rows(&mut scr.arow, cc, AttnFn::Softmax);
                    for (dst, &a) in agchunk[i * cc..][..cc].iter_mut().zip(&scr.arow) {
                        *dst += a * inv_h;
                    }
                }
                // per-cluster top-k sets from the centroid's attention row
                for c in 0..cc {
                    let murow = &scr.mu[c * d_h..][..d_h];
                    for j in 0..n {
                        let krow = &k[(bb * n + j) * d + hh * d_h..][..d_h];
                        scr.pre[j] = ops::dot(murow, krow) / tau;
                    }
                    scr.post.copy_from_slice(&scr.pre);
                    ops::attn_rows(&mut scr.post, n, attn);
                    ops::top_k_desc(&scr.post, kp, &mut scr.idx);
                    let dst = &mut pchunk[topk_off(dims, kp, hh, c)..][..kp];
                    for (slot, &t) in dst.iter_mut().zip(&scr.idx) {
                        *slot = t as u32;
                    }
                }
            }
        },
    );
}

struct AttendScratch {
    mu: Vec<f32>,
    sum: Vec<f32>,
    cnt: Vec<usize>,
    pre: Vec<f32>,
    p: Vec<f32>,
    m: Vec<f32>,
    cent: Vec<f32>,
    tops: Vec<f32>,
    e_pre: Vec<f32>,
    e: Vec<f32>,
    w: Vec<f32>,
}

fn attend_scratch(dims: &Dims, kp: usize) -> AttendScratch {
    let (n, d_h, cc) = (dims.n, dims.d_h, dims.n_c);
    AttendScratch {
        mu: vec![0.0; cc * d_h],
        sum: vec![0.0; cc * d_h],
        cnt: vec![0; cc],
        pre: vec![0.0; cc * n],
        p: vec![0.0; cc * n],
        m: vec![0.0; cc],
        cent: vec![0.0; cc * d_h],
        tops: vec![0.0; cc * d_h],
        e_pre: vec![0.0; kp],
        e: vec![0.0; kp],
        w: vec![0.0; d_h],
    }
}

/// Recompute the per-cluster statistics of one (batch, head) from the
/// plan: centroids (means of final members), the centroid attention
/// rows `p_c`, and the corrected mass `m_c`.  Only non-empty clusters
/// are filled — empty ones own no tokens and are never read.
#[allow(clippy::too_many_arguments)]
fn cluster_stats(
    q: &[f32],
    k: &[f32],
    bb: usize,
    hh: usize,
    dims: &Dims,
    kp: usize,
    assign: &[u32],
    pchunk: &[u32],
    scr: &mut AttendScratch,
) {
    let (n, d_h, cc) = (dims.n, dims.d_h, dims.n_c);
    let d = dims.d();
    let tau = (d_h as f32).sqrt();
    means_from_assign(q, bb, hh, dims, assign, &mut scr.sum, &mut scr.cnt, &mut scr.mu);
    for c in 0..cc {
        if scr.cnt[c] == 0 {
            continue;
        }
        let murow = &scr.mu[c * d_h..][..d_h];
        let pre = &mut scr.pre[c * n..][..n];
        for (j, dst) in pre.iter_mut().enumerate() {
            let krow = &k[(bb * n + j) * d + hh * d_h..][..d_h];
            *dst = ops::dot(murow, krow) / tau;
        }
        let prow = &mut scr.p[c * n..][..n];
        prow.copy_from_slice(&scr.pre[c * n..][..n]);
        ops::attn_rows(prow, n, dims.attn);
        let mut mass = 0.0f32;
        for &t in &pchunk[topk_off(dims, kp, hh, c)..][..kp] {
            mass += scr.p[c * n + t as usize];
        }
        scr.m[c] = mass;
    }
}

/// Pass 2: the attention itself.  `r` gets the pre-output-projection
/// mix; parallel over batch elements, everything inside sequential.
fn attend_clustered(
    r: &mut [f32],
    q: &[f32],
    k: &[f32],
    v: &[f32],
    plan: &[u32],
    dims: &Dims,
    kp: usize,
) {
    let (n, h, d_h, cc) = (dims.n, dims.heads, dims.d_h, dims.n_c);
    let d = dims.d();
    let tau = (d_h as f32).sqrt();
    let stride = plan_stride(dims, kp);
    parallel::par_chunks_mut_with(
        r,
        n * d,
        || attend_scratch(dims, kp),
        |scr, bb, chunk| {
            let pchunk = &plan[bb * stride..][..stride];
            for hh in 0..h {
                let assign = &pchunk[hh * n..][..n];
                cluster_stats(q, k, bb, hh, dims, kp, assign, pchunk, scr);
                for c in 0..cc {
                    if scr.cnt[c] == 0 {
                        continue;
                    }
                    let cent = &mut scr.cent[c * d_h..][..d_h];
                    cent.iter_mut().for_each(|x| *x = 0.0);
                    for j in 0..n {
                        let vrow = &v[(bb * n + j) * d + hh * d_h..][..d_h];
                        simd::axpy8(cent, scr.p[c * n + j], vrow);
                    }
                    let tops = &mut scr.tops[c * d_h..][..d_h];
                    tops.iter_mut().for_each(|x| *x = 0.0);
                    for &t in &pchunk[topk_off(dims, kp, hh, c)..][..kp] {
                        let vrow = &v[(bb * n + t as usize) * d + hh * d_h..][..d_h];
                        simd::axpy8(tops, scr.p[c * n + t as usize], vrow);
                    }
                }
                for (i, &a) in assign.iter().enumerate() {
                    let c = a as usize;
                    let qrow = &q[(bb * n + i) * d + hh * d_h..][..d_h];
                    let tset = &pchunk[topk_off(dims, kp, hh, c)..][..kp];
                    for (dst, &t) in scr.e_pre.iter_mut().zip(tset) {
                        let krow = &k[(bb * n + t as usize) * d + hh * d_h..][..d_h];
                        *dst = ops::dot(qrow, krow) / tau;
                    }
                    scr.e.copy_from_slice(&scr.e_pre);
                    ops::attn_rows(&mut scr.e, kp, dims.attn);
                    scr.w.iter_mut().for_each(|x| *x = 0.0);
                    for (jj, &t) in tset.iter().enumerate() {
                        let vrow = &v[(bb * n + t as usize) * d + hh * d_h..][..d_h];
                        simd::axpy8(&mut scr.w, scr.e[jj], vrow);
                    }
                    let out = &mut chunk[i * d + hh * d_h..][..d_h];
                    let cent = &scr.cent[c * d_h..][..d_h];
                    let tops = &scr.tops[c * d_h..][..d_h];
                    let m = scr.m[c];
                    for (l, dst) in out.iter_mut().enumerate() {
                        *dst = m * scr.w[l] + cent[l] - tops[l];
                    }
                }
            }
        },
    );
}

type ForwardCore = (Vec<f32>, Vec<f32>, Vec<u32>, usize);

fn forward_core(p: &BaselineParams, x: &[f32], dims: &Dims) -> Result<ForwardCore> {
    let rows = dims.b * dims.n;
    let d = dims.d();
    ensure!(x.len() == rows * d, "clustered layer input shape");
    ensure!(dims.n_c >= 1 && dims.kappa >= 1, "clustered layer needs n_c >= 1 and kappa >= 1");
    let kp = top_width(dims);
    let q = ops::dense(x, p.wq_w, p.wq_b, rows, d, d);
    let k = ops::dense(x, p.wk_w, p.wk_b, rows, d, d);
    let v = ops::dense(x, p.wv_w, p.wv_b, rows, d, d);
    let mut plan = vec![0u32; dims.b * plan_stride(dims, kp)];
    let mut ag = vec![0.0f32; dims.b * dims.n * dims.n_c];
    compute_plan_and_ag(&q, &k, dims, kp, &mut plan, &mut ag);
    let mut r = vec![0.0f32; rows * d];
    attend_clustered(&mut r, &q, &k, &v, &plan, dims, kp);
    let out = ops::dense(&r, p.wo_w, p.wo_b, rows, d, d);
    Ok((out, ag, plan, kp))
}

/// Forward of the `clustered` layer: returns the output and the
/// head-averaged cluster affinity matrix `A_g` (B·N × n_c, rows sum
/// to 1).
pub fn clustered_layer(p: &BaselineParams, x: &[f32], dims: &Dims) -> Result<(Vec<f32>, Vec<f32>)> {
    let (out, ag, _, _) = forward_core(p, x, dims)?;
    Ok((out, ag))
}

/// Forward intermediates of one clustered layer: the input plus the
/// fused discrete plan (assignments and top-k sets, straight-through in
/// the backward).  Everything smooth is recomputed.
pub struct ClusteredTape {
    pub x: Vec<f32>,
    plan: Vec<u32>,
    kp: usize,
}

impl ClusteredTape {
    /// Folds the discrete plan so gradient checks can skip perturbations
    /// that flip an assignment or a top-k set.
    pub fn fingerprint(&self) -> u64 {
        let mut hsh = fnv_fold(FNV_SEED, self.kp as u64);
        for &u in &self.plan {
            hsh = fnv_fold(hsh, u as u64);
        }
        hsh
    }
}

/// Forward pass that also captures the tape for [`clustered_backward`].
pub fn clustered_forward_tape(
    p: &BaselineParams,
    x: &[f32],
    dims: &Dims,
) -> Result<(Vec<f32>, ClusteredTape)> {
    let (out, _, plan, kp) = forward_core(p, x, dims)?;
    Ok((out, ClusteredTape { x: x.to_vec(), plan, kp }))
}

struct BwdScratch {
    att: AttendScratch,
    gclu: Vec<f32>,
    dm: Vec<f32>,
    de: Vec<f32>,
    du: Vec<f32>,
    dp: Vec<f32>,
    ds: Vec<f32>,
    dmu: Vec<f32>,
}

fn bwd_scratch(dims: &Dims, kp: usize) -> BwdScratch {
    let (n, d_h, cc) = (dims.n, dims.d_h, dims.n_c);
    BwdScratch {
        att: attend_scratch(dims, kp),
        gclu: vec![0.0; cc * d_h],
        dm: vec![0.0; cc],
        de: vec![0.0; kp],
        du: vec![0.0; kp],
        dp: vec![0.0; n],
        ds: vec![0.0; n],
        dmu: vec![0.0; d_h],
    }
}

/// Exact reverse pass with the discrete plan held fixed
/// (straight-through).  The parallel grain is one batch element's fused
/// `dq|dk|dv` row slab, same idiom as `window_backward`.
pub fn clustered_backward(
    p: &BaselineParams,
    tape: &ClusteredTape,
    dims: &Dims,
    d_out: &[f32],
    dx: &mut [f32],
    g: &mut BaselineGradRefs,
) -> Result<()> {
    let (b, n, h, d_h, cc) = (dims.b, dims.n, dims.heads, dims.d_h, dims.n_c);
    let d = dims.d();
    let rows = b * n;
    let kp = tape.kp;
    let x: &[f32] = &tape.x;
    ensure!(kp == top_width(dims), "clustered tape does not match dims");
    ensure!(d_out.len() == rows * d && dx.len() == rows * d, "clustered backward shape");
    let tau = (d_h as f32).sqrt();
    let attn = dims.attn;
    let stride = plan_stride(dims, kp);

    let q = ops::dense(x, p.wq_w, p.wq_b, rows, d, d);
    let k = ops::dense(x, p.wk_w, p.wk_b, rows, d, d);
    let v = ops::dense(x, p.wv_w, p.wv_b, rows, d, d);
    let mut r = vec![0.0f32; rows * d];
    attend_clustered(&mut r, &q, &k, &v, &tape.plan, dims, kp);

    let mut dr = vec![0.0f32; rows * d];
    gops::dense_grad_input_acc(d_out, p.wo_w, rows, d, d, &mut dr);
    gops::dense_grad_params(&r, d_out, rows, d, d, g.wo_w, g.wo_b);
    let dr_s: &[f32] = &dr;
    let (q_s, k_s, v_s): (&[f32], &[f32], &[f32]) = (&q, &k, &v);
    let plan: &[u32] = &tape.plan;

    let mut dqkv = vec![0.0f32; rows * 3 * d];
    parallel::par_chunks_mut_with(
        dqkv.as_mut_slice(),
        n * 3 * d,
        || bwd_scratch(dims, kp),
        |scr, bb, slab| {
            let pchunk = &plan[bb * stride..][..stride];
            for hh in 0..h {
                let assign = &pchunk[hh * n..][..n];
                cluster_stats(q_s, k_s, bb, hh, dims, kp, assign, pchunk, &mut scr.att);
                scr.gclu.iter_mut().for_each(|x| *x = 0.0);
                scr.dm.iter_mut().for_each(|x| *x = 0.0);
                // token loop: the exact-correction part, plus the
                // accumulators the cluster loop below consumes
                for (i, &a) in assign.iter().enumerate() {
                    let c = a as usize;
                    let m = scr.att.m[c];
                    let qrow = &q_s[(bb * n + i) * d + hh * d_h..][..d_h];
                    let tset = &pchunk[topk_off(dims, kp, hh, c)..][..kp];
                    for (dst, &t) in scr.att.e_pre.iter_mut().zip(tset) {
                        let krow = &k_s[(bb * n + t as usize) * d + hh * d_h..][..d_h];
                        *dst = ops::dot(qrow, krow) / tau;
                    }
                    scr.att.e.copy_from_slice(&scr.att.e_pre);
                    ops::attn_rows(&mut scr.att.e, kp, attn);
                    scr.att.w.iter_mut().for_each(|x| *x = 0.0);
                    for (jj, &t) in tset.iter().enumerate() {
                        let vrow = &v_s[(bb * n + t as usize) * d + hh * d_h..][..d_h];
                        simd::axpy8(&mut scr.att.w, scr.att.e[jj], vrow);
                    }
                    let dro = &dr_s[(bb * n + i) * d + hh * d_h..][..d_h];
                    simd::add8(&mut scr.gclu[c * d_h..][..d_h], dro);
                    scr.dm[c] += ops::dot(dro, &scr.att.w);
                    for (jj, &t) in tset.iter().enumerate() {
                        let vrow = &v_s[(bb * n + t as usize) * d + hh * d_h..][..d_h];
                        scr.de[jj] = m * ops::dot(dro, vrow);
                    }
                    scr.du.iter_mut().for_each(|x| *x = 0.0);
                    gops::attn_rows_backward(
                        &scr.att.e_pre,
                        &scr.att.e,
                        &scr.de,
                        kp,
                        attn,
                        &mut scr.du,
                    );
                    for (jj, &t) in tset.iter().enumerate() {
                        let t = t as usize;
                        let coef = scr.du[jj] / tau;
                        let krow = &k_s[(bb * n + t) * d + hh * d_h..][..d_h];
                        simd::axpy8(&mut slab[i * 3 * d + hh * d_h..][..d_h], coef, krow);
                        simd::axpy8(&mut slab[t * 3 * d + d + hh * d_h..][..d_h], coef, qrow);
                        let dv_row = &mut slab[t * 3 * d + 2 * d + hh * d_h..][..d_h];
                        simd::axpy8(dv_row, m * scr.att.e[jj], dro);
                    }
                }
                // cluster loop: centroid tail, corrected mass, and the
                // straight-through mean gradient back to member queries
                for c in 0..cc {
                    if scr.att.cnt[c] == 0 {
                        continue;
                    }
                    let gc = &scr.gclu[c * d_h..][..d_h];
                    let prow = &scr.att.p[c * n..][..n];
                    for (j, dst) in scr.dp.iter_mut().enumerate() {
                        let vrow = &v_s[(bb * n + j) * d + hh * d_h..][..d_h];
                        *dst = ops::dot(gc, vrow);
                        simd::axpy8(&mut slab[j * 3 * d + 2 * d + hh * d_h..][..d_h], prow[j], gc);
                    }
                    for &t in &pchunk[topk_off(dims, kp, hh, c)..][..kp] {
                        let t = t as usize;
                        let vrow = &v_s[(bb * n + t) * d + hh * d_h..][..d_h];
                        scr.dp[t] -= ops::dot(gc, vrow);
                        simd::axpy8(&mut slab[t * 3 * d + 2 * d + hh * d_h..][..d_h], -prow[t], gc);
                        scr.dp[t] += scr.dm[c];
                    }
                    scr.ds.iter_mut().for_each(|x| *x = 0.0);
                    gops::attn_rows_backward(
                        &scr.att.pre[c * n..][..n],
                        prow,
                        &scr.dp,
                        n,
                        attn,
                        &mut scr.ds,
                    );
                    let murow = &scr.att.mu[c * d_h..][..d_h];
                    scr.dmu.iter_mut().for_each(|x| *x = 0.0);
                    for (j, &dsv) in scr.ds.iter().enumerate() {
                        if dsv == 0.0 {
                            continue;
                        }
                        let coef = dsv / tau;
                        let krow = &k_s[(bb * n + j) * d + hh * d_h..][..d_h];
                        simd::axpy8(&mut scr.dmu, coef, krow);
                        simd::axpy8(&mut slab[j * 3 * d + d + hh * d_h..][..d_h], coef, murow);
                    }
                    let inv_cnt = 1.0 / scr.att.cnt[c] as f32;
                    for (i, &a) in assign.iter().enumerate() {
                        if a as usize == c {
                            let dq_row = &mut slab[i * 3 * d + hh * d_h..][..d_h];
                            simd::axpy8(dq_row, inv_cnt, &scr.dmu);
                        }
                    }
                }
            }
        },
    );

    qkv_slab_project_backward(p, x, &dqkv, rows, d, g, dx);
    Ok(())
}

/// Unpack a fused `dq|dk|dv` row slab and run the three projection
/// backwards.  Shared by the clustered and tost backward passes (same
/// idiom as `window_backward`'s tail).
pub(crate) fn qkv_slab_project_backward(
    p: &BaselineParams,
    x: &[f32],
    dqkv: &[f32],
    rows: usize,
    d: usize,
    g: &mut BaselineGradRefs,
    dx: &mut [f32],
) {
    let blk = parallel::row_block(rows);
    let mut dq = vec![0.0f32; rows * d];
    let mut dk = vec![0.0f32; rows * d];
    let mut dv = vec![0.0f32; rows * d];
    for (off, buf) in [(0usize, &mut dq), (d, &mut dk), (2 * d, &mut dv)] {
        parallel::par_chunks_mut(buf.as_mut_slice(), blk * d, |ci, chunk| {
            let r0 = ci * blk;
            for (rr, dst) in chunk.chunks_mut(d).enumerate() {
                dst.copy_from_slice(&dqkv[(r0 + rr) * 3 * d + off..][..d]);
            }
        });
    }
    gops::dense_grad_params(x, &dq, rows, d, d, g.wq_w, g.wq_b);
    gops::dense_grad_input_acc(&dq, p.wq_w, rows, d, d, dx);
    gops::dense_grad_params(x, &dk, rows, d, d, g.wk_w, g.wk_b);
    gops::dense_grad_input_acc(&dk, p.wk_w, rows, d, d, dx);
    gops::dense_grad_params(x, &dv, rows, d, d, g.wv_w, g.wv_b);
    gops::dense_grad_input_acc(&dv, p.wv_w, rows, d, d, dx);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::native::layer::vanilla_layer;
    use crate::util::prop::{assert_grads_close, GradCheckCfg};
    use crate::util::rng::Rng;

    fn dims(attn: AttnFn, kappa: usize) -> Dims {
        Dims {
            b: 2,
            n: 8,
            heads: 2,
            d_h: 4,
            n_c: 2,
            kappa,
            attn,
            clustering: "topk".to_string(),
            causal: false,
            window: 4,
        }
    }

    fn layer_cfg() -> GradCheckCfg {
        GradCheckCfg { eps: 1e-2, rel_tol: 1e-2, abs_tol: 1e-3, max_per_block: 8 }
    }

    fn randn(rng: &mut Rng, n: usize, scale: f32) -> Vec<f32> {
        (0..n).map(|_| rng.gaussian() as f32 * scale).collect()
    }

    fn lens(d: usize) -> Vec<(String, usize)> {
        vec![
            ("wq.w".into(), d * d),
            ("wq.b".into(), d),
            ("wk.w".into(), d * d),
            ("wk.b".into(), d),
            ("wv.w".into(), d * d),
            ("wv.b".into(), d),
            ("wo.w".into(), d * d),
            ("wo.b".into(), d),
        ]
    }

    fn random_theta(rng: &mut Rng, lens: &[(String, usize)], d: usize) -> Vec<f32> {
        let scale = 1.0 / (d as f32).sqrt();
        let mut theta = Vec::new();
        for (name, len) in lens {
            let s = if name.ends_with(".b") { 0.1 } else { scale };
            theta.extend(randn(rng, *len, s));
        }
        theta
    }

    fn split<'a>(t: &'a [f32], lens: &[usize]) -> Vec<&'a [f32]> {
        let mut out = Vec::with_capacity(lens.len());
        let mut off = 0usize;
        for &l in lens {
            out.push(&t[off..off + l]);
            off += l;
        }
        out
    }

    fn params_of<'a>(parts: &[&'a [f32]]) -> BaselineParams<'a> {
        BaselineParams {
            wq_w: parts[0],
            wq_b: parts[1],
            wk_w: parts[2],
            wk_b: parts[3],
            wv_w: parts[4],
            wv_b: parts[5],
            wo_w: parts[6],
            wo_b: parts[7],
        }
    }

    fn analytic_grads(
        theta: &[f32],
        lens_only: &[usize],
        x: &[f32],
        c: &[f32],
        dm: &Dims,
    ) -> (Vec<f32>, Vec<f32>) {
        let parts = split(theta, lens_only);
        let p = params_of(&parts);
        let mut gbufs: Vec<Vec<f32>> = lens_only.iter().map(|&l| vec![0.0; l]).collect();
        let mut dx = vec![0.0f32; x.len()];
        let [wq_w, wq_b, wk_w, wk_b, wv_w, wv_b, wo_w, wo_b] = &mut gbufs[..] else {
            unreachable!()
        };
        let mut g = BaselineGradRefs {
            wq_w: wq_w.as_mut_slice(),
            wq_b: wq_b.as_mut_slice(),
            wk_w: wk_w.as_mut_slice(),
            wk_b: wk_b.as_mut_slice(),
            wv_w: wv_w.as_mut_slice(),
            wv_b: wv_b.as_mut_slice(),
            wo_w: wo_w.as_mut_slice(),
            wo_b: wo_b.as_mut_slice(),
        };
        let (_, tape) = clustered_forward_tape(&p, x, dm).unwrap();
        clustered_backward(&p, &tape, dm, c, &mut dx, &mut g).unwrap();
        (gbufs.concat(), dx)
    }

    #[test]
    fn kappa_at_least_n_matches_vanilla_attention() {
        // with κ ≥ N every cluster's correction set covers all keys:
        // the centroid tail cancels and each token attends exactly —
        // the layer must reproduce vanilla attention (up to fp
        // summation order, the top-k set is a permutation of 0..N)
        for attn in [AttnFn::Softmax, AttnFn::Laplace] {
            let dm = dims(attn, 8);
            let d = dm.d();
            let mut rng = Rng::new(71);
            let ls = lens(d);
            let lens_only: Vec<usize> = ls.iter().map(|(_, l)| *l).collect();
            let theta = random_theta(&mut rng, &ls, d);
            let x = randn(&mut rng, dm.b * dm.n * d, 1.0);
            let parts = split(&theta, &lens_only);
            let p = params_of(&parts);
            let (got, _) = clustered_layer(&p, &x, &dm).unwrap();
            let want = vanilla_layer(&p, &x, &dm).unwrap();
            for (a, b) in got.iter().zip(&want) {
                assert!((a - b).abs() < 1e-3, "clustered(κ=N) {a} vs vanilla {b}");
            }
        }
    }

    #[test]
    fn affinity_rows_sum_to_one() {
        let dm = dims(AttnFn::Softmax, 4);
        let d = dm.d();
        let mut rng = Rng::new(73);
        let ls = lens(d);
        let lens_only: Vec<usize> = ls.iter().map(|(_, l)| *l).collect();
        let theta = random_theta(&mut rng, &ls, d);
        let x = randn(&mut rng, dm.b * dm.n * d, 1.0);
        let parts = split(&theta, &lens_only);
        let (out, ag) = clustered_layer(&params_of(&parts), &x, &dm).unwrap();
        assert!(out.iter().all(|v| v.is_finite()));
        assert_eq!(ag.len(), dm.b * dm.n * dm.n_c);
        for row in ag.chunks(dm.n_c) {
            let s: f32 = row.iter().sum();
            assert!((s - 1.0).abs() < 1e-5, "affinity row sums to {s}");
        }
    }

    #[test]
    fn plan_fingerprint_is_stable_and_input_sensitive() {
        let dm = dims(AttnFn::Softmax, 4);
        let d = dm.d();
        let mut rng = Rng::new(79);
        let ls = lens(d);
        let lens_only: Vec<usize> = ls.iter().map(|(_, l)| *l).collect();
        let theta = random_theta(&mut rng, &ls, d);
        let x = randn(&mut rng, dm.b * dm.n * d, 1.0);
        let parts = split(&theta, &lens_only);
        let p = params_of(&parts);
        let (_, t1) = clustered_forward_tape(&p, &x, &dm).unwrap();
        let (_, t2) = clustered_forward_tape(&p, &x, &dm).unwrap();
        assert_eq!(t1.fingerprint(), t2.fingerprint());
        let y = randn(&mut rng, x.len(), 1.0);
        let (_, t3) = clustered_forward_tape(&p, &y, &dm).unwrap();
        assert_ne!(t1.fingerprint(), t3.fingerprint());
    }

    #[test]
    fn parameter_gradients_match_central_difference() {
        for attn in [AttnFn::Softmax, AttnFn::Laplace] {
            let dm = dims(attn, 4);
            let d = dm.d();
            let rows = dm.b * dm.n;
            let mut rng = Rng::new(331);
            let ls = lens(d);
            let lens_only: Vec<usize> = ls.iter().map(|(_, l)| *l).collect();
            let theta = random_theta(&mut rng, &ls, d);
            let x = randn(&mut rng, rows * d, 1.0);
            let c = randn(&mut rng, rows * d, 0.5);
            let (analytic, _) = analytic_grads(&theta, &lens_only, &x, &c, &dm);
            assert_grads_close(&layer_cfg(), &theta, &ls, &analytic, |t| {
                let parts = split(t, &lens_only);
                let p = params_of(&parts);
                let (out, tape) = clustered_forward_tape(&p, &x, &dm).unwrap();
                (ops::dot(&c, &out), tape.fingerprint())
            });
        }
    }

    #[test]
    fn input_gradient_matches_central_difference() {
        let dm = dims(AttnFn::Softmax, 4);
        let d = dm.d();
        let rows = dm.b * dm.n;
        let mut rng = Rng::new(337);
        let ls = lens(d);
        let lens_only: Vec<usize> = ls.iter().map(|(_, l)| *l).collect();
        let theta = random_theta(&mut rng, &ls, d);
        let x = randn(&mut rng, rows * d, 1.0);
        let c = randn(&mut rng, rows * d, 0.5);
        let (_, dx) = analytic_grads(&theta, &lens_only, &x, &c, &dm);
        let blocks = vec![("x".to_string(), rows * d)];
        assert_grads_close(&layer_cfg(), &x, &blocks, &dx, |xt| {
            let parts = split(&theta, &lens_only);
            let p = params_of(&parts);
            let (out, tape) = clustered_forward_tape(&p, xt, &dm).unwrap();
            (ops::dot(&c, &out), tape.fingerprint())
        });
    }
}
