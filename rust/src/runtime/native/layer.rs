//! Native attention layers: the CAST layer (paper §3.1–3.3) and the three
//! baselines (vanilla / local / LSH), mirroring `python/compile/cast_layer.py`,
//! `clustering.py`, `attention_baselines.py`, and `kernels/ref.py`.
//!
//! Shapes are row-major flat `&[f32]`:
//!   x (B,N,d) · q/k/v (B,N,h·d_h) · A_g (B,N,Nc) · idx/valid (B,Nc,κ).
//!
//! Execution model (DESIGN.md §Threading): every hot loop is dispatched
//! over the `util::parallel` worker pool — per-row blocks for the
//! projections/affinities, the B×Nc cluster grid for the fused
//! intra-cluster attention, per-destination-token blocks for the
//! combination scatter, and per-batch shards for the baselines.  Each
//! task owns a disjoint `&mut` output chunk and per-worker scratch
//! buffers, and all reductions keep a fixed order, so the output is
//! bit-identical for any `CAST_NUM_THREADS`.

use anyhow::{ensure, Result};

use crate::util::parallel;
use crate::util::rng::Rng;
use crate::util::simd;
use crate::util::trace;

use super::ops::{self, AttnFn, NEG_INF};

/// Geometry + mechanism of one attention layer.
#[derive(Clone, Debug)]
pub struct Dims {
    pub b: usize,
    pub n: usize,
    pub heads: usize,
    pub d_h: usize,
    pub n_c: usize,
    pub kappa: usize,
    pub attn: AttnFn,
    /// "topk" | "sa" | "causal" (paper §3.2 / §5.5).
    pub clustering: String,
    pub causal: bool,
    pub window: usize,
}

impl Dims {
    pub fn d(&self) -> usize {
        self.heads * self.d_h
    }
}

/// Weights of one CAST attention layer (borrowed from the flat param list).
pub struct CastParams<'a> {
    pub wq_w: &'a [f32],
    pub wq_b: &'a [f32],
    pub wk_w: &'a [f32],
    pub wk_b: &'a [f32],
    pub wv_w: &'a [f32],
    pub wv_b: &'a [f32],
    pub wo_w: &'a [f32],
    pub wo_b: &'a [f32],
    /// Surrogate tokens S (Nc, h, d_h) — the learnable cluster directions.
    pub s: &'a [f32],
    pub phi_w: &'a [f32],
    pub phi_b: &'a [f32],
}

/// Weights of a baseline attention layer.
pub struct BaselineParams<'a> {
    pub wq_w: &'a [f32],
    pub wq_b: &'a [f32],
    pub wk_w: &'a [f32],
    pub wk_b: &'a [f32],
    pub wv_w: &'a [f32],
    pub wv_b: &'a [f32],
    pub wo_w: &'a [f32],
    pub wo_b: &'a [f32],
}

/// Reusable intermediate buffers for [`cast_layer`].  One instance per
/// model-forward (reused across depth layers and calls) removes the
/// per-layer-per-call `Vec` churn on the hot path; buffers are resized
/// lazily so one scratch serves any layer geometry.
///
/// Fields are crate-visible because after a forward the scratch *is* the
/// autograd tape: `grad::layer::CastTape::capture` snapshots exactly
/// these buffers (plus the layer input) for the reverse pass.
#[derive(Default)]
pub struct CastScratch {
    pub(crate) q: Vec<f32>,
    pub(crate) k: Vec<f32>,
    pub(crate) v: Vec<f32>,
    pub(crate) phi: Vec<f32>,
    pub(crate) a_q: Vec<f32>,
    pub(crate) a_k: Vec<f32>,
    pub(crate) a_q_raw: Vec<f32>,
    pub(crate) a_sum: Vec<f32>,
    pub(crate) r_intra: Vec<f32>,
    pub(crate) r_inter: Vec<f32>,
    pub(crate) r: Vec<f32>,
    pub(crate) slot_of: Vec<usize>,
    /// Cluster slot → token assignment (B, Nc, κ) from step 4.
    pub(crate) idx: Vec<usize>,
    /// 1.0 where the slot holds a real token, 0.0 for padding.
    pub(crate) valid: Vec<f32>,
}

impl CastScratch {
    pub fn new() -> CastScratch {
        CastScratch::default()
    }
}

/// Clear + zero-fill a reusable buffer (keeps its allocation).
fn zeroed<T: Copy + Default>(buf: &mut Vec<T>, len: usize) {
    buf.clear();
    buf.resize(len, T::default());
}

// ---------------------------------------------------------------------------
// clustering mechanisms G (clustering.py)
// ---------------------------------------------------------------------------

/// Algorithm 1 (Top-K): every cluster independently takes its κ
/// highest-affinity tokens; a token may land in several clusters or none.
/// Batch elements are sharded across the worker pool; the per-cluster
/// selection is O(N) quickselect instead of a full argsort.
pub fn top_k_cluster(
    a_g: &[f32],
    b: usize,
    n: usize,
    n_c: usize,
    kappa: usize,
) -> (Vec<usize>, Vec<f32>) {
    let mut idx = vec![0usize; b * n_c * kappa];
    let valid = vec![1.0f32; b * n_c * kappa];
    parallel::par_chunks_mut_with(
        idx.as_mut_slice(),
        n_c * kappa,
        || (vec![0.0f32; n], Vec::with_capacity(n)),
        |scr, bb, idx_b| {
            let (col, sel) = scr;
            for c in 0..n_c {
                for (nn, cv) in col.iter_mut().enumerate() {
                    *cv = a_g[(bb * n + nn) * n_c + c];
                }
                ops::top_k_desc(col, kappa, sel);
                idx_b[c * kappa..(c + 1) * kappa].copy_from_slice(&sel[..kappa]);
            }
        },
    );
    (idx, valid)
}

/// Per-batch scratch for the greedy assignment (reused across batches by
/// each worker, never reallocated per token).
#[derive(Default)]
struct GreedyScratch {
    /// Flat (N, Nc) preference table: row t = clusters by desc affinity.
    pref: Vec<usize>,
    best: Vec<f32>,
    order: Vec<usize>,
    fill: Vec<usize>,
}

/// Greedy capacity-constrained assignment shared by SA Top-K (visit order =
/// descending best affinity) and the causal variant (visit order = position).
/// The greedy scan is inherently sequential per batch element, so the
/// parallel grain is the batch dimension.
fn greedy_assign(
    a_g: &[f32],
    b: usize,
    n: usize,
    n_c: usize,
    kappa: usize,
    by_position: bool,
) -> (Vec<usize>, Vec<f32>) {
    let mut idx = vec![0usize; b * n_c * kappa];
    let mut valid = vec![0.0f32; b * n_c * kappa];
    parallel::par_zip2_mut_with(
        idx.as_mut_slice(),
        n_c * kappa,
        valid.as_mut_slice(),
        n_c * kappa,
        GreedyScratch::default,
        |scr, bb, idx_b, valid_b| {
            zeroed(&mut scr.pref, n * n_c);
            zeroed(&mut scr.best, n);
            zeroed(&mut scr.fill, n_c);
            scr.order.clear();
            for nn in 0..n {
                let arow = &a_g[(bb * n + nn) * n_c..(bb * n + nn + 1) * n_c];
                let prow = &mut scr.pref[nn * n_c..(nn + 1) * n_c];
                for (c, pv) in prow.iter_mut().enumerate() {
                    *pv = c;
                }
                prow.sort_unstable_by(|&x, &y| {
                    arow[y]
                        .partial_cmp(&arow[x])
                        .unwrap_or(std::cmp::Ordering::Equal)
                        .then(x.cmp(&y))
                });
                scr.best[nn] = arow.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            }
            scr.order.extend(0..n);
            if !by_position {
                let best = &scr.best;
                scr.order.sort_unstable_by(|&x, &y| {
                    best[y]
                        .partial_cmp(&best[x])
                        .unwrap_or(std::cmp::Ordering::Equal)
                        .then(x.cmp(&y))
                });
            }
            for &t in scr.order.iter() {
                let prow = &scr.pref[t * n_c..(t + 1) * n_c];
                if let Some(&c) = prow.iter().find(|&&c| scr.fill[c] < kappa) {
                    let slot = c * kappa + scr.fill[c];
                    idx_b[slot] = t;
                    valid_b[slot] = 1.0;
                    scr.fill[c] += 1;
                }
            }
        },
    );
    (idx, valid)
}

/// Algorithm 2 (SA Top-K): each token joins exactly one cluster, greedily
/// in descending order of its best affinity, subject to capacity.
pub fn sa_top_k_cluster(
    a_g: &[f32],
    b: usize,
    n: usize,
    n_c: usize,
    kappa: usize,
) -> (Vec<usize>, Vec<f32>) {
    greedy_assign(a_g, b, n, n_c, kappa, false)
}

/// Causal clustering (paper §5.5): assignment in *position* order, so
/// token n's cluster depends only on tokens 0..n.
pub fn causal_cluster(
    a_g: &[f32],
    b: usize,
    n: usize,
    n_c: usize,
    kappa: usize,
) -> (Vec<usize>, Vec<f32>) {
    greedy_assign(a_g, b, n, n_c, kappa, true)
}

/// The paper's membership mask M (B,N,Nc): 1 iff the token sits in the
/// cluster's slot list.
pub fn membership(
    idx: &[usize],
    valid: &[f32],
    b: usize,
    n: usize,
    n_c: usize,
    kappa: usize,
) -> Vec<f32> {
    let mut m = vec![0.0f32; b * n * n_c];
    for bb in 0..b {
        for c in 0..n_c {
            for slot in 0..kappa {
                let base = (bb * n_c + c) * kappa + slot;
                if valid[base] > 0.0 {
                    m[(bb * n + idx[base]) * n_c + c] = 1.0;
                }
            }
        }
    }
    m
}

fn cluster(
    mechanism: &str,
    a_g: &[f32],
    b: usize,
    n: usize,
    n_c: usize,
    kappa: usize,
) -> Result<(Vec<usize>, Vec<f32>)> {
    Ok(match mechanism {
        "topk" => top_k_cluster(a_g, b, n, n_c, kappa),
        "sa" => sa_top_k_cluster(a_g, b, n, n_c, kappa),
        "causal" => causal_cluster(a_g, b, n, n_c, kappa),
        other => anyhow::bail!("unknown clustering mechanism {other:?}"),
    })
}

// ---------------------------------------------------------------------------
// the CAST layer (cast_layer.py apply())
// ---------------------------------------------------------------------------

/// Full CAST attention layer.  Returns `(out (B,N,d), a_g (B,N,Nc))`.
/// `ws` carries the reusable intermediates (see [`CastScratch`]).
pub fn cast_layer(
    p: &CastParams,
    x: &[f32],
    dims: &Dims,
    ws: &mut CastScratch,
) -> Result<(Vec<f32>, Vec<f32>)> {
    let (b, n, h, d_h, n_c) = (dims.b, dims.n, dims.heads, dims.d_h, dims.n_c);
    let d = dims.d();
    let kappa = dims.kappa.min(n);
    ensure!(kappa > 0 && n_c > 0, "CAST needs n_c>0 and kappa>0");
    let rows = b * n;
    let tau = (d_h as f32).sqrt();
    let attn = dims.attn;
    let causal = dims.causal;
    let blk = parallel::row_block(rows);

    // step 1: projections (eq. 1) — row-parallel blocked matmuls
    let t = trace::span("attn.qkv_proj");
    ops::dense_into(x, p.wq_w, p.wq_b, rows, d, d, &mut ws.q);
    ops::dense_into(x, p.wk_w, p.wk_b, rows, d, d, &mut ws.k);
    ops::dense_into(x, p.wv_w, p.wv_b, rows, d, d, &mut ws.v);
    ops::dense_into(x, p.phi_w, p.phi_b, rows, d, 1, &mut ws.phi); // (B·N,)
    drop(t);

    let CastScratch {
        q,
        k,
        v,
        phi,
        a_q,
        a_k,
        a_q_raw,
        a_sum,
        r_intra,
        r_inter,
        r,
        slot_of,
        idx,
        valid,
    } = ws;
    let q: &[f32] = q.as_slice();
    let k: &[f32] = k.as_slice();
    let v: &[f32] = v.as_slice();
    let phi: &[f32] = phi.as_slice();

    // step 2: surrogate similarities A_q, A_k (eq. 6), per head, sharded
    // over row blocks
    let t = trace::span("attn.surrogate");
    zeroed(a_q, rows * h * n_c);
    zeroed(a_k, rows * h * n_c);
    let s = p.s;
    parallel::par_zip2_mut(
        a_q.as_mut_slice(),
        blk * h * n_c,
        a_k.as_mut_slice(),
        blk * h * n_c,
        |ci, aq, ak| {
            let r0 = ci * blk;
            for rr in 0..aq.len() / (h * n_c) {
                let rg = r0 + rr;
                for hh in 0..h {
                    let qrow = &q[rg * d + hh * d_h..][..d_h];
                    let krow = &k[rg * d + hh * d_h..][..d_h];
                    for c in 0..n_c {
                        let srow = &s[(c * h + hh) * d_h..][..d_h];
                        aq[(rr * h + hh) * n_c + c] = ops::dot(qrow, srow);
                        ak[(rr * h + hh) * n_c + c] = ops::dot(krow, srow);
                    }
                }
            }
        },
    );
    let a_q: &[f32] = a_q.as_slice();
    let a_k: &[f32] = a_k.as_slice();

    // step 3: head-summed raw similarities + gate
    // A_g = sigm(phi)·f2(ΣA_q) + (1-sigm(phi))·f2(ΣA_k); the f2 rows are
    // per-worker scratch (the k-sum is never materialized globally)
    zeroed(a_q_raw, rows * n_c);
    let mut a_g = vec![0.0f32; rows * n_c];
    parallel::par_zip2_mut_with(
        a_q_raw.as_mut_slice(),
        blk * n_c,
        a_g.as_mut_slice(),
        blk * n_c,
        || vec![0.0f32; 2 * n_c],
        |scr, ci, rawq, ag| {
            let (f2q, f2k) = scr.split_at_mut(n_c);
            let r0 = ci * blk;
            for rr in 0..rawq.len() / n_c {
                let rg = r0 + rr;
                let rq = &mut rawq[rr * n_c..(rr + 1) * n_c];
                for c in 0..n_c {
                    rq[c] = 0.0;
                    f2k[c] = 0.0;
                }
                for hh in 0..h {
                    for c in 0..n_c {
                        rq[c] += a_q[(rg * h + hh) * n_c + c];
                        f2k[c] += a_k[(rg * h + hh) * n_c + c];
                    }
                }
                f2q.copy_from_slice(rq);
                ops::attn_rows(f2q, n_c, attn);
                ops::attn_rows(f2k, n_c, attn);
                let g = ops::sigmoid(phi[rg]);
                let agrow = &mut ag[rr * n_c..(rr + 1) * n_c];
                for c in 0..n_c {
                    agrow[c] = g * f2q[c] + (1.0 - g) * f2k[c];
                }
            }
        },
    );
    let a_q_raw_s: &[f32] = a_q_raw.as_slice();
    drop(t);

    // step 4: clustering (indices are non-differentiable, paper §3.2);
    // the assignment stays in the scratch so the autograd tape sees it
    let t = trace::span("attn.cluster");
    let (idx_new, valid_new) = cluster(&dims.clustering, &a_g, b, n, n_c, kappa)?;
    *idx = idx_new;
    *valid = valid_new;

    // reverse map token→slot (+1; 0 = not a member) so the combination
    // scatter can run token-parallel with disjoint writes
    zeroed(slot_of, rows * n_c);
    for bb in 0..b {
        for c in 0..n_c {
            for slot in 0..kappa {
                let base = (bb * n_c + c) * kappa + slot;
                if valid[base] > 0.0 {
                    slot_of[(bb * n + idx[base]) * n_c + c] = slot + 1;
                }
            }
        }
    }

    drop(t);
    // step 5: fused intra-cluster attention + cluster summaries (eq. 3/4),
    // one task per (batch, cluster) cell with per-worker κ×κ scratch
    let t = trace::span("attn.av");
    zeroed(r_intra, b * n_c * kappa * d);
    zeroed(r_inter, b * n_c * d);
    let idx_s: &[usize] = idx.as_slice();
    let valid_s: &[f32] = valid.as_slice();
    parallel::par_zip2_mut_with(
        r_intra.as_mut_slice(),
        kappa * d,
        r_inter.as_mut_slice(),
        d,
        || (vec![0.0f32; kappa * kappa], vec![0.0f32; kappa]),
        |scr, cell, intra, inter| {
            let (scores, wrow) = scr;
            let bb = cell / n_c;
            let c = cell % n_c;
            let base = (bb * n_c + c) * kappa;
            let slots = &idx_s[base..base + kappa];
            let val = &valid_s[base..base + kappa];
            let mask_ij = |i: usize, j: usize| -> f32 {
                if causal && slots[j] > slots[i] {
                    0.0
                } else {
                    val[j]
                }
            };
            for hh in 0..h {
                // masked κ×κ scores: f(Q_g K_gᵀ / τ)
                for i in 0..kappa {
                    let qrow = &q[(bb * n + slots[i]) * d + hh * d_h..][..d_h];
                    for j in 0..kappa {
                        let krow = &k[(bb * n + slots[j]) * d + hh * d_h..][..d_h];
                        scores[i * kappa + j] =
                            ops::dot(qrow, krow) / tau + (1.0 - mask_ij(i, j)) * NEG_INF;
                    }
                }
                ops::attn_rows(scores.as_mut_slice(), kappa, attn);
                for i in 0..kappa {
                    if val[i] == 0.0 {
                        continue; // padding rows stay zero (· valid)
                    }
                    let out0 = i * d + hh * d_h;
                    for j in 0..kappa {
                        let pij = scores[i * kappa + j] * mask_ij(i, j);
                        if pij != 0.0 {
                            let vrow = &v[(bb * n + slots[j]) * d + hh * d_h..][..d_h];
                            simd::axpy8(&mut intra[out0..out0 + d_h], pij, vrow);
                        }
                    }
                }
                // eq. 4: cluster summary R_inter (omitted in causal mode —
                // summaries would leak future tokens)
                if !causal {
                    for j in 0..kappa {
                        let t = slots[j];
                        wrow[j] = a_k[((bb * n + t) * h + hh) * n_c + c]
                            * ops::softplus1(-phi[bb * n + t])
                            / tau
                            + (1.0 - val[j]) * NEG_INF;
                    }
                    ops::attn_rows(wrow.as_mut_slice(), kappa, attn);
                    let out0 = hh * d_h;
                    for j in 0..kappa {
                        let pk = wrow[j] * val[j];
                        if pk != 0.0 {
                            let vrow = &v[(bb * n + slots[j]) * d + hh * d_h..][..d_h];
                            simd::axpy8(&mut inter[out0..out0 + d_h], pk, vrow);
                        }
                    }
                }
            }
        },
    );

    drop(t);
    // step 6a: combination weights A_sum (eq. 5), row-parallel
    let t = trace::span("attn.combine");
    zeroed(a_sum, rows * n_c);
    parallel::par_chunks_mut(a_sum.as_mut_slice(), blk * n_c, |ci, chunk| {
        let r0 = ci * blk;
        for rr in 0..chunk.len() / n_c {
            let rg = r0 + rr;
            let sp = ops::softplus1(phi[rg]) / tau;
            let rowc = &mut chunk[rr * n_c..(rr + 1) * n_c];
            for (c, rv) in rowc.iter_mut().enumerate() {
                *rv = a_q_raw_s[rg * n_c + c] * sp;
            }
        }
        ops::attn_rows(chunk, n_c, attn);
    });

    // step 6b: gather per destination token (disjoint writes; contribution
    // order per token is fixed — intra over c ascending, then summaries of
    // *other* clusters weighted by off-membership A_sum)
    let a_sum_s: &[f32] = a_sum.as_slice();
    let slot_s: &[usize] = slot_of.as_slice();
    let r_intra_s: &[f32] = r_intra.as_slice();
    let r_inter_s: &[f32] = r_inter.as_slice();
    zeroed(r, rows * d);
    parallel::par_chunks_mut(r.as_mut_slice(), blk * d, |ci, chunk| {
        let r0 = ci * blk;
        for (rr, dst) in chunk.chunks_mut(d).enumerate() {
            let gr = r0 + rr;
            let bb = gr / n;
            for c in 0..n_c {
                let slot = slot_s[gr * n_c + c];
                if slot > 0 {
                    let wi = a_sum_s[gr * n_c + c];
                    if wi != 0.0 {
                        let src = ((bb * n_c + c) * kappa + (slot - 1)) * d;
                        simd::axpy8(dst, wi, &r_intra_s[src..src + d]);
                    }
                }
            }
            if !causal {
                for c in 0..n_c {
                    if slot_s[gr * n_c + c] == 0 {
                        let ai = a_sum_s[gr * n_c + c];
                        if ai != 0.0 {
                            let src = (bb * n_c + c) * d;
                            simd::axpy8(dst, ai, &r_inter_s[src..src + d]);
                        }
                    }
                }
            }
        }
    });

    drop(t);
    let t = trace::span("attn.out_proj");
    let out = ops::dense(r.as_slice(), p.wo_w, p.wo_b, rows, d, d);
    drop(t);
    Ok((out, a_g))
}

// ---------------------------------------------------------------------------
// baselines (attention_baselines.py)
// ---------------------------------------------------------------------------

/// Row-parallel attention over per-row key windows — the shared core of
/// the vanilla (`window = None`: full sequence) and local (`Some(w)`:
/// enclosing non-overlapping window) baselines.  Scores live in
/// per-worker scratch (O(window), not O(N²)) and honor `attn` (the
/// baselines used to hardcode softmax, silently ignoring laplace configs).
/// Crate-visible so the autograd tape (`grad::layer`) can recompute the
/// pre-projection attention output instead of storing it.
#[allow(clippy::too_many_arguments)]
pub(crate) fn attend_windows(
    out: &mut [f32],
    q: &[f32],
    k: &[f32],
    v: &[f32],
    b: usize,
    n: usize,
    h: usize,
    d_h: usize,
    window: Option<usize>,
    attn: AttnFn,
) {
    let d = h * d_h;
    let tau = (d_h as f32).sqrt();
    let rows = b * n;
    let max_w = window.unwrap_or(n);
    let blk = parallel::row_block(rows);
    parallel::par_chunks_mut_with(
        out,
        blk * d,
        || vec![0.0f32; max_w],
        |scores, ci, chunk| {
            let r0 = ci * blk;
            for (rr, dst) in chunk.chunks_mut(d).enumerate() {
                let gr = r0 + rr;
                let (bb, i) = (gr / n, gr % n);
                let (lo, hi) = match window {
                    Some(w) => ((i / w) * w, (i / w) * w + w),
                    None => (0, n),
                };
                let wlen = hi - lo;
                let sc = &mut scores[..wlen];
                for hh in 0..h {
                    let qrow = &q[(bb * n + i) * d + hh * d_h..][..d_h];
                    for (jj, sv) in sc.iter_mut().enumerate() {
                        let krow = &k[(bb * n + lo + jj) * d + hh * d_h..][..d_h];
                        *sv = ops::dot(qrow, krow) / tau;
                    }
                    ops::attn_rows(sc, wlen, attn);
                    let dsth = &mut dst[hh * d_h..(hh + 1) * d_h];
                    for (jj, &pj) in sc.iter().enumerate() {
                        let vrow = &v[(bb * n + lo + jj) * d + hh * d_h..][..d_h];
                        simd::axpy8(dsth, pj, vrow);
                    }
                }
            }
        },
    );
}

/// The original O(N²) multi-head self-attention.
pub fn vanilla_layer(p: &BaselineParams, x: &[f32], dims: &Dims) -> Result<Vec<f32>> {
    let (b, n, h, d_h) = (dims.b, dims.n, dims.heads, dims.d_h);
    let d = dims.d();
    let rows = b * n;
    let q = ops::dense(x, p.wq_w, p.wq_b, rows, d, d);
    let k = ops::dense(x, p.wk_w, p.wk_b, rows, d, d);
    let v = ops::dense(x, p.wv_w, p.wv_b, rows, d, d);
    let mut out = vec![0.0f32; rows * d];
    attend_windows(&mut out, &q, &k, &v, b, n, h, d_h, None, dims.attn);
    Ok(ops::dense(&out, p.wo_w, p.wo_b, rows, d, d))
}

/// LRA's Local Attention: full attention within non-overlapping windows.
pub fn local_layer(p: &BaselineParams, x: &[f32], dims: &Dims) -> Result<Vec<f32>> {
    let (b, n, h, d_h) = (dims.b, dims.n, dims.heads, dims.d_h);
    let w = dims.window.min(n).max(1);
    ensure!(n % w == 0, "local attention needs seq_len % window == 0 ({n} % {w})");
    let d = dims.d();
    let rows = b * n;
    let q = ops::dense(x, p.wq_w, p.wq_b, rows, d, d);
    let k = ops::dense(x, p.wk_w, p.wk_b, rows, d, d);
    let v = ops::dense(x, p.wv_w, p.wv_b, rows, d, d);
    let mut out = vec![0.0f32; rows * d];
    attend_windows(&mut out, &q, &k, &v, b, n, h, d_h, Some(w), dims.attn);
    Ok(ops::dense(&out, p.wo_w, p.wo_b, rows, d, d))
}

/// Per-batch scratch for the LSH baseline (bucket-sorted token copies).
struct LshScratch {
    qk_s: Vec<f32>,
    v_s: Vec<f32>,
    chunk_out: Vec<f32>,
    scores: Vec<f32>,
}

/// Bucket-sorted token order of the LSH baseline: random-rotation
/// hashing into Nc buckets (fixed pseudorandom rotation — python uses
/// PRNGKey(0); a fixed draw keeps the layer deterministic), then a
/// stable ascending per-batch sort by bucket (ties keep sequence
/// order).  Returns the flat (B, N) order.  Crate-visible so the
/// autograd tape treats the (non-differentiable) sort as a constant and
/// shares this exact code with the forward.
pub(crate) fn lsh_sort_order(qk: &[f32], b: usize, n: usize, d: usize, n_c: usize) -> Vec<usize> {
    let rows = b * n;
    let rc = (n_c / 2).max(1);
    let mut rng = Rng::new(0);
    let rot: Vec<f32> = (0..d * rc).map(|_| rng.gaussian() as f32).collect();

    // bucket = argmax over [xR ; -xR], row-parallel
    let mut buckets = vec![0usize; rows];
    let blk = parallel::row_block(rows);
    parallel::par_chunks_mut(buckets.as_mut_slice(), blk, |ci, chunk| {
        let r0 = ci * blk;
        for (rr, bucket) in chunk.iter_mut().enumerate() {
            let rg = r0 + rr;
            let mut best = f32::NEG_INFINITY;
            let mut arg = 0usize;
            for j in 0..2 * rc {
                let col = j % rc;
                let mut acc = 0.0f32;
                for i in 0..d {
                    acc += qk[rg * d + i] * rot[i * rc + col];
                }
                if j >= rc {
                    acc = -acc;
                }
                if acc > best {
                    best = acc;
                    arg = j;
                }
            }
            *bucket = arg;
        }
    });

    let buckets_s: &[usize] = &buckets;
    let mut order = vec![0usize; rows];
    parallel::par_chunks_mut(order.as_mut_slice(), n, |bb, ord| {
        for (pos, o) in ord.iter_mut().enumerate() {
            *o = pos;
        }
        ord.sort_by_key(|&i| buckets_s[bb * n + i]);
    });
    order
}

/// The bucket-chunked attention core of the LSH baseline: tokens are
/// copied into `order`, attended in κ-sized chunks (padding keys masked),
/// and un-sorted back to sequence order.  Shards per batch.  Shared by
/// [`lsh_layer`] and the autograd backward's recompute path.
#[allow(clippy::too_many_arguments)]
pub(crate) fn lsh_attend(
    qk: &[f32],
    v: &[f32],
    order: &[usize],
    b: usize,
    n: usize,
    h: usize,
    d_h: usize,
    kappa: usize,
    attn: AttnFn,
) -> Vec<f32> {
    let d = h * d_h;
    let rows = b * n;
    let m = n.div_ceil(kappa) * kappa; // padded length
    let tau = (d_h as f32).sqrt();
    let mut out = vec![0.0f32; rows * d];
    parallel::par_chunks_mut_with(
        out.as_mut_slice(),
        n * d,
        || LshScratch {
            qk_s: vec![0.0f32; m * d],
            v_s: vec![0.0f32; m * d],
            chunk_out: vec![0.0f32; m * d],
            scores: vec![0.0f32; kappa],
        },
        |scr, bb, out_b| {
            let ord = &order[bb * n..(bb + 1) * n];
            scr.qk_s.iter_mut().for_each(|z| *z = 0.0);
            scr.v_s.iter_mut().for_each(|z| *z = 0.0);
            scr.chunk_out.iter_mut().for_each(|z| *z = 0.0);
            for (pos, &t) in ord.iter().enumerate() {
                scr.qk_s[pos * d..(pos + 1) * d].copy_from_slice(&qk[(bb * n + t) * d..][..d]);
                scr.v_s[pos * d..(pos + 1) * d].copy_from_slice(&v[(bb * n + t) * d..][..d]);
            }
            for chunk in 0..m / kappa {
                let lo = chunk * kappa;
                // rows past n are padding (dropped by the un-sort); pad *keys*
                // must be masked so real tokens don't leak attention mass
                for i in lo..(lo + kappa).min(n) {
                    for hh in 0..h {
                        let qrow = &scr.qk_s[i * d + hh * d_h..][..d_h];
                        for jj in 0..kappa {
                            if lo + jj >= n {
                                scr.scores[jj] = NEG_INF;
                                continue;
                            }
                            let krow = &scr.qk_s[(lo + jj) * d + hh * d_h..][..d_h];
                            scr.scores[jj] = ops::dot(qrow, krow) / tau;
                        }
                        ops::attn_rows(&mut scr.scores, kappa, attn);
                        let dst = i * d + hh * d_h;
                        for (jj, &pj) in scr.scores.iter().enumerate() {
                            let (v_s, out_s) = (&scr.v_s, &mut scr.chunk_out);
                            let vrow = &v_s[(lo + jj) * d + hh * d_h..][..d_h];
                            simd::axpy8(&mut out_s[dst..dst + d_h], pj, vrow);
                        }
                    }
                }
            }
            // un-sort back to sequence order (padding rows are dropped)
            for (pos, &t) in ord.iter().enumerate() {
                out_b[t * d..][..d].copy_from_slice(&scr.chunk_out[pos * d..][..d]);
            }
        },
    );
    out
}

/// Reformer-style LSH attention: shared Q/K projection, random-rotation
/// hashing into Nc buckets, bucket-sorted κ-sized chunks.  Hashing runs
/// row-parallel; the bucket-sort + chunked attention shards per batch.
pub fn lsh_layer(p: &BaselineParams, x: &[f32], dims: &Dims) -> Result<Vec<f32>> {
    let (b, n, h, d_h, n_c) = (dims.b, dims.n, dims.heads, dims.d_h, dims.n_c);
    let d = dims.d();
    let rows = b * n;
    let kappa = dims.kappa.min(n).max(1);
    let qk = ops::dense(x, p.wq_w, p.wq_b, rows, d, d); // Reformer ties Q and K
    let v = ops::dense(x, p.wv_w, p.wv_b, rows, d, d);
    let order = lsh_sort_order(&qk, b, n, d, n_c);
    let out = lsh_attend(&qk, &v, &order, b, n, h, d_h, kappa, dims.attn);
    Ok(ops::dense(&out, p.wo_w, p.wo_b, rows, d, d))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dims(clustering: &str) -> Dims {
        Dims {
            b: 1,
            n: 8,
            heads: 2,
            d_h: 4,
            n_c: 2,
            kappa: 4,
            attn: AttnFn::Softmax,
            clustering: clustering.to_string(),
            causal: clustering == "causal",
            window: 4,
        }
    }

    fn ag_for(n: usize, n_c: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..n * n_c).map(|_| rng.f32()).collect()
    }

    #[test]
    fn topk_takes_highest_affinity_tokens() {
        // 4 tokens, 2 clusters, kappa 2
        #[rustfmt::skip]
        let a_g = vec![
            0.9, 0.1, // token 0: cluster 0
            0.8, 0.2, // token 1: cluster 0
            0.1, 0.9, // token 2: cluster 1
            0.7, 0.6, // token 3
        ];
        let (idx, valid) = top_k_cluster(&a_g, 1, 4, 2, 2);
        assert_eq!(&idx[0..2], &[0, 1]); // cluster 0 top-2
        assert_eq!(&idx[2..4], &[2, 3]); // cluster 1 top-2
        assert!(valid.iter().all(|&v| v == 1.0));
    }

    #[test]
    fn topk_matches_argsort_reference() {
        // the select_nth fast path must reproduce the full-argsort answer
        let (b, n, n_c, kappa) = (2usize, 13usize, 3usize, 5usize);
        let a_g = ag_for(b * n, n_c, 21);
        let (idx, _) = top_k_cluster(&a_g, b, n, n_c, kappa);
        let mut col = vec![0.0f32; n];
        for bb in 0..b {
            for c in 0..n_c {
                for (nn, cv) in col.iter_mut().enumerate() {
                    *cv = a_g[(bb * n + nn) * n_c + c];
                }
                let expect = &ops::argsort_desc(&col)[..kappa];
                let base = (bb * n_c + c) * kappa;
                assert_eq!(&idx[base..base + kappa], expect, "bb={bb} c={c}");
            }
        }
    }

    #[test]
    fn sa_topk_assigns_each_token_once_with_capacity() {
        let a_g = ag_for(8, 2, 7);
        let (idx, valid) = sa_top_k_cluster(&a_g, 1, 8, 2, 4);
        // Nc*kappa == N: every token placed exactly once
        assert!(valid.iter().all(|&v| v == 1.0));
        let mut seen: Vec<usize> = idx.clone();
        seen.sort();
        assert_eq!(seen, (0..8).collect::<Vec<_>>());
    }

    #[test]
    fn sa_topk_respects_capacity_with_slack() {
        // capacity 8*2 = 16 > 8 tokens: some slots stay padding
        let a_g = ag_for(8, 2, 9);
        let (idx, valid) = sa_top_k_cluster(&a_g, 1, 8, 2, 8);
        let placed: usize = valid.iter().map(|&v| v as usize).sum();
        assert_eq!(placed, 8);
        for c in 0..2 {
            for slot in 0..8 {
                let b = c * 8 + slot;
                if valid[b] == 0.0 {
                    assert_eq!(idx[b], 0, "padding slots hold index 0");
                }
            }
        }
    }

    #[test]
    fn membership_marks_assignments() {
        let a_g = ag_for(8, 2, 3);
        let (idx, valid) = sa_top_k_cluster(&a_g, 1, 8, 2, 4);
        let m = membership(&idx, &valid, 1, 8, 2, 4);
        // single-assignment: each token belongs to exactly one cluster
        for nn in 0..8 {
            let s: f32 = (0..2).map(|c| m[nn * 2 + c]).sum();
            assert_eq!(s, 1.0, "token {nn}");
        }
    }

    fn rand_cast_params(d: usize, h: usize, n_c: usize, seed: u64) -> Vec<Vec<f32>> {
        let d_h = d / h;
        let mut rng = Rng::new(seed);
        let mut mk = |len: usize, scale: f32| -> Vec<f32> {
            (0..len).map(|_| rng.gaussian() as f32 * scale).collect()
        };
        let s = 1.0 / (d as f32).sqrt();
        vec![
            mk(d * d, s),           // wq_w
            vec![0.0; d],           // wq_b
            mk(d * d, s),           // wk_w
            vec![0.0; d],           // wk_b
            mk(d * d, s),           // wv_w
            vec![0.0; d],           // wv_b
            mk(d * d, s),           // wo_w
            vec![0.0; d],           // wo_b
            mk(n_c * h * d_h, 1.0 / (d_h as f32).sqrt()), // s
            mk(d, s),               // phi_w
            vec![0.0; 1],           // phi_b
        ]
    }

    fn cast_params(buf: &[Vec<f32>]) -> CastParams<'_> {
        CastParams {
            wq_w: &buf[0],
            wq_b: &buf[1],
            wk_w: &buf[2],
            wk_b: &buf[3],
            wv_w: &buf[4],
            wv_b: &buf[5],
            wo_w: &buf[6],
            wo_b: &buf[7],
            s: &buf[8],
            phi_w: &buf[9],
            phi_b: &buf[10],
        }
    }

    #[test]
    fn cast_layer_shapes_and_ag_rows_sum_to_one() {
        for mech in ["topk", "sa", "causal"] {
            let dm = dims(mech);
            let d = dm.d();
            let buf = rand_cast_params(d, dm.heads, dm.n_c, 11);
            let p = cast_params(&buf);
            let mut rng = Rng::new(5);
            let x: Vec<f32> = (0..dm.b * dm.n * d).map(|_| rng.gaussian() as f32).collect();
            let mut ws = CastScratch::new();
            let (out, a_g) = cast_layer(&p, &x, &dm, &mut ws).unwrap();
            assert_eq!(out.len(), dm.b * dm.n * d, "{mech}");
            assert_eq!(a_g.len(), dm.b * dm.n * dm.n_c, "{mech}");
            assert!(out.iter().all(|v| v.is_finite()), "{mech}");
            // A_g is a convex mix of two softmaxes: rows sum to 1
            for row in a_g.chunks(dm.n_c) {
                let s: f32 = row.iter().sum();
                assert!((s - 1.0).abs() < 1e-4, "{mech}: A_g row sums to {s}");
            }
        }
    }

    #[test]
    fn cast_layer_is_deterministic() {
        let dm = dims("topk");
        let d = dm.d();
        let buf = rand_cast_params(d, dm.heads, dm.n_c, 2);
        let p = cast_params(&buf);
        let x: Vec<f32> = (0..dm.b * dm.n * d).map(|i| (i as f32 * 0.37).sin()).collect();
        let mut ws = CastScratch::new();
        let (a, _) = cast_layer(&p, &x, &dm, &mut ws).unwrap();
        // scratch reuse across calls must not change the result
        let (b2, _) = cast_layer(&p, &x, &dm, &mut ws).unwrap();
        assert_eq!(a, b2);
    }

    fn rand_baseline(d: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Rng::new(seed);
        let s = 1.0 / (d as f32).sqrt();
        let mut mk =
            |len: usize| -> Vec<f32> { (0..len).map(|_| rng.gaussian() as f32 * s).collect() };
        vec![
            mk(d * d),
            vec![0.0; d],
            mk(d * d),
            vec![0.0; d],
            mk(d * d),
            vec![0.0; d],
            mk(d * d),
            vec![0.0; d],
        ]
    }

    fn baseline_params(buf: &[Vec<f32>]) -> BaselineParams<'_> {
        BaselineParams {
            wq_w: &buf[0],
            wq_b: &buf[1],
            wk_w: &buf[2],
            wk_b: &buf[3],
            wv_w: &buf[4],
            wv_b: &buf[5],
            wo_w: &buf[6],
            wo_b: &buf[7],
        }
    }

    #[test]
    fn baselines_produce_finite_outputs() {
        let dm = dims("topk");
        let d = dm.d();
        let buf = rand_baseline(d, 4);
        let p = baseline_params(&buf);
        let mut rng = Rng::new(6);
        let x: Vec<f32> = (0..dm.b * dm.n * d).map(|_| rng.gaussian() as f32).collect();
        for (name, out) in [
            ("vanilla", vanilla_layer(&p, &x, &dm).unwrap()),
            ("local", local_layer(&p, &x, &dm).unwrap()),
            ("lsh", lsh_layer(&p, &x, &dm).unwrap()),
        ] {
            assert_eq!(out.len(), x.len(), "{name}");
            assert!(out.iter().all(|v| v.is_finite()), "{name}");
        }
    }

    #[test]
    fn local_equals_vanilla_when_window_covers_sequence() {
        let mut dm = dims("topk");
        dm.window = dm.n; // one window == full attention
        let d = dm.d();
        let buf = rand_baseline(d, 8);
        let p = baseline_params(&buf);
        let x: Vec<f32> = (0..dm.b * dm.n * d).map(|i| ((i * 7 % 13) as f32) / 13.0).collect();
        let a = vanilla_layer(&p, &x, &dm).unwrap();
        let b = local_layer(&p, &x, &dm).unwrap();
        for (u, w) in a.iter().zip(&b) {
            assert!((u - w).abs() < 1e-4, "{u} vs {w}");
        }
    }

    #[test]
    fn baselines_honor_configured_attn_fn() {
        // laplace configs must not silently run softmax (the old
        // `attend_range`/`lsh_layer` hardcoded AttnFn::Softmax)
        let mut soft = dims("topk");
        soft.b = 2;
        let mut lap = soft.clone();
        lap.attn = AttnFn::Laplace;
        let d = soft.d();
        let buf = rand_baseline(d, 12);
        let p = baseline_params(&buf);
        let mut rng = Rng::new(13);
        let x: Vec<f32> = (0..soft.b * soft.n * d).map(|_| rng.gaussian() as f32).collect();
        let pairs = [
            (
                "vanilla",
                vanilla_layer(&p, &x, &soft).unwrap(),
                vanilla_layer(&p, &x, &lap).unwrap(),
            ),
            ("local", local_layer(&p, &x, &soft).unwrap(), local_layer(&p, &x, &lap).unwrap()),
            ("lsh", lsh_layer(&p, &x, &soft).unwrap(), lsh_layer(&p, &x, &lap).unwrap()),
        ];
        for (name, a, b) in pairs {
            let max_diff =
                a.iter().zip(&b).map(|(u, w)| (u - w).abs()).fold(0.0f32, f32::max);
            assert!(max_diff > 1e-6, "{name}: laplace output identical to softmax");
        }
    }
}
