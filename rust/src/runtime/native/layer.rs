//! Native attention layers: the CAST layer (paper §3.1–3.3) and the three
//! baselines (vanilla / local / LSH), mirroring `python/compile/cast_layer.py`,
//! `clustering.py`, `attention_baselines.py`, and `kernels/ref.py`.
//!
//! Shapes are row-major flat `&[f32]`:
//!   x (B,N,d) · q/k/v (B,N,h·d_h) · A_g (B,N,Nc) · idx/valid (B,Nc,κ).

use anyhow::{ensure, Result};

use crate::util::rng::Rng;

use super::ops::{self, AttnFn, NEG_INF};

/// Geometry + mechanism of one attention layer.
#[derive(Clone, Debug)]
pub struct Dims {
    pub b: usize,
    pub n: usize,
    pub heads: usize,
    pub d_h: usize,
    pub n_c: usize,
    pub kappa: usize,
    pub attn: AttnFn,
    /// "topk" | "sa" | "causal" (paper §3.2 / §5.5).
    pub clustering: String,
    pub causal: bool,
    pub window: usize,
}

impl Dims {
    pub fn d(&self) -> usize {
        self.heads * self.d_h
    }
}

/// Weights of one CAST attention layer (borrowed from the flat param list).
pub struct CastParams<'a> {
    pub wq_w: &'a [f32],
    pub wq_b: &'a [f32],
    pub wk_w: &'a [f32],
    pub wk_b: &'a [f32],
    pub wv_w: &'a [f32],
    pub wv_b: &'a [f32],
    pub wo_w: &'a [f32],
    pub wo_b: &'a [f32],
    /// Surrogate tokens S (Nc, h, d_h) — the learnable cluster directions.
    pub s: &'a [f32],
    pub phi_w: &'a [f32],
    pub phi_b: &'a [f32],
}

/// Weights of a baseline attention layer.
pub struct BaselineParams<'a> {
    pub wq_w: &'a [f32],
    pub wq_b: &'a [f32],
    pub wk_w: &'a [f32],
    pub wk_b: &'a [f32],
    pub wv_w: &'a [f32],
    pub wv_b: &'a [f32],
    pub wo_w: &'a [f32],
    pub wo_b: &'a [f32],
}

// ---------------------------------------------------------------------------
// clustering mechanisms G (clustering.py)
// ---------------------------------------------------------------------------

/// Algorithm 1 (Top-K): every cluster independently takes its κ
/// highest-affinity tokens; a token may land in several clusters or none.
pub fn top_k_cluster(
    a_g: &[f32],
    b: usize,
    n: usize,
    n_c: usize,
    kappa: usize,
) -> (Vec<usize>, Vec<f32>) {
    let mut idx = vec![0usize; b * n_c * kappa];
    let valid = vec![1.0f32; b * n_c * kappa];
    let mut col = vec![0.0f32; n];
    for bb in 0..b {
        for c in 0..n_c {
            for (nn, cv) in col.iter_mut().enumerate() {
                *cv = a_g[(bb * n + nn) * n_c + c];
            }
            let order = ops::argsort_desc(&col);
            let base = (bb * n_c + c) * kappa;
            idx[base..base + kappa].copy_from_slice(&order[..kappa]);
        }
    }
    (idx, valid)
}

/// Greedy capacity-constrained assignment shared by SA Top-K (visit order =
/// descending best affinity) and the causal variant (visit order = position).
fn greedy_assign(
    a_g: &[f32],
    b: usize,
    n: usize,
    n_c: usize,
    kappa: usize,
    by_position: bool,
) -> (Vec<usize>, Vec<f32>) {
    let mut idx = vec![0usize; b * n_c * kappa];
    let mut valid = vec![0.0f32; b * n_c * kappa];
    let mut row = vec![0.0f32; n_c];
    for bb in 0..b {
        // per-token cluster preference (descending affinity)
        let mut pref: Vec<Vec<usize>> = Vec::with_capacity(n);
        let mut best = vec![0.0f32; n];
        for nn in 0..n {
            for (c, rv) in row.iter_mut().enumerate() {
                *rv = a_g[(bb * n + nn) * n_c + c];
            }
            best[nn] = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            pref.push(ops::argsort_desc(&row));
        }
        let order: Vec<usize> =
            if by_position { (0..n).collect() } else { ops::argsort_desc(&best) };
        let mut fill = vec![0usize; n_c];
        for &t in &order {
            if let Some(&c) = pref[t].iter().find(|&&c| fill[c] < kappa) {
                let base = (bb * n_c + c) * kappa + fill[c];
                idx[base] = t;
                valid[base] = 1.0;
                fill[c] += 1;
            }
        }
    }
    (idx, valid)
}

/// Algorithm 2 (SA Top-K): each token joins exactly one cluster, greedily
/// in descending order of its best affinity, subject to capacity.
pub fn sa_top_k_cluster(
    a_g: &[f32],
    b: usize,
    n: usize,
    n_c: usize,
    kappa: usize,
) -> (Vec<usize>, Vec<f32>) {
    greedy_assign(a_g, b, n, n_c, kappa, false)
}

/// Causal clustering (paper §5.5): assignment in *position* order, so
/// token n's cluster depends only on tokens 0..n.
pub fn causal_cluster(
    a_g: &[f32],
    b: usize,
    n: usize,
    n_c: usize,
    kappa: usize,
) -> (Vec<usize>, Vec<f32>) {
    greedy_assign(a_g, b, n, n_c, kappa, true)
}

/// The paper's membership mask M (B,N,Nc): 1 iff the token sits in the
/// cluster's slot list.
pub fn membership(
    idx: &[usize],
    valid: &[f32],
    b: usize,
    n: usize,
    n_c: usize,
    kappa: usize,
) -> Vec<f32> {
    let mut m = vec![0.0f32; b * n * n_c];
    for bb in 0..b {
        for c in 0..n_c {
            for slot in 0..kappa {
                let base = (bb * n_c + c) * kappa + slot;
                if valid[base] > 0.0 {
                    m[(bb * n + idx[base]) * n_c + c] = 1.0;
                }
            }
        }
    }
    m
}

fn cluster(
    mechanism: &str,
    a_g: &[f32],
    b: usize,
    n: usize,
    n_c: usize,
    kappa: usize,
) -> Result<(Vec<usize>, Vec<f32>)> {
    Ok(match mechanism {
        "topk" => top_k_cluster(a_g, b, n, n_c, kappa),
        "sa" => sa_top_k_cluster(a_g, b, n, n_c, kappa),
        "causal" => causal_cluster(a_g, b, n, n_c, kappa),
        other => anyhow::bail!("unknown clustering mechanism {other:?}"),
    })
}

// ---------------------------------------------------------------------------
// the CAST layer (cast_layer.py apply())
// ---------------------------------------------------------------------------

/// Full CAST attention layer.  Returns `(out (B,N,d), a_g (B,N,Nc))`.
pub fn cast_layer(p: &CastParams, x: &[f32], dims: &Dims) -> Result<(Vec<f32>, Vec<f32>)> {
    let (b, n, h, d_h, n_c) = (dims.b, dims.n, dims.heads, dims.d_h, dims.n_c);
    let d = dims.d();
    let kappa = dims.kappa.min(n);
    ensure!(kappa > 0 && n_c > 0, "CAST needs n_c>0 and kappa>0");
    let rows = b * n;
    let tau = (d_h as f32).sqrt();

    // step 1: projections (eq. 1)
    let q = ops::dense(x, p.wq_w, p.wq_b, rows, d, d);
    let k = ops::dense(x, p.wk_w, p.wk_b, rows, d, d);
    let v = ops::dense(x, p.wv_w, p.wv_b, rows, d, d);
    let phi = ops::dense(x, p.phi_w, p.phi_b, rows, d, 1); // (B·N,)

    // step 2: surrogate similarities A_q, A_k (eq. 6), per head
    let mut a_q = vec![0.0f32; rows * h * n_c];
    let mut a_k = vec![0.0f32; rows * h * n_c];
    for r in 0..rows {
        for hh in 0..h {
            let qrow = &q[r * d + hh * d_h..r * d + (hh + 1) * d_h];
            let krow = &k[r * d + hh * d_h..r * d + (hh + 1) * d_h];
            for c in 0..n_c {
                let srow = &p.s[(c * h + hh) * d_h..(c * h + hh + 1) * d_h];
                let mut sq = 0.0f32;
                let mut sk = 0.0f32;
                for dd in 0..d_h {
                    sq += qrow[dd] * srow[dd];
                    sk += krow[dd] * srow[dd];
                }
                a_q[(r * h + hh) * n_c + c] = sq;
                a_k[(r * h + hh) * n_c + c] = sk;
            }
        }
    }

    // head-summed raw similarities
    let mut a_q_raw = vec![0.0f32; rows * n_c];
    let mut a_k_raw = vec![0.0f32; rows * n_c];
    for r in 0..rows {
        for hh in 0..h {
            for c in 0..n_c {
                a_q_raw[r * n_c + c] += a_q[(r * h + hh) * n_c + c];
                a_k_raw[r * n_c + c] += a_k[(r * h + hh) * n_c + c];
            }
        }
    }

    // step 3: gate + affinity A_g = sigm(phi)·f2(ΣA_q) + (1-sigm(phi))·f2(ΣA_k)
    let mut f2q = a_q_raw.clone();
    ops::attn_rows(&mut f2q, n_c, dims.attn);
    let mut f2k = a_k_raw.clone();
    ops::attn_rows(&mut f2k, n_c, dims.attn);
    let mut a_g = vec![0.0f32; rows * n_c];
    for r in 0..rows {
        let g = ops::sigmoid(phi[r]);
        for c in 0..n_c {
            a_g[r * n_c + c] = g * f2q[r * n_c + c] + (1.0 - g) * f2k[r * n_c + c];
        }
    }

    // step 4: clustering (indices are non-differentiable, paper §3.2)
    let (idx, valid) = cluster(&dims.clustering, &a_g, b, n, n_c, kappa)?;
    let member = membership(&idx, &valid, b, n, n_c, kappa);

    // step 5: fused intra-cluster attention + cluster summaries (eq. 3/4)
    let mut r_intra = vec![0.0f32; b * n_c * kappa * d];
    let mut r_inter = vec![0.0f32; b * n_c * d];
    let mut scores = vec![0.0f32; kappa * kappa];
    let mut wrow = vec![0.0f32; kappa];
    for bb in 0..b {
        for c in 0..n_c {
            let base = (bb * n_c + c) * kappa;
            let slots = &idx[base..base + kappa];
            let val = &valid[base..base + kappa];
            let mask_ij = |i: usize, j: usize| -> f32 {
                if dims.causal && slots[j] > slots[i] {
                    0.0
                } else {
                    val[j]
                }
            };
            for hh in 0..h {
                // masked κ×κ scores: f(Q_g K_gᵀ / τ)
                for i in 0..kappa {
                    let qrow = &q[(bb * n + slots[i]) * d + hh * d_h..][..d_h];
                    for j in 0..kappa {
                        let krow = &k[(bb * n + slots[j]) * d + hh * d_h..][..d_h];
                        let mut dot = 0.0f32;
                        for dd in 0..d_h {
                            dot += qrow[dd] * krow[dd];
                        }
                        scores[i * kappa + j] = dot / tau + (1.0 - mask_ij(i, j)) * NEG_INF;
                    }
                }
                ops::attn_rows(&mut scores, kappa, dims.attn);
                for i in 0..kappa {
                    if val[i] == 0.0 {
                        continue; // padding rows stay zero (· valid)
                    }
                    let out = ((bb * n_c + c) * kappa + i) * d + hh * d_h;
                    for j in 0..kappa {
                        let pij = scores[i * kappa + j] * mask_ij(i, j);
                        if pij != 0.0 {
                            let vrow = &v[(bb * n + slots[j]) * d + hh * d_h..][..d_h];
                            for dd in 0..d_h {
                                r_intra[out + dd] += pij * vrow[dd];
                            }
                        }
                    }
                }
                // eq. 4: cluster summary R_inter (omitted in causal mode —
                // summaries would leak future tokens)
                if !dims.causal {
                    for j in 0..kappa {
                        let t = slots[j];
                        wrow[j] = a_k[((bb * n + t) * h + hh) * n_c + c]
                            * ops::softplus1(-phi[bb * n + t])
                            / tau
                            + (1.0 - val[j]) * NEG_INF;
                    }
                    ops::attn_rows(&mut wrow, kappa, dims.attn);
                    let out = (bb * n_c + c) * d + hh * d_h;
                    for j in 0..kappa {
                        let pk = wrow[j] * val[j];
                        if pk != 0.0 {
                            let vrow = &v[(bb * n + slots[j]) * d + hh * d_h..][..d_h];
                            for dd in 0..d_h {
                                r_inter[out + dd] += pk * vrow[dd];
                            }
                        }
                    }
                }
            }
        }
    }

    // step 6: combination (eq. 5)
    let mut a_sum = vec![0.0f32; rows * n_c];
    for r in 0..rows {
        let sp = ops::softplus1(phi[r]) / tau;
        for c in 0..n_c {
            a_sum[r * n_c + c] = a_q_raw[r * n_c + c] * sp;
        }
    }
    ops::attn_rows(&mut a_sum, n_c, dims.attn);

    let mut r = vec![0.0f32; rows * d];
    for bb in 0..b {
        for c in 0..n_c {
            let base = (bb * n_c + c) * kappa;
            for slot in 0..kappa {
                if valid[base + slot] == 0.0 {
                    continue;
                }
                let t = idx[base + slot];
                let wi = a_sum[(bb * n + t) * n_c + c];
                if wi == 0.0 {
                    continue;
                }
                let src = (base + slot) * d;
                let dst = (bb * n + t) * d;
                for dd in 0..d {
                    r[dst + dd] += wi * r_intra[src + dd];
                }
            }
        }
    }
    if !dims.causal {
        // summaries of *other* clusters, weighted by off-membership A_sum
        for bb in 0..b {
            for nn in 0..n {
                let dst = (bb * n + nn) * d;
                for c in 0..n_c {
                    let ai = a_sum[(bb * n + nn) * n_c + c]
                        * (1.0 - member[(bb * n + nn) * n_c + c]);
                    if ai != 0.0 {
                        let src = (bb * n_c + c) * d;
                        for dd in 0..d {
                            r[dst + dd] += ai * r_inter[src + dd];
                        }
                    }
                }
            }
        }
    }

    let out = ops::dense(&r, p.wo_w, p.wo_b, rows, d, d);
    Ok((out, a_g))
}

// ---------------------------------------------------------------------------
// baselines (attention_baselines.py)
// ---------------------------------------------------------------------------

/// Row-wise softmax attention of `q` against keys/values restricted to the
/// token range `[lo, hi)` of batch `bb` — the shared core of the vanilla
/// and local baselines (row-wise so O(N) scratch, not O(N²)).
fn attend_range(
    out: &mut [f32],
    q: &[f32],
    k: &[f32],
    v: &[f32],
    bb: usize,
    n: usize,
    h: usize,
    d_h: usize,
    lo: usize,
    hi: usize,
    row_lo: usize,
    row_hi: usize,
) {
    let d = h * d_h;
    let tau = (d_h as f32).sqrt();
    let w = hi - lo;
    let mut scores = vec![0.0f32; w];
    for i in row_lo..row_hi {
        for hh in 0..h {
            let qrow = &q[(bb * n + i) * d + hh * d_h..][..d_h];
            for (jj, sc) in scores.iter_mut().enumerate() {
                let krow = &k[(bb * n + lo + jj) * d + hh * d_h..][..d_h];
                let mut dot = 0.0f32;
                for dd in 0..d_h {
                    dot += qrow[dd] * krow[dd];
                }
                *sc = dot / tau;
            }
            ops::attn_rows(&mut scores, w, AttnFn::Softmax);
            let dst = (bb * n + i) * d + hh * d_h;
            for (jj, &pj) in scores.iter().enumerate() {
                let vrow = &v[(bb * n + lo + jj) * d + hh * d_h..][..d_h];
                for dd in 0..d_h {
                    out[dst + dd] += pj * vrow[dd];
                }
            }
        }
    }
}

/// The original O(N²) multi-head self-attention.
pub fn vanilla_layer(p: &BaselineParams, x: &[f32], dims: &Dims) -> Result<Vec<f32>> {
    let (b, n, h, d_h) = (dims.b, dims.n, dims.heads, dims.d_h);
    let d = dims.d();
    let rows = b * n;
    let q = ops::dense(x, p.wq_w, p.wq_b, rows, d, d);
    let k = ops::dense(x, p.wk_w, p.wk_b, rows, d, d);
    let v = ops::dense(x, p.wv_w, p.wv_b, rows, d, d);
    let mut out = vec![0.0f32; rows * d];
    for bb in 0..b {
        attend_range(&mut out, &q, &k, &v, bb, n, h, d_h, 0, n, 0, n);
    }
    Ok(ops::dense(&out, p.wo_w, p.wo_b, rows, d, d))
}

/// LRA's Local Attention: full attention within non-overlapping windows.
pub fn local_layer(p: &BaselineParams, x: &[f32], dims: &Dims) -> Result<Vec<f32>> {
    let (b, n, h, d_h) = (dims.b, dims.n, dims.heads, dims.d_h);
    let w = dims.window.min(n).max(1);
    ensure!(n % w == 0, "local attention needs seq_len % window == 0 ({n} % {w})");
    let d = dims.d();
    let rows = b * n;
    let q = ops::dense(x, p.wq_w, p.wq_b, rows, d, d);
    let k = ops::dense(x, p.wk_w, p.wk_b, rows, d, d);
    let v = ops::dense(x, p.wv_w, p.wv_b, rows, d, d);
    let mut out = vec![0.0f32; rows * d];
    for bb in 0..b {
        for chunk in 0..n / w {
            let lo = chunk * w;
            attend_range(&mut out, &q, &k, &v, bb, n, h, d_h, lo, lo + w, lo, lo + w);
        }
    }
    Ok(ops::dense(&out, p.wo_w, p.wo_b, rows, d, d))
}

/// Reformer-style LSH attention: shared Q/K projection, random-rotation
/// hashing into Nc buckets, bucket-sorted κ-sized chunks.
pub fn lsh_layer(p: &BaselineParams, x: &[f32], dims: &Dims) -> Result<Vec<f32>> {
    let (b, n, h, d_h, n_c) = (dims.b, dims.n, dims.heads, dims.d_h, dims.n_c);
    let d = dims.d();
    let rows = b * n;
    let kappa = dims.kappa.min(n).max(1);
    let qk = ops::dense(x, p.wq_w, p.wq_b, rows, d, d); // Reformer ties Q and K
    let v = ops::dense(x, p.wv_w, p.wv_b, rows, d, d);

    // fixed pseudorandom rotation (python uses PRNGKey(0); a fixed draw
    // keeps the layer deterministic — the property that matters)
    let rc = (n_c / 2).max(1);
    let mut rng = Rng::new(0);
    let rot: Vec<f32> = (0..d * rc).map(|_| rng.gaussian() as f32).collect();

    // bucket = argmax over [xR ; -xR]
    let mut buckets = vec![0usize; rows];
    for r in 0..rows {
        let mut best = f32::NEG_INFINITY;
        let mut arg = 0usize;
        for j in 0..2 * rc {
            let col = j % rc;
            let mut acc = 0.0f32;
            for i in 0..d {
                acc += qk[r * d + i] * rot[i * rc + col];
            }
            if j >= rc {
                acc = -acc;
            }
            if acc > best {
                best = acc;
                arg = j;
            }
        }
        buckets[r] = arg;
    }

    let m = n.div_ceil(kappa) * kappa; // padded length
    let mut out = vec![0.0f32; rows * d];
    let mut qk_s = vec![0.0f32; m * d];
    let mut v_s = vec![0.0f32; m * d];
    let mut chunk_out = vec![0.0f32; m * d];
    let mut scores = vec![0.0f32; kappa];
    for bb in 0..b {
        // stable ascending sort by bucket (ties keep sequence order)
        let mut order: Vec<usize> = (0..n).collect();
        order.sort_by_key(|&i| buckets[bb * n + i]);
        qk_s.iter_mut().for_each(|z| *z = 0.0);
        v_s.iter_mut().for_each(|z| *z = 0.0);
        chunk_out.iter_mut().for_each(|z| *z = 0.0);
        for (pos, &t) in order.iter().enumerate() {
            qk_s[pos * d..(pos + 1) * d].copy_from_slice(&qk[(bb * n + t) * d..][..d]);
            v_s[pos * d..(pos + 1) * d].copy_from_slice(&v[(bb * n + t) * d..][..d]);
        }
        let tau = (d_h as f32).sqrt();
        for chunk in 0..m / kappa {
            let lo = chunk * kappa;
            // rows past n are padding (dropped by the un-sort); pad *keys*
            // must be masked so real tokens don't leak softmax mass to them
            for i in lo..(lo + kappa).min(n) {
                for hh in 0..h {
                    let qrow = &qk_s[i * d + hh * d_h..][..d_h];
                    for jj in 0..kappa {
                        if lo + jj >= n {
                            scores[jj] = NEG_INF;
                            continue;
                        }
                        let krow = &qk_s[(lo + jj) * d + hh * d_h..][..d_h];
                        let mut dot = 0.0f32;
                        for dd in 0..d_h {
                            dot += qrow[dd] * krow[dd];
                        }
                        scores[jj] = dot / tau;
                    }
                    ops::attn_rows(&mut scores, kappa, AttnFn::Softmax);
                    let dst = i * d + hh * d_h;
                    for (jj, &pj) in scores.iter().enumerate() {
                        let vrow = &v_s[(lo + jj) * d + hh * d_h..][..d_h];
                        for dd in 0..d_h {
                            chunk_out[dst + dd] += pj * vrow[dd];
                        }
                    }
                }
            }
        }
        // un-sort back to sequence order (padding rows are dropped)
        for (pos, &t) in order.iter().enumerate() {
            out[(bb * n + t) * d..][..d].copy_from_slice(&chunk_out[pos * d..][..d]);
        }
    }
    Ok(ops::dense(&out, p.wo_w, p.wo_b, rows, d, d))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dims(clustering: &str) -> Dims {
        Dims {
            b: 1,
            n: 8,
            heads: 2,
            d_h: 4,
            n_c: 2,
            kappa: 4,
            attn: AttnFn::Softmax,
            clustering: clustering.to_string(),
            causal: clustering == "causal",
            window: 4,
        }
    }

    fn ag_for(n: usize, n_c: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..n * n_c).map(|_| rng.f32()).collect()
    }

    #[test]
    fn topk_takes_highest_affinity_tokens() {
        // 4 tokens, 2 clusters, kappa 2
        #[rustfmt::skip]
        let a_g = vec![
            0.9, 0.1, // token 0: cluster 0
            0.8, 0.2, // token 1: cluster 0
            0.1, 0.9, // token 2: cluster 1
            0.7, 0.6, // token 3
        ];
        let (idx, valid) = top_k_cluster(&a_g, 1, 4, 2, 2);
        assert_eq!(&idx[0..2], &[0, 1]); // cluster 0 top-2
        assert_eq!(&idx[2..4], &[2, 3]); // cluster 1 top-2
        assert!(valid.iter().all(|&v| v == 1.0));
    }

    #[test]
    fn sa_topk_assigns_each_token_once_with_capacity() {
        let a_g = ag_for(8, 2, 7);
        let (idx, valid) = sa_top_k_cluster(&a_g, 1, 8, 2, 4);
        // Nc*kappa == N: every token placed exactly once
        assert!(valid.iter().all(|&v| v == 1.0));
        let mut seen: Vec<usize> = idx.clone();
        seen.sort();
        assert_eq!(seen, (0..8).collect::<Vec<_>>());
    }

    #[test]
    fn sa_topk_respects_capacity_with_slack() {
        // capacity 8*2 = 16 > 8 tokens: some slots stay padding
        let a_g = ag_for(8, 2, 9);
        let (idx, valid) = sa_top_k_cluster(&a_g, 1, 8, 2, 8);
        let placed: usize = valid.iter().map(|&v| v as usize).sum();
        assert_eq!(placed, 8);
        for c in 0..2 {
            for slot in 0..8 {
                let b = c * 8 + slot;
                if valid[b] == 0.0 {
                    assert_eq!(idx[b], 0, "padding slots hold index 0");
                }
            }
        }
    }

    #[test]
    fn membership_marks_assignments() {
        let a_g = ag_for(8, 2, 3);
        let (idx, valid) = sa_top_k_cluster(&a_g, 1, 8, 2, 4);
        let m = membership(&idx, &valid, 1, 8, 2, 4);
        // single-assignment: each token belongs to exactly one cluster
        for nn in 0..8 {
            let s: f32 = (0..2).map(|c| m[nn * 2 + c]).sum();
            assert_eq!(s, 1.0, "token {nn}");
        }
    }

    fn rand_cast_params(d: usize, h: usize, n_c: usize, seed: u64) -> Vec<Vec<f32>> {
        let d_h = d / h;
        let mut rng = Rng::new(seed);
        let mut mk = |len: usize, scale: f32| -> Vec<f32> {
            (0..len).map(|_| rng.gaussian() as f32 * scale).collect()
        };
        let s = 1.0 / (d as f32).sqrt();
        vec![
            mk(d * d, s),           // wq_w
            vec![0.0; d],           // wq_b
            mk(d * d, s),           // wk_w
            vec![0.0; d],           // wk_b
            mk(d * d, s),           // wv_w
            vec![0.0; d],           // wv_b
            mk(d * d, s),           // wo_w
            vec![0.0; d],           // wo_b
            mk(n_c * h * d_h, 1.0 / (d_h as f32).sqrt()), // s
            mk(d, s),               // phi_w
            vec![0.0; 1],           // phi_b
        ]
    }

    fn cast_params(buf: &[Vec<f32>]) -> CastParams<'_> {
        CastParams {
            wq_w: &buf[0],
            wq_b: &buf[1],
            wk_w: &buf[2],
            wk_b: &buf[3],
            wv_w: &buf[4],
            wv_b: &buf[5],
            wo_w: &buf[6],
            wo_b: &buf[7],
            s: &buf[8],
            phi_w: &buf[9],
            phi_b: &buf[10],
        }
    }

    #[test]
    fn cast_layer_shapes_and_ag_rows_sum_to_one() {
        for mech in ["topk", "sa", "causal"] {
            let dm = dims(mech);
            let d = dm.d();
            let buf = rand_cast_params(d, dm.heads, dm.n_c, 11);
            let p = cast_params(&buf);
            let mut rng = Rng::new(5);
            let x: Vec<f32> = (0..dm.b * dm.n * d).map(|_| rng.gaussian() as f32).collect();
            let (out, a_g) = cast_layer(&p, &x, &dm).unwrap();
            assert_eq!(out.len(), dm.b * dm.n * d, "{mech}");
            assert_eq!(a_g.len(), dm.b * dm.n * dm.n_c, "{mech}");
            assert!(out.iter().all(|v| v.is_finite()), "{mech}");
            // A_g is a convex mix of two softmaxes: rows sum to 1
            for row in a_g.chunks(dm.n_c) {
                let s: f32 = row.iter().sum();
                assert!((s - 1.0).abs() < 1e-4, "{mech}: A_g row sums to {s}");
            }
        }
    }

    #[test]
    fn cast_layer_is_deterministic() {
        let dm = dims("topk");
        let d = dm.d();
        let buf = rand_cast_params(d, dm.heads, dm.n_c, 2);
        let p = cast_params(&buf);
        let x: Vec<f32> = (0..dm.b * dm.n * d).map(|i| (i as f32 * 0.37).sin()).collect();
        let (a, _) = cast_layer(&p, &x, &dm).unwrap();
        let (b2, _) = cast_layer(&p, &x, &dm).unwrap();
        assert_eq!(a, b2);
    }

    fn rand_baseline(d: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Rng::new(seed);
        let s = 1.0 / (d as f32).sqrt();
        let mut mk =
            |len: usize| -> Vec<f32> { (0..len).map(|_| rng.gaussian() as f32 * s).collect() };
        vec![
            mk(d * d),
            vec![0.0; d],
            mk(d * d),
            vec![0.0; d],
            mk(d * d),
            vec![0.0; d],
            mk(d * d),
            vec![0.0; d],
        ]
    }

    fn baseline_params(buf: &[Vec<f32>]) -> BaselineParams<'_> {
        BaselineParams {
            wq_w: &buf[0],
            wq_b: &buf[1],
            wk_w: &buf[2],
            wk_b: &buf[3],
            wv_w: &buf[4],
            wv_b: &buf[5],
            wo_w: &buf[6],
            wo_b: &buf[7],
        }
    }

    #[test]
    fn baselines_produce_finite_outputs() {
        let dm = dims("topk");
        let d = dm.d();
        let buf = rand_baseline(d, 4);
        let p = baseline_params(&buf);
        let mut rng = Rng::new(6);
        let x: Vec<f32> = (0..dm.b * dm.n * d).map(|_| rng.gaussian() as f32).collect();
        for (name, out) in [
            ("vanilla", vanilla_layer(&p, &x, &dm).unwrap()),
            ("local", local_layer(&p, &x, &dm).unwrap()),
            ("lsh", lsh_layer(&p, &x, &dm).unwrap()),
        ] {
            assert_eq!(out.len(), x.len(), "{name}");
            assert!(out.iter().all(|v| v.is_finite()), "{name}");
        }
    }

    #[test]
    fn local_equals_vanilla_when_window_covers_sequence() {
        let mut dm = dims("topk");
        dm.window = dm.n; // one window == full attention
        let d = dm.d();
        let buf = rand_baseline(d, 8);
        let p = baseline_params(&buf);
        let x: Vec<f32> = (0..dm.b * dm.n * d).map(|i| ((i * 7 % 13) as f32) / 13.0).collect();
        let a = vanilla_layer(&p, &x, &dm).unwrap();
        let b = local_layer(&p, &x, &dm).unwrap();
        for (u, w) in a.iter().zip(&b) {
            assert!((u - w).abs() < 1e-4, "{u} vs {w}");
        }
    }
}
