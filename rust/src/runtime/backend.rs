//! The execution-substrate seam: every way of running a model program —
//! the native CPU engine, PJRT over AOT HLO artifacts, and whatever later
//! PRs add (threaded batching, sharded execution, remote workers) — sits
//! behind these two traits.
//!
//! A *program* is identified by `(manifest, entry)` where `entry` is one
//! of the artifact contract's entry points (`init`, `predict`,
//! `predict_ag`, `train_step`); loading yields an [`Executable`] that maps
//! a flat `HostTensor` input list to a flat output list.  Everything above
//! this seam (`ModelState`, the trainer, the bench harness, analysis) is
//! backend-agnostic.

use std::sync::Arc;

use anyhow::Result;

use super::artifacts::Manifest;
use super::tensor::HostTensor;

/// A loaded, runnable program.
pub trait Executable: Send + Sync {
    /// The entry-point name this executable was loaded for.
    fn entry(&self) -> &str;

    /// Execute with borrowed inputs — the trainer's hot path (no clone of
    /// the 3P-tensor optimizer state per step).
    fn run_refs(&self, inputs: &[&HostTensor]) -> Result<Vec<HostTensor>>;

    /// Execute with owned inputs.
    fn run(&self, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let refs: Vec<&HostTensor> = inputs.iter().collect();
        self.run_refs(&refs)
    }
}

/// An execution substrate that can load programs for a model config.
pub trait Backend: Send + Sync {
    /// Short backend name for logs/reports ("native", "pjrt", ...).
    fn name(&self) -> &'static str;

    /// Whether this backend can provide `entry` for `manifest` (the
    /// native engine answers from the model config; PJRT from the files
    /// on disk).
    fn supports(&self, manifest: &Manifest, entry: &str) -> bool;

    /// Load (and, where applicable, compile) the program.
    fn load(&self, manifest: &Manifest, entry: &str) -> Result<Arc<dyn Executable>>;
}
