//! The execution-substrate seam: every way of running a model program —
//! the native CPU engine, PJRT over AOT HLO artifacts, and whatever later
//! PRs add (threaded batching, sharded execution, remote workers) — sits
//! behind these two traits.
//!
//! A *program* is identified by `(manifest, entry)` where `entry` is one
//! of the artifact contract's entry points (`init`, `predict`,
//! `predict_ag`, `train_step`, `decode`); loading yields an [`Executable`] that maps
//! a flat `HostTensor` input list to a flat output list.  Everything above
//! this seam (`ModelState`, the trainer, the bench harness, analysis) is
//! backend-agnostic.

use std::any::Any;
use std::sync::Arc;

use anyhow::{bail, Result};

use super::artifacts::Manifest;
use super::tensor::HostTensor;

/// Opaque per-worker scratch an [`Executable`] may reuse across calls.
/// Program entry points are stateless by contract, so any reusable
/// working memory (the native engine's `Workspace`/`CastScratch`) has to
/// be owned by the *caller* and threaded back in — this trait is that
/// hand-back channel, kept opaque so the seam stays backend-agnostic.
/// A long-lived serving worker allocates one scratch per model it runs
/// and hands it to every batch, collapsing the per-call hot-path
/// allocations to zero.
pub trait Scratch: Send {
    fn as_any(&mut self) -> &mut dyn Any;
}

/// The no-op scratch backends without reusable state hand out.
struct NoScratch;

impl Scratch for NoScratch {
    fn as_any(&mut self) -> &mut dyn Any {
        self
    }
}

/// A loaded, runnable program.
pub trait Executable: Send + Sync {
    /// The entry-point name this executable was loaded for.
    fn entry(&self) -> &str;

    /// Execute with borrowed inputs — the trainer's hot path (no clone of
    /// the 3P-tensor optimizer state per step).
    fn run_refs(&self, inputs: &[&HostTensor]) -> Result<Vec<HostTensor>>;

    /// Execute with owned inputs.
    fn run(&self, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let refs: Vec<&HostTensor> = inputs.iter().collect();
        self.run_refs(&refs)
    }

    /// Allocate a reusable scratch for this program.  Callers that run
    /// the same program repeatedly (the serve inference workers) keep one
    /// per worker and pass it to [`Executable::run_refs_scratch`].
    fn make_scratch(&self) -> Box<dyn Scratch> {
        Box::new(NoScratch)
    }

    /// Execute with borrowed inputs, reusing `scratch` for working
    /// memory.  The default ignores the scratch — backends without
    /// reusable state stay correct for free.
    fn run_refs_scratch(
        &self,
        inputs: &[&HostTensor],
        _scratch: &mut dyn Scratch,
    ) -> Result<Vec<HostTensor>> {
        self.run_refs(inputs)
    }

    /// Open an incremental-decode session for a `"decode"` executable.
    /// The returned [`DecodeSession`] is CAST's analog of a KV cache: it
    /// persists per-layer cluster assignments, per-cluster K/V slots, and
    /// running cluster summaries across steps so each generated token
    /// costs O(α) instead of a full O(αN) forward.  Backends that do not
    /// implement decode keep the default and bail.
    fn decode_begin(&self) -> Result<Box<dyn DecodeSession>> {
        bail!("backend does not support incremental decode (entry `{}`)", self.entry())
    }

    /// Absorb `tokens` (the prompt, or a chunk of it) into the session
    /// cache without sampling — the chunked prefill path.  May be called
    /// repeatedly; chunking must not change the resulting state.
    fn decode_prefill(
        &self,
        _params: &[&HostTensor],
        _session: &mut dyn DecodeSession,
        _tokens: &[i32],
    ) -> Result<()> {
        bail!("backend does not support incremental decode (entry `{}`)", self.entry())
    }

    /// Absorb one token and return next-token logits over the vocabulary
    /// (length `meta.d_emb`-projected tied-embedding readout, `vocab`
    /// entries).  Bit-identical to re-running the full causal forward
    /// over the whole history — asserted by the parity suite.
    fn decode_step(
        &self,
        _params: &[&HostTensor],
        _session: &mut dyn DecodeSession,
        _token: i32,
    ) -> Result<Vec<f32>> {
        bail!("backend does not support incremental decode (entry `{}`)", self.entry())
    }
}

/// Opaque per-sequence decode state owned by the caller and threaded back
/// into [`Executable::decode_step`], mirroring the [`Scratch`] hand-back
/// pattern: the seam stays backend-agnostic, the native engine downcasts.
pub trait DecodeSession: Send {
    fn as_any(&mut self) -> &mut dyn Any;

    /// Tokens absorbed so far (prompt + generated).
    fn len(&self) -> usize;

    fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// An execution substrate that can load programs for a model config.
pub trait Backend: Send + Sync {
    /// Short backend name for logs/reports ("native", "pjrt", ...).
    fn name(&self) -> &'static str;

    /// Whether this backend can provide `entry` for `manifest` (the
    /// native engine answers from the model config; PJRT from the files
    /// on disk).
    fn supports(&self, manifest: &Manifest, entry: &str) -> bool;

    /// Load (and, where applicable, compile) the program.
    fn load(&self, manifest: &Manifest, entry: &str) -> Result<Arc<dyn Executable>>;
}
