//! `cast` — the L3 coordinator binary.
//!
//! Subcommands:
//!   gen     --out <dir> [--variant V --seq N --nc C --kappa K --depth D]
//!           (write native-runnable manifests; size flags scale the tiny
//!            config, e.g. --seq 2048 --nc 16 --kappa 128 for perf runs)
//!   train   [--dir <artifact-dir>] [--steps N --lr X --warmup N --seed S
//!           --eval-every N --ckpt PATH --ckpt-every N --history PATH
//!           --metrics-out PATH --metrics-every N
//!           --bench-json PATH --assert-improves]
//!           (without --dir: synthesize a native config from
//!            --task/--variant/--seq/--nc/--kappa/--depth/--batch and
//!            train end-to-end with zero artifacts; --ckpt resumes from
//!            the checkpoint — or its digest-valid .prev rotation — when
//!            one exists, --ckpt-every saves mid-run every N steps;
//!            --metrics-out streams one JSON line per step — loss, lr,
//!            grad_norm, nan_skips, steps/s, plus per-op time shares
//!            every --metrics-every steps under CAST_TRACE=1;
//!            --bench-json appends a train_steps_per_sec row)
//!   eval    --dir <artifact-dir> [--ckpt PATH --batches N]
//!   bench   --table {1,5} [--task text --steps N --isolate
//!           --seq 1024,2048 --json out.json --append-json BENCH_native.json
//!           --profile --trace-out trace.json]
//!           (--json overwrites; --append-json appends measured rows to
//!            the cross-PR trajectory file — run once normally and once
//!            under CAST_NO_SIMD=1 for the SIMD speedup pair.
//!            --profile turns on the in-process tracer and prints the
//!            per-op self-time share table after the bench; --trace-out
//!            additionally writes Chrome trace-event JSON for Perfetto.
//!            --decode switches to the incremental-decode bench: greedy
//!            generation through the cluster-state cache vs full-forward
//!            recompute per seq length [--kappa K --nc C --prompt N
//!            --max-new N], parity-checked, appending
//!            decode_tokens_per_sec rows under --append-json.
//!            --memory switches to the measured-memory sweep: the
//!            tracking allocator's peak-bytes watermark over the
//!            materializing CAST and vanilla reference kernels per seq
//!            length [--seq 512,1024,.. --batch B --heads H --d D],
//!            printed against the §3.4 analytic model and appending
//!            mem_peak_bytes rows under --append-json)
//!   sweep   [--tasks text,listops --variants all --steps N --seed S
//!           --bench-json PATH]
//!           (variant bake-off: trains every variant × task combination
//!            on synthetic configs and prints the accuracy-vs-throughput
//!            frontier as a markdown table; --bench-json appends one
//!            train_steps_per_sec row per point.  `--ablation` switches
//!            to the Figure-3 kappa ablation: --task <task>
//!            [--steps N --isolate])
//!   viz     --dir <artifact-dir> --out <dir> [--seed S]   (Figure 4)
//!   data    --task <task> [--n N --seq L]            (inspect generators)
//!   inspect --dir <artifact-dir>                      (manifest summary)
//!   memmodel [--seq N --kappa K]                      (§3.4 predictions)
//!   serve   [--addr H:P --dir <d1,d2,..> --ckpt PATH --max-batch N
//!           --max-wait-us U --queue N --conn-workers N --infer-workers N
//!           --deadline-ms MS --breaker-failures N --breaker-cooldown-ms MS
//!           --trace-ring N --seed S --causal | size flags as in train]
//!           (HTTP inference server with dynamic micro-batching; without
//!            --dir it serves a synthetic config built from
//!            --task/--variant/--seq/--nc/--kappa/--depth — zero
//!            artifacts, with --causal forcing the decoder extension so
//!            /generate has a decode entry.  Endpoints: POST /predict,
//!            POST /generate
//!            (streaming NDJSON incremental decode for causal CAST
//!            models), GET /models, POST /models/reload, GET /healthz,
//!            GET /readyz, GET /metrics, GET /debug/trace?n=K,
//!            GET /debug/clusters, POST /admin/shutdown.
//!            --trace-ring N sizes the /debug/trace ring buffer
//!            (default 256 requests); under CAST_CLUSTER_STATS=1 the
//!            /metrics page adds per-model cluster-health gauges and
//!            /debug/clusters returns the same as JSON.
//!            SIGINT/SIGTERM drain gracefully; clients may bound queue
//!            time with an X-Deadline-Ms header, capped by
//!            --deadline-ms.  /metrics exposes parse/queue/batch/
//!            compute/reply stage histograms; under CAST_TRACE=1
//!            responses also carry an X-Stage-Timings header.)
//!   generate [--dir <artifact-dir> --ckpt PATH | size flags as in train]
//!           [--prompt TEXT | --tokens 1,2,3] [--max-new N
//!           --temperature T --seed S --check]
//!           (incremental decoding through the decode entry's
//!            cluster-state cache — tokens stream to stdout as they are
//!            produced.  Without --dir, synthesizes a causal CAST config
//!            from the size flags.  --check re-runs the full causal
//!            forward every step and asserts the incremental logits
//!            match bit-for-bit; --temperature 0 is greedy argmax)
//!   loadgen [--addr H:P --conns N --requests N --model KEY --seq N
//!           --seed S --generate N --bench-json PATH --allow-errors
//!           --client-faults]
//!           (closed-loop client driving a running server; --bench-json
//!            appends a serve_reqs_per_sec row, e.g. to BENCH_native.json
//!            — `make bench-serve` records the batched/unbatched pair.
//!            --generate N switches to streaming POST /generate requests
//!            of N new tokens each, validating each NDJSON stream's
//!            final {"done":…} line.  --client-faults turns a
//!            deterministic residue of requests hostile — slow-loris
//!            bodies and mid-body disconnects — and fails unless the
//!            server sheds every one cleanly)
//!   _job    (internal: isolated child for peak-RSS measurement)
//!
//! Backend selection: CAST_BACKEND=native (default, pure-Rust engine, no
//! artifacts needed beyond manifest.json) or CAST_BACKEND=pjrt (`xla`
//! feature build, executes the AOT HLO files).

use std::path::PathBuf;

use anyhow::{bail, Context, Result};

use cast::analysis;
use cast::bench::{self, memmodel};
use cast::coordinator::sweep::Sweep;
use cast::coordinator::{Job, JobKind};
use cast::data;
use cast::model::{checkpoint, ModelState};
use cast::runtime::{Engine, Executable as _, Manifest, ModelMeta};
use cast::train::{Schedule, TrainConfig, Trainer};
use cast::util::cli::Args;
use cast::util::rng::Rng;

/// Counting allocator (util::memtrack) — a pass-through over `System`
/// whose per-phase peak watermarks power `cast bench --memory`.  The
/// counters are two relaxed atomics per alloc/free; phase *recording*
/// stays behind the CAST_MEMTRACK gate.
#[global_allocator]
static ALLOC: cast::util::memtrack::TrackingAlloc = cast::util::memtrack::TrackingAlloc;

fn main() {
    let args = Args::parse();
    let cmd = args.positional.first().cloned().unwrap_or_else(|| "help".to_string());
    let code = match run(&cmd, &args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn run(cmd: &str, args: &Args) -> Result<()> {
    match cmd {
        "gen" => cmd_gen(args),
        "train" => cmd_train(args),
        "eval" => cmd_eval(args),
        "bench" => cmd_bench(args),
        "sweep" => cmd_sweep(args),
        "viz" => cmd_viz(args),
        "data" => cmd_data(args),
        "inspect" => cmd_inspect(args),
        "memmodel" => cmd_memmodel(args),
        "serve" => cmd_serve(args),
        "generate" => cmd_generate(args),
        "loadgen" => cmd_loadgen(args),
        "_job" => cmd_job(args),
        "help" | "--help" => {
            println!("{}", HELP);
            Ok(())
        }
        other => bail!("unknown subcommand {other:?}; try `cast help`"),
    }
}

const HELP: &str = "cast — CAST reproduction coordinator
  gen | train | eval | bench | sweep | viz | data | inspect | memmodel | serve | generate | loadgen
Quickstart (no artifacts needed — native backend):
  cast gen --out artifacts && cast train --dir artifacts/text_cast_topk_n64_b2_c4_k16
Variant bake-off (Table-2 story; all variants come from the registry):
  cast sweep --tasks text,listops --variants all --steps 200
Serving (zero-artifact smoke):
  cast serve --seq 128 --max-batch 8 &   then   cast loadgen --conns 16 --requests 25
Profiling (per-op time shares + Chrome trace):
  cast bench --table 1 --seq 256 --steps 2 --profile --trace-out trace.json
Memory curves (tracking-allocator peak bytes vs the §3.4 model):
  cast bench --memory --seq 512,1024,2048,4096,8192
See rust/src/main.rs header or DESIGN.md §Serving / §Observability for flags.";

/// Write native-runnable artifact directories (manifest.json only) for
/// the tiny smoke configs — the zero-Python path into train/eval/viz.
/// Size flags (`--seq/--nc/--kappa/--depth/--d/--heads`) scale the tiny
/// geometry so perf benches get e.g. N=2048 configs without the AOT
/// pipeline.
fn cmd_gen(args: &Args) -> Result<()> {
    use cast::runtime::native::{spec::tiny_meta, variants, VARIANTS};
    let out = PathBuf::from(args.str("out", "artifacts"));
    let wanted: Vec<String> = match args.opt_str("variant") {
        Some(v) => {
            variants::AttnVariant::parse(&v)?;
            vec![v]
        }
        None => VARIANTS.iter().map(|s| s.to_string()).collect(),
    };
    let mut dirs = Vec::new();
    for variant in &wanted {
        dirs.push(Manifest::synthetic(apply_size_flags(tiny_meta(variant), args)).save(&out)?);
    }
    if args.opt_str("variant").is_none() {
        // the decoder extension (paper §5.5) rides along in the full set
        let mut meta =
            apply_size_flags(tiny_meta(variants::AttnVariant::CastSa.name()), args);
        meta.causal = true;
        dirs.push(Manifest::synthetic(meta).save(&out)?);
    }
    for d in &dirs {
        println!("wrote {}", d.join("manifest.json").display());
    }
    println!("{} native-runnable config(s) under {}", dirs.len(), out.display());
    Ok(())
}

fn artifact_dir(args: &Args) -> Result<PathBuf> {
    let dir = args.opt_str("dir").context("--dir <artifact-dir> is required")?;
    Ok(PathBuf::from(dir))
}

/// Apply the CLI size flags (`--seq/--nc/--kappa/--depth/--heads/--d/
/// --batch`) to a base config — the one place the geometry-scaling
/// rules live, shared by `cast gen` and the artifact-less `cast train`.
fn apply_size_flags(mut meta: ModelMeta, args: &Args) -> ModelMeta {
    meta.seq_len = args.usize("seq", meta.seq_len);
    // local attention requires seq_len % window == 0; shrink to the
    // nearest divisor so every generated config is runnable
    meta.window = meta.window.min(meta.seq_len).max(1);
    while meta.seq_len % meta.window != 0 {
        meta.window -= 1;
    }
    meta.n_c = args.usize("nc", meta.n_c);
    meta.kappa = args.usize("kappa", meta.kappa);
    meta.depth = args.usize("depth", meta.depth);
    meta.heads = args.usize("heads", meta.heads);
    meta.d = args.usize("d", meta.d);
    meta.batch = args.usize("batch", meta.batch);
    meta
}

/// Synthesize a native-runnable manifest from CLI size flags (the
/// zero-artifact `cast train` path; same scaling rules as `cast gen`).
/// `--causal` opts into the decoder extension (paper §5.5) — required
/// for a zero-artifact `cast serve` to answer `POST /generate`.
fn synthetic_manifest(args: &Args) -> Result<Manifest> {
    use cast::runtime::native::{spec, variants};
    let variant = args.str("variant", variants::DEFAULT.name());
    variants::AttnVariant::parse(&variant)?;
    let mut meta = spec::tiny_meta_for_task(&args.str("task", "text"), &variant)?;
    meta = apply_size_flags(meta, args);
    meta.causal = meta.causal || args.has("causal");
    Ok(Manifest::synthetic(meta))
}

fn cmd_train(args: &Args) -> Result<()> {
    let manifest = match args.opt_str("dir") {
        Some(dir) => Manifest::load(&PathBuf::from(dir))?,
        None => synthetic_manifest(args)?,
    };
    let cfg = TrainConfig {
        steps: args.usize("steps", 200),
        schedule: Schedule::Warmup {
            lr: args.f32("lr", 1e-3),
            warmup: args.usize("warmup", 20),
        },
        seed: args.u64("seed", 0),
        eval_every: args.usize("eval-every", 0),
        eval_batches: args.usize("eval-batches", 8),
        data_workers: args.usize("workers", 2),
        queue_depth: args.usize("queue", 4),
        log_every: args.usize("log-every", 10),
        checkpoint: args.opt_str("ckpt").map(PathBuf::from),
        ckpt_every: args.usize("ckpt-every", 0),
        metrics_out: args.opt_str("metrics-out").map(PathBuf::from),
        metrics_every: args.usize("metrics-every", 50),
    };
    let engine = Engine::auto()?;
    let mut trainer = Trainer::new(engine, manifest, cfg, args.u64("seed", 0) as u32)?;
    if let Some(ckpt) = args.opt_str("ckpt") {
        let path = PathBuf::from(&ckpt);
        if path.exists() || checkpoint::prev_path(&path).exists() {
            trainer.load_checkpoint(&path)?;
            println!("resumed from {ckpt} at step {}", trainer.state.step);
        }
    }
    let report = trainer.run()?;
    if let Some(path) = args.opt_str("history") {
        report.history.save_json(&PathBuf::from(&path))?;
        println!("history -> {path}");
    }
    println!(
        "done: final loss {:.4}, final acc {:.3}, eval acc {:?}, {:.2} steps/s",
        report.final_train_loss,
        report.final_train_acc,
        report.best_eval_acc,
        report.steps_per_sec
    );
    if let Some(path) = args.opt_str("bench-json") {
        let meta = &trainer.manifest.meta;
        let row = cast::bench::train_row_json(
            &trainer.manifest.key,
            &meta.variant,
            meta.seq_len,
            report.steps_per_sec,
        );
        cast::bench::append_bench_row(&PathBuf::from(&path), row)?;
        println!(
            "train bench row -> {path} ({:.2} steps/s, {} threads)",
            report.steps_per_sec,
            Engine::threads()
        );
    }
    if args.has("assert-improves") {
        let first = report
            .history
            .steps
            .first()
            .map(|r| r.loss)
            .context("no training steps recorded")?;
        anyhow::ensure!(
            report.final_train_loss < first,
            "training did not improve: first-step loss {first:.4} vs final {:.4}",
            report.final_train_loss
        );
        println!(
            "improvement check passed: {first:.4} -> {:.4}",
            report.final_train_loss
        );
    }
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let dir = artifact_dir(args)?;
    let manifest = Manifest::load(&dir)?;
    let engine = Engine::auto()?;
    let cfg = TrainConfig { eval_batches: args.usize("batches", 16), ..Default::default() };
    let mut trainer = Trainer::new(engine, manifest, cfg, args.u64("seed", 0) as u32)?;
    if let Some(ckpt) = args.opt_str("ckpt") {
        let (state, _) = checkpoint::load(&PathBuf::from(&ckpt))?;
        trainer.state = state;
    }
    let (acc, loss) = trainer.evaluate(args.usize("batches", 16))?;
    println!("eval: acc {acc:.4} loss {loss:.4}");
    Ok(())
}

fn cmd_bench(args: &Args) -> Result<()> {
    use cast::util::trace;
    if args.has("decode") {
        return cmd_bench_decode(args);
    }
    if args.has("memory") {
        return cmd_bench_memory(args);
    }
    let root = PathBuf::from(args.str("artifacts", "artifacts"));
    let table = args.usize("table", 1);
    let task = args.str("task", "text");
    let steps = args.usize("steps", 5);
    let profile = args.has("profile");
    let isolate = args.has("isolate") && !profile;
    if args.has("isolate") && profile {
        println!("note: --profile needs in-process spans; ignoring --isolate");
    }
    if profile {
        trace::set_enabled(true);
        trace::clear();
    }
    let seq_lens: Vec<usize> = match args.opt_str("seq") {
        Some(s) => s
            .split(',')
            .map(|t| t.trim().parse::<usize>().context("--seq expects comma-separated lengths"))
            .collect::<Result<Vec<usize>>>()?,
        None => vec![1024, 2048, 3072, 4096],
    };
    let (kind, title) = match table {
        1 => (JobKind::TrainEfficiency { steps }, "Table 1: training efficiency (rel. to Transformer)"),
        5 => (JobKind::InferEfficiency { steps }, "Table 5: inference efficiency (rel. to Transformer)"),
        other => bail!("unknown table {other}; know 1 and 5"),
    };
    let rows = bench::efficiency_rows(&root, &task, &seq_lens, kind, isolate)?;
    let baseline = cast::runtime::native::variants::AttnVariant::Vanilla.name();
    let t = bench::table_from_rows(title, baseline, &seq_lens, &rows);
    println!("{}", t.render());
    if profile {
        let tr = trace::drain();
        let stats = trace::summarize(&tr.spans);
        println!("# per-op time share ({} spans)", tr.spans.len());
        print!("{}", trace::render_table(&stats));
        if let Some(path) = args.opt_str("trace-out") {
            std::fs::write(&path, trace::chrome_json(&tr))
                .with_context(|| format!("writing {path}"))?;
            println!("chrome trace -> {path} (load in Perfetto or chrome://tracing)");
        }
        trace::set_enabled(false);
    }
    if let Some(path) = args.opt_str("json") {
        bench::write_bench_json(&PathBuf::from(&path), &rows)?;
        println!("bench json -> {path} ({} rows, {} threads)", rows.len(), Engine::threads());
    }
    if let Some(path) = args.opt_str("append-json") {
        // append to the cross-PR trajectory file (rows + note preserved),
        // e.g. the SIMD vs CAST_NO_SIMD=1 measurement pair in
        // BENCH_native.json
        let p = PathBuf::from(&path);
        bench::append_bench_rows(&p, rows.iter().map(bench::bench_row_json).collect())?;
        println!(
            "appended {} bench row(s) -> {path} (simd={}, {} threads)",
            rows.len(),
            cast::util::simd::enabled(),
            Engine::threads()
        );
    }
    Ok(())
}

/// `cast bench --decode`: incremental-decode throughput.  One greedy
/// generation per sequence length through the decode entry's
/// cluster-state cache, against the full-forward-recompute baseline
/// (sampled, parity-checked), with the early-vs-late tokens/sec split as
/// the constant-per-token evidence.  `--append-json` adds
/// `decode_tokens_per_sec` rows to the cross-PR trajectory file.
fn cmd_bench_decode(args: &Args) -> Result<()> {
    use cast::runtime::native::{spec, variants};
    let variant = args.str("variant", "cast_sa");
    variants::AttnVariant::parse(&variant)?;
    let seq_lens: Vec<usize> = match args.opt_str("seq") {
        Some(s) => s
            .split(',')
            .map(|t| t.trim().parse::<usize>().context("--seq expects comma-separated lengths"))
            .collect::<Result<Vec<usize>>>()?,
        None => vec![128, 256],
    };
    let kappa = args.usize("kappa", 32);
    let engine = Engine::auto()?;
    let mut points = Vec::new();
    println!("# decode bench: incremental cluster-state cache vs full-forward recompute");
    println!(
        "config,seq,prompt,new,decode_tok_s,full_tok_s,speedup,early_tok_s,late_tok_s"
    );
    for &seq in &seq_lens {
        let mut meta = spec::tiny_meta_for_task(&args.str("task", "text"), &variant)?;
        meta.causal = true;
        meta.seq_len = seq;
        meta.kappa = args.usize("kappa", kappa);
        // default Nc so the cluster capacity covers the sequence, the
        // paper's N = Nc·kappa operating point
        meta.n_c = if args.has("nc") {
            args.usize("nc", meta.n_c)
        } else {
            seq.div_ceil(meta.kappa).max(1)
        };
        meta.depth = args.usize("depth", meta.depth);
        meta.heads = args.usize("heads", meta.heads);
        meta.d = args.usize("d", meta.d);
        let prompt_len = args.usize("prompt", (seq / 2).max(2));
        let new_tokens = args.usize("max-new", 64);
        let p = cast::bench::decode_bench(&engine, meta, prompt_len, new_tokens)?;
        println!(
            "{},{},{},{},{:.2},{:.2},{:.2},{:.2},{:.2}",
            p.config,
            p.seq_len,
            p.prompt_len,
            p.new_tokens,
            p.decode_tokens_per_sec,
            p.full_tokens_per_sec,
            p.decode_tokens_per_sec / p.full_tokens_per_sec.max(1e-12),
            p.early_tokens_per_sec,
            p.late_tokens_per_sec
        );
        points.push(p);
    }
    if let Some(path) = args.opt_str("append-json") {
        let pb = PathBuf::from(&path);
        cast::bench::append_bench_rows(
            &pb,
            points.iter().map(cast::bench::decode_row_json).collect(),
        )?;
        println!(
            "appended {} decode row(s) -> {path} (simd={}, {} threads)",
            points.len(),
            cast::util::simd::enabled(),
            Engine::threads()
        );
    }
    Ok(())
}

/// `cast bench --memory`: measured attention memory curves via the
/// tracking allocator.  For each sequence length, runs the materializing
/// CAST and vanilla reference kernels (bench::memory) under a
/// `memtrack::Watermark` and reports the measured peak bytes next to
/// the §3.4 analytic model — the empirical O(αN)-vs-O(N²) evidence.
/// `--append-json` adds `mem_peak_bytes` rows to the trajectory file.
fn cmd_bench_memory(args: &Args) -> Result<()> {
    anyhow::ensure!(
        cast::util::memtrack::installed(),
        "the tracking allocator is not installed in this binary"
    );
    let seqs: Vec<usize> = match args.opt_str("seq") {
        Some(s) => s
            .split(',')
            .map(|t| t.trim().parse::<usize>().context("--seq expects comma-separated lengths"))
            .collect::<Result<Vec<usize>>>()?,
        None => vec![512, 1024, 2048, 4096, 8192],
    };
    let batch = args.usize("batch", 1);
    let heads = args.usize("heads", 2);
    let d = args.usize("d", 64);
    let points = cast::bench::memory_sweep(&seqs, batch, heads, d)?;
    println!("# memory bench: measured peak bytes (tracking allocator) vs the \u{a7}3.4 model");
    println!("config,variant,seq,n_c,kappa,measured_peak_mb,model_mb,measured/model,rss_mb");
    for p in &points {
        println!(
            "{},{},{},{},{},{:.2},{:.2},{:.3},{:.1}",
            p.config,
            p.variant,
            p.seq_len,
            p.n_c,
            p.kappa,
            p.measured_peak_bytes as f64 / 1e6,
            p.model_bytes as f64 / 1e6,
            p.measured_peak_bytes as f64 / (p.model_bytes as f64).max(1.0),
            p.rss_mb
        );
    }
    // doubling ratios: consecutive same-variant points show the growth
    // exponent directly (vanilla -> 4.0, balanced CAST -> ~2^(5/3))
    for variant in ["cast_topk", "vanilla"] {
        let curve: Vec<&cast::bench::MemoryPoint> =
            points.iter().filter(|p| p.variant == variant).collect();
        for w in curve.windows(2) {
            if w[1].seq_len == 2 * w[0].seq_len {
                println!(
                    "# {variant}: N {} -> {} grows peak bytes x{:.2}",
                    w[0].seq_len,
                    w[1].seq_len,
                    w[1].measured_peak_bytes as f64
                        / (w[0].measured_peak_bytes as f64).max(1.0)
                );
            }
        }
    }
    if let Some(path) = args.opt_str("append-json") {
        let pb = PathBuf::from(&path);
        cast::bench::append_bench_rows(
            &pb,
            points.iter().map(cast::bench::memory_row_json).collect(),
        )?;
        println!("appended {} memory row(s) -> {path}", points.len());
    }
    Ok(())
}

/// `cast sweep`: the variant bake-off.  Trains every requested variant ×
/// task combination on synthetic tiny configs and prints the
/// accuracy-vs-throughput frontier (the repo's Table-2 story).
/// `--ablation` keeps the original Figure-3 kappa sweep.
fn cmd_sweep(args: &Args) -> Result<()> {
    if args.has("ablation") {
        let root = PathBuf::from(args.str("artifacts", "artifacts"));
        let task = args.str("task", "text");
        let steps = args.usize("steps", 5);
        let points = bench::ablation_points(&root, &task, steps, args.has("isolate"))?;
        println!("# Figure 3 ablation ({task}): kappa vs loss / memory / steps-per-sec");
        println!("variant,kappa,n_c,steps_per_sec,peak_rss_mb,final_loss");
        for p in &points {
            println!(
                "{},{},{},{:.4},{:.1},{:.4}",
                p.variant,
                p.kappa,
                p.n_c,
                p.result.steps_per_sec,
                p.result.peak_rss_bytes as f64 / 1e6,
                p.result.final_loss
            );
        }
        return Ok(());
    }

    use cast::coordinator::sweep::run_frontier;
    use cast::runtime::native::{variants, VARIANTS};
    let tasks: Vec<String> = args
        .str("tasks", "text,listops")
        .split(',')
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect();
    anyhow::ensure!(!tasks.is_empty(), "--tasks got no task names");
    let wanted = args.str("variants", "all");
    let variant_names: Vec<String> = if wanted == "all" {
        VARIANTS.iter().map(|s| s.to_string()).collect()
    } else {
        wanted.split(',').map(|s| s.trim().to_string()).filter(|s| !s.is_empty()).collect()
    };
    for v in &variant_names {
        variants::AttnVariant::parse(v)?;
    }
    let steps = args.usize("steps", 200);
    let seed = args.u64("seed", 0);
    let engine = Engine::auto()?;
    let refs: Vec<&str> = variant_names.iter().map(|s| s.as_str()).collect();
    let points = run_frontier(&engine, &tasks, &refs, steps, seed)?;

    println!("# variant bake-off: accuracy vs throughput ({steps} steps per config)");
    println!("| variant | task | steps/s | first loss | final loss | train acc | eval acc |");
    println!("|---|---|---|---|---|---|---|");
    for p in &points {
        println!(
            "| {} | {} | {:.2} | {:.4} | {:.4} | {:.3} | {:.3} |",
            p.variant, p.task, p.steps_per_sec, p.first_loss, p.final_loss, p.final_acc, p.eval_acc
        );
    }
    if let Some(path) = args.opt_str("bench-json") {
        let pb = PathBuf::from(&path);
        for p in &points {
            bench::append_bench_row(
                &pb,
                bench::train_row_json(&p.key, &p.variant, p.seq_len, p.steps_per_sec),
            )?;
        }
        println!("appended {} bench row(s) -> {path}", points.len());
    }
    Ok(())
}

fn cmd_viz(args: &Args) -> Result<()> {
    let dir = artifact_dir(args)?;
    let out = PathBuf::from(args.str("out", "viz_out"));
    let manifest = Manifest::load(&dir)?;
    let engine = Engine::auto()?;
    let state = if let Some(ckpt) = args.opt_str("ckpt") {
        checkpoint::load(&PathBuf::from(&ckpt))?.0
    } else {
        ModelState::init(&engine, &manifest, args.u64("seed", 0) as u32)?
    };
    let gen = data::task(&manifest.meta.task)?;
    let mut rng = Rng::new(args.u64("seed", 0) ^ 0xF19);
    let batch = data::make_batch(gen.as_ref(), &mut rng, manifest.meta.batch, manifest.meta.seq_len);
    let files = analysis::visualize_image_clusters(
        &engine,
        &manifest,
        &state,
        &batch.tokens,
        args.usize("index", 0),
        &out,
    )?;
    println!("wrote {} files to {}", files.len(), out.display());
    Ok(())
}

fn cmd_data(args: &Args) -> Result<()> {
    let task = args.str("task", "listops");
    let gen = data::task(&task)?;
    let n = args.usize("n", 3);
    let default_seq = match task.as_str() {
        "image" | "pathfinder" => 1024,
        "pathx" => 16384,
        _ => 256,
    };
    let seq = args.usize("seq", default_seq);
    let mut rng = Rng::new(args.u64("seed", 0));
    for i in 0..n {
        let ex = gen.example(&mut rng, seq);
        println!("--- example {i}: label {}", ex.label);
        if task == "text" || task == "retrieval" {
            let text: String =
                ex.tokens.iter().take(160).map(|&t| t as u8 as char).collect();
            println!("{text}...");
        } else {
            println!("{:?}...", &ex.tokens[..32.min(ex.tokens.len())]);
        }
    }
    Ok(())
}

fn cmd_inspect(args: &Args) -> Result<()> {
    let dir = artifact_dir(args)?;
    let manifest = Manifest::load(&dir)?;
    let m = &manifest.meta;
    println!("key:        {}", manifest.key);
    println!("task:       {} ({} classes, dual={})", m.task, m.n_classes, m.dual);
    println!("variant:    {}", m.variant);
    println!("shape:      seq {} batch {} depth {} h {} d {} d_ff {}", m.seq_len, m.batch, m.depth, m.heads, m.d, m.d_ff);
    println!("clusters:   Nc {} kappa {}", m.n_c, m.kappa);
    println!("params:     {} tensors, {} elems", manifest.n_params(), manifest.total_param_elems());
    println!("artifacts:  {:?}", manifest.files.iter().map(|(k, _)| k.clone()).collect::<Vec<_>>());
    Ok(())
}

fn cmd_memmodel(args: &Args) -> Result<()> {
    let seq = args.usize("seq", 4096);
    let heads = args.usize("heads", 4);
    let d = args.usize("d", 64);
    let batch = args.usize("batch", 25);
    println!("# analytic attention-memory model (paper §3.4), N={seq}");
    println!("kappa,n_c,cast_bytes,vanilla_bytes,ratio,alpha");
    for kappa in [32, 64, 128, 200, 256, 512, 1024] {
        let n_c = seq.div_ceil(kappa).max(1);
        let s = memmodel::AttnShape { batch, seq, heads, d, n_c, kappa };
        println!(
            "{kappa},{n_c},{},{},{:.4},{}",
            s.cast_attn_bytes(),
            s.vanilla_attn_bytes(),
            s.memory_ratio(),
            s.alpha()
        );
    }
    println!("\n# fused-kernel TPU estimate (DESIGN.md §Hardware-Adaptation)");
    println!("kappa,vmem_kb,flops,hbm_bytes,intensity");
    for kappa in [128, 256, 512] {
        let est = memmodel::kernel_estimate(kappa, d / heads);
        println!(
            "{kappa},{:.1},{},{},{:.1}",
            est.vmem_bytes as f64 / 1024.0,
            est.mxu_flops,
            est.hbm_bytes,
            est.arithmetic_intensity
        );
    }
    Ok(())
}

/// `cast serve`: load the requested models into a registry and run the
/// micro-batching HTTP server until SIGINT/SIGTERM or /admin/shutdown.
fn cmd_serve(args: &Args) -> Result<()> {
    use cast::serve::{install_signal_handlers, ModelSource, Registry, ServeConfig, Server};
    let engine = Engine::auto()?;
    let breaker_failures = args.u64("breaker-failures", 5) as u32;
    let breaker_cooldown = std::time::Duration::from_millis(args.u64("breaker-cooldown-ms", 5000));
    anyhow::ensure!(breaker_failures > 0, "--breaker-failures must be at least 1");
    let registry =
        std::sync::Arc::new(Registry::with_breaker(engine, breaker_failures, breaker_cooldown));
    let seed = args.u64("seed", 0) as u32;
    match args.opt_str("dir") {
        Some(dirs) => {
            let ckpt = args.opt_str("ckpt").map(PathBuf::from);
            let list: Vec<&str> =
                dirs.split(',').map(|s| s.trim()).filter(|s| !s.is_empty()).collect();
            anyhow::ensure!(!list.is_empty(), "--dir got no directories");
            anyhow::ensure!(
                ckpt.is_none() || list.len() == 1,
                "--ckpt applies to exactly one --dir (got {})",
                list.len()
            );
            for d in &list {
                registry.load(
                    None,
                    ModelSource::Dir { dir: PathBuf::from(d), ckpt: ckpt.clone(), seed },
                )?;
            }
        }
        None => {
            // zero-artifact path: synthesize from the size flags, like
            // the artifact-less `cast train`
            let manifest = synthetic_manifest(args)?;
            registry.load(None, ModelSource::Synthetic { meta: manifest.meta.clone(), seed })?;
        }
    }
    let cfg = ServeConfig {
        addr: args.str("addr", "127.0.0.1:8477"),
        max_batch: args.usize("max-batch", 8),
        max_wait: std::time::Duration::from_micros(args.u64("max-wait-us", 2000)),
        queue_cap: args.usize("queue", 256),
        conn_workers: args.usize("conn-workers", 32),
        infer_workers: args.usize("infer-workers", 1),
        max_body: args.usize("max-body", 8 << 20),
        deadline_ms: args.u64("deadline-ms", 60_000),
        breaker_failures,
        breaker_cooldown,
        trace_ring: args.usize("trace-ring", 256),
    };
    install_signal_handlers();
    let server = Server::bind(cfg, registry)?;
    println!(
        "serving on http://{} — endpoints: POST /predict, POST /generate, GET /models, \
         POST /models/reload, GET /healthz, GET /readyz, GET /metrics, GET /debug/trace, \
         GET /debug/clusters, POST /admin/shutdown (ctrl-c drains gracefully)",
        server.local_addr()
    );
    server.run()
}

/// `cast generate`: incremental decoding at the CLI — stream tokens
/// from a causal CAST model through the decode entry's cluster-state
/// cache.  `--check` re-runs the full causal forward at every step and
/// asserts the incremental logits match bit-for-bit (the CI parity
/// smoke); without it, per-token cost stays O(α) regardless of how much
/// history has accumulated.
fn cmd_generate(args: &Args) -> Result<()> {
    use cast::runtime::native::decode;
    use std::io::Write as _;
    let manifest = match args.opt_str("dir") {
        Some(dir) => Manifest::load(&PathBuf::from(dir))?,
        None => {
            // zero-artifact path: the size flags, forced causal (the
            // decode entry only exists for causal CAST configs)
            use cast::runtime::native::{spec, variants};
            let variant = args.str("variant", "cast_sa");
            variants::AttnVariant::parse(&variant)?;
            let mut meta = spec::tiny_meta_for_task(&args.str("task", "text"), &variant)?;
            meta = apply_size_flags(meta, args);
            meta.causal = true;
            Manifest::synthetic(meta)
        }
    };
    let engine = Engine::auto()?;
    let exe = engine.load(&manifest, "decode")?;
    let state = if let Some(ckpt) = args.opt_str("ckpt") {
        checkpoint::load(&PathBuf::from(&ckpt))?.0
    } else {
        ModelState::init(&engine, &manifest, args.u64("seed", 0) as u32)?
    };
    let params: Vec<&cast::runtime::HostTensor> = state.params.iter().collect();
    let vocab = manifest.meta.vocab as i32;
    let prompt: Vec<i32> = match args.opt_str("tokens") {
        Some(s) => s
            .split(',')
            .map(|t| t.trim().parse::<i32>().context("--tokens expects comma-separated ids"))
            .collect::<Result<Vec<i32>>>()?,
        None => args
            .str("prompt", "the quick brown fox ")
            .bytes()
            .map(|b| (b as i32) % vocab.max(1))
            .collect(),
    };
    anyhow::ensure!(!prompt.is_empty(), "empty prompt");
    anyhow::ensure!(
        prompt.iter().all(|&t| t >= 0 && t < vocab),
        "prompt tokens must be in 0..{vocab}"
    );
    let max_new = args.usize("max-new", 64);
    let temperature = args.f32("temperature", 0.0);
    let check = args.has("check");
    let mut rng = Rng::new(args.u64("seed", 0) ^ 0x9E37);
    let mut session = exe.decode_begin()?;
    let t0 = std::time::Instant::now();
    exe.decode_prefill(&params, session.as_mut(), &prompt[..prompt.len() - 1])?;
    let prefill_s = t0.elapsed().as_secs_f64();
    let mut history = prompt.clone();
    let mut next = *prompt.last().unwrap();
    let is_text = manifest.meta.task == "text";
    let stdout = std::io::stdout();
    let mut out = stdout.lock();
    let t1 = std::time::Instant::now();
    for _ in 0..max_new {
        let logits = exe.decode_step(&params, session.as_mut(), next)?;
        if check {
            let reference = decode::full_logits(&manifest, &params, &history)?;
            anyhow::ensure!(
                logits == reference,
                "parity failure at history length {}: incremental decode diverged from the full causal forward",
                history.len()
            );
        }
        let tok = decode::sample(&logits, temperature, &mut rng) as i32;
        if is_text {
            write!(out, "{}", (tok as u8) as char)?;
        } else {
            write!(out, "{tok} ")?;
        }
        out.flush()?;
        history.push(tok);
        next = tok;
    }
    let decode_s = t1.elapsed().as_secs_f64();
    writeln!(out)?;
    println!(
        "generated {max_new} tokens (prompt {}) in {prefill_s:.2}s prefill + {decode_s:.2}s decode \
         -> {:.2} tok/s{}",
        prompt.len(),
        max_new as f64 / decode_s.max(1e-9),
        if check { "; parity check passed" } else { "" }
    );
    Ok(())
}

/// `cast loadgen`: drive a running server closed-loop and report
/// requests/sec + exact client-side p50/p99 latency.
fn cmd_loadgen(args: &Args) -> Result<()> {
    let cfg = cast::serve::LoadgenConfig {
        addr: args.str("addr", "127.0.0.1:8477"),
        conns: args.usize("conns", 16),
        requests: args.usize("requests", 25),
        model: args.opt_str("model"),
        seq: if args.has("seq") { Some(args.usize("seq", 0)) } else { None },
        seed: args.u64("seed", 0),
        generate: if args.has("generate") { Some(args.usize("generate", 16)) } else { None },
        client_faults: args.has("client-faults"),
    };
    let report = cast::serve::loadgen::run(&cfg)?;
    println!(
        "loadgen: {} ok / {} errors in {:.2}s -> {:.2} req/s  p50 {:.2} ms  p99 {:.2} ms  \
         (model {}, {} tokens/req, {} conns, server max_batch {}, largest batch seen {})",
        report.ok,
        report.errors,
        report.elapsed_s,
        report.reqs_per_sec,
        report.p50_ms,
        report.p99_ms,
        report.model,
        report.seq_len,
        report.conns,
        report.server_max_batch,
        report.batch_rows_max
    );
    if report.staged > 0 {
        // server-side split from X-Stage-Timings (emitted when the
        // server runs with CAST_TRACE=1)
        println!(
            "stage split ({} traced responses): queue {:.2} ms  compute {:.2} ms mean",
            report.staged, report.stage_queue_ms, report.stage_compute_ms
        );
    }
    if report.errors > 0 || report.retried > 0 {
        println!(
            "loadgen errors: {} connect, {} stale-conn, {} non-200, {} transport \
             ({} stale retries succeeded transparently)",
            report.err_connect,
            report.err_stale,
            report.err_status,
            report.err_transport,
            report.retried
        );
    }
    let faults = report.faults_slowloris + report.faults_disconnect;
    if faults > 0 {
        println!(
            "client faults: {} slow-loris + {} mid-body disconnects injected, {} shed cleanly",
            report.faults_slowloris, report.faults_disconnect, report.faults_shed
        );
    }
    if let Some(path) = args.opt_str("bench-json") {
        cast::bench::append_bench_row(&PathBuf::from(&path), cast::bench::serve_row_json(&report))?;
        println!("serve bench row -> {path}");
    }
    if report.errors > 0 && !args.has("allow-errors") {
        bail!("{} of {} requests failed", report.errors, report.ok + report.errors);
    }
    if faults > report.faults_shed && !args.has("allow-errors") {
        bail!(
            "{} of {faults} injected client faults were not shed cleanly",
            faults - report.faults_shed
        );
    }
    Ok(())
}

/// Internal: run one job in this (child) process and print the result JSON.
fn cmd_job(args: &Args) -> Result<()> {
    let dir = artifact_dir(args)?;
    let steps = args.usize("steps", 5);
    let seed = args.u64("seed", 7);
    let kind = match args.str("kind", "train_eff").as_str() {
        "train" => JobKind::Train { steps, lr: 1e-3, warmup: steps / 10 },
        "train_eff" => JobKind::TrainEfficiency { steps },
        "infer_eff" => JobKind::InferEfficiency { steps },
        other => bail!("unknown job kind {other:?}"),
    };
    let sweep = Sweep::new();
    let engine = Engine::auto()?;
    let job = Job { artifact_dir: dir, kind, seed };
    let result = sweep.run_inprocess(&engine, &job)?;
    println!("{}", result.to_json().to_string());
    Ok(())
}
