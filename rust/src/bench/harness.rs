//! Efficiency harness: builds the paper's Table-1 / Table-5 / Figure-3
//! measurements out of coordinator jobs, with child-process isolation for
//! peak-memory fidelity (see `coordinator::sweep`), plus the
//! machine-readable `BENCH_native.json` emitter that tracks the perf
//! trajectory across PRs.

use std::path::Path;

use anyhow::{Context, Result};

use crate::coordinator::sweep::{jobs_matching, Sweep};
use crate::coordinator::{JobKind, JobResult};
use crate::runtime::Engine;
use crate::util::json::Json;

use super::tables::RelativeTable;

/// One measured efficiency cell, raw (before relative normalization).
#[derive(Clone, Debug)]
pub struct BenchRow {
    /// Full artifact key, e.g. `text_cast_topk_n2048_b2_c10_k200`.
    pub config: String,
    pub variant: String,
    pub seq_len: usize,
    pub result: JobResult,
}

/// Run efficiency jobs for every artifact whose key matches `task` at the
/// given sequence lengths; returns the raw measured rows.
pub fn efficiency_rows(
    artifacts_root: &Path,
    task: &str,
    seq_lens: &[usize],
    kind: JobKind,
    isolate: bool,
) -> Result<Vec<BenchRow>> {
    let sweep = Sweep::new();
    let engine = Engine::auto()?;
    let task_owned = task.to_string();
    let wanted: Vec<usize> = seq_lens.to_vec();
    let jobs = jobs_matching(
        artifacts_root,
        move |key| {
            // only the efficiency-suite configs (batch 2) at the requested
            // sequence lengths — not the tiny/LRA/ablation artifacts that
            // share the task prefix
            key.starts_with(&format!("{task_owned}_"))
                && key.contains("_b2")
                && parse_key(key).map(|(_, seq)| wanted.contains(&seq)).unwrap_or(false)
        },
        kind,
        7,
    );
    anyhow::ensure!(
        !jobs.is_empty(),
        "no artifacts for task {task:?} at N={seq_lens:?} under {artifacts_root:?} — \
         run `make artifacts-efficiency` (or `cast gen` for native smoke configs) first"
    );
    let mut rows = Vec::new();
    for (job, res) in sweep.run_all(&engine, &jobs, isolate) {
        let key = job.artifact_dir.file_name().unwrap().to_string_lossy().to_string();
        match res {
            Ok(result) => {
                if let Some((variant, seq)) = parse_key(&key) {
                    if seq_lens.contains(&seq) {
                        rows.push(BenchRow { config: key, variant, seq_len: seq, result });
                    }
                }
            }
            Err(e) => crate::info!("skipping {key}: {e:#}"),
        }
    }
    Ok(rows)
}

/// Assemble the paper-style relative table from raw rows.
pub fn table_from_rows(
    title: &str,
    baseline: &str,
    seq_lens: &[usize],
    rows: &[BenchRow],
) -> RelativeTable {
    let mut table = RelativeTable::new(title, baseline, seq_lens.to_vec());
    for row in rows {
        table.insert(&row.variant, row.seq_len, row.result.clone());
    }
    table
}

/// Back-compat: measure and assemble the relative table in one call.
pub fn efficiency_table(
    artifacts_root: &Path,
    task: &str,
    seq_lens: &[usize],
    kind: JobKind,
    isolate: bool,
    title: &str,
) -> Result<RelativeTable> {
    let rows = efficiency_rows(artifacts_root, task, seq_lens, kind, isolate)?;
    let baseline = crate::runtime::native::variants::AttnVariant::Vanilla.name();
    Ok(table_from_rows(title, baseline, seq_lens, &rows))
}

/// One row of the `BENCH_native.json` schema.  `simd` records whether
/// the 8-lane kernels were live (false = `CAST_NO_SIMD=1` scalar
/// reference), so SIMD-vs-scalar pairs are distinguishable in the
/// trajectory file.
fn row_json(
    config: &str,
    variant: &str,
    seq_len: usize,
    kind: &str,
    steps_per_sec: f64,
    peak_rss_mb: f64,
    threads: usize,
) -> Json {
    Json::obj(vec![
        ("config", Json::str(config)),
        ("variant", Json::str(variant)),
        ("seq_len", Json::num(seq_len as f64)),
        ("kind", Json::str(kind)),
        ("steps_per_sec", Json::num(steps_per_sec)),
        ("peak_rss_mb", Json::num(peak_rss_mb)),
        ("threads", Json::num(threads as f64)),
        ("simd", Json::Bool(crate::util::simd::enabled())),
    ])
}

/// One measured efficiency row in the `BENCH_native.json` schema — the
/// `cast bench --append-json` form of [`bench_json`], for appending a
/// SIMD/scalar measurement pair to the cross-PR trajectory file via
/// [`append_bench_row`].
pub fn bench_row_json(row: &BenchRow) -> Json {
    row_json(
        &row.config,
        &row.variant,
        row.seq_len,
        &row.result.kind,
        row.result.steps_per_sec,
        row.result.peak_rss_bytes as f64 / 1e6,
        Engine::threads(),
    )
}

/// Serialize measured rows as the `BENCH_native.json` schema:
/// `{backend, threads, rows: [{config, variant, seq_len, steps_per_sec,
/// peak_rss_mb, threads}]}` — one stable machine-readable file so the
/// perf trajectory is comparable across PRs.
pub fn bench_json(rows: &[BenchRow]) -> Json {
    Json::obj(vec![
        ("backend", Json::str("native")),
        ("threads", Json::num(Engine::threads() as f64)),
        ("rows", Json::Arr(rows.iter().map(bench_row_json).collect())),
    ])
}

/// Write [`bench_json`] to `path`.
pub fn write_bench_json(path: &Path, rows: &[BenchRow]) -> Result<()> {
    std::fs::write(path, bench_json(rows).to_string() + "\n")
        .with_context(|| format!("writing bench json {path:?}"))
}

/// A `train_steps_per_sec` row in the same schema — what
/// `cast train --bench-json` appends after an end-to-end training run.
pub fn train_row_json(config: &str, variant: &str, seq_len: usize, steps_per_sec: f64) -> Json {
    let peak_mb =
        crate::util::peak_rss_bytes().map(|b| b as f64 / 1e6).unwrap_or(0.0);
    row_json(
        config,
        variant,
        seq_len,
        "train_steps_per_sec",
        steps_per_sec,
        peak_mb,
        Engine::threads(),
    )
}

/// A `serve_reqs_per_sec` row in the same schema — what `cast loadgen
/// --bench-json` appends after driving a running server.  The shared
/// `steps_per_sec` field carries requests/sec so cross-PR tooling reads
/// one schema; the serve-specific fields (client-side exact latency
/// percentiles, the loadgen concurrency) ride alongside.
pub fn serve_row_json(report: &crate::serve::LoadReport) -> Json {
    Json::obj(vec![
        ("config", Json::str(&report.model)),
        ("variant", Json::str("serve")),
        ("seq_len", Json::num(report.seq_len as f64)),
        ("kind", Json::str("serve_reqs_per_sec")),
        ("steps_per_sec", Json::num(report.reqs_per_sec)),
        ("p50_ms", Json::num(report.p50_ms)),
        ("p99_ms", Json::num(report.p99_ms)),
        ("max_batch", Json::num(report.server_max_batch as f64)),
        ("batch_rows_max", Json::num(report.batch_rows_max as f64)),
        ("conns", Json::num(report.conns as f64)),
        ("requests", Json::num((report.ok + report.errors) as f64)),
        ("errors", Json::num(report.errors as f64)),
        ("retried", Json::num(report.retried as f64)),
        ("err_connect", Json::num(report.err_connect as f64)),
        ("err_stale", Json::num(report.err_stale as f64)),
        ("err_status", Json::num(report.err_status as f64)),
        ("err_transport", Json::num(report.err_transport as f64)),
        // queue-vs-compute split from the server's X-Stage-Timings
        // header (zeros unless the server ran with CAST_TRACE on)
        ("staged", Json::num(report.staged as f64)),
        ("stage_queue_ms", Json::num(report.stage_queue_ms)),
        ("stage_compute_ms", Json::num(report.stage_compute_ms)),
        ("peak_rss_mb", Json::num(0.0)),
        ("threads", Json::num(Engine::threads() as f64)),
        ("simd", Json::Bool(crate::util::simd::enabled())),
    ])
}

/// One measured incremental-decode point: tokens/sec through the decode
/// entry's cluster-state cache, against the full-forward recompute
/// baseline over the same greedy history, plus early/late segment
/// throughput (a flat early:late ratio is the evidence that per-token
/// cost does not grow with generated length).
#[derive(Clone, Debug)]
pub struct DecodePoint {
    pub config: String,
    pub variant: String,
    pub seq_len: usize,
    pub prompt_len: usize,
    pub new_tokens: usize,
    pub decode_tokens_per_sec: f64,
    /// Baseline: re-running the whole causal forward per token, sampled
    /// at evenly spaced history lengths across the generation.
    pub full_tokens_per_sec: f64,
    /// Tokens/sec over the first third of the generation…
    pub early_tokens_per_sec: f64,
    /// …and over the last third (≈ equal ⇒ O(α) per token, not O(αN)).
    pub late_tokens_per_sec: f64,
}

/// Measure one greedy generation through the decode seam.  Every sampled
/// baseline step also asserts bit-parity with the incremental logits, so
/// a bench run doubles as a correctness check.
pub fn decode_bench(
    engine: &std::sync::Arc<Engine>,
    meta: crate::runtime::ModelMeta,
    prompt_len: usize,
    new_tokens: usize,
) -> Result<DecodePoint> {
    use std::time::Instant;

    use crate::model::ModelState;
    use crate::runtime::native::decode;
    use crate::runtime::{Executable as _, Manifest};

    anyhow::ensure!(prompt_len >= 2, "decode bench needs a prompt of at least 2 tokens");
    anyhow::ensure!(new_tokens >= 3, "decode bench needs at least 3 new tokens");
    let manifest = Manifest::synthetic(meta);
    let state = ModelState::init(engine, &manifest, 7)?;
    let params: Vec<&crate::runtime::HostTensor> = state.params.iter().collect();
    let exe = engine.load(&manifest, "decode")?;
    let vocab = manifest.meta.vocab;
    let mut rng = crate::util::rng::Rng::new(0xDEC0DE);
    let prompt: Vec<i32> = (0..prompt_len).map(|_| rng.below(vocab) as i32).collect();

    let mut session = exe.decode_begin()?;
    exe.decode_prefill(&params, session.as_mut(), &prompt[..prompt.len() - 1])?;
    let mut history = prompt.clone();
    let mut next = *prompt.last().unwrap();
    let mut step_s: Vec<f64> = Vec::with_capacity(new_tokens);
    let stride = (new_tokens / 8).max(1);
    let (mut full_s, mut full_n) = (0.0f64, 0usize);
    for i in 0..new_tokens {
        let t = Instant::now();
        let logits = exe.decode_step(&params, session.as_mut(), next)?;
        step_s.push(t.elapsed().as_secs_f64());
        if i % stride == 0 {
            // sampled full-forward baseline at this exact history
            let t = Instant::now();
            let full = decode::full_logits(&manifest, &params, &history)?;
            full_s += t.elapsed().as_secs_f64();
            full_n += 1;
            anyhow::ensure!(
                full == logits,
                "decode bench parity failure at step {i} (history {})",
                history.len()
            );
        }
        let tok = decode::argmax(&logits) as i32;
        history.push(tok);
        next = tok;
    }
    let total: f64 = step_s.iter().sum();
    let third = (new_tokens / 3).max(1);
    let early: f64 = step_s[..third].iter().sum();
    let late: f64 = step_s[step_s.len() - third..].iter().sum();
    Ok(DecodePoint {
        config: manifest.key.clone(),
        variant: manifest.meta.variant.clone(),
        seq_len: manifest.meta.seq_len,
        prompt_len,
        new_tokens,
        decode_tokens_per_sec: new_tokens as f64 / total.max(1e-12),
        full_tokens_per_sec: full_n as f64 / full_s.max(1e-12),
        early_tokens_per_sec: third as f64 / early.max(1e-12),
        late_tokens_per_sec: third as f64 / late.max(1e-12),
    })
}

/// A `decode_tokens_per_sec` row in the `BENCH_native.json` schema —
/// what `cast bench --decode --append-json` appends.  `steps_per_sec`
/// carries incremental tokens/sec so cross-PR tooling reads one schema;
/// the baseline and early/late split ride alongside.
pub fn decode_row_json(p: &DecodePoint) -> Json {
    Json::obj(vec![
        ("config", Json::str(&p.config)),
        ("variant", Json::str(&p.variant)),
        ("seq_len", Json::num(p.seq_len as f64)),
        ("kind", Json::str("decode_tokens_per_sec")),
        ("steps_per_sec", Json::num(p.decode_tokens_per_sec)),
        ("full_tokens_per_sec", Json::num(p.full_tokens_per_sec)),
        (
            "speedup",
            Json::num(p.decode_tokens_per_sec / p.full_tokens_per_sec.max(1e-12)),
        ),
        ("prompt_len", Json::num(p.prompt_len as f64)),
        ("new_tokens", Json::num(p.new_tokens as f64)),
        ("early_tokens_per_sec", Json::num(p.early_tokens_per_sec)),
        ("late_tokens_per_sec", Json::num(p.late_tokens_per_sec)),
        ("peak_rss_mb", Json::num(0.0)),
        ("threads", Json::num(Engine::threads() as f64)),
        ("simd", Json::Bool(crate::util::simd::enabled())),
    ])
}

/// Append one row to a bench-json file — see [`append_bench_rows`].
pub fn append_bench_row(path: &Path, row: Json) -> Result<()> {
    append_bench_rows(path, vec![row])
}

/// Append rows to a bench-json file in one read-extend-write, preserving
/// any existing rows and the optional top-level `note` (the seed
/// `BENCH_native.json` carries one); creates the file when absent.  An
/// existing file that fails to parse is an error — this file is the
/// cross-PR perf trajectory, never silently reset.
pub fn append_bench_rows(path: &Path, new_rows: Vec<Json>) -> Result<()> {
    let mut rows: Vec<Json> = Vec::new();
    let mut note: Option<Json> = None;
    if let Ok(text) = std::fs::read_to_string(path) {
        let old = Json::parse(&text).map_err(|e| {
            anyhow::anyhow!(
                "existing bench json {path:?} is unparseable ({e}); refusing to overwrite \
                 the perf trajectory — fix or remove the file first"
            )
        })?;
        if let Some(arr) = old.get("rows").and_then(Json::as_arr) {
            rows.extend(arr.iter().cloned());
        }
        note = old.get("note").cloned();
    }
    rows.extend(new_rows);
    let mut fields = vec![
        ("backend", Json::str("native")),
        ("threads", Json::num(Engine::threads() as f64)),
        ("rows", Json::Arr(rows)),
    ];
    if let Some(n) = note {
        fields.push(("note", n));
    }
    std::fs::write(path, Json::obj(fields).to_string() + "\n")
        .with_context(|| format!("appending bench row to {path:?}"))
}

/// Parse `(variant, seq_len)` out of an artifact key like
/// `text_cast_topk_n2048_b2_c10_k200`.
pub fn parse_key(key: &str) -> Option<(String, usize)> {
    let parts: Vec<&str> = key.split('_').collect();
    let n_pos = parts.iter().position(|p| {
        p.starts_with('n') && p[1..].chars().all(|c| c.is_ascii_digit()) && p.len() > 1
    })?;
    let seq: usize = parts[n_pos][1..].parse().ok()?;
    let variant = parts[1..n_pos].join("_");
    Some((variant, seq))
}

/// One measured efficiency point (used by the Figure-3 bench).
#[derive(Clone, Debug)]
pub struct AblationPoint {
    pub task: String,
    pub variant: String,
    pub kappa: usize,
    pub n_c: usize,
    pub result: JobResult,
}

/// Measure every `{task}_{cast_*}` artifact whose key carries `kNNN`,
/// returning points sorted by kappa — the Figure-3 x-axis.
pub fn ablation_points(
    artifacts_root: &Path,
    task: &str,
    steps: usize,
    isolate: bool,
) -> Result<Vec<AblationPoint>> {
    let sweep = Sweep::new();
    let engine = Engine::auto()?;
    let task_owned = task.to_string();
    const SWEEP_KAPPAS: [usize; 5] = [32, 64, 128, 256, 512];
    let jobs = jobs_matching(
        artifacts_root,
        move |key| {
            key.starts_with(&format!("{task_owned}_cast"))
                && key.contains("_b2")
                && key
                    .split('_')
                    .filter(|p| p.starts_with('k'))
                    .next_back()
                    .and_then(|p| p[1..].parse::<usize>().ok())
                    .map(|k| SWEEP_KAPPAS.contains(&k))
                    .unwrap_or(false)
        },
        JobKind::TrainEfficiency { steps },
        11,
    );
    let mut points = Vec::new();
    for (job, res) in sweep.run_all(&engine, &jobs, isolate) {
        let key = job.artifact_dir.file_name().unwrap().to_string_lossy().to_string();
        let result = match res {
            Ok(r) => r,
            Err(e) => {
                crate::info!("skipping {key}: {e:#}");
                continue;
            }
        };
        let (variant, _) = match parse_key(&key) {
            Some(v) => v,
            None => continue,
        };
        let kappa = field(&key, 'k');
        let n_c = field(&key, 'c');
        if let (Some(kappa), Some(n_c)) = (kappa, n_c) {
            points.push(AblationPoint { task: task.to_string(), variant, kappa, n_c, result });
        }
    }
    points.sort_by_key(|p| (p.variant.clone(), p.kappa));
    Ok(points)
}

fn field(key: &str, prefix: char) -> Option<usize> {
    key.split('_')
        .filter(|p| p.starts_with(prefix) && p[1..].chars().all(|c| c.is_ascii_digit()) && p.len() > 1)
        .next_back()
        .and_then(|p| p[1..].parse().ok())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_keys() {
        assert_eq!(
            parse_key("text_cast_topk_n2048_b2_c10_k200"),
            Some(("cast_topk".to_string(), 2048))
        );
        assert_eq!(parse_key("text_vanilla_n1024_b2"), Some(("vanilla".to_string(), 1024)));
        assert_eq!(
            parse_key("image_cast_sa_n1024_b8_c8_k128"),
            Some(("cast_sa".to_string(), 1024))
        );
        assert_eq!(parse_key("garbage"), None);
    }

    #[test]
    fn field_extraction() {
        let key = "text_cast_topk_n2048_b2_c10_k200";
        assert_eq!(field(key, 'k'), Some(200));
        assert_eq!(field(key, 'c'), Some(10));
        assert_eq!(field(key, 'b'), Some(2));
        assert_eq!(field(key, 'z'), None);
    }

    #[test]
    fn append_bench_row_preserves_rows_and_note() {
        let dir = std::env::temp_dir().join("cast_bench_append_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bench.json");
        // seed file with a note and no rows (the BENCH_native.json shape)
        std::fs::write(
            &path,
            r#"{"backend": "native", "threads": null, "rows": [], "note": "seed"}"#,
        )
        .unwrap();
        append_bench_row(
            &path,
            train_row_json("text_cast_topk_n64_b2_c4_k16", "cast_topk", 64, 12.5),
        )
        .unwrap();
        append_bench_row(
            &path,
            train_row_json("text_vanilla_n64_b2", "vanilla", 64, 3.25),
        )
        .unwrap();
        let back = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        let rows = back.get("rows").and_then(Json::as_arr).unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].get("kind").and_then(Json::as_str), Some("train_steps_per_sec"));
        assert_eq!(rows[0].get("steps_per_sec").and_then(Json::as_f64), Some(12.5));
        assert_eq!(rows[1].get("variant").and_then(Json::as_str), Some("vanilla"));
        assert_eq!(back.get("note").and_then(Json::as_str), Some("seed"));
        assert_eq!(back.get("backend").and_then(Json::as_str), Some("native"));
    }

    #[test]
    fn append_bench_row_refuses_to_clobber_corrupt_file() {
        let dir = std::env::temp_dir().join("cast_bench_corrupt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bench.json");
        std::fs::write(&path, "{ this is not json").unwrap();
        let err = append_bench_row(&path, train_row_json("k", "v", 64, 1.0)).unwrap_err();
        assert!(format!("{err:#}").contains("refusing"), "{err:#}");
        // the corrupt file is left untouched for inspection
        assert_eq!(std::fs::read_to_string(&path).unwrap(), "{ this is not json");
    }
}
