//! Efficiency harness: builds the paper's Table-1 / Table-5 / Figure-3
//! measurements out of coordinator jobs, with child-process isolation for
//! peak-memory fidelity (see `coordinator::sweep`), plus the
//! machine-readable `BENCH_native.json` emitter that tracks the perf
//! trajectory across PRs.

use std::path::Path;

use anyhow::{Context, Result};

use crate::coordinator::sweep::{jobs_matching, Sweep};
use crate::coordinator::{JobKind, JobResult};
use crate::runtime::Engine;
use crate::util::json::Json;

use super::tables::RelativeTable;

/// One measured efficiency cell, raw (before relative normalization).
#[derive(Clone, Debug)]
pub struct BenchRow {
    /// Full artifact key, e.g. `text_cast_topk_n2048_b2_c10_k200`.
    pub config: String,
    pub variant: String,
    pub seq_len: usize,
    pub result: JobResult,
}

/// Run efficiency jobs for every artifact whose key matches `task` at the
/// given sequence lengths; returns the raw measured rows.
pub fn efficiency_rows(
    artifacts_root: &Path,
    task: &str,
    seq_lens: &[usize],
    kind: JobKind,
    isolate: bool,
) -> Result<Vec<BenchRow>> {
    let sweep = Sweep::new();
    let engine = Engine::auto()?;
    let task_owned = task.to_string();
    let wanted: Vec<usize> = seq_lens.to_vec();
    let jobs = jobs_matching(
        artifacts_root,
        move |key| {
            // only the efficiency-suite configs (batch 2) at the requested
            // sequence lengths — not the tiny/LRA/ablation artifacts that
            // share the task prefix
            key.starts_with(&format!("{task_owned}_"))
                && key.contains("_b2")
                && parse_key(key).map(|(_, seq)| wanted.contains(&seq)).unwrap_or(false)
        },
        kind,
        7,
    );
    anyhow::ensure!(
        !jobs.is_empty(),
        "no artifacts for task {task:?} at N={seq_lens:?} under {artifacts_root:?} — \
         run `make artifacts-efficiency` (or `cast gen` for native smoke configs) first"
    );
    let mut rows = Vec::new();
    for (job, res) in sweep.run_all(&engine, &jobs, isolate) {
        let key = job.artifact_dir.file_name().unwrap().to_string_lossy().to_string();
        match res {
            Ok(result) => {
                if let Some((variant, seq)) = parse_key(&key) {
                    if seq_lens.contains(&seq) {
                        rows.push(BenchRow { config: key, variant, seq_len: seq, result });
                    }
                }
            }
            Err(e) => crate::info!("skipping {key}: {e:#}"),
        }
    }
    Ok(rows)
}

/// Assemble the paper-style relative table from raw rows.
pub fn table_from_rows(
    title: &str,
    baseline: &str,
    seq_lens: &[usize],
    rows: &[BenchRow],
) -> RelativeTable {
    let mut table = RelativeTable::new(title, baseline, seq_lens.to_vec());
    for row in rows {
        table.insert(&row.variant, row.seq_len, row.result.clone());
    }
    table
}

/// Back-compat: measure and assemble the relative table in one call.
pub fn efficiency_table(
    artifacts_root: &Path,
    task: &str,
    seq_lens: &[usize],
    kind: JobKind,
    isolate: bool,
    title: &str,
) -> Result<RelativeTable> {
    let rows = efficiency_rows(artifacts_root, task, seq_lens, kind, isolate)?;
    Ok(table_from_rows(title, "vanilla", seq_lens, &rows))
}

/// Serialize measured rows as the `BENCH_native.json` schema:
/// `{backend, threads, rows: [{config, variant, seq_len, steps_per_sec,
/// peak_rss_mb, threads}]}` — one stable machine-readable file so the
/// perf trajectory is comparable across PRs.
pub fn bench_json(rows: &[BenchRow]) -> Json {
    let threads = Engine::threads();
    let row_objs: Vec<Json> = rows
        .iter()
        .map(|r| {
            Json::obj(vec![
                ("config", Json::str(&r.config)),
                ("variant", Json::str(&r.variant)),
                ("seq_len", Json::num(r.seq_len as f64)),
                ("kind", Json::str(&r.result.kind)),
                ("steps_per_sec", Json::num(r.result.steps_per_sec)),
                ("peak_rss_mb", Json::num(r.result.peak_rss_bytes as f64 / 1e6)),
                ("threads", Json::num(threads as f64)),
            ])
        })
        .collect();
    Json::obj(vec![
        ("backend", Json::str("native")),
        ("threads", Json::num(threads as f64)),
        ("rows", Json::Arr(row_objs)),
    ])
}

/// Write [`bench_json`] to `path`.
pub fn write_bench_json(path: &Path, rows: &[BenchRow]) -> Result<()> {
    std::fs::write(path, bench_json(rows).to_string() + "\n")
        .with_context(|| format!("writing bench json {path:?}"))
}

/// Parse `(variant, seq_len)` out of an artifact key like
/// `text_cast_topk_n2048_b2_c10_k200`.
pub fn parse_key(key: &str) -> Option<(String, usize)> {
    let parts: Vec<&str> = key.split('_').collect();
    let n_pos = parts.iter().position(|p| {
        p.starts_with('n') && p[1..].chars().all(|c| c.is_ascii_digit()) && p.len() > 1
    })?;
    let seq: usize = parts[n_pos][1..].parse().ok()?;
    let variant = parts[1..n_pos].join("_");
    Some((variant, seq))
}

/// One measured efficiency point (used by the Figure-3 bench).
#[derive(Clone, Debug)]
pub struct AblationPoint {
    pub task: String,
    pub variant: String,
    pub kappa: usize,
    pub n_c: usize,
    pub result: JobResult,
}

/// Measure every `{task}_{cast_*}` artifact whose key carries `kNNN`,
/// returning points sorted by kappa — the Figure-3 x-axis.
pub fn ablation_points(
    artifacts_root: &Path,
    task: &str,
    steps: usize,
    isolate: bool,
) -> Result<Vec<AblationPoint>> {
    let sweep = Sweep::new();
    let engine = Engine::auto()?;
    let task_owned = task.to_string();
    const SWEEP_KAPPAS: [usize; 5] = [32, 64, 128, 256, 512];
    let jobs = jobs_matching(
        artifacts_root,
        move |key| {
            key.starts_with(&format!("{task_owned}_cast"))
                && key.contains("_b2")
                && key
                    .split('_')
                    .filter(|p| p.starts_with('k'))
                    .next_back()
                    .and_then(|p| p[1..].parse::<usize>().ok())
                    .map(|k| SWEEP_KAPPAS.contains(&k))
                    .unwrap_or(false)
        },
        JobKind::TrainEfficiency { steps },
        11,
    );
    let mut points = Vec::new();
    for (job, res) in sweep.run_all(&engine, &jobs, isolate) {
        let key = job.artifact_dir.file_name().unwrap().to_string_lossy().to_string();
        let result = match res {
            Ok(r) => r,
            Err(e) => {
                crate::info!("skipping {key}: {e:#}");
                continue;
            }
        };
        let (variant, _) = match parse_key(&key) {
            Some(v) => v,
            None => continue,
        };
        let kappa = field(&key, 'k');
        let n_c = field(&key, 'c');
        if let (Some(kappa), Some(n_c)) = (kappa, n_c) {
            points.push(AblationPoint { task: task.to_string(), variant, kappa, n_c, result });
        }
    }
    points.sort_by_key(|p| (p.variant.clone(), p.kappa));
    Ok(points)
}

fn field(key: &str, prefix: char) -> Option<usize> {
    key.split('_')
        .filter(|p| p.starts_with(prefix) && p[1..].chars().all(|c| c.is_ascii_digit()) && p.len() > 1)
        .next_back()
        .and_then(|p| p[1..].parse().ok())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_keys() {
        assert_eq!(
            parse_key("text_cast_topk_n2048_b2_c10_k200"),
            Some(("cast_topk".to_string(), 2048))
        );
        assert_eq!(parse_key("text_vanilla_n1024_b2"), Some(("vanilla".to_string(), 1024)));
        assert_eq!(
            parse_key("image_cast_sa_n1024_b8_c8_k128"),
            Some(("cast_sa".to_string(), 1024))
        );
        assert_eq!(parse_key("garbage"), None);
    }

    #[test]
    fn field_extraction() {
        let key = "text_cast_topk_n2048_b2_c10_k200";
        assert_eq!(field(key, 'k'), Some(200));
        assert_eq!(field(key, 'c'), Some(10));
        assert_eq!(field(key, 'b'), Some(2));
        assert_eq!(field(key, 'z'), None);
    }
}
