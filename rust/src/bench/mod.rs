//! Benchmark infrastructure: the measurement harness behind every paper
//! table/figure (`harness`), the analytic complexity model (`memmodel`),
//! the measured-bytes sweep over it (`memory`), and paper-shaped report
//! rendering (`tables`).

pub mod harness;
pub mod memmodel;
pub mod memory;
pub mod tables;

pub use harness::{
    ablation_points, append_bench_row, append_bench_rows, bench_json, bench_row_json,
    decode_bench, decode_row_json, efficiency_rows, efficiency_table, parse_key,
    serve_row_json, table_from_rows, train_row_json, write_bench_json, BenchRow, DecodePoint,
};
pub use memmodel::{kernel_estimate, AttnShape};
pub use memory::{memory_row_json, memory_sweep, MemoryPoint};
pub use tables::{AccuracyTable, RelativeTable};
