//! Table formatting: renders benchmark results in the shape the paper
//! prints them (relative steps/s and peak memory vs the Transformer row).

use std::collections::BTreeMap;

use crate::coordinator::JobResult;

/// A (variant, seq_len) → result grid with a designated baseline variant,
/// reproducing the layout of paper Tables 1 and 5.
pub struct RelativeTable {
    pub title: String,
    pub seq_lens: Vec<usize>,
    pub baseline: String,
    /// variant -> seq_len -> result
    pub cells: BTreeMap<String, BTreeMap<usize, JobResult>>,
}

impl RelativeTable {
    pub fn new(title: &str, baseline: &str, seq_lens: Vec<usize>) -> RelativeTable {
        RelativeTable {
            title: title.to_string(),
            baseline: baseline.to_string(),
            seq_lens,
            cells: BTreeMap::new(),
        }
    }

    pub fn insert(&mut self, variant: &str, seq_len: usize, result: JobResult) {
        self.cells.entry(variant.to_string()).or_default().insert(seq_len, result);
    }

    fn baseline_cell(&self, seq: usize) -> Option<&JobResult> {
        self.cells.get(&self.baseline)?.get(&seq)
    }

    pub fn speed_rel(&self, variant: &str, seq: usize) -> Option<f64> {
        let cell = self.cells.get(variant)?.get(&seq)?;
        let base = self.baseline_cell(seq)?;
        Some(cell.steps_per_sec / base.steps_per_sec)
    }

    pub fn mem_rel(&self, variant: &str, seq: usize) -> Option<f64> {
        let cell = self.cells.get(variant)?.get(&seq)?;
        let base = self.baseline_cell(seq)?;
        if base.peak_rss_bytes == 0 {
            return None;
        }
        Some(cell.peak_rss_bytes as f64 / base.peak_rss_bytes as f64)
    }

    /// Render the paper-style table: one row per variant, relative
    /// steps/s then relative peak memory per sequence length.
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("## {}\n\n", self.title));
        out.push_str("| Model |");
        for s in &self.seq_lens {
            out.push_str(&format!(" sps@{s} ↑ |"));
        }
        for s in &self.seq_lens {
            out.push_str(&format!(" mem@{s} ↓ |"));
        }
        out.push('\n');
        out.push_str("|---|");
        for _ in 0..self.seq_lens.len() * 2 {
            out.push_str("---|");
        }
        out.push('\n');
        for variant in self.cells.keys() {
            out.push_str(&format!("| {variant} |"));
            for &s in &self.seq_lens {
                match self.speed_rel(variant, s) {
                    Some(r) => out.push_str(&format!(" {r:.2} |")),
                    None => out.push_str(" - |"),
                }
            }
            for &s in &self.seq_lens {
                match self.mem_rel(variant, s) {
                    Some(r) => out.push_str(&format!(" {r:.2} |")),
                    None => out.push_str(" - |"),
                }
            }
            out.push('\n');
        }
        out
    }
}

/// Plain accuracy table (paper Table 2 shape): task columns, model rows.
pub struct AccuracyTable {
    pub title: String,
    pub tasks: Vec<String>,
    /// model -> task -> accuracy (percent)
    pub rows: BTreeMap<String, BTreeMap<String, f64>>,
}

impl AccuracyTable {
    pub fn new(title: &str, tasks: &[&str]) -> AccuracyTable {
        AccuracyTable {
            title: title.to_string(),
            tasks: tasks.iter().map(|s| s.to_string()).collect(),
            rows: BTreeMap::new(),
        }
    }

    pub fn insert(&mut self, model: &str, task: &str, acc_pct: f64) {
        self.rows.entry(model.to_string()).or_default().insert(task.to_string(), acc_pct);
    }

    pub fn average(&self, model: &str) -> Option<f64> {
        let row = self.rows.get(model)?;
        if row.is_empty() {
            return None;
        }
        Some(row.values().sum::<f64>() / row.len() as f64)
    }

    pub fn render(&self) -> String {
        let mut out = format!("## {}\n\n| Model |", self.title);
        for t in &self.tasks {
            out.push_str(&format!(" {t} |"));
        }
        out.push_str(" Avg |\n|---|");
        for _ in 0..=self.tasks.len() {
            out.push_str("---|");
        }
        out.push('\n');
        for (model, row) in &self.rows {
            out.push_str(&format!("| {model} |"));
            for t in &self.tasks {
                match row.get(t) {
                    Some(a) => out.push_str(&format!(" {a:.2} |")),
                    None => out.push_str(" - |"),
                }
            }
            match self.average(model) {
                Some(a) => out.push_str(&format!(" {a:.2} |\n")),
                None => out.push_str(" - |\n"),
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn result(sps: f64, rss: u64) -> JobResult {
        JobResult {
            key: "k".into(),
            kind: "train_eff".into(),
            steps_per_sec: sps,
            peak_rss_bytes: rss,
            final_loss: 0.0,
            final_acc: 0.0,
            eval_acc: None,
        }
    }

    #[test]
    fn relative_table_math() {
        let mut t = RelativeTable::new("Table 1", "vanilla", vec![1024, 2048]);
        t.insert("vanilla", 1024, result(1.0, 1000));
        t.insert("vanilla", 2048, result(0.5, 4000));
        t.insert("cast_topk", 1024, result(2.0, 400));
        t.insert("cast_topk", 2048, result(1.5, 700));
        assert_eq!(t.speed_rel("cast_topk", 1024), Some(2.0));
        assert_eq!(t.speed_rel("cast_topk", 2048), Some(3.0));
        assert_eq!(t.mem_rel("cast_topk", 1024), Some(0.4));
        let text = t.render();
        assert!(text.contains("| cast_topk | 2.00 | 3.00 | 0.40 |"), "{text}");
    }

    #[test]
    fn accuracy_table_average() {
        let mut t = AccuracyTable::new("Table 2", &["listops", "text"]);
        t.insert("cast", "listops", 40.0);
        t.insert("cast", "text", 60.0);
        assert_eq!(t.average("cast"), Some(50.0));
        assert!(t.render().contains("| cast | 40.00 | 60.00 | 50.00 |"));
    }

    #[test]
    fn missing_cells_render_dash() {
        let mut t = RelativeTable::new("T", "vanilla", vec![1024]);
        t.insert("cast", 1024, result(2.0, 100));
        assert!(t.render().contains("| cast | - | - |"));
    }
}
