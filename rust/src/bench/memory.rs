//! Measured attention memory: the `cast bench --memory` sweep that
//! turns the §3.4 analytic model (`memmodel`) into measured bytes via
//! the tracking allocator (`util::memtrack`).
//!
//! Both sides run as *materializing reference kernels* that allocate
//! exactly the tensors the §3.4 accounting charges — the vanilla side
//! because the engine deliberately never materializes the N×N score
//! matrix (it streams per-row scratch, see `ops::attend_windows`), so a
//! materializing reference is the only faithful O(N²) baseline; the
//! CAST side in the same style so the two measurements are comparable.
//! Arithmetic inside the kernels is thinned to one MAC per cell: the
//! measured quantity is bytes, not FLOPs.
//!
//! The measured peak therefore decomposes as `model_bytes` (the
//! `memmodel::AttnShape` prediction) plus a shared base of
//! `4·B·N·d` f32 for q/k/v/out — which is what the cross-validation in
//! `tests/integration_memstats.rs` pins: CAST sub-quadratic, vanilla
//! quadratic, measured-vs-model within a constant factor.

use anyhow::Result;

use crate::runtime::Engine;
use crate::util::json::Json;
use crate::util::memtrack;

use super::memmodel::{kappa_memory_curve, AttnShape, BYTES_F32};

/// One measured memory point (one variant at one sequence length).
#[derive(Clone, Debug)]
pub struct MemoryPoint {
    /// Synthetic config key, e.g. `mem_cast_topk_n2048_c8_k256`.
    pub config: String,
    /// "cast_topk" or "vanilla".
    pub variant: String,
    pub seq_len: usize,
    pub n_c: usize,
    pub kappa: usize,
    /// Peak allocator bytes over the reference kernel (tracking
    /// allocator watermark).
    pub measured_peak_bytes: usize,
    /// The §3.4 analytic prediction for the same shape.
    pub model_bytes: usize,
    /// Process peak RSS (VmHWM) after the kernel, for the row's
    /// `peak_rss_mb` field.
    pub rss_mb: f64,
    /// Checksum keeping the kernel's work observable (and honest).
    pub checksum: f32,
}

/// Shared q/k/v/out base the reference kernels allocate on top of the
/// model's attention terms: `4·B·N·d` f32 values.
pub fn base_bytes(shape: &AttnShape) -> usize {
    4 * shape.batch * shape.seq * shape.d * BYTES_F32
}

/// Deterministic pseudo-data without touching the global RNG.
fn fill_vec(len: usize, salt: u32) -> Vec<f32> {
    let mut v = Vec::with_capacity(len);
    for i in 0..len {
        let h = (i as u32).wrapping_add(salt).wrapping_mul(2654435761);
        v.push(((h >> 16) & 0x3ff) as f32 / 1024.0 + 0.01);
    }
    v
}

fn softmax_inplace(row: &mut [f32]) {
    let mut max = f32::NEG_INFINITY;
    for &x in row.iter() {
        if x > max {
            max = x;
        }
    }
    let mut sum = 0.0f32;
    for x in row.iter_mut() {
        *x = (*x - max).exp();
        sum += *x;
    }
    if sum > 0.0 {
        for x in row.iter_mut() {
            *x /= sum;
        }
    }
}

/// Materializing vanilla attention reference: q/k/v, the full
/// `B·h·N·N` score slab (the §3.4 quadratic term), row softmax, and a
/// thinned PV reduction into `out`.  Returns a checksum so the slabs
/// stay observable.
pub fn vanilla_attn_reference(shape: &AttnShape) -> f32 {
    let (b, h, n, d) = (shape.batch, shape.heads, shape.seq, shape.d);
    let rows = b * n;
    let q = fill_vec(rows * d, 1);
    let k = fill_vec(rows * d, 2);
    let v = fill_vec(rows * d, 3);
    let mut scores = vec![0.0f32; b * h * n * n];
    for bh in 0..b * h {
        let bi = bh / h;
        let base = bh * n * n;
        for i in 0..n {
            let qi = q[(bi * n + i) * d];
            for j in 0..n {
                scores[base + i * n + j] = qi * k[(bi * n + j) * d];
            }
        }
    }
    for row in scores.chunks_mut(n) {
        softmax_inplace(row);
    }
    let mut out = vec![0.0f32; rows * d];
    for bh in 0..b * h {
        let bi = bh / h;
        let base = bh * n * n;
        for i in 0..n {
            let mut acc = 0.0f32;
            for j in 0..n {
                acc += scores[base + i * n + j] * v[(bi * n + j) * d];
            }
            out[(bi * n + i) * d] += acc;
        }
    }
    std::hint::black_box(out.iter().sum())
}

/// Materializing CAST attention reference, tensor-for-tensor the §3.4
/// accounting: three `B·N·Nc` affinity blocks (A_q, A_k, A_g), the
/// `B·h·Nc·κ²` intra-cluster score tiles, the `B·N·Nc²` inter-cluster
/// mixing block, plus the shared q/k/v/out base.
pub fn cast_attn_reference(shape: &AttnShape) -> f32 {
    let (b, h, n, d) = (shape.batch, shape.heads, shape.seq, shape.d);
    let (n_c, kappa) = (shape.n_c, shape.kappa);
    let rows = b * n;
    let q = fill_vec(rows * d, 4);
    let k = fill_vec(rows * d, 5);
    let v = fill_vec(rows * d, 6);
    let a_q = fill_vec(rows * n_c, 7);
    let a_k = fill_vec(rows * n_c, 8);
    // A_g = sigm(phi)·f2(ΣA_q) + (1-sigm(phi))·f2(ΣA_k), thinned to a
    // fixed gate — the allocation, not the arithmetic, is the point
    let mut a_g = vec![0.0f32; rows * n_c];
    for (g, (aq, ak)) in a_g.iter_mut().zip(a_q.iter().zip(&a_k)) {
        *g = 0.5 * aq + 0.5 * ak;
    }
    let mut intra = vec![0.0f32; b * h * n_c * kappa * kappa];
    for bh in 0..b * h {
        let bi = bh / h;
        for c in 0..n_c {
            let tile = (bh * n_c + c) * kappa * kappa;
            for i in 0..kappa {
                let qi = q[(bi * n + (c * kappa + i) % n) * d];
                for j in 0..kappa {
                    intra[tile + i * kappa + j] = qi * k[(bi * n + (c * kappa + j) % n) * d];
                }
            }
        }
    }
    for row in intra.chunks_mut(kappa) {
        softmax_inplace(row);
    }
    let mut inter = vec![0.0f32; rows * n_c * n_c];
    for r in 0..rows {
        for c in 0..n_c * n_c {
            inter[r * n_c * n_c + c] = a_g[r * n_c + c % n_c] * a_g[r * n_c + c / n_c];
        }
    }
    let mut out = vec![0.0f32; rows * d];
    for r in 0..rows {
        let mut acc = 0.0f32;
        for c in 0..n_c {
            acc += a_g[r * n_c + c] * v[r * d];
        }
        acc += inter[r * n_c * n_c];
        out[r * d] = acc;
    }
    std::hint::black_box(out.iter().sum::<f32>() + intra[0])
}

/// Measure one variant at one shape: run the reference kernel under a
/// [`memtrack::Watermark`] and report peak bytes.  Errors when the
/// tracking allocator is not installed in this binary (the `cast` CLI
/// and the memstats integration tests install it; plain `cargo test`
/// unit binaries do not).
pub fn memory_point(variant: &str, shape: &AttnShape) -> Result<MemoryPoint> {
    anyhow::ensure!(
        memtrack::installed(),
        "memory bench needs the tracking allocator (#[global_allocator] \
         memtrack::TrackingAlloc) installed in this binary"
    );
    let wm = memtrack::Watermark::begin("bench.memory");
    let (checksum, model_bytes) = match variant {
        "vanilla" => (vanilla_attn_reference(shape), shape.vanilla_attn_bytes()),
        _ => (cast_attn_reference(shape), shape.cast_attn_bytes()),
    };
    let measured_peak_bytes = wm.peak_delta();
    drop(wm);
    let config = if variant == "vanilla" {
        format!("mem_vanilla_n{}_b{}", shape.seq, shape.batch)
    } else {
        format!("mem_{variant}_n{}_b{}_c{}_k{}", shape.seq, shape.batch, shape.n_c, shape.kappa)
    };
    Ok(MemoryPoint {
        config,
        variant: variant.to_string(),
        seq_len: shape.seq,
        n_c: shape.n_c,
        kappa: shape.kappa,
        measured_peak_bytes,
        model_bytes,
        rss_mb: crate::util::peak_rss_bytes().map(|b| b as f64 / 1e6).unwrap_or(0.0),
        checksum,
    })
}

/// Pick the balanced κ for one sequence length off the §3.4 curve: the
/// power-of-two argmin of predicted CAST memory (lands near Nc² = κ).
pub fn balanced_kappa(batch: usize, seq: usize, heads: usize, d: usize) -> usize {
    let mut kappas = Vec::new();
    let mut k = 16usize;
    while k <= (seq / 2).max(16) {
        kappas.push(k);
        k *= 2;
    }
    kappa_memory_curve(batch, seq, heads, d, &kappas)
        .into_iter()
        .min_by_key(|&(_, bytes)| bytes)
        .map(|(kappa, _)| kappa)
        .unwrap_or(16)
        .min(seq.max(1))
}

/// The `cast bench --memory` sweep: cast vs vanilla at each sequence
/// length, CAST at its balanced κ.  Returns cast/vanilla point pairs in
/// seq order.
pub fn memory_sweep(
    seqs: &[usize],
    batch: usize,
    heads: usize,
    d: usize,
) -> Result<Vec<MemoryPoint>> {
    let mut points = Vec::new();
    for &seq in seqs {
        let kappa = balanced_kappa(batch, seq, heads, d);
        let n_c = seq.div_ceil(kappa).max(1);
        let shape = AttnShape { batch, seq, heads, d, n_c, kappa };
        points.push(memory_point("cast_topk", &shape)?);
        points.push(memory_point("vanilla", &shape)?);
    }
    Ok(points)
}

/// A `mem_peak_bytes` row in the `BENCH_native.json` schema — what
/// `cast bench --memory --append-json` appends.  `peak_bytes` is the
/// headline number; `steps_per_sec` is 0 so throughput tooling skips
/// these rows, and `peak_rss_mb` finally carries a real VmHWM.
pub fn memory_row_json(p: &MemoryPoint) -> Json {
    Json::obj(vec![
        ("config", Json::str(&p.config)),
        ("variant", Json::str(&p.variant)),
        ("seq_len", Json::num(p.seq_len as f64)),
        ("kind", Json::str("mem_peak_bytes")),
        ("steps_per_sec", Json::num(0.0)),
        ("peak_bytes", Json::num(p.measured_peak_bytes as f64)),
        ("model_bytes", Json::num(p.model_bytes as f64)),
        ("n_c", Json::num(p.n_c as f64)),
        ("kappa", Json::num(p.kappa as f64)),
        ("peak_rss_mb", Json::num(p.rss_mb)),
        ("threads", Json::num(Engine::threads() as f64)),
        ("simd", Json::Bool(crate::util::simd::enabled())),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    // NOTE: peak-byte *measurements* are exercised in
    // tests/integration_memstats.rs, which installs the tracking
    // allocator in its own test binary; the lib unit-test binary has
    // no #[global_allocator], so these tests cover shapes, kernel
    // liveness, and the row schema.

    #[test]
    fn balanced_kappa_lands_near_nc2_eq_kappa() {
        for seq in [512usize, 2048, 8192] {
            let kappa = balanced_kappa(1, seq, 2, 64);
            let n_c = seq.div_ceil(kappa).max(1);
            let ratio = (n_c * n_c) as f64 / kappa as f64;
            assert!(
                (1.0 / 8.0..=8.0).contains(&ratio),
                "N={seq}: κ={kappa} Nc={n_c} gives Nc²/κ={ratio:.2}"
            );
        }
    }

    #[test]
    fn reference_kernels_produce_finite_checksums() {
        let shape = AttnShape { batch: 1, seq: 64, heads: 2, d: 16, n_c: 4, kappa: 16 };
        assert!(vanilla_attn_reference(&shape).is_finite());
        assert!(cast_attn_reference(&shape).is_finite());
    }

    #[test]
    fn memory_point_requires_the_tracking_allocator() {
        // this binary has no #[global_allocator]; the point must refuse
        // rather than report a bogus zero measurement
        let shape = AttnShape { batch: 1, seq: 64, heads: 2, d: 16, n_c: 4, kappa: 16 };
        let err = memory_point("vanilla", &shape).unwrap_err();
        assert!(format!("{err:#}").contains("tracking allocator"), "{err:#}");
    }

    #[test]
    fn memory_row_schema() {
        let p = MemoryPoint {
            config: "mem_cast_topk_n512_b1_c8_k64".to_string(),
            variant: "cast_topk".to_string(),
            seq_len: 512,
            n_c: 8,
            kappa: 64,
            measured_peak_bytes: 1_000_000,
            model_bytes: 900_000,
            rss_mb: 42.0,
            checksum: 1.0,
        };
        let row = memory_row_json(&p);
        assert_eq!(row.get("kind").and_then(Json::as_str), Some("mem_peak_bytes"));
        assert_eq!(row.get("peak_bytes").and_then(Json::as_f64), Some(1_000_000.0));
        assert_eq!(row.get("model_bytes").and_then(Json::as_f64), Some(900_000.0));
        assert_eq!(row.get("peak_rss_mb").and_then(Json::as_f64), Some(42.0));
        assert_eq!(row.get("steps_per_sec").and_then(Json::as_f64), Some(0.0));
    }
}
