//! Analytic memory / FLOPs model (paper §3.4 + DESIGN.md
//! §Hardware-Adaptation).
//!
//! Reproduces the paper's complexity claims independently of any runtime
//! measurement: attention activation memory and FLOPs per layer for the
//! vanilla Transformer (O(N²)) and CAST (O(α·N), α = max(κ, Nc²)), plus
//! the VMEM footprint / MXU utilization estimate of the Pallas kernel on a
//! hypothetical TPU core.  The `complexity_model` bench regenerates the
//! §3.4 prediction that memory is minimized near Nc² = κ.

/// Shapes entering one attention layer.
#[derive(Clone, Copy, Debug)]
pub struct AttnShape {
    pub batch: usize,
    pub seq: usize,
    pub heads: usize,
    pub d: usize,
    pub n_c: usize,
    pub kappa: usize,
}

pub const BYTES_F32: usize = 4;

impl AttnShape {
    pub fn d_h(&self) -> usize {
        self.d / self.heads
    }

    /// Activation bytes for vanilla attention: the N×N score matrix per
    /// head dominates (we ignore O(N·d) terms common to both models).
    pub fn vanilla_attn_bytes(&self) -> usize {
        self.batch * self.heads * self.seq * self.seq * BYTES_F32
    }

    /// Activation bytes for CAST, following the paper's §3.4 accounting:
    /// the intra term is O(N·κ) (per-cluster κ×κ score tiles, Nc·κ² =
    /// N·κ), the inter/summary term is O(N·Nc²), and the affinity
    /// matrices add O(N·Nc).  Total ∝ N·max(κ, Nc²) = N·α.
    pub fn cast_attn_bytes(&self) -> usize {
        let intra = self.batch * self.heads * self.n_c * self.kappa * self.kappa;
        let inter = self.batch * self.seq * self.n_c * self.n_c;
        let affinity = 3 * self.batch * self.seq * self.n_c;
        (intra + inter + affinity) * BYTES_F32
    }

    /// FLOPs for vanilla attention (2 matmuls: QKᵀ and PV).
    pub fn vanilla_attn_flops(&self) -> usize {
        2 * 2 * self.batch * self.heads * self.seq * self.seq * self.d_h()
    }

    /// FLOPs for CAST (intra matmuls over clusters + affinity matmuls).
    pub fn cast_attn_flops(&self) -> usize {
        let intra = 2 * 2 * self.batch * self.heads * self.n_c * self.kappa * self.kappa * self.d_h();
        let affinity = 2 * 2 * self.batch * self.heads * self.seq * self.n_c * self.d_h();
        let inter = 2 * self.batch * self.heads * self.n_c * self.kappa * self.d_h();
        intra + affinity + inter
    }

    /// The paper's α = max(κ, Nc²): CAST cost is O(α·N).
    pub fn alpha(&self) -> usize {
        self.kappa.max(self.n_c * self.n_c)
    }

    /// Predicted memory ratio CAST / vanilla (the Table-1 shape).
    pub fn memory_ratio(&self) -> f64 {
        self.cast_attn_bytes() as f64 / self.vanilla_attn_bytes() as f64
    }
}

/// TPU kernel estimate for one grid step of the fused Pallas kernel
/// (DESIGN.md §Hardware-Adaptation).
#[derive(Clone, Copy, Debug)]
pub struct KernelEstimate {
    pub vmem_bytes: usize,
    pub mxu_flops: usize,
    pub hbm_bytes: usize,
    /// FLOPs per HBM byte — compare against an MXU roofline ridge of
    /// ~240 flops/byte (197 Tf/s ÷ 819 GB/s, TPU v4-like).
    pub arithmetic_intensity: f64,
}

pub fn kernel_estimate(kappa: usize, d_h: usize) -> KernelEstimate {
    // resident per step: Q,K,V tiles + score tile + two weight vectors
    let vmem = (3 * kappa * d_h + kappa * kappa + 2 * kappa) * BYTES_F32;
    // QKᵀ + PV + summary reduction
    let flops = 2 * kappa * kappa * d_h * 2 + 2 * kappa * d_h;
    // HBM traffic: read Q,K,V + weights, write R_intra + R_inter
    let hbm = (4 * kappa * d_h + 2 * kappa + d_h) * BYTES_F32;
    KernelEstimate {
        vmem_bytes: vmem,
        mxu_flops: flops,
        hbm_bytes: hbm,
        arithmetic_intensity: flops as f64 / hbm as f64,
    }
}

/// VMEM capacity of a TPU core (v4-like), used for feasibility checks.
pub const TPU_VMEM_BYTES: usize = 16 * 1024 * 1024;

/// Sweep κ for a fixed N (with Nc = N/κ) and report predicted CAST memory;
/// the §3.4 claim is that the minimum sits near Nc² = κ.
pub fn kappa_memory_curve(
    batch: usize,
    seq: usize,
    heads: usize,
    d: usize,
    kappas: &[usize],
) -> Vec<(usize, usize)> {
    kappas
        .iter()
        .map(|&kappa| {
            let n_c = seq.div_ceil(kappa).max(1);
            let s = AttnShape { batch, seq, heads, d, n_c, kappa };
            (kappa, s.cast_attn_bytes())
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn shape(seq: usize, kappa: usize) -> AttnShape {
        AttnShape { batch: 4, seq, heads: 4, d: 64, n_c: seq.div_ceil(kappa), kappa }
    }

    #[test]
    fn cast_memory_is_sublinear_fraction_at_long_seq() {
        // The Table-1 shape: ratio shrinks as N grows.
        let r1 = shape(1024, 200).memory_ratio();
        let r4 = shape(4096, 200).memory_ratio();
        assert!(r4 < r1, "ratio should shrink with N: {r1} -> {r4}");
        assert!(r4 < 0.25, "CAST @4K should use well under 25% ({r4})");
    }

    #[test]
    fn vanilla_memory_quadratic() {
        let a = shape(1024, 128).vanilla_attn_bytes();
        let b = shape(2048, 128).vanilla_attn_bytes();
        assert_eq!(b, a * 4);
    }

    #[test]
    fn alpha_matches_paper_definition() {
        assert_eq!(shape(1024, 256).alpha(), 256); // Nc=4, Nc²=16 < κ
        let s = AttnShape { batch: 1, seq: 4096, heads: 1, d: 64, n_c: 128, kappa: 32 };
        assert_eq!(s.alpha(), 128 * 128);
    }

    #[test]
    fn memory_minimum_near_nc2_eq_kappa() {
        // N=4096: Nc²=κ with κ=N/Nc gives Nc=16, κ=256.
        let curve = kappa_memory_curve(1, 4096, 2, 64, &[32, 64, 128, 256, 512, 1024]);
        let (best_kappa, _) = curve.iter().min_by_key(|(_, b)| *b).unwrap();
        assert!(
            (128..=512).contains(best_kappa),
            "expected minimum near κ=256, got {best_kappa} (curve {curve:?})"
        );
    }

    #[test]
    fn kernel_fits_vmem() {
        for kappa in [128, 256, 512] {
            let est = kernel_estimate(kappa, 64);
            assert!(
                est.vmem_bytes < TPU_VMEM_BYTES / 2,
                "κ={kappa} kernel must fit VMEM with double-buffer headroom"
            );
        }
        // κ=2048 would blow half-VMEM with the κ² score tile
        assert!(kernel_estimate(2048, 64).vmem_bytes > TPU_VMEM_BYTES / 2);
    }

    #[test]
    fn intensity_grows_with_kappa() {
        let a = kernel_estimate(128, 64).arithmetic_intensity;
        let b = kernel_estimate(512, 64).arithmetic_intensity;
        assert!(b > a);
    }

    // -- §3.4 property tests ------------------------------------------------

    use crate::util::prop;

    /// Draw a random balanced-clustering attention geometry.
    fn draw_geometry(rng: &mut crate::util::rng::Rng) -> (usize, usize, usize, usize) {
        let batch = rng.range(1, 8);
        let heads = *rng.choice(&[2usize, 4]);
        let d_h = *rng.choice(&[16usize, 32, 64]);
        let kappa = *rng.choice(&[64usize, 128, 256, 512]);
        (batch, heads, heads * d_h, kappa)
    }

    fn balanced(batch: usize, heads: usize, d: usize, seq: usize, kappa: usize) -> AttnShape {
        AttnShape { batch, seq, heads, d, n_c: seq.div_ceil(kappa).max(1), kappa }
    }

    #[test]
    fn prop_cast_stays_below_vanilla_beyond_crossover() {
        // The Table-1 claim: once N passes the crossover point, CAST's
        // attention memory stays below the Transformer's at every longer
        // N inside the paper's operating envelope.  With fixed κ and
        // Nc = N/κ the inter term is Θ(N³/κ²), so the envelope ends near
        // N = h·κ² (where balanced configs rescale κ ~ N^(2/3), §3.4);
        // we assert strictly below up to half that bound.
        prop::check(
            "cast<vanilla beyond crossover",
            prop::Config { cases: 48, ..Default::default() },
            draw_geometry,
            |&(batch, heads, d, kappa)| {
                let envelope = (heads * kappa * kappa / 2).min(1 << 20);
                let mut crossover = None;
                let mut n = 64usize;
                while n <= envelope {
                    let s = balanced(batch, heads, d, n, kappa);
                    if s.cast_attn_bytes() < s.vanilla_attn_bytes() {
                        crossover = Some(n);
                        break;
                    }
                    n *= 2;
                }
                let n0 = crossover.ok_or_else(|| {
                    format!("no crossover below N={envelope} for h={heads} κ={kappa}")
                })?;
                let mut n = n0;
                while n <= envelope {
                    let s = balanced(batch, heads, d, n, kappa);
                    if s.cast_attn_bytes() >= s.vanilla_attn_bytes() {
                        return Err(format!(
                            "regression above crossover: N={n} (crossover {n0}, κ={kappa}, \
                             h={heads}): cast {} >= vanilla {}",
                            s.cast_attn_bytes(),
                            s.vanilla_attn_bytes()
                        ));
                    }
                    n *= 2;
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_memory_minimum_sits_near_nc2_eq_kappa() {
        // §3.4: with Nc = N/κ, predicted CAST memory is minimized where
        // Nc² ≈ κ (analytically κ* = (2N²/h)^(1/3)).  On a power-of-two κ
        // grid the argmin must land within a small constant factor.
        prop::check(
            "memory minimum near Nc²=κ",
            prop::Config { cases: 32, ..Default::default() },
            |rng| {
                let batch = rng.range(1, 4);
                let heads = *rng.choice(&[2usize, 4]);
                let d_h = *rng.choice(&[16usize, 32]);
                let seq = *rng.choice(&[2048usize, 4096, 8192]);
                (batch, heads, heads * d_h, seq)
            },
            |&(batch, heads, d, seq)| {
                let mut kappas = Vec::new();
                let mut k = 16usize;
                while k <= seq / 4 {
                    kappas.push(k);
                    k *= 2;
                }
                let curve = kappa_memory_curve(batch, seq, heads, d, &kappas);
                let (best_kappa, _) =
                    *curve.iter().min_by_key(|(_, bytes)| *bytes).ok_or("empty curve")?;
                let n_c = seq.div_ceil(best_kappa).max(1);
                let ratio = (n_c * n_c) as f64 / best_kappa as f64;
                if !(1.0 / 6.0..=6.0).contains(&ratio) {
                    return Err(format!(
                        "argmin κ={best_kappa} gives Nc²/κ = {ratio:.2} (Nc={n_c}) for \
                         N={seq} h={heads} — too far from the Nc²=κ balance"
                    ));
                }
                Ok(())
            },
        );
    }
}
