//! Deterministic, seeded fault injection.
//!
//! Named fault points are compiled into IO/queue/worker hot spots across
//! the serve and train stacks (see DESIGN.md §Robustness for the
//! catalog).  A plan comes from the `CAST_FAULTS` environment variable
//! (or `set_plan` in tests):
//!
//! ```text
//! CAST_FAULTS="<point>=<kind>[:<prob>][:x<count>][;<rule>...][@<seed>]"
//! ```
//!
//! Kinds: `err` (injected IO error), `panic`, `delay(<ms>)` (sleep),
//! `torn(<pct>)` (truncate a write to pct% of its bytes), `flag`
//! (generic boolean, e.g. forcing a non-finite loss).  `prob` is a float
//! in [0,1] (default 1.0 — every hit fires); `x<count>` caps the total
//! number of fires (default unlimited).  Example:
//!
//! ```text
//! CAST_FAULTS="serve.infer.batch=panic:0.05:x3;ckpt.save.torn=torn(50):x1@42"
//! ```
//!
//! Firing is deterministic: each rule keeps an atomic hit counter, and
//! hit `k` fires iff `hash(seed, point, k)` falls under `prob` — so the
//! *set of firing hit indices* depends only on the plan string, never on
//! thread interleaving (when a fire-count cap binds, the total stays
//! exact but which passing hits claim the cap can vary).
//!
//! When no plan is installed every fault point is a single relaxed
//! atomic load — strictly a no-op on production hot paths.

use std::io;
use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};
use std::sync::RwLock;

const UNINIT: u8 = 0;
const INACTIVE: u8 = 1;
const ENABLED: u8 = 2;

static STATE: AtomicU8 = AtomicU8::new(UNINIT);
static PLAN: RwLock<Option<Plan>> = RwLock::new(None);

#[derive(Debug, Clone, Copy, PartialEq)]
enum Kind {
    Err,
    Panic,
    Delay(u64),
    Torn(u32),
    Flag,
}

#[derive(Debug)]
struct Rule {
    point: String,
    kind: Kind,
    /// firing probability in basis points of 10_000
    prob_bp: u32,
    /// cap on total fires (u64::MAX = unlimited)
    max: u64,
    hits: AtomicU64,
    fired: AtomicU64,
}

#[derive(Debug)]
struct Plan {
    seed: u64,
    rules: Vec<Rule>,
}

/// True when a fault plan is installed.  One relaxed load when not.
#[inline]
pub fn active() -> bool {
    state() == ENABLED
}

/// IO-style fault point: an `err` rule returns an injected
/// `io::Error`, a `panic` rule panics, a `delay(ms)` rule sleeps.
/// Strictly a no-op without a plan.
#[inline]
pub fn check(point: &str) -> io::Result<()> {
    if state() != ENABLED {
        return Ok(());
    }
    check_slow(point)
}

/// Boolean fault point for non-IO injection (e.g. forcing the trainer
/// to treat a step's loss as non-finite).  Fires on `flag` rules.
#[inline]
pub fn flag(point: &str) -> bool {
    if state() != ENABLED {
        return false;
    }
    flag_slow(point)
}

/// Torn-write fault point: when a `torn(pct)` rule fires, returns the
/// truncated byte count a crashed writer would have persisted out of
/// `full`.
#[inline]
pub fn torn_len(point: &str, full: usize) -> Option<usize> {
    if state() != ENABLED {
        return None;
    }
    torn_slow(point, full)
}

/// Total fires recorded for `point` across all rule kinds (0 without a
/// plan).  Used by chaos tests to assert a plan actually exercised a
/// recovery path instead of passing vacuously.
pub fn fired(point: &str) -> u64 {
    if state() != ENABLED {
        return 0;
    }
    let plan = PLAN.read().unwrap_or_else(|p| p.into_inner());
    plan.as_ref().map_or(0, |p| {
        p.rules
            .iter()
            .filter(|r| r.point == point)
            .map(|r| r.fired.load(Ordering::Relaxed).min(r.max))
            .sum()
    })
}

/// Install a plan programmatically (tests).  Overrides `CAST_FAULTS`.
/// Panics on a malformed spec.
pub fn set_plan(spec: &str) {
    match parse_plan(spec) {
        Ok(p) => install(Some(p)),
        Err(e) => panic!("set_plan: {e}"),
    }
}

/// Remove any installed plan; every fault point returns to no-op.
pub fn clear() {
    install(None);
}

/// Serialize in-process tests that install plans: the plan store is
/// process-global, so any two tests calling [`set_plan`] race unless
/// they hold this lock.  Not part of the public API.
#[doc(hidden)]
pub fn test_guard() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());
    LOCK.lock().unwrap_or_else(|p| p.into_inner())
}

#[inline]
fn state() -> u8 {
    let s = STATE.load(Ordering::Relaxed);
    if s == UNINIT {
        init_from_env()
    } else {
        s
    }
}

#[cold]
fn init_from_env() -> u8 {
    static INIT: std::sync::Once = std::sync::Once::new();
    INIT.call_once(|| {
        let plan = match std::env::var("CAST_FAULTS") {
            Ok(spec) if !spec.trim().is_empty() => match parse_plan(&spec) {
                Ok(p) => {
                    crate::info!("fault: plan installed from CAST_FAULTS ({} rules)", p.rules.len());
                    Some(p)
                }
                // a typo'd plan silently never firing would let a chaos
                // CI run pass vacuously — fail fast and loudly instead
                Err(e) => panic!("CAST_FAULTS parse error: {e}"),
            },
            _ => None,
        };
        install(plan);
    });
    STATE.load(Ordering::Relaxed)
}

fn install(plan: Option<Plan>) {
    let enabled = plan.is_some();
    *PLAN.write().unwrap_or_else(|p| p.into_inner()) = plan;
    STATE.store(if enabled { ENABLED } else { INACTIVE }, Ordering::SeqCst);
}

#[cold]
fn check_slow(point: &str) -> io::Result<()> {
    let plan = PLAN.read().unwrap_or_else(|p| p.into_inner());
    let Some(plan) = plan.as_ref() else { return Ok(()) };
    for rule in plan.rules.iter().filter(|r| r.point == point) {
        match rule.kind {
            Kind::Err | Kind::Panic | Kind::Delay(_) => {
                if !fires(rule, plan.seed) {
                    continue;
                }
            }
            Kind::Torn(_) | Kind::Flag => continue,
        }
        // a firing leaves an instant event on the active trace (if any),
        // so chaos traces are self-explanatory
        crate::util::trace::event(&format!("fault:{point}"));
        match rule.kind {
            Kind::Err => {
                crate::debug!("fault: injected io error at {point}");
                return Err(io::Error::other(format!("injected fault at {point}")));
            }
            Kind::Panic => {
                crate::info!("fault: injected panic at {point}");
                panic!("injected panic at fault point {point}");
            }
            Kind::Delay(ms) => std::thread::sleep(std::time::Duration::from_millis(ms)),
            Kind::Torn(_) | Kind::Flag => unreachable!(),
        }
    }
    Ok(())
}

#[cold]
fn flag_slow(point: &str) -> bool {
    let plan = PLAN.read().unwrap_or_else(|p| p.into_inner());
    let Some(plan) = plan.as_ref() else { return false };
    let hit = plan
        .rules
        .iter()
        .filter(|r| r.point == point && r.kind == Kind::Flag)
        .any(|r| fires(r, plan.seed));
    if hit {
        crate::util::trace::event(&format!("fault:{point}"));
    }
    hit
}

#[cold]
fn torn_slow(point: &str, full: usize) -> Option<usize> {
    let plan = PLAN.read().unwrap_or_else(|p| p.into_inner());
    let plan = plan.as_ref()?;
    for rule in plan.rules.iter().filter(|r| r.point == point) {
        if let Kind::Torn(pct) = rule.kind {
            if fires(rule, plan.seed) {
                crate::util::trace::event(&format!("fault:{point}"));
                return Some(full * pct as usize / 100);
            }
        }
    }
    None
}

fn fires(rule: &Rule, seed: u64) -> bool {
    let k = rule.hits.fetch_add(1, Ordering::Relaxed);
    if rule.prob_bp < 10_000 {
        let h = mix(seed, &rule.point, k);
        if (h % 10_000) as u32 >= rule.prob_bp {
            return false;
        }
    }
    // claim one of the `max` fire slots; passes beyond the cap stay quiet
    rule.fired.fetch_add(1, Ordering::Relaxed) < rule.max
}

/// FNV-1a over (seed, point, hit index): cheap, dependency-free, and
/// stable across platforms, so a plan string pins the set of firing
/// hit indices exactly.
fn mix(seed: u64, point: &str, k: u64) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in seed.to_le_bytes().iter().chain(point.as_bytes()).chain(&k.to_le_bytes()) {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

fn parse_plan(spec: &str) -> Result<Plan, String> {
    let (body, seed) = match spec.rsplit_once('@') {
        Some((body, s)) => {
            (body, s.trim().parse::<u64>().map_err(|_| format!("bad plan seed {s:?}"))?)
        }
        None => (spec, 0),
    };
    let mut rules = Vec::new();
    for part in body.split(';') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        let (point, rest) =
            part.split_once('=').ok_or_else(|| format!("rule {part:?} is missing '='"))?;
        let mut toks = rest.split(':');
        let kind = parse_kind(toks.next().unwrap_or_default())?;
        let mut prob_bp = 10_000u32;
        let mut max = u64::MAX;
        for t in toks {
            if let Some(n) = t.strip_prefix('x') {
                max = n.parse().map_err(|_| format!("bad fire count {t:?} in {part:?}"))?;
            } else {
                let p: f64 =
                    t.parse().map_err(|_| format!("bad probability {t:?} in {part:?}"))?;
                if !(0.0..=1.0).contains(&p) {
                    return Err(format!("probability {p} out of [0,1] in {part:?}"));
                }
                prob_bp = (p * 10_000.0).round() as u32;
            }
        }
        rules.push(Rule {
            point: point.trim().to_string(),
            kind,
            prob_bp,
            max,
            hits: AtomicU64::new(0),
            fired: AtomicU64::new(0),
        });
    }
    if rules.is_empty() {
        return Err(format!("fault plan {spec:?} has no rules"));
    }
    Ok(Plan { seed, rules })
}

fn parse_kind(tok: &str) -> Result<Kind, String> {
    let (name, arg) = match tok.split_once('(') {
        Some((n, rest)) => {
            let arg = rest.strip_suffix(')').ok_or_else(|| format!("kind {tok:?} missing ')'"))?;
            (n, Some(arg))
        }
        None => (tok, None),
    };
    match (name, arg) {
        ("err", None) => Ok(Kind::Err),
        ("panic", None) => Ok(Kind::Panic),
        ("flag", None) => Ok(Kind::Flag),
        ("delay", Some(ms)) => {
            Ok(Kind::Delay(ms.parse().map_err(|_| format!("bad delay ms {ms:?}"))?))
        }
        ("torn", arg) => {
            let pct: u32 = match arg {
                Some(a) => a.parse().map_err(|_| format!("bad torn pct {a:?}"))?,
                None => 50,
            };
            if pct > 100 {
                return Err(format!("torn pct {pct} > 100"));
            }
            Ok(Kind::Torn(pct))
        }
        _ => Err(format!("unknown fault kind {tok:?}")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Every test that installs a plan holds the process-global
    /// [`test_guard`] lock (shared with the serve-side unit tests that
    /// inject faults; tests/integration_chaos.rs runs in its own binary).
    fn guard() -> std::sync::MutexGuard<'static, ()> {
        test_guard()
    }

    #[test]
    fn inactive_points_are_noops() {
        let _g = guard();
        clear();
        assert!(!active());
        assert!(check("anything").is_ok());
        assert!(!flag("anything"));
        assert_eq!(torn_len("anything", 100), None);
        assert_eq!(fired("anything"), 0);
    }

    #[test]
    fn err_rule_fires_up_to_count() {
        let _g = guard();
        set_plan("io.test=err:x2@7");
        assert!(check("other.point").is_ok());
        assert!(check("io.test").is_err());
        assert!(check("io.test").is_err());
        assert!(check("io.test").is_ok(), "x2 cap must exhaust");
        assert_eq!(fired("io.test"), 2);
        clear();
    }

    #[test]
    fn probability_selects_a_deterministic_hit_set() {
        let _g = guard();
        let run = || {
            set_plan("q.test=flag:0.3@42");
            let fired: Vec<usize> = (0..64).filter(|_| flag("q.test")).collect();
            clear();
            fired
        };
        let a = run();
        let b = run();
        assert_eq!(a, b, "same plan string must fire the same hit indices");
        assert!(!a.is_empty() && a.len() < 64, "p=0.3 over 64 hits: got {a:?}");
    }

    #[test]
    fn seed_changes_the_hit_set() {
        let _g = guard();
        let run = |seed: u64| {
            set_plan(&format!("q.seed=flag:0.5@{seed}"));
            let fired: Vec<usize> = (0..64).filter(|_| flag("q.seed")).collect();
            clear();
            fired
        };
        assert_ne!(run(1), run(2));
    }

    #[test]
    fn torn_len_truncates_once() {
        let _g = guard();
        set_plan("w.test=torn(25):x1");
        assert_eq!(torn_len("w.test", 400), Some(100));
        assert_eq!(torn_len("w.test", 400), None, "x1 cap must exhaust");
        clear();
    }

    #[test]
    fn delay_rule_sleeps() {
        let _g = guard();
        set_plan("d.test=delay(20):x1");
        let t = std::time::Instant::now();
        assert!(check("d.test").is_ok());
        assert!(t.elapsed().as_millis() >= 15, "delay(20) must actually sleep");
        assert!(check("d.test").is_ok());
        clear();
    }

    #[test]
    #[should_panic(expected = "injected panic at fault point")]
    fn panic_rule_panics() {
        // intentionally takes the lock without releasing cleanly: the
        // guard unwinds with the panic, and lock() recovers from poison
        let _g = guard();
        set_plan("p.test=panic:x1");
        let _ = check("p.test");
    }

    #[test]
    fn multi_rule_plans_parse() {
        let _g = guard();
        set_plan("a.x=err:0.5:x3; b.y=delay(5); c.z=torn(80):x1 @ 99");
        assert!(active());
        clear();
    }

    #[test]
    fn malformed_plans_are_rejected() {
        for bad in [
            "",
            "noequals",
            "p=unknownkind",
            "p=err:1.5",
            "p=err:xq",
            "p=delay(abc)",
            "p=torn(200)",
            "p=err@notanumber",
        ] {
            assert!(parse_plan(bad).is_err(), "{bad:?} must be rejected");
        }
    }
}
