//! Minimal JSON substrate (serde is unavailable offline).
//!
//! A recursive-descent parser and writer covering everything the artifact
//! manifests, configs, and experiment reports need: objects, arrays,
//! strings (with escapes), numbers, booleans, null.  Numbers are kept as
//! f64 — manifests only carry shapes/hyperparameters, all exactly
//! representable.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing garbage"));
        }
        Ok(v)
    }

    // -- typed accessors -------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// `obj.path("a.b.c")` — dotted lookup for nested objects.
    pub fn path(&self, dotted: &str) -> Option<&Json> {
        let mut cur = self;
        for part in dotted.split('.') {
            cur = cur.get(part)?;
        }
        Some(cur)
    }

    // -- builders ----------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }

    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn arr_usize(xs: &[usize]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    // -- writer ------------------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if !n.is_finite() {
                    // JSON has no NaN/Infinity; emit null (readers treat
                    // missing numerics as NaN)
                    out.push_str("null");
                } else if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(w) = indent {
                        out.push('\n');
                        out.push_str(&" ".repeat(w * (depth + 1)));
                    }
                    v.write(out, indent, depth + 1);
                }
                if indent.is_some() && !a.is_empty() {
                    out.push('\n');
                    out.push_str(&" ".repeat(indent.unwrap() * depth));
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(w) = indent {
                        out.push('\n');
                        out.push_str(&" ".repeat(w * (depth + 1)));
                    }
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if indent.is_some() && !m.is_empty() {
                    out.push('\n');
                    out.push_str(&" ".repeat(indent.unwrap() * depth));
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.pos }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(b'-' | b'0'..=b'9') => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.skip_ws();
            a.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_manifest_like() {
        let text = r#"{"key":"text_cast","n_params":42,"params":[{"name":"embed.emb","shape":[32,16],"dtype":"f32"}],"config":{"use_pallas":true,"lr":0.001,"nested":null}}"#;
        let v = Json::parse(text).unwrap();
        assert_eq!(v.path("config.use_pallas"), Some(&Json::Bool(true)));
        assert_eq!(v.get("n_params").unwrap().as_usize(), Some(42));
        let p0 = &v.get("params").unwrap().as_arr().unwrap()[0];
        assert_eq!(p0.get("shape").unwrap().as_arr().unwrap().len(), 2);
        // writer -> parser round trip
        let again = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, again);
        let pretty = Json::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, pretty);
    }

    #[test]
    fn escapes() {
        let v = Json::parse(r#""a\"b\\c\ndA""#).unwrap();
        assert_eq!(v.as_str(), Some("a\"b\\c\ndA"));
        let w = Json::Str("x\"y\n\t".into());
        assert_eq!(Json::parse(&w.to_string()).unwrap(), w);
    }

    #[test]
    fn numbers() {
        for (t, want) in [("0", 0.0), ("-12", -12.0), ("3.5", 3.5), ("1e-3", 1e-3), ("2.5E2", 250.0)] {
            assert_eq!(Json::parse(t).unwrap().as_f64(), Some(want), "{t}");
        }
    }

    #[test]
    fn rejects_garbage() {
        for t in ["{", "[1,", "\"abc", "{\"a\" 1}", "12 34", "tru"] {
            assert!(Json::parse(t).is_err(), "{t} should fail");
        }
    }

    #[test]
    fn non_finite_numbers_emit_null() {
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
        assert_eq!(Json::Num(f64::INFINITY).to_string(), "null");
        let obj = Json::obj(vec![("loss", Json::num(f64::NAN))]);
        // the round-trip stays valid JSON
        assert!(Json::parse(&obj.to_string()).is_ok());
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
    }
}
