//! Minimal CLI argument substrate (clap is unavailable offline).
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, and free
//! positional arguments.  Typed getters with defaults keep call sites
//! terse: `args.usize("steps", 100)`.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
    bools: Vec<String>,
}

impl Args {
    /// Parse from an explicit iterator (tests) — `parse()` uses std::env.
    pub fn from_iter<I: IntoIterator<Item = String>>(iter: I) -> Args {
        let mut out = Args::default();
        let mut it = iter.into_iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(rest) = arg.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    out.flags.insert(rest.to_string(), v);
                } else {
                    out.bools.push(rest.to_string());
                }
            } else {
                out.positional.push(arg);
            }
        }
        out
    }

    pub fn parse() -> Args {
        Args::from_iter(std::env::args().skip(1))
    }

    pub fn has(&self, name: &str) -> bool {
        self.bools.iter().any(|b| b == name) || self.flags.contains_key(name)
    }

    pub fn str(&self, name: &str, default: &str) -> String {
        self.flags.get(name).cloned().unwrap_or_else(|| default.to_string())
    }

    pub fn opt_str(&self, name: &str) -> Option<String> {
        self.flags.get(name).cloned()
    }

    pub fn usize(&self, name: &str, default: usize) -> usize {
        self.flags
            .get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} expects an integer, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn u64(&self, name: &str, default: u64) -> u64 {
        self.flags
            .get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} expects an integer, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn f64(&self, name: &str, default: f64) -> f64 {
        self.flags
            .get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} expects a number, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn f32(&self, name: &str, default: f32) -> f32 {
        self.f64(name, default as f64) as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::from_iter(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn flags_and_positional() {
        let a = args("train artifacts/tiny --steps 50 --lr=0.01 --verbose");
        assert_eq!(a.positional, vec!["train", "artifacts/tiny"]);
        assert_eq!(a.usize("steps", 0), 50);
        assert_eq!(a.f64("lr", 0.0), 0.01);
        assert!(a.has("verbose"));
        assert!(!a.has("quiet"));
        assert_eq!(a.str("missing", "dflt"), "dflt");
    }

    #[test]
    fn trailing_bool_flag() {
        let a = args("bench --quick");
        assert!(a.has("quick"));
        assert!(a.positional == vec!["bench"]);
    }

    #[test]
    #[should_panic]
    fn bad_int_panics() {
        args("--steps abc").usize("steps", 0);
    }
}
