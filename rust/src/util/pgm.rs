//! PGM/PPM image writers for the cluster-visualization analysis
//! (paper Figure 4 and Appendix Figures 7–9).  Plain-text netpbm formats:
//! zero dependencies, viewable everywhere.

use std::io::Write;
use std::path::Path;

/// 8-bit grayscale image, row-major.
pub struct Gray {
    pub w: usize,
    pub h: usize,
    pub pixels: Vec<u8>,
}

impl Gray {
    pub fn new(w: usize, h: usize) -> Gray {
        Gray { w, h, pixels: vec![0; w * h] }
    }

    /// Build from f32 data normalized to the [min,max] of the slice.
    pub fn from_f32(w: usize, h: usize, data: &[f32]) -> Gray {
        assert_eq!(data.len(), w * h);
        let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
        for &x in data {
            lo = lo.min(x);
            hi = hi.max(x);
        }
        let span = (hi - lo).max(1e-9);
        let pixels = data.iter().map(|&x| (255.0 * (x - lo) / span) as u8).collect();
        Gray { w, h, pixels }
    }

    pub fn save(&self, path: &Path) -> std::io::Result<()> {
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        writeln!(f, "P2\n{} {}\n255", self.w, self.h)?;
        for row in self.pixels.chunks(self.w) {
            let line: Vec<String> = row.iter().map(|p| p.to_string()).collect();
            writeln!(f, "{}", line.join(" "))?;
        }
        Ok(())
    }
}

/// 8-bit RGB image, row-major.
pub struct Rgb {
    pub w: usize,
    pub h: usize,
    pub pixels: Vec<[u8; 3]>,
}

/// A qualitative palette with good separation for up to 16 clusters
/// (matplotlib `tab`-like).
pub const PALETTE: [[u8; 3]; 16] = [
    [31, 119, 180],
    [255, 127, 14],
    [44, 160, 44],
    [214, 39, 40],
    [148, 103, 189],
    [140, 86, 75],
    [227, 119, 194],
    [127, 127, 127],
    [188, 189, 34],
    [23, 190, 207],
    [174, 199, 232],
    [255, 187, 120],
    [152, 223, 138],
    [255, 152, 150],
    [197, 176, 213],
    [196, 156, 148],
];

impl Rgb {
    pub fn new(w: usize, h: usize) -> Rgb {
        Rgb { w, h, pixels: vec![[0; 3]; w * h] }
    }

    /// Color each pixel by its cluster id (Figure 4b style).
    pub fn from_labels(w: usize, h: usize, labels: &[usize]) -> Rgb {
        assert_eq!(labels.len(), w * h);
        let pixels = labels.iter().map(|&c| PALETTE[c % PALETTE.len()]).collect();
        Rgb { w, h, pixels }
    }

    pub fn save(&self, path: &Path) -> std::io::Result<()> {
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        writeln!(f, "P3\n{} {}\n255", self.w, self.h)?;
        for row in self.pixels.chunks(self.w) {
            let mut line = String::new();
            for p in row {
                line.push_str(&format!("{} {} {} ", p[0], p[1], p[2]));
            }
            writeln!(f, "{}", line.trim_end())?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gray_normalizes_range() {
        let g = Gray::from_f32(2, 2, &[0.0, 1.0, 0.5, 1.0]);
        assert_eq!(g.pixels[0], 0);
        assert_eq!(g.pixels[1], 255);
        assert!(g.pixels[2] >= 126 && g.pixels[2] <= 128);
    }

    #[test]
    fn gray_constant_image_does_not_nan() {
        let g = Gray::from_f32(2, 1, &[3.0, 3.0]);
        assert_eq!(g.pixels, vec![0, 0]);
    }

    #[test]
    fn save_roundtrip_header() {
        let dir = std::env::temp_dir().join("cast_pgm_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.pgm");
        Gray::from_f32(3, 2, &[0., 1., 2., 3., 4., 5.]).save(&p).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        assert!(text.starts_with("P2\n3 2\n255\n"), "{text}");
        let q = dir.join("t.ppm");
        Rgb::from_labels(2, 2, &[0, 1, 2, 3]).save(&q).unwrap();
        assert!(std::fs::read_to_string(&q).unwrap().starts_with("P3\n2 2\n255"));
    }
}
