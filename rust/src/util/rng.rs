//! Deterministic PRNG substrate (the `rand` crate is unavailable offline).
//!
//! `Rng` is SplitMix64 — tiny state, excellent statistical quality for data
//! generation, and trivially splittable so every dataset shard / worker
//! thread can derive an independent stream from (seed, stream-id).

#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // Avalanche the seed once so small seeds don't correlate.
        let mut r = Rng { state: seed.wrapping_add(0x9E37_79B9_7F4A_7C15) };
        r.next_u64();
        r
    }

    /// Derive an independent stream, e.g. per worker or per example.
    pub fn split(&self, stream: u64) -> Rng {
        Rng::new(self.state ^ stream.wrapping_mul(0xBF58_476D_1CE4_E5B9))
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, n) without modulo bias (Lemire's method).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "Rng::below(0)");
        let n = n as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Uniform in [lo, hi] inclusive.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo + 1)
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller.
    pub fn gaussian(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    pub fn choice<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.below(items.len())]
    }

    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.below(i + 1);
            items.swap(i, j);
        }
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn split_streams_differ() {
        let r = Rng::new(7);
        let (mut a, mut b) = (r.split(0), r.split(1));
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(1);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = r.below(10);
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(5);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.08, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn weighted_prefers_heavy() {
        let mut r = Rng::new(11);
        let w = [1.0, 0.0, 9.0];
        let mut counts = [0usize; 3];
        for _ in 0..2000 {
            counts[r.weighted(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        assert!(counts[2] > counts[0] * 5);
    }
}
