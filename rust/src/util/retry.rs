//! Deterministic exponential-backoff retry for transient IO.
//!
//! No jitter, on purpose: this repo's contract is bit-identical reruns,
//! and a fixed delay ladder (base, 2·base, 4·base, …) keeps
//! fault-injected tests exactly reproducible (DESIGN.md §Robustness).

use std::io;
use std::time::Duration;

/// Retry policy: up to `attempts` tries, sleeping `base · 2^k` between
/// try `k` and try `k+1`.
#[derive(Debug, Clone, Copy)]
pub struct Backoff {
    pub attempts: u32,
    pub base: Duration,
}

impl Backoff {
    pub const fn new(attempts: u32, base: Duration) -> Backoff {
        Backoff { attempts, base }
    }

    /// Default ladder for checkpoint IO: 3 tries, 10ms then 20ms waits.
    pub const fn io() -> Backoff {
        Backoff::new(3, Duration::from_millis(10))
    }
}

/// Run `op` under the policy, returning its first success or the last
/// attempt's error.  Intermediate failures are logged with the attempt
/// index so transient-IO recovery is visible in serve/train logs.
pub fn with_backoff<T>(
    label: &str,
    policy: Backoff,
    mut op: impl FnMut() -> io::Result<T>,
) -> io::Result<T> {
    let attempts = policy.attempts.max(1);
    let mut delay = policy.base;
    let mut attempt = 1;
    loop {
        match op() {
            Ok(v) => return Ok(v),
            Err(e) if attempt < attempts => {
                crate::info!(
                    "{label}: attempt {attempt}/{attempts} failed ({e}); retrying in {}ms",
                    delay.as_millis()
                );
                std::thread::sleep(delay);
                delay *= 2;
                attempt += 1;
            }
            Err(e) => return Err(e),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU32, Ordering};

    #[test]
    fn succeeds_after_transient_failures() {
        let calls = AtomicU32::new(0);
        let out = with_backoff("test", Backoff::new(3, Duration::from_millis(1)), || {
            if calls.fetch_add(1, Ordering::Relaxed) < 2 {
                Err(io::Error::other("transient"))
            } else {
                Ok(42)
            }
        });
        assert_eq!(out.unwrap(), 42);
        assert_eq!(calls.load(Ordering::Relaxed), 3);
    }

    #[test]
    fn exhausts_and_returns_last_error() {
        let calls = AtomicU32::new(0);
        let out: io::Result<()> =
            with_backoff("test", Backoff::new(3, Duration::from_millis(1)), || {
                calls.fetch_add(1, Ordering::Relaxed);
                Err(io::Error::other("permanent"))
            });
        assert!(out.is_err());
        assert_eq!(calls.load(Ordering::Relaxed), 3);
    }

    #[test]
    fn zero_attempts_clamps_to_one() {
        let calls = AtomicU32::new(0);
        let out = with_backoff("test", Backoff::new(0, Duration::from_millis(1)), || {
            calls.fetch_add(1, Ordering::Relaxed);
            Ok(1)
        });
        assert_eq!(out.unwrap(), 1);
        assert_eq!(calls.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn delays_grow_exponentially() {
        let calls = AtomicU32::new(0);
        let t = std::time::Instant::now();
        let _: io::Result<()> =
            with_backoff("test", Backoff::new(3, Duration::from_millis(10)), || {
                calls.fetch_add(1, Ordering::Relaxed);
                Err(io::Error::other("always"))
            });
        // 10ms + 20ms of deterministic backoff between the three tries
        assert!(t.elapsed().as_millis() >= 25, "elapsed {:?}", t.elapsed());
    }
}
