//! Dependency-free parallel execution subsystem for the native engine.
//!
//! A scoped fork/join pool built on `std::thread::scope`: every parallel
//! region spawns up to [`max_threads`] workers that pull work items from a
//! shared queue (a mutex-guarded chunk iterator or an atomic counter) and
//! join before the call returns.  Spawn cost is a few microseconds per
//! region — noise next to the millisecond-scale matmul / attention loops
//! this serves — and in exchange the subsystem needs no channels, no
//! `unsafe`, and no external crates (the build environment is offline;
//! see DESIGN.md §Substitutions).
//!
//! Determinism contract (relied on by the parity tests and DESIGN.md
//! §Threading): helpers hand each task a *disjoint* `&mut` chunk of the
//! output, and every reduction stays inside one task in a fixed order, so
//! results are bit-identical for any worker count and any scheduling
//! interleaving.  `CAST_NUM_THREADS=1` (or [`set_threads`]) therefore
//! reproduces the threaded output exactly.
//!
//! Sizing: `CAST_NUM_THREADS` env override (tests pin 1), else
//! `std::thread::available_parallelism`.  [`set_threads`] is a
//! process-global programmatic override used by the parity tests — safe
//! to race precisely because results never depend on the worker count.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// Programmatic override; 0 = unset (fall through to env / hardware).
static THREAD_OVERRIDE: AtomicUsize = AtomicUsize::new(0);

/// Override the worker count for this process (0 clears the override).
pub fn set_threads(n: usize) {
    THREAD_OVERRIDE.store(n, Ordering::SeqCst);
}

/// Resolved worker count: `set_threads` override, else `CAST_NUM_THREADS`,
/// else `available_parallelism` (≥ 1 always).
pub fn max_threads() -> usize {
    let over = THREAD_OVERRIDE.load(Ordering::SeqCst);
    if over > 0 {
        return over;
    }
    if let Ok(v) = std::env::var("CAST_NUM_THREADS") {
        if let Ok(n) = v.trim().parse::<usize>() {
            if n > 0 {
                return n;
            }
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Rows per task for a row-parallel loop: ~4 tasks per worker so the
/// mutex handout amortizes while stragglers still rebalance.
pub fn row_block(rows: usize) -> usize {
    rows.div_ceil(max_threads() * 4).max(1)
}

/// Elements per task for a flat elementwise loop (≥ 4096 so task handout
/// never dominates trivially cheap bodies).
pub fn elem_block(len: usize) -> usize {
    len.div_ceil(max_threads() * 4).max(4096)
}

/// Fork `threads` workers (worker 0 runs on the calling thread), join
/// all — the public fork/join shape behind every parallel region, also
/// used directly by long-lived pools (the serve subsystem's connection
/// and inference workers).
pub fn scoped_workers<F: Fn(usize) + Sync>(threads: usize, worker: F) {
    run_workers(threads, worker)
}

/// Fork `threads` workers (worker 0 runs on the calling thread), join all.
fn run_workers<F: Fn(usize) + Sync>(threads: usize, worker: F) {
    if threads <= 1 {
        worker(0);
        return;
    }
    std::thread::scope(|s| {
        for t in 1..threads {
            let w = &worker;
            s.spawn(move || w(t));
        }
        worker(0);
    });
}

/// Parallel `for i in 0..n { f(i) }` with dynamic (atomic-counter) load
/// balancing.  `f` must only touch state that is safe to share (reads,
/// atomics) — for disjoint mutable output use the chunk helpers below.
pub fn par_iter_indexed<F: Fn(usize) + Sync>(n: usize, f: F) {
    let threads = max_threads().min(n.max(1));
    if threads <= 1 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let next = AtomicUsize::new(0);
    run_workers(threads, |_| loop {
        let i = next.fetch_add(1, Ordering::Relaxed);
        if i >= n {
            break;
        }
        f(i);
    });
}

/// Parallel loop over disjoint `chunk`-sized pieces of `data`; each task
/// gets `(chunk_index, &mut chunk)`.  The last chunk may be shorter.
pub fn par_chunks_mut<T, F>(data: &mut [T], chunk: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    par_chunks_mut_with(data, chunk, || (), |_s, i, c| f(i, c));
}

/// [`par_chunks_mut`] with a per-worker scratch value built by `make`
/// (allocated once per worker, not once per task).
pub fn par_chunks_mut_with<T, S, M, F>(data: &mut [T], chunk: usize, make: M, f: F)
where
    T: Send,
    M: Fn() -> S + Sync,
    F: Fn(&mut S, usize, &mut [T]) + Sync,
{
    debug_assert!(chunk > 0, "chunk length must be positive");
    if data.is_empty() {
        return;
    }
    let n_chunks = data.len().div_ceil(chunk);
    let threads = max_threads().min(n_chunks);
    if threads <= 1 {
        let mut scratch = make();
        for (i, c) in data.chunks_mut(chunk).enumerate() {
            f(&mut scratch, i, c);
        }
        return;
    }
    let queue = Mutex::new(data.chunks_mut(chunk).enumerate());
    run_workers(threads, |_| {
        let mut scratch = make();
        loop {
            let item = queue.lock().unwrap().next();
            match item {
                Some((i, c)) => f(&mut scratch, i, c),
                None => break,
            }
        }
    });
}

/// Parallel loop over two lock-stepped chunked outputs: task `i` gets
/// `(i, &mut a[i*ca..], &mut b[i*cb..])`.  Used when one logical task
/// writes two disjoint result arrays (e.g. per-cluster `R_intra` and
/// `R_inter` slabs).
pub fn par_zip2_mut<A, B, F>(a: &mut [A], ca: usize, b: &mut [B], cb: usize, f: F)
where
    A: Send,
    B: Send,
    F: Fn(usize, &mut [A], &mut [B]) + Sync,
{
    par_zip2_mut_with(a, ca, b, cb, || (), |_s, i, x, y| f(i, x, y));
}

/// [`par_zip2_mut`] with a per-worker scratch value built by `make`.
pub fn par_zip2_mut_with<A, B, S, M, F>(
    a: &mut [A],
    ca: usize,
    b: &mut [B],
    cb: usize,
    make: M,
    f: F,
) where
    A: Send,
    B: Send,
    M: Fn() -> S + Sync,
    F: Fn(&mut S, usize, &mut [A], &mut [B]) + Sync,
{
    debug_assert!(ca > 0 && cb > 0, "chunk lengths must be positive");
    debug_assert_eq!(
        a.len().div_ceil(ca),
        b.len().div_ceil(cb),
        "zip2 outputs must have the same task count"
    );
    if a.is_empty() {
        return;
    }
    let n_chunks = a.len().div_ceil(ca);
    let threads = max_threads().min(n_chunks);
    if threads <= 1 {
        let mut scratch = make();
        for (i, (x, y)) in a.chunks_mut(ca).zip(b.chunks_mut(cb)).enumerate() {
            f(&mut scratch, i, x, y);
        }
        return;
    }
    let queue = Mutex::new(a.chunks_mut(ca).zip(b.chunks_mut(cb)).enumerate());
    run_workers(threads, |_| {
        let mut scratch = make();
        loop {
            let item = queue.lock().unwrap().next();
            match item {
                Some((i, (x, y))) => f(&mut scratch, i, x, y),
                None => break,
            }
        }
    });
}

// ---------------------------------------------------------------------------
// bounded closable MPMC queue
// ---------------------------------------------------------------------------

/// Result of a timed [`Queue::pop_timeout`].
#[derive(Debug)]
pub enum Pop<T> {
    /// An item was dequeued.
    Item(T),
    /// The wait elapsed with the queue still open but empty.
    Empty,
    /// The queue is closed and fully drained.
    Closed,
}

struct QueueInner<T> {
    items: VecDeque<T>,
    closed: bool,
}

/// A bounded, closable multi-producer multi-consumer queue
/// (`Mutex`+`Condvar`; channels stay out of this subsystem, see the
/// module doc).  `push` blocks when full — the backpressure the serve
/// micro-batcher relies on — and fails once the queue is closed; `pop`
/// blocks when empty and returns `None` once the queue is closed *and*
/// drained, so consumers naturally finish in-flight work on shutdown.
///
/// Poison-tolerant: the serve subsystem isolates worker panics with
/// `catch_unwind`, so a queue shared with a panicked worker must keep
/// serving the survivors — the state here (a deque + a flag) is valid
/// at every await point, making poison recovery sound.
pub struct Queue<T> {
    inner: Mutex<QueueInner<T>>,
    not_empty: Condvar,
    not_full: Condvar,
    cap: usize,
}

impl<T> Queue<T> {
    pub fn bounded(cap: usize) -> Queue<T> {
        Queue {
            inner: Mutex::new(QueueInner { items: VecDeque::new(), closed: false }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            cap: cap.max(1),
        }
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, QueueInner<T>> {
        self.inner.lock().unwrap_or_else(|p| p.into_inner())
    }

    /// Enqueue, blocking while the queue is at capacity.  Returns the
    /// item back as `Err` when the queue has been closed.
    pub fn push(&self, item: T) -> Result<(), T> {
        let mut g = self.lock();
        while g.items.len() >= self.cap && !g.closed {
            g = self.not_full.wait(g).unwrap_or_else(|p| p.into_inner());
        }
        if g.closed {
            return Err(item);
        }
        g.items.push_back(item);
        drop(g);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Dequeue, blocking while the queue is open and empty.  `None`
    /// means closed-and-drained — the consumer's exit signal.
    pub fn pop(&self) -> Option<T> {
        let mut g = self.lock();
        loop {
            if let Some(x) = g.items.pop_front() {
                drop(g);
                self.not_full.notify_one();
                return Some(x);
            }
            if g.closed {
                return None;
            }
            g = self.not_empty.wait(g).unwrap_or_else(|p| p.into_inner());
        }
    }

    /// [`Queue::pop`] with a wait bound, distinguishing "nothing arrived
    /// in time" from "closed" (the micro-batcher's max-wait timer).
    pub fn pop_timeout(&self, dur: Duration) -> Pop<T> {
        let deadline = Instant::now() + dur;
        let mut g = self.lock();
        loop {
            if let Some(x) = g.items.pop_front() {
                drop(g);
                self.not_full.notify_one();
                return Pop::Item(x);
            }
            if g.closed {
                return Pop::Closed;
            }
            let now = Instant::now();
            if now >= deadline {
                return Pop::Empty;
            }
            let (ng, timeout) = self
                .not_empty
                .wait_timeout(g, deadline - now)
                .unwrap_or_else(|p| p.into_inner());
            g = ng;
            if timeout.timed_out() {
                // one final check: an item may have landed exactly at
                // the deadline
                if let Some(x) = g.items.pop_front() {
                    drop(g);
                    self.not_full.notify_one();
                    return Pop::Item(x);
                }
                return Pop::Empty;
            }
        }
    }

    /// Close the queue: further pushes fail, poppers drain what remains
    /// and then observe `None`/`Closed`.  Idempotent.
    pub fn close(&self) {
        self.lock().closed = true;
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    pub fn is_closed(&self) -> bool {
        self.lock().closed
    }

    /// Current depth (a metrics gauge; racy by nature).
    pub fn len(&self) -> usize {
        self.lock().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn par_chunks_visits_every_chunk_once() {
        let mut data = vec![0u32; 1000];
        par_chunks_mut(&mut data, 7, |_i, c| {
            for v in c.iter_mut() {
                *v += 1; // each element visited exactly once
            }
        });
        assert!(data.iter().all(|&v| v == 1));
    }

    #[test]
    fn par_chunks_indices_match_offsets() {
        let mut data = vec![0usize; 103];
        par_chunks_mut(&mut data, 10, |i, c| {
            for (j, v) in c.iter_mut().enumerate() {
                *v = i * 10 + j;
            }
        });
        let expect: Vec<usize> = (0..103).collect();
        assert_eq!(data, expect);
    }

    #[test]
    fn par_iter_indexed_covers_range() {
        let sum = AtomicU64::new(0);
        par_iter_indexed(100, |i| {
            sum.fetch_add(i as u64, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 4950);
    }

    #[test]
    fn zip2_chunks_stay_locked_step() {
        let mut a = vec![0usize; 60]; // 6 tasks of 10
        let mut b = vec![0usize; 12]; // 6 tasks of 2
        par_zip2_mut(&mut a, 10, &mut b, 2, |i, x, y| {
            for v in x.iter_mut() {
                *v = i;
            }
            for v in y.iter_mut() {
                *v = i;
            }
        });
        for i in 0..6 {
            assert!(a[i * 10..(i + 1) * 10].iter().all(|&v| v == i));
            assert!(b[i * 2..(i + 1) * 2].iter().all(|&v| v == i));
        }
    }

    #[test]
    fn thread_override_blocks_and_scratch_reuse() {
        // single test owns the process-global override (merging the
        // override and scratch assertions here avoids cross-test races
        // on THREAD_OVERRIDE within this test binary)
        set_threads(3);
        assert_eq!(max_threads(), 3);
        let mut data = vec![0u32; 50];
        par_chunks_mut(&mut data, 5, |_, c| c.iter_mut().for_each(|v| *v += 1));
        assert!(data.iter().all(|&v| v == 1));

        // the scratch closure runs at most once per worker (3 pinned
        // workers, 64 tasks — a per-task impl would report 64 makes)
        let makes = AtomicU64::new(0);
        let mut data = vec![0u8; 64];
        par_chunks_mut_with(
            &mut data,
            1,
            || {
                makes.fetch_add(1, Ordering::Relaxed);
                vec![0.0f32; 8]
            },
            |s, _i, c| {
                s[0] += 1.0;
                c[0] = 1;
            },
        );
        assert!(makes.load(Ordering::Relaxed) <= 3);
        assert!(data.iter().all(|&v| v == 1));

        set_threads(1);
        assert_eq!(max_threads(), 1);
        assert!(row_block(100) >= 1 && elem_block(10) >= 1);
        set_threads(0);
        assert!(max_threads() >= 1);
    }

    #[test]
    fn queue_fifo_and_close_semantics() {
        let q: Queue<u32> = Queue::bounded(8);
        q.push(1).unwrap();
        q.push(2).unwrap();
        assert_eq!(q.len(), 2);
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(2));
        assert!(matches!(q.pop_timeout(Duration::from_millis(5)), Pop::Empty));
        q.push(3).unwrap();
        q.close();
        // closed: pushes fail and hand the item back, drain continues
        assert_eq!(q.push(9), Err(9));
        assert_eq!(q.pop(), Some(3));
        assert_eq!(q.pop(), None);
        assert!(matches!(q.pop_timeout(Duration::from_millis(1)), Pop::Closed));
        assert!(q.is_closed() && q.is_empty());
    }

    #[test]
    fn queue_bounds_producers() {
        let q: Queue<usize> = Queue::bounded(2);
        q.push(0).unwrap();
        q.push(1).unwrap();
        std::thread::scope(|s| {
            let h = s.spawn(|| q.push(2)); // must block until a pop frees a slot
            std::thread::sleep(Duration::from_millis(20));
            assert_eq!(q.len(), 2, "bounded queue exceeded its capacity");
            assert_eq!(q.pop(), Some(0));
            h.join().unwrap().unwrap();
        });
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(2));
    }

    #[test]
    fn queue_close_unblocks_waiting_poppers() {
        let q: Queue<()> = Queue::bounded(1);
        std::thread::scope(|s| {
            let h = s.spawn(|| q.pop());
            std::thread::sleep(Duration::from_millis(10));
            q.close();
            assert_eq!(h.join().unwrap(), None);
        });
    }

    #[test]
    fn queue_mpmc_delivers_every_item_once() {
        let q: Queue<usize> = Queue::bounded(4);
        let seen = Mutex::new(vec![0u8; 200]);
        std::thread::scope(|s| {
            for _ in 0..3 {
                s.spawn(|| {
                    while let Some(i) = q.pop() {
                        seen.lock().unwrap()[i] += 1;
                    }
                });
            }
            for i in 0..200 {
                q.push(i).unwrap();
            }
            q.close();
        });
        assert!(seen.lock().unwrap().iter().all(|&c| c == 1));
    }

    #[test]
    fn scoped_workers_runs_every_index() {
        let hits = Mutex::new(vec![false; 4]);
        scoped_workers(4, |w| {
            hits.lock().unwrap()[w] = true;
        });
        assert!(hits.lock().unwrap().iter().all(|&h| h));
    }

    #[test]
    fn empty_and_short_inputs_are_safe() {
        let mut empty: Vec<f32> = Vec::new();
        par_chunks_mut(&mut empty, 4, |_, _| panic!("no tasks expected"));
        par_iter_indexed(0, |_| panic!("no tasks expected"));
        let mut one = vec![0.0f32; 3];
        par_chunks_mut(&mut one, 100, |i, c| {
            assert_eq!(i, 0);
            assert_eq!(c.len(), 3);
        });
    }
}
