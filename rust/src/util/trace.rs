//! Zero-dependency tracing/profiling: per-op spans with per-layer
//! attribution, aggregated into time-share tables and exportable as
//! Chrome trace-event JSON (`chrome://tracing` / Perfetto).
//!
//! The design mirrors `util::fault`: tracing is env-gated via
//! `CAST_TRACE` (any non-empty value other than `0`), and when disabled
//! every instrumentation point is a single relaxed atomic load — no
//! clock reads, no allocation, no locks.  `cast bench --profile` (and
//! tests) flip it on programmatically via [`set_enabled`].
//!
//! Recording never perturbs the engine's bit-identical threading
//! guarantees: spans only read the wall clock and push into a buffer
//! owned by the recording thread (its mutex is uncontended except
//! during [`drain`]), so float accumulation order is untouched and the
//! SIMD×threads determinism matrices hold with tracing on or off.
//!
//! Span self-time is maintained with a per-thread stack at record time:
//! a parent's self time excludes its children, so the per-op shares in
//! [`summarize`] partition traced time exactly (they sum to 100%).
//! Fault firings (`util::fault`) are recorded as instant events on the
//! active trace, so chaos traces are self-explanatory.

use std::cell::{Cell, RefCell};
use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use super::json::Json;

const UNINIT: u8 = 0;
const INACTIVE: u8 = 1;
const ENABLED: u8 = 2;

static STATE: AtomicU8 = AtomicU8::new(UNINIT);

/// True when tracing is on.  One relaxed load when not.
#[inline]
pub fn active() -> bool {
    state() == ENABLED
}

/// Programmatically enable/disable tracing (overrides `CAST_TRACE`).
/// Used by `cast bench --profile` and the test suite.
pub fn set_enabled(on: bool) {
    STATE.store(if on { ENABLED } else { INACTIVE }, Ordering::SeqCst);
}

#[inline]
fn state() -> u8 {
    let s = STATE.load(Ordering::Relaxed);
    if s == UNINIT {
        init_from_env()
    } else {
        s
    }
}

#[cold]
fn init_from_env() -> u8 {
    static INIT: std::sync::Once = std::sync::Once::new();
    INIT.call_once(|| {
        let on = match std::env::var("CAST_TRACE") {
            Ok(v) => !v.trim().is_empty() && v.trim() != "0",
            Err(_) => false,
        };
        if on {
            crate::info!("trace: enabled via CAST_TRACE");
        }
        // racing set_enabled wins: only claim the slot if still UNINIT
        let _ = STATE.compare_exchange(
            UNINIT,
            if on { ENABLED } else { INACTIVE },
            Ordering::SeqCst,
            Ordering::SeqCst,
        );
    });
    STATE.load(Ordering::Relaxed)
}

// ---------------------------------------------------------------------------
// clock + per-thread recording state
// ---------------------------------------------------------------------------

/// Monotonic nanoseconds since the process-wide trace epoch (the first
/// call wins; cached per thread so the hot path never locks for it).
fn now_ns() -> u64 {
    epoch().elapsed().as_nanos() as u64
}

fn epoch() -> Instant {
    thread_local! {
        static CACHED: Cell<Option<Instant>> = const { Cell::new(None) };
    }
    CACHED.with(|c| match c.get() {
        Some(e) => e,
        None => {
            static GLOBAL: Mutex<Option<Instant>> = Mutex::new(None);
            let mut g = GLOBAL.lock().unwrap_or_else(|p| p.into_inner());
            let e = *g.get_or_insert_with(Instant::now);
            drop(g);
            c.set(Some(e));
            e
        }
    })
}

/// Small dense thread ids for trace attribution (OS thread ids are
/// neither stable nor compact).
fn tid() -> u64 {
    static NEXT: AtomicU64 = AtomicU64::new(1);
    thread_local! {
        static TID: Cell<u64> = const { Cell::new(0) };
    }
    TID.with(|t| {
        let mut v = t.get();
        if v == 0 {
            v = NEXT.fetch_add(1, Ordering::Relaxed);
            t.set(v);
        }
        v
    })
}

/// One completed span.
#[derive(Clone, Debug)]
pub struct SpanRec {
    pub name: &'static str,
    /// Layer index attribution, or -1 when not layer-scoped.
    pub layer: i32,
    pub tid: u64,
    /// Nesting depth on the recording thread at entry (0 = top level).
    pub depth: u32,
    pub start_ns: u64,
    pub dur_ns: u64,
    /// Duration minus enclosed child spans (what [`summarize`] shares).
    pub self_ns: u64,
}

/// One instant event (fault firings and other point-in-time markers).
#[derive(Clone, Debug)]
pub struct EventRec {
    pub name: String,
    pub tid: u64,
    pub ts_ns: u64,
}

#[derive(Default)]
struct Sink {
    spans: Vec<SpanRec>,
    events: Vec<EventRec>,
}

/// Every thread's buffer, registered on first use.  The `Arc` keeps a
/// buffer alive past its thread (the scoped pool spawns short-lived
/// workers), so [`drain`] still sees late spans.
static SINKS: Mutex<Vec<Arc<Mutex<Sink>>>> = Mutex::new(Vec::new());

thread_local! {
    static LOCAL: Arc<Mutex<Sink>> = {
        let sink = Arc::new(Mutex::new(Sink::default()));
        SINKS.lock().unwrap_or_else(|p| p.into_inner()).push(sink.clone());
        sink
    };
    /// Per-thread span stack: each frame accumulates child time so a
    /// closing span can record its self time without a post-pass.
    static STACK: RefCell<Vec<u64>> = const { RefCell::new(Vec::new()) };
}

fn push_span(rec: SpanRec) {
    LOCAL.with(|s| s.lock().unwrap_or_else(|p| p.into_inner()).spans.push(rec));
}

/// Record an instant event (no-op unless tracing is on).
pub fn event(name: &str) {
    if !active() {
        return;
    }
    let rec = EventRec { name: name.to_string(), tid: tid(), ts_ns: now_ns() };
    LOCAL.with(|s| s.lock().unwrap_or_else(|p| p.into_inner()).events.push(rec));
}

/// Open span nesting depth on this thread (0 when every span guard has
/// dropped — the well-formedness invariant the tests pin down).
pub fn current_depth() -> usize {
    STACK.with(|s| s.borrow().len())
}

// ---------------------------------------------------------------------------
// span guards
// ---------------------------------------------------------------------------

/// RAII span guard: records on drop.  Disabled tracing constructs an
/// inert guard after one relaxed load.
pub struct Span {
    name: &'static str,
    layer: i32,
    start_ns: u64,
    depth: u32,
    live: bool,
}

/// Start a span (no layer attribution).
#[inline]
pub fn span(name: &'static str) -> Span {
    span_layer(name, -1)
}

/// Start a span attributed to `layer`.
#[inline]
pub fn span_layer(name: &'static str, layer: i32) -> Span {
    if !active() {
        return Span { name, layer, start_ns: 0, depth: 0, live: false };
    }
    let depth = STACK.with(|s| {
        let mut st = s.borrow_mut();
        st.push(0);
        (st.len() - 1) as u32
    });
    Span { name, layer, start_ns: now_ns(), depth, live: true }
}

impl Drop for Span {
    fn drop(&mut self) {
        if !self.live {
            return;
        }
        let dur_ns = now_ns().saturating_sub(self.start_ns);
        let child_ns = STACK.with(|s| {
            let mut st = s.borrow_mut();
            let child = st.pop().unwrap_or(0);
            if let Some(parent) = st.last_mut() {
                *parent += dur_ns;
            }
            child
        });
        push_span(SpanRec {
            name: self.name,
            layer: self.layer,
            tid: tid(),
            depth: self.depth,
            start_ns: self.start_ns,
            dur_ns,
            self_ns: dur_ns.saturating_sub(child_ns),
        });
    }
}

// ---------------------------------------------------------------------------
// drain + aggregation
// ---------------------------------------------------------------------------

/// Everything recorded since the last drain, merged across threads.
#[derive(Default, Debug)]
pub struct Trace {
    pub spans: Vec<SpanRec>,
    pub events: Vec<EventRec>,
}

/// Take all buffered spans/events (sorted by start time) and release
/// buffers whose threads have exited.
pub fn drain() -> Trace {
    let mut out = Trace::default();
    let mut sinks = SINKS.lock().unwrap_or_else(|p| p.into_inner());
    for sink in sinks.iter() {
        let mut g = sink.lock().unwrap_or_else(|p| p.into_inner());
        out.spans.append(&mut g.spans);
        out.events.append(&mut g.events);
    }
    sinks.retain(|s| Arc::strong_count(s) > 1);
    drop(sinks);
    out.spans.sort_by(|a, b| (a.start_ns, a.tid).cmp(&(b.start_ns, b.tid)));
    out.events.sort_by(|a, b| (a.ts_ns, a.tid).cmp(&(b.ts_ns, b.tid)));
    out
}

/// Drop everything buffered without returning it.
pub fn clear() {
    let _ = drain();
}

/// Per-op aggregate over a set of spans.
#[derive(Clone, Debug)]
pub struct OpStat {
    pub name: &'static str,
    pub calls: u64,
    /// Inclusive time (children counted).
    pub total_ms: f64,
    /// Exclusive time — the basis of `share_pct`.
    pub self_ms: f64,
    /// Share of total traced self time, in percent.
    pub share_pct: f64,
}

/// Aggregate spans into per-op self-time shares (descending).  Shares
/// partition traced time: they sum to 100% (of a non-empty trace).
pub fn summarize(spans: &[SpanRec]) -> Vec<OpStat> {
    let mut by_name: Vec<(&'static str, u64, u64, u64)> = Vec::new();
    for s in spans {
        match by_name.iter_mut().find(|(n, ..)| *n == s.name) {
            Some((_, calls, total, selfs)) => {
                *calls += 1;
                *total += s.dur_ns;
                *selfs += s.self_ns;
            }
            None => by_name.push((s.name, 1, s.dur_ns, s.self_ns)),
        }
    }
    let grand: u64 = by_name.iter().map(|(_, _, _, s)| *s).sum();
    let mut stats: Vec<OpStat> = by_name
        .into_iter()
        .map(|(name, calls, total, selfs)| OpStat {
            name,
            calls,
            total_ms: total as f64 / 1e6,
            self_ms: selfs as f64 / 1e6,
            share_pct: if grand == 0 { 0.0 } else { selfs as f64 * 100.0 / grand as f64 },
        })
        .collect();
    stats.sort_by(|a, b| b.self_ms.total_cmp(&a.self_ms));
    stats
}

/// Render the time-share table `cast bench --profile` prints.
pub fn render_table(stats: &[OpStat]) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "{:<24} {:>8} {:>12} {:>12} {:>8}\n",
        "op", "calls", "total_ms", "self_ms", "share"
    ));
    let mut total_self = 0.0;
    for s in stats {
        total_self += s.self_ms;
        out.push_str(&format!(
            "{:<24} {:>8} {:>12.3} {:>12.3} {:>7.2}%\n",
            s.name, s.calls, s.total_ms, s.self_ms, s.share_pct
        ));
    }
    out.push_str(&format!(
        "{:<24} {:>8} {:>12} {:>12.3} {:>7.2}%\n",
        "total", "", "", total_self, if stats.is_empty() { 0.0 } else { 100.0 }
    ));
    out
}

/// Export as Chrome trace-event JSON (the `{"traceEvents":[...]}`
/// envelope; timestamps in microseconds), loadable in Perfetto.
pub fn chrome_json(t: &Trace) -> String {
    let mut evs = Vec::with_capacity(t.spans.len() + t.events.len());
    for s in &t.spans {
        let mut args = vec![("self_us", Json::num(s.self_ns as f64 / 1e3))];
        if s.layer >= 0 {
            args.push(("layer", Json::num(s.layer as f64)));
        }
        evs.push(Json::obj(vec![
            ("name", Json::str(s.name)),
            ("cat", Json::str("engine")),
            ("ph", Json::str("X")),
            ("ts", Json::num(s.start_ns as f64 / 1e3)),
            ("dur", Json::num(s.dur_ns as f64 / 1e3)),
            ("pid", Json::num(1.0)),
            ("tid", Json::num(s.tid as f64)),
            ("args", Json::obj(args)),
        ]));
    }
    for e in &t.events {
        evs.push(Json::obj(vec![
            ("name", Json::str(&e.name)),
            ("cat", Json::str("fault")),
            ("ph", Json::str("i")),
            ("s", Json::str("t")),
            ("ts", Json::num(e.ts_ns as f64 / 1e3)),
            ("pid", Json::num(1.0)),
            ("tid", Json::num(e.tid as f64)),
        ]));
    }
    Json::obj(vec![("traceEvents", Json::Arr(evs))]).to_string()
}

/// Serialize in-process tests that toggle tracing: the span store is
/// process-global.  Shared by unit and integration tests; not API.
#[doc(hidden)]
pub fn test_guard() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    LOCK.lock().unwrap_or_else(|p| p.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn guard() -> std::sync::MutexGuard<'static, ()> {
        test_guard()
    }

    #[test]
    fn disabled_records_nothing() {
        let _g = guard();
        set_enabled(false);
        clear();
        {
            let _a = span("noop.a");
            let _b = span_layer("noop.b", 3);
            event("noop.ev");
        }
        let t = drain();
        assert!(t.spans.is_empty() && t.events.is_empty());
        assert_eq!(current_depth(), 0);
    }

    #[test]
    fn spans_nest_and_self_time_partitions() {
        let _g = guard();
        set_enabled(true);
        clear();
        {
            let _outer = span("t.outer");
            std::thread::sleep(std::time::Duration::from_millis(2));
            {
                let _inner = span_layer("t.inner", 1);
                std::thread::sleep(std::time::Duration::from_millis(2));
            }
        }
        set_enabled(false);
        let t = drain();
        assert_eq!(t.spans.len(), 2);
        assert_eq!(current_depth(), 0, "guards balanced");
        let outer = t.spans.iter().find(|s| s.name == "t.outer").unwrap();
        let inner = t.spans.iter().find(|s| s.name == "t.inner").unwrap();
        assert_eq!((outer.depth, inner.depth), (0, 1));
        assert_eq!(inner.layer, 1);
        assert!(outer.dur_ns >= inner.dur_ns);
        assert!(
            outer.self_ns <= outer.dur_ns - inner.dur_ns,
            "parent self time excludes the child"
        );
        assert!(inner.start_ns >= outer.start_ns, "monotonic timestamps");
    }

    #[test]
    fn summarize_shares_sum_to_100() {
        let _g = guard();
        set_enabled(true);
        clear();
        for _ in 0..3 {
            let _a = span("s.a");
            let _b = span("s.b");
        }
        set_enabled(false);
        let t = drain();
        let stats = summarize(&t.spans);
        assert_eq!(stats.len(), 2);
        let total: f64 = stats.iter().map(|s| s.share_pct).sum();
        assert!((total - 100.0).abs() < 1e-6, "shares sum to {total}");
        let table = render_table(&stats);
        assert!(table.contains("s.a") && table.contains('%'), "{table}");
    }

    #[test]
    fn chrome_json_is_valid_and_carries_events() {
        let _g = guard();
        set_enabled(true);
        clear();
        {
            let _a = span_layer("c.op", 0);
            event("fault:test.point");
        }
        set_enabled(false);
        let t = drain();
        let json = Json::parse(&chrome_json(&t)).expect("valid JSON");
        let evs = json.get("traceEvents").and_then(Json::as_arr).unwrap();
        assert_eq!(evs.len(), 2);
        assert!(evs.iter().any(|e| e.get("ph").and_then(Json::as_str) == Some("X")));
        assert!(evs.iter().any(|e| e.get("ph").and_then(Json::as_str) == Some("i")
            && e.get("name").and_then(Json::as_str) == Some("fault:test.point")));
    }

    #[test]
    fn cross_thread_spans_merge_on_drain() {
        let _g = guard();
        set_enabled(true);
        clear();
        let handles: Vec<_> = (0..3)
            .map(|i| {
                std::thread::spawn(move || {
                    let _s = span_layer("x.thread", i);
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        set_enabled(false);
        let t = drain();
        let mine: Vec<_> = t.spans.iter().filter(|s| s.name == "x.thread").collect();
        assert_eq!(mine.len(), 3);
        let tids: std::collections::BTreeSet<u64> = mine.iter().map(|s| s.tid).collect();
        assert_eq!(tids.len(), 3, "distinct thread ids");
    }
}
