//! Dependency-free heap accounting: a `#[global_allocator]` wrapper
//! over [`std::alloc::System`] with relaxed-atomic current/peak
//! counters, plus RAII phase watermarks mirroring [`trace::Span`].
//!
//! The counters are *always* maintained once the allocator is installed
//! (three relaxed atomic ops per alloc/dealloc — no locks, no clocks,
//! and critically no allocation from inside the allocator itself).
//! What is gated, exactly like `util::trace`, is the *phase-mark
//! store*: `CAST_MEMTRACK` (any non-empty value other than `0`) or
//! [`set_enabled`] turns on recording of [`Watermark`] phases into a
//! global buffer; when off, a watermark drop is a couple of relaxed
//! loads and no heap traffic.
//!
//! Installation is per binary: the `cast` CLI installs
//! [`TrackingAlloc`] in `main.rs`, and integration tests that assert on
//! byte counts install their own (`#[global_allocator]` does not cross
//! crate boundaries).  [`installed`] probes whether the counters are
//! actually live so `cast bench --memory` can fail loudly instead of
//! reporting zeros.
//!
//! Determinism contract (same as tracing): accounting never changes
//! what the engine computes — it only observes the allocator — so
//! outputs are bit-identical with tracking installed or not.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU8, AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;

/// Live heap bytes allocated through the tracking allocator.
static CURRENT: AtomicUsize = AtomicUsize::new(0);
/// High-water mark of [`CURRENT`] since process start / last reset.
static PEAK: AtomicUsize = AtomicUsize::new(0);
/// Total successful allocations (alloc + alloc_zeroed + realloc).
static ALLOCS: AtomicU64 = AtomicU64::new(0);

/// The `#[global_allocator]` wrapper.  Zero-sized; all state is in the
/// module statics so counters are readable without a handle.
pub struct TrackingAlloc;

#[inline]
fn on_alloc(size: usize) {
    let cur = CURRENT.fetch_add(size, Ordering::Relaxed) + size;
    PEAK.fetch_max(cur, Ordering::Relaxed);
    ALLOCS.fetch_add(1, Ordering::Relaxed);
}

#[inline]
fn on_dealloc(size: usize) {
    CURRENT.fetch_sub(size, Ordering::Relaxed);
}

// SAFETY: delegates every operation to `System` unchanged; the counter
// updates are lock-free atomics and never allocate, so the allocator
// cannot re-enter itself.
unsafe impl GlobalAlloc for TrackingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        let p = System.alloc(layout);
        if !p.is_null() {
            on_alloc(layout.size());
        }
        p
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        let p = System.alloc_zeroed(layout);
        if !p.is_null() {
            on_alloc(layout.size());
        }
        p
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout);
        on_dealloc(layout.size());
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        let p = System.realloc(ptr, layout, new_size);
        if !p.is_null() {
            on_dealloc(layout.size());
            on_alloc(new_size);
        }
        p
    }
}

/// Live heap bytes right now (0 until [`TrackingAlloc`] is installed).
pub fn current_bytes() -> usize {
    CURRENT.load(Ordering::Relaxed)
}

/// High-water heap bytes since process start or the last [`reset_peak`].
pub fn peak_bytes() -> usize {
    PEAK.load(Ordering::Relaxed)
}

/// Total allocations observed (monotonic; the overhead-guard tests
/// diff this around code that must not touch the heap).
pub fn total_allocs() -> u64 {
    ALLOCS.load(Ordering::Relaxed)
}

/// Reset the high-water mark to the current level, so the next
/// [`peak_bytes`] reading reflects only growth from here on.
pub fn reset_peak() {
    PEAK.store(CURRENT.load(Ordering::Relaxed), Ordering::Relaxed);
}

/// True when [`TrackingAlloc`] is this binary's global allocator: a
/// probe allocation must move the counter.  `black_box` keeps the
/// optimizer from eliding the probe.
pub fn installed() -> bool {
    let before = ALLOCS.load(Ordering::Relaxed);
    let probe = std::hint::black_box(Box::new([0u8; 64]));
    drop(std::hint::black_box(probe));
    ALLOCS.load(Ordering::Relaxed) != before
}

// ---------------------------------------------------------------------------
// phase-mark gate (mirrors util::trace STATE handling)
// ---------------------------------------------------------------------------

const UNINIT: u8 = 0;
const INACTIVE: u8 = 1;
const ENABLED: u8 = 2;

static STATE: AtomicU8 = AtomicU8::new(UNINIT);

/// True when phase-mark recording is on.  One relaxed load when not.
#[inline]
pub fn active() -> bool {
    state() == ENABLED
}

/// Programmatically enable/disable phase-mark recording (overrides
/// `CAST_MEMTRACK`).  Used by `cast bench --memory` and the test suite.
pub fn set_enabled(on: bool) {
    STATE.store(if on { ENABLED } else { INACTIVE }, Ordering::SeqCst);
}

#[inline]
fn state() -> u8 {
    let s = STATE.load(Ordering::Relaxed);
    if s == UNINIT {
        init_from_env()
    } else {
        s
    }
}

#[cold]
fn init_from_env() -> u8 {
    static INIT: std::sync::Once = std::sync::Once::new();
    INIT.call_once(|| {
        let on = match std::env::var("CAST_MEMTRACK") {
            Ok(v) => !v.trim().is_empty() && v.trim() != "0",
            Err(_) => false,
        };
        if on {
            crate::info!("memtrack: phase marks enabled via CAST_MEMTRACK");
        }
        let _ = STATE.compare_exchange(
            UNINIT,
            if on { ENABLED } else { INACTIVE },
            Ordering::SeqCst,
            Ordering::SeqCst,
        );
    });
    STATE.load(Ordering::Relaxed)
}

// ---------------------------------------------------------------------------
// RAII phase watermarks
// ---------------------------------------------------------------------------

/// One completed watermark phase: how far the heap grew above its
/// starting level while the phase ran.
#[derive(Clone, Debug)]
pub struct PhaseMark {
    pub name: &'static str,
    /// Live bytes when the phase began.
    pub base_bytes: usize,
    /// Peak growth above `base_bytes` during the phase.
    pub peak_delta_bytes: usize,
    /// Live bytes when the phase ended (leaks/retained buffers show as
    /// `end_bytes > base_bytes`).
    pub end_bytes: usize,
}

static MARKS: Mutex<Vec<PhaseMark>> = Mutex::new(Vec::new());

/// RAII phase watermark, the space analog of [`crate::util::trace::Span`]:
/// resets the global peak to the current level on begin, and reads the
/// phase's peak growth on drop (recorded into the mark store only while
/// [`active`]).  Watermarks measure a *global* high-water mark, so
/// overlapping phases on concurrent threads attribute shared growth to
/// both — scope them around single-threaded driver code (bench sweeps,
/// train steps), not inside parallel workers.
pub struct Watermark {
    name: &'static str,
    base: usize,
}

impl Watermark {
    /// Begin a phase: snapshot the current level and reset the peak so
    /// the phase measures only its own growth.
    pub fn begin(name: &'static str) -> Watermark {
        let base = current_bytes();
        reset_peak();
        Watermark { name, base }
    }

    /// Peak growth above the phase's starting level, so far.
    pub fn peak_delta(&self) -> usize {
        peak_bytes().saturating_sub(self.base)
    }
}

impl Drop for Watermark {
    fn drop(&mut self) {
        if !active() {
            return;
        }
        let mark = PhaseMark {
            name: self.name,
            base_bytes: self.base,
            peak_delta_bytes: self.peak_delta(),
            end_bytes: current_bytes(),
        };
        MARKS.lock().unwrap_or_else(|p| p.into_inner()).push(mark);
    }
}

/// Take all recorded phase marks (oldest first).
pub fn drain_marks() -> Vec<PhaseMark> {
    std::mem::take(&mut *MARKS.lock().unwrap_or_else(|p| p.into_inner()))
}

/// Serialize in-process tests that toggle the gate or read the global
/// counters: both are process-global.  Not API.
#[doc(hidden)]
pub fn test_guard() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    LOCK.lock().unwrap_or_else(|p| p.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;

    // NOTE: the lib unit-test binary does NOT install TrackingAlloc
    // (`#[global_allocator]` is per binary), so these tests only cover
    // the gate and mark-store plumbing; the byte-accounting assertions
    // live in tests/integration_memstats.rs, which installs its own.

    #[test]
    fn gate_toggles_and_probe_does_not_panic() {
        let _g = test_guard();
        set_enabled(false);
        assert!(!active());
        set_enabled(true);
        assert!(active());
        set_enabled(false);
        let _ = installed(); // false here (no allocator), but must not panic
    }

    #[test]
    fn watermark_is_silent_when_gate_is_off() {
        let _g = test_guard();
        set_enabled(false);
        let _ = drain_marks();
        drop(Watermark::begin("unit.off"));
        assert!(drain_marks().is_empty(), "no marks recorded while off");
    }

    #[test]
    fn watermark_records_a_mark_when_gate_is_on() {
        let _g = test_guard();
        set_enabled(true);
        let _ = drain_marks();
        drop(Watermark::begin("unit.on"));
        let marks = drain_marks();
        set_enabled(false);
        assert_eq!(marks.len(), 1);
        assert_eq!(marks[0].name, "unit.on");
    }
}
