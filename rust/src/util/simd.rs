//! Portable explicit 8-lane f32 kernels for the native engine's inner
//! loops — the constant-factor lever under the thread pool (DESIGN.md
//! §SIMD).  No intrinsics, no nightly `std::simd`, no external crates:
//! every kernel is written over fixed-shape `[f32; 8]` lane groups with
//! a deterministic, fixed-order reduction, which stable rustc reliably
//! lowers to vector instructions on any target that has them (and to
//! plain scalar code on any that doesn't).
//!
//! **Two implementations per kernel.**  Every public kernel `k8` has a
//! `k8_lanes` (vector) and a `k8_scalar` (sequential reference) variant
//! and dispatches on [`enabled`].  The scalar variants are the
//! correctness oracles of the parity harness (`tests/integration_simd.rs`)
//! and the escape hatch: `CAST_NO_SIMD=1` (or [`set_forced`]) routes every
//! call to them.
//!
//! **Exactness contract** (relied on by the parity tests):
//!
//! * *Elementwise* kernels ([`axpy8`], [`add8`], [`scale8`],
//!   [`scale_add8`], [`norm_affine8`]), [`max8`] (max is
//!   order-insensitive), and the [`matmul_rows8`] microkernel (its
//!   per-element accumulation order — ascending input dimension — is
//!   identical in both variants) are **bit-identical** between lanes and
//!   scalar.
//! * *Reduction* kernels ([`dot8`], [`sum8`], [`sumsq_diff8`]) reassociate
//!   the sum into 8 lanes (tree-combined `((0+1)+(2+3)) + ((4+5)+(6+7))`,
//!   then a sequential tail), so lanes-vs-scalar may differ by f32
//!   rounding — the documented reassociation tolerance (≤ 1e-5 relative
//!   at layer shapes).  Each variant is individually deterministic: the
//!   reduction order never depends on thread count or scheduling.
//!
//! **Mode is process-global.**  Unlike `parallel::set_threads` (safe to
//! race because results never depend on the worker count), the SIMD mode
//! *does* move results within the tolerance above, so tests that flip it
//! serialize on their own lock and restore the prior mode.

use std::sync::atomic::{AtomicU8, Ordering};

/// Lane width of every kernel in this module.
pub const LANES: usize = 8;

const MODE_UNSET: u8 = 0;
const MODE_LANES: u8 = 1;
const MODE_SCALAR: u8 = 2;

/// Resolved dispatch mode, cached after the first env read.
static MODE: AtomicU8 = AtomicU8::new(MODE_UNSET);

fn env_mode() -> u8 {
    match std::env::var("CAST_NO_SIMD") {
        Ok(v) if !matches!(v.trim(), "" | "0" | "false") => MODE_SCALAR,
        _ => MODE_LANES,
    }
}

/// Whether calls dispatch to the lane kernels (`true`) or the scalar
/// reference path (`false`): [`set_forced`] override, else `CAST_NO_SIMD`.
#[inline]
pub fn enabled() -> bool {
    match MODE.load(Ordering::Relaxed) {
        MODE_LANES => true,
        MODE_SCALAR => false,
        _ => {
            let m = env_mode();
            MODE.store(m, Ordering::Relaxed);
            m == MODE_LANES
        }
    }
}

/// Force the dispatch mode for this process: `Some(true)` = lanes,
/// `Some(false)` = scalar reference, `None` = re-resolve from
/// `CAST_NO_SIMD` on the next call.  Test/tool hook — see the module
/// docs for the serialization caveat.
pub fn set_forced(mode: Option<bool>) {
    let v = match mode {
        Some(true) => MODE_LANES,
        Some(false) => MODE_SCALAR,
        None => MODE_UNSET,
    };
    MODE.store(v, Ordering::SeqCst);
}

// ---------------------------------------------------------------------------
// reductions (lanes-vs-scalar differ by reassociation tolerance)
// ---------------------------------------------------------------------------

/// Fixed-order combine of one lane accumulator block.
#[inline]
fn reduce_lanes(acc: [f32; LANES]) -> f32 {
    ((acc[0] + acc[1]) + (acc[2] + acc[3])) + ((acc[4] + acc[5]) + (acc[6] + acc[7]))
}

/// Unit-stride dot product.
#[inline]
pub fn dot8(a: &[f32], b: &[f32]) -> f32 {
    if enabled() {
        dot8_lanes(a, b)
    } else {
        dot8_scalar(a, b)
    }
}

/// [`dot8`], 8-lane accumulators + tree reduction + sequential tail.
#[inline]
pub fn dot8_lanes(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0.0f32; LANES];
    let mut ca = a.chunks_exact(LANES);
    let mut cb = b.chunks_exact(LANES);
    for (xa, xb) in (&mut ca).zip(&mut cb) {
        for l in 0..LANES {
            acc[l] += xa[l] * xb[l];
        }
    }
    let mut tail = 0.0f32;
    for (&x, &y) in ca.remainder().iter().zip(cb.remainder()) {
        tail += x * y;
    }
    reduce_lanes(acc) + tail
}

/// [`dot8`], sequential scalar reference.
#[inline]
pub fn dot8_scalar(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0.0f32;
    for (&x, &y) in a.iter().zip(b) {
        acc += x * y;
    }
    acc
}

/// Sum of a slice.
#[inline]
pub fn sum8(x: &[f32]) -> f32 {
    if enabled() {
        sum8_lanes(x)
    } else {
        sum8_scalar(x)
    }
}

/// [`sum8`], 8-lane accumulators + tree reduction + sequential tail.
#[inline]
pub fn sum8_lanes(x: &[f32]) -> f32 {
    let mut acc = [0.0f32; LANES];
    let mut cx = x.chunks_exact(LANES);
    for xa in &mut cx {
        for l in 0..LANES {
            acc[l] += xa[l];
        }
    }
    let mut tail = 0.0f32;
    for &v in cx.remainder() {
        tail += v;
    }
    reduce_lanes(acc) + tail
}

/// [`sum8`], sequential scalar reference.
#[inline]
pub fn sum8_scalar(x: &[f32]) -> f32 {
    let mut acc = 0.0f32;
    for &v in x {
        acc += v;
    }
    acc
}

/// `Σ (x_i - mu)²` — the shared variance / squared-norm reduction of the
/// layer and scale norms (`mu = 0` gives the plain sum of squares).
#[inline]
pub fn sumsq_diff8(x: &[f32], mu: f32) -> f32 {
    if enabled() {
        sumsq_diff8_lanes(x, mu)
    } else {
        sumsq_diff8_scalar(x, mu)
    }
}

/// [`sumsq_diff8`], 8-lane accumulators + tree reduction + tail.
#[inline]
pub fn sumsq_diff8_lanes(x: &[f32], mu: f32) -> f32 {
    let mut acc = [0.0f32; LANES];
    let mut cx = x.chunks_exact(LANES);
    for xa in &mut cx {
        for l in 0..LANES {
            let d = xa[l] - mu;
            acc[l] += d * d;
        }
    }
    let mut tail = 0.0f32;
    for &v in cx.remainder() {
        let d = v - mu;
        tail += d * d;
    }
    reduce_lanes(acc) + tail
}

/// [`sumsq_diff8`], sequential scalar reference.
#[inline]
pub fn sumsq_diff8_scalar(x: &[f32], mu: f32) -> f32 {
    let mut acc = 0.0f32;
    for &v in x {
        let d = v - mu;
        acc += d * d;
    }
    acc
}

/// Sum of `f(0..n)` with **exactly** the summation order of [`sum8`] in
/// the corresponding mode — for callers that compute terms on the fly
/// (e.g. the laplace backward recomputing a normalizer the forward
/// produced via [`sum8`]) without materializing a scratch row.
#[inline]
pub fn sum8_map(n: usize, f: impl FnMut(usize) -> f32) -> f32 {
    if enabled() {
        sum8_map_lanes(n, f)
    } else {
        sum8_map_scalar(n, f)
    }
}

/// [`sum8_map`], lane order (matches [`sum8_lanes`] term for term).
#[inline]
pub fn sum8_map_lanes(n: usize, mut f: impl FnMut(usize) -> f32) -> f32 {
    let mut acc = [0.0f32; LANES];
    let mut i = 0usize;
    while i + LANES <= n {
        for l in 0..LANES {
            acc[l] += f(i + l);
        }
        i += LANES;
    }
    let mut tail = 0.0f32;
    while i < n {
        tail += f(i);
        i += 1;
    }
    reduce_lanes(acc) + tail
}

/// [`sum8_map`], sequential order (matches [`sum8_scalar`]).
#[inline]
pub fn sum8_map_scalar(n: usize, mut f: impl FnMut(usize) -> f32) -> f32 {
    let mut acc = 0.0f32;
    for i in 0..n {
        acc += f(i);
    }
    acc
}

// ---------------------------------------------------------------------------
// order-insensitive / elementwise kernels (bit-identical across modes)
// ---------------------------------------------------------------------------

/// Row maximum with a `-∞` identity (softmax row max).  Max is
/// order-insensitive, so lanes and scalar agree exactly.
#[inline]
pub fn max8(x: &[f32]) -> f32 {
    if enabled() {
        max8_lanes(x)
    } else {
        max8_scalar(x)
    }
}

/// [`max8`], lane-blocked.
#[inline]
pub fn max8_lanes(x: &[f32]) -> f32 {
    let mut acc = [f32::NEG_INFINITY; LANES];
    let mut cx = x.chunks_exact(LANES);
    for xa in &mut cx {
        for l in 0..LANES {
            acc[l] = acc[l].max(xa[l]);
        }
    }
    let mut m = f32::NEG_INFINITY;
    for &v in cx.remainder() {
        m = m.max(v);
    }
    for a in acc {
        m = m.max(a);
    }
    m
}

/// [`max8`], sequential scalar reference.
#[inline]
pub fn max8_scalar(x: &[f32]) -> f32 {
    let mut m = f32::NEG_INFINITY;
    for &v in x {
        m = m.max(v);
    }
    m
}

/// `y[i] += a · x[i]` — the scaled-accumulate of the attention AV loops,
/// the combination scatter, and the dense parameter gradients.
#[inline]
pub fn axpy8(y: &mut [f32], a: f32, x: &[f32]) {
    if enabled() {
        axpy8_lanes(y, a, x)
    } else {
        axpy8_scalar(y, a, x)
    }
}

/// [`axpy8`], lane-blocked (identical per-element arithmetic).
#[inline]
pub fn axpy8_lanes(y: &mut [f32], a: f32, x: &[f32]) {
    debug_assert_eq!(y.len(), x.len());
    let mut cy = y.chunks_exact_mut(LANES);
    let mut cx = x.chunks_exact(LANES);
    for (ya, xa) in (&mut cy).zip(&mut cx) {
        for l in 0..LANES {
            ya[l] += a * xa[l];
        }
    }
    for (yv, &xv) in cy.into_remainder().iter_mut().zip(cx.remainder()) {
        *yv += a * xv;
    }
}

/// [`axpy8`], sequential scalar reference.
#[inline]
pub fn axpy8_scalar(y: &mut [f32], a: f32, x: &[f32]) {
    debug_assert_eq!(y.len(), x.len());
    for (yv, &xv) in y.iter_mut().zip(x) {
        *yv += a * xv;
    }
}

/// `y[i] += x[i]` — residual adds, slab gathers, bias gradients.
#[inline]
pub fn add8(y: &mut [f32], x: &[f32]) {
    if enabled() {
        add8_lanes(y, x)
    } else {
        add8_scalar(y, x)
    }
}

/// [`add8`], lane-blocked.
#[inline]
pub fn add8_lanes(y: &mut [f32], x: &[f32]) {
    debug_assert_eq!(y.len(), x.len());
    let mut cy = y.chunks_exact_mut(LANES);
    let mut cx = x.chunks_exact(LANES);
    for (ya, xa) in (&mut cy).zip(&mut cx) {
        for l in 0..LANES {
            ya[l] += xa[l];
        }
    }
    for (yv, &xv) in cy.into_remainder().iter_mut().zip(cx.remainder()) {
        *yv += xv;
    }
}

/// [`add8`], sequential scalar reference.
#[inline]
pub fn add8_scalar(y: &mut [f32], x: &[f32]) {
    debug_assert_eq!(y.len(), x.len());
    for (yv, &xv) in y.iter_mut().zip(x) {
        *yv += xv;
    }
}

/// `y[i] *= a` — the row renormalization of softmax / laplace / scalenorm.
#[inline]
pub fn scale8(y: &mut [f32], a: f32) {
    if enabled() {
        scale8_lanes(y, a)
    } else {
        scale8_scalar(y, a)
    }
}

/// [`scale8`], lane-blocked.
#[inline]
pub fn scale8_lanes(y: &mut [f32], a: f32) {
    let mut cy = y.chunks_exact_mut(LANES);
    for ya in &mut cy {
        for l in 0..LANES {
            ya[l] *= a;
        }
    }
    for yv in cy.into_remainder() {
        *yv *= a;
    }
}

/// [`scale8`], sequential scalar reference.
#[inline]
pub fn scale8_scalar(y: &mut [f32], a: f32) {
    for yv in y {
        *yv *= a;
    }
}

/// `y[i] = a · y[i] + b` — the scalar-affine in-place row update
/// (rescale + shift in one pass).
#[inline]
pub fn scale_add8(y: &mut [f32], a: f32, b: f32) {
    if enabled() {
        scale_add8_lanes(y, a, b)
    } else {
        scale_add8_scalar(y, a, b)
    }
}

/// [`scale_add8`], lane-blocked.
#[inline]
pub fn scale_add8_lanes(y: &mut [f32], a: f32, b: f32) {
    let mut cy = y.chunks_exact_mut(LANES);
    for ya in &mut cy {
        for l in 0..LANES {
            ya[l] = a * ya[l] + b;
        }
    }
    for yv in cy.into_remainder() {
        *yv = a * *yv + b;
    }
}

/// [`scale_add8`], sequential scalar reference.
#[inline]
pub fn scale_add8_scalar(y: &mut [f32], a: f32, b: f32) {
    for yv in y {
        *yv = a * *yv + b;
    }
}

/// `row[i] = g[i] · (row[i] - mu) · inv + b[i]` — the fused affine tail
/// of a layernorm row.
#[inline]
pub fn norm_affine8(row: &mut [f32], g: &[f32], b: &[f32], mu: f32, inv: f32) {
    if enabled() {
        norm_affine8_lanes(row, g, b, mu, inv)
    } else {
        norm_affine8_scalar(row, g, b, mu, inv)
    }
}

/// [`norm_affine8`], lane-blocked.
#[inline]
pub fn norm_affine8_lanes(row: &mut [f32], g: &[f32], b: &[f32], mu: f32, inv: f32) {
    debug_assert_eq!(row.len(), g.len());
    debug_assert_eq!(row.len(), b.len());
    let mut cr = row.chunks_exact_mut(LANES);
    let mut cg = g.chunks_exact(LANES);
    let mut cb = b.chunks_exact(LANES);
    for ((ra, ga), ba) in (&mut cr).zip(&mut cg).zip(&mut cb) {
        for l in 0..LANES {
            ra[l] = ga[l] * (ra[l] - mu) * inv + ba[l];
        }
    }
    let (rem_g, rem_b) = (cg.remainder(), cb.remainder());
    for ((rv, &gv), &bv) in cr.into_remainder().iter_mut().zip(rem_g).zip(rem_b) {
        *rv = gv * (*rv - mu) * inv + bv;
    }
}

/// [`norm_affine8`], sequential scalar reference.
#[inline]
pub fn norm_affine8_scalar(row: &mut [f32], g: &[f32], b: &[f32], mu: f32, inv: f32) {
    debug_assert_eq!(row.len(), g.len());
    debug_assert_eq!(row.len(), b.len());
    for ((rv, &gv), &bv) in row.iter_mut().zip(g).zip(b) {
        *rv = gv * (*rv - mu) * inv + bv;
    }
}

// ---------------------------------------------------------------------------
// the matmul microkernel
// ---------------------------------------------------------------------------

/// `out = x @ w + bias` over row-major slices — `x` is (rows, d_in),
/// `w` is (d_in, d_out), `bias` is (d_out), `out` is (rows, d_out).
///
/// Rank-1-update formulation in 8-row blocks: the outer loop walks the
/// input dimension so each weight row `w[i, :]` is streamed once per
/// 8-row block (instead of once per output row) and accumulated into the
/// block's output rows as a unit-stride [`axpy8`].  Per output element
/// the accumulation order is ascending `i` in **both** variants and is
/// independent of row blocking, so results are bit-identical across
/// lanes/scalar dispatch, thread counts, and caller chunking.  Zero
/// input activations are skipped on both paths (identical arithmetic:
/// the skipped update is an exact `+ 0`).
pub fn matmul_rows8(
    x: &[f32],
    w: &[f32],
    bias: &[f32],
    rows: usize,
    d_in: usize,
    d_out: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(x.len(), rows * d_in);
    debug_assert_eq!(w.len(), d_in * d_out);
    debug_assert_eq!(bias.len(), d_out);
    debug_assert_eq!(out.len(), rows * d_out);
    let lanes = enabled();
    for yrow in out.chunks_mut(d_out) {
        yrow.copy_from_slice(bias);
    }
    let mut r0 = 0usize;
    while r0 < rows {
        let rb = (rows - r0).min(LANES);
        let block = &mut out[r0 * d_out..(r0 + rb) * d_out];
        for i in 0..d_in {
            let wrow = &w[i * d_out..(i + 1) * d_out];
            for rr in 0..rb {
                let xv = x[(r0 + rr) * d_in + i];
                if xv != 0.0 {
                    let yrow = &mut block[rr * d_out..(rr + 1) * d_out];
                    if lanes {
                        axpy8_lanes(yrow, xv, wrow);
                    } else {
                        axpy8_scalar(yrow, xv, wrow);
                    }
                }
            }
        }
        r0 += rb;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    // NOTE: these tests compare the `_lanes` and `_scalar` variants
    // directly and never call `set_forced` — the dispatch mode is
    // process-global and other lib tests run concurrently (the forced
    // modes are exercised in `tests/integration_simd.rs`, which owns its
    // whole process).

    fn randn(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.gaussian() as f32).collect()
    }

    /// Ragged lengths around the lane width.
    const LENS: [usize; 10] = [0, 1, 3, 7, 8, 9, 15, 16, 17, 100];

    #[test]
    fn reductions_match_f64_reference_on_ragged_lengths() {
        let mut rng = Rng::new(42);
        for &n in &LENS {
            let a = randn(&mut rng, n);
            let b = randn(&mut rng, n);
            let dot_ref: f64 = a.iter().zip(&b).map(|(&x, &y)| x as f64 * y as f64).sum();
            let sum_ref: f64 = a.iter().map(|&x| x as f64).sum();
            let mu = 0.25f32;
            let ssq_ref: f64 =
                a.iter().map(|&x| (x as f64 - mu as f64) * (x as f64 - mu as f64)).sum();
            for (name, got) in [
                ("dot.lanes", dot8_lanes(&a, &b) as f64 - dot_ref),
                ("dot.scalar", dot8_scalar(&a, &b) as f64 - dot_ref),
                ("sum.lanes", sum8_lanes(&a) as f64 - sum_ref),
                ("sum.scalar", sum8_scalar(&a) as f64 - sum_ref),
                ("ssq.lanes", sumsq_diff8_lanes(&a, mu) as f64 - ssq_ref),
                ("ssq.scalar", sumsq_diff8_scalar(&a, mu) as f64 - ssq_ref),
            ] {
                assert!(got.abs() < 1e-3 * (n as f64 + 1.0), "n={n} {name}: off by {got}");
            }
        }
    }

    #[test]
    fn sum_map_matches_materialized_sum_exactly() {
        // same summation order as sum8 in each mode, term for term
        let mut rng = Rng::new(58);
        for &n in &LENS {
            let a = randn(&mut rng, n);
            assert_eq!(sum8_map_lanes(n, |i| a[i]), sum8_lanes(&a), "lanes n={n}");
            assert_eq!(sum8_map_scalar(n, |i| a[i]), sum8_scalar(&a), "scalar n={n}");
        }
    }

    #[test]
    fn max_is_exact_across_variants() {
        let mut rng = Rng::new(7);
        for &n in &LENS {
            let mut a = randn(&mut rng, n);
            if n > 2 {
                a[n / 2] = f32::NEG_INFINITY;
            }
            assert_eq!(max8_lanes(&a), max8_scalar(&a), "n={n}");
        }
        assert_eq!(max8_lanes(&[]), f32::NEG_INFINITY);
    }

    #[test]
    fn elementwise_kernels_are_bit_identical_across_variants() {
        let mut rng = Rng::new(19);
        for &n in &LENS {
            let x = randn(&mut rng, n);
            let base = randn(&mut rng, n);
            let a = 0.73f32;

            let mut y1 = base.clone();
            let mut y2 = base.clone();
            axpy8_lanes(&mut y1, a, &x);
            axpy8_scalar(&mut y2, a, &x);
            assert_eq!(y1, y2, "axpy n={n}");

            let mut y1 = base.clone();
            let mut y2 = base.clone();
            add8_lanes(&mut y1, &x);
            add8_scalar(&mut y2, &x);
            assert_eq!(y1, y2, "add n={n}");

            let mut y1 = base.clone();
            let mut y2 = base.clone();
            scale8_lanes(&mut y1, a);
            scale8_scalar(&mut y2, a);
            assert_eq!(y1, y2, "scale n={n}");

            let mut y1 = base.clone();
            let mut y2 = base.clone();
            scale_add8_lanes(&mut y1, a, -0.4);
            scale_add8_scalar(&mut y2, a, -0.4);
            assert_eq!(y1, y2, "scale_add n={n}");

            let g = randn(&mut rng, n);
            let b = randn(&mut rng, n);
            let mut y1 = base.clone();
            let mut y2 = base;
            norm_affine8_lanes(&mut y1, &g, &b, 0.2, 1.7);
            norm_affine8_scalar(&mut y2, &g, &b, 0.2, 1.7);
            assert_eq!(y1, y2, "norm_affine n={n}");
        }
    }

    #[test]
    fn matmul_matches_naive_reference() {
        let mut rng = Rng::new(33);
        // ragged row counts and dims around the 8-row block
        for &(rows, d_in, d_out) in
            &[(1usize, 1usize, 1usize), (3, 5, 2), (8, 8, 8), (9, 7, 5), (17, 13, 11), (2, 4, 1)]
        {
            let x = randn(&mut rng, rows * d_in);
            let w = randn(&mut rng, d_in * d_out);
            let b = randn(&mut rng, d_out);
            let mut naive = vec![0.0f32; rows * d_out];
            for r in 0..rows {
                for o in 0..d_out {
                    let mut acc = b[o] as f64;
                    for i in 0..d_in {
                        acc += x[r * d_in + i] as f64 * w[i * d_out + o] as f64;
                    }
                    naive[r * d_out + o] = acc as f32;
                }
            }
            let mut got = vec![0.0f32; rows * d_out];
            matmul_rows8(&x, &w, &b, rows, d_in, d_out, &mut got);
            for (g, n) in got.iter().zip(&naive) {
                assert!(
                    (g - n).abs() <= 1e-4 * (1.0 + n.abs()),
                    "({rows},{d_in},{d_out}): {g} vs {n}"
                );
            }
        }
    }

    #[test]
    fn enabled_resolves_without_panicking() {
        // value depends on the environment (CI runs the suite under both
        // CAST_NO_SIMD settings); only the dispatch machinery is asserted
        let _ = enabled();
    }
}
