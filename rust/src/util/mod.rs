//! Offline substrates: everything a normal project would pull from
//! crates.io but this repo builds from scratch (see DESIGN.md
//! §Substitutions — no network in the build environment).

pub mod cli;
pub mod fault;
pub mod json;
pub mod memtrack;
pub mod parallel;
pub mod pgm;
pub mod prop;
pub mod rng;
pub mod retry;
pub mod simd;
pub mod trace;

use std::time::Instant;

/// Wall-clock timer with a readable report, used by the bench harness.
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Timer {
        Timer { start: Instant::now() }
    }

    pub fn seconds(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
}

/// Peak resident set size of this process in bytes (VmHWM from
/// /proc/self/status).  The bench harness runs each measured config in a
/// child process so peaks don't contaminate each other.
pub fn peak_rss_bytes() -> Option<u64> {
    let text = std::fs::read_to_string("/proc/self/status").ok()?;
    for line in text.lines() {
        if let Some(rest) = line.strip_prefix("VmHWM:") {
            let kb: u64 = rest.trim().trim_end_matches(" kB").trim().parse().ok()?;
            return Some(kb * 1024);
        }
    }
    None
}

/// Simple stamped logging to stderr; level filtered by CAST_LOG=debug|info.
pub fn log(level: &str, msg: &str) {
    let want_debug = std::env::var("CAST_LOG").map(|v| v == "debug").unwrap_or(false);
    if level == "debug" && !want_debug {
        return;
    }
    let t = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs_f64())
        .unwrap_or(0.0);
    eprintln!("[{t:.3} {level}] {msg}");
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => { $crate::util::log("info", &format!($($arg)*)) };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => { $crate::util::log("debug", &format!($($arg)*)) };
}

#[cfg(test)]
mod tests {
    #[test]
    fn peak_rss_is_positive_on_linux() {
        let rss = super::peak_rss_bytes();
        assert!(rss.unwrap_or(0) > 0);
    }
}
