//! Tiny property-testing substrate (proptest is unavailable offline).
//!
//! `check(name, cases, gen, prop)` runs `prop` on `cases` random inputs
//! drawn by `gen`; on failure it performs a bounded greedy shrink using the
//! caller-provided `shrink` candidates (if any) and panics with the seed so
//! the case is reproducible: rerun with `PROP_SEED=<seed>`.
//!
//! Also home to the reusable central-difference gradient checker
//! ([`grad_check`]) the native autograd subsystem validates itself with:
//! tolerance-aware, per-parameter-block reporting, and robust to the
//! non-differentiable points of hard clustering via a caller-supplied
//! discrete-state fingerprint (coordinates whose perturbation flips the
//! cluster assignment are skipped, not failed — the derivative genuinely
//! does not exist there).

use super::rng::Rng;

pub struct Config {
    pub cases: usize,
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        let seed = std::env::var("PROP_SEED")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(0xCA57_0001);
        Config { cases: 64, seed }
    }
}

/// Run a property over random inputs.  `gen` draws a case from the RNG;
/// `prop` returns Err(description) on violation.
pub fn check<T: std::fmt::Debug>(
    name: &str,
    cfg: Config,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    for case in 0..cfg.cases {
        let mut rng = Rng::new(cfg.seed).split(case as u64);
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property {name:?} failed on case {case} (PROP_SEED={}):\n  {msg}\n  input: {input:?}",
                cfg.seed,
            );
        }
    }
}

/// Like `check` but with a caller-provided shrinker: on failure, repeatedly
/// tries `shrink(input)` candidates that still fail, reporting the smallest.
pub fn check_shrink<T: std::fmt::Debug + Clone>(
    name: &str,
    cfg: Config,
    mut gen: impl FnMut(&mut Rng) -> T,
    shrink: impl Fn(&T) -> Vec<T>,
    prop: impl Fn(&T) -> Result<(), String>,
) {
    for case in 0..cfg.cases {
        let mut rng = Rng::new(cfg.seed).split(case as u64);
        let input = gen(&mut rng);
        if let Err(first_msg) = prop(&input) {
            let mut best = input.clone();
            let mut msg = first_msg;
            // bounded greedy descent
            'outer: for _ in 0..200 {
                for cand in shrink(&best) {
                    if let Err(m) = prop(&cand) {
                        best = cand;
                        msg = m;
                        continue 'outer;
                    }
                }
                break;
            }
            panic!(
                "property {name:?} failed on case {case} (PROP_SEED={}):\n  {msg}\n  shrunk input: {best:?}",
                cfg.seed,
            );
        }
    }
}

// ---------------------------------------------------------------------------
// central-difference gradient checking
// ---------------------------------------------------------------------------

/// Tolerances and sampling policy for [`grad_check`].
#[derive(Clone, Debug)]
pub struct GradCheckCfg {
    /// Central-difference step.
    pub eps: f32,
    /// Relative tolerance: a coordinate passes when
    /// `|num - ana| <= abs_tol + rel_tol * max(|num|, |ana|)`.
    pub rel_tol: f32,
    /// Absolute floor of the tolerance (f32 loss evaluations are noisy
    /// near zero gradients).
    pub abs_tol: f32,
    /// Coordinates checked per block (evenly strided; every coordinate
    /// when the block is smaller).
    pub max_per_block: usize,
}

impl Default for GradCheckCfg {
    fn default() -> Self {
        GradCheckCfg { eps: 1e-3, rel_tol: 1e-2, abs_tol: 1e-4, max_per_block: 16 }
    }
}

/// Outcome of checking one named parameter block.
#[derive(Clone, Debug)]
pub struct GradBlockReport {
    pub name: String,
    /// Coordinates actually compared.
    pub checked: usize,
    /// Coordinates skipped because the perturbation changed the discrete
    /// state fingerprint (clustering flip — no derivative there).
    pub skipped: usize,
    /// Largest `|num - ana| / max(|num|, |ana|, 1e-6)` over the block.
    pub max_rel_err: f32,
    /// `(flat index, analytic, numeric)` of the worst coordinate.
    pub worst: Option<(usize, f32, f32)>,
}

/// Central-difference check of `analytic` (the gradient of `eval`'s loss
/// at `theta`).  `blocks` is a `(name, len)` partition of `theta` in
/// order — per-parameter-block reporting comes back in the same order.
/// `eval` returns `(loss, discrete-state fingerprint)`; a coordinate is
/// skipped when the two perturbed fingerprints differ.  Returns `Err`
/// naming every out-of-tolerance block.
pub fn grad_check(
    cfg: &GradCheckCfg,
    theta: &[f32],
    blocks: &[(String, usize)],
    analytic: &[f32],
    mut eval: impl FnMut(&[f32]) -> (f32, u64),
) -> Result<Vec<GradBlockReport>, String> {
    let total: usize = blocks.iter().map(|(_, len)| len).sum();
    assert_eq!(total, theta.len(), "blocks must partition theta");
    assert_eq!(analytic.len(), theta.len(), "analytic gradient length");
    let mut work = theta.to_vec();
    let mut reports = Vec::with_capacity(blocks.len());
    let mut failures = Vec::new();
    let mut offset = 0usize;
    for (name, len) in blocks {
        let stride = (len / cfg.max_per_block.max(1)).max(1);
        let mut report = GradBlockReport {
            name: name.clone(),
            checked: 0,
            skipped: 0,
            max_rel_err: 0.0,
            worst: None,
        };
        let mut block_fail: Option<String> = None;
        for j in (0..*len).step_by(stride) {
            let i = offset + j;
            let saved = work[i];
            work[i] = saved + cfg.eps;
            let (lp, fp_plus) = eval(&work);
            work[i] = saved - cfg.eps;
            let (lm, fp_minus) = eval(&work);
            work[i] = saved;
            if fp_plus != fp_minus {
                report.skipped += 1;
                continue;
            }
            let num = (lp - lm) / (2.0 * cfg.eps);
            let ana = analytic[i];
            let diff = (num - ana).abs();
            let rel = diff / num.abs().max(ana.abs()).max(1e-6);
            report.checked += 1;
            if rel > report.max_rel_err {
                report.max_rel_err = rel;
                report.worst = Some((i, ana, num));
            }
            let tol = cfg.abs_tol + cfg.rel_tol * num.abs().max(ana.abs());
            if diff > tol && block_fail.is_none() {
                block_fail = Some(format!(
                    "block {name:?} coord {i}: analytic {ana:.6} vs numeric {num:.6} \
                     (diff {diff:.2e} > tol {tol:.2e})"
                ));
            }
        }
        if let Some(msg) = block_fail {
            failures.push(msg);
        }
        reports.push(report);
        offset += len;
    }
    if failures.is_empty() {
        Ok(reports)
    } else {
        Err(failures.join("\n"))
    }
}

/// Per-block divergence between the SIMD and scalar backward passes
/// (see [`grad_check_modes`]).
#[derive(Clone, Debug)]
pub struct ModeDivergence {
    pub name: String,
    /// Largest `|g_simd - g_scalar|` over the block.
    pub max_abs: f32,
    /// Largest `|g_simd - g_scalar| / max(|g_simd|, |g_scalar|, 1e-6)`.
    pub max_rel: f32,
}

/// Run the central-difference check **twice** — once with the
/// `util::simd` lane kernels forced on and once forced to the scalar
/// reference (`CAST_NO_SIMD`'s code path) — and report the per-block
/// maximum divergence between the two analytic backward passes.
///
/// `analytic` recomputes the gradient under the currently-forced mode;
/// `eval` is the loss for the numeric check (also re-run per mode, so
/// each pass is self-consistent).  The forced override is cleared on
/// every exit path — including panics inside the closures — via a drop
/// guard, so the dispatch mode re-resolves from the environment
/// afterwards.  The caller asserts on the returned divergences (the
/// reassociation contract: ≤ ~1e-5 relative at layer shapes).
///
/// NOTE: this flips the process-global SIMD mode — callers serialize
/// against any concurrent test that asserts bit-exact determinism
/// (see `util::simd` module docs).
pub fn grad_check_modes(
    cfg: &GradCheckCfg,
    theta: &[f32],
    blocks: &[(String, usize)],
    mut analytic: impl FnMut() -> Vec<f32>,
    mut eval: impl FnMut(&[f32]) -> (f32, u64),
) -> Vec<ModeDivergence> {
    /// Clears the forced SIMD mode even when a closure panics.
    struct ModeRestore;
    impl Drop for ModeRestore {
        fn drop(&mut self) {
            crate::util::simd::set_forced(None);
        }
    }
    let _restore = ModeRestore;
    let mut per_mode: Vec<Vec<f32>> = Vec::with_capacity(2);
    for lanes in [true, false] {
        crate::util::simd::set_forced(Some(lanes));
        let ana = analytic();
        if let Err(msg) = grad_check(cfg, theta, blocks, &ana, &mut eval) {
            panic!(
                "gradient check failed with SIMD {}:\n{msg}",
                if lanes { "lanes" } else { "scalar reference" }
            );
        }
        per_mode.push(ana);
    }
    let (g_simd, g_scalar) = (&per_mode[0], &per_mode[1]);
    assert_eq!(g_simd.len(), g_scalar.len(), "mode gradients must align");
    let mut out = Vec::with_capacity(blocks.len());
    let mut off = 0usize;
    for (name, len) in blocks {
        let mut max_abs = 0.0f32;
        let mut max_rel = 0.0f32;
        for i in off..off + len {
            let (a, b) = (g_simd[i], g_scalar[i]);
            let diff = (a - b).abs();
            max_abs = max_abs.max(diff);
            max_rel = max_rel.max(diff / a.abs().max(b.abs()).max(1e-6));
        }
        out.push(ModeDivergence { name: name.clone(), max_abs, max_rel });
        off += len;
    }
    out
}

/// [`grad_check`] that panics with the full report on failure — the
/// assertion form the grad tests use.
pub fn assert_grads_close(
    cfg: &GradCheckCfg,
    theta: &[f32],
    blocks: &[(String, usize)],
    analytic: &[f32],
    eval: impl FnMut(&[f32]) -> (f32, u64),
) -> Vec<GradBlockReport> {
    match grad_check(cfg, theta, blocks, analytic, eval) {
        Ok(reports) => reports,
        Err(msg) => panic!("gradient check failed:\n{msg}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut n = 0;
        check(
            "u64 plus zero",
            Config { cases: 10, ..Default::default() },
            |r| r.next_u64(),
            |x| {
                n += 1;
                if x + 0 == *x { Ok(()) } else { Err("math broke".into()) }
            },
        );
        assert_eq!(n, 10);
    }

    #[test]
    #[should_panic(expected = "always fails")]
    fn failing_property_panics_with_seed() {
        check(
            "always fails",
            Config::default(),
            |r| r.below(10),
            |_| Err("always fails".into()),
        );
    }

    #[test]
    #[should_panic(expected = "shrunk input: 0")]
    fn shrinker_reaches_minimum() {
        check_shrink(
            "all inputs fail, shrink to 0",
            Config { cases: 1, ..Default::default() },
            |r| r.range(50, 100),
            |&x| if x > 0 { vec![x / 2, x - 1] } else { vec![] },
            |_| Err("fails everywhere".into()),
        );
    }

    #[test]
    fn grad_check_accepts_exact_quadratic_gradient() {
        // L = sum(a_i * x_i^2): dL/dx_i = 2 a_i x_i, exactly representable
        let a = [0.5f32, -1.0, 2.0, 0.25, 1.5];
        let theta = [0.3f32, -0.7, 0.9, 1.1, -0.2];
        let analytic: Vec<f32> =
            theta.iter().zip(&a).map(|(&x, &c)| 2.0 * c * x).collect();
        let blocks = vec![("w".to_string(), 3), ("b".to_string(), 2)];
        let reports = assert_grads_close(
            &GradCheckCfg::default(),
            &theta,
            &blocks,
            &analytic,
            |t| (t.iter().zip(&a).map(|(&x, &c)| c * x * x).sum(), 0),
        );
        assert_eq!(reports.len(), 2);
        assert_eq!(reports[0].checked, 3);
        assert_eq!(reports[1].checked, 2);
        assert!(reports.iter().all(|r| r.skipped == 0));
    }

    #[test]
    fn grad_check_rejects_wrong_gradient_and_names_block() {
        let theta = [0.5f32, 0.5];
        let analytic = [1.0f32, 99.0]; // second entry is wrong
        let blocks = vec![("ok".to_string(), 1), ("bad".to_string(), 1)];
        let err = grad_check(
            &GradCheckCfg::default(),
            &theta,
            &blocks,
            &analytic,
            |t| (t.iter().sum(), 0),
        )
        .unwrap_err();
        assert!(err.contains("bad"), "failure must name the block: {err}");
        assert!(!err.contains("\"ok\""), "passing block must not be reported: {err}");
    }

    #[test]
    fn grad_check_skips_fingerprint_flips() {
        // loss jumps discontinuously when x crosses 0 — the fingerprint
        // marks the branch, so the coordinate is skipped, not failed
        let theta = [1e-4f32];
        let blocks = vec![("x".to_string(), 1)];
        let reports = assert_grads_close(
            &GradCheckCfg { eps: 1e-2, ..Default::default() },
            &theta,
            &blocks,
            &[0.0],
            |t| {
                let branch = if t[0] >= 0.0 { 1u64 } else { 0 };
                (if t[0] >= 0.0 { 5.0 } else { -3.0 }, branch)
            },
        );
        assert_eq!(reports[0].skipped, 1);
        assert_eq!(reports[0].checked, 0);
    }
}
