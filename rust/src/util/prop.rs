//! Tiny property-testing substrate (proptest is unavailable offline).
//!
//! `check(name, cases, gen, prop)` runs `prop` on `cases` random inputs
//! drawn by `gen`; on failure it performs a bounded greedy shrink using the
//! caller-provided `shrink` candidates (if any) and panics with the seed so
//! the case is reproducible: rerun with `PROP_SEED=<seed>`.

use super::rng::Rng;

pub struct Config {
    pub cases: usize,
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        let seed = std::env::var("PROP_SEED")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(0xCA57_0001);
        Config { cases: 64, seed }
    }
}

/// Run a property over random inputs.  `gen` draws a case from the RNG;
/// `prop` returns Err(description) on violation.
pub fn check<T: std::fmt::Debug>(
    name: &str,
    cfg: Config,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    for case in 0..cfg.cases {
        let mut rng = Rng::new(cfg.seed).split(case as u64);
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property {name:?} failed on case {case} (PROP_SEED={}):\n  {msg}\n  input: {input:?}",
                cfg.seed,
            );
        }
    }
}

/// Like `check` but with a caller-provided shrinker: on failure, repeatedly
/// tries `shrink(input)` candidates that still fail, reporting the smallest.
pub fn check_shrink<T: std::fmt::Debug + Clone>(
    name: &str,
    cfg: Config,
    mut gen: impl FnMut(&mut Rng) -> T,
    shrink: impl Fn(&T) -> Vec<T>,
    prop: impl Fn(&T) -> Result<(), String>,
) {
    for case in 0..cfg.cases {
        let mut rng = Rng::new(cfg.seed).split(case as u64);
        let input = gen(&mut rng);
        if let Err(first_msg) = prop(&input) {
            let mut best = input.clone();
            let mut msg = first_msg;
            // bounded greedy descent
            'outer: for _ in 0..200 {
                for cand in shrink(&best) {
                    if let Err(m) = prop(&cand) {
                        best = cand;
                        msg = m;
                        continue 'outer;
                    }
                }
                break;
            }
            panic!(
                "property {name:?} failed on case {case} (PROP_SEED={}):\n  {msg}\n  shrunk input: {best:?}",
                cfg.seed,
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut n = 0;
        check(
            "u64 plus zero",
            Config { cases: 10, ..Default::default() },
            |r| r.next_u64(),
            |x| {
                n += 1;
                if x + 0 == *x { Ok(()) } else { Err("math broke".into()) }
            },
        );
        assert_eq!(n, 10);
    }

    #[test]
    #[should_panic(expected = "always fails")]
    fn failing_property_panics_with_seed() {
        check(
            "always fails",
            Config::default(),
            |r| r.below(10),
            |_| Err("always fails".into()),
        );
    }

    #[test]
    #[should_panic(expected = "shrunk input: 0")]
    fn shrinker_reaches_minimum() {
        check_shrink(
            "all inputs fail, shrink to 0",
            Config { cases: 1, ..Default::default() },
            |r| r.range(50, 100),
            |&x| if x > 0 { vec![x / 2, x - 1] } else { vec![] },
            |_| Err("fails everywhere".into()),
        );
    }
}
