//! Image (sequential pixel classification): synthetic stand-in for LRA's
//! sCIFAR task.
//!
//! 32x32 8-bit grayscale renders of ten procedurally drawn classes
//! (disk, box, cross, h-stripes, v-stripes, checker, diagonal, ring,
//! gradient blob, two-disk scene), with randomized position, size,
//! intensity, background level, and additive noise.  The image is
//! raster-scanned into a 1024-token sequence of pixel intensities —
//! exactly the LRA pipeline, probing 2-D structure recovery from a 1-D
//! serialization.

use crate::util::rng::Rng;

use super::{Example, TaskGen};

pub const SIDE: usize = 32;

#[derive(Default)]
pub struct ImageClassify;

pub struct Canvas {
    pub side: usize,
    pub px: Vec<f32>,
}

impl Canvas {
    pub fn new(side: usize, bg: f32) -> Canvas {
        Canvas { side, px: vec![bg; side * side] }
    }

    pub fn set(&mut self, x: i32, y: i32, v: f32) {
        if x >= 0 && y >= 0 && (x as usize) < self.side && (y as usize) < self.side {
            self.px[y as usize * self.side + x as usize] = v;
        }
    }

    pub fn to_tokens(&self, rng: &mut Rng, noise: f32) -> Vec<i32> {
        self.px
            .iter()
            .map(|&v| {
                let n = (rng.gaussian() as f32) * noise;
                ((v + n).clamp(0.0, 1.0) * 255.0) as i32
            })
            .collect()
    }
}

fn draw_disk(c: &mut Canvas, cx: f32, cy: f32, r: f32, v: f32) {
    for y in 0..c.side as i32 {
        for x in 0..c.side as i32 {
            let d2 = (x as f32 - cx).powi(2) + (y as f32 - cy).powi(2);
            if d2 <= r * r {
                c.set(x, y, v);
            }
        }
    }
}

fn draw_ring(c: &mut Canvas, cx: f32, cy: f32, r: f32, w: f32, v: f32) {
    for y in 0..c.side as i32 {
        for x in 0..c.side as i32 {
            let d = ((x as f32 - cx).powi(2) + (y as f32 - cy).powi(2)).sqrt();
            if (d - r).abs() <= w {
                c.set(x, y, v);
            }
        }
    }
}

fn draw_box(c: &mut Canvas, x0: i32, y0: i32, w: i32, h: i32, v: f32) {
    for y in y0..y0 + h {
        for x in x0..x0 + w {
            c.set(x, y, v);
        }
    }
}

impl ImageClassify {
    pub fn render(&self, rng: &mut Rng, class: usize) -> Canvas {
        let side = SIDE;
        let bg = 0.1 + 0.2 * rng.f32();
        let fg = 0.7 + 0.3 * rng.f32();
        let mut c = Canvas::new(side, bg);
        let s = side as f32;
        let cx = s * (0.3 + 0.4 * rng.f32());
        let cy = s * (0.3 + 0.4 * rng.f32());
        let r = s * (0.12 + 0.12 * rng.f32());
        match class {
            0 => draw_disk(&mut c, cx, cy, r, fg),
            1 => {
                let w = (r * 2.0) as i32;
                draw_box(&mut c, cx as i32 - w / 2, cy as i32 - w / 2, w, w, fg);
            }
            2 => {
                // cross
                let w = (r * 2.2) as i32;
                let t = (r * 0.5).max(1.5) as i32;
                draw_box(&mut c, cx as i32 - w / 2, cy as i32 - t / 2, w, t.max(1), fg);
                draw_box(&mut c, cx as i32 - t / 2, cy as i32 - w / 2, t.max(1), w, fg);
            }
            3 => {
                // horizontal stripes
                let period = rng.range(3, 6);
                for y in 0..side {
                    if (y / period) % 2 == 0 {
                        for x in 0..side {
                            c.set(x as i32, y as i32, fg);
                        }
                    }
                }
            }
            4 => {
                // vertical stripes
                let period = rng.range(3, 6);
                for x in 0..side {
                    if (x / period) % 2 == 0 {
                        for y in 0..side {
                            c.set(x as i32, y as i32, fg);
                        }
                    }
                }
            }
            5 => {
                // checkerboard
                let period = rng.range(3, 6);
                for y in 0..side {
                    for x in 0..side {
                        if ((x / period) + (y / period)) % 2 == 0 {
                            c.set(x as i32, y as i32, fg);
                        }
                    }
                }
            }
            6 => {
                // thick diagonal line
                let t = rng.range(2, 4) as f32;
                let up = rng.bool(0.5);
                for y in 0..side as i32 {
                    for x in 0..side as i32 {
                        let d = if up { (x - y).abs() } else { (x + y - side as i32 + 1).abs() };
                        if (d as f32) <= t {
                            c.set(x, y, fg);
                        }
                    }
                }
            }
            7 => draw_ring(&mut c, cx, cy, r * 1.4, (r * 0.35).max(1.0), fg),
            8 => {
                // radial gradient blob
                for y in 0..side as i32 {
                    for x in 0..side as i32 {
                        let d = ((x as f32 - cx).powi(2) + (y as f32 - cy).powi(2)).sqrt();
                        let v = (fg - bg) * (1.0 - (d / (2.2 * r)).min(1.0)) + bg;
                        c.set(x, y, v);
                    }
                }
            }
            9 => {
                // two-disk scene
                draw_disk(&mut c, cx * 0.6, cy * 0.6, r * 0.8, fg);
                draw_disk(&mut c, s - cx * 0.5, s - cy * 0.5, r * 0.8, fg * 0.9);
            }
            _ => unreachable!(),
        }
        c
    }
}

impl TaskGen for ImageClassify {
    fn name(&self) -> &'static str {
        "image"
    }

    fn vocab(&self) -> usize {
        256
    }

    fn n_classes(&self) -> usize {
        10
    }

    fn example(&self, rng: &mut Rng, seq_len: usize) -> Example {
        assert_eq!(seq_len, SIDE * SIDE, "image task requires seq_len = {}", SIDE * SIDE);
        let class = rng.below(10);
        let canvas = self.render(rng, class);
        let tokens = canvas.to_tokens(rng, 0.03);
        Example { tokens, tokens2: None, label: class as i32 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn tokens_are_byte_range() {
        let gen = ImageClassify;
        let ex = gen.example(&mut Rng::new(1), 1024);
        assert!(ex.tokens.iter().all(|&t| (0..256).contains(&t)));
    }

    #[test]
    fn prop_classes_visually_distinct_from_background() {
        let gen = ImageClassify;
        prop::check(
            "foreground pixels exist",
            prop::Config { cases: 50, ..Default::default() },
            |rng| gen.example(rng, 1024),
            |ex| {
                // histogram spread: a degenerate render would be constant
                let min = ex.tokens.iter().min().unwrap();
                let max = ex.tokens.iter().max().unwrap();
                if max - min > 60 {
                    Ok(())
                } else {
                    Err(format!("image nearly constant (range {})", max - min))
                }
            },
        );
    }

    #[test]
    fn stripes_have_expected_autocorrelation() {
        // class 3 = horizontal stripes: rows constant, columns alternate
        let gen = ImageClassify;
        let mut rng = Rng::new(42);
        let c = gen.render(&mut rng, 3);
        let row0: Vec<f32> = c.px[0..SIDE].to_vec();
        let spread = row0.iter().cloned().fold(f32::NEG_INFINITY, f32::max)
            - row0.iter().cloned().fold(f32::INFINITY, f32::min);
        assert!(spread < 1e-6, "row of h-stripes should be constant");
    }

    #[test]
    #[should_panic(expected = "seq_len")]
    fn wrong_seq_len_panics() {
        ImageClassify.example(&mut Rng::new(1), 999);
    }
}
