//! Text (byte-level sentiment): synthetic stand-in for LRA's IMDb task.
//!
//! Documents are composed from sentence templates over positive / negative
//! / neutral lexicons, with negators ("not", "never") flipping the polarity
//! of the following sentiment word and distractor clauses adding noise.
//! The label is the sign of the net (negation-adjusted) polarity, and
//! generation enforces a margin so labels are unambiguous — the skill
//! probed is the same as IMDb-bytes: accumulate weak sentiment evidence
//! spread across thousands of characters.
//!
//! Tokens are raw bytes (vocab 256), padded with 0, as in LRA.

use crate::util::rng::Rng;

use super::{fit, Example, TaskGen};

pub const POSITIVE: &[&str] = &[
    "wonderful", "brilliant", "delightful", "superb", "excellent", "charming", "moving",
    "masterful", "gorgeous", "fresh", "gripping", "hilarious", "stunning", "perfect",
    "heartfelt", "captivating",
];

pub const NEGATIVE: &[&str] = &[
    "dreadful", "boring", "clumsy", "awful", "terrible", "bland", "tedious", "shallow",
    "forgettable", "stale", "painful", "lifeless", "messy", "hollow", "annoying", "dull",
];

pub const NEUTRAL: &[&str] = &[
    "movie", "film", "plot", "scene", "actor", "camera", "script", "score", "director",
    "pacing", "dialogue", "editing", "sequel", "character", "ending", "premise", "studio",
    "screen", "runtime", "cast",
];

pub const NEGATORS: &[&str] = &["not", "never", "hardly"];

const TEMPLATES: &[&str] = &[
    "the {n} was {s}.",
    "i found the {n} {s} and the {n} {s}.",
    "critics called it {s}, a {s} piece of {n}.",
    "its {n} felt {s} throughout.",
    "what a {s} {n} with a {s} {n}.",
    "the {n}, though, was {neg} {s}.",
    "overall the {n} seemed {neg} {s} to me.",
];

const FILLER: &[&str] = &[
    "meanwhile the {n} drifts along with the {n}.",
    "there is a {n} about a {n} and its {n}.",
    "the {n} shares screen time with another {n}.",
    "somewhere in act two a {n} appears.",
];

#[derive(Default)]
pub struct TextSentiment;

impl TextSentiment {
    /// Generate one document and its net polarity score.
    fn compose(&self, rng: &mut Rng, approx_chars: usize) -> (String, i32) {
        let mut out = String::with_capacity(approx_chars + 64);
        let mut score = 0i32;
        // choose a target label and bias word draws toward it; the *label*
        // is still computed from the realized text so it is always correct.
        let want_positive = rng.bool(0.5);
        while out.len() < approx_chars {
            let use_filler = rng.bool(0.35);
            let template = if use_filler { *rng.choice(FILLER) } else { *rng.choice(TEMPLATES) };
            let mut sentence = String::new();
            let mut i = 0;
            let bytes = template.as_bytes();
            let mut pending_negation = false;
            while i < bytes.len() {
                if bytes[i] == b'{' {
                    let end = template[i..].find('}').unwrap() + i;
                    match &template[i + 1..end] {
                        "n" => sentence.push_str(*rng.choice(NEUTRAL)),
                        "neg" => {
                            if rng.bool(0.5) {
                                sentence.push_str(*rng.choice(NEGATORS));
                                pending_negation = true;
                            } else {
                                sentence.push_str("quite");
                            }
                        }
                        "s" => {
                            let draw_positive = if rng.bool(0.72) {
                                want_positive
                            } else {
                                !want_positive
                            };
                            let w = if draw_positive {
                                rng.choice(POSITIVE)
                            } else {
                                rng.choice(NEGATIVE)
                            };
                            sentence.push_str(w);
                            let mut delta = if draw_positive { 1 } else { -1 };
                            if pending_negation {
                                delta = -delta;
                                pending_negation = false;
                            }
                            score += delta;
                        }
                        other => panic!("bad template slot {other:?}"),
                    }
                    i = end + 1;
                } else {
                    sentence.push(bytes[i] as char);
                    i += 1;
                }
            }
            out.push_str(&sentence);
            out.push(' ');
        }
        (out, score)
    }
}

impl TaskGen for TextSentiment {
    fn name(&self) -> &'static str {
        "text"
    }

    fn vocab(&self) -> usize {
        256
    }

    fn n_classes(&self) -> usize {
        2
    }

    fn example(&self, rng: &mut Rng, seq_len: usize) -> Example {
        // resample until the margin is decisive (score 0 would be ambiguous)
        loop {
            let (doc, score) = self.compose(rng, seq_len.saturating_sub(2).max(16));
            if score.abs() < 2 {
                continue;
            }
            let tokens: Vec<i32> = doc.bytes().map(|b| b as i32).collect();
            let label = if score > 0 { 1 } else { 0 };
            return Example { tokens: fit(tokens, seq_len), tokens2: None, label };
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    /// Recompute polarity from raw text: the label must be recoverable by
    /// an independent scorer (same negation rule).
    pub fn score_text(text: &str) -> i32 {
        let mut score = 0;
        let mut negate = false;
        for word in text.split(|c: char| !c.is_ascii_alphabetic()) {
            if word.is_empty() {
                continue;
            }
            if NEGATORS.contains(&word) {
                negate = true;
            } else if POSITIVE.contains(&word) {
                score += if negate { -1 } else { 1 };
                negate = false;
            } else if NEGATIVE.contains(&word) {
                score += if negate { 1 } else { -1 };
                negate = false;
            }
            // negation only applies to the immediately-following sentiment
            // word within the template, which never has an intervening
            // sentiment word — neutral words keep the flag.
        }
        score
    }

    #[test]
    fn prop_label_matches_independent_scorer() {
        let gen = TextSentiment;
        prop::check(
            "text label == sign of recomputed polarity",
            prop::Config { cases: 100, ..Default::default() },
            |rng| gen.example(rng, 512),
            |ex| {
                let text: String =
                    ex.tokens.iter().take_while(|&&t| t != 0).map(|&t| t as u8 as char).collect();
                let s = score_text(&text);
                // truncation can clip the last sentence; tolerate the
                // boundary word by requiring the sign to match when the
                // recomputed score is decisive.
                if s == 0 {
                    return Ok(());
                }
                let label = if s > 0 { 1 } else { 0 };
                if label == ex.label {
                    Ok(())
                } else {
                    Err(format!("recovered score {s} vs label {}", ex.label))
                }
            },
        );
    }

    #[test]
    fn labels_are_balanced() {
        let gen = TextSentiment;
        let mut rng = Rng::new(5);
        let mut pos = 0;
        for _ in 0..200 {
            pos += gen.example(&mut rng, 256).label;
        }
        assert!((40..160).contains(&pos), "imbalanced: {pos}/200 positive");
    }

    #[test]
    fn all_ascii_tokens() {
        let gen = TextSentiment;
        let ex = gen.example(&mut Rng::new(3), 300);
        assert!(ex.tokens.iter().all(|&t| t < 128));
    }
}
