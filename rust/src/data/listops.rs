//! ListOps (Nangia & Bowman, 2018; LRA variant): nested list operations.
//!
//! This task is synthetic *by construction*, so unlike the other LRA tasks
//! we reproduce it exactly: expressions over MAX / MIN / MED / SUM_MOD with
//! operands 0..9 and nesting, serialized to tokens, 10-way classification
//! of the expression's value.
//!
//! Example (flattened):  [MAX 4 [MIN 2 8 ] 7 ]  ->  7
//!
//! The module also ships an independent parser/evaluator (`eval_tokens`)
//! used by the property tests: generator output re-parsed and re-evaluated
//! must reproduce the label.

use crate::util::rng::Rng;

use super::{fit, Example, TaskGen};

// token ids (vocab = 24, a few reserved)
pub const PAD: i32 = 0;
pub const DIGIT0: i32 = 1; // digits d -> 1 + d
pub const OP_MAX: i32 = 11;
pub const OP_MIN: i32 = 12;
pub const OP_MED: i32 = 13;
pub const OP_SM: i32 = 14; // SUM_MOD
pub const CLOSE: i32 = 15;
pub const VOCAB: usize = 24;

#[derive(Debug, Clone)]
enum Node {
    Leaf(i32),
    Op(i32, Vec<Node>),
}

pub struct ListOps {
    pub max_args: usize,
    pub max_depth: usize,
}

impl Default for ListOps {
    fn default() -> Self {
        ListOps { max_args: 5, max_depth: 6 }
    }
}

impl ListOps {
    fn gen_node(&self, rng: &mut Rng, depth: usize, budget: &mut isize) -> Node {
        // each op costs 2 tokens (open+close), each leaf 1
        *budget -= 1;
        let can_nest = depth < self.max_depth && *budget > 6;
        if !can_nest || rng.bool(0.55) {
            return Node::Leaf(rng.below(10) as i32);
        }
        let op = *rng.choice(&[OP_MAX, OP_MIN, OP_MED, OP_SM]);
        *budget -= 1; // close token
        let n_args = rng.range(2, self.max_args);
        let args = (0..n_args).map(|_| self.gen_node(rng, depth + 1, budget)).collect();
        Node::Op(op, args)
    }
}

fn eval_node(n: &Node) -> i32 {
    match n {
        Node::Leaf(d) => *d,
        Node::Op(op, args) => {
            let mut vals: Vec<i32> = args.iter().map(eval_node).collect();
            match *op {
                OP_MAX => *vals.iter().max().unwrap(),
                OP_MIN => *vals.iter().min().unwrap(),
                OP_MED => {
                    vals.sort();
                    vals[vals.len() / 2]
                }
                OP_SM => vals.iter().sum::<i32>() % 10,
                _ => unreachable!(),
            }
        }
    }
}

fn serialize(n: &Node, out: &mut Vec<i32>) {
    match n {
        Node::Leaf(d) => out.push(DIGIT0 + d),
        Node::Op(op, args) => {
            out.push(*op);
            for a in args {
                serialize(a, out);
            }
            out.push(CLOSE);
        }
    }
}

impl TaskGen for ListOps {
    fn name(&self) -> &'static str {
        "listops"
    }

    fn vocab(&self) -> usize {
        VOCAB
    }

    fn n_classes(&self) -> usize {
        10
    }

    fn example(&self, rng: &mut Rng, seq_len: usize) -> Example {
        // fill roughly 60-95% of the sequence with real expression tokens
        let target = rng.range((seq_len * 6) / 10, (seq_len * 19) / 20);
        let mut budget = target as isize;
        // root is always an operation (as in the original dataset)
        let op = *rng.choice(&[OP_MAX, OP_MIN, OP_MED, OP_SM]);
        let n_args = rng.range(2, self.max_args);
        budget -= 2;
        let args: Vec<Node> =
            (0..n_args).map(|_| self.gen_node(rng, 1, &mut budget)).collect();
        let root = Node::Op(op, args);
        let label = eval_node(&root);
        let mut tokens = Vec::with_capacity(seq_len);
        serialize(&root, &mut tokens);
        Example { tokens: fit(tokens, seq_len), tokens2: None, label }
    }
}

/// Independent recursive-descent evaluator over serialized tokens.
/// Returns None on malformed input (used by property tests and as the
/// trainer's label-sanity check).
pub fn eval_tokens(tokens: &[i32]) -> Option<i32> {
    let mut pos = 0usize;
    let v = parse(tokens, &mut pos)?;
    // ignore trailing padding
    if tokens[pos..].iter().any(|&t| t != PAD) {
        return None;
    }
    Some(v)
}

fn parse(tokens: &[i32], pos: &mut usize) -> Option<i32> {
    let t = *tokens.get(*pos)?;
    *pos += 1;
    match t {
        d if (DIGIT0..DIGIT0 + 10).contains(&d) => Some(d - DIGIT0),
        op @ (OP_MAX | OP_MIN | OP_MED | OP_SM) => {
            let mut vals = Vec::new();
            loop {
                match tokens.get(*pos)? {
                    &CLOSE => {
                        *pos += 1;
                        break;
                    }
                    _ => vals.push(parse(tokens, pos)?),
                }
            }
            if vals.is_empty() {
                return None;
            }
            Some(match op {
                OP_MAX => *vals.iter().max().unwrap(),
                OP_MIN => *vals.iter().min().unwrap(),
                OP_MED => {
                    vals.sort();
                    vals[vals.len() / 2]
                }
                _ => vals.iter().sum::<i32>() % 10,
            })
        }
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn hand_built_expression() {
        // [MAX 4 [MIN 2 8] 7] = 7
        let toks = vec![
            OP_MAX,
            DIGIT0 + 4,
            OP_MIN,
            DIGIT0 + 2,
            DIGIT0 + 8,
            CLOSE,
            DIGIT0 + 7,
            CLOSE,
        ];
        assert_eq!(eval_tokens(&toks), Some(7));
    }

    #[test]
    fn med_and_summod() {
        // [MED 1 9 5] = 5 ; [SM 7 8] = 5
        assert_eq!(
            eval_tokens(&[OP_MED, DIGIT0 + 1, DIGIT0 + 9, DIGIT0 + 5, CLOSE]),
            Some(5)
        );
        assert_eq!(eval_tokens(&[OP_SM, DIGIT0 + 7, DIGIT0 + 8, CLOSE]), Some(5));
    }

    #[test]
    fn malformed_inputs_rejected() {
        assert_eq!(eval_tokens(&[OP_MAX, DIGIT0]), None); // unterminated
        assert_eq!(eval_tokens(&[CLOSE]), None);
        assert_eq!(eval_tokens(&[OP_MAX, CLOSE]), None); // empty args
        assert_eq!(eval_tokens(&[DIGIT0, DIGIT0]), None); // trailing token
    }

    /// Property: generator label == independent evaluator on the tokens.
    #[test]
    fn prop_generator_evaluator_agree() {
        let gen = ListOps::default();
        prop::check(
            "listops label matches independent evaluator",
            prop::Config { cases: 200, ..Default::default() },
            |rng| {
                let seq = 64 + rng.below(512);
                let ex = gen.example(rng, seq);
                (ex.tokens, ex.label)
            },
            |(tokens, label)| {
                let stripped: Vec<i32> =
                    tokens.iter().copied().take_while(|&t| t != PAD).collect();
                match eval_tokens(&stripped) {
                    Some(v) if v == *label => Ok(()),
                    Some(v) => Err(format!("evaluator got {v}, generator said {label}")),
                    None => Err("generator emitted unparseable tokens".into()),
                }
            },
        );
    }

    /// Property: label distribution is not degenerate.
    #[test]
    fn label_distribution_covers_classes() {
        let gen = ListOps::default();
        let mut rng = Rng::new(99);
        let mut counts = [0usize; 10];
        for _ in 0..400 {
            counts[gen.example(&mut rng, 256).label as usize] += 1;
        }
        let nonzero = counts.iter().filter(|&&c| c > 0).count();
        assert!(nonzero >= 8, "label histogram too concentrated: {counts:?}");
    }
}
