//! Background batch pipeline with backpressure.
//!
//! Worker threads synthesize batches ahead of the training loop and push
//! them into a bounded channel; when the trainer falls behind, the bound
//! provides backpressure and workers block instead of ballooning memory
//! (tokio is unavailable offline — std threads + `sync_channel` give the
//! same semantics for this CPU-bound pipeline; DESIGN.md §Substitutions).
//!
//! Streams are deterministic: worker w produces the batches with
//! `index % workers == w`, each derived from `seed.split(index)`, so the
//! consumed batch sequence is identical regardless of worker count or
//! scheduling — a property the tests pin down.

use std::sync::mpsc::{sync_channel, Receiver};
use std::sync::Arc;
use std::thread::JoinHandle;

use crate::runtime::HostTensor;
use crate::util::rng::Rng;

use super::{make_batch, Batch, TaskGen};

/// Right-pad token rows with `pad` to a fixed `seq_len` and pack them
/// into one `(B, seq_len)` s32 tensor — the batch-assembly step shared
/// by the serve micro-batcher (CAST's per-cluster geometry requires
/// every row of a batch to share one sequence length, so ragged client
/// requests are padded up to the model's length).  Rows longer than
/// `seq_len`, and empty row sets, are errors.
pub fn pad_rows(rows: &[Vec<i32>], seq_len: usize, pad: i32) -> anyhow::Result<HostTensor> {
    anyhow::ensure!(!rows.is_empty(), "no token rows to batch");
    let mut data = vec![pad; rows.len() * seq_len];
    for (i, row) in rows.iter().enumerate() {
        anyhow::ensure!(
            row.len() <= seq_len,
            "token row {i} has {} tokens but the model sequence length is {seq_len}",
            row.len()
        );
        data[i * seq_len..i * seq_len + row.len()].copy_from_slice(row);
    }
    Ok(HostTensor::s32(vec![rows.len(), seq_len], data))
}

pub struct Batcher {
    rx: Receiver<(u64, Batch)>,
    pending: std::collections::BTreeMap<u64, Batch>,
    next_index: u64,
    workers: Vec<JoinHandle<()>>,
}

impl Batcher {
    /// Spawn `workers` producer threads generating `(b, seq_len)` batches
    /// of `task`, holding at most `depth` finished batches in flight.
    pub fn spawn(
        gen: Arc<dyn TaskGen>,
        seed: u64,
        b: usize,
        seq_len: usize,
        workers: usize,
        depth: usize,
    ) -> Batcher {
        let workers = workers.max(1);
        let (tx, rx) = sync_channel(depth.max(1));
        let mut handles = Vec::new();
        for w in 0..workers {
            let tx = tx.clone();
            let gen = gen.clone();
            let base = Rng::new(seed);
            handles.push(std::thread::spawn(move || {
                let mut index = w as u64;
                loop {
                    let mut rng = base.split(index);
                    let batch = make_batch(gen.as_ref(), &mut rng, b, seq_len);
                    if tx.send((index, batch)).is_err() {
                        return; // consumer dropped
                    }
                    index += workers as u64;
                }
            }));
        }
        Batcher { rx, pending: Default::default(), next_index: 0, workers: handles }
    }

    /// Next batch in deterministic stream order (blocks on producers).
    pub fn next(&mut self) -> Batch {
        loop {
            if let Some(b) = self.pending.remove(&self.next_index) {
                self.next_index += 1;
                return b;
            }
            let (idx, batch) = self.rx.recv().expect("all batch workers died");
            self.pending.insert(idx, batch);
        }
    }
}

impl Drop for Batcher {
    fn drop(&mut self) {
        // closing rx unblocks senders; workers then exit
        // drain a few pending sends so blocked workers see the hangup fast
        while self.rx.try_recv().is_ok() {}
        let handles = std::mem::take(&mut self.workers);
        drop(std::mem::replace(&mut self.rx, {
            let (_tx, rx) = sync_channel(1);
            rx
        }));
        for h in handles {
            let _ = h.join();
        }
    }
}

/// Synchronous reference stream (what Batcher must be equivalent to).
pub struct SyncStream {
    gen: Arc<dyn TaskGen>,
    seed: u64,
    b: usize,
    seq_len: usize,
    index: u64,
}

impl SyncStream {
    pub fn new(gen: Arc<dyn TaskGen>, seed: u64, b: usize, seq_len: usize) -> SyncStream {
        SyncStream { gen, seed, b, seq_len, index: 0 }
    }

    pub fn next(&mut self) -> Batch {
        let mut rng = Rng::new(self.seed).split(self.index);
        self.index += 1;
        make_batch(self.gen.as_ref(), &mut rng, self.b, self.seq_len)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::task;

    #[test]
    fn batcher_matches_sync_stream_any_worker_count() {
        let gen: Arc<dyn TaskGen> = Arc::from(task("listops").unwrap());
        let mut reference = SyncStream::new(gen.clone(), 123, 2, 64);
        let expected: Vec<_> = (0..6).map(|_| reference.next()).collect();

        for workers in [1, 2, 4] {
            let mut batcher = Batcher::spawn(gen.clone(), 123, 2, 64, workers, 4);
            for want in &expected {
                let got = batcher.next();
                assert_eq!(
                    got.tokens.as_s32().unwrap(),
                    want.tokens.as_s32().unwrap(),
                    "workers={workers}"
                );
                assert_eq!(got.labels.as_s32().unwrap(), want.labels.as_s32().unwrap());
            }
        }
    }

    #[test]
    fn bounded_queue_applies_backpressure() {
        let gen: Arc<dyn TaskGen> = Arc::from(task("text").unwrap());
        let mut batcher = Batcher::spawn(gen, 1, 1, 64, 2, 2);
        // give workers time to fill the queue; the bound keeps them from
        // producing unboundedly (no assertion possible on internals —
        // simply consuming a long prefix exercises the path)
        std::thread::sleep(std::time::Duration::from_millis(50));
        for _ in 0..10 {
            let b = batcher.next();
            assert_eq!(b.tokens.shape, vec![1, 64]);
        }
    }

    #[test]
    fn pad_rows_pads_and_packs() {
        let t = pad_rows(&[vec![1, 2, 3], vec![4], vec![5, 6, 7, 8]], 4, 0).unwrap();
        assert_eq!(t.shape, vec![3, 4]);
        assert_eq!(t.as_s32().unwrap(), &[1, 2, 3, 0, 4, 0, 0, 0, 5, 6, 7, 8]);
        assert!(pad_rows(&[vec![1; 5]], 4, 0).is_err(), "overlong row must fail");
        assert!(pad_rows(&[], 4, 0).is_err(), "empty batch must fail");
    }

    #[test]
    fn drop_terminates_workers() {
        let gen: Arc<dyn TaskGen> = Arc::from(task("text").unwrap());
        let batcher = Batcher::spawn(gen, 1, 1, 64, 3, 2);
        drop(batcher); // must not hang
    }
}
