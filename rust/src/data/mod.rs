//! LRA data substrate: synthetic generators for all six benchmark tasks.
//!
//! The original LRA corpora (IMDb, AAN, CIFAR-10, Pathfinder) are not
//! available offline, so each task is regenerated procedurally with the
//! same token space, sequence length, class count, and — most importantly —
//! the same *skill being probed* (DESIGN.md §Substitutions).  ListOps is
//! synthetic by construction and is reproduced exactly per the original
//! grammar.
//!
//! Every generator is deterministic in (seed, example-index), so train /
//! validation / test splits are disjoint streams and experiments reproduce
//! bit-for-bit.

pub mod batcher;
pub mod image;
pub mod listops;
pub mod pathfinder;
pub mod retrieval;
pub mod text;

use anyhow::{bail, Result};

use crate::runtime::HostTensor;
use crate::util::rng::Rng;

/// One labelled example.  `tokens2` is set for dual-encoder tasks
/// (Retrieval), where the model consumes a (B, 2, N) batch.
#[derive(Clone, Debug)]
pub struct Example {
    pub tokens: Vec<i32>,
    pub tokens2: Option<Vec<i32>>,
    pub label: i32,
}

/// A task generator: stateless, seed-addressable example synthesis.
pub trait TaskGen: Send + Sync {
    fn name(&self) -> &'static str;
    fn vocab(&self) -> usize;
    fn n_classes(&self) -> usize;
    fn dual(&self) -> bool {
        false
    }
    /// Generate the `index`-th example of the stream owned by `rng`'s seed.
    fn example(&self, rng: &mut Rng, seq_len: usize) -> Example;
}

/// Instantiate a generator by LRA task name.
pub fn task(name: &str) -> Result<Box<dyn TaskGen>> {
    Ok(match name {
        "listops" => Box::new(listops::ListOps::default()),
        "text" => Box::new(text::TextSentiment::default()),
        "retrieval" => Box::new(retrieval::Retrieval::default()),
        "image" => Box::new(image::ImageClassify::default()),
        "pathfinder" => Box::new(pathfinder::Pathfinder::new(32)),
        "pathx" => Box::new(pathfinder::Pathfinder::new(128)),
        other => bail!("unknown task {other:?}"),
    })
}

/// A device-ready batch.
#[derive(Clone, Debug)]
pub struct Batch {
    pub tokens: HostTensor,
    pub labels: HostTensor,
}

/// Synthesize a batch of `b` examples at `seq_len` from stream `rng`.
pub fn make_batch(gen: &dyn TaskGen, rng: &mut Rng, b: usize, seq_len: usize) -> Batch {
    let mut tokens = Vec::with_capacity(b * seq_len * if gen.dual() { 2 } else { 1 });
    let mut labels = Vec::with_capacity(b);
    for _ in 0..b {
        let ex = gen.example(rng, seq_len);
        debug_assert_eq!(ex.tokens.len(), seq_len, "{} generator length", gen.name());
        tokens.extend_from_slice(&ex.tokens);
        if gen.dual() {
            let t2 = ex.tokens2.expect("dual task must set tokens2");
            debug_assert_eq!(t2.len(), seq_len);
            tokens.extend_from_slice(&t2);
        }
        labels.push(ex.label);
    }
    let shape = if gen.dual() { vec![b, 2, seq_len] } else { vec![b, seq_len] };
    Batch {
        tokens: HostTensor::s32(shape, tokens),
        labels: HostTensor::s32(vec![b], labels),
    }
}

/// Pad-or-truncate a token stream to exactly `seq_len` (PAD = 0).
pub fn fit(mut tokens: Vec<i32>, seq_len: usize) -> Vec<i32> {
    tokens.truncate(seq_len);
    tokens.resize(seq_len, 0);
    tokens
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_tasks_instantiate_and_generate() {
        for name in ["listops", "text", "retrieval", "image", "pathfinder", "pathx"] {
            let gen = task(name).unwrap();
            let mut rng = Rng::new(1);
            let seq = match name {
                "pathx" => 16384,
                "image" | "pathfinder" => 1024,
                _ => 256,
            };
            let ex = gen.example(&mut rng, seq);
            assert_eq!(ex.tokens.len(), seq, "{name}");
            assert!(ex.label >= 0 && (ex.label as usize) < gen.n_classes(), "{name}");
            assert!(
                ex.tokens.iter().all(|&t| t >= 0 && (t as usize) < gen.vocab()),
                "{name}: token out of vocab"
            );
            assert_eq!(gen.dual(), ex.tokens2.is_some(), "{name}");
        }
    }

    #[test]
    fn unknown_task_is_error() {
        assert!(task("no_such_task").is_err());
    }

    #[test]
    fn batch_shapes() {
        let gen = task("text").unwrap();
        let mut rng = Rng::new(2);
        let b = make_batch(gen.as_ref(), &mut rng, 3, 128);
        assert_eq!(b.tokens.shape, vec![3, 128]);
        assert_eq!(b.labels.shape, vec![3]);

        let gen = task("retrieval").unwrap();
        let b = make_batch(gen.as_ref(), &mut rng, 2, 128);
        assert_eq!(b.tokens.shape, vec![2, 2, 128]);
    }

    #[test]
    fn determinism_per_seed() {
        let gen = task("image").unwrap();
        let a = gen.example(&mut Rng::new(7), 1024);
        let b = gen.example(&mut Rng::new(7), 1024);
        assert_eq!(a.tokens, b.tokens);
        assert_eq!(a.label, b.label);
        let c = gen.example(&mut Rng::new(8), 1024);
        assert_ne!(a.tokens, c.tokens);
    }

    #[test]
    fn fit_pads_and_truncates() {
        assert_eq!(fit(vec![1, 2, 3], 5), vec![1, 2, 3, 0, 0]);
        assert_eq!(fit(vec![1, 2, 3], 2), vec![1, 2]);
    }
}
