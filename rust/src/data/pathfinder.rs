//! Pathfinder / Path-X: synthetic reimplementation of the Linsley et al.
//! (2018) connectivity task used by LRA.
//!
//! Each image contains several *dashed* curves; two endpoint dots mark
//! either the two ends of the SAME curve (positive) or ends of two
//! DIFFERENT curves (negative).  Distractor curves are always present.
//! Curves are smooth random walks (heading + bounded turn rate), rendered
//! with a dash duty cycle; dots are small filled disks.
//!
//! `Pathfinder::new(32)` is the LRA Pathfinder (1024 tokens);
//! `Pathfinder::new(128)` is Path-X (16384 tokens).

use crate::util::rng::Rng;

use super::{Example, TaskGen};

pub struct Pathfinder {
    pub side: usize,
}

#[derive(Clone, Debug)]
pub struct Curve {
    pub points: Vec<(f32, f32)>,
}

impl Pathfinder {
    pub fn new(side: usize) -> Pathfinder {
        Pathfinder { side }
    }

    /// A smooth random walk of ~len steps staying inside the canvas.
    pub fn curve(&self, rng: &mut Rng, len: usize) -> Curve {
        let s = self.side as f32;
        let margin = 2.0;
        let mut x = margin + (s - 2.0 * margin) * rng.f32();
        let mut y = margin + (s - 2.0 * margin) * rng.f32();
        let mut heading = rng.f32() * std::f32::consts::TAU;
        let step = 1.0;
        let mut pts = Vec::with_capacity(len);
        pts.push((x, y));
        for _ in 0..len {
            heading += (rng.f32() - 0.5) * 0.9; // bounded turn rate
            let nx = x + step * heading.cos();
            let ny = y + step * heading.sin();
            // reflect off walls
            if nx < margin || nx > s - margin {
                heading = std::f32::consts::PI - heading;
            }
            if ny < margin || ny > s - margin {
                heading = -heading;
            }
            x = (x + step * heading.cos()).clamp(margin, s - margin);
            y = (y + step * heading.sin()).clamp(margin, s - margin);
            pts.push((x, y));
        }
        Curve { points: pts }
    }

    fn render(&self, rng: &mut Rng, curves: &[Curve], dots: [(f32, f32); 2]) -> Vec<i32> {
        let side = self.side;
        let mut px = vec![0.06f32; side * side];
        let mut set = |px: &mut Vec<f32>, x: f32, y: f32, v: f32| {
            let (xi, yi) = (x.round() as i32, y.round() as i32);
            if xi >= 0 && yi >= 0 && (xi as usize) < side && (yi as usize) < side {
                px[yi as usize * side + xi as usize] = v;
            }
        };
        // dashed curves: duty cycle ~ 3 on / 2 off
        for curve in curves {
            let phase = rng.below(5);
            for (i, &(x, y)) in curve.points.iter().enumerate() {
                if (i + phase) % 5 < 3 {
                    set(&mut px, x, y, 0.75);
                }
            }
        }
        // endpoint dots: bright 2x2-ish disks
        for &(dx, dy) in &dots {
            for oy in -1..=1 {
                for ox in -1..=1 {
                    set(&mut px, dx + ox as f32, dy + oy as f32, 1.0);
                }
            }
        }
        px.iter()
            .map(|&v| {
                let n = (rng.gaussian() as f32) * 0.02;
                ((v + n).clamp(0.0, 1.0) * 255.0) as i32
            })
            .collect()
    }
}

impl TaskGen for Pathfinder {
    fn name(&self) -> &'static str {
        if self.side >= 128 {
            "pathx"
        } else {
            "pathfinder"
        }
    }

    fn vocab(&self) -> usize {
        256
    }

    fn n_classes(&self) -> usize {
        2
    }

    fn example(&self, rng: &mut Rng, seq_len: usize) -> Example {
        assert_eq!(
            seq_len,
            self.side * self.side,
            "pathfinder({}) requires seq_len {}",
            self.side,
            self.side * self.side
        );
        let curve_len = self.side * 3 / 2;
        let n_distractors = 2 + rng.below(3);
        let mut curves: Vec<Curve> =
            (0..n_distractors + 2).map(|_| self.curve(rng, curve_len)).collect();
        let connected = rng.bool(0.5);
        let dots = if connected {
            let c = &curves[0];
            [c.points[0], *c.points.last().unwrap()]
        } else {
            [curves[0].points[0], *curves[1].points.last().unwrap()]
        };
        // randomize curve draw order so the target curve isn't special
        let order = rng.below(curves.len());
        curves.swap(0, order);
        let tokens = self.render(rng, &curves, dots);
        Example { tokens, tokens2: None, label: connected as i32 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn curve_stays_in_bounds() {
        let pf = Pathfinder::new(32);
        prop::check(
            "curve points inside canvas",
            prop::Config { cases: 40, ..Default::default() },
            |rng| pf.curve(rng, 64),
            |c| {
                for &(x, y) in &c.points {
                    if !(0.0..32.0).contains(&x) || !(0.0..32.0).contains(&y) {
                        return Err(format!("point ({x},{y}) out of bounds"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn renders_bright_dots() {
        let pf = Pathfinder::new(32);
        let ex = pf.example(&mut Rng::new(2), 1024);
        let bright = ex.tokens.iter().filter(|&&t| t > 230).count();
        assert!(bright >= 8, "expected endpoint dots, got {bright} bright px");
    }

    #[test]
    fn pathx_is_16k_tokens() {
        let pf = Pathfinder::new(128);
        let ex = pf.example(&mut Rng::new(3), 16384);
        assert_eq!(ex.tokens.len(), 16384);
        assert_eq!(pf.name(), "pathx");
    }

    #[test]
    fn labels_balanced() {
        let pf = Pathfinder::new(32);
        let mut rng = Rng::new(17);
        let pos: i32 = (0..100).map(|_| pf.example(&mut rng, 1024).label).sum();
        assert!((25..75).contains(&pos), "{pos}/100 positive");
    }
}
