//! Retrieval (dual-encoder document matching): synthetic stand-in for
//! LRA's ACL Anthology citation-link task.
//!
//! Each "paper" is an abstract written from a latent topic's vocabulary
//! (with a citation-key header line).  A positive pair shares the topic
//! and cites a common key; a negative pair is drawn from two different
//! topics.  The model must compress two ~N-char byte sequences into
//! features whose interaction predicts relatedness — the same skill as the
//! AAN task.  Byte-level tokens, two documents per example: (B, 2, N).

use crate::util::rng::Rng;

use super::{fit, Example, TaskGen};

/// Topic vocabularies: disjoint content words per latent topic.
const TOPICS: &[&[&str]] = &[
    &["parser", "grammar", "syntax", "treebank", "constituent", "dependency", "tagger"],
    &["embedding", "vector", "semantic", "similarity", "analogy", "corpus", "distributional"],
    &["translation", "bilingual", "alignment", "decoder", "phrase", "fluency", "bleu"],
    &["sentiment", "polarity", "opinion", "review", "subjective", "lexicon", "stance"],
    &["dialogue", "utterance", "intent", "slot", "turn", "response", "conversational"],
    &["summarization", "extractive", "abstractive", "salience", "rouge", "compression", "headline"],
    &["speech", "acoustic", "phoneme", "transcription", "prosody", "recognizer", "audio"],
    &["retrieval", "query", "ranking", "relevance", "index", "document", "recall"],
];

const CONNECTIVES: &[&str] = &[
    "we propose", "we present", "results show", "in contrast to", "building on",
    "we evaluate", "compared with", "this paper studies", "we analyze", "experiments on",
];

#[derive(Default)]
pub struct Retrieval;

impl Retrieval {
    fn abstract_text(&self, rng: &mut Rng, topic: usize, cite: u32, approx: usize) -> String {
        let words = TOPICS[topic];
        let mut out = format!("anthology:{cite:08x}\n");
        while out.len() < approx {
            let conn = rng.choice(CONNECTIVES);
            let a = rng.choice(words);
            let b = rng.choice(words);
            let noise_topic = rng.below(TOPICS.len());
            let c = rng.choice(TOPICS[noise_topic]); // cross-topic noise
            out.push_str(&format!("{conn} {a} {b} with {c} analysis. "));
            if rng.bool(0.15) {
                out.push_str(&format!("see anthology:{cite:08x}. "));
            }
        }
        out
    }
}

impl TaskGen for Retrieval {
    fn name(&self) -> &'static str {
        "retrieval"
    }

    fn vocab(&self) -> usize {
        256
    }

    fn n_classes(&self) -> usize {
        2
    }

    fn dual(&self) -> bool {
        true
    }

    fn example(&self, rng: &mut Rng, seq_len: usize) -> Example {
        let linked = rng.bool(0.5);
        let topic_a = rng.below(TOPICS.len());
        let cite_a = rng.next_u32();
        let (topic_b, cite_b) = if linked {
            (topic_a, cite_a)
        } else {
            // different topic, different citation key
            let mut t = rng.below(TOPICS.len());
            while t == topic_a {
                t = rng.below(TOPICS.len());
            }
            (t, rng.next_u32())
        };
        let approx = seq_len.saturating_sub(2).max(32);
        let doc_a = self.abstract_text(rng, topic_a, cite_a, approx);
        let doc_b = self.abstract_text(rng, topic_b, cite_b, approx);
        Example {
            tokens: fit(doc_a.bytes().map(|b| b as i32).collect(), seq_len),
            tokens2: Some(fit(doc_b.bytes().map(|b| b as i32).collect(), seq_len)),
            label: linked as i32,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn text_of(tokens: &[i32]) -> String {
        tokens.iter().take_while(|&&t| t != 0).map(|&t| t as u8 as char).collect()
    }

    fn dominant_topic(text: &str) -> usize {
        let mut counts = vec![0usize; TOPICS.len()];
        for w in text.split(|c: char| !c.is_ascii_alphanumeric()) {
            for (t, words) in TOPICS.iter().enumerate() {
                if words.contains(&w) {
                    counts[t] += 1;
                }
            }
        }
        counts.iter().enumerate().max_by_key(|(_, &c)| c).unwrap().0
    }

    #[test]
    fn prop_positive_pairs_share_topic_and_key() {
        let gen = Retrieval;
        prop::check(
            "linked docs share citation key and dominant topic",
            prop::Config { cases: 60, ..Default::default() },
            |rng| gen.example(rng, 1024),
            |ex| {
                let a = text_of(&ex.tokens);
                let b = text_of(ex.tokens2.as_ref().unwrap());
                let key_a = &a[..19.min(a.len())];
                let key_b = &b[..19.min(b.len())];
                let same_key = key_a == key_b;
                if ex.label == 1 && !same_key {
                    return Err(format!("positive pair, different keys: {key_a} vs {key_b}"));
                }
                if ex.label == 0 && same_key {
                    return Err("negative pair, same key".into());
                }
                if ex.label == 1 && dominant_topic(&a) != dominant_topic(&b) {
                    return Err("positive pair with different dominant topics".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn labels_balanced() {
        let gen = Retrieval;
        let mut rng = Rng::new(11);
        let pos: i32 = (0..100).map(|_| gen.example(&mut rng, 256).label).sum();
        assert!((25..75).contains(&pos), "{pos}/100 positive");
    }
}
