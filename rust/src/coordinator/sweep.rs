//! The sweep runner: executes job lists (hyperparameter sweeps, the
//! Figure-3 ablation grid, the Table-1/5 efficiency rows).
//!
//! Two execution modes:
//! * **in-process** — shares one PJRT engine; right for accuracy sweeps.
//! * **isolated** — re-invokes the current binary (`cast _job …`) per job
//!   so each measurement gets a private address space and its `VmHWM`
//!   (peak RSS) is attributable to that config alone.  This is how the
//!   paper's peak-memory columns are reproduced on CPU.

use std::path::Path;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::data;
use crate::runtime::{Engine, HostTensor, Manifest};
use crate::train::{score_logits, Trainer};
use crate::util::json::Json;
use crate::util::Timer;

use super::events::EventLog;
use super::jobs::{Job, JobKind, JobResult};

pub struct Sweep {
    pub log: EventLog,
}

impl Default for Sweep {
    fn default() -> Self {
        Self::new()
    }
}

impl Sweep {
    pub fn new() -> Sweep {
        Sweep { log: EventLog::new() }
    }

    /// Run a job inside this process (engine shared / cached).
    pub fn run_inprocess(&self, engine: &Arc<Engine>, job: &Job) -> Result<JobResult> {
        self.log.emit("job_start", job.describe());
        let manifest = Manifest::load(&job.artifact_dir)?;
        let key = manifest.key.clone();
        let result = match job.kind {
            JobKind::Train { .. } | JobKind::TrainEfficiency { .. } => {
                let mut trainer =
                    Trainer::new(engine.clone(), manifest, job.train_config(), job.seed as u32)?;
                let report = trainer.run()?;
                JobResult {
                    key,
                    kind: kind_name(&job.kind).into(),
                    steps_per_sec: report.steps_per_sec,
                    peak_rss_bytes: crate::util::peak_rss_bytes().unwrap_or(0),
                    final_loss: report.final_train_loss,
                    final_acc: report.final_train_acc,
                    eval_acc: report.best_eval_acc,
                }
            }
            JobKind::InferEfficiency { steps } => {
                self.infer_efficiency(engine, &manifest, steps, job.seed)?
            }
        };
        self.log.emit("job_done", format!("{} {:.3} steps/s", result.key, result.steps_per_sec));
        Ok(result)
    }

    /// Inference throughput: run `predict` over `steps` batches.
    fn infer_efficiency(
        &self,
        engine: &Arc<Engine>,
        manifest: &Manifest,
        steps: usize,
        seed: u64,
    ) -> Result<JobResult> {
        let gen = data::task(&manifest.meta.task)?;
        let exe = engine.load(manifest, "predict")?;
        let state = crate::model::ModelState::init(engine, manifest, seed as u32)?;
        let mut rng = crate::util::rng::Rng::new(seed);
        // warmup execution (compile/caches) excluded from timing
        let warm = data::make_batch(gen.as_ref(), &mut rng, manifest.meta.batch, manifest.meta.seq_len);
        let mut inputs: Vec<HostTensor> = state.params.clone();
        inputs.push(warm.tokens);
        let _ = exe.run(&inputs)?;

        let mut correct = 0usize;
        let mut total = 0usize;
        let timer = Timer::start();
        for _ in 0..steps {
            let batch =
                data::make_batch(gen.as_ref(), &mut rng, manifest.meta.batch, manifest.meta.seq_len);
            let mut inputs: Vec<HostTensor> = state.params.clone();
            inputs.push(batch.tokens);
            let out = exe.run(&inputs)?;
            let (c, _) = score_logits(&out[0], batch.labels.as_s32()?)?;
            correct += c;
            total += manifest.meta.batch;
        }
        let secs = timer.seconds();
        Ok(JobResult {
            key: manifest.key.clone(),
            kind: "infer_eff".into(),
            steps_per_sec: steps as f64 / secs.max(1e-9),
            peak_rss_bytes: crate::util::peak_rss_bytes().unwrap_or(0),
            final_loss: f32::NAN,
            final_acc: correct as f32 / total.max(1) as f32,
            eval_acc: None,
        })
    }

    /// Run a job in a child process for isolated peak-RSS measurement.
    pub fn run_isolated(&self, job: &Job) -> Result<JobResult> {
        self.log.emit("job_spawn", job.describe());
        let exe = coordinator_binary()?;
        let (kind, steps) = match job.kind {
            JobKind::Train { steps, .. } => ("train", steps),
            JobKind::TrainEfficiency { steps } => ("train_eff", steps),
            JobKind::InferEfficiency { steps } => ("infer_eff", steps),
        };
        let out = std::process::Command::new(exe)
            .args([
                "_job",
                "--dir",
                job.artifact_dir.to_str().unwrap(),
                "--kind",
                kind,
                "--steps",
                &steps.to_string(),
                "--seed",
                &job.seed.to_string(),
            ])
            .output()
            .context("spawning job child")?;
        if !out.status.success() {
            bail!(
                "job child failed ({}): {}",
                out.status,
                String::from_utf8_lossy(&out.stderr)
            );
        }
        let stdout = String::from_utf8_lossy(&out.stdout);
        // last line of stdout is the result JSON
        let line = stdout
            .lines()
            .rev()
            .find(|l| l.trim_start().starts_with('{'))
            .context("no JSON result from job child")?;
        let parsed = Json::parse(line.trim()).context("parsing job child result")?;
        let result = JobResult::from_json(&parsed)?;
        self.log.emit("job_done", format!("{} (isolated)", result.key));
        Ok(result)
    }

    /// Run all jobs; `isolate` selects child-process mode.
    pub fn run_all(
        &self,
        engine: &Arc<Engine>,
        jobs: &[Job],
        isolate: bool,
    ) -> Vec<(Job, Result<JobResult>)> {
        jobs.iter()
            .map(|job| {
                let res = if isolate {
                    self.run_isolated(job)
                } else {
                    self.run_inprocess(engine, job)
                };
                if let Err(e) = &res {
                    self.log.emit("job_error", format!("{}: {e:#}", job.describe()));
                }
                (job.clone(), res)
            })
            .collect()
    }
}

/// Resolve the `cast` coordinator binary for isolated child jobs.
///
/// MUST NOT blindly use `current_exe()`: when the caller is a bench/test
/// binary, spawning itself with `_job` args would recursively re-run the
/// whole bench (a self-replicating process chain).  Resolution order:
/// `$CAST_BIN` override → current exe if it *is* `cast` → a `cast` file in
/// an ancestor target directory (bench/test binaries live in
/// `target/<profile>/deps/`, the bin one level up).
pub fn coordinator_binary() -> Result<std::path::PathBuf> {
    if let Ok(p) = std::env::var("CAST_BIN") {
        let p = std::path::PathBuf::from(p);
        anyhow::ensure!(p.is_file(), "CAST_BIN={p:?} does not exist");
        return Ok(p);
    }
    let exe = std::env::current_exe().context("resolving current executable")?;
    if exe.file_stem().map(|s| s == "cast").unwrap_or(false) {
        return Ok(exe);
    }
    for anc in exe.ancestors().skip(1) {
        let cand = anc.join("cast");
        if cand.is_file() {
            return Ok(cand);
        }
    }
    bail!(
        "cannot locate the `cast` binary near {exe:?}; build it \
         (`cargo build --release`) or set CAST_BIN"
    )
}

pub fn kind_name(kind: &JobKind) -> &'static str {
    match kind {
        JobKind::Train { .. } => "train",
        JobKind::TrainEfficiency { .. } => "train_eff",
        JobKind::InferEfficiency { .. } => "infer_eff",
    }
}

/// One point on the variant bake-off's accuracy-vs-throughput frontier:
/// a (variant, task) pair trained from scratch on a synthetic config.
pub struct FrontierPoint {
    pub task: String,
    pub variant: String,
    pub key: String,
    pub seq_len: usize,
    pub steps_per_sec: f64,
    pub first_loss: f32,
    pub final_loss: f32,
    pub final_acc: f32,
    pub eval_acc: f32,
}

/// The variant bake-off behind `cast sweep`: for every task × variant,
/// synthesize a tiny config, train it for `steps` steps, and measure
/// throughput plus train/eval accuracy — the repo's Table-2 frontier.
/// All configs share the geometry of `tiny_meta_for_task`, so
/// steps-per-sec is comparable across variants.
pub fn run_frontier(
    engine: &Arc<Engine>,
    tasks: &[String],
    variants: &[&str],
    steps: usize,
    seed: u64,
) -> Result<Vec<FrontierPoint>> {
    use crate::runtime::native::spec;
    use crate::train::{Schedule, TrainConfig};
    let mut points = Vec::with_capacity(tasks.len() * variants.len());
    for task in tasks {
        for &variant in variants {
            let meta = spec::tiny_meta_for_task(task, variant)?;
            let manifest = Manifest::synthetic(meta);
            let key = manifest.key.clone();
            let seq_len = manifest.meta.seq_len;
            let cfg = TrainConfig {
                steps,
                schedule: Schedule::Warmup { lr: 1e-3, warmup: (steps / 10).max(1) },
                seed,
                eval_every: 0,
                eval_batches: 8,
                ..Default::default()
            };
            let mut trainer = Trainer::new(engine.clone(), manifest, cfg, seed as u32)?;
            let report = trainer.run()?;
            let first_loss = report
                .history
                .steps
                .first()
                .map(|r| r.loss)
                .context("frontier run recorded no training steps")?;
            let eval_acc = report.best_eval_acc.unwrap_or(f32::NAN);
            points.push(FrontierPoint {
                task: task.clone(),
                variant: variant.to_string(),
                key,
                seq_len,
                steps_per_sec: report.steps_per_sec,
                first_loss,
                final_loss: report.final_train_loss,
                final_acc: report.final_train_acc,
                eval_acc,
            });
        }
    }
    Ok(points)
}

/// Discover jobs for every artifact directory matching a key predicate.
pub fn jobs_matching(
    artifacts_root: &Path,
    pred: impl Fn(&str) -> bool,
    kind: JobKind,
    seed: u64,
) -> Vec<Job> {
    crate::runtime::artifacts::discover(artifacts_root)
        .into_iter()
        .filter(|dir| {
            dir.file_name().map(|n| pred(&n.to_string_lossy())).unwrap_or(false)
        })
        .map(|dir| Job { artifact_dir: dir, kind: kind.clone(), seed })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn jobs_matching_filters_by_key() {
        let root = std::env::temp_dir().join("cast_sweep_test");
        let _ = std::fs::remove_dir_all(&root);
        for name in ["text_cast_a", "text_vanilla_b", "image_cast_c"] {
            let d = root.join(name);
            std::fs::create_dir_all(&d).unwrap();
            std::fs::write(d.join("manifest.json"), "{}").unwrap();
        }
        let jobs = jobs_matching(
            &root,
            |k| k.starts_with("text_"),
            JobKind::TrainEfficiency { steps: 3 },
            0,
        );
        assert_eq!(jobs.len(), 2);
        assert!(jobs.iter().all(|j| j.artifact_dir.to_string_lossy().contains("text_")));
    }
}

#[cfg(test)]
mod binary_tests {
    #[test]
    fn coordinator_binary_never_returns_a_test_binary() {
        // current_exe here is the unit-test binary in target/debug/deps;
        // the resolver must either find a real `cast` bin or error —
        // never return ourselves (which caused a self-spawning chain).
        match super::coordinator_binary() {
            Ok(p) => assert_eq!(p.file_stem().unwrap(), "cast", "{p:?}"),
            Err(_) => {} // acceptable when the bin hasn't been built
        }
    }
}
