//! Append-only event log shared across coordinator components; dumped as
//! JSON next to experiment outputs so every run is auditable.

use std::sync::Mutex;
use std::time::{SystemTime, UNIX_EPOCH};

use crate::util::json::Json;

#[derive(Clone, Debug)]
pub struct Event {
    pub t: f64,
    pub kind: String,
    pub detail: String,
}

#[derive(Default)]
pub struct EventLog {
    events: Mutex<Vec<Event>>,
}

impl EventLog {
    pub fn new() -> EventLog {
        EventLog::default()
    }

    pub fn emit(&self, kind: &str, detail: impl Into<String>) {
        let t = SystemTime::now()
            .duration_since(UNIX_EPOCH)
            .map(|d| d.as_secs_f64())
            .unwrap_or(0.0);
        let e = Event { t, kind: kind.to_string(), detail: detail.into() };
        crate::debug!("event {}: {}", e.kind, e.detail);
        self.events.lock().unwrap().push(e);
    }

    pub fn len(&self) -> usize {
        self.events.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn count(&self, kind: &str) -> usize {
        self.events.lock().unwrap().iter().filter(|e| e.kind == kind).count()
    }

    pub fn to_json(&self) -> Json {
        Json::Arr(
            self.events
                .lock()
                .unwrap()
                .iter()
                .map(|e| {
                    Json::obj(vec![
                        ("t", Json::num(e.t)),
                        ("kind", Json::str(&e.kind)),
                        ("detail", Json::str(&e.detail)),
                    ])
                })
                .collect(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn emits_and_counts() {
        let log = EventLog::new();
        log.emit("job_start", "a");
        log.emit("job_done", "a");
        log.emit("job_start", "b");
        assert_eq!(log.len(), 3);
        assert_eq!(log.count("job_start"), 2);
        let j = log.to_json().to_string();
        assert!(j.contains("job_done"));
    }
}
