//! The coordinator: job specifications, the sweep/ablation runner, and the
//! event log.
//!
//! The paper's contribution lives at L1/L2 (the attention mechanism), so —
//! per the architecture notes — L3's coordination role is the *experiment
//! orchestrator*: it owns process lifecycle, artifact discovery, the
//! training/benchmark job queue, per-job isolation (child processes for
//! peak-memory fidelity), and result aggregation into the paper's tables
//! and figures.

pub mod events;
pub mod jobs;
pub mod sweep;

pub use events::{Event, EventLog};
pub use jobs::{Job, JobKind, JobResult};
pub use sweep::Sweep;
