//! Job specifications: a unit of coordinated work over one artifact
//! directory — training run, efficiency measurement, or evaluation.

use std::path::PathBuf;

use anyhow::Result;

use crate::train::{Schedule, TrainConfig};
use crate::util::json::Json;

#[derive(Clone, Debug, PartialEq)]
pub enum JobKind {
    /// Train for `steps` steps, report loss/accuracy trajectory.
    Train { steps: usize, lr: f32, warmup: usize },
    /// Measure training throughput + peak memory (Table 1 / Fig 3 rows).
    TrainEfficiency { steps: usize },
    /// Measure inference throughput + peak memory (Table 5 rows).
    InferEfficiency { steps: usize },
}

#[derive(Clone, Debug)]
pub struct Job {
    pub artifact_dir: PathBuf,
    pub kind: JobKind,
    pub seed: u64,
}

impl Job {
    pub fn train_config(&self) -> TrainConfig {
        match self.kind {
            JobKind::Train { steps, lr, warmup } => TrainConfig {
                steps,
                schedule: Schedule::Warmup { lr, warmup },
                seed: self.seed,
                eval_every: 0,
                eval_batches: 8,
                ..Default::default()
            },
            JobKind::TrainEfficiency { steps } => TrainConfig {
                steps,
                schedule: Schedule::Constant { lr: 1e-3 },
                seed: self.seed,
                eval_every: 0,
                eval_batches: 0,
                log_every: 0,
                ..Default::default()
            },
            JobKind::InferEfficiency { steps } => TrainConfig {
                steps,
                schedule: Schedule::Constant { lr: 0.0 },
                seed: self.seed,
                eval_every: 0,
                eval_batches: steps,
                log_every: 0,
                ..Default::default()
            },
        }
    }

    pub fn describe(&self) -> String {
        format!("{:?} on {}", self.kind, self.artifact_dir.display())
    }
}

/// The outcome of a job, as aggregated by the sweep runner.
#[derive(Clone, Debug)]
pub struct JobResult {
    pub key: String,
    pub kind: String,
    pub steps_per_sec: f64,
    pub peak_rss_bytes: u64,
    pub final_loss: f32,
    pub final_acc: f32,
    pub eval_acc: Option<f32>,
}

impl JobResult {
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("key", Json::str(&self.key)),
            ("kind", Json::str(&self.kind)),
            ("steps_per_sec", Json::num(self.steps_per_sec)),
            ("peak_rss_bytes", Json::num(self.peak_rss_bytes as f64)),
            ("final_loss", Json::num(self.final_loss as f64)),
            ("final_acc", Json::num(self.final_acc as f64)),
        ];
        if let Some(acc) = self.eval_acc {
            fields.push(("eval_acc", Json::num(acc as f64)));
        }
        Json::obj(fields)
    }

    pub fn from_json(j: &Json) -> Result<JobResult> {
        use anyhow::Context;
        Ok(JobResult {
            key: j.get("key").and_then(Json::as_str).context("key")?.to_string(),
            kind: j.get("kind").and_then(Json::as_str).context("kind")?.to_string(),
            steps_per_sec: j.get("steps_per_sec").and_then(Json::as_f64).context("sps")?,
            peak_rss_bytes: j
                .get("peak_rss_bytes")
                .and_then(Json::as_f64)
                .context("rss")? as u64,
            final_loss: j.get("final_loss").and_then(Json::as_f64).unwrap_or(f64::NAN) as f32,
            final_acc: j.get("final_acc").and_then(Json::as_f64).unwrap_or(f64::NAN) as f32,
            eval_acc: j.get("eval_acc").and_then(Json::as_f64).map(|x| x as f32),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn train_config_from_kind() {
        let job = Job {
            artifact_dir: PathBuf::from("/tmp/x"),
            kind: JobKind::Train { steps: 50, lr: 2e-3, warmup: 5 },
            seed: 9,
        };
        let cfg = job.train_config();
        assert_eq!(cfg.steps, 50);
        assert_eq!(cfg.schedule, Schedule::Warmup { lr: 2e-3, warmup: 5 });
        assert_eq!(cfg.seed, 9);
    }

    #[test]
    fn result_json_roundtrip() {
        let r = JobResult {
            key: "k".into(),
            kind: "train".into(),
            steps_per_sec: 3.5,
            peak_rss_bytes: 1024,
            final_loss: 0.5,
            final_acc: 0.9,
            eval_acc: Some(0.8),
        };
        let j = Json::parse(&r.to_json().to_string()).unwrap();
        let back = JobResult::from_json(&j).unwrap();
        assert_eq!(back.key, "k");
        assert_eq!(back.peak_rss_bytes, 1024);
        assert_eq!(back.eval_acc, Some(0.8));
    }
}
