//! Cluster visualization (paper Figure 4, Appendix Figures 7–9).
//!
//! Runs the `predict_ag` artifact to extract the per-layer affinity matrix
//! A_g ∈ (L, B, N, Nc), derives each token's cluster assignment
//! (argmax over clusters — the Top-K limit the paper visualizes), and for
//! image tasks renders:
//!   * the input image (PGM),
//!   * per-layer cluster-assignment maps (PPM, one color per cluster),
//!   * per-layer, per-cluster A_g score heatmaps (PGM) — the
//!     foreground/background separation evidence of §5.4.

use std::path::Path;
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::model::ModelState;
use crate::runtime::{Engine, HostTensor, Manifest};
use crate::util::pgm::{Gray, Rgb};

/// A_g for one forward pass: `scores[layer][token][cluster]` for batch
/// element `b_idx`.
pub struct AgScores {
    pub layers: usize,
    pub n: usize,
    pub n_c: usize,
    pub scores: Vec<f32>, // (L, N, Nc) for the selected batch element
}

impl AgScores {
    pub fn at(&self, layer: usize, token: usize, cluster: usize) -> f32 {
        self.scores[(layer * self.n + token) * self.n_c + cluster]
    }

    /// Argmax cluster per token for a layer (first max on ties, like
    /// numpy's argmax).
    pub fn assignments(&self, layer: usize) -> Vec<usize> {
        (0..self.n)
            .map(|t| {
                let mut arg = 0;
                for c in 1..self.n_c {
                    if self.at(layer, t, c) > self.at(layer, t, arg) {
                        arg = c;
                    }
                }
                arg
            })
            .collect()
    }

    /// One cluster's score column as an (N,) slice copy.
    pub fn cluster_scores(&self, layer: usize, cluster: usize) -> Vec<f32> {
        (0..self.n).map(|t| self.at(layer, t, cluster)).collect()
    }
}

/// Execute predict_ag and pull out batch element `b_idx`.
pub fn cluster_assignments(
    engine: &Arc<Engine>,
    manifest: &Manifest,
    state: &ModelState,
    tokens: &HostTensor,
    b_idx: usize,
) -> Result<AgScores> {
    let exe = engine.load(manifest, "predict_ag")?;
    let mut inputs: Vec<HostTensor> = state.params.clone();
    inputs.push(tokens.clone());
    let out = exe.run(&inputs).context("predict_ag execution")?;
    let ag = &out[0];
    anyhow::ensure!(ag.shape.len() == 4, "A_g shape {:?}, want (L,B,N,Nc)", ag.shape);
    let (l, b, n, n_c) = (ag.shape[0], ag.shape[1], ag.shape[2], ag.shape[3]);
    anyhow::ensure!(b_idx < b, "batch index {b_idx} out of range {b}");
    let v = ag.as_f32()?;
    let mut scores = Vec::with_capacity(l * n * n_c);
    for layer in 0..l {
        let base = (layer * b + b_idx) * n * n_c;
        scores.extend_from_slice(&v[base..base + n * n_c]);
    }
    Ok(AgScores { layers: l, n, n_c, scores })
}

/// Full Figure-4 pipeline for an image-task model: writes
///   input.pgm, layer{i}_clusters.ppm, layer{i}_cluster{c}_scores.pgm
/// into `out_dir`.  Returns the list of files written.
pub fn visualize_image_clusters(
    engine: &Arc<Engine>,
    manifest: &Manifest,
    state: &ModelState,
    tokens: &HostTensor,
    b_idx: usize,
    out_dir: &Path,
) -> Result<Vec<std::path::PathBuf>> {
    let n = manifest.meta.seq_len;
    let side = (n as f64).sqrt() as usize;
    anyhow::ensure!(side * side == n, "not an image task: seq_len {n} is not square");
    anyhow::ensure!(
        tokens.shape.len() >= 2 && tokens.shape[tokens.shape.len() - 1] == n,
        "tokens must be a (B, .., {n}) batch, got shape {:?}",
        tokens.shape
    );
    let b_total = tokens.shape[0];
    anyhow::ensure!(
        b_idx < b_total,
        "batch index {b_idx} out of range: tokens batch dimension is {b_total}"
    );
    std::fs::create_dir_all(out_dir)?;
    let mut written = Vec::new();

    // input image
    let toks = tokens.as_s32()?;
    let img: Vec<f32> = toks[b_idx * n..(b_idx + 1) * n].iter().map(|&t| t as f32).collect();
    let p = out_dir.join("input.pgm");
    Gray::from_f32(side, side, &img).save(&p)?;
    written.push(p);

    let ag = cluster_assignments(engine, manifest, state, tokens, b_idx)?;
    for layer in 0..ag.layers {
        let assign = ag.assignments(layer);
        let p = out_dir.join(format!("layer{layer}_clusters.ppm"));
        Rgb::from_labels(side, side, &assign).save(&p)?;
        written.push(p);
        for c in 0..ag.n_c {
            let scores = ag.cluster_scores(layer, c);
            let p = out_dir.join(format!("layer{layer}_cluster{c}_scores.pgm"));
            Gray::from_f32(side, side, &scores).save(&p)?;
            written.push(p);
        }
    }
    Ok(written)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ag_scores_indexing_and_argmax() {
        // 1 layer, 3 tokens, 2 clusters
        let scores = vec![
            0.9, 0.1, // token 0 -> cluster 0
            0.2, 0.8, // token 1 -> cluster 1
            0.5, 0.5, // token 2 -> tie, argmax -> 0
        ];
        let ag = AgScores { layers: 1, n: 3, n_c: 2, scores };
        assert_eq!(ag.assignments(0), vec![0, 1, 0]);
        assert_eq!(ag.cluster_scores(0, 1), vec![0.1, 0.8, 0.5]);
        assert_eq!(ag.at(0, 1, 1), 0.8);
    }
}
