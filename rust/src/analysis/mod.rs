//! Post-hoc analysis of trained models (paper §5.4 + Appendix A.6.3).

pub mod clusters;

pub use clusters::{cluster_assignments, visualize_image_clusters};
